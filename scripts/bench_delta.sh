#!/usr/bin/env bash
# Generate the repo's measured-perf trajectory files:
#   BENCH_0.json — `hostencil bench --json` at a baseline commit
#                  (default: the parent of HEAD)
#   BENCH_1.json — the same bench on the current working tree
#   BENCH_2.json — the working tree's persistent-pool thread sweep
#                  (`bench --thread-sweep`): per-worker-count
#                  steady-state rates + parallel efficiency
# and print the per-shape speedup plus the pool's thread scaling. Run
# from the repository root in a cargo-capable environment, then commit
# the files:
#
#   ./scripts/bench_delta.sh [baseline-ref]
#
# Honors HOSTENCIL_BENCH_SAMPLES / HOSTENCIL_BENCH_WARMUP and
# BENCH_SIZE / BENCH_STEPS / BENCH_SWEEP.
set -euo pipefail

BASE_REF="${1:-HEAD~1}"
SIZE="${BENCH_SIZE:-40}"
STEPS="${BENCH_STEPS:-6}"
SWEEP="${BENCH_SWEEP:-1,2,4,8}"
OUT_DIR="$(pwd)"

if ! git rev-parse --verify --quiet "$BASE_REF^{commit}" >/dev/null; then
  echo "bench_delta: baseline ref $BASE_REF not found (shallow clone?)" >&2
  exit 1
fi

TMP_ROOT="$(mktemp -d)"
WORKTREE="$TMP_ROOT/hostencil-base"
git worktree add --detach "$WORKTREE" "$BASE_REF" >/dev/null
cleanup() {
  git worktree remove --force "$WORKTREE" >/dev/null 2>&1 || true
  rm -rf "$TMP_ROOT"
}
trap cleanup EXIT

echo "== baseline $(git rev-parse --short "$BASE_REF") -> BENCH_0.json"
(cd "$WORKTREE" && cargo run --release -p hostencil -- bench \
  --size "$SIZE" --steps "$STEPS" --json "$OUT_DIR/BENCH_0.json")

# One head-side run yields both the matrix (cases) and the pool sweep
# (thread_sweep); BENCH_2 is split out of BENCH_1's JSON below instead
# of re-benching the whole matrix a second time.
echo "== working tree (+ pool thread sweep $SWEEP) -> BENCH_1.json / BENCH_2.json"
cargo run --release -p hostencil -- bench \
  --size "$SIZE" --steps "$STEPS" --thread-sweep "$SWEEP" \
  --json "$OUT_DIR/BENCH_1.json"

python3 - "$OUT_DIR/BENCH_0.json" "$OUT_DIR/BENCH_1.json" "$OUT_DIR/BENCH_2.json" <<'EOF'
import json, sys

def rates(path):
    doc = json.load(open(path))
    out = {}
    for c in doc.get("cases", []):
        # format v2 carries the steady-state (min) rate; v1 only median
        out[c["name"]] = c.get("points_per_sec_best", c.get("points_per_sec", 0.0))
    return out

head = json.load(open(sys.argv[2]))

# BENCH_2: the pool's thread sweep, split out of the head run so the
# scaling trajectory is a standalone committable artifact
sweep = head.pop("thread_sweep", [])
bench2 = {k: head[k] for k in ("format_version", "grid", "steps_per_sample", "samples", "warmup") if k in head}
bench2["kind"] = "hostencil-bench-thread-sweep"
bench2["thread_sweep"] = sweep
with open(sys.argv[3], "w") as f:
    json.dump(bench2, f, indent=1)

base, new = rates(sys.argv[1]), rates(sys.argv[2])
print(f"{'shape':<24}{'BENCH_0 Mpts/s':>16}{'BENCH_1 Mpts/s':>16}{'speedup':>9}")
for name in new:
    b, n = base.get(name, 0.0), new[name]
    s = f"{n / b:6.2f}x" if b > 0 else "   new"
    print(f"{name:<24}{b / 1e6:>16.2f}{n / 1e6:>16.2f}{s:>9}")

if sweep:
    print(f"\npool thread scaling (steady-state min; eff = rate_T / (T x rate_1)):")
    print(f"{'shape':<24}{'threads':>8}{'Mpts/s':>12}{'efficiency':>12}")
    for r in sweep:
        eff = f"{100.0 * r['efficiency']:9.0f}%" if "efficiency" in r else "        -"
        print(f"{r['name']:<24}{int(r['threads']):>8}{r['points_per_sec_best'] / 1e6:>12.2f}{eff:>12}")
EOF
