#!/usr/bin/env bash
# Generate the repo's measured-perf trajectory files:
#   BENCH_0.json — `hostencil bench --json` at a baseline commit
#                  (default: the parent of HEAD)
#   BENCH_1.json — the same bench on the current working tree
#   BENCH_2.json — the working tree's persistent-pool thread sweep
#                  (`bench --thread-sweep`): per-worker-count
#                  steady-state rates + parallel efficiency (plus the
#                  Amdahl scaling_model fit when the sweep includes 1)
#   BENCH_3.json — the working tree's temporal-fusion sweep
#                  (`bench --fuse 1,2,4`): steady-state rate per fusion
#                  degree with speedups vs the unfused s=1 control
#   BENCH_4.json — the working tree's scalar-vs-SIMD row-kernel sweep
#                  (`bench --simd-sweep`, built `--features simd`):
#                  per-shape forced-scalar vs dispatched-SIMD rates at
#                  threads=1 with speedups (docs/KERNELS.md)
#   BENCH_5.json — the working tree's shard scaling sweep
#                  (`bench --shard-sweep`): steady-state sharded
#                  steps/sec per z-slab shard count at fuse 2 with
#                  speedups vs the 1-shard control (docs/SHARDING.md)
#   BENCH_6.json — the working tree's checkpoint-cadence sweep
#                  (`bench --checkpoint-sweep`): steady-state fuse-2
#                  steps/sec per snapshot cadence with the overhead of
#                  each cadence vs the checkpointing-off control
#                  (docs/OPERATIONS.md)
#   BENCH_1.prom — the head run's Prometheus telemetry exposition
#                  (pool occupancy, tiles claimed, sweep latency
#                  histograms — see docs/METRICS.md)
# and print the per-shape speedup plus the pool's thread scaling. Run
# from the repository root in a cargo-capable environment, then commit
# the files:
#
#   ./scripts/bench_delta.sh [baseline-ref]
#
# Honors HOSTENCIL_BENCH_SAMPLES / HOSTENCIL_BENCH_WARMUP and
# BENCH_SIZE / BENCH_STEPS / BENCH_SWEEP / BENCH_FUSE / BENCH_SHARDS /
# BENCH_CKPT.
set -euo pipefail

BASE_REF="${1:-HEAD~1}"
SIZE="${BENCH_SIZE:-40}"
STEPS="${BENCH_STEPS:-6}"
SWEEP="${BENCH_SWEEP:-1,2,4,8}"
FUSE="${BENCH_FUSE:-1,2,4}"
SHARDS="${BENCH_SHARDS:-1,2,4}"
CKPT="${BENCH_CKPT:-0,8,1}"
OUT_DIR="$(pwd)"

if ! git rev-parse --verify --quiet "$BASE_REF^{commit}" >/dev/null; then
  echo "bench_delta: baseline ref $BASE_REF not found (shallow clone?)" >&2
  exit 1
fi

TMP_ROOT="$(mktemp -d)"
WORKTREE="$TMP_ROOT/hostencil-base"
git worktree add --detach "$WORKTREE" "$BASE_REF" >/dev/null
cleanup() {
  git worktree remove --force "$WORKTREE" >/dev/null 2>&1 || true
  rm -rf "$TMP_ROOT"
}
trap cleanup EXIT

echo "== baseline $(git rev-parse --short "$BASE_REF") -> BENCH_0.json"
(cd "$WORKTREE" && cargo run --release -p hostencil -- bench \
  --size "$SIZE" --steps "$STEPS" --json "$OUT_DIR/BENCH_0.json")

# One head-side run yields the matrix (cases), the pool sweep
# (thread_sweep + scaling_model), the fusion sweep (fuse_sweep), the
# scalar-vs-SIMD row sweep (simd_sweep — the head build carries
# `--features simd` so the dispatched leg is the wide kernel), the
# shard scaling sweep (shard_sweep) and the checkpoint-cadence sweep
# (checkpoint_sweep); BENCH_2..6 are split out of BENCH_1's JSON below
# instead of re-benching the whole matrix again.
echo "== working tree (+ pool sweep $SWEEP, fusion sweep $FUSE, simd sweep, shard sweep $SHARDS, checkpoint sweep $CKPT) -> BENCH_1/2/3/4/5/6.json + BENCH_1.prom"
cargo run --release --features simd -p hostencil -- bench \
  --size "$SIZE" --steps "$STEPS" --thread-sweep "$SWEEP" --fuse "$FUSE" --simd-sweep \
  --shard-sweep "$SHARDS" --checkpoint-sweep "$CKPT" \
  --json "$OUT_DIR/BENCH_1.json" --telemetry "$OUT_DIR/BENCH_1.prom"

python3 - "$OUT_DIR/BENCH_0.json" "$OUT_DIR/BENCH_1.json" "$OUT_DIR/BENCH_2.json" "$OUT_DIR/BENCH_3.json" "$OUT_DIR/BENCH_4.json" "$OUT_DIR/BENCH_5.json" "$OUT_DIR/BENCH_6.json" <<'EOF'
import json, sys

def rates(path):
    doc = json.load(open(path))
    out = {}
    for c in doc.get("cases", []):
        # format v2 carries the steady-state (min) rate; v1 only median
        out[c["name"]] = c.get("points_per_sec_best", c.get("points_per_sec", 0.0))
    return out

head = json.load(open(sys.argv[2]))

# BENCH_2: the pool's thread sweep (+ the Amdahl scaling-model fit),
# split out of the head run so the scaling trajectory is a standalone
# committable artifact
sweep = head.pop("thread_sweep", [])
scaling = head.pop("scaling_model", [])
meta_keys = ("format_version", "grid", "steps_per_sample", "samples", "warmup")
bench2 = {k: head[k] for k in meta_keys if k in head}
bench2["kind"] = "hostencil-bench-thread-sweep"
bench2["thread_sweep"] = sweep
bench2["scaling_model"] = scaling
with open(sys.argv[3], "w") as f:
    json.dump(bench2, f, indent=1)

# BENCH_3: the temporal-fusion sweep (s in {1,2,4}), same treatment
fuse = head.pop("fuse_sweep", [])
bench3 = {k: head[k] for k in meta_keys if k in head}
bench3["kind"] = "hostencil-bench-fuse-sweep"
bench3["fuse_sweep"] = fuse
with open(sys.argv[4], "w") as f:
    json.dump(bench3, f, indent=1)

# BENCH_4: the scalar-vs-SIMD row-kernel sweep (threads=1, forced
# scalar vs dispatched kernel per shape), same treatment
simd = head.pop("simd_sweep", [])
bench4 = {k: head[k] for k in meta_keys if k in head}
bench4["kind"] = "hostencil-bench-simd-sweep"
bench4["simd_sweep"] = simd
with open(sys.argv[5], "w") as f:
    json.dump(bench4, f, indent=1)

# BENCH_5: the z-slab shard scaling sweep (fuse 2, steady-state
# sharded steps/sec per shard count), same treatment
shard = head.pop("shard_sweep", [])
bench5 = {k: head[k] for k in meta_keys if k in head}
bench5["kind"] = "hostencil-bench-shard-sweep"
bench5["shard_sweep"] = shard
with open(sys.argv[6], "w") as f:
    json.dump(bench5, f, indent=1)

# BENCH_6: the checkpoint-cadence overhead sweep (fuse 2, snapshot
# every N steps vs the cadence-0 off control), same treatment
ckpt = head.pop("checkpoint_sweep", [])
bench6 = {k: head[k] for k in meta_keys if k in head}
bench6["kind"] = "hostencil-bench-checkpoint-sweep"
bench6["checkpoint_sweep"] = ckpt
with open(sys.argv[7], "w") as f:
    json.dump(bench6, f, indent=1)

# rewrite BENCH_1 without the sweeps it just donated, so the committed
# matrix artifact does not duplicate BENCH_2/BENCH_3's contents
with open(sys.argv[2], "w") as f:
    json.dump(head, f, indent=1)

base, new = rates(sys.argv[1]), rates(sys.argv[2])
print(f"{'shape':<24}{'BENCH_0 Mpts/s':>16}{'BENCH_1 Mpts/s':>16}{'speedup':>9}")
for name in new:
    b, n = base.get(name, 0.0), new[name]
    s = f"{n / b:6.2f}x" if b > 0 else "   new"
    print(f"{name:<24}{b / 1e6:>16.2f}{n / 1e6:>16.2f}{s:>9}")

if sweep:
    print(f"\npool thread scaling (steady-state min; eff = rate_T / (T x rate_1)):")
    print(f"{'shape':<24}{'threads':>8}{'Mpts/s':>12}{'efficiency':>12}")
    for r in sweep:
        eff = f"{100.0 * r['efficiency']:9.0f}%" if "efficiency" in r else "        -"
        print(f"{r['name']:<24}{int(r['threads']):>8}{r['points_per_sec_best'] / 1e6:>12.2f}{eff:>12}")

if scaling:
    print(f"\nscaling model (Amdahl serial fraction vs gpusim occupancy):")
    for r in scaling:
        sf = f"{100.0 * r['serial_fraction']:6.1f}%" if "serial_fraction" in r else "      -"
        oc = f"{r['occupancy_pct']:6.1f}%" if "occupancy_pct" in r else "      -"
        print(f"{r['name']:<24}serial {sf}   occupancy {oc}")

if fuse:
    print(f"\ntemporal-fusion sweep (tf_s{{S}}; speedup vs the s=1 control):")
    for r in fuse:
        sp = f"{r['speedup_vs_unfused']:6.2f}x" if "speedup_vs_unfused" in r else "      -"
        print(f"s={int(r['fuse']):<3}{r['points_per_sec_best'] / 1e6:>12.2f} Mpts/s{sp:>10}")

if simd:
    print(f"\nscalar -> SIMD row kernels (threads=1; dispatched vs forced scalar):")
    print(f"{'shape':<24}{'scalar Mpts/s':>15}{'simd Mpts/s':>13}{'speedup':>9}")
    for r in simd:
        print(
            f"{r['name']:<24}{r['scalar_points_per_sec_best'] / 1e6:>15.2f}"
            f"{r['simd_points_per_sec_best'] / 1e6:>13.2f}"
            f"{r['speedup_vs_scalar']:>8.2f}x  ({r['isa']}x{int(r['lanes'])})"
        )

if shard:
    print(f"\nz-slab shard scaling (fuse 2; speedup vs the 1-shard control):")
    for r in shard:
        sp = f"{r['speedup_vs_single']:6.2f}x" if "speedup_vs_single" in r else "      -"
        print(f"shards={int(r['shards']):<3}{r['steps_per_sec_best']:>10.1f} steps/s{sp:>10}")

if ckpt:
    print(f"\ncheckpoint cadence (fuse 2; overhead vs the cadence-off control):")
    for r in ckpt:
        ov = f"{100.0 * r['overhead_vs_off']:6.2f}%" if "overhead_vs_off" in r else "      -"
        label = "off" if int(r["every"]) == 0 else str(int(r["every"]))
        print(f"every={label:<4}{r['steps_per_sec_best']:>10.1f} steps/s  overhead{ov:>9}")
EOF
