#!/usr/bin/env bash
# Generate the repo's measured-perf trajectory files:
#   BENCH_0.json — `hostencil bench --json` at a baseline commit
#                  (default: the parent of HEAD)
#   BENCH_1.json — the same bench on the current working tree
# and print the per-shape speedup. Run from the repository root in a
# cargo-capable environment, then commit both files:
#
#   ./scripts/bench_delta.sh [baseline-ref]
#
# Honors HOSTENCIL_BENCH_SAMPLES / HOSTENCIL_BENCH_WARMUP and
# BENCH_SIZE / BENCH_STEPS.
set -euo pipefail

BASE_REF="${1:-HEAD~1}"
SIZE="${BENCH_SIZE:-40}"
STEPS="${BENCH_STEPS:-6}"
OUT_DIR="$(pwd)"

if ! git rev-parse --verify --quiet "$BASE_REF^{commit}" >/dev/null; then
  echo "bench_delta: baseline ref $BASE_REF not found (shallow clone?)" >&2
  exit 1
fi

TMP_ROOT="$(mktemp -d)"
WORKTREE="$TMP_ROOT/hostencil-base"
git worktree add --detach "$WORKTREE" "$BASE_REF" >/dev/null
cleanup() {
  git worktree remove --force "$WORKTREE" >/dev/null 2>&1 || true
  rm -rf "$TMP_ROOT"
}
trap cleanup EXIT

echo "== baseline $(git rev-parse --short "$BASE_REF") -> BENCH_0.json"
(cd "$WORKTREE" && cargo run --release -p hostencil -- bench \
  --size "$SIZE" --steps "$STEPS" --json "$OUT_DIR/BENCH_0.json")

echo "== working tree -> BENCH_1.json"
cargo run --release -p hostencil -- bench \
  --size "$SIZE" --steps "$STEPS" --json "$OUT_DIR/BENCH_1.json"

python3 - "$OUT_DIR/BENCH_0.json" "$OUT_DIR/BENCH_1.json" <<'EOF'
import json, sys

def rates(path):
    doc = json.load(open(path))
    out = {}
    for c in doc.get("cases", []):
        # format v2 carries the steady-state (min) rate; v1 only median
        out[c["name"]] = c.get("points_per_sec_best", c.get("points_per_sec", 0.0))
    return out

base, new = rates(sys.argv[1]), rates(sys.argv[2])
print(f"{'shape':<24}{'BENCH_0 Mpts/s':>16}{'BENCH_1 Mpts/s':>16}{'speedup':>9}")
for name in new:
    b, n = base.get(name, 0.0), new[name]
    s = f"{n / b:6.2f}x" if b > 0 else "   new"
    print(f"{name:<24}{b / 1e6:>16.2f}{n / 1e6:>16.2f}{s:>9}")
EOF
