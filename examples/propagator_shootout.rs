//! Propagator shootout: run every executable CPU code shape on
//! identical physics — no AOT artifacts needed — and rank them by
//! measured throughput, next to the gpusim prediction for the same
//! family on a chosen machine. This is the paper's Table II question
//! ("which code shape wins?") asked of the CPU engine instead of the
//! model.
//!
//! The matrix includes the temporally fused `tf_s2`/`tf_s4` rows:
//! those advance `s` leapfrog steps per memory sweep (`--fuse` on the
//! `run`/`bench` subcommands selects the same shapes), so the ranking
//! shows where temporal blocking pays against single-step tiling on
//! your machine. Each shape runs through `Coordinator::run`, which
//! batches fused families automatically — what you measure here is
//! the same path `hostencil run --fuse 2` takes.
//!
//!     cargo run --release --example propagator_shootout [steps] [machine]

use hostencil::coordinator::{Coordinator, Mode};
use hostencil::grid::{Dim3, Domain};
use hostencil::scenario::predict_perf;
use hostencil::stencil::{self, propagator};
use hostencil::wave::{self, Source, VelocityModel};

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(20);
    let machine = std::env::args().nth(2).unwrap_or_else(|| "v100".to_string());

    let n = 40usize;
    let interior = Dim3::new(n, n, n);
    let h = 10.0;
    let v0 = 2500.0f32;
    let domain = Domain::new(interior, 5, h, stencil::cfl_dt(h, v0 as f64))?;
    println!(
        "shootout: {steps} steps per shape on {} (pml {}), CPU engine vs gpusim/{machine}",
        domain.interior, domain.pml_width
    );

    let mut rows: Vec<(String, f64, f64, f64)> = Vec::new();
    for (label, variant) in propagator::bench_matrix() {
        let v = VelocityModel::Constant(v0).build(interior);
        let eta = wave::eta_profile(&domain, v0 as f64);
        let src = Source { pos: Dim3::new(n / 2, n / 2, n / 2), f0: 15.0, amplitude: 1.0 };
        let mut coord =
            Coordinator::new(None, domain, Mode::Golden, variant, "gmem", v, eta, src, vec![])?;
        coord.run(coord.fuse())?; // warm caches + plans before timing
        let summary = coord.run(steps)?; // fused families advance in batches here
        let wall = summary.wall.as_secs_f64();
        let mpts = (interior.volume() * steps) as f64 / wall / 1e6;
        // the naive reference has no Table II row to predict
        let predicted = if variant == "naive" {
            f64::NAN
        } else {
            predict_perf(&machine, variant)?.steps_per_sec
        };
        rows.push((label.to_string(), wall, mpts, predicted));
    }
    rows.sort_by(|a, b| a.1.total_cmp(&b.1));

    println!(
        "\n{:<24}{:>10}{:>12}{:>16}",
        "shape", "wall (s)", "Mpts/s", "pred st/s"
    );
    for (i, (name, wall, mpts, pred)) in rows.iter().enumerate() {
        let pred_str =
            if pred.is_nan() { "-".to_string() } else { format!("{pred:.1}") };
        println!("  {:>2}. {:<20}{:>8.3}{:>12.2}{:>16}", i + 1, name, wall, mpts, pred_str);
    }
    println!(
        "\nnote: CPU cache behavior, not occupancy, decides this ranking — compare\n\
         with `hostencil sweep --machine {machine}` for the modeled GPU ordering."
    );
    Ok(())
}
