//! Scenario gauntlet: run the whole named-scenario catalogue on the
//! pure-Rust golden backend (no AOT artifacts needed), print each
//! verdict with its criteria, then fan the scenario x variant matrix
//! out as a quick campaign on one machine.
//!
//!     cargo run --release --example scenario_gauntlet [machine]

use hostencil::report;
use hostencil::scenario::campaign::{run_campaign, CampaignSpec};
use hostencil::scenario::{run_scenario, RunnerOptions, ScenarioId};

fn main() -> anyhow::Result<()> {
    let machine = std::env::args().nth(1).unwrap_or_else(|| "v100".to_string());

    // 1. every scenario, sequentially, with full criterion detail
    println!("=== scenario gauntlet (golden backend) ===");
    let mut unexpected = 0;
    for id in ScenarioId::all() {
        let run = run_scenario(id, &RunnerOptions::default())?;
        let ok = run.as_expected();
        println!(
            "\n{} — {} (expected {}){}",
            id.name(),
            run.result.overall.name(),
            id.expected_verdict().name(),
            if ok { "" } else { "  <-- UNEXPECTED" }
        );
        println!("  {}", id.describe());
        for c in run.result.failed() {
            println!("  FAIL {:<22} {}", c.name, c.detail);
        }
        println!(
            "  {} steps, peak |u| {:.3e}, leakage {:.3}, {:.1} ms",
            run.metrics.steps_completed,
            run.metrics.peak_abs,
            run.metrics.boundary_leakage,
            run.metrics.wall_ms
        );
        if !ok {
            unexpected += 1;
        }
    }

    // 2. the same catalogue as a parallel campaign on one machine
    println!("\n=== quick campaign on {machine} ===");
    let spec = CampaignSpec {
        steps_scale: Some(0.5),
        ..CampaignSpec::full(vec![machine])
    };
    let report = run_campaign(&spec);
    print!("{}", report::campaign_table(&report));

    anyhow::ensure!(unexpected == 0, "{unexpected} scenario(s) off-catalogue");
    anyhow::ensure!(report.off_expectation_count() == 0, "campaign deviated from the catalogue");
    Ok(())
}
