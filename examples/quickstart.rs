//! Quickstart: load the AOT artifacts, propagate a wave for 50 steps
//! with the paper's 7-region launch topology, print a summary.
//!
//!     make artifacts && cargo run --release --example quickstart

use hostencil::coordinator::{Coordinator, Mode};
use hostencil::grid::Dim3;
use hostencil::runtime::Engine;
use hostencil::wave::{self, Source, VelocityModel};

fn main() -> anyhow::Result<()> {
    // 1. open the artifact set produced by `make artifacts`
    let engine = Engine::load("artifacts")?;
    let domain = engine.manifest().domain;
    println!(
        "domain {} (pml {}), dt {}s, h {}m — {} artifacts on {}",
        domain.interior,
        domain.pml_width,
        domain.dt,
        domain.h,
        engine.manifest().artifacts.len(),
        engine.platform()
    );

    // 2. physics: homogeneous medium, Ricker source at the center
    let v = VelocityModel::Constant(2500.0).build(domain.interior);
    let eta = wave::eta_profile(&domain, 2500.0);
    let c = domain.interior.z / 2;
    let source = Source { pos: Dim3::new(c, c, c), f0: 15.0, amplitude: 1.0 };

    // 3. coordinator: decomposed mode = 1 inner + 6 PML launches per step
    let mut coord = Coordinator::new(
        Some(&engine),
        domain,
        Mode::Decomposed,
        "gmem",        // inner-region kernel code shape
        "smem_eta_1",  // PML eta staging strategy
        v,
        eta,
        source,
        vec![Dim3::new(domain.pml_width + 1, c, c)],
    )?;

    // 4. run
    let summary = coord.run(50)?;
    println!(
        "50 steps: {} launches, {:.2?} wall, {:.2} Mpts/s, |u|max {:.3e}",
        summary.launches,
        summary.wall,
        summary.points_per_sec / 1e6,
        summary.final_max_abs
    );
    println!(
        "receiver trace (last 5 samples): {:?}",
        &summary.traces[0][45..]
    );
    Ok(())
}
