//! Kernel explorer: interrogate the simulated GPU testbed the way the
//! paper's evaluation section does — occupancy per variant, predicted
//! Table II ranking per machine, limiter analysis, and a what-if sweep
//! over hypothetical tile shapes (the tuning workflow of §V).
//!
//!     cargo run --release --example kernel_explorer

use hostencil::gpusim::arch::{self, GpuArch};
use hostencil::gpusim::{kernels, occupancy, timing, KernelResources};

fn main() {
    // 1. occupancy + limiter per paper variant, per machine
    for machine in arch::all() {
        println!("=== {} ({}, {} SMs) ===", machine.name, machine.sm_version, machine.sm_count);
        println!(
            "{:<22}{:>7}{:>6}{:>7}{:>9}{:>8}  {}",
            "variant", "block", "regs", "smem", "thWarps", "occ%", "limited by"
        );
        for v in kernels::paper_variants() {
            let res = v.resources_inner();
            if res.threads_per_block > machine.max_threads_per_block
                || res.smem_per_block > machine.smem_per_block
            {
                println!("{:<22}  (exceeds {} block limits)", v.id, machine.name);
                continue;
            }
            let occ = occupancy::occupancy(&machine, &res);
            println!(
                "{:<22}{:>7}{:>6}{:>7}{:>9}{:>8.1}  {:?}",
                v.id,
                res.threads_per_block,
                res.regs_per_thread,
                res.smem_per_block,
                occ.active_warps,
                occ.occupancy_pct,
                occ.limiter
            );
        }
        top5(&machine);
        println!();
    }

    // 2. what-if: sweep hypothetical 2.5D plane shapes on V100 and find
    //    the occupancy-optimal tile for a register-streaming kernel.
    println!("=== what-if: st_reg_fixed-style tiles on V100 ===");
    let a = arch::v100();
    let mut best: Option<(u32, u32, u32)> = None;
    for d1 in [8u32, 16, 32, 64] {
        for d2 in [8u32, 16, 32, 64] {
            let threads = d1 * d2;
            if threads > a.max_threads_per_block || threads < 64 {
                continue;
            }
            let regs = if threads >= 1024 { 64 } else { 78 };
            let smem = (d1 + 8) * (d2 + 8) * 4;
            let occ = occupancy::occupancy(&a, &KernelResources {
                threads_per_block: threads,
                regs_per_thread: regs,
                smem_per_block: smem,
            });
            println!("  {d1:>2}x{d2:<3} threads {threads:>4} regs {regs} -> {:>2} warps ({:.1}%)", occ.active_warps, occ.occupancy_pct);
            if best.map(|(_, _, w)| occ.active_warps > w).unwrap_or(true) {
                best = Some((d1, d2, occ.active_warps));
            }
        }
    }
    let (b1, b2, bw) = best.unwrap();
    println!("best occupancy tile: {b1}x{b2} ({bw} warps)");
}

fn top5(machine: &GpuArch) {
    let mut runs = timing::simulate_all(machine, 1000);
    runs.sort_by(|a, b| a.time_s.total_cmp(&b.time_s));
    println!("predicted fastest on {}:", machine.name);
    for r in runs.iter().take(5) {
        println!(
            "  {:<22}{:>9.2}s  {:>6.0} GF/s  AI_dram {:.2}  ({:.0}% of DRAM roof)",
            r.variant_id, r.time_s, r.gflops, r.ai_dram, r.pct_of_dram_peak
        );
    }
}
