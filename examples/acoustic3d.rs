//! End-to-end driver: a full seismic shot through the complete stack.
//!
//! Proves all layers compose on a real (small) workload:
//!   Pallas kernels (L1, build time) -> JAX region models lowered to HLO
//!   (L2, build time) -> Rust coordinator scheduling 7 PJRT launches per
//!   time step (L3, run time).
//!
//! Workload: a Ricker shot in a 3-layer earth model (sediment / chalk /
//! salt), PML-absorbed boundaries, a surface receiver line. The run is
//! cross-validated against the pure-Rust golden propagator, receivers
//! are written as CSV, and per-region launch statistics are reported.
//! Recorded in EXPERIMENTS.md §End-to-end.
//!
//!     make artifacts && cargo run --release --example acoustic3d

use hostencil::coordinator::{Coordinator, Mode};
use hostencil::grid::Dim3;
use hostencil::runtime::Engine;
use hostencil::wave::{self, Source, VelocityModel};

fn main() -> anyhow::Result<()> {
    let engine = Engine::load("artifacts")?;
    let domain = engine.manifest().domain;
    let n = domain.interior;
    println!("=== acoustic3d: 3-layer shot on {} (pml {}) ===", n, domain.pml_width);

    // --- earth model: three flat layers -------------------------------
    let model = VelocityModel::Layered(vec![
        (0.0, 1800.0),  // unconsolidated sediment
        (0.45, 2600.0), // chalk
        (0.75, 3000.0), // salt  (v_max sets the CFL and eta_max)
    ]);
    let v = model.build(n);
    let eta = wave::eta_profile(&domain, model.v_max_on(n) as f64);

    // --- acquisition geometry -----------------------------------------
    let w = domain.pml_width;
    let src = Source {
        pos: Dim3::new(w + 2, n.y / 2, n.x / 2), // shallow shot
        f0: 18.0,
        amplitude: 1.0,
    };
    // receiver line along x at the "surface" (just under the sponge)
    let receivers: Vec<Dim3> = (w..n.x - w)
        .step_by(2)
        .map(|x| Dim3::new(w + 1, n.y / 2, x))
        .collect();
    println!("source at {}, {} receivers at depth {}", src.pos, receivers.len(), w + 1);

    // --- cross-validate PJRT vs golden for the first steps ------------
    let mk = |eng, mode| {
        Coordinator::new(
            eng,
            domain,
            mode,
            "st_reg_fixed", // the paper's performance-portable pick
            "smem_eta_1",
            v.clone(),
            eta.clone(),
            src,
            receivers.clone(),
        )
    };
    let mut pjrt = mk(Some(&engine), Mode::Decomposed)?;
    let mut gold = mk(None, Mode::Golden)?;
    for _ in 0..10 {
        pjrt.step()?;
        gold.step()?;
    }
    let rel = pjrt.wavefield().max_abs_diff(&gold.wavefield())
        / gold.wavefield().max_abs().max(1e-30);
    println!("PJRT vs golden after 10 steps: rel diff {rel:.3e}");
    anyhow::ensure!(rel < 1e-4, "three-layer stack diverged from golden");

    // --- the shot ------------------------------------------------------
    let steps = 300;
    let summary = pjrt.run(steps - 10)?;
    println!(
        "{steps} steps total: {} launches, wall {:.2?}, {:.2} Mpts/s",
        pjrt.launches(),
        summary.wall,
        summary.points_per_sec / 1e6
    );
    println!(
        "final wavefield: |u|max {:.3e}, energy {:.3e}",
        summary.final_max_abs, summary.final_energy
    );

    // energy must decay after the wave hits the PML (absorption works)
    let e = &summary.energy_log;
    let peak = e.iter().cloned().fold(0.0, f64::max);
    let tail = e[e.len() - 1];
    println!("energy: peak {peak:.3e} -> final {tail:.3e} ({:.1}% absorbed)", 100.0 * (1.0 - tail / peak));
    anyhow::ensure!(tail < peak, "PML failed to absorb boundary energy");

    // --- write the shot gather -----------------------------------------
    std::fs::create_dir_all("target").ok();
    let path = "target/acoustic3d_gather.csv";
    let mut csv = String::from("step");
    for (i, _) in receivers.iter().enumerate() {
        csv.push_str(&format!(",r{i}"));
    }
    csv.push('\n');
    for t in 0..summary.traces[0].len() {
        csv.push_str(&t.to_string());
        for tr in &summary.traces {
            csv.push_str(&format!(",{:.6e}", tr[t]));
        }
        csv.push('\n');
    }
    std::fs::write(path, csv)?;
    println!("wrote shot gather -> {path}");

    // --- engine statistics: the 7-region launch topology at work -------
    println!("\nper-artifact launch statistics:");
    for (name, s) in engine.stats() {
        println!(
            "  {:34} calls {:>5}  mean exec {:>10.3?}",
            name,
            s.calls,
            s.mean_exec()
        );
    }
    Ok(())
}
