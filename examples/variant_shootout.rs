//! Variant shootout: run every inner-kernel code shape on the real PJRT
//! testbed with identical physics, and rank them — the local, measured
//! analog of a Table II column — then compare the measured ranking with
//! the gpusim prediction for this class of machine.
//!
//!     make artifacts && cargo run --release --example variant_shootout

use std::time::Instant;

use hostencil::coordinator::{Coordinator, Mode};
use hostencil::grid::Dim3;
use hostencil::runtime::Engine;
use hostencil::wave::{self, Source, VelocityModel};

fn main() -> anyhow::Result<()> {
    let engine = Engine::load("artifacts")?;
    engine.preload_all()?;
    let domain = engine.manifest().domain;
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(10);
    println!(
        "shootout: {} steps per variant on {} (pml {}), platform {}",
        steps,
        domain.interior,
        domain.pml_width,
        engine.platform()
    );

    let v = VelocityModel::Constant(2500.0).build(domain.interior);
    let eta = wave::eta_profile(&domain, 2500.0);
    let c = domain.interior.z / 2;
    let src = Source { pos: Dim3::new(c, c, c), f0: 15.0, amplitude: 1.0 };

    let mut rows: Vec<(String, f64, f64)> = Vec::new();
    let variants: Vec<String> = engine
        .manifest()
        .inner_variants()
        .iter()
        .map(|s| s.to_string())
        .collect();
    for variant in &variants {
        let mut coord = Coordinator::new(
            Some(&engine),
            domain,
            Mode::Decomposed,
            variant,
            "smem_eta_1",
            v.clone(),
            eta.clone(),
            src,
            vec![],
        )?;
        coord.step()?; // warm the executable cache
        let t0 = Instant::now();
        for _ in 0..steps {
            coord.step()?;
        }
        let dt = t0.elapsed().as_secs_f64();
        let mpts = (domain.interior.volume() * steps) as f64 / dt / 1e6;
        rows.push((variant.clone(), dt, mpts));
    }
    rows.sort_by(|a, b| a.1.total_cmp(&b.1));

    println!("\nmeasured (this machine, CPU PJRT):");
    for (i, (name, t, mpts)) in rows.iter().enumerate() {
        println!("  {:>2}. {:<16}{:>8.3}s  {:>8.2} Mpts/s", i + 1, name, t, mpts);
    }

    println!(
        "\nnote: on this CPU testbed all variants lower to similar XLA loops, so\n\
         spreads are small; the per-GPU spreads live in the gpusim model\n\
         (`hostencil table2` / `hostencil sweep --machine p100`)."
    );
    Ok(())
}
