//! Persistent worker-pool executor: zero-spawn, zero-alloc parallel
//! stepping.
//!
//! The paper's core measurement lesson is that steady-state kernel
//! cost — not setup — must dominate the time loop. Spawning scoped
//! threads on every step (the pre-pool fan-out) charged O(threads) of
//! spawn/join bookkeeping to every measured step, so small and medium
//! grids benchmarked the harness instead of the code shape. This
//! module removes that cost structurally:
//!
//! * [`WorkerPool::new`] spawns `workers - 1` OS threads **once**; the
//!   caller's thread is always slot 0, so a one-worker pool never
//!   spawns anything.
//! * Between steps the workers park on a condvar. [`WorkerPool::run`]
//!   publishes one borrowed, type-erased job and bumps a per-step
//!   generation counter (the *epoch*) to release them; every slot runs
//!   the job exactly once per epoch.
//! * The caller joins by draining a completed-count under the same
//!   mutex — no `thread::scope`, no `thread::spawn`, and no
//!   steady-state heap allocation anywhere on the path (the job is a
//!   borrowed trait object; `std`'s mutex/condvar pair is
//!   allocation-free after construction).
//! * A job that panics on any slot is caught, counted, and re-raised
//!   on the caller's thread **after** the pool has quiesced: a
//!   panicking step surfaces as a clean unwind, never a hang, and the
//!   pool stays usable for the next step.
//!
//! The stencil propagators build one pool per cached execution plan
//! (keyed on `(domain, threads)`, next to the tile task list and the
//! per-worker scratch), so per-worker state like streaming ring planes
//! stays pinned to a stable slot index across steps. The campaign
//! runner keeps its own scoped fan-out — that one spawns once per
//! *campaign*, not per step — while each physics job's tile execution
//! goes through a pool sized by its share of the global worker budget.

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::fault::{FaultKind, FaultPlan, FaultSite, InjectedPanic};
use crate::telemetry::Registry;

/// Process-wide gauge of live parked pool threads. Lifecycle tests
/// assert the serial fast path spawns nothing, steady-state steps
/// never grow it, and dropped pools join their workers.
static LIVE_WORKERS: AtomicUsize = AtomicUsize::new(0);

/// Current number of live pool worker threads across the whole
/// process (parked or running a step).
pub fn live_worker_threads() -> usize {
    LIVE_WORKERS.load(Ordering::SeqCst)
}

/// A published job: a borrow of the caller's closure with the lifetime
/// transmuted away so the parked workers can hold it. Sound because
/// [`WorkerPool::run`] never returns (or unwinds) before every worker
/// has finished its call for the current epoch.
#[derive(Copy, Clone)]
struct JobRef(&'static (dyn Fn(usize) + Sync));

struct State {
    /// Per-step generation counter; a bump releases the parked workers
    /// for exactly one run of the published job each.
    epoch: u64,
    job: Option<JobRef>,
    /// Spawned workers that have not yet finished the current epoch.
    active: usize,
    /// First panic payload caught on a worker slot during the current
    /// epoch, kept so the caller re-raises the *original* panic (with
    /// its message) instead of a generic "a worker panicked".
    panic_payload: Option<Box<dyn Any + Send>>,
    /// Slot whose thread *exited* this epoch (injected-fault death, as
    /// opposed to a caught job panic, which leaves the thread alive).
    /// `run` respawns a replacement at the same slot.
    panicked_slot: Option<usize>,
    /// Armed fault plan consulted by workers at epoch claim.
    faults: Option<Arc<FaultPlan>>,
    shutdown: bool,
}

/// Always-on pool counters, read lazily by telemetry collectors.
/// Plain relaxed atomics bumped outside the mutex: the cost is not
/// measurable next to a tile sweep, and the steady-state path stays
/// allocation-free whether or not a registry is attached.
struct PoolStats {
    /// Times a worker went to sleep on the condvar between epochs.
    parks: AtomicU64,
    /// Times a parked worker was released by a fresh epoch.
    wakes: AtomicU64,
    /// Jobs executed across all slots (one per slot per epoch).
    jobs: AtomicU64,
    /// Worker threads respawned after a quarantined death.
    respawns: AtomicU64,
    /// Nanoseconds each slot has spent inside jobs.
    busy_ns: Vec<AtomicU64>,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here between steps.
    go: Condvar,
    /// The caller joins here until `active` drains to zero.
    done: Condvar,
    stats: PoolStats,
}

impl Shared {
    /// Poison-proof lock: a panic can only originate inside a job,
    /// which runs outside the mutex, so a poisoned guard still holds a
    /// consistent `State`.
    fn lock(&self) -> MutexGuard<'_, State> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }
}

/// A pool of parked worker threads that execute one job per step
/// across `workers` slots (slot 0 is the calling thread).
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    /// Quarantine budget: how many injected worker deaths `run` will
    /// absorb (respawn + continue) before escalating to the caller.
    respawn_budget: u32,
}

impl WorkerPool {
    /// Build a pool presenting `workers` total slots: the caller's
    /// thread is slot 0 and `workers - 1` threads are spawned now,
    /// park between steps, and live until the pool is dropped.
    pub fn new(workers: usize) -> WorkerPool {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                job: None,
                active: 0,
                panic_payload: None,
                panicked_slot: None,
                faults: None,
                shutdown: false,
            }),
            go: Condvar::new(),
            done: Condvar::new(),
            stats: PoolStats {
                parks: AtomicU64::new(0),
                wakes: AtomicU64::new(0),
                jobs: AtomicU64::new(0),
                respawns: AtomicU64::new(0),
                busy_ns: (0..workers.max(1)).map(|_| AtomicU64::new(0)).collect(),
            },
        });
        let handles = (1..workers.max(1))
            .map(|slot| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("hostencil-pool-{slot}"))
                    .spawn(move || worker_loop(&shared, slot))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, handles, respawn_budget: 1 }
    }

    /// Total worker slots (spawned threads + the caller's slot 0).
    pub fn workers(&self) -> usize {
        self.handles.len() + 1
    }

    /// Arm a fault plan: workers consult it once per epoch claim and an
    /// armed `pool:panic` spec takes exactly one worker thread down
    /// (before it claims any tile). With no plan armed the epoch path
    /// is untouched.
    pub fn set_faults(&mut self, faults: Arc<FaultPlan>) {
        self.shared.lock().faults = Some(faults);
    }

    /// Execute `job(slot)` once on every slot and block until all
    /// slots finished. The caller's thread runs slot 0 itself instead
    /// of idling on the join. Steady-state calls perform no heap
    /// allocation and spawn no threads.
    ///
    /// If the job panicked on any slot, the original panic payload is
    /// re-raised here after every worker has quiesced — the step fails
    /// as a clean unwind with the real message (never a hang) and the
    /// pool remains usable.
    ///
    /// An *injected* worker death (the [`InjectedPanic`] marker from an
    /// armed fault plan) is handled one level earlier: the dead thread
    /// is quarantined and a replacement respawned at the same slot, and
    /// — while the respawn budget lasts — the step is treated as
    /// complete, since the fault fires before the worker claims any
    /// tile and the surviving slots drain the whole shared cursor. Once
    /// the budget is spent the marker escalates like any other panic
    /// (the pool is still made whole first, so it stays usable).
    pub fn run(&mut self, job: &(dyn Fn(usize) + Sync)) {
        if self.handles.is_empty() {
            let t0 = Instant::now();
            job(0);
            self.record_slot0(t0);
            return;
        }
        // SAFETY: the erased borrow only escapes to this pool's own
        // workers, and this function does not return (or unwind) until
        // every worker has reported back in — the borrow outlives
        // every use.
        let jref = JobRef(unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(job)
        });
        {
            let mut st = self.shared.lock();
            debug_assert_eq!(st.active, 0, "a previous step is still draining");
            st.job = Some(jref);
            st.epoch = st.epoch.wrapping_add(1);
            st.active = self.handles.len();
            st.panic_payload = None;
            st.panicked_slot = None;
            self.shared.go.notify_all();
        }
        let t0 = Instant::now();
        let caller = catch_unwind(AssertUnwindSafe(|| job(0)));
        self.record_slot0(t0);
        let (worker_panic, dead_slot) = {
            let mut st = self.shared.lock();
            while st.active > 0 {
                st = self
                    .shared
                    .done
                    .wait(st)
                    .unwrap_or_else(PoisonError::into_inner);
            }
            st.job = None;
            (st.panic_payload.take(), st.panicked_slot.take())
        };
        if let Err(payload) = caller {
            resume_unwind(payload);
        }
        if let Some(payload) = worker_panic {
            if payload.downcast_ref::<InjectedPanic>().is_some() {
                if let Some(slot) = dead_slot {
                    self.respawn(slot);
                }
                if self.respawn_budget > 0 {
                    self.respawn_budget -= 1;
                    return;
                }
            }
            resume_unwind(payload);
        }
    }

    /// Replace the exited thread at `slot` with a fresh one parked on
    /// the same shared state (the replacement sees the current epoch as
    /// already-claimed, so it first runs on the *next* epoch).
    fn respawn(&mut self, slot: usize) {
        let epoch = self.shared.lock().epoch;
        let shared = Arc::clone(&self.shared);
        let h = std::thread::Builder::new()
            .name(format!("hostencil-pool-{slot}"))
            .spawn(move || worker_loop_from(&shared, slot, epoch))
            .expect("respawn pool worker");
        let old = std::mem::replace(&mut self.handles[slot - 1], h);
        let _ = old.join();
        self.shared.stats.respawns.fetch_add(1, Ordering::Relaxed);
    }

    fn record_slot0(&self, t0: Instant) {
        let stats = &self.shared.stats;
        stats.busy_ns[0].fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        stats.jobs.fetch_add(1, Ordering::Relaxed);
    }

    /// Point `reg`'s pool collectors at this pool's live stats. Called
    /// from `Plan::ensure` when a plan binds a telemetry registry;
    /// re-registration replaces the closures, so a rebuilt plan's new
    /// pool re-points the same exposition series at its own counters.
    pub fn register_telemetry(&self, reg: &Registry) {
        let s = Arc::clone(&self.shared);
        reg.counter_fn(
            "hostencil_pool_parks_total",
            "Times a pool worker parked on the condvar between epochs.",
            &[],
            move || s.stats.parks.load(Ordering::Relaxed),
        );
        let s = Arc::clone(&self.shared);
        reg.counter_fn(
            "hostencil_pool_wakes_total",
            "Times a parked pool worker was released by a fresh epoch.",
            &[],
            move || s.stats.wakes.load(Ordering::Relaxed),
        );
        let s = Arc::clone(&self.shared);
        reg.counter_fn(
            "hostencil_pool_jobs_total",
            "Jobs executed across all pool slots (one per slot per epoch).",
            &[],
            move || s.stats.jobs.load(Ordering::Relaxed),
        );
        let s = Arc::clone(&self.shared);
        reg.counter_fn(
            "hostencil_pool_respawns_total",
            "Worker threads respawned after a quarantined (injected) death.",
            &[],
            move || s.stats.respawns.load(Ordering::Relaxed),
        );
        for slot in 0..self.shared.stats.busy_ns.len() {
            let s = Arc::clone(&self.shared);
            let label = slot.to_string();
            reg.counter_fn(
                "hostencil_pool_slot_busy_ns_total",
                "Nanoseconds each pool slot has spent running jobs.",
                &[("slot", &label)],
                move || s.stats.busy_ns[slot].load(Ordering::Relaxed),
            );
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.lock();
            st.shutdown = true;
            self.shared.go.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared, slot: usize) {
    worker_loop_from(shared, slot, 0)
}

/// Worker body, parameterized on the last epoch already counted as
/// claimed (0 for initial spawns; the current epoch for respawned
/// replacements, whose dead predecessor already decremented `active`).
fn worker_loop_from(shared: &Shared, slot: usize, start_epoch: u64) {
    LIVE_WORKERS.fetch_add(1, Ordering::SeqCst);
    let mut seen = start_epoch;
    loop {
        let (job, faults) = {
            let mut st = shared.lock();
            loop {
                if st.shutdown {
                    drop(st);
                    LIVE_WORKERS.fetch_sub(1, Ordering::SeqCst);
                    return;
                }
                match st.job {
                    // a fresh epoch releases each worker exactly once;
                    // the job is never cleared before the whole epoch
                    // completed, so a new epoch always carries one
                    Some(job) if st.epoch != seen => {
                        seen = st.epoch;
                        shared.stats.wakes.fetch_add(1, Ordering::Relaxed);
                        break (job, st.faults.clone());
                    }
                    _ => {
                        shared.stats.parks.fetch_add(1, Ordering::Relaxed);
                        st = shared.go.wait(st).unwrap_or_else(PoisonError::into_inner);
                    }
                }
            }
        };
        // An armed `pool:panic` spec kills exactly one worker (the CAS
        // in `fire` picks the winner) *before* it claims any tile, so
        // the surviving slots drain the shared cursor and the step
        // still completes bit-identically. The marker payload and slot
        // tell `run` to quarantine + respawn instead of escalating.
        if let Some(f) = &faults {
            if f.fire(FaultSite::Pool, FaultKind::Panic) {
                let mut st = shared.lock();
                st.panic_payload.get_or_insert(Box::new(InjectedPanic { step: f.step() }));
                st.panicked_slot = Some(slot);
                st.active -= 1;
                if st.active == 0 {
                    shared.done.notify_one();
                }
                drop(st);
                LIVE_WORKERS.fetch_sub(1, Ordering::SeqCst);
                return;
            }
        }
        // A panicking job must not take the worker down: stash the
        // payload (first one wins), keep the completed-count honest so
        // the caller never hangs, and let `run` re-raise it after the
        // join.
        let t0 = Instant::now();
        let result = catch_unwind(AssertUnwindSafe(|| (job.0)(slot)));
        shared.stats.busy_ns[slot].fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        shared.stats.jobs.fetch_add(1, Ordering::Relaxed);
        let mut st = shared.lock();
        if let Err(payload) = result {
            st.panic_payload.get_or_insert(payload);
        }
        st.active -= 1;
        if st.active == 0 {
            shared.done.notify_one();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_slot_runs_the_job_exactly_once_per_epoch() {
        let mut pool = WorkerPool::new(4);
        assert_eq!(pool.workers(), 4);
        let hits: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
        for _ in 0..50 {
            pool.run(&|slot| {
                hits[slot].fetch_add(1, Ordering::SeqCst);
            });
        }
        for (slot, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 50, "slot {slot}");
        }
    }

    #[test]
    fn single_worker_pool_runs_inline_on_the_caller() {
        let mut pool = WorkerPool::new(1);
        assert_eq!(pool.workers(), 1);
        let calls = AtomicUsize::new(0);
        pool.run(&|slot| {
            assert_eq!(slot, 0);
            calls.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(calls.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn zero_workers_clamps_to_one() {
        let mut pool = WorkerPool::new(0);
        assert_eq!(pool.workers(), 1);
        let calls = AtomicUsize::new(0);
        pool.run(&|_| {
            calls.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(calls.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn stats_accumulate_and_export_through_a_registry() {
        let mut pool = WorkerPool::new(2);
        let reg = Registry::new();
        pool.register_telemetry(&reg);
        let hits = AtomicUsize::new(0);
        for _ in 0..5 {
            pool.run(&|_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(hits.load(Ordering::Relaxed), 10);
        let text = reg.render();
        // 2 slots x 5 epochs; wakes come only from the spawned worker
        assert!(text.contains("hostencil_pool_jobs_total 10"), "{text}");
        assert!(text.contains("hostencil_pool_wakes_total 5"), "{text}");
        assert!(text.contains("hostencil_pool_parks_total"), "{text}");
        assert!(text.contains("hostencil_pool_slot_busy_ns_total{slot=\"0\"}"), "{text}");
        assert!(text.contains("hostencil_pool_slot_busy_ns_total{slot=\"1\"}"), "{text}");
    }

    #[test]
    fn shared_cursor_fanout_covers_every_task_exactly_once() {
        // the propagators' claim pattern: slots race on an atomic
        // cursor; every task must be executed exactly once
        let mut pool = WorkerPool::new(3);
        let n = 1000;
        let done: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        let cursor = AtomicUsize::new(0);
        pool.run(&|_slot| loop {
            let i = cursor.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            done[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(done.iter().all(|d| d.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn worker_panic_reraises_on_the_caller_and_pool_survives() {
        let mut pool = WorkerPool::new(3);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run(&|slot| {
                if slot != 0 {
                    panic!("injected worker fault");
                }
            });
        }));
        let payload = r.expect_err("a worker panic must unwind out of run()");
        assert_eq!(
            payload.downcast_ref::<&str>(),
            Some(&"injected worker fault"),
            "the original panic payload must survive the hand-off"
        );
        let hits = AtomicUsize::new(0);
        pool.run(&|_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 3, "the pool must stay usable");
    }

    #[test]
    fn injected_worker_death_is_quarantined_and_respawned() {
        let mut pool = WorkerPool::new(3);
        let reg = Registry::new();
        pool.register_telemetry(&reg);
        pool.set_faults(FaultPlan::single(FaultSite::Pool, FaultKind::Panic, 0, 5));
        // cursor fan-out: the dead slot never claims a tile, so the
        // survivors cover every tile exactly once and run() absorbs
        // the death instead of unwinding
        let n = 256;
        let done: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        let cursor = AtomicUsize::new(0);
        pool.run(&|_slot| loop {
            let i = cursor.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            done[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(done.iter().all(|d| d.load(Ordering::Relaxed) == 1), "every tile exactly once");
        let text = reg.render();
        assert!(text.contains("hostencil_pool_respawns_total 1"), "{text}");
        // the replacement thread participates in the next epoch
        let hits = AtomicUsize::new(0);
        pool.run(&|_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 3, "the pool must be whole again");
    }

    #[test]
    fn a_second_injected_death_escalates_but_leaves_the_pool_whole() {
        let mut pool = WorkerPool::new(3);
        pool.set_faults(FaultPlan::single(FaultSite::Pool, FaultKind::Panic, 0, 5));
        pool.run(&|_| {}); // first death: absorbed, budget spent
        pool.set_faults(FaultPlan::single(FaultSite::Pool, FaultKind::Panic, 0, 7));
        let r = catch_unwind(AssertUnwindSafe(|| pool.run(&|_| {})));
        let payload = r.expect_err("budget spent: the marker must escalate");
        assert!(
            payload.downcast_ref::<InjectedPanic>().is_some(),
            "the marker payload must reach the caller intact"
        );
        // escalation still respawned the dead slot, so the pool stays
        // usable (and correctly sized) for the caller's recovery path
        let hits = AtomicUsize::new(0);
        pool.run(&|_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn caller_slot_panic_still_joins_the_workers() {
        let mut pool = WorkerPool::new(2);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run(&|slot| {
                if slot == 0 {
                    panic!("injected caller fault");
                }
            });
        }));
        assert!(r.is_err());
        let hits = AtomicUsize::new(0);
        pool.run(&|_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 2);
    }
}
