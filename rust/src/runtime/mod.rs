//! Execution runtimes: the PJRT engine for AOT HLO artifacts and the
//! persistent CPU worker pool ([`pool`]) the stencil propagators fan
//! tile work over.
//!
//! The engine below loads AOT HLO-text artifacts and executes them on
//! the CPU client; it is the only code that touches the `xla` crate.
//! Pattern (see /opt/xla-example/load_hlo): `HloModuleProto::from_text_file`
//! -> `XlaComputation::from_proto` -> `client.compile` -> `execute_b`.
//! The Python side lowers with `return_tuple=False` (each step function
//! returns exactly one array), so outputs come back as a single buffer
//! with no tuple unwrap; inputs go host->device directly as PjRtBuffers
//! with no Literal intermediate (see EXPERIMENTS.md §Perf).

pub mod pool;

use std::cell::RefCell;
use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::grid::{Dim3, Field3};
use crate::manifest::Manifest;

/// Per-artifact execution statistics (compile once, execute many).
#[derive(Clone, Debug, Default)]
pub struct ExecStats {
    pub compile_time: Duration,
    pub calls: u64,
    pub exec_time: Duration,
    /// host->device literal preparation + device->host fetch
    pub transfer_time: Duration,
}

impl ExecStats {
    pub fn mean_exec(&self) -> Duration {
        if self.calls == 0 {
            Duration::ZERO
        } else {
            self.exec_time / self.calls as u32
        }
    }
}

/// The PJRT engine: a CPU client plus a lazily-compiled executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    exes: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
    stats: RefCell<HashMap<String, ExecStats>>,
}

/// Convert a field to a device literal (f32, row-major (z,y,x)).
pub fn literal_from_field(f: &Field3) -> anyhow::Result<xla::Literal> {
    let d = f.dims();
    let lit = xla::Literal::vec1(f.as_slice());
    Ok(lit.reshape(&[d.z as i64, d.y as i64, d.x as i64])?)
}

/// One executable argument: either host data (uploaded per call) or a
/// resident device buffer (uploaded once via [`Engine::upload`] — used
/// for run-constant inputs like the velocity model and eta tiles).
pub enum ExecArg<'a> {
    Host(&'a Field3),
    Device(&'a xla::PjRtBuffer),
}

/// Convert a device literal back to a field with expected dims.
pub fn field_from_literal(lit: &xla::Literal, dims: Dim3) -> anyhow::Result<Field3> {
    let data = lit.to_vec::<f32>()?;
    Field3::from_vec(dims, data)
}

impl Engine {
    /// Open the artifact directory and create the PJRT CPU client.
    /// Compilation is lazy: artifacts compile on first use.
    pub fn load(artifact_dir: impl AsRef<std::path::Path>) -> anyhow::Result<Engine> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            client,
            manifest,
            exes: RefCell::new(HashMap::new()),
            stats: RefCell::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an artifact now (no-op if cached). Returns compile time.
    pub fn preload(&self, name: &str) -> anyhow::Result<Duration> {
        if self.exes.borrow().contains_key(name) {
            return Ok(Duration::ZERO);
        }
        let art = self.manifest.get(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            art.file
                .to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 artifact path {:?}", art.file))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let dt = t0.elapsed();
        self.exes.borrow_mut().insert(name.to_string(), exe);
        self.stats.borrow_mut().entry(name.to_string()).or_default().compile_time = dt;
        Ok(dt)
    }

    /// Compile every artifact in the manifest.
    pub fn preload_all(&self) -> anyhow::Result<Duration> {
        let names: Vec<String> = self.manifest.names().iter().map(|s| s.to_string()).collect();
        let mut total = Duration::ZERO;
        for n in &names {
            total += self.preload(n)?;
        }
        Ok(total)
    }

    /// Upload a field to a resident device buffer (host->device once;
    /// pass back via [`ExecArg::Device`] on every subsequent call).
    pub fn upload(&self, f: &Field3) -> anyhow::Result<xla::PjRtBuffer> {
        let d = f.dims();
        Ok(self
            .client
            .buffer_from_host_buffer::<f32>(f.as_slice(), &[d.z, d.y, d.x], None)?)
    }

    /// Execute artifact `name` on the given input fields; returns the
    /// output field (shape from the manifest). Input shapes are validated
    /// against the recorded signature before launch.
    pub fn execute(&self, name: &str, inputs: &[&Field3]) -> anyhow::Result<Field3> {
        let args: Vec<ExecArg> = inputs.iter().map(|f| ExecArg::Host(f)).collect();
        self.execute_args(name, &args)
    }

    /// Execute with a mix of host fields and resident device buffers.
    pub fn execute_args(&self, name: &str, inputs: &[ExecArg]) -> anyhow::Result<Field3> {
        Ok(self.execute_args_keep(name, inputs)?.0)
    }

    /// Like [`execute_args`], but also hands back the output's device
    /// buffer so the caller can feed it to a later launch without a
    /// host round-trip (the coordinator's um-recycling optimization).
    ///
    /// Fast path: host args go straight to device buffers (no Literal
    /// intermediate) and the single untupled output is fetched with one
    /// literal copy.
    pub fn execute_args_keep(
        &self,
        name: &str,
        inputs: &[ExecArg],
    ) -> anyhow::Result<(Field3, xla::PjRtBuffer)> {
        self.preload(name)?;
        let art = self.manifest.get(name)?;
        anyhow::ensure!(
            inputs.len() == art.input_shapes.len(),
            "{name}: expected {} inputs, got {}",
            art.input_shapes.len(),
            inputs.len()
        );
        for (a, (pname, want)) in inputs.iter().zip(&art.input_shapes) {
            if let ExecArg::Host(f) = a {
                anyhow::ensure!(
                    f.dims() == *want,
                    "{name}: input {pname:?} shape {} != expected {want}",
                    f.dims()
                );
            }
        }

        let t0 = Instant::now();
        let uploaded: Vec<Option<xla::PjRtBuffer>> = inputs
            .iter()
            .map(|a| match a {
                ExecArg::Host(f) => self.upload(f).map(Some),
                ExecArg::Device(_) => Ok(None),
            })
            .collect::<anyhow::Result<_>>()?;
        let args: Vec<&xla::PjRtBuffer> = inputs
            .iter()
            .zip(&uploaded)
            .map(|(a, up)| match a {
                ExecArg::Host(_) => up.as_ref().expect("uploaded above"),
                ExecArg::Device(b) => *b,
            })
            .collect();
        let t_prep = t0.elapsed();

        let t1 = Instant::now();
        let exes = self.exes.borrow();
        let exe = exes.get(name).expect("preloaded above");
        let mut outputs = exe.execute_b::<&xla::PjRtBuffer>(&args)?;
        let t_exec = t1.elapsed();

        let t2 = Instant::now();
        // (copy_raw_to_host is unimplemented on the CPU PJRT client; the
        // untupled output still saves the tuple unwrap + one copy)
        let out_buf = outputs[0].remove(0);
        let lit = out_buf.to_literal_sync()?;
        let field = field_from_literal(&lit, art.output_shape)?;
        let t_fetch = t2.elapsed();

        let mut stats = self.stats.borrow_mut();
        let s = stats.entry(name.to_string()).or_default();
        s.calls += 1;
        s.exec_time += t_exec;
        s.transfer_time += t_prep + t_fetch;
        Ok((field, out_buf))
    }

    /// Snapshot of per-artifact statistics.
    pub fn stats(&self) -> Vec<(String, ExecStats)> {
        let mut v: Vec<(String, ExecStats)> =
            self.stats.borrow().iter().map(|(k, s)| (k.clone(), s.clone())).collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Total executable launches so far (the coordinator's "kernel launch"
    /// counter — 7 per step in decomposed mode).
    pub fn total_calls(&self) -> u64 {
        self.stats.borrow().values().map(|s| s.calls).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let f = Field3::from_fn(Dim3::new(2, 3, 4), |z, y, x| (z * 12 + y * 4 + x) as f32);
        let lit = literal_from_field(&f).unwrap();
        let g = field_from_literal(&lit, f.dims()).unwrap();
        assert_eq!(f, g);
    }

    #[test]
    fn field_from_literal_rejects_wrong_dims() {
        let f = Field3::zeros(Dim3::new(2, 2, 2));
        let lit = literal_from_field(&f).unwrap();
        assert!(field_from_literal(&lit, Dim3::new(3, 3, 3)).is_err());
    }
}
