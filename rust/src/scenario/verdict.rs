//! Pass/fail evaluation: named criteria over collected [`Metrics`] and
//! the three-level verdict the campaign runner aggregates on.

use std::fmt;

use super::metrics::Metrics;

/// Scenario verdict, worst-criterion-wins.
#[derive(Copy, Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Verdict {
    Pass,
    SoftFail,
    HardFail,
}

impl Verdict {
    pub fn name(&self) -> &'static str {
        match self {
            Verdict::Pass => "Pass",
            Verdict::SoftFail => "SoftFail",
            Verdict::HardFail => "HardFail",
        }
    }

    fn worst(self, other: Verdict) -> Verdict {
        self.max(other)
    }
}

impl fmt::Display for Verdict {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// What a failed criterion costs.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Severity {
    /// Quality concern: the physics ran but looks degraded.
    Soft,
    /// Correctness/stability violation: the run cannot be trusted.
    Hard,
}

impl Severity {
    fn verdict_on_failure(self) -> Verdict {
        match self {
            Severity::Soft => Verdict::SoftFail,
            Severity::Hard => Verdict::HardFail,
        }
    }
}

/// One evaluated criterion.
#[derive(Clone, Debug)]
pub struct Criterion {
    pub name: &'static str,
    pub passed: bool,
    pub severity: Severity,
    /// Human-readable measured-vs-threshold detail.
    pub detail: String,
}

/// The full evaluation: every criterion plus the aggregate verdict.
#[derive(Clone, Debug)]
pub struct ScenarioResult {
    pub criteria: Vec<Criterion>,
    pub overall: Verdict,
}

impl ScenarioResult {
    pub fn failed(&self) -> Vec<&Criterion> {
        self.criteria.iter().filter(|c| !c.passed).collect()
    }

    fn from_criteria(criteria: Vec<Criterion>) -> ScenarioResult {
        let overall = criteria.iter().filter(|c| !c.passed).fold(Verdict::Pass, |acc, c| {
            acc.worst(c.severity.verdict_on_failure())
        });
        ScenarioResult { criteria, overall }
    }
}

/// Per-scenario thresholds. Scenarios materialize these alongside their
/// `RunConfig`; stress scenarios keep the same thresholds (the point is
/// that they *violate* them) but mark the expected verdict.
#[derive(Copy, Clone, Debug)]
pub struct Expectations {
    /// The wave must actually show up: peak |u| over the run.
    pub min_peak_abs: f32,
    /// Boundary containment: peak |u| on the outermost interior layer,
    /// normalized by the overall peak, must stay below this.
    pub max_leakage: f64,
    /// Late-run energy growth ratio (final vs the 3/4-point of the
    /// energy trace) must stay below this — catches slow instability
    /// that never reaches NaN within the step budget.
    pub max_late_growth: f64,
    /// Absorption: final energy as a fraction of peak energy.
    pub max_final_fraction: f64,
    /// Whether the absorption criterion applies (meaningless for runs
    /// shorter than the source wavelet or for degenerate grids).
    pub check_absorption: bool,
    /// Whether every receiver must have recorded signal.
    pub require_receivers: bool,
    /// Whether the run must carry a checkpoint->restore->compare
    /// measurement (`Metrics::restart_max_diff`) proving bitwise
    /// restart consistency (the restart-consistency scenario).
    pub require_restart_consistency: bool,
}

impl Default for Expectations {
    fn default() -> Self {
        Expectations {
            min_peak_abs: 1e-6,
            max_leakage: 0.5,
            max_late_growth: 2.0,
            max_final_fraction: 0.9,
            check_absorption: true,
            require_receivers: false,
            require_restart_consistency: false,
        }
    }
}

/// Evaluate collected metrics against scenario expectations. Criteria
/// are always all listed (passed or not) so reports stay comparable
/// across scenarios; the verdict is worst-criterion-wins.
pub fn evaluate_pass_fail(m: &Metrics, exp: &Expectations) -> ScenarioResult {
    let mut criteria = Vec::new();
    let mut push = |name, passed, severity, detail: String| {
        criteria.push(Criterion { name, passed, severity, detail });
    };

    // 1. finite_field (hard): NaN/Inf anywhere, ever, is fatal.
    push(
        "finite_field",
        m.first_non_finite.is_none(),
        Severity::Hard,
        match m.first_non_finite {
            None => format!("all {} steps finite", m.steps_completed),
            Some(s) => format!("non-finite wavefield at step {s}"),
        },
    );

    // 2. cfl_respected (hard): dt against the CFL bound computed from
    //    the *materialized* velocity grid (not a nominal bound).
    let cfl_ok = m.dt <= m.cfl_dt * (1.0 + 1e-9);
    push(
        "cfl_respected",
        cfl_ok,
        Severity::Hard,
        format!("dt {:.4e} vs CFL limit {:.4e} (v_max {:.0})", m.dt, m.cfl_dt, m.v_max),
    );

    // 3. wave_propagated (hard): a silent simulation is a broken one.
    push(
        "wave_propagated",
        m.peak_abs >= exp.min_peak_abs,
        Severity::Hard,
        format!("peak |u| {:.3e} vs required {:.3e}", m.peak_abs, exp.min_peak_abs),
    );

    // 4. energy_bounded (hard): late-run growth means instability even
    //    if the field never reached non-finite within the budget.
    let growth_ok = m.late_growth.is_finite() && m.late_growth <= exp.max_late_growth;
    push(
        "energy_bounded",
        growth_ok,
        Severity::Hard,
        format!("late energy growth x{:.3} vs allowed x{:.2}", m.late_growth, exp.max_late_growth),
    );

    // 5. boundary_containment (soft): PML should keep the outermost
    //    interior layer quiet relative to the run's peak amplitude.
    let leak_ok = m.boundary_leakage.is_finite() && m.boundary_leakage <= exp.max_leakage;
    push(
        "boundary_containment",
        leak_ok,
        Severity::Soft,
        format!("edge/peak amplitude ratio {:.3} vs allowed {:.3}", m.boundary_leakage, exp.max_leakage),
    );

    // 6. energy_absorbed (soft): after the source dies, the sponge
    //    should have swallowed most of the injected energy.
    let final_frac = if m.peak_energy > 0.0 { m.final_energy / m.peak_energy } else { 0.0 };
    let absorb_ok =
        !exp.check_absorption || (final_frac.is_finite() && final_frac <= exp.max_final_fraction);
    push(
        "energy_absorbed",
        absorb_ok,
        Severity::Soft,
        if exp.check_absorption {
            format!("final/peak energy {:.3} vs allowed {:.3}", final_frac, exp.max_final_fraction)
        } else {
            "not applicable for this scenario".to_string()
        },
    );

    // 7. receivers_live (soft): every receiver recorded real signal.
    let recv_ok = !exp.require_receivers
        || (!m.receiver_peak.is_empty() && m.receiver_peak.iter().all(|&p| p > 0.0 && p.is_finite()));
    push(
        "receivers_live",
        recv_ok,
        Severity::Soft,
        format!(
            "{}/{} receivers saw signal",
            m.receiver_peak.iter().filter(|&&p| p > 0.0 && p.is_finite()).count(),
            m.receiver_peak.len()
        ),
    );

    // 8. throughput_model (soft): the gpusim prediction for this
    //    variant x machine must be sane (occupancy >= 1 block, finite
    //    positive steps/sec). The detail places the *measured* CPU
    //    propagator rate next to the prediction so reports show both
    //    columns. Vacuously true when no prediction was requested.
    let (thr_ok, thr_detail) = match &m.predicted {
        None => (
            true,
            format!(
                "no prediction requested; measured {:.1} steps/s ({})",
                m.measured_steps_per_sec, m.propagator
            ),
        ),
        Some(p) => (
            p.steps_per_sec.is_finite() && p.steps_per_sec > 0.0 && p.blocks_per_sm >= 1,
            format!(
                "{} on {}: predicted {:.2} steps/s ({} blocks/SM); measured {:.1} steps/s ({})",
                p.variant,
                p.machine,
                p.steps_per_sec,
                p.blocks_per_sm,
                m.measured_steps_per_sec,
                m.propagator
            ),
        ),
    };
    push("throughput_model", thr_ok, Severity::Soft, thr_detail);

    // 9. restart_consistent (hard): when the run exercised
    //    checkpoint -> restore -> continue, the resumed state must be
    //    bitwise identical to the uninterrupted one. Vacuously true
    //    for scenarios that do not exercise restart.
    let (rc_ok, rc_detail) = match (exp.require_restart_consistency, m.restart_max_diff) {
        (false, None) => (true, "restart not exercised by this scenario".to_string()),
        (true, None) => {
            (false, "restart required but the run recorded no comparison".to_string())
        }
        (_, Some(d)) => (
            d == 0.0,
            format!("max |resumed - uninterrupted| = {d:.3e} (bitwise identity required)"),
        ),
    };
    push("restart_consistent", rc_ok, Severity::Hard, rc_detail);

    ScenarioResult::from_criteria(criteria)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::metrics::{Metrics, PredictedPerf};

    fn healthy() -> Metrics {
        Metrics {
            steps_requested: 100,
            steps_completed: 100,
            dt: 1.0e-3,
            h: 10.0,
            v_max: 2500.0,
            cfl_dt: 1.5e-3,
            energy_trace: vec![1.0; 100],
            peak_energy: 10.0,
            final_energy: 1.0,
            peak_abs: 5.0,
            final_max_abs: 0.5,
            edge_peak_abs: 0.5,
            boundary_leakage: 0.1,
            late_growth: 0.8,
            first_non_finite: None,
            receiver_peak: vec![0.2, 0.3],
            wall_ms: 12.0,
            batch_wall_ms: 0.0,
            measured_mpts_per_sec: 1.0,
            measured_steps_per_sec: 8000.0,
            propagator: "naive".to_string(),
            restart_max_diff: None,
            predicted: None,
        }
    }

    #[test]
    fn healthy_metrics_pass_every_criterion() {
        let r = evaluate_pass_fail(&healthy(), &Expectations::default());
        assert_eq!(r.overall, Verdict::Pass, "failed: {:?}", r.failed());
        assert_eq!(r.criteria.len(), 9);
    }

    #[test]
    fn non_finite_is_a_hard_fail() {
        let mut m = healthy();
        m.first_non_finite = Some(42);
        let r = evaluate_pass_fail(&m, &Expectations::default());
        assert_eq!(r.overall, Verdict::HardFail);
        assert!(r.failed().iter().any(|c| c.name == "finite_field"));
    }

    #[test]
    fn cfl_violation_is_a_hard_fail() {
        let mut m = healthy();
        m.dt = 2.0 * m.cfl_dt;
        let r = evaluate_pass_fail(&m, &Expectations::default());
        assert_eq!(r.overall, Verdict::HardFail);
        assert!(r.failed().iter().any(|c| c.name == "cfl_respected"));
    }

    #[test]
    fn leakage_alone_is_a_soft_fail() {
        let mut m = healthy();
        m.boundary_leakage = 0.9;
        let r = evaluate_pass_fail(&m, &Expectations::default());
        assert_eq!(r.overall, Verdict::SoftFail);
        assert!(r.failed().iter().any(|c| c.name == "boundary_containment"));
    }

    #[test]
    fn hard_beats_soft_in_aggregate() {
        let mut m = healthy();
        m.boundary_leakage = 0.9; // soft
        m.late_growth = 100.0; // hard
        let r = evaluate_pass_fail(&m, &Expectations::default());
        assert_eq!(r.overall, Verdict::HardFail);
        assert_eq!(r.failed().len(), 2);
    }

    #[test]
    fn bad_prediction_is_soft() {
        let mut m = healthy();
        m.predicted = Some(PredictedPerf {
            machine: "V100".into(),
            variant: "gmem_8x8x8".into(),
            steps_per_sec: 0.0,
            gflops: 0.0,
            blocks_per_sm: 0,
        });
        let r = evaluate_pass_fail(&m, &Expectations::default());
        assert_eq!(r.overall, Verdict::SoftFail);
        assert!(r.failed().iter().any(|c| c.name == "throughput_model"));
    }

    #[test]
    fn restart_criterion_gates_on_bitwise_identity() {
        // not exercised, not required: vacuous pass
        let r = evaluate_pass_fail(&healthy(), &Expectations::default());
        assert!(r.criteria.iter().any(|c| c.name == "restart_consistent" && c.passed));

        // required but the run never compared: hard fail
        let exp = Expectations { require_restart_consistency: true, ..Expectations::default() };
        let r = evaluate_pass_fail(&healthy(), &exp);
        assert_eq!(r.overall, Verdict::HardFail);
        assert!(r.failed().iter().any(|c| c.name == "restart_consistent"));

        // compared and bitwise identical: pass
        let mut m = healthy();
        m.restart_max_diff = Some(0.0);
        let r = evaluate_pass_fail(&m, &exp);
        assert_eq!(r.overall, Verdict::Pass, "failed: {:?}", r.failed());

        // any nonzero diff is a hard fail, required or not
        m.restart_max_diff = Some(1.0e-7);
        let r = evaluate_pass_fail(&m, &Expectations::default());
        assert_eq!(r.overall, Verdict::HardFail);
    }

    #[test]
    fn verdict_ordering_and_names() {
        assert!(Verdict::Pass < Verdict::SoftFail && Verdict::SoftFail < Verdict::HardFail);
        assert_eq!(Verdict::HardFail.to_string(), "HardFail");
        let exp = Expectations { check_absorption: false, ..Expectations::default() };
        let mut m = healthy();
        m.final_energy = 100.0; // would fail absorption if checked
        let r = evaluate_pass_fail(&m, &exp);
        assert_eq!(r.overall, Verdict::Pass);
    }
}
