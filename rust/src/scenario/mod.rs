//! Scenario subsystem: named physics stress scenarios with pass/fail
//! verdicts, plus the parallel variant x machine campaign runner.
//!
//! The paper evaluates its 25 kernel variants across three machines on
//! one physics workload; cross-architecture follow-ups (arXiv:2404.04441,
//! arXiv:2406.08923) show such claims only hold under a *matrix* of
//! scenarios. This module supplies that matrix for the Rust testbed:
//!
//! * [`ScenarioId`] — a seeded catalogue of named stress scenarios,
//!   each materializing a full `RunConfig` (domain, velocity model,
//!   sources, receivers, dt) plus [`Expectations`] thresholds.
//! * [`MetricsCollector`] — a `StepObserver` hooked into
//!   `Coordinator::run_observed`: energy trace, peak amplitude,
//!   boundary-leakage ratio, NaN/Inf watch, plus gpusim-predicted
//!   steps/sec per variant x machine.
//! * [`evaluate_pass_fail`] — named criteria folded into a
//!   [`Verdict`] (`Pass` / `SoftFail` / `HardFail`).
//! * [`campaign`] — fans scenario x variant x machine cells out over
//!   `std::thread`, aggregates a report table + JSON export.
//!
//! Physics always runs on the pure-Rust CPU backend, so scenarios need
//! no AOT artifacts — but the variant axis is no longer cosmetic: each
//! kernel-variant id resolves to its executable CPU code shape
//! (`stencil::propagator`), so every cell carries a *measured*
//! steps/sec next to the gpusim-*predicted* one. The campaign runs the
//! physics once per (scenario, propagator signature) and reuses the
//! metrics across cells that only differ in predicted perf.

pub mod campaign;
pub mod metrics;
pub mod verdict;

pub use metrics::{predict_perf, Metrics, MetricsCollector, PredictedPerf};
pub use verdict::{evaluate_pass_fail, Criterion, Expectations, ScenarioResult, Severity, Verdict};

use crate::config::RunConfig;
use crate::coordinator::{Coordinator, Mode, RunOptions};
use crate::grid::{Dim3, Domain};
use crate::recovery::{BreakerConfig, Checkpoint};
use crate::stencil;
use crate::telemetry::{Registry, LATENCY_BOUNDS};
use crate::wave::{self, Source, VelocityModel};
use std::path::PathBuf;

/// The scenario catalogue. Every entry is deterministic: same id, same
/// physics, same verdict.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub enum ScenarioId {
    /// Point source in a homogeneous medium — the baseline sanity run.
    HomogeneousPoint,
    /// Three-layer earth model with strong impedance contrasts; the
    /// reflector bounces energy back through the grid.
    LayeredReflector,
    /// Linear velocity gradient with depth — exercises the
    /// materialized-grid CFL bound (the old 1e4 m depth assumption
    /// would have mis-throttled dt by ~6x here).
    GradientMedium,
    /// Source tucked next to a PML corner: the sponge absorbs at
    /// grazing incidence, its weakest regime.
    PmlCornerAbsorption,
    /// Three simultaneous sources, one in antiphase — interference
    /// must superpose linearly without spurious growth.
    MultiSourceInterference,
    /// Long run well past the source wavelet: energy must decay, not
    /// plateau or creep.
    EnergyStability,
    /// Deliberate CFL violation (dt 2.5x the stable limit): the verdict
    /// must be HardFail. The campaign treats this as expected-fail.
    CflMarginStress,
    /// Degenerate anisotropic tiny grid (single-digit extents, PML 2):
    /// decomposition and stencils must survive the smallest shapes.
    TinyGrid,
    /// Finely laminated fast/slow medium (~3 planes per layer): each
    /// cell is isotropic, but the long-wavelength response is
    /// effectively anisotropic (Backus averaging) — internal multiples
    /// stress layer lookup and the dt derivation.
    AnisotropicMedia,
    /// Checkpoint/restart gauntlet: the run interrupts itself mid-way,
    /// pushes its state through the serialized snapshot format,
    /// restores into a fresh coordinator, and must finish bitwise
    /// identical to the uninterrupted run.
    RestartConsistency,
}

/// A materialized scenario: run configuration, any extra sources, and
/// the thresholds its metrics are judged against.
#[derive(Clone, Debug)]
pub struct ScenarioSpec {
    pub config: RunConfig,
    pub extra_sources: Vec<Source>,
    pub expectations: Expectations,
}

impl ScenarioId {
    /// Every scenario, in catalogue order.
    pub fn all() -> Vec<ScenarioId> {
        vec![
            ScenarioId::HomogeneousPoint,
            ScenarioId::LayeredReflector,
            ScenarioId::GradientMedium,
            ScenarioId::PmlCornerAbsorption,
            ScenarioId::MultiSourceInterference,
            ScenarioId::EnergyStability,
            ScenarioId::CflMarginStress,
            ScenarioId::TinyGrid,
            ScenarioId::AnisotropicMedia,
            ScenarioId::RestartConsistency,
        ]
    }

    /// Kebab-case name (CLI id and JSON key).
    pub fn name(&self) -> &'static str {
        match self {
            ScenarioId::HomogeneousPoint => "homogeneous-point",
            ScenarioId::LayeredReflector => "layered-reflector",
            ScenarioId::GradientMedium => "gradient-medium",
            ScenarioId::PmlCornerAbsorption => "pml-corner-absorption",
            ScenarioId::MultiSourceInterference => "multi-source-interference",
            ScenarioId::EnergyStability => "energy-stability",
            ScenarioId::CflMarginStress => "cfl-margin-stress",
            ScenarioId::TinyGrid => "tiny-grid",
            ScenarioId::AnisotropicMedia => "anisotropic-media",
            ScenarioId::RestartConsistency => "restart-consistency",
        }
    }

    /// One-line description for listings.
    pub fn describe(&self) -> &'static str {
        match self {
            ScenarioId::HomogeneousPoint => "point source, homogeneous medium (baseline)",
            ScenarioId::LayeredReflector => "3-layer reflector with strong contrasts",
            ScenarioId::GradientMedium => "linear v(z) gradient; CFL from the real grid",
            ScenarioId::PmlCornerAbsorption => "source against a PML corner (grazing absorption)",
            ScenarioId::MultiSourceInterference => "3 simultaneous sources, one antiphase",
            ScenarioId::EnergyStability => "long run; energy must decay after the wavelet",
            ScenarioId::CflMarginStress => "dt 2.5x past CFL — expected HardFail",
            ScenarioId::TinyGrid => "degenerate 9x7x11 grid, PML width 2",
            ScenarioId::AnisotropicMedia => "finely laminated fast/slow medium (effective anisotropy)",
            ScenarioId::RestartConsistency => "checkpoint -> restore mid-run; must stay bitwise identical",
        }
    }

    /// Parse a CLI/JSON name (kebab or snake case).
    pub fn parse(s: &str) -> anyhow::Result<ScenarioId> {
        let norm = s.trim().to_ascii_lowercase().replace('_', "-");
        Self::all()
            .into_iter()
            .find(|id| id.name() == norm)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown scenario {s:?} (expected one of: {})",
                    Self::all().iter().map(|i| i.name()).collect::<Vec<_>>().join(", ")
                )
            })
    }

    /// Deliberately mis-configured scenarios: the campaign expects
    /// these to fail and does not count them against the exit code.
    pub fn is_stress(&self) -> bool {
        matches!(self, ScenarioId::CflMarginStress)
    }

    /// The verdict a healthy implementation should produce.
    pub fn expected_verdict(&self) -> Verdict {
        if self.is_stress() {
            Verdict::HardFail
        } else {
            Verdict::Pass
        }
    }

    /// Materialize the scenario into a runnable spec. Grids are kept
    /// small so the whole catalogue runs in seconds on the golden
    /// backend; dt always derives from the materialized velocity grid.
    pub fn materialize(&self) -> ScenarioSpec {
        let base = RunConfig::defaults();
        let spec = |interior: Dim3,
                    pml: usize,
                    h: f64,
                    model: VelocityModel,
                    dt_scale: f64,
                    steps: usize,
                    source: Source,
                    receivers: Vec<Dim3>|
         -> RunConfig {
            let v_max = model.v_max_on(interior) as f64;
            let dt = stencil::cfl_dt(h, v_max) * dt_scale;
            RunConfig {
                domain: Domain { interior, pml_width: pml, h, dt },
                steps,
                mode: Mode::Golden,
                model,
                source,
                receivers,
                ..base.clone()
            }
        };
        let src = |pos: Dim3, f0: f64, amplitude: f64| Source { pos, f0, amplitude };
        let shallow_line = |interior: Dim3, pml: usize| -> Vec<Dim3> {
            let y = interior.y / 2;
            (0..3)
                .map(|i| Dim3::new(pml + 1, y, pml + 2 + i * ((interior.x - 2 * pml) / 3).max(1)))
                .collect()
        };

        match self {
            ScenarioId::HomogeneousPoint => {
                let n = Dim3::new(32, 32, 32);
                ScenarioSpec {
                    config: spec(
                        n,
                        5,
                        10.0,
                        VelocityModel::Constant(2500.0),
                        1.0,
                        180,
                        src(Dim3::new(16, 16, 16), 25.0, 1.0),
                        shallow_line(n, 5),
                    ),
                    extra_sources: vec![],
                    expectations: Expectations {
                        min_peak_abs: 1e-4,
                        max_leakage: 0.6,
                        max_final_fraction: 0.8,
                        require_receivers: true,
                        ..Expectations::default()
                    },
                }
            }
            ScenarioId::LayeredReflector => {
                let n = Dim3::new(36, 32, 32);
                let model = VelocityModel::Layered(vec![
                    (0.0, 1800.0),
                    (0.45, 3200.0),
                    (0.75, 4200.0),
                ]);
                ScenarioSpec {
                    config: spec(
                        n,
                        5,
                        10.0,
                        model,
                        1.0,
                        180,
                        src(Dim3::new(9, 16, 16), 22.0, 1.0),
                        shallow_line(n, 5),
                    ),
                    extra_sources: vec![],
                    expectations: Expectations {
                        min_peak_abs: 1e-4,
                        max_leakage: 0.7, // reflector pushes energy at the faces
                        max_final_fraction: 0.9,
                        require_receivers: true,
                        ..Expectations::default()
                    },
                }
            }
            ScenarioId::GradientMedium => {
                let n = Dim3::new(40, 28, 28);
                let model = VelocityModel::GradientZ { v0: 1500.0, k_per_m: 1.0, h: 10.0 };
                ScenarioSpec {
                    config: spec(
                        n,
                        5,
                        10.0,
                        model,
                        1.0,
                        180,
                        src(Dim3::new(12, 14, 14), 22.0, 1.0),
                        vec![],
                    ),
                    extra_sources: vec![],
                    expectations: Expectations {
                        min_peak_abs: 1e-4,
                        max_leakage: 0.7,
                        max_final_fraction: 0.9,
                        ..Expectations::default()
                    },
                }
            }
            ScenarioId::PmlCornerAbsorption => {
                let n = Dim3::new(32, 32, 32);
                let pml = 6;
                ScenarioSpec {
                    config: spec(
                        n,
                        pml,
                        10.0,
                        VelocityModel::Constant(2500.0),
                        1.0,
                        200,
                        src(Dim3::new(pml + 2, pml + 2, pml + 2), 25.0, 1.0),
                        vec![],
                    ),
                    extra_sources: vec![],
                    expectations: Expectations {
                        min_peak_abs: 1e-4,
                        max_leakage: 0.7,
                        max_final_fraction: 0.9,
                        ..Expectations::default()
                    },
                }
            }
            ScenarioId::MultiSourceInterference => {
                let n = Dim3::new(36, 36, 36);
                ScenarioSpec {
                    config: spec(
                        n,
                        5,
                        10.0,
                        VelocityModel::Constant(2500.0),
                        1.0,
                        160,
                        src(Dim3::new(18, 18, 12), 25.0, 1.0),
                        shallow_line(n, 5),
                    ),
                    extra_sources: vec![
                        src(Dim3::new(18, 18, 24), 25.0, 1.0),
                        src(Dim3::new(18, 12, 18), 25.0, -1.0), // antiphase
                    ],
                    expectations: Expectations {
                        min_peak_abs: 1e-4,
                        max_leakage: 0.7,
                        max_final_fraction: 0.9,
                        require_receivers: true,
                        ..Expectations::default()
                    },
                }
            }
            ScenarioId::EnergyStability => {
                let n = Dim3::new(28, 28, 28);
                ScenarioSpec {
                    config: spec(
                        n,
                        5,
                        10.0,
                        VelocityModel::Constant(2200.0),
                        1.0,
                        400,
                        src(Dim3::new(14, 14, 14), 30.0, 1.0),
                        vec![],
                    ),
                    extra_sources: vec![],
                    expectations: Expectations {
                        min_peak_abs: 1e-4,
                        max_leakage: 0.7,
                        max_late_growth: 1.5,
                        max_final_fraction: 0.6,
                        ..Expectations::default()
                    },
                }
            }
            ScenarioId::CflMarginStress => {
                let n = Dim3::new(28, 28, 28);
                ScenarioSpec {
                    config: spec(
                        n,
                        4,
                        10.0,
                        VelocityModel::Constant(2500.0),
                        2.5, // dt deliberately past the stable limit
                        200,
                        src(Dim3::new(14, 14, 14), 25.0, 1.0),
                        vec![],
                    ),
                    extra_sources: vec![],
                    expectations: Expectations::default(),
                }
            }
            ScenarioId::TinyGrid => {
                let n = Dim3::new(9, 7, 11);
                ScenarioSpec {
                    config: spec(
                        n,
                        2,
                        10.0,
                        VelocityModel::Constant(2000.0),
                        1.0,
                        80,
                        src(Dim3::new(4, 3, 5), 40.0, 1.0),
                        vec![],
                    ),
                    extra_sources: vec![],
                    expectations: Expectations {
                        min_peak_abs: 1e-6,
                        max_leakage: 1.0, // PML width 2 barely absorbs
                        // few modes -> sum(u^2) swings; only order-of-
                        // magnitude growth means instability here
                        max_late_growth: 4.0,
                        max_final_fraction: 1.0,
                        check_absorption: false,
                        ..Expectations::default()
                    },
                }
            }
            ScenarioId::AnisotropicMedia => {
                let n = Dim3::new(36, 32, 32);
                // 12 alternating fast/slow laminae, ~3 planes each: the
                // Backus-averaged long-wavelength medium is anisotropic
                // even though every cell is isotropic
                let layers: Vec<(f64, f32)> = (0..12)
                    .map(|i| (i as f64 / 12.0, if i % 2 == 0 { 2000.0 } else { 3600.0 }))
                    .collect();
                ScenarioSpec {
                    config: spec(
                        n,
                        5,
                        10.0,
                        VelocityModel::Layered(layers),
                        1.0,
                        180,
                        src(Dim3::new(9, 16, 16), 22.0, 1.0),
                        shallow_line(n, 5),
                    ),
                    extra_sources: vec![],
                    expectations: Expectations {
                        min_peak_abs: 1e-4,
                        // internal multiples keep energy bouncing between
                        // laminae longer than a 3-layer reflector does
                        max_leakage: 0.8,
                        max_final_fraction: 0.95,
                        require_receivers: true,
                        ..Expectations::default()
                    },
                }
            }
            ScenarioId::RestartConsistency => {
                let n = Dim3::new(28, 28, 28);
                ScenarioSpec {
                    config: spec(
                        n,
                        5,
                        10.0,
                        VelocityModel::Constant(2400.0),
                        1.0,
                        160,
                        src(Dim3::new(14, 14, 14), 25.0, 1.0),
                        shallow_line(n, 5),
                    ),
                    extra_sources: vec![],
                    expectations: Expectations {
                        min_peak_abs: 1e-4,
                        max_leakage: 0.7,
                        max_final_fraction: 0.9,
                        require_receivers: true,
                        require_restart_consistency: true,
                        ..Expectations::default()
                    },
                }
            }
        }
    }
}

/// Knobs for a single scenario run.
#[derive(Clone, Debug, Default)]
pub struct RunnerOptions {
    /// Override the scenario's step count outright.
    pub steps_override: Option<usize>,
    /// Scale the scenario's step count (campaign `--quick`); floor 20.
    pub steps_scale: Option<f64>,
    /// Attach a gpusim performance prediction for this machine...
    pub machine: Option<String>,
    /// ...and this kernel variant id (both or neither).
    pub variant: Option<String>,
    /// CPU code shape for the physics run. Defaults to the variant's
    /// propagator analog, or `naive` when no variant is given — so a
    /// predicted cell also *measures* the shape it predicts.
    pub propagator: Option<String>,
    /// Worker threads inside the propagator tile fan-out (0 = one per
    /// core). The campaign sets each job's share of the global worker
    /// budget (`campaign::split_budget`).
    pub cpu_threads: usize,
    /// Cap observed-run batches at N steps so fused backends retain
    /// finer-grained energy/receiver traces (0 keeps the backend's
    /// natural cadence; `--sample-every` on the CLI).
    pub sample_every: usize,
    /// z-slab shard count for the physics run (0/1 = unsharded;
    /// `--shards` on the CLI). Sharded runs stay bit-identical to
    /// unsharded ones, so expectations are unchanged; infeasible
    /// decompositions (slab thinner than the fused halo) error out.
    pub shards: usize,
    /// Telemetry registry to attach to the run (a cloned handle shares
    /// the same series). When absent the physics still runs with a
    /// private registry so per-batch wall time lands in the metrics.
    pub telemetry: Option<Registry>,
    /// Checkpoint cadence in steps (0 = disabled; `--checkpoint-every`
    /// on the CLI). Needs `checkpoint_path` to actually write.
    pub checkpoint_every: usize,
    /// Snapshot destination for cadence checkpoints and breaker-trip
    /// dumps (`--checkpoint-path` on the CLI).
    pub checkpoint_path: Option<PathBuf>,
    /// Restore the run from this snapshot before stepping and execute
    /// only the remaining step budget (`--restore` on the CLI).
    pub restore: Option<PathBuf>,
    /// Divergence circuit breakers to arm (`--breakers` on the CLI):
    /// a tripped run soft-aborts with a checkpoint instead of stepping
    /// a dead wavefield to the budget.
    pub breakers: Option<BreakerConfig>,
}

impl RunnerOptions {
    /// The propagator name this run's physics will execute with.
    pub fn physics_propagator(&self) -> String {
        self.propagator
            .clone()
            .or_else(|| self.variant.clone())
            .unwrap_or_else(|| "naive".to_string())
    }
}

/// One completed scenario run.
#[derive(Clone, Debug)]
pub struct ScenarioRun {
    pub id: ScenarioId,
    pub metrics: Metrics,
    pub result: ScenarioResult,
}

impl ScenarioRun {
    /// Did the verdict match what the catalogue expects? (Stress
    /// scenarios are *supposed* to fail hard.)
    pub fn as_expected(&self) -> bool {
        self.result.overall == self.id.expected_verdict()
    }
}

/// Run one scenario's *physics* on the CPU propagator selected by
/// `opts` and collect metrics — no prediction, no verdict. The
/// campaign fans these out once per (scenario, propagator signature)
/// and reuses the metrics across every cell that only differs in
/// predicted perf.
pub fn run_scenario_physics(id: ScenarioId, opts: &RunnerOptions) -> anyhow::Result<Metrics> {
    let spec = id.materialize();
    let cfg = &spec.config;
    let mut steps = opts.steps_override.unwrap_or(cfg.steps);
    if let Some(scale) = opts.steps_scale {
        steps = ((steps as f64 * scale) as usize).max(20);
    }

    let propagator = opts.physics_propagator();
    let interior = cfg.domain.interior;
    let v_max_grid =
        cfg.model.build(interior).as_slice().iter().fold(0.0f32, |a, &b| a.max(b)) as f64;
    // the restart-consistency scenario needs identically-configured
    // twin coordinators, so construction lives in a closure
    let mk_coord = || -> anyhow::Result<Coordinator<'static>> {
        let v = cfg.model.build(interior);
        let eta = wave::eta_profile(&cfg.domain, v_max_grid);
        let mut c = Coordinator::new(
            None,
            cfg.domain,
            Mode::Golden,
            &propagator,
            &cfg.pml_variant,
            v,
            eta,
            cfg.source,
            cfg.receivers.clone(),
        )?;
        c.set_cpu_threads(opts.cpu_threads);
        c.set_shards(opts.shards.max(1))?;
        for s in &spec.extra_sources {
            c.add_source(*s)?;
        }
        Ok(c)
    };
    let mut coord = mk_coord()?;
    // every physics run is instrumented: with a caller-supplied
    // registry when given (CLI --telemetry), a private one otherwise,
    // so the batch-latency histogram always feeds the metrics
    let reg = opts.telemetry.clone().unwrap_or_default();
    coord.set_telemetry(&reg);
    coord.set_checkpointing(opts.checkpoint_every, opts.checkpoint_path.clone());
    coord.set_breakers(opts.breakers);
    let mut steps_to_run = steps;
    if let Some(path) = &opts.restore {
        coord.restore(&Checkpoint::load(path)?)?;
        steps_to_run = steps.saturating_sub(coord.steps_done());
    }
    let signature = coord.propagator_signature().expect("Golden mode has a propagator");

    let ropts = RunOptions { halt_on_non_finite: false, sample_every: opts.sample_every };
    let mut collector = MetricsCollector::new(cfg.domain);
    let summary = coord.run_observed(steps_to_run, ropts, Some(&mut collector))?;
    let mut metrics = collector.finish(steps_to_run, &summary, v_max_grid, signature);
    metrics.batch_wall_ms = reg
        .histogram(
            "hostencil_batch_latency_seconds",
            "Wall-clock latency of one observed-run step batch.",
            &LATENCY_BOUNDS,
        )
        .sum()
        * 1e3;

    // the restart-consistency scenario interrupts a twin of the run
    // above mid-way, pushes its state through the serialized snapshot
    // format, restores into a fresh coordinator, finishes the budget,
    // and records the max deviation from the uninterrupted oracle —
    // bitwise identity means exactly 0.0
    if matches!(id, ScenarioId::RestartConsistency) && opts.restore.is_none() {
        let k = (steps_to_run / 2).max(1);
        let mut first = mk_coord()?;
        first.run_observed(k, ropts, None)?;
        let snapshot = Checkpoint::from_bytes(&first.checkpoint().to_bytes())?;
        let mut resumed = mk_coord()?;
        resumed.restore(&snapshot)?;
        resumed.run_observed(steps_to_run - k, ropts, None)?;
        let mut worst = resumed.wavefield().max_abs_diff(&coord.wavefield()) as f64;
        if worst == 0.0 && resumed.state_digest() != coord.state_digest() {
            // u matches but um or the step cursor drifted
            worst = f64::MIN_POSITIVE;
        }
        metrics.restart_max_diff = Some(worst);
    }
    Ok(metrics)
}

/// Run one scenario end to end: propagator physics, optional gpusim
/// prediction, pass/fail verdict.
pub fn run_scenario(id: ScenarioId, opts: &RunnerOptions) -> anyhow::Result<ScenarioRun> {
    match (&opts.machine, &opts.variant) {
        (Some(_), Some(_)) | (None, None) => {}
        _ => anyhow::bail!("prediction needs both --machine and --variant (or neither)"),
    }
    let mut metrics = run_scenario_physics(id, opts)?;
    if let (Some(m), Some(vid)) = (&opts.machine, &opts.variant) {
        metrics.predicted = Some(predict_perf(m, vid)?);
    }
    let result = evaluate_pass_fail(&metrics, &id.materialize().expectations);
    Ok(ScenarioRun { id, metrics, result })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalogue_has_at_least_eight_named_scenarios() {
        let all = ScenarioId::all();
        assert!(all.len() >= 8, "{}", all.len());
        let mut names: Vec<_> = all.iter().map(|i| i.name()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), all.len(), "names must be unique");
        for id in &all {
            assert!(!id.describe().is_empty());
            assert_eq!(ScenarioId::parse(id.name()).unwrap(), *id);
        }
        assert_eq!(
            ScenarioId::parse("cfl_margin_stress").unwrap(),
            ScenarioId::CflMarginStress
        );
        assert!(ScenarioId::parse("black-thursday").is_err());
    }

    #[test]
    fn every_spec_materializes_a_valid_domain() {
        for id in ScenarioId::all() {
            let s = id.materialize();
            s.config.domain.validate().unwrap_or_else(|e| panic!("{}: {e}", id.name()));
            assert!(s.config.steps >= 20, "{}", id.name());
            let n = s.config.domain.interior;
            let inb = |p: Dim3| p.z < n.z && p.y < n.y && p.x < n.x;
            assert!(inb(s.config.source.pos), "{}: source oob", id.name());
            for r in &s.config.receivers {
                assert!(inb(*r), "{}: receiver oob", id.name());
            }
            for x in &s.extra_sources {
                assert!(inb(x.pos), "{}: extra source oob", id.name());
            }
        }
    }

    #[test]
    fn stress_flags_line_up_with_expected_verdicts() {
        for id in ScenarioId::all() {
            if id.is_stress() {
                assert_eq!(id.expected_verdict(), Verdict::HardFail);
            } else {
                assert_eq!(id.expected_verdict(), Verdict::Pass);
            }
        }
        assert!(ScenarioId::CflMarginStress.is_stress());
    }

    #[test]
    fn cfl_stress_dt_is_actually_unstable() {
        let s = ScenarioId::CflMarginStress.materialize();
        let v_max = s.config.model.v_max_on(s.config.domain.interior) as f64;
        assert!(s.config.domain.dt > stencil::cfl_dt(s.config.domain.h, v_max) * 2.0);
    }

    #[test]
    fn tiny_grid_runs_to_completion() {
        let run = run_scenario(ScenarioId::TinyGrid, &RunnerOptions::default()).unwrap();
        assert!(run.metrics.first_non_finite.is_none());
        assert_eq!(run.metrics.steps_completed, run.metrics.steps_requested);
    }

    #[test]
    fn runner_rejects_half_specified_prediction() {
        let opts = RunnerOptions { machine: Some("v100".into()), ..Default::default() };
        assert!(run_scenario(ScenarioId::TinyGrid, &opts).is_err());
    }

    #[test]
    fn physics_propagator_defaults_and_overrides() {
        assert_eq!(RunnerOptions::default().physics_propagator(), "naive");
        let from_variant =
            RunnerOptions { variant: Some("st_smem_16x16".into()), ..Default::default() };
        assert_eq!(from_variant.physics_propagator(), "st_smem_16x16");
        let explicit = RunnerOptions {
            variant: Some("gmem_8x8x8".into()),
            propagator: Some("semi".into()),
            ..Default::default()
        };
        assert_eq!(explicit.physics_propagator(), "semi");
    }

    #[test]
    fn scenario_runs_feed_telemetry_and_honor_sample_every() {
        let reg = crate::telemetry::Registry::new();
        let opts = RunnerOptions {
            propagator: Some("tf_s4".into()),
            telemetry: Some(reg.clone()),
            ..Default::default()
        };
        let m = run_scenario_physics(ScenarioId::TinyGrid, &opts).unwrap();
        assert!(m.batch_wall_ms > 0.0, "batch wall must come from the histogram");
        assert!(m.batch_wall_ms <= m.wall_ms, "batch wall is a slice of total wall");
        // TinyGrid runs 80 steps; fuse 4 -> 20 batch-boundary samples
        assert_eq!(m.energy_trace.len(), 20);
        let text = reg.render();
        assert!(text.contains("hostencil_steps_total 80"), "{text}");
        assert!(text.contains("hostencil_batch_latency_seconds_count 20"), "{text}");

        // --sample-every 1 restores the full per-step trace (satellite
        // regression: fused runs must match the unfused trace length)
        let fine = RunnerOptions {
            propagator: Some("tf_s4".into()),
            sample_every: 1,
            ..Default::default()
        };
        let mf = run_scenario_physics(ScenarioId::TinyGrid, &fine).unwrap();
        let unfused = RunnerOptions { propagator: Some("naive".into()), ..Default::default() };
        let mu = run_scenario_physics(ScenarioId::TinyGrid, &unfused).unwrap();
        assert_eq!(mf.energy_trace.len(), mu.energy_trace.len());
        assert_eq!(mf.energy_trace.len(), 80);
    }

    #[test]
    fn restart_scenario_proves_bitwise_continuation() {
        let opts = RunnerOptions { steps_override: Some(60), ..Default::default() };
        let m = run_scenario_physics(ScenarioId::RestartConsistency, &opts).unwrap();
        assert_eq!(m.restart_max_diff, Some(0.0));
        // sharded restart gathers slabs through the same format and
        // must stay bitwise too
        let sharded =
            RunnerOptions { steps_override: Some(60), shards: 2, ..Default::default() };
        let ms = run_scenario_physics(ScenarioId::RestartConsistency, &sharded).unwrap();
        assert_eq!(ms.restart_max_diff, Some(0.0));
        // other scenarios do not exercise restart
        let mt = run_scenario_physics(ScenarioId::TinyGrid, &RunnerOptions::default()).unwrap();
        assert_eq!(mt.restart_max_diff, None);
        // and the verdict wires the measurement into its own criterion
        let run = run_scenario(ScenarioId::RestartConsistency, &opts).unwrap();
        assert!(run
            .result
            .criteria
            .iter()
            .any(|c| c.name == "restart_consistent" && c.passed));
    }

    #[test]
    fn default_breakers_stay_quiet_on_healthy_scenarios() {
        // false-positive gate: an armed energy-growth breaker must not
        // clip any passing scenario short, unsharded or 2-shard (the
        // EnergyStability 400-step run arms well inside its budget, so
        // the ring comparison genuinely runs there)
        for id in ScenarioId::all().into_iter().filter(|i| !i.is_stress()) {
            for shards in [1usize, 2] {
                let opts = RunnerOptions {
                    breakers: Some(BreakerConfig::default()),
                    shards,
                    ..Default::default()
                };
                let m = run_scenario_physics(id, &opts).unwrap();
                assert_eq!(
                    m.steps_completed,
                    m.steps_requested,
                    "breaker tripped {} (shards={shards})",
                    id.name()
                );
                assert!(m.first_non_finite.is_none(), "{}", id.name());
            }
        }
    }

    #[test]
    fn scenario_metrics_record_the_measured_shape() {
        let opts = RunnerOptions { propagator: Some("st_smem_8x8".into()), ..Default::default() };
        let run = run_scenario(ScenarioId::TinyGrid, &opts).unwrap();
        assert_eq!(run.metrics.propagator, "streaming2.5d:8x8");
        assert!(run.metrics.measured_steps_per_sec > 0.0);
        assert!(run.metrics.predicted.is_none());
    }
}
