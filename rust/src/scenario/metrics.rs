//! Metrics collection: a [`StepObserver`] hooked into
//! `Coordinator::run_observed` plus the finished [`Metrics`] record the
//! verdict engine evaluates.

use crate::coordinator::{RunSummary, StepObserver};
use crate::grid::{Dim3, Domain, Field3};
use crate::gpusim::{arch, kernels, occupancy, timing};
use crate::stencil;
use crate::R;

/// gpusim-model performance prediction for one variant on one machine
/// (the paper's Table II cell, expressed as a rate).
#[derive(Clone, Debug)]
pub struct PredictedPerf {
    pub machine: String,
    pub variant: String,
    /// Predicted full-step rate on the machine's evaluation grid.
    pub steps_per_sec: f64,
    pub gflops: f64,
    /// Inner-kernel occupancy: 0 means the variant cannot launch.
    pub blocks_per_sm: u32,
}

/// Predict steps/sec for `variant` on `machine` with the roofline
/// timing model (1000-step paper convention; the rate is step-count
/// invariant up to launch-overhead amortization).
pub fn predict_perf(machine: &str, variant: &str) -> anyhow::Result<PredictedPerf> {
    let a = arch::by_name(machine)?;
    let v = kernels::by_id(variant)?;
    let steps = 1000;
    let run = timing::simulate(&a, &v, steps);
    let occ = occupancy(&a, &v.resources_inner());
    Ok(PredictedPerf {
        machine: a.name.to_string(),
        variant: variant.to_string(),
        steps_per_sec: steps as f64 / run.time_s.max(1e-12),
        gflops: run.gflops,
        blocks_per_sm: occ.blocks_per_sm,
    })
}

/// Everything the verdict engine looks at, collected from one run.
#[derive(Clone, Debug)]
pub struct Metrics {
    pub steps_requested: usize,
    pub steps_completed: usize,
    pub dt: f64,
    pub h: f64,
    /// Maximum velocity of the *materialized* grid (not a nominal bound).
    pub v_max: f64,
    /// CFL limit for (h, v_max).
    pub cfl_dt: f64,
    /// Interior energy after every step.
    pub energy_trace: Vec<f64>,
    pub peak_energy: f64,
    pub final_energy: f64,
    /// Peak |u| anywhere, over the whole run.
    pub peak_abs: f32,
    pub final_max_abs: f32,
    /// Peak |u| on the outermost interior layer, over the whole run.
    pub edge_peak_abs: f32,
    /// edge_peak_abs / peak_abs — the boundary-leakage ratio.
    pub boundary_leakage: f64,
    /// final energy vs the 3/4-point of the trace (slow-instability watch).
    pub late_growth: f64,
    /// First step at which the wavefield went NaN/Inf.
    pub first_non_finite: Option<usize>,
    /// Peak |trace| per receiver.
    pub receiver_peak: Vec<f32>,
    pub wall_ms: f64,
    /// Wall time spent inside step batches, summed from the run's
    /// telemetry batch-latency histogram — the kernel-only slice of
    /// `wall_ms` (observer and setup overhead excluded). 0 when the
    /// run carried no telemetry registry.
    pub batch_wall_ms: f64,
    pub measured_mpts_per_sec: f64,
    /// Measured full-step rate of the CPU propagator that actually ran
    /// this scenario's physics — the empirical column next to the
    /// gpusim `predicted` one.
    pub measured_steps_per_sec: f64,
    /// Code shape that produced the measured physics (propagator
    /// signature, e.g. `blocked3d:8x8x8`).
    pub propagator: String,
    /// Max |resumed - uninterrupted| over the final wavefield when the
    /// run exercised checkpoint -> restore -> continue (the
    /// restart-consistency scenario); `None` when restart was not
    /// exercised. Bitwise restart consistency means exactly 0.0.
    pub restart_max_diff: Option<f64>,
    pub predicted: Option<PredictedPerf>,
}

/// Step observer that accumulates the per-step ingredients of
/// [`Metrics`]. Feed it to `Coordinator::run_observed`, then call
/// [`MetricsCollector::finish`] with the run summary.
pub struct MetricsCollector {
    domain: Domain,
    energy: Vec<f64>,
    peak_abs: f32,
    edge_peak_abs: f32,
    first_non_finite: Option<usize>,
}

/// Max |u| over the outermost interior layer of an R-ghost-padded
/// wavefield (the six faces of the interior box).
fn edge_max_abs(u_pad: &Field3, interior: Dim3) -> f32 {
    let g = R;
    let (nz, ny, nx) = (interior.z, interior.y, interior.x);
    let mut m = 0.0f32;
    let mut scan = |z: usize, y: usize, x: usize| {
        m = m.max(u_pad.get(g + z, g + y, g + x).abs());
    };
    for y in 0..ny {
        for x in 0..nx {
            scan(0, y, x);
            scan(nz - 1, y, x);
        }
    }
    for z in 1..nz.saturating_sub(1) {
        for x in 0..nx {
            scan(z, 0, x);
            scan(z, ny - 1, x);
        }
        for y in 1..ny.saturating_sub(1) {
            scan(z, y, 0);
            scan(z, y, nx - 1);
        }
    }
    m
}

impl MetricsCollector {
    pub fn new(domain: Domain) -> MetricsCollector {
        MetricsCollector {
            domain,
            energy: Vec::new(),
            peak_abs: 0.0,
            edge_peak_abs: 0.0,
            first_non_finite: None,
        }
    }

    /// Fold the per-step accumulators and the run summary into the
    /// final record. `v_max` is the materialized-grid maximum velocity;
    /// `propagator` is the signature of the CPU code shape that ran
    /// the physics.
    pub fn finish(
        self,
        steps_requested: usize,
        summary: &RunSummary,
        v_max: f64,
        propagator: String,
    ) -> Metrics {
        let energy = self.energy;
        let peak_energy = energy.iter().copied().filter(|e| e.is_finite()).fold(0.0, f64::max);
        let final_energy = energy.last().copied().unwrap_or(0.0);
        // Slow-instability watch: mean energy over the trace's tail
        // window vs the window ending at the 3/4 point. Window means
        // (rather than point samples) keep the kinetic<->potential
        // oscillation of sum(u^2) on small grids from masquerading as
        // growth; a genuinely diverging run dwarfs any such swing.
        let mean = |s: &[f64]| s.iter().sum::<f64>() / s.len().max(1) as f64;
        let late_growth = if energy.len() >= 16 {
            let w = (energy.len() / 8).max(2);
            let tail = mean(&energy[energy.len() - w..]);
            let ref_end = energy.len() * 3 / 4;
            let e_ref = mean(&energy[ref_end - w..ref_end]);
            if !e_ref.is_finite() || !tail.is_finite() {
                f64::INFINITY
            } else if e_ref <= 1e-300 {
                if tail <= 1e-300 {
                    1.0
                } else {
                    f64::INFINITY
                }
            } else {
                tail / e_ref
            }
        } else {
            1.0
        };
        let boundary_leakage = if self.peak_abs > 0.0 {
            self.edge_peak_abs as f64 / self.peak_abs as f64
        } else {
            0.0
        };
        Metrics {
            steps_requested,
            steps_completed: summary.steps,
            dt: self.domain.dt,
            h: self.domain.h,
            v_max,
            cfl_dt: stencil::cfl_dt(self.domain.h, v_max),
            peak_energy,
            final_energy,
            peak_abs: self.peak_abs,
            final_max_abs: summary.final_max_abs,
            edge_peak_abs: self.edge_peak_abs,
            boundary_leakage,
            late_growth,
            first_non_finite: self.first_non_finite,
            receiver_peak: summary
                .traces
                .iter()
                .map(|t| t.iter().fold(0.0f32, |a, &b| a.max(b.abs())))
                .collect(),
            wall_ms: summary.wall.as_secs_f64() * 1e3,
            batch_wall_ms: 0.0, // filled in by run_scenario_physics from telemetry
            measured_mpts_per_sec: summary.points_per_sec / 1e6,
            measured_steps_per_sec: summary.steps as f64
                / summary.wall.as_secs_f64().max(1e-12),
            propagator,
            energy_trace: energy,
            restart_max_diff: None, // filled in by run_scenario_physics
            predicted: None,
        }
    }
}

impl StepObserver for MetricsCollector {
    fn on_step(&mut self, step: usize, u_pad: &Field3, energy: f64) {
        // `energy` is the coordinator's own per-step sum — no recompute.
        // A finite f32 field always sums to a finite f64 (max term
        // ~1.2e77 over <=1e9 points), so non-finite energy is an exact
        // proxy for a non-finite wavefield.
        self.energy.push(energy);
        // f32::max ignores NaN operands, so peaks stay meaningful even
        // after a blow-up; the non-finite watch records the step.
        self.peak_abs = self.peak_abs.max(u_pad.max_abs());
        self.edge_peak_abs = self.edge_peak_abs.max(edge_max_abs(u_pad, self.domain.interior));
        if self.first_non_finite.is_none() && !energy.is_finite() {
            self.first_non_finite = Some(step);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn summary(steps: usize) -> RunSummary {
        RunSummary {
            steps,
            wall: Duration::from_millis(5),
            launches: 7 * steps as u64,
            final_max_abs: 0.1,
            final_energy: 0.5,
            points_per_sec: 1e6,
            energy_log: vec![],
            traces: vec![vec![0.0, -0.4, 0.2]],
        }
    }

    fn domain() -> Domain {
        Domain::new(Dim3::new(12, 12, 12), 2, 10.0, 1e-3).unwrap()
    }

    #[test]
    fn edge_max_abs_sees_only_the_shell() {
        let interior = Dim3::new(6, 5, 4);
        let mut u = Field3::zeros(interior.padded(R));
        // center value must be invisible to the edge scan
        u.set(R + 3, R + 2, R + 2, 100.0);
        assert_eq!(edge_max_abs(&u, interior), 0.0);
        // a face value must be seen
        u.set(R, R + 2, R + 2, -7.0);
        assert_eq!(edge_max_abs(&u, interior), 7.0);
        // and an edge/corner value too
        u.set(R + 5, R + 4, R + 3, 9.0);
        assert_eq!(edge_max_abs(&u, interior), 9.0);
    }

    #[test]
    fn collector_tracks_peaks_and_non_finite() {
        let d = domain();
        let mut c = MetricsCollector::new(d);
        let mut u = Field3::zeros(d.padded());
        u.set(R + 6, R + 6, R + 6, 2.0);
        c.on_step(1, &u, u.energy());
        u.set(R + 6, R + 6, R + 6, -3.0);
        c.on_step(2, &u, u.energy());
        u.set(R, R, R, f32::NAN);
        c.on_step(3, &u, u.energy());
        assert_eq!(c.first_non_finite, Some(3));
        let m = c.finish(10, &summary(3), 2500.0, "naive".to_string());
        assert_eq!(m.peak_abs, 3.0);
        assert_eq!(m.steps_completed, 3);
        assert_eq!(m.steps_requested, 10);
        assert_eq!(m.energy_trace.len(), 3);
        assert_eq!(m.receiver_peak, vec![0.4]);
        assert!(m.cfl_dt > 0.0);
        assert_eq!(m.propagator, "naive");
        // 3 steps over 5 ms of wall
        assert!((m.measured_steps_per_sec - 600.0).abs() < 1e-6, "{}", m.measured_steps_per_sec);
    }

    #[test]
    fn late_growth_flags_monotone_increase() {
        let d = domain();
        let mut grow = MetricsCollector::new(d);
        let mut decay = MetricsCollector::new(d);
        let u = Field3::zeros(d.padded());
        for i in 0..32 {
            // fake energies by pushing directly through on_step is
            // impossible (energy comes from the field), so emulate with
            // scaled fields.
            let mut f = u.clone();
            f.set(R + 5, R + 5, R + 5, (i + 1) as f32);
            grow.on_step(i + 1, &f, f.energy());
            let mut g = u.clone();
            g.set(R + 5, R + 5, R + 5, (32 - i) as f32);
            decay.on_step(i + 1, &g, g.energy());
        }
        let mg = grow.finish(32, &summary(32), 2500.0, "naive".to_string());
        let md = decay.finish(32, &summary(32), 2500.0, "naive".to_string());
        assert!(mg.late_growth > 1.5, "{}", mg.late_growth);
        assert!(md.late_growth < 1.0, "{}", md.late_growth);
    }

    #[test]
    fn predict_perf_is_sane_for_paper_variants() {
        let p = predict_perf("v100", "gmem_8x8x8").unwrap();
        assert!(p.steps_per_sec > 0.0 && p.steps_per_sec.is_finite());
        assert!(p.blocks_per_sm >= 1);
        assert!(p.gflops > 0.0);
        assert!(predict_perf("h100", "gmem_8x8x8").is_err());
        assert!(predict_perf("v100", "nope").is_err());
    }
}
