//! Campaign runner: fan the scenario x variant x machine matrix out
//! over `std::thread` worker threads, aggregate per-cell verdicts into
//! a report table plus a JSON export (`json::Json`-consumable).
//!
//! Physics is shared: cells whose variants resolve to the same CPU
//! propagator signature (and machine cells, which only differ in
//! predicted perf) reuse one measured physics run per scenario. Only
//! the unique (scenario, signature) jobs fan out over the job workers;
//! per-cell prediction + verdict assembly is cheap and serial.
//!
//! Two fan-out layers, two mechanisms: the *job* workers below are
//! scoped threads spawned once per campaign (setup cost, not measured
//! cost). Each job's propagator then fans its *tiles* over the
//! persistent worker-pool executor (`runtime::pool`) sized by that
//! job's [`split_budget`] share — so the measured steps/sec each cell
//! reports is steady-state kernel cost, with no per-step spawn in it,
//! and the global `--threads` budget still bounds total parallelism.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::{
    evaluate_pass_fail, predict_perf, run_scenario_physics, Metrics, RunnerOptions, ScenarioId,
    Verdict,
};
use crate::json::Json;
use crate::stencil::propagator;

/// The matrix to run.
#[derive(Clone, Debug)]
pub struct CampaignSpec {
    pub scenarios: Vec<ScenarioId>,
    /// gpusim kernel variant ids (e.g. `gmem_8x8x8`).
    pub variants: Vec<String>,
    /// gpusim machine names (e.g. `v100`).
    pub machines: Vec<String>,
    /// Scale every scenario's step count (`--quick` smoke runs).
    pub steps_scale: Option<f64>,
    /// Global worker *budget*, shared between the physics-job fan-out
    /// and each propagator's tile fan-out (see [`split_budget`]);
    /// 0 = available parallelism.
    pub threads: usize,
    /// Cap observed-run batches at N steps (fused backends keep
    /// finer-grained traces; 0 keeps the natural cadence).
    pub sample_every: usize,
    /// Z-slab shard count for every physics run (`--shards`); 0 or 1 =
    /// unsharded. Sharded runs are bit-identical to unsharded ones, so
    /// verdicts and expectations are unchanged — only the execution
    /// shape (and each job's internal budget split) moves.
    pub shards: usize,
    /// Fitted Amdahl serial fraction (`bench --thread-sweep` prints the
    /// least-squares fit; `--serial-fraction` feeds it back here). When
    /// set, the gpusim-predicted steps/sec column is derated by the
    /// Amdahl efficiency `1 / (f*P + (1-f))` at the machine's modeled
    /// parallelism `P = blocks_per_sm * sm_count`, so predictions stop
    /// assuming perfectly parallel kernels. `None` keeps the raw model.
    pub serial_fraction: Option<f64>,
    /// Shared telemetry registry attached to every physics run. Jobs
    /// run in parallel but series are deduplicated by name + labels,
    /// so the whole matrix accumulates into one exposition.
    pub telemetry: Option<crate::telemetry::Registry>,
}

/// Split one global worker budget between the outer physics-job
/// fan-out and each job's propagator tile fan-out: `jobs` outer
/// workers (capped by the budget), each granted `budget / outer` tile
/// threads. The product never exceeds the budget, so big matrices on
/// big hosts cannot oversubscribe cores — and small matrices still use
/// the whole machine through the tile fan-out.
pub fn split_budget(budget: usize, jobs: usize) -> (usize, usize) {
    let budget = budget.max(1);
    let outer = budget.min(jobs.max(1));
    (outer, (budget / outer).max(1))
}

/// Three-way budget split for sharded campaigns: the global budget is
/// first shared between the physics jobs ([`split_budget`]), then each
/// job's slice is shared between its shard fan-out and every shard's
/// tile fan-out ([`crate::shard::split_shard_budget`]). Returns
/// `(job_workers, shard_workers, tile_threads)` with
/// `job_workers * shard_workers * tile_threads <= budget`, so a
/// sharded matrix can never oversubscribe the host either. Each job's
/// `RunnerOptions::cpu_threads` carries the middle*inner product and
/// the coordinator's engine re-derives the same split deterministically.
pub fn split_budget3(budget: usize, jobs: usize, shards: usize) -> (usize, usize, usize) {
    let (outer, per_job) = split_budget(budget, jobs);
    let (shard_workers, tile) = crate::shard::split_shard_budget(per_job, shards);
    (outer, shard_workers, tile)
}

/// One representative variant per code-shape family: the six families
/// the AOT artifact set ships as inner kernels, plus the temporally
/// fused `tf_s2` column (measured through the `TimeFused` CPU analog;
/// its physics run advances in fused batches, so its metrics sample at
/// batch boundaries). `tf_s4` stays opt-in via `--variant tf_s4`: its
/// deep ring cannot launch on the pre-Ampere machines, which would
/// make "cannot launch" the expected-but-noisy default verdict.
pub fn default_variants() -> Vec<String> {
    [
        "gmem_8x8x8",
        "smem_u",
        "semi",
        "st_smem_16x16",
        "st_reg_shft_16x16",
        "st_reg_fixed_32x32",
        "tf_s2",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect()
}

/// Map a family shorthand (the `run --variant` names) to its
/// representative gpusim id; full gpusim ids pass through validated.
pub fn resolve_variant(name: &str) -> anyhow::Result<String> {
    Ok(crate::gpusim::kernels::resolve(name)?.id.to_string())
}

impl CampaignSpec {
    /// The full catalogue x family representatives on the given machines.
    pub fn full(machines: Vec<String>) -> CampaignSpec {
        CampaignSpec {
            scenarios: ScenarioId::all(),
            variants: default_variants(),
            machines,
            steps_scale: None,
            threads: 0,
            sample_every: 0,
            shards: 1,
            serial_fraction: None,
            telemetry: None,
        }
    }

    /// Quick smoke matrix: every scenario, one variant, quartered steps,
    /// on all the requested machines.
    pub fn quick(machines: Vec<String>) -> CampaignSpec {
        CampaignSpec {
            scenarios: ScenarioId::all(),
            variants: vec!["gmem_8x8x8".to_string()],
            machines,
            steps_scale: Some(0.25),
            threads: 0,
            sample_every: 0,
            shards: 1,
            serial_fraction: None,
            telemetry: None,
        }
    }

    fn cells(&self) -> Vec<(ScenarioId, String, String)> {
        let mut out = Vec::new();
        for &sc in &self.scenarios {
            for v in &self.variants {
                for m in &self.machines {
                    out.push((sc, v.clone(), m.clone()));
                }
            }
        }
        out
    }
}

/// One evaluated cell of the matrix.
#[derive(Clone, Debug)]
pub struct CampaignCell {
    pub scenario: ScenarioId,
    pub variant: String,
    pub machine: String,
    pub verdict: Verdict,
    pub expected: Verdict,
    /// Names of failed criteria, in evaluation order.
    pub failed_criteria: Vec<String>,
    pub steps_completed: usize,
    pub peak_abs: f32,
    pub final_energy: f64,
    pub boundary_leakage: f64,
    /// gpusim-modeled full-step rate for variant x machine.
    pub predicted_steps_per_sec: f64,
    /// Measured full-step rate of the CPU propagator that ran this
    /// cell's physics (shared across cells with the same signature).
    pub measured_steps_per_sec: f64,
    /// Signature of that propagator (e.g. `blocked3d:8x8x8`).
    pub propagator: String,
    pub wall_ms: f64,
    /// Kernel-only wall time: the physics run's step batches, summed
    /// from its telemetry batch-latency histogram (a slice of
    /// `wall_ms`; shared across cells with the same physics run).
    pub batch_wall_ms: f64,
    /// Runner error (cell recorded as HardFail), if any.
    pub error: Option<String>,
}

impl CampaignCell {
    /// The cell deviated from the catalogue: wrong verdict in either
    /// direction (a non-stress scenario failing, a stress scenario
    /// unexpectedly passing) or a runner error. This — not raw
    /// HardFail counts — is what fails a campaign, so a regression
    /// that stops a stress scenario from hard-failing is caught too.
    pub fn off_expectation(&self) -> bool {
        self.error.is_some() || self.verdict != self.expected
    }
}

/// The aggregated campaign outcome.
#[derive(Clone, Debug)]
pub struct CampaignReport {
    pub cells: Vec<CampaignCell>,
    pub wall: Duration,
    /// Outer physics-job workers (the budget's first factor).
    pub threads: usize,
    /// Tile-fan-out threads granted to each physics job (the budget's
    /// second factor; `threads * tile_threads <= budget`).
    pub tile_threads: usize,
    /// Unique physics runs executed (<= cells: the sharing win).
    pub physics_runs: usize,
}

impl CampaignReport {
    pub fn count(&self, v: Verdict) -> usize {
        self.cells.iter().filter(|c| c.verdict == v).count()
    }

    pub fn off_expectation_count(&self) -> usize {
        self.cells.iter().filter(|c| c.off_expectation()).count()
    }

    /// Render as a `json::Json` document (finite numbers only — blown-up
    /// metrics export as null so the emitted text always re-parses).
    pub fn to_json(&self) -> Json {
        fn num(v: f64) -> Json {
            if v.is_finite() {
                Json::Num(v)
            } else {
                Json::Null
            }
        }
        let cells: Vec<Json> = self
            .cells
            .iter()
            .map(|c| {
                let mut o = BTreeMap::new();
                o.insert("scenario".into(), Json::Str(c.scenario.name().into()));
                o.insert("variant".into(), Json::Str(c.variant.clone()));
                o.insert("machine".into(), Json::Str(c.machine.clone()));
                o.insert("verdict".into(), Json::Str(c.verdict.name().into()));
                o.insert("expected".into(), Json::Str(c.expected.name().into()));
                o.insert(
                    "failed_criteria".into(),
                    Json::Arr(c.failed_criteria.iter().map(|f| Json::Str(f.clone())).collect()),
                );
                o.insert("steps_completed".into(), Json::Num(c.steps_completed as f64));
                o.insert("peak_abs".into(), num(c.peak_abs as f64));
                o.insert("final_energy".into(), num(c.final_energy));
                o.insert("boundary_leakage".into(), num(c.boundary_leakage));
                o.insert("predicted_steps_per_sec".into(), num(c.predicted_steps_per_sec));
                o.insert("measured_steps_per_sec".into(), num(c.measured_steps_per_sec));
                o.insert("propagator".into(), Json::Str(c.propagator.clone()));
                o.insert("wall_ms".into(), num(c.wall_ms));
                o.insert("batch_wall_ms".into(), num(c.batch_wall_ms));
                if let Some(e) = &c.error {
                    o.insert("error".into(), Json::Str(e.clone()));
                }
                Json::Obj(o)
            })
            .collect();
        let mut summary = BTreeMap::new();
        summary.insert("total".into(), Json::Num(self.cells.len() as f64));
        summary.insert("pass".into(), Json::Num(self.count(Verdict::Pass) as f64));
        summary.insert("soft_fail".into(), Json::Num(self.count(Verdict::SoftFail) as f64));
        summary.insert("hard_fail".into(), Json::Num(self.count(Verdict::HardFail) as f64));
        summary.insert(
            "off_expectation".into(),
            Json::Num(self.off_expectation_count() as f64),
        );
        summary.insert("wall_ms".into(), num(self.wall.as_secs_f64() * 1e3));
        summary.insert("threads".into(), Json::Num(self.threads as f64));
        summary.insert("tile_threads".into(), Json::Num(self.tile_threads as f64));
        summary.insert("physics_runs".into(), Json::Num(self.physics_runs as f64));
        let mut root = BTreeMap::new();
        root.insert("format_version".into(), Json::Num(1.0));
        root.insert("kind".into(), Json::Str("hostencil-campaign".into()));
        root.insert("summary".into(), Json::Obj(summary));
        root.insert("cells".into(), Json::Arr(cells));
        Json::Obj(root)
    }
}

/// Derate a raw gpusim steps/sec prediction by the Amdahl efficiency
/// at the machine's modeled parallelism: `P` concurrent blocks
/// (`blocks_per_sm * sm_count`) and a fitted serial fraction `f` give
/// `speedup(P)/P = 1 / (f*P + (1-f))`. The raw model assumes the
/// kernel scales perfectly across blocks; the fitted fraction (from
/// `bench --thread-sweep`'s least-squares Amdahl fit) folds the
/// measured serial residue back into the predicted column.
fn amdahl_derate(steps_per_sec: f64, serial_fraction: f64, parallelism: f64) -> f64 {
    let f = serial_fraction.clamp(0.0, 1.0);
    let p = parallelism.max(1.0);
    steps_per_sec / (f * p + (1.0 - f))
}

/// Assemble one cell from its (possibly shared) physics outcome plus a
/// per-cell gpusim prediction and verdict. Any error — physics or
/// prediction — records the cell as an errored HardFail.
fn assemble_cell(
    sc: ScenarioId,
    variant: &str,
    machine: &str,
    serial_fraction: Option<f64>,
    physics: &anyhow::Result<Metrics>,
) -> CampaignCell {
    let error_cell = |e: String| CampaignCell {
        scenario: sc,
        variant: variant.to_string(),
        machine: machine.to_string(),
        verdict: Verdict::HardFail,
        expected: sc.expected_verdict(),
        failed_criteria: vec!["runner_error".to_string()],
        steps_completed: 0,
        peak_abs: 0.0,
        final_energy: 0.0,
        boundary_leakage: 0.0,
        predicted_steps_per_sec: 0.0,
        measured_steps_per_sec: 0.0,
        propagator: String::new(),
        wall_ms: 0.0,
        batch_wall_ms: 0.0,
        error: Some(e),
    };
    let base = match physics {
        Ok(m) => m,
        Err(e) => return error_cell(e.to_string()),
    };
    let mut predicted = match predict_perf(machine, variant) {
        Ok(p) => p,
        Err(e) => return error_cell(e.to_string()),
    };
    if let Some(f) = serial_fraction {
        if f > 0.0 {
            let arch = match crate::gpusim::arch::by_name(machine) {
                Ok(a) => a,
                Err(e) => return error_cell(e.to_string()),
            };
            let p = (predicted.blocks_per_sm as f64) * (arch.sm_count as f64);
            predicted.steps_per_sec = amdahl_derate(predicted.steps_per_sec, f, p);
        }
    }
    let mut metrics = base.clone();
    metrics.predicted = Some(predicted);
    let result = evaluate_pass_fail(&metrics, &sc.materialize().expectations);
    CampaignCell {
        scenario: sc,
        variant: variant.to_string(),
        machine: machine.to_string(),
        verdict: result.overall,
        expected: sc.expected_verdict(),
        failed_criteria: result.failed().iter().map(|c| c.name.to_string()).collect(),
        steps_completed: metrics.steps_completed,
        peak_abs: metrics.peak_abs,
        final_energy: metrics.final_energy,
        boundary_leakage: metrics.boundary_leakage,
        predicted_steps_per_sec: metrics
            .predicted
            .as_ref()
            .map(|p| p.steps_per_sec)
            .unwrap_or(0.0),
        measured_steps_per_sec: metrics.measured_steps_per_sec,
        propagator: metrics.propagator.clone(),
        wall_ms: metrics.wall_ms,
        batch_wall_ms: metrics.batch_wall_ms,
        error: None,
    }
}

fn physics_opts(spec: &CampaignSpec, variant: &str, tile_threads: usize) -> RunnerOptions {
    RunnerOptions {
        steps_scale: spec.steps_scale,
        variant: Some(variant.to_string()),
        // this job's share of the global worker budget; with shards the
        // coordinator's engine re-splits it via split_shard_budget
        cpu_threads: tile_threads,
        sample_every: spec.sample_every,
        shards: spec.shards,
        telemetry: spec.telemetry.clone(),
        ..RunnerOptions::default()
    }
}

/// Run one cell standalone (fresh physics, whole budget to the tile
/// fan-out). The campaign itself goes through the shared-physics path;
/// this is the single-cell building block (and what tests poke
/// directly).
fn run_cell(spec: &CampaignSpec, sc: ScenarioId, variant: &str, machine: &str) -> CampaignCell {
    let physics = run_scenario_physics(sc, &physics_opts(spec, variant, spec.threads));
    assemble_cell(sc, variant, machine, spec.serial_fraction, &physics)
}

/// Run the whole matrix. The physics is deduplicated to one run per
/// (scenario, propagator signature); worker threads pull those jobs
/// off a shared atomic cursor, then every cell is assembled from its
/// job's metrics plus a per-cell prediction. Results come back in
/// deterministic matrix order regardless of scheduling.
pub fn run_campaign(spec: &CampaignSpec) -> CampaignReport {
    let cells = spec.cells();
    // group cells into unique physics jobs
    let mut jobs: Vec<(ScenarioId, String)> = Vec::new();
    let mut job_index: HashMap<(ScenarioId, String), usize> = HashMap::new();
    let mut job_of_cell = Vec::with_capacity(cells.len());
    for (sc, variant, _machine) in &cells {
        // unresolvable variants get their own job so the resolve error
        // surfaces per cell instead of poisoning a shared run
        let sig = propagator::signature(variant)
            .unwrap_or_else(|_| format!("unresolvable:{variant}"));
        let next = jobs.len();
        let idx = *job_index.entry((*sc, sig)).or_insert_with(|| {
            jobs.push((*sc, variant.clone()));
            next
        });
        job_of_cell.push(idx);
    }

    // one global worker budget, shared between the job fan-out and
    // each job's propagator tile fan-out (ROADMAP: no oversubscription
    // on big hosts, full machine on small matrices)
    let budget = if spec.threads > 0 {
        spec.threads
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    };
    // sharded specs split each job's slice a second time (shard
    // fan-out x per-shard tiles); the job still carries the product so
    // the engine's own split_shard_budget re-derives the same factors
    let (n_threads, shard_workers, shard_tile) = split_budget3(budget, jobs.len(), spec.shards);
    let tile_threads = shard_workers * shard_tile;

    let t0 = Instant::now();
    let cursor = AtomicUsize::new(0);
    let physics: Mutex<Vec<Option<anyhow::Result<Metrics>>>> =
        Mutex::new((0..jobs.len()).map(|_| None).collect());

    std::thread::scope(|s| {
        for _ in 0..n_threads {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= jobs.len() {
                    break;
                }
                let (sc, variant) = &jobs[i];
                let m = run_scenario_physics(*sc, &physics_opts(spec, variant, tile_threads));
                physics.lock().unwrap()[i] = Some(m);
            });
        }
    });

    let physics: Vec<anyhow::Result<Metrics>> = physics
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|m| m.expect("every physics job ran"))
        .collect();
    let out = cells
        .iter()
        .zip(&job_of_cell)
        .map(|((sc, variant, machine), &j)| {
            assemble_cell(*sc, variant, machine, spec.serial_fraction, &physics[j])
        })
        .collect();
    CampaignReport {
        cells: out,
        wall: t0.elapsed(),
        threads: n_threads,
        tile_threads,
        physics_runs: jobs.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> CampaignSpec {
        CampaignSpec {
            scenarios: vec![ScenarioId::TinyGrid],
            variants: vec!["gmem_8x8x8".to_string()],
            machines: vec!["v100".to_string()],
            steps_scale: Some(0.5),
            threads: 2,
            sample_every: 0,
            shards: 1,
            serial_fraction: None,
            telemetry: None,
        }
    }

    #[test]
    fn split_budget_shares_cores_between_layers() {
        assert_eq!(split_budget(16, 4), (4, 4));
        assert_eq!(split_budget(16, 32), (16, 1));
        assert_eq!(split_budget(3, 8), (3, 1));
        assert_eq!(split_budget(8, 3), (3, 2));
        assert_eq!(split_budget(0, 5), (1, 1)); // degenerate budget
        assert_eq!(split_budget(5, 0), (1, 5)); // no jobs yet: all tiles
        for budget in 1..24 {
            for jobs in 1..24 {
                let (outer, inner) = split_budget(budget, jobs);
                assert!(outer >= 1 && inner >= 1);
                assert!(outer * inner <= budget, "({budget},{jobs}) oversubscribes");
                assert!(outer <= jobs.max(1));
            }
        }
    }

    #[test]
    fn split_budget3_never_oversubscribes_either_layer() {
        assert_eq!(split_budget3(16, 2, 2), (2, 2, 4));
        assert_eq!(split_budget3(4, 1, 2), (1, 2, 2));
        assert_eq!(split_budget3(8, 3, 1), (3, 1, 2)); // unsharded == split_budget
        assert_eq!(split_budget3(1, 5, 5), (1, 1, 1)); // serial host stays serial
        for budget in 1..20 {
            for jobs in 1..8 {
                for shards in 1..6 {
                    let (a, b, c) = split_budget3(budget, jobs, shards);
                    assert!(a >= 1 && b >= 1 && c >= 1);
                    assert!(
                        a * b * c <= budget,
                        "({budget},{jobs},{shards}) -> ({a},{b},{c}) oversubscribes"
                    );
                    assert!(b <= shards.max(1));
                    // the job's slice carries the product, so the
                    // engine's own re-split reproduces the same factors
                    let (eb, ec) = crate::shard::split_shard_budget(b * c, shards);
                    assert_eq!((eb, ec), (b, c), "engine re-split must be deterministic");
                }
            }
        }
    }

    #[test]
    fn amdahl_derate_matches_the_closed_form() {
        // f = 0 or P = 1: nothing to derate
        assert_eq!(amdahl_derate(1000.0, 0.0, 80.0), 1000.0);
        assert_eq!(amdahl_derate(1000.0, 0.5, 1.0), 1000.0);
        // f = 1: fully serial, the parallel model overcounts by P
        assert!((amdahl_derate(800.0, 1.0, 80.0) - 10.0).abs() < 1e-9);
        // derating is monotone in f
        let raw = 1234.5;
        let mut last = raw;
        for f in [0.01, 0.05, 0.2, 0.8] {
            let d = amdahl_derate(raw, f, 160.0);
            assert!(d < last, "serial fraction {f} must shrink the prediction");
            last = d;
        }
    }

    #[test]
    fn serial_fraction_derates_the_predicted_column_only() {
        let raw = run_cell(&tiny_spec(), ScenarioId::TinyGrid, "gmem_8x8x8", "v100");
        let mut spec = tiny_spec();
        spec.serial_fraction = Some(0.05);
        let fit = run_cell(&spec, ScenarioId::TinyGrid, "gmem_8x8x8", "v100");
        assert!(fit.predicted_steps_per_sec > 0.0);
        assert!(
            fit.predicted_steps_per_sec < raw.predicted_steps_per_sec,
            "fitted serial fraction must derate the model ({} !< {})",
            fit.predicted_steps_per_sec,
            raw.predicted_steps_per_sec
        );
        assert_eq!(fit.verdict, raw.verdict, "the verdict judges physics, not the model");
        // a zero fraction is the identity
        spec.serial_fraction = Some(0.0);
        let zero = run_cell(&spec, ScenarioId::TinyGrid, "gmem_8x8x8", "v100");
        assert_eq!(zero.predicted_steps_per_sec, raw.predicted_steps_per_sec);
    }

    #[test]
    fn sharded_campaign_matches_the_unsharded_physics() {
        // TinyGrid is 9 z-planes: two fuse-1 shards own 5 and 4, both
        // >= the halo depth 4, so the decomposition is feasible — and
        // must be invisible in every physics column
        let base = run_campaign(&tiny_spec());
        let mut spec = tiny_spec();
        spec.shards = 2;
        spec.threads = 4;
        let sharded = run_campaign(&spec);
        assert_eq!(sharded.off_expectation_count(), 0, "{:?}", sharded.cells);
        assert_eq!(sharded.tile_threads, 4, "1 job: shard x tile product gets the budget");
        let (a, b) = (&base.cells[0], &sharded.cells[0]);
        assert_eq!(a.peak_abs, b.peak_abs, "sharding leaked into physics");
        assert_eq!(a.final_energy, b.final_energy);
        assert_eq!(a.boundary_leakage, b.boundary_leakage);
        assert_eq!(a.verdict, b.verdict);
    }

    #[test]
    fn tile_thread_budget_does_not_change_physics() {
        // granting each job more tile threads must only change timing,
        // never the physics the verdict is judged on
        let mut spec = tiny_spec();
        spec.threads = 1;
        let serial = run_campaign(&spec);
        spec.threads = 8;
        let budgeted = run_campaign(&spec);
        assert_eq!(budgeted.tile_threads, 8, "1 job must get the whole budget");
        let (a, b) = (&serial.cells[0], &budgeted.cells[0]);
        assert_eq!(a.peak_abs, b.peak_abs, "tile scheduling leaked into physics");
        assert_eq!(a.final_energy, b.final_energy);
        assert_eq!(a.verdict, b.verdict);
    }

    #[test]
    fn cells_cover_the_cartesian_product() {
        let spec = CampaignSpec {
            scenarios: vec![ScenarioId::TinyGrid, ScenarioId::CflMarginStress],
            variants: vec!["a".into(), "b".into(), "c".into()],
            machines: vec!["m1".into(), "m2".into()],
            steps_scale: None,
            threads: 0,
            sample_every: 0,
            shards: 1,
            serial_fraction: None,
            telemetry: None,
        };
        assert_eq!(spec.cells().len(), 2 * 3 * 2);
    }

    #[test]
    fn resolve_variant_accepts_family_shorthand_and_full_ids() {
        assert_eq!(resolve_variant("gmem").unwrap(), "gmem_8x8x8");
        assert_eq!(resolve_variant("st_reg_fixed").unwrap(), "st_reg_fixed_32x32");
        assert_eq!(resolve_variant("gmem_4x4x4").unwrap(), "gmem_4x4x4");
        assert!(resolve_variant("warp_specialized").is_err());
    }

    #[test]
    fn default_variants_are_valid_gpusim_ids() {
        for v in default_variants() {
            assert!(crate::gpusim::kernels::by_id(&v).is_ok(), "{v}");
        }
        assert!(
            default_variants().iter().any(|v| v == "tf_s2"),
            "the fused family must be a campaign column"
        );
    }

    #[test]
    fn fused_campaign_cells_run_and_match_expectations() {
        // the fused column's physics advances in batches; verdicts and
        // both perf columns must still come out healthy
        let spec = CampaignSpec {
            scenarios: vec![ScenarioId::TinyGrid, ScenarioId::CflMarginStress],
            variants: vec!["tf_s2".to_string()],
            machines: vec!["v100".to_string()],
            steps_scale: Some(0.5),
            threads: 2,
            sample_every: 0,
            shards: 1,
            serial_fraction: None,
            telemetry: None,
        };
        let report = run_campaign(&spec);
        assert_eq!(report.cells.len(), 2);
        assert_eq!(report.off_expectation_count(), 0, "{:?}", report.cells);
        let tiny = &report.cells[0];
        assert_eq!(tiny.propagator, "time_fused:s2:16x16");
        assert!(tiny.measured_steps_per_sec > 0.0);
        assert!(tiny.predicted_steps_per_sec > 0.0, "tf_s2 launches on V100");
    }

    #[test]
    fn tiny_campaign_runs_and_reports() {
        let report = run_campaign(&tiny_spec());
        assert_eq!(report.cells.len(), 1);
        assert_eq!(report.physics_runs, 1);
        let c = &report.cells[0];
        assert_eq!(c.scenario, ScenarioId::TinyGrid);
        assert!(c.predicted_steps_per_sec > 0.0);
        assert!(c.measured_steps_per_sec > 0.0, "{:?}", c);
        assert_eq!(c.propagator, "blocked3d:8x8x8");
        assert!(c.batch_wall_ms > 0.0, "cell must carry its telemetry wall time");
        assert!(c.batch_wall_ms <= c.wall_ms);
        assert_eq!(report.off_expectation_count(), 0, "{:?}", c);
        let j = report.to_json();
        let cell = &j.get("cells").unwrap().as_arr().unwrap()[0];
        assert!(cell.get("batch_wall_ms").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn physics_is_shared_across_equivalent_variants_and_machines() {
        // gmem_8x8x8 and smem_u collapse onto the same CPU code shape
        // (blocked3d:8x8x8); two machines only differ in prediction.
        // 1 scenario x 2 variants x 2 machines = 4 cells, 1 physics run.
        let spec = CampaignSpec {
            scenarios: vec![ScenarioId::TinyGrid],
            variants: vec!["gmem_8x8x8".to_string(), "smem_u".to_string()],
            machines: vec!["v100".to_string(), "p100".to_string()],
            steps_scale: Some(0.5),
            threads: 2,
            sample_every: 0,
            shards: 1,
            serial_fraction: None,
            telemetry: None,
        };
        let report = run_campaign(&spec);
        assert_eq!(report.cells.len(), 4);
        assert_eq!(report.physics_runs, 1, "equivalent cells must share one physics run");
        for c in &report.cells {
            assert_eq!(c.propagator, "blocked3d:8x8x8");
            assert_eq!(c.measured_steps_per_sec, report.cells[0].measured_steps_per_sec);
            assert_eq!(c.peak_abs, report.cells[0].peak_abs, "shared physics must be identical");
        }
        // a different tile shape forces its own physics run
        let spec2 = CampaignSpec {
            variants: vec!["gmem_8x8x8".to_string(), "gmem_16x16x4".to_string()],
            ..spec
        };
        assert_eq!(run_campaign(&spec2).physics_runs, 2);
    }

    #[test]
    fn report_json_has_summary_and_cells() {
        let report = run_campaign(&tiny_spec());
        let j = report.to_json();
        assert_eq!(j.get("format_version").unwrap().as_usize().unwrap(), 1);
        let cells = j.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].get("scenario").unwrap().as_str().unwrap(), "tiny-grid");
        let s = j.get("summary").unwrap();
        assert_eq!(s.get("total").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn runner_error_cells_are_hard_fails() {
        // an invalid machine name forces the error path
        let cell = run_cell(&tiny_spec(), ScenarioId::TinyGrid, "gmem_8x8x8", "h100");
        assert_eq!(cell.verdict, Verdict::HardFail);
        assert!(cell.off_expectation());
        assert!(cell.error.is_some());
    }

    #[test]
    fn runner_error_on_a_stress_cell_is_still_off_expectation() {
        // a stress scenario is expected to HardFail for *physics*
        // reasons; an infrastructure error must not hide behind that
        let cell = run_cell(&tiny_spec(), ScenarioId::CflMarginStress, "gmem_8x8x8", "h100");
        assert_eq!(cell.verdict, cell.expected);
        assert!(cell.off_expectation(), "errors must never count as expected");
    }

    #[test]
    fn stress_cell_that_passes_is_off_expectation() {
        let cell = CampaignCell {
            scenario: ScenarioId::CflMarginStress,
            variant: "gmem_8x8x8".into(),
            machine: "v100".into(),
            verdict: Verdict::Pass,
            expected: Verdict::HardFail,
            failed_criteria: vec![],
            steps_completed: 10,
            peak_abs: 1.0,
            final_energy: 1.0,
            boundary_leakage: 0.1,
            predicted_steps_per_sec: 1.0,
            measured_steps_per_sec: 1.0,
            propagator: "naive".to_string(),
            wall_ms: 1.0,
            batch_wall_ms: 0.5,
            error: None,
        };
        assert!(cell.off_expectation(), "an unexpectedly-green stress cell must fail the gate");
    }

    #[test]
    fn quick_spec_keeps_every_requested_machine() {
        let spec = CampaignSpec::quick(vec!["v100".into(), "p100".into(), "nvs510".into()]);
        assert_eq!(spec.machines.len(), 3);
        assert_eq!(spec.variants.len(), 1);
        assert_eq!(spec.steps_scale, Some(0.25));
    }
}
