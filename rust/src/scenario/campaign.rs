//! Campaign runner: fan the scenario x variant x machine matrix out
//! over `std::thread` worker threads, aggregate per-cell verdicts into
//! a report table plus a JSON export (`json::Json`-consumable).
//!
//! Cells are independent (each runs its own golden-backend physics and
//! its own gpusim prediction), so the matrix is embarrassingly
//! parallel; a shared atomic cursor feeds a fixed worker pool.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::{run_scenario, RunnerOptions, ScenarioId, Verdict};
use crate::json::Json;

/// The matrix to run.
#[derive(Clone, Debug)]
pub struct CampaignSpec {
    pub scenarios: Vec<ScenarioId>,
    /// gpusim kernel variant ids (e.g. `gmem_8x8x8`).
    pub variants: Vec<String>,
    /// gpusim machine names (e.g. `v100`).
    pub machines: Vec<String>,
    /// Scale every scenario's step count (`--quick` smoke runs).
    pub steps_scale: Option<f64>,
    /// Worker threads; 0 = one per available core, capped by cell count.
    pub threads: usize,
}

/// One representative variant per code-shape family (the six families
/// the AOT artifact set ships as inner kernels).
pub fn default_variants() -> Vec<String> {
    ["gmem_8x8x8", "smem_u", "semi", "st_smem_16x16", "st_reg_shft_16x16", "st_reg_fixed_32x32"]
        .iter()
        .map(|s| s.to_string())
        .collect()
}

/// Map a family shorthand (the `run --variant` names) to its
/// representative gpusim id; full gpusim ids pass through validated.
pub fn resolve_variant(name: &str) -> anyhow::Result<String> {
    let shorthand = match name {
        "gmem" => Some("gmem_8x8x8"),
        "smem_u" => Some("smem_u"),
        "semi" => Some("semi"),
        "st_smem" => Some("st_smem_16x16"),
        "st_reg_shft" => Some("st_reg_shft_16x16"),
        "st_reg_fixed" => Some("st_reg_fixed_32x32"),
        _ => None,
    };
    let id = shorthand.unwrap_or(name);
    crate::gpusim::kernels::by_id(id)?;
    Ok(id.to_string())
}

impl CampaignSpec {
    /// The full catalogue x family representatives on the given machines.
    pub fn full(machines: Vec<String>) -> CampaignSpec {
        CampaignSpec {
            scenarios: ScenarioId::all(),
            variants: default_variants(),
            machines,
            steps_scale: None,
            threads: 0,
        }
    }

    /// Quick smoke matrix: every scenario, one variant, quartered steps,
    /// on all the requested machines.
    pub fn quick(machines: Vec<String>) -> CampaignSpec {
        CampaignSpec {
            scenarios: ScenarioId::all(),
            variants: vec!["gmem_8x8x8".to_string()],
            machines,
            steps_scale: Some(0.25),
            threads: 0,
        }
    }

    fn cells(&self) -> Vec<(ScenarioId, String, String)> {
        let mut out = Vec::new();
        for &sc in &self.scenarios {
            for v in &self.variants {
                for m in &self.machines {
                    out.push((sc, v.clone(), m.clone()));
                }
            }
        }
        out
    }
}

/// One evaluated cell of the matrix.
#[derive(Clone, Debug)]
pub struct CampaignCell {
    pub scenario: ScenarioId,
    pub variant: String,
    pub machine: String,
    pub verdict: Verdict,
    pub expected: Verdict,
    /// Names of failed criteria, in evaluation order.
    pub failed_criteria: Vec<String>,
    pub steps_completed: usize,
    pub peak_abs: f32,
    pub final_energy: f64,
    pub boundary_leakage: f64,
    pub predicted_steps_per_sec: f64,
    pub wall_ms: f64,
    /// Runner error (cell recorded as HardFail), if any.
    pub error: Option<String>,
}

impl CampaignCell {
    /// The cell deviated from the catalogue: wrong verdict in either
    /// direction (a non-stress scenario failing, a stress scenario
    /// unexpectedly passing) or a runner error. This — not raw
    /// HardFail counts — is what fails a campaign, so a regression
    /// that stops a stress scenario from hard-failing is caught too.
    pub fn off_expectation(&self) -> bool {
        self.error.is_some() || self.verdict != self.expected
    }
}

/// The aggregated campaign outcome.
#[derive(Clone, Debug)]
pub struct CampaignReport {
    pub cells: Vec<CampaignCell>,
    pub wall: Duration,
    pub threads: usize,
}

impl CampaignReport {
    pub fn count(&self, v: Verdict) -> usize {
        self.cells.iter().filter(|c| c.verdict == v).count()
    }

    pub fn off_expectation_count(&self) -> usize {
        self.cells.iter().filter(|c| c.off_expectation()).count()
    }

    /// Render as a `json::Json` document (finite numbers only — blown-up
    /// metrics export as null so the emitted text always re-parses).
    pub fn to_json(&self) -> Json {
        fn num(v: f64) -> Json {
            if v.is_finite() {
                Json::Num(v)
            } else {
                Json::Null
            }
        }
        let cells: Vec<Json> = self
            .cells
            .iter()
            .map(|c| {
                let mut o = BTreeMap::new();
                o.insert("scenario".into(), Json::Str(c.scenario.name().into()));
                o.insert("variant".into(), Json::Str(c.variant.clone()));
                o.insert("machine".into(), Json::Str(c.machine.clone()));
                o.insert("verdict".into(), Json::Str(c.verdict.name().into()));
                o.insert("expected".into(), Json::Str(c.expected.name().into()));
                o.insert(
                    "failed_criteria".into(),
                    Json::Arr(c.failed_criteria.iter().map(|f| Json::Str(f.clone())).collect()),
                );
                o.insert("steps_completed".into(), Json::Num(c.steps_completed as f64));
                o.insert("peak_abs".into(), num(c.peak_abs as f64));
                o.insert("final_energy".into(), num(c.final_energy));
                o.insert("boundary_leakage".into(), num(c.boundary_leakage));
                o.insert("predicted_steps_per_sec".into(), num(c.predicted_steps_per_sec));
                o.insert("wall_ms".into(), num(c.wall_ms));
                if let Some(e) = &c.error {
                    o.insert("error".into(), Json::Str(e.clone()));
                }
                Json::Obj(o)
            })
            .collect();
        let mut summary = BTreeMap::new();
        summary.insert("total".into(), Json::Num(self.cells.len() as f64));
        summary.insert("pass".into(), Json::Num(self.count(Verdict::Pass) as f64));
        summary.insert("soft_fail".into(), Json::Num(self.count(Verdict::SoftFail) as f64));
        summary.insert("hard_fail".into(), Json::Num(self.count(Verdict::HardFail) as f64));
        summary.insert(
            "off_expectation".into(),
            Json::Num(self.off_expectation_count() as f64),
        );
        summary.insert("wall_ms".into(), num(self.wall.as_secs_f64() * 1e3));
        summary.insert("threads".into(), Json::Num(self.threads as f64));
        let mut root = BTreeMap::new();
        root.insert("format_version".into(), Json::Num(1.0));
        root.insert("kind".into(), Json::Str("hostencil-campaign".into()));
        root.insert("summary".into(), Json::Obj(summary));
        root.insert("cells".into(), Json::Arr(cells));
        Json::Obj(root)
    }
}

fn run_cell(spec: &CampaignSpec, sc: ScenarioId, variant: &str, machine: &str) -> CampaignCell {
    let opts = RunnerOptions {
        steps_override: None,
        steps_scale: spec.steps_scale,
        machine: Some(machine.to_string()),
        variant: Some(variant.to_string()),
    };
    match run_scenario(sc, &opts) {
        Ok(run) => CampaignCell {
            scenario: sc,
            variant: variant.to_string(),
            machine: machine.to_string(),
            verdict: run.result.overall,
            expected: sc.expected_verdict(),
            failed_criteria: run.result.failed().iter().map(|c| c.name.to_string()).collect(),
            steps_completed: run.metrics.steps_completed,
            peak_abs: run.metrics.peak_abs,
            final_energy: run.metrics.final_energy,
            boundary_leakage: run.metrics.boundary_leakage,
            predicted_steps_per_sec: run
                .metrics
                .predicted
                .as_ref()
                .map(|p| p.steps_per_sec)
                .unwrap_or(0.0),
            wall_ms: run.metrics.wall_ms,
            error: None,
        },
        Err(e) => CampaignCell {
            scenario: sc,
            variant: variant.to_string(),
            machine: machine.to_string(),
            verdict: Verdict::HardFail,
            expected: sc.expected_verdict(),
            failed_criteria: vec!["runner_error".to_string()],
            steps_completed: 0,
            peak_abs: 0.0,
            final_energy: 0.0,
            boundary_leakage: 0.0,
            predicted_steps_per_sec: 0.0,
            wall_ms: 0.0,
            error: Some(e.to_string()),
        },
    }
}

/// Run the whole matrix. Worker threads pull cells off a shared atomic
/// cursor; results come back in deterministic matrix order regardless
/// of scheduling.
pub fn run_campaign(spec: &CampaignSpec) -> CampaignReport {
    let cells = spec.cells();
    let n_threads = if spec.threads > 0 {
        spec.threads
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    }
    .min(cells.len())
    .max(1);

    let t0 = Instant::now();
    let cursor = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<CampaignCell>>> = Mutex::new((0..cells.len()).map(|_| None).collect());

    std::thread::scope(|s| {
        for _ in 0..n_threads {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= cells.len() {
                    break;
                }
                let (sc, variant, machine) = &cells[i];
                let cell = run_cell(spec, *sc, variant, machine);
                results.lock().unwrap()[i] = Some(cell);
            });
        }
    });

    let cells = results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|c| c.expect("every cell ran"))
        .collect();
    CampaignReport { cells, wall: t0.elapsed(), threads: n_threads }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> CampaignSpec {
        CampaignSpec {
            scenarios: vec![ScenarioId::TinyGrid],
            variants: vec!["gmem_8x8x8".to_string()],
            machines: vec!["v100".to_string()],
            steps_scale: Some(0.5),
            threads: 2,
        }
    }

    #[test]
    fn cells_cover_the_cartesian_product() {
        let spec = CampaignSpec {
            scenarios: vec![ScenarioId::TinyGrid, ScenarioId::CflMarginStress],
            variants: vec!["a".into(), "b".into(), "c".into()],
            machines: vec!["m1".into(), "m2".into()],
            steps_scale: None,
            threads: 0,
        };
        assert_eq!(spec.cells().len(), 2 * 3 * 2);
    }

    #[test]
    fn resolve_variant_accepts_family_shorthand_and_full_ids() {
        assert_eq!(resolve_variant("gmem").unwrap(), "gmem_8x8x8");
        assert_eq!(resolve_variant("st_reg_fixed").unwrap(), "st_reg_fixed_32x32");
        assert_eq!(resolve_variant("gmem_4x4x4").unwrap(), "gmem_4x4x4");
        assert!(resolve_variant("warp_specialized").is_err());
    }

    #[test]
    fn default_variants_are_valid_gpusim_ids() {
        for v in default_variants() {
            assert!(crate::gpusim::kernels::by_id(&v).is_ok(), "{v}");
        }
    }

    #[test]
    fn tiny_campaign_runs_and_reports() {
        let report = run_campaign(&tiny_spec());
        assert_eq!(report.cells.len(), 1);
        let c = &report.cells[0];
        assert_eq!(c.scenario, ScenarioId::TinyGrid);
        assert!(c.predicted_steps_per_sec > 0.0);
        assert_eq!(report.off_expectation_count(), 0, "{:?}", c);
    }

    #[test]
    fn report_json_has_summary_and_cells() {
        let report = run_campaign(&tiny_spec());
        let j = report.to_json();
        assert_eq!(j.get("format_version").unwrap().as_usize().unwrap(), 1);
        let cells = j.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].get("scenario").unwrap().as_str().unwrap(), "tiny-grid");
        let s = j.get("summary").unwrap();
        assert_eq!(s.get("total").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn runner_error_cells_are_hard_fails() {
        // an invalid machine name forces the error path
        let cell = run_cell(&tiny_spec(), ScenarioId::TinyGrid, "gmem_8x8x8", "h100");
        assert_eq!(cell.verdict, Verdict::HardFail);
        assert!(cell.off_expectation());
        assert!(cell.error.is_some());
    }

    #[test]
    fn runner_error_on_a_stress_cell_is_still_off_expectation() {
        // a stress scenario is expected to HardFail for *physics*
        // reasons; an infrastructure error must not hide behind that
        let cell = run_cell(&tiny_spec(), ScenarioId::CflMarginStress, "gmem_8x8x8", "h100");
        assert_eq!(cell.verdict, cell.expected);
        assert!(cell.off_expectation(), "errors must never count as expected");
    }

    #[test]
    fn stress_cell_that_passes_is_off_expectation() {
        let cell = CampaignCell {
            scenario: ScenarioId::CflMarginStress,
            variant: "gmem_8x8x8".into(),
            machine: "v100".into(),
            verdict: Verdict::Pass,
            expected: Verdict::HardFail,
            failed_criteria: vec![],
            steps_completed: 10,
            peak_abs: 1.0,
            final_energy: 1.0,
            boundary_leakage: 0.1,
            predicted_steps_per_sec: 1.0,
            wall_ms: 1.0,
            error: None,
        };
        assert!(cell.off_expectation(), "an unexpectedly-green stress cell must fail the gate");
    }

    #[test]
    fn quick_spec_keeps_every_requested_machine() {
        let spec = CampaignSpec::quick(vec!["v100".into(), "p100".into(), "nvs510".into()]);
        assert_eq!(spec.machines.len(), 3);
        assert_eq!(spec.variants.len(), 1);
        assert_eq!(spec.steps_scale, Some(0.25));
    }
}
