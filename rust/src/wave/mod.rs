//! Wave-physics substrate: velocity models, source wavelets, and the PML
//! damping profile. Mirrors `python/tests/test_physics.py::eta_profile`
//! and the constants in DESIGN.md §5.

use crate::grid::{Dim3, Domain, Field3};

/// Velocity models used by the examples and benches.
#[derive(Clone, Debug)]
pub enum VelocityModel {
    /// Homogeneous medium.
    Constant(f32),
    /// Horizontally layered medium: (top_z_fraction, velocity) pairs,
    /// sorted by depth; each layer extends to the next boundary.
    Layered(Vec<(f64, f32)>),
    /// Linear velocity gradient with depth: v(z) = v0 + k * z * h.
    GradientZ { v0: f32, k_per_m: f32, h: f64 },
}

impl VelocityModel {
    /// Materialize onto an interior grid.
    pub fn build(&self, interior: Dim3) -> Field3 {
        match self {
            VelocityModel::Constant(v) => Field3::full(interior, *v),
            VelocityModel::Layered(layers) => {
                assert!(!layers.is_empty(), "layered model needs at least one layer");
                Field3::from_fn(interior, |z, _, _| {
                    let frac = z as f64 / interior.z.max(1) as f64;
                    let mut v = layers[0].1;
                    for &(top, vel) in layers {
                        if frac >= top {
                            v = vel;
                        }
                    }
                    v
                })
            }
            VelocityModel::GradientZ { v0, k_per_m, h } => {
                Field3::from_fn(interior, |z, _, _| v0 + k_per_m * (z as f64 * h) as f32)
            }
        }
    }

    /// Maximum velocity over the grid this model would materialize on
    /// (for CFL / eta_max). For `GradientZ` this is the velocity at the
    /// actual bottom of the grid — not a nominal depth bound, which
    /// used to overstate v_max (and so over-throttle dt) by the ratio
    /// of the assumed to the real depth.
    pub fn v_max_on(&self, interior: Dim3) -> f32 {
        match self {
            VelocityModel::Constant(v) => *v,
            VelocityModel::Layered(layers) => {
                layers.iter().map(|&(_, v)| v).fold(0.0f32, f32::max)
            }
            VelocityModel::GradientZ { v0, k_per_m, h } => {
                let depth_m = interior.z.saturating_sub(1) as f64 * h;
                // negative gradients peak at the surface
                v0.max(v0 + k_per_m * depth_m as f32)
            }
        }
    }
}

/// Ricker wavelet with peak frequency `f0`, delayed so it starts near 0.
pub fn ricker(t: f64, f0: f64) -> f64 {
    let a = (std::f64::consts::PI * f0 * (t - 1.2 / f0)).powi(2);
    (1.0 - 2.0 * a) * (-a).exp()
}

/// Quadratic PML damping ramp (DESIGN.md §5):
/// eta(d) = eta_max ((W-d)/W)^2 within the sponge, 0 in the inner region,
/// eta_max = 3 v_max ln(1/Rc) / (2 W h), Rc = 1e-3. Per-axis ramps are
/// combined with max(), mirroring the Python profile exactly.
pub fn eta_profile(domain: &Domain, v_max: f64) -> Field3 {
    let w = domain.pml_width;
    let eta_max = 3.0 * v_max * (1000.0f64).ln() / (2.0 * w as f64 * domain.h);
    let n = domain.interior;
    let ramp = |i: usize, len: usize| -> f64 {
        let d = (i.min(len - 1 - i)) as f64; // distance to nearest face
        if d < w as f64 {
            let r = (w as f64 - d) / w as f64;
            r * r
        } else {
            0.0
        }
    };
    Field3::from_fn(n, |z, y, x| {
        let r = ramp(z, n.z).max(ramp(y, n.y)).max(ramp(x, n.x));
        (eta_max * r) as f32
    })
}

/// Source descriptor: an interior grid position + Ricker parameters.
#[derive(Copy, Clone, Debug)]
pub struct Source {
    pub pos: Dim3,
    pub f0: f64,
    pub amplitude: f64,
}

impl Source {
    /// Injection amplitude at step n (the coordinator adds this to u+):
    /// dt^2 v(src)^2 amplitude ricker(n dt).
    pub fn amp_at(&self, n: usize, dt: f64, v_at_src: f32) -> f32 {
        let w = ricker(n as f64 * dt, self.f0);
        (dt * dt * (v_at_src as f64) * (v_at_src as f64) * self.amplitude * w) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn domain() -> Domain {
        Domain::new(Dim3::new(36, 36, 36), 6, 10.0, 1e-3).unwrap()
    }

    #[test]
    fn constant_model() {
        let v = VelocityModel::Constant(2500.0).build(Dim3::new(4, 4, 4));
        assert!(v.as_slice().iter().all(|&x| x == 2500.0));
        assert_eq!(VelocityModel::Constant(2500.0).v_max_on(Dim3::new(4, 4, 4)), 2500.0);
    }

    #[test]
    fn layered_model_monotone_depth() {
        let m = VelocityModel::Layered(vec![(0.0, 1500.0), (0.4, 2500.0), (0.8, 4000.0)]);
        let v = m.build(Dim3::new(10, 2, 2));
        assert_eq!(v.get(0, 0, 0), 1500.0);
        assert_eq!(v.get(5, 0, 0), 2500.0);
        assert_eq!(v.get(9, 0, 0), 4000.0);
        assert_eq!(m.v_max_on(Dim3::new(10, 2, 2)), 4000.0);
    }

    #[test]
    fn gradient_model() {
        let m = VelocityModel::GradientZ { v0: 1500.0, k_per_m: 0.5, h: 10.0 };
        let v = m.build(Dim3::new(5, 1, 1));
        assert_eq!(v.get(0, 0, 0), 1500.0);
        assert_eq!(v.get(4, 0, 0), 1500.0 + 0.5 * 40.0);
    }

    #[test]
    fn gradient_v_max_tracks_the_materialized_grid() {
        let m = VelocityModel::GradientZ { v0: 1500.0, k_per_m: 1.0, h: 10.0 };
        for nz in [5usize, 40, 200] {
            let dims = Dim3::new(nz, 2, 2);
            let built_max =
                m.build(dims).as_slice().iter().fold(0.0f32, |a, &b| a.max(b));
            assert_eq!(m.v_max_on(dims), built_max, "nz = {nz}");
        }
        // the old behavior bounded depth at 1e4 m — on a 40-cell grid
        // that overstated v_max by ~6x (11500 vs 1890) and would have
        // over-throttled dt by the same factor
        assert!(m.v_max_on(Dim3::new(40, 2, 2)) < 2000.0);
        // negative gradients peak at the surface, never below v0
        let neg = VelocityModel::GradientZ { v0: 3000.0, k_per_m: -2.0, h: 10.0 };
        assert_eq!(neg.v_max_on(Dim3::new(50, 2, 2)), 3000.0);
    }

    #[test]
    fn ricker_peaks_near_delay() {
        let f0 = 15.0;
        let t_peak = 1.2 / f0;
        assert!((ricker(t_peak, f0) - 1.0).abs() < 1e-9);
        assert!(ricker(0.0, f0).abs() < 0.01);
        assert!(ricker(10.0, f0).abs() < 1e-12);
    }

    #[test]
    fn eta_profile_zero_inside_positive_on_shell() {
        let d = domain();
        let eta = eta_profile(&d, 2000.0);
        let w = d.pml_width;
        // strictly inside: zero
        for z in w..d.interior.z - w {
            assert_eq!(eta.get(z, d.interior.y / 2, d.interior.x / 2), 0.0);
        }
        // faces: positive, maximal at the outer face
        assert!(eta.get(0, 18, 18) > eta.get(w - 1, 18, 18));
        assert!(eta.get(0, 18, 18) > 0.0);
        assert!(eta.get(18, 0, 18) > 0.0);
        assert!(eta.get(18, 18, d.interior.x - 1) > 0.0);
    }

    #[test]
    fn eta_profile_matches_python_formula() {
        let d = domain();
        let eta = eta_profile(&d, 2000.0);
        let eta_max = 3.0 * 2000.0 * (1000.0f64).ln() / (2.0 * 6.0 * 10.0);
        // corner-most cell has d=0 -> full eta_max
        assert!((eta.get(0, 0, 0) as f64 - eta_max).abs() / eta_max < 1e-6);
        // one cell in: ((6-1)/6)^2 * eta_max along a single axis
        let want = eta_max * (5.0f64 / 6.0).powi(2);
        assert!((eta.get(1, 18, 18) as f64 - want).abs() / want < 1e-6);
    }

    #[test]
    fn source_amplitude_scales() {
        let s = Source { pos: Dim3::new(1, 1, 1), f0: 15.0, amplitude: 1.0 };
        let a = s.amp_at(10, 1e-3, 2000.0);
        let b = s.amp_at(10, 1e-3, 4000.0);
        assert!((b / a - 4.0).abs() < 1e-3); // quadratic in v
    }
}
