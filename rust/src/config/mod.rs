//! Run configuration: a small TOML-subset parser (substrate — no `toml`
//! crate in the offline set) plus the typed `RunConfig`.
//!
//! Supported syntax: `[section]` headers, `key = value` with string,
//! integer, float, and boolean values, `#` comments, blank lines.

use std::collections::BTreeMap;

use crate::coordinator::Mode;
use crate::grid::{Dim3, Domain};
use crate::stencil;
use crate::wave::{Source, VelocityModel};

/// Raw parsed config: section -> key -> value.
#[derive(Clone, Debug, Default)]
pub struct Toml {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    fn parse(raw: &str) -> anyhow::Result<Value> {
        let raw = raw.trim();
        if let Some(stripped) = raw.strip_prefix('"') {
            let inner = stripped
                .strip_suffix('"')
                .ok_or_else(|| anyhow::anyhow!("unterminated string {raw:?}"))?;
            return Ok(Value::Str(inner.to_string()));
        }
        match raw {
            "true" => return Ok(Value::Bool(true)),
            "false" => return Ok(Value::Bool(false)),
            _ => {}
        }
        if let Ok(i) = raw.parse::<i64>() {
            return Ok(Value::Int(i));
        }
        if let Ok(f) = raw.parse::<f64>() {
            return Ok(Value::Float(f));
        }
        anyhow::bail!("cannot parse value {raw:?}")
    }
}

impl Toml {
    pub fn parse(text: &str) -> anyhow::Result<Toml> {
        let mut t = Toml::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = match raw.find('#') {
                // only strip comments outside strings (strings here never contain '#')
                Some(i) if !raw[..i].contains('"') || raw[..i].matches('"').count() % 2 == 0 => {
                    &raw[..i]
                }
                _ => raw,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                t.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value: {raw:?}", lineno + 1))?;
            let value = Value::parse(v)
                .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
            t.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), value);
        }
        Ok(t)
    }

    fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> anyhow::Result<String> {
        match self.get(section, key) {
            None => Ok(default.to_string()),
            Some(Value::Str(s)) => Ok(s.clone()),
            Some(other) => anyhow::bail!("[{section}] {key}: expected string, got {other:?}"),
        }
    }

    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(section, key) {
            None => Ok(default),
            Some(Value::Int(i)) if *i >= 0 => Ok(*i as usize),
            Some(other) => anyhow::bail!("[{section}] {key}: expected non-negative int, got {other:?}"),
        }
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(section, key) {
            None => Ok(default),
            Some(Value::Float(f)) => Ok(*f),
            Some(Value::Int(i)) => Ok(*i as f64),
            Some(other) => anyhow::bail!("[{section}] {key}: expected number, got {other:?}"),
        }
    }
}

/// Fully-resolved run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub domain: Domain,
    pub steps: usize,
    pub mode: Mode,
    pub inner_variant: String,
    pub pml_variant: String,
    pub artifacts_dir: String,
    pub model: VelocityModel,
    pub source: Source,
    pub receivers: Vec<Dim3>,
}

impl RunConfig {
    /// Defaults matching the default artifact build (48^3, pml 8).
    pub fn defaults() -> RunConfig {
        let interior = Dim3::new(48, 48, 48);
        let h = 10.0;
        let v = 3000.0;
        let dt = (stencil::cfl_dt(h, v) * 1e6).floor() / 1e6; // mirror aot.py truncation
        RunConfig {
            domain: Domain::new(interior, 8, h, dt).expect("default domain valid"),
            steps: 100,
            mode: Mode::Decomposed,
            inner_variant: "gmem".into(),
            pml_variant: "smem_eta_1".into(),
            artifacts_dir: "artifacts".into(),
            model: VelocityModel::Constant(2500.0),
            source: Source { pos: Dim3::new(24, 24, 24), f0: 15.0, amplitude: 1.0 },
            receivers: (0..8).map(|i| Dim3::new(10, 10, 4 + 5 * i)).collect(),
        }
    }

    /// Parse a TOML-subset config file; missing keys fall back to defaults.
    pub fn from_toml(text: &str) -> anyhow::Result<RunConfig> {
        let t = Toml::parse(text)?;
        let d = RunConfig::defaults();

        let nz = t.usize_or("domain", "nz", d.domain.interior.z)?;
        let ny = t.usize_or("domain", "ny", d.domain.interior.y)?;
        let nx = t.usize_or("domain", "nx", d.domain.interior.x)?;
        let pml = t.usize_or("domain", "pml_width", d.domain.pml_width)?;
        let h = t.f64_or("domain", "h", d.domain.h)?;

        let model = match t.str_or("model", "type", "constant")?.as_str() {
            "constant" => VelocityModel::Constant(t.f64_or("model", "v", 2500.0)? as f32),
            "gradient" => VelocityModel::GradientZ {
                v0: t.f64_or("model", "v0", 1500.0)? as f32,
                k_per_m: t.f64_or("model", "k_per_m", 0.5)? as f32,
                h,
            },
            "layered" => VelocityModel::Layered(vec![
                (0.0, t.f64_or("model", "v_top", 1500.0)? as f32),
                (t.f64_or("model", "interface", 0.5)?, t.f64_or("model", "v_bottom", 3500.0)? as f32),
            ]),
            other => anyhow::bail!("[model] type: unknown {other:?}"),
        };

        // CFL from the velocity the grid will actually materialize
        let v_max = model.v_max_on(Dim3::new(nz, ny, nx)) as f64;
        let dt_default = (stencil::cfl_dt(h, v_max) * 1e6).floor() / 1e6;
        let dt = t.f64_or("domain", "dt", dt_default)?;
        let domain = Domain::new(Dim3::new(nz, ny, nx), pml, h, dt)?;

        let source = Source {
            pos: Dim3::new(
                t.usize_or("source", "z", nz / 2)?,
                t.usize_or("source", "y", ny / 2)?,
                t.usize_or("source", "x", nx / 2)?,
            ),
            f0: t.f64_or("source", "f0", 15.0)?,
            amplitude: t.f64_or("source", "amplitude", 1.0)?,
        };

        // receivers: a horizontal line at fixed depth
        let n_recv = t.usize_or("receivers", "count", 8)?;
        let depth = t.usize_or("receivers", "depth_z", pml + 2)?;
        let ry = t.usize_or("receivers", "y", ny / 2)?;
        let receivers = if n_recv == 0 {
            vec![]
        } else {
            let step = (nx - 2 * pml).max(1) / n_recv.max(1);
            (0..n_recv)
                .map(|i| Dim3::new(depth, ry, (pml + i * step.max(1)).min(nx - 1)))
                .collect()
        };

        Ok(RunConfig {
            domain,
            steps: t.usize_or("run", "steps", d.steps)?,
            mode: Mode::parse(&t.str_or("run", "mode", "decomposed")?)?,
            inner_variant: t.str_or("run", "inner_variant", &d.inner_variant)?,
            pml_variant: t.str_or("run", "pml_variant", &d.pml_variant)?,
            artifacts_dir: t.str_or("run", "artifacts", &d.artifacts_dir)?,
            model,
            source,
            receivers,
        })
    }

    pub fn load(path: &str) -> anyhow::Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read config {path:?}: {e}"))?;
        Self::from_toml(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_toml() {
        let t = Toml::parse(
            "# comment\n[a]\nx = 3\ny = 1.5\ns = \"hi\" # trailing\nb = true\n\n[b]\nz=-2\n",
        )
        .unwrap();
        assert_eq!(t.usize_or("a", "x", 0).unwrap(), 3);
        assert_eq!(t.f64_or("a", "y", 0.0).unwrap(), 1.5);
        assert_eq!(t.str_or("a", "s", "").unwrap(), "hi");
        assert_eq!(t.get("a", "b"), Some(&Value::Bool(true)));
        assert_eq!(t.f64_or("b", "z", 0.0).unwrap(), -2.0);
        assert_eq!(t.usize_or("missing", "k", 7).unwrap(), 7);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Toml::parse("[a]\nnope").is_err());
        assert!(Toml::parse("[a]\nx = \"unterminated").is_err());
        assert!(Toml::parse("[a]\nx = what").is_err());
    }

    #[test]
    fn type_mismatch_reported() {
        let t = Toml::parse("[a]\nx = \"s\"\n").unwrap();
        assert!(t.usize_or("a", "x", 0).is_err());
        assert!(t.f64_or("a", "x", 0.0).is_err());
    }

    #[test]
    fn run_config_defaults_are_valid() {
        let c = RunConfig::defaults();
        assert!(c.domain.validate().is_ok());
        assert_eq!(c.mode, Mode::Decomposed);
        // default dt respects CFL for the default vmax
        assert!(c.domain.dt <= stencil::cfl_dt(c.domain.h, 3000.0));
    }

    #[test]
    fn run_config_from_toml_overrides() {
        let cfg = RunConfig::from_toml(
            "[domain]\nnz = 36\nny = 36\nnx = 36\npml_width = 6\n\
             [run]\nsteps = 50\nmode = \"golden\"\ninner_variant = \"st_smem\"\n\
             [model]\ntype = \"gradient\"\nv0 = 1600\nk_per_m = 0.4\n\
             [source]\nz = 10\nf0 = 20.0\n",
        )
        .unwrap();
        assert_eq!(cfg.domain.interior, Dim3::new(36, 36, 36));
        assert_eq!(cfg.steps, 50);
        assert_eq!(cfg.mode, Mode::Golden);
        assert_eq!(cfg.inner_variant, "st_smem");
        assert_eq!(cfg.source.pos.z, 10);
        assert!(matches!(cfg.model, VelocityModel::GradientZ { .. }));
        // dt derived from gradient v_max, still positive
        assert!(cfg.domain.dt > 0.0);
    }

    #[test]
    fn run_config_rejects_bad_mode_and_model() {
        assert!(RunConfig::from_toml("[run]\nmode = \"hyper\"\n").is_err());
        assert!(RunConfig::from_toml("[model]\ntype = \"magma\"\n").is_err());
    }
}
