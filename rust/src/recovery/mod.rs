//! Long-run operations: checkpoint/restart, divergence circuit
//! breakers, and trace replay (see docs/OPERATIONS.md).
//!
//! Production stencil runs are hours of wall time, not the 100-step
//! gauntlets the scenario catalogue gates — a killed process or a
//! diverged wavefield must not burn the whole budget. This module is
//! the recovery substrate the coordinator wires through the time loop:
//!
//! * [`Checkpoint`] — a versioned, checksummed binary snapshot of the
//!   full propagator state: both R-ghost-padded leapfrog buffers, the
//!   step index (which *is* the injection-schedule cursor — sources
//!   are pure functions of the step index, so there is no separate RNG
//!   state to save), the accumulated receiver traces and energy log.
//!   Restoring into a fresh coordinator continues **bitwise
//!   identically** (`rust/tests/restart_consistency.rs`).
//! * [`DivergenceBreaker`] — in-loop watchdogs generalizing the
//!   non-finite abort: an energy-growth breaker over a sliding window
//!   and a NaN-rate breaker, tripping to [`SoftAbort`]
//!   (checkpoint-and-halt with a structured reason) instead of
//!   stepping a dead run to the step budget.
//! * [`Trace`] — a JSONL recording of the injected source samples and
//!   receiver traces (`run --record`), replayable via `hostencil
//!   replay` which re-executes the run and diffs receiver output
//!   against the recording, turning an incident into a test case.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::fault::{FaultKind, FaultPlan, FaultSite};
use crate::grid::Dim3;
use crate::json::Json;
use crate::wave::{Source, VelocityModel};

/// Leading magic of every checkpoint file.
pub const CHECKPOINT_MAGIC: [u8; 8] = *b"HOSTCKPT";
/// Current checkpoint format version (bump on any layout change).
pub const CHECKPOINT_VERSION: u32 = 1;
/// `kind` field of a replay-trace header line.
pub const TRACE_KIND: &str = "hostencil-trace";
/// Current replay-trace format version.
pub const TRACE_VERSION: u32 = 1;

/// FNV-1a 64-bit hash — the checkpoint checksum and the state digest.
/// Stable, dependency-free, and byte-order independent (it hashes the
/// little-endian serialized stream).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// FNV-1a 64 over the little-endian bit patterns of an `f32` slice —
/// the per-band halo checksum. Allocation-free (no serialization
/// buffer), so it is safe inside the zero-alloc steady-state loop.
pub fn fnv1a64_f32(vals: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &v in vals {
        for b in v.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// fsync the directory holding `path`, so a rename published into it
/// survives a crash (on ext4-style journals the rename itself is not
/// durable until the directory is synced). No-op off unix, where the
/// directory-handle sync idiom does not exist.
fn sync_parent_dir(path: &Path) -> anyhow::Result<()> {
    #[cfg(unix)]
    {
        let parent = match path.parent() {
            Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
            _ => PathBuf::from("."),
        };
        let dir = File::open(&parent).map_err(|e| {
            anyhow::anyhow!("cannot open checkpoint directory {}: {e}", parent.display())
        })?;
        dir.sync_all().map_err(|e| {
            anyhow::anyhow!("cannot fsync checkpoint directory {}: {e}", parent.display())
        })?;
    }
    #[cfg(not(unix))]
    let _ = path;
    Ok(())
}

/// Write `bytes` to `path` atomically and durably: a sibling `.tmp`
/// file is written and fsynced first, renamed into place, then the
/// parent directory is fsynced — so a crash at any point leaves either
/// the old snapshot or the new one, never a torn or vanished file.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> anyhow::Result<()> {
    write_atomic_with(path, bytes, None)
}

/// [`write_atomic`] with an optional fault plan armed at the
/// checkpoint-I/O site. Injected faults (`ckpt:short`, `ckpt:enospc`)
/// fail the write with a named error *before* the rename, leaving any
/// previously published snapshot untouched; `ckpt:corrupt` flips a
/// byte of the freshly *published* file — silent on the write path by
/// design, caught by the checksum at restore time (where the retention
/// ring falls back to an older valid snapshot).
pub fn write_atomic_with(
    path: &Path,
    bytes: &[u8],
    faults: Option<&FaultPlan>,
) -> anyhow::Result<()> {
    let tmp = path.with_extension("ckpt.tmp");
    if let Some(f) = faults {
        if f.fire(FaultSite::Checkpoint, FaultKind::Enospc) {
            anyhow::bail!(
                "cannot write checkpoint {}: injected fault: no space left on device (ENOSPC)",
                tmp.display()
            );
        }
    }
    let write_len = match faults {
        Some(f) if f.fire(FaultSite::Checkpoint, FaultKind::ShortWrite) => bytes.len() / 2,
        _ => bytes.len(),
    };
    let mut file = File::create(&tmp)
        .map_err(|e| anyhow::anyhow!("cannot create checkpoint {}: {e}", tmp.display()))?;
    file.write_all(&bytes[..write_len])
        .map_err(|e| anyhow::anyhow!("cannot write checkpoint {}: {e}", tmp.display()))?;
    file.sync_all()
        .map_err(|e| anyhow::anyhow!("cannot fsync checkpoint {}: {e}", tmp.display()))?;
    drop(file);
    if write_len != bytes.len() {
        // the torn tmp is left behind deliberately — exactly what a
        // real short write leaves — and the next successful write
        // truncates over it; the *published* path was never touched
        anyhow::bail!(
            "cannot write checkpoint {}: injected fault: short write ({write_len} of {} bytes)",
            tmp.display(),
            bytes.len()
        );
    }
    std::fs::rename(&tmp, path)
        .map_err(|e| anyhow::anyhow!("cannot move checkpoint into {}: {e}", path.display()))?;
    sync_parent_dir(path)?;
    if let Some(f) = faults {
        if f.fire(FaultSite::Checkpoint, FaultKind::Corrupt) {
            flip_byte_mid_file(path)?;
        }
    }
    Ok(())
}

/// Flip one bit in the middle of `path` — the injected-corruption
/// primitive shared by the `ckpt:corrupt` / `restore:corrupt` fault
/// sites and the chaos harness. The midpoint of any non-trivial
/// snapshot lands in the state payload, so the trailing checksum is
/// guaranteed to catch the flip at load time.
pub fn flip_byte_mid_file(path: &Path) -> anyhow::Result<()> {
    let mut bytes = std::fs::read(path)
        .map_err(|e| anyhow::anyhow!("cannot read {} to corrupt it: {e}", path.display()))?;
    anyhow::ensure!(!bytes.is_empty(), "cannot corrupt empty file {}", path.display());
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(path, &bytes)
        .map_err(|e| anyhow::anyhow!("cannot write corrupted {}: {e}", path.display()))?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Checkpoint retention ring
// ---------------------------------------------------------------------------

/// The retention-ring member paths for `path` with `keep` slots,
/// newest first: the live snapshot itself, then `.1` … `.{keep-1}`
/// suffixed rotations. `keep` is clamped to at least 1.
pub fn ring_paths(path: &Path, keep: usize) -> Vec<PathBuf> {
    let mut out = vec![path.to_path_buf()];
    for i in 1..keep.max(1) {
        let mut os = path.as_os_str().to_os_string();
        os.push(format!(".{i}"));
        out.push(PathBuf::from(os));
    }
    out
}

/// Rotate existing ring members one slot older (the oldest falls off)
/// so slot 0 is free for a fresh snapshot. With `keep == 1` this is a
/// no-op — the atomic rename in [`write_atomic`] already replaces the
/// only slot. Renames are followed by a parent-directory fsync so the
/// rotation is durable as a unit.
pub fn rotate_ring(path: &Path, keep: usize) -> anyhow::Result<()> {
    let ring = ring_paths(path, keep);
    if ring.len() < 2 {
        return Ok(());
    }
    let mut moved = false;
    for i in (0..ring.len() - 1).rev() {
        if ring[i].exists() {
            std::fs::rename(&ring[i], &ring[i + 1]).map_err(|e| {
                anyhow::anyhow!(
                    "cannot rotate checkpoint {} -> {}: {e}",
                    ring[i].display(),
                    ring[i + 1].display()
                )
            })?;
            moved = true;
        }
    }
    if moved {
        sync_parent_dir(path)?;
    }
    Ok(())
}

/// Load the newest *valid* snapshot in the retention ring, scanning
/// newest-first past corrupt, torn, or missing members. Returns the
/// checkpoint, the slot it was read from, and one note per skipped
/// slot (so the caller can surface what the fallback stepped over).
/// Errors only when every slot is unreadable.
pub fn load_newest_valid(
    path: &Path,
    keep: usize,
) -> anyhow::Result<(Checkpoint, PathBuf, Vec<String>)> {
    let mut skipped = Vec::new();
    for slot in ring_paths(path, keep) {
        match Checkpoint::load(&slot) {
            Ok(ck) => return Ok((ck, slot, skipped)),
            Err(e) => skipped.push(format!("{}: {e}", slot.display())),
        }
    }
    anyhow::bail!(
        "no valid checkpoint in the retention ring of {} (keep {}):\n  {}",
        path.display(),
        keep.max(1),
        skipped.join("\n  ")
    )
}

// ---------------------------------------------------------------------------
// Checkpoint: versioned, checksummed binary snapshot
// ---------------------------------------------------------------------------

/// Full propagator state at a step boundary. `u_pad`/`um_pad` are the
/// two R-ghost-padded leapfrog buffers in row-major order (the same
/// layout `Field3::as_slice` exposes); `steps_done` doubles as the
/// injection-schedule cursor because source amplitudes are pure
/// functions of the step index.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub interior: Dim3,
    pub pml_width: usize,
    pub h: f64,
    pub dt: f64,
    pub steps_done: u64,
    pub launches: u64,
    /// Per-receiver sample history accumulated so far.
    pub traces: Vec<Vec<f32>>,
    /// Per-batch energy log accumulated so far.
    pub energy_log: Vec<f64>,
    pub u_pad: Vec<f32>,
    pub um_pad: Vec<f32>,
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}

fn put_f32_slice(out: &mut Vec<u8>, v: &[f32]) {
    put_u64(out, v.len() as u64);
    for &x in v {
        out.extend_from_slice(&x.to_bits().to_le_bytes());
    }
}

fn put_f64_slice(out: &mut Vec<u8>, v: &[f64]) {
    put_u64(out, v.len() as u64);
    for &x in v {
        put_f64(out, x);
    }
}

/// Bounds-checked reader over a checkpoint byte stream: every read
/// errors with the offending byte offset instead of panicking on a
/// truncated or corrupt file.
struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .ok_or_else(|| anyhow::anyhow!("checkpoint length overflows at byte {}", self.pos))?;
        let s = self
            .bytes
            .get(self.pos..end)
            .ok_or_else(|| anyhow::anyhow!("checkpoint truncated at byte {}", self.pos))?;
        self.pos = end;
        Ok(s)
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn len(&mut self) -> anyhow::Result<usize> {
        let n = self.u64()?;
        usize::try_from(n)
            .map_err(|_| anyhow::anyhow!("checkpoint length {n} does not fit this platform"))
    }

    fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn f32_vec(&mut self) -> anyhow::Result<Vec<f32>> {
        let n = self.len()?;
        let raw = self.take(n.checked_mul(4).ok_or_else(|| {
            anyhow::anyhow!("checkpoint f32 run of {n} elements overflows")
        })?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().expect("4 bytes"))))
            .collect())
    }

    fn f64_vec(&mut self) -> anyhow::Result<Vec<f64>> {
        let n = self.len()?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f64()?);
        }
        Ok(out)
    }
}

impl Checkpoint {
    /// Serialize to the versioned binary layout, FNV-1a 64 checksum
    /// trailing (computed over every preceding byte).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64 + 4 * (self.u_pad.len() + self.um_pad.len()));
        out.extend_from_slice(&CHECKPOINT_MAGIC);
        put_u32(&mut out, CHECKPOINT_VERSION);
        put_u64(&mut out, self.interior.z as u64);
        put_u64(&mut out, self.interior.y as u64);
        put_u64(&mut out, self.interior.x as u64);
        put_u64(&mut out, self.pml_width as u64);
        put_f64(&mut out, self.h);
        put_f64(&mut out, self.dt);
        put_u64(&mut out, self.steps_done);
        put_u64(&mut out, self.launches);
        put_u64(&mut out, self.traces.len() as u64);
        for t in &self.traces {
            put_f32_slice(&mut out, t);
        }
        put_f64_slice(&mut out, &self.energy_log);
        put_f32_slice(&mut out, &self.u_pad);
        put_f32_slice(&mut out, &self.um_pad);
        let sum = fnv1a64(&out);
        put_u64(&mut out, sum);
        out
    }

    /// Parse and verify a serialized checkpoint: magic, version, the
    /// trailing checksum, and exact length are all enforced.
    pub fn from_bytes(bytes: &[u8]) -> anyhow::Result<Checkpoint> {
        anyhow::ensure!(
            bytes.len() >= CHECKPOINT_MAGIC.len() + 4 + 8,
            "checkpoint too short ({} bytes) to carry a header",
            bytes.len()
        );
        anyhow::ensure!(
            bytes[..CHECKPOINT_MAGIC.len()] == CHECKPOINT_MAGIC,
            "not a hostencil checkpoint (bad magic)"
        );
        let body = &bytes[..bytes.len() - 8];
        let stored =
            u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().expect("8 bytes"));
        let computed = fnv1a64(body);
        anyhow::ensure!(
            stored == computed,
            "checkpoint checksum mismatch (stored {stored:#018x}, computed {computed:#018x}) — \
             file corrupt or torn"
        );
        let mut c = Cursor { bytes: body, pos: CHECKPOINT_MAGIC.len() };
        let version = c.u32()?;
        anyhow::ensure!(
            version == CHECKPOINT_VERSION,
            "checkpoint version {version} unsupported (this build reads version \
             {CHECKPOINT_VERSION})"
        );
        let (z, y, x) = (c.len()?, c.len()?, c.len()?);
        let pml_width = c.len()?;
        let h = c.f64()?;
        let dt = c.f64()?;
        let steps_done = c.u64()?;
        let launches = c.u64()?;
        let n_traces = c.len()?;
        let mut traces = Vec::with_capacity(n_traces);
        for _ in 0..n_traces {
            traces.push(c.f32_vec()?);
        }
        let energy_log = c.f64_vec()?;
        let u_pad = c.f32_vec()?;
        let um_pad = c.f32_vec()?;
        anyhow::ensure!(
            c.pos == body.len(),
            "checkpoint has {} trailing bytes after the state payload",
            body.len() - c.pos
        );
        Ok(Checkpoint {
            interior: Dim3::new(z, y, x),
            pml_width,
            h,
            dt,
            steps_done,
            launches,
            traces,
            energy_log,
            u_pad,
            um_pad,
        })
    }

    /// Atomic write to `path` (tmp + rename).
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        write_atomic(path, &self.to_bytes())
    }

    pub fn load(path: &Path) -> anyhow::Result<Checkpoint> {
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("cannot read checkpoint {}: {e}", path.display()))?;
        Self::from_bytes(&bytes)
    }

    /// Digest of the physical state only (both leapfrog buffers plus
    /// the step cursor) — what the restart-consistency CI smoke
    /// compares between an interrupted and an uninterrupted run.
    pub fn state_digest(&self) -> u64 {
        state_digest(self.steps_done, &self.u_pad, &self.um_pad)
    }
}

/// FNV-1a digest over (step cursor, u bits, um bits) — bitwise state
/// identity in one printable number.
pub fn state_digest(steps_done: u64, u_pad: &[f32], um_pad: &[f32]) -> u64 {
    let mut bytes = Vec::with_capacity(8 + 4 * (u_pad.len() + um_pad.len()));
    put_u64(&mut bytes, steps_done);
    for &x in u_pad {
        bytes.extend_from_slice(&x.to_bits().to_le_bytes());
    }
    for &x in um_pad {
        bytes.extend_from_slice(&x.to_bits().to_le_bytes());
    }
    fnv1a64(&bytes)
}

// ---------------------------------------------------------------------------
// Divergence circuit breakers
// ---------------------------------------------------------------------------

/// Which watchdog tripped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerKind {
    /// Energy grew past `energy_ratio` times the oldest sample in the
    /// sliding window while the field was still finite.
    EnergyGrowth,
    /// More non-finite energy observations than `nan_budget` allows.
    NanRate,
    /// A halo exchange exhausted its retry budget or per-exchange
    /// deadline: the sharded engine could not complete a batch, the
    /// pre-batch state is still intact, and the coordinator
    /// checkpoints it and soft-aborts instead of wedging.
    HaloStall,
}

impl BreakerKind {
    /// Label value for `hostencil_breaker_trips_total{kind=...}` and
    /// the `watchdog_trip` flight-recorder event.
    pub fn name(&self) -> &'static str {
        match self {
            BreakerKind::EnergyGrowth => "energy_growth",
            BreakerKind::NanRate => "nan_rate",
            BreakerKind::HaloStall => "halo_stall",
        }
    }
}

/// Breaker thresholds. `arm_step: None` auto-arms after the source
/// wavelets have finished injecting (the Ricker ramp is
/// super-exponential, so a window ratio during injection would
/// false-trip on perfectly healthy runs); once the sources are quiet a
/// stable run's energy is non-increasing under PML absorption, which
/// is what makes the ratio test sound.
#[derive(Clone, Copy, Debug)]
pub struct BreakerConfig {
    /// Sliding-window length in observed batches.
    pub energy_window: usize,
    /// Trip when `energy > energy_ratio * oldest-in-window`.
    pub energy_ratio: f64,
    /// First step index at which energy samples are recorded; `None`
    /// lets the coordinator compute the source-quiet step.
    pub arm_step: Option<usize>,
    /// Non-finite energy observations tolerated before the NaN-rate
    /// breaker trips (0 = trip on the first one).
    pub nan_budget: usize,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        BreakerConfig {
            energy_window: 16,
            energy_ratio: 1e3,
            arm_step: None,
            nan_budget: 0,
        }
    }
}

/// Structured reason a run halted early: which breaker, at which step,
/// with a human-readable detail line. The coordinator checkpoints (if
/// configured) and returns a *successful* summary carrying this — a
/// tripped breaker is an operational outcome, not a crash.
#[derive(Clone, Debug)]
pub struct SoftAbort {
    pub kind: BreakerKind,
    pub step: usize,
    pub detail: String,
}

/// In-loop divergence watchdog. `observe` is allocation-free: the
/// energy window is a ring buffer preallocated at construction, so the
/// zero-alloc steady-state proof holds with breakers armed.
#[derive(Debug)]
pub struct DivergenceBreaker {
    cfg: BreakerConfig,
    arm_step: usize,
    ring: Vec<f64>,
    head: usize,
    filled: usize,
    nan_seen: usize,
}

impl DivergenceBreaker {
    /// `auto_arm_step` is used when the config leaves `arm_step` unset
    /// (the coordinator passes the source-quiet step).
    pub fn new(cfg: BreakerConfig, auto_arm_step: usize) -> DivergenceBreaker {
        DivergenceBreaker {
            arm_step: cfg.arm_step.unwrap_or(auto_arm_step),
            ring: vec![0.0; cfg.energy_window.max(1)],
            head: 0,
            filled: 0,
            nan_seen: 0,
            cfg,
        }
    }

    /// Step index at which the energy-growth window starts recording.
    pub fn arm_step(&self) -> usize {
        self.arm_step
    }

    /// Feed one batch-boundary energy sample; returns the breaker that
    /// tripped, if any. Non-finite samples count against the NaN
    /// budget regardless of arming; finite samples only enter the
    /// window once armed, and the ratio test only fires on a full
    /// window (so the baseline is a genuine steady-state sample, not
    /// the first post-arm reading).
    pub fn observe(&mut self, step: usize, energy: f64) -> Option<BreakerKind> {
        if !energy.is_finite() {
            self.nan_seen += 1;
            if self.nan_seen > self.cfg.nan_budget {
                return Some(BreakerKind::NanRate);
            }
            return None;
        }
        if step < self.arm_step {
            return None;
        }
        let window = self.ring.len();
        if self.filled == window {
            let oldest = self.ring[self.head];
            if energy > self.cfg.energy_ratio * oldest && energy > 0.0 {
                return Some(BreakerKind::EnergyGrowth);
            }
            self.ring[self.head] = energy;
            self.head = (self.head + 1) % window;
        } else {
            let idx = (self.head + self.filled) % window;
            self.ring[idx] = energy;
            self.filled += 1;
        }
        None
    }
}

// ---------------------------------------------------------------------------
// Trace replay
// ---------------------------------------------------------------------------

/// One recorded source: its descriptor plus the per-step injected
/// amplitude samples (already scaled by dt^2 * v^2 at the source).
#[derive(Clone, Debug)]
pub struct TraceSource {
    pub source: Source,
    pub amps: Vec<f32>,
}

/// One recorded receiver: its grid position plus the sampled trace
/// (one sample per observed batch).
#[derive(Clone, Debug)]
pub struct TraceReceiver {
    pub pos: Dim3,
    pub trace: Vec<f32>,
}

/// A replayable run recording: enough to rebuild the exact run
/// (domain, velocity model, propagator, fusion degree, sources) plus
/// the observed outputs to diff against (`hostencil replay`).
#[derive(Clone, Debug)]
pub struct Trace {
    pub interior: Dim3,
    pub pml_width: usize,
    pub h: f64,
    pub dt: f64,
    pub steps: usize,
    pub fuse: usize,
    pub propagator: String,
    pub model: VelocityModel,
    pub sources: Vec<TraceSource>,
    pub receivers: Vec<TraceReceiver>,
}

/// Serialize a velocity model to a small JSON descriptor (the trace
/// must rebuild the exact grid, so the model rides in the header).
pub fn model_to_json(m: &VelocityModel) -> Json {
    let mut o = BTreeMap::new();
    match m {
        VelocityModel::Constant(v) => {
            o.insert("kind".to_string(), Json::Str("constant".to_string()));
            o.insert("v".to_string(), Json::Num(*v as f64));
        }
        VelocityModel::Layered(layers) => {
            o.insert("kind".to_string(), Json::Str("layered".to_string()));
            o.insert(
                "layers".to_string(),
                Json::Arr(
                    layers
                        .iter()
                        .map(|&(frac, v)| Json::Arr(vec![Json::Num(frac), Json::Num(v as f64)]))
                        .collect(),
                ),
            );
        }
        VelocityModel::GradientZ { v0, k_per_m, h } => {
            o.insert("kind".to_string(), Json::Str("gradient_z".to_string()));
            o.insert("v0".to_string(), Json::Num(*v0 as f64));
            o.insert("k_per_m".to_string(), Json::Num(*k_per_m as f64));
            o.insert("h".to_string(), Json::Num(*h));
        }
    }
    Json::Obj(o)
}

/// Inverse of [`model_to_json`].
pub fn model_from_json(j: &Json) -> anyhow::Result<VelocityModel> {
    match j.get("kind")?.as_str()? {
        "constant" => Ok(VelocityModel::Constant(j.get("v")?.as_f64()? as f32)),
        "layered" => {
            let mut layers = Vec::new();
            for pair in j.get("layers")?.as_arr()? {
                let pair = pair.as_arr()?;
                anyhow::ensure!(pair.len() == 2, "layered model: each layer is [frac, v]");
                layers.push((pair[0].as_f64()?, pair[1].as_f64()? as f32));
            }
            Ok(VelocityModel::Layered(layers))
        }
        "gradient_z" => Ok(VelocityModel::GradientZ {
            v0: j.get("v0")?.as_f64()? as f32,
            k_per_m: j.get("k_per_m")?.as_f64()? as f32,
            h: j.get("h")?.as_f64()?,
        }),
        other => anyhow::bail!("unknown velocity-model kind {other:?} in trace"),
    }
}

fn pos_fields(o: &mut BTreeMap<String, Json>, pos: Dim3) {
    o.insert("z".to_string(), Json::Num(pos.z as f64));
    o.insert("y".to_string(), Json::Num(pos.y as f64));
    o.insert("x".to_string(), Json::Num(pos.x as f64));
}

fn pos_from(j: &Json) -> anyhow::Result<Dim3> {
    Ok(Dim3::new(j.get("z")?.as_usize()?, j.get("y")?.as_usize()?, j.get("x")?.as_usize()?))
}

fn f32_arr(v: &[f32]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn f32_vec_from(j: &Json) -> anyhow::Result<Vec<f32>> {
    j.as_arr()?.iter().map(|x| Ok(x.as_f64()? as f32)).collect()
}

impl Trace {
    /// Emit the JSONL recording: one header line, then one line per
    /// source and per receiver. Numbers round-trip exactly — f32
    /// samples widen to f64 losslessly and `Json` emits the shortest
    /// round-trip decimal — so a replay diff of 0.0 is achievable.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        let mut h = BTreeMap::new();
        h.insert("kind".to_string(), Json::Str(TRACE_KIND.to_string()));
        h.insert("version".to_string(), Json::Num(TRACE_VERSION as f64));
        h.insert("nz".to_string(), Json::Num(self.interior.z as f64));
        h.insert("ny".to_string(), Json::Num(self.interior.y as f64));
        h.insert("nx".to_string(), Json::Num(self.interior.x as f64));
        h.insert("pml".to_string(), Json::Num(self.pml_width as f64));
        h.insert("h".to_string(), Json::Num(self.h));
        h.insert("dt".to_string(), Json::Num(self.dt));
        h.insert("steps".to_string(), Json::Num(self.steps as f64));
        h.insert("fuse".to_string(), Json::Num(self.fuse as f64));
        h.insert("propagator".to_string(), Json::Str(self.propagator.clone()));
        h.insert("model".to_string(), model_to_json(&self.model));
        out.push_str(&Json::Obj(h).emit());
        out.push('\n');
        for s in &self.sources {
            let mut o = BTreeMap::new();
            o.insert("record".to_string(), Json::Str("source".to_string()));
            pos_fields(&mut o, s.source.pos);
            o.insert("f0".to_string(), Json::Num(s.source.f0));
            o.insert("amplitude".to_string(), Json::Num(s.source.amplitude));
            o.insert("amps".to_string(), f32_arr(&s.amps));
            out.push_str(&Json::Obj(o).emit());
            out.push('\n');
        }
        for r in &self.receivers {
            let mut o = BTreeMap::new();
            o.insert("record".to_string(), Json::Str("receiver".to_string()));
            pos_fields(&mut o, r.pos);
            o.insert("trace".to_string(), f32_arr(&r.trace));
            out.push_str(&Json::Obj(o).emit());
            out.push('\n');
        }
        out
    }

    /// Parse a JSONL recording; the header line is validated (kind +
    /// version) before any record line is interpreted.
    pub fn from_jsonl(text: &str) -> anyhow::Result<Trace> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let header = Json::parse(
            lines.next().ok_or_else(|| anyhow::anyhow!("empty trace (no header line)"))?,
        )?;
        let kind = header.get("kind")?.as_str()?;
        anyhow::ensure!(kind == TRACE_KIND, "not a hostencil trace (kind {kind:?})");
        let version = header.get("version")?.as_usize()?;
        anyhow::ensure!(
            version == TRACE_VERSION as usize,
            "trace version {version} unsupported (this build reads version {TRACE_VERSION})"
        );
        let mut t = Trace {
            interior: Dim3::new(
                header.get("nz")?.as_usize()?,
                header.get("ny")?.as_usize()?,
                header.get("nx")?.as_usize()?,
            ),
            pml_width: header.get("pml")?.as_usize()?,
            h: header.get("h")?.as_f64()?,
            dt: header.get("dt")?.as_f64()?,
            steps: header.get("steps")?.as_usize()?,
            fuse: header.get("fuse")?.as_usize()?,
            propagator: header.get("propagator")?.as_str()?.to_string(),
            model: model_from_json(header.get("model")?)?,
            sources: Vec::new(),
            receivers: Vec::new(),
        };
        for line in lines {
            let j = Json::parse(line)?;
            match j.get("record")?.as_str()? {
                "source" => t.sources.push(TraceSource {
                    source: Source {
                        pos: pos_from(&j)?,
                        f0: j.get("f0")?.as_f64()?,
                        amplitude: j.get("amplitude")?.as_f64()?,
                    },
                    amps: f32_vec_from(j.get("amps")?)?,
                }),
                "receiver" => t
                    .receivers
                    .push(TraceReceiver { pos: pos_from(&j)?, trace: f32_vec_from(j.get("trace")?)? }),
                other => anyhow::bail!("unknown trace record kind {other:?}"),
            }
        }
        anyhow::ensure!(!t.sources.is_empty(), "trace has no source records");
        Ok(t)
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_jsonl())
            .map_err(|e| anyhow::anyhow!("cannot write trace {}: {e}", path.display()))?;
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<Trace> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read trace {}: {e}", path.display()))?;
        Self::from_jsonl(&text)
    }
}

/// Max absolute difference between two equally-shaped sample sets
/// (recorded vs replayed receiver traces). Errors on a shape mismatch
/// instead of silently truncating the comparison.
pub fn max_trace_diff(recorded: &[TraceReceiver], replayed: &[Vec<f32>]) -> anyhow::Result<f64> {
    anyhow::ensure!(
        recorded.len() == replayed.len(),
        "receiver count mismatch: trace has {}, replay produced {}",
        recorded.len(),
        replayed.len()
    );
    let mut worst = 0.0f64;
    for (r, p) in recorded.iter().zip(replayed) {
        anyhow::ensure!(
            r.trace.len() == p.len(),
            "trace length mismatch at receiver {}: recorded {}, replayed {}",
            r.pos,
            r.trace.len(),
            p.len()
        );
        for (&a, &b) in r.trace.iter().zip(p) {
            worst = worst.max((a as f64 - b as f64).abs());
        }
    }
    Ok(worst)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_checkpoint() -> Checkpoint {
        Checkpoint {
            interior: Dim3::new(4, 5, 6),
            pml_width: 2,
            h: 10.0,
            dt: 1.25e-3,
            steps_done: 17,
            launches: 119,
            traces: vec![vec![0.0, -0.5, 0.25], vec![1.0e-7, 3.5]],
            energy_log: vec![0.1, 0.4, 0.9],
            u_pad: (0..24).map(|i| i as f32 * 0.5 - 3.0).collect(),
            um_pad: (0..24).map(|i| (i as f32).sin()).collect(),
        }
    }

    #[test]
    fn fnv1a64_known_vectors() {
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn checkpoint_roundtrips_bitwise() {
        let ck = sample_checkpoint();
        let back = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(back.interior, ck.interior);
        assert_eq!(back.pml_width, ck.pml_width);
        assert_eq!(back.h.to_bits(), ck.h.to_bits());
        assert_eq!(back.dt.to_bits(), ck.dt.to_bits());
        assert_eq!(back.steps_done, ck.steps_done);
        assert_eq!(back.launches, ck.launches);
        assert_eq!(back.traces, ck.traces);
        assert_eq!(back.energy_log, ck.energy_log);
        assert_eq!(back.u_pad, ck.u_pad);
        assert_eq!(back.um_pad, ck.um_pad);
        assert_eq!(back.state_digest(), ck.state_digest());
    }

    #[test]
    fn checkpoint_rejects_corruption_truncation_and_bad_magic() {
        let ck = sample_checkpoint();
        let good = ck.to_bytes();

        let mut flipped = good.clone();
        flipped[40] ^= 0x01;
        let err = Checkpoint::from_bytes(&flipped).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");

        let err = Checkpoint::from_bytes(&good[..good.len() / 2]).unwrap_err().to_string();
        assert!(
            err.contains("checksum") || err.contains("truncated") || err.contains("too short"),
            "{err}"
        );

        let mut wrong_magic = good.clone();
        wrong_magic[0] = b'X';
        let err = Checkpoint::from_bytes(&wrong_magic).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");

        // a future version must be refused, not misparsed
        let mut future = good;
        future[8] = 99; // version u32 LE low byte
        // fix the checksum so the version check is what fires
        let n = future.len();
        let sum = fnv1a64(&future[..n - 8]);
        future[n - 8..].copy_from_slice(&sum.to_le_bytes());
        let err = Checkpoint::from_bytes(&future).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn checkpoint_save_load_roundtrip() {
        let path = std::env::temp_dir()
            .join(format!("hostencil_ckpt_test_{}.ckpt", std::process::id()));
        let ck = sample_checkpoint();
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(back.u_pad, ck.u_pad);
        assert_eq!(back.state_digest(), ck.state_digest());
    }

    #[test]
    fn state_digest_tracks_state() {
        let ck = sample_checkpoint();
        let mut other = ck.clone();
        assert_eq!(ck.state_digest(), other.state_digest());
        other.u_pad[3] += 1.0;
        assert_ne!(ck.state_digest(), other.state_digest());
        let mut stepped = ck.clone();
        stepped.steps_done += 1;
        assert_ne!(ck.state_digest(), stepped.state_digest());
    }

    #[test]
    fn breaker_ignores_decaying_energy() {
        let cfg = BreakerConfig { energy_window: 4, energy_ratio: 10.0, arm_step: Some(0), nan_budget: 0 };
        let mut br = DivergenceBreaker::new(cfg, 0);
        // healthy post-source energy: monotone non-increasing
        let mut e = 1.0;
        for step in 0..100 {
            assert_eq!(br.observe(step, e), None, "decay must not trip (step {step})");
            e *= 0.97;
        }
    }

    #[test]
    fn breaker_trips_on_windowed_growth() {
        let cfg = BreakerConfig { energy_window: 4, energy_ratio: 10.0, arm_step: Some(0), nan_budget: 0 };
        let mut br = DivergenceBreaker::new(cfg, 0);
        let mut e = 1.0;
        let mut tripped = None;
        for step in 0..32 {
            if let Some(kind) = br.observe(step, e) {
                tripped = Some((kind, step));
                break;
            }
            e *= 3.0; // 3^4 = 81 > ratio 10 across the window
        }
        let (kind, step) = tripped.expect("exponential growth must trip");
        assert_eq!(kind, BreakerKind::EnergyGrowth);
        // window fills over 4 samples; the first full-window comparison
        // that sees >10x growth is only a few steps later
        assert!(step >= 4 && step < 10, "tripped at {step}");
    }

    #[test]
    fn breaker_stays_disarmed_before_arm_step() {
        let cfg = BreakerConfig { energy_window: 2, energy_ratio: 2.0, arm_step: Some(50), nan_budget: 0 };
        let mut br = DivergenceBreaker::new(cfg, 0);
        assert_eq!(br.arm_step(), 50);
        let mut e = 1.0;
        for step in 0..50 {
            assert_eq!(br.observe(step, e), None, "disarmed window must not trip");
            e *= 10.0; // the Ricker-ramp analog: huge growth pre-arm
        }
        // armed now: growth within the window trips
        let mut tripped = false;
        for step in 50..60 {
            if br.observe(step, e).is_some() {
                tripped = true;
                break;
            }
            e *= 10.0;
        }
        assert!(tripped);
    }

    #[test]
    fn breaker_auto_arm_used_when_unset() {
        let br = DivergenceBreaker::new(BreakerConfig::default(), 123);
        assert_eq!(br.arm_step(), 123);
        let br = DivergenceBreaker::new(
            BreakerConfig { arm_step: Some(7), ..BreakerConfig::default() },
            123,
        );
        assert_eq!(br.arm_step(), 7);
    }

    #[test]
    fn nan_breaker_honors_budget() {
        let cfg = BreakerConfig { energy_window: 4, energy_ratio: 1e3, arm_step: Some(0), nan_budget: 2 };
        let mut br = DivergenceBreaker::new(cfg, 0);
        assert_eq!(br.observe(0, f64::NAN), None);
        assert_eq!(br.observe(1, f64::INFINITY), None);
        assert_eq!(br.observe(2, f64::NAN), Some(BreakerKind::NanRate));
        // budget 0 trips immediately
        let mut strict = DivergenceBreaker::new(
            BreakerConfig { nan_budget: 0, ..BreakerConfig::default() },
            0,
        );
        assert_eq!(strict.observe(0, f64::NAN), Some(BreakerKind::NanRate));
    }

    #[test]
    fn breaker_kind_names_are_label_safe() {
        assert_eq!(BreakerKind::EnergyGrowth.name(), "energy_growth");
        assert_eq!(BreakerKind::NanRate.name(), "nan_rate");
        assert_eq!(BreakerKind::HaloStall.name(), "halo_stall");
    }

    #[test]
    fn fnv1a64_f32_matches_the_byte_hash_and_tracks_bits() {
        let vals = [0.0f32, -1.5, 3.25e-7, f32::NEG_INFINITY];
        let mut bytes = Vec::new();
        for v in vals {
            bytes.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        assert_eq!(fnv1a64_f32(&vals), fnv1a64(&bytes));
        // -0.0 and 0.0 differ bitwise, so the checksum must separate them
        assert_ne!(fnv1a64_f32(&[0.0]), fnv1a64_f32(&[-0.0]));
    }

    #[test]
    fn ring_paths_name_slots_newest_first() {
        let p = Path::new("/tmp/run.ckpt");
        assert_eq!(ring_paths(p, 1), vec![PathBuf::from("/tmp/run.ckpt")]);
        assert_eq!(ring_paths(p, 0), vec![PathBuf::from("/tmp/run.ckpt")], "keep clamps to 1");
        assert_eq!(
            ring_paths(p, 3),
            vec![
                PathBuf::from("/tmp/run.ckpt"),
                PathBuf::from("/tmp/run.ckpt.1"),
                PathBuf::from("/tmp/run.ckpt.2"),
            ]
        );
    }

    fn ring_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("hostencil_ring_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn ring_rotation_ages_snapshots_and_drops_the_oldest() {
        let dir = ring_dir("rotate");
        let path = dir.join("run.ckpt");
        let mut ck = sample_checkpoint();
        for step in [10u64, 20, 30, 40] {
            ck.steps_done = step;
            rotate_ring(&path, 3).unwrap();
            ck.save(&path).unwrap();
        }
        let ring = ring_paths(&path, 3);
        assert_eq!(Checkpoint::load(&ring[0]).unwrap().steps_done, 40);
        assert_eq!(Checkpoint::load(&ring[1]).unwrap().steps_done, 30);
        assert_eq!(Checkpoint::load(&ring[2]).unwrap().steps_done, 20);
        // step 10 fell off the end
        assert_eq!(ring.len(), 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_newest_valid_falls_back_past_corruption() {
        let dir = ring_dir("fallback");
        let path = dir.join("run.ckpt");
        let mut ck = sample_checkpoint();
        for step in [5u64, 6] {
            ck.steps_done = step;
            rotate_ring(&path, 2).unwrap();
            ck.save(&path).unwrap();
        }
        // pristine ring: newest wins, nothing skipped
        let (best, slot, skipped) = load_newest_valid(&path, 2).unwrap();
        assert_eq!(best.steps_done, 6);
        assert_eq!(slot, path);
        assert!(skipped.is_empty());
        // corrupt the newest: the fallback lands on the older slot and
        // names what it stepped over
        flip_byte_mid_file(&path).unwrap();
        let (best, slot, skipped) = load_newest_valid(&path, 2).unwrap();
        assert_eq!(best.steps_done, 5);
        assert_eq!(slot, ring_paths(&path, 2)[1]);
        assert_eq!(skipped.len(), 1);
        assert!(skipped[0].contains("checksum"), "{}", skipped[0]);
        // corrupt the older one too: every slot is named in the error
        flip_byte_mid_file(&ring_paths(&path, 2)[1]).unwrap();
        let err = load_newest_valid(&path, 2).unwrap_err().to_string();
        assert!(err.contains("no valid checkpoint"), "{err}");
        assert!(err.contains("run.ckpt.1"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn injected_write_faults_error_by_name_and_spare_the_published_snapshot() {
        use crate::fault::{FaultKind, FaultPlan, FaultSite};
        let dir = ring_dir("wfaults");
        let path = dir.join("run.ckpt");
        let ck = sample_checkpoint();
        ck.save(&path).unwrap();

        for (kind, needle) in
            [(FaultKind::ShortWrite, "short write"), (FaultKind::Enospc, "ENOSPC")]
        {
            let plan = FaultPlan::single(FaultSite::Checkpoint, kind, 0, 1);
            plan.set_step(1);
            let err = write_atomic_with(&path, &ck.to_bytes(), Some(plan.as_ref()))
                .unwrap_err()
                .to_string();
            assert!(err.contains("injected fault"), "{err}");
            assert!(err.contains(needle), "{err}");
            // the published snapshot survived the failed write
            assert_eq!(Checkpoint::load(&path).unwrap().steps_done, ck.steps_done);
        }

        // post-publish corruption is silent at write time and caught at load
        let plan = FaultPlan::single(FaultSite::Checkpoint, FaultKind::Corrupt, 0, 1);
        plan.set_step(1);
        write_atomic_with(&path, &ck.to_bytes(), Some(plan.as_ref())).unwrap();
        let err = Checkpoint::load(&path).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    fn sample_trace() -> Trace {
        Trace {
            interior: Dim3::new(20, 22, 24),
            pml_width: 4,
            h: 10.0,
            dt: 9.17e-4,
            steps: 6,
            fuse: 2,
            propagator: "tf_s2".to_string(),
            model: VelocityModel::Layered(vec![(0.0, 1800.0), (0.5, 3200.0)]),
            sources: vec![TraceSource {
                source: Source { pos: Dim3::new(10, 11, 12), f0: 15.0, amplitude: 1.0 },
                amps: vec![0.0, 1.25e-3, -7.5e-4, 0.125, -0.25, 3.0e-9],
            }],
            receivers: vec![
                TraceReceiver { pos: Dim3::new(6, 11, 12), trace: vec![0.0, 0.5, -0.125] },
                TraceReceiver { pos: Dim3::new(6, 11, 18), trace: vec![1.0e-7, -2.5, 0.75] },
            ],
        }
    }

    #[test]
    fn trace_roundtrips_through_jsonl() {
        let t = sample_trace();
        let back = Trace::from_jsonl(&t.to_jsonl()).unwrap();
        assert_eq!(back.interior, t.interior);
        assert_eq!(back.pml_width, t.pml_width);
        assert_eq!(back.h.to_bits(), t.h.to_bits());
        assert_eq!(back.dt.to_bits(), t.dt.to_bits());
        assert_eq!(back.steps, t.steps);
        assert_eq!(back.fuse, t.fuse);
        assert_eq!(back.propagator, t.propagator);
        assert_eq!(back.sources.len(), 1);
        assert_eq!(back.sources[0].source.pos, t.sources[0].source.pos);
        assert_eq!(back.sources[0].source.f0, t.sources[0].source.f0);
        // bitwise: f32 -> f64 -> shortest-decimal -> f64 -> f32
        assert_eq!(back.sources[0].amps, t.sources[0].amps);
        assert_eq!(back.receivers.len(), 2);
        assert_eq!(back.receivers[0].pos, t.receivers[0].pos);
        assert_eq!(back.receivers[0].trace, t.receivers[0].trace);
        assert_eq!(back.receivers[1].trace, t.receivers[1].trace);
        match (&back.model, &t.model) {
            (VelocityModel::Layered(a), VelocityModel::Layered(b)) => assert_eq!(a, b),
            other => panic!("model variant changed in round trip: {other:?}"),
        }
    }

    #[test]
    fn model_json_roundtrips_all_variants() {
        let models = [
            VelocityModel::Constant(2500.0),
            VelocityModel::Layered(vec![(0.0, 1500.0), (0.45, 3200.0), (0.75, 4200.0)]),
            VelocityModel::GradientZ { v0: 1600.0, k_per_m: 0.4, h: 10.0 },
        ];
        for m in &models {
            let back = model_from_json(&model_to_json(m)).unwrap();
            match (m, &back) {
                (VelocityModel::Constant(a), VelocityModel::Constant(b)) => assert_eq!(a, b),
                (VelocityModel::Layered(a), VelocityModel::Layered(b)) => assert_eq!(a, b),
                (
                    VelocityModel::GradientZ { v0: a0, k_per_m: a1, h: a2 },
                    VelocityModel::GradientZ { v0: b0, k_per_m: b1, h: b2 },
                ) => {
                    assert_eq!(a0, b0);
                    assert_eq!(a1, b1);
                    assert_eq!(a2, b2);
                }
                other => panic!("variant changed: {other:?}"),
            }
        }
    }

    #[test]
    fn trace_rejects_bad_headers_and_records() {
        assert!(Trace::from_jsonl("").is_err());
        assert!(Trace::from_jsonl("{\"kind\":\"other\",\"version\":1}").is_err());
        let t = sample_trace();
        let versioned = t.to_jsonl().replacen("\"version\":1", "\"version\":9", 1);
        let err = Trace::from_jsonl(&versioned).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
        let bad_record = format!(
            "{}\n{{\"record\":\"mystery\"}}\n",
            t.to_jsonl().lines().next().unwrap()
        );
        assert!(Trace::from_jsonl(&bad_record).is_err());
    }

    #[test]
    fn max_trace_diff_reports_worst_sample_and_shape_errors() {
        let recorded = vec![TraceReceiver { pos: Dim3::new(1, 2, 3), trace: vec![0.0, 1.0, -1.0] }];
        let exact = vec![vec![0.0, 1.0, -1.0]];
        assert_eq!(max_trace_diff(&recorded, &exact).unwrap(), 0.0);
        let off = vec![vec![0.0, 1.5, -1.0]];
        assert_eq!(max_trace_diff(&recorded, &off).unwrap(), 0.5);
        assert!(max_trace_diff(&recorded, &[]).is_err());
        assert!(max_trace_diff(&recorded, &[vec![0.0]]).is_err());
    }
}
