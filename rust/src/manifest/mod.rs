//! Typed view of `artifacts/manifest.json` (written by `compile.aot`).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::grid::{Dim3, Domain};
use crate::json::Json;

/// One AOT artifact: an HLO-text executable plus its I/O signature.
#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub file: PathBuf,
    pub kind: String,         // "inner" | "pml" | "monolithic" | "fused"
    pub variant: String,      // kernel variant id
    pub region_class: String, // "inner" | face class | "full"
    pub input_shapes: Vec<(String, Dim3)>,
    pub output_shape: Dim3,
}

/// The manifest: problem spec + artifact index.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub domain: Domain,
    pub artifacts: Vec<Artifact>,
    by_name: HashMap<String, usize>,
    pub dir: PathBuf,
}

fn dim3_of(j: &Json) -> anyhow::Result<Dim3> {
    let a = j.as_arr()?;
    anyhow::ensure!(a.len() == 3, "expected 3-element shape, got {}", a.len());
    Ok(Dim3::new(a[0].as_usize()?, a[1].as_usize()?, a[2].as_usize()?))
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("cannot read {path:?} (run `make artifacts`?): {e}"))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (artifact files resolved relative to `dir`).
    pub fn parse(text: &str, dir: PathBuf) -> anyhow::Result<Manifest> {
        let j = Json::parse(text)?;
        let version = j.get("format_version")?.as_usize()?;
        anyhow::ensure!(version == 1, "unsupported manifest format_version {version}");

        let spec = j.get("spec")?;
        let interior = dim3_of(spec.get("interior")?)?;
        let halo = spec.get("halo")?.as_usize()?;
        anyhow::ensure!(halo == crate::R, "artifact halo {halo} != crate R {}", crate::R);
        let domain = Domain::new(
            interior,
            spec.get("pml_width")?.as_usize()?,
            spec.get("h")?.as_f64()?,
            spec.get("dt")?.as_f64()?,
        )?;

        let mut artifacts = Vec::new();
        for e in j.get("artifacts")?.as_arr()? {
            let mut input_shapes = Vec::new();
            for inp in e.get("inputs")?.as_arr()? {
                input_shapes.push((
                    inp.get("name")?.as_str()?.to_string(),
                    dim3_of(inp.get("shape")?)?,
                ));
            }
            artifacts.push(Artifact {
                name: e.get("name")?.as_str()?.to_string(),
                file: dir.join(e.get("file")?.as_str()?),
                kind: e.get("kind")?.as_str()?.to_string(),
                variant: e.get("variant")?.as_str()?.to_string(),
                region_class: e.get("region_class")?.as_str()?.to_string(),
                input_shapes,
                output_shape: dim3_of(e.get("output_shape")?)?,
            });
        }
        anyhow::ensure!(!artifacts.is_empty(), "manifest has no artifacts");
        let by_name = artifacts
            .iter()
            .enumerate()
            .map(|(i, a)| (a.name.clone(), i))
            .collect();
        Ok(Manifest { domain, artifacts, by_name, dir })
    }

    pub fn get(&self, name: &str) -> anyhow::Result<&Artifact> {
        self.by_name
            .get(name)
            .map(|&i| &self.artifacts[i])
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "artifact {name:?} not in manifest (have: {})",
                    self.names().join(", ")
                )
            })
    }

    pub fn names(&self) -> Vec<&str> {
        self.artifacts.iter().map(|a| a.name.as_str()).collect()
    }

    /// All inner-region kernel variants present.
    pub fn inner_variants(&self) -> Vec<&str> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == "inner")
            .map(|a| a.variant.as_str())
            .collect()
    }

    /// All PML variants present (deduplicated across face classes).
    pub fn pml_variants(&self) -> Vec<String> {
        let mut v: Vec<String> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == "pml")
            .map(|a| a.variant.clone())
            .collect();
        v.sort();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format_version": 1,
      "spec": {"interior": [48,48,48], "pml_width": 8, "h": 10.0, "dt": 0.001, "halo": 4},
      "artifacts": [
        {"name": "inner_gmem", "file": "inner_gmem.hlo.txt", "kind": "inner",
         "variant": "gmem", "region_class": "inner",
         "inputs": [{"name": "u_pad", "shape": [40,40,40]},
                    {"name": "um", "shape": [32,32,32]},
                    {"name": "v", "shape": [32,32,32]}],
         "output_shape": [32,32,32]},
        {"name": "pml_top_bottom_gmem", "file": "p.hlo.txt", "kind": "pml",
         "variant": "gmem", "region_class": "top_bottom",
         "inputs": [{"name": "u_pad1", "shape": [10,50,50]}],
         "output_shape": [8,48,48]}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.domain.interior, Dim3::new(48, 48, 48));
        assert_eq!(m.domain.pml_width, 8);
        assert_eq!(m.artifacts.len(), 2);
        let a = m.get("inner_gmem").unwrap();
        assert_eq!(a.input_shapes[0].1, Dim3::new(40, 40, 40));
        assert_eq!(a.output_shape, Dim3::new(32, 32, 32));
        assert_eq!(a.file, PathBuf::from("/tmp/a/inner_gmem.hlo.txt"));
        assert_eq!(m.inner_variants(), vec!["gmem"]);
        assert_eq!(m.pml_variants(), vec!["gmem".to_string()]);
    }

    #[test]
    fn missing_artifact_error_lists_names() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let err = m.get("nope").unwrap_err().to_string();
        assert!(err.contains("inner_gmem"), "{err}");
    }

    #[test]
    fn rejects_wrong_version_or_halo() {
        let bad = SAMPLE.replace("\"format_version\": 1", "\"format_version\": 9");
        assert!(Manifest::parse(&bad, PathBuf::from("/tmp")).is_err());
        let bad = SAMPLE.replace("\"halo\": 4", "\"halo\": 2");
        assert!(Manifest::parse(&bad, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn load_missing_dir_mentions_make_artifacts() {
        let err = Manifest::load("/nonexistent-dir-xyz").unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
