//! Prometheus text-format exposition (version 0.0.4): `# HELP` /
//! `# TYPE` headers per family, one sample line per series, histograms
//! expanded into cumulative `_bucket{le="..."}` lines plus `_sum` and
//! `_count`. The output is what `hostencil run --telemetry out.prom`
//! writes and what a future `hostencil serve` would return from
//! `/metrics`; `testkit::prom` parses it back for round-trip tests.

use std::fmt::Write as _;

use super::{Histogram, Registry, Value};

/// Render every registered family, in registration order.
pub fn render(reg: &Registry) -> String {
    let mut out = String::new();
    reg.with_families(|fams| {
        for fam in fams {
            let _ = writeln!(out, "# HELP {} {}", fam.name, escape_help(&fam.help));
            let _ = writeln!(out, "# TYPE {} {}", fam.name, fam.kind.name());
            for s in &fam.series {
                match &s.value {
                    Value::Counter(c) => {
                        let _ = writeln!(out, "{} {}", series_name(&fam.name, &s.labels), c.get());
                    }
                    Value::CounterFn(f) => {
                        let _ = writeln!(out, "{} {}", series_name(&fam.name, &s.labels), f());
                    }
                    Value::Gauge(g) => {
                        let _ = writeln!(out, "{} {}", series_name(&fam.name, &s.labels), g.get());
                    }
                    Value::GaugeFn(f) => {
                        let _ = writeln!(out, "{} {}", series_name(&fam.name, &s.labels), f());
                    }
                    Value::Histogram(h) => render_histogram(&mut out, &fam.name, &s.labels, h),
                }
            }
        }
    });
    out
}

fn render_histogram(out: &mut String, name: &str, labels: &[(String, String)], h: &Histogram) {
    let mut cum = 0u64;
    let counts = h.bucket_counts();
    for (i, &bound) in h.bounds().iter().enumerate() {
        cum += counts[i];
        let _ = writeln!(
            out,
            "{}_bucket{} {}",
            name,
            label_set(labels, Some(("le", &fmt_f64(bound)))),
            cum
        );
    }
    cum += counts[h.bounds().len()];
    let _ = writeln!(out, "{}_bucket{} {}", name, label_set(labels, Some(("le", "+Inf"))), cum);
    let _ = writeln!(out, "{}_sum{} {}", name, label_set(labels, None), fmt_f64(h.sum()));
    let _ = writeln!(out, "{}_count{} {}", name, label_set(labels, None), h.count());
}

/// `name` + rendered label set — the exposition sample name and the
/// key used by `Registry::snapshot_json`.
pub(crate) fn series_name(name: &str, labels: &[(String, String)]) -> String {
    format!("{}{}", name, label_set(labels, None))
}

fn label_set(labels: &[(String, String)], extra: Option<(&str, &str)>) -> String {
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{}=\"{}\"", k, escape_label(v)))
        .collect();
    if let Some((k, v)) = extra {
        parts.push(format!("{}=\"{}\"", k, escape_label(v)));
    }
    if parts.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", parts.join(","))
    }
}

/// `f64` as exposition text: `Display` is shortest-roundtrip and never
/// uses exponent notation, so the parser reads back the exact value.
fn fmt_f64(v: f64) -> String {
    if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else if v.is_nan() {
        "NaN".to_string()
    } else {
        format!("{v}")
    }
}

fn escape_help(s: &str) -> String {
    s.replace('\\', "\\\\").replace('\n', "\\n")
}

fn escape_label(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

#[cfg(test)]
mod tests {
    use super::super::Registry;

    #[test]
    fn renders_help_type_and_samples() {
        let reg = Registry::new();
        reg.counter("demo_steps_total", "Steps completed.").add(12);
        reg.gauge_with("demo_depth", "Queue depth.", &[("q", "a")]).set(-3);
        let text = reg.render();
        assert!(text.contains("# HELP demo_steps_total Steps completed."), "{text}");
        assert!(text.contains("# TYPE demo_steps_total counter"), "{text}");
        assert!(text.contains("\ndemo_steps_total 12\n"), "{text}");
        assert!(text.contains("demo_depth{q=\"a\"} -3"), "{text}");
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_end_at_inf() {
        let reg = Registry::new();
        let h = reg.histogram("demo_lat_seconds", "Latency.", &[0.001, 0.01]);
        h.observe(0.0005);
        h.observe(0.005);
        h.observe(0.005);
        h.observe(2.0);
        let text = reg.render();
        assert!(text.contains("demo_lat_seconds_bucket{le=\"0.001\"} 1"), "{text}");
        assert!(text.contains("demo_lat_seconds_bucket{le=\"0.01\"} 3"), "{text}");
        assert!(text.contains("demo_lat_seconds_bucket{le=\"+Inf\"} 4"), "{text}");
        assert!(text.contains("demo_lat_seconds_count 4"), "{text}");
        assert!(text.contains("demo_lat_seconds_sum 2.0105"), "{text}");
    }

    #[test]
    fn label_values_are_escaped() {
        let reg = Registry::new();
        reg.counter_with("demo_esc_total", "h", &[("path", "a\"b\\c")]).inc();
        let text = reg.render();
        assert!(text.contains("demo_esc_total{path=\"a\\\"b\\\\c\"} 1"), "{text}");
    }
}
