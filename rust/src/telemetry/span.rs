//! RAII phase spans: start one at the top of a phase (a tile sweep, a
//! coordinator batch, a campaign cell) and its elapsed seconds land in
//! the backing [`Histogram`](super::Histogram) when it drops — early
//! returns and `?` propagation included. The drop path is
//! allocation-free (one `Instant` read plus the histogram's atomics),
//! so spans are safe inside the zero-alloc steady state.

use std::time::Instant;

use super::Histogram;

/// A live phase timer; observes into its histogram on drop.
pub struct Span {
    hist: Histogram,
    start: Instant,
    armed: bool,
}

impl Span {
    pub(crate) fn new(hist: Histogram) -> Span {
        Span { hist, start: Instant::now(), armed: true }
    }

    /// Seconds elapsed so far (the span keeps running).
    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Drop without recording (e.g. a phase aborted by an error whose
    /// duration would poison the latency distribution).
    pub fn discard(mut self) {
        self.armed = false;
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if self.armed {
            self.hist.observe(self.start.elapsed().as_secs_f64());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::Registry;

    #[test]
    fn span_observes_on_drop() {
        let reg = Registry::new();
        let h = reg.histogram("t_span_seconds", "h", &[10.0]);
        {
            let _s = h.time();
        }
        assert_eq!(h.count(), 1);
        assert!(h.sum() >= 0.0 && h.sum() < 10.0);
    }

    #[test]
    fn discarded_span_records_nothing() {
        let reg = Registry::new();
        let h = reg.histogram("t_disc_seconds", "h", &[10.0]);
        let s = h.time();
        assert!(s.elapsed_secs() >= 0.0);
        s.discard();
        assert_eq!(h.count(), 0);
    }
}
