//! The flight recorder: an append-only JSONL event log for the rare,
//! high-signal moments of a run — plan builds/rebuilds, batch
//! boundaries, source injections gone silent, watchdog trips, worker
//! panics. One JSON object per line, every object carrying `event`
//! (the kind) and `t_ms` (milliseconds since the log was created), so
//! `jq`/`python -c 'json.loads(line)'` consume it directly.
//!
//! The log starts disabled (every `emit` is a cheap boolean check and
//! a no-op) and can be routed to an in-memory buffer (tests) or a
//! buffered file (`--events out.jsonl`) *in place* — all clones share
//! one sink, so the `EventLog` embedded in a
//! [`Registry`](super::Registry) at construction can be pointed at a
//! file later by the CLI.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

use crate::json::Json;

enum Sink {
    Off,
    Mem(Vec<String>),
    File(BufWriter<File>),
}

/// Shared handle to one event stream. `Clone` is an `Arc` bump.
#[derive(Clone)]
pub struct EventLog {
    sink: Arc<Mutex<Sink>>,
    /// Events lost to file-sink I/O errors (a full disk must not kill
    /// or silently lie to a multi-hour run — drops are *counted* and
    /// exported as `hostencil_events_dropped_total`).
    dropped: Arc<AtomicU64>,
    start: Instant,
}

impl EventLog {
    /// A log that drops everything (the default state).
    pub fn disabled() -> EventLog {
        EventLog {
            sink: Arc::new(Mutex::new(Sink::Off)),
            dropped: Arc::new(AtomicU64::new(0)),
            start: Instant::now(),
        }
    }

    /// A fresh log buffering lines in memory (tests, `--demo`).
    pub fn in_memory() -> EventLog {
        let log = EventLog::disabled();
        log.to_memory();
        log
    }

    fn lock(&self) -> MutexGuard<'_, Sink> {
        self.sink.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Route this log (and every clone of it) to an in-memory buffer.
    pub fn to_memory(&self) {
        *self.lock() = Sink::Mem(Vec::new());
    }

    /// Route this log (and every clone of it) to `path`, truncating.
    pub fn to_file(&self, path: &Path) -> anyhow::Result<()> {
        let f = File::create(path)
            .map_err(|e| anyhow::anyhow!("creating event log {}: {e}", path.display()))?;
        *self.lock() = Sink::File(BufWriter::new(f));
        Ok(())
    }

    /// Whether `emit` currently records anything. Callers assembling
    /// expensive event payloads should check this first; `emit` itself
    /// also no-ops when disabled.
    pub fn enabled(&self) -> bool {
        !matches!(*self.lock(), Sink::Off)
    }

    /// Append one event. `fields` are merged into the line next to the
    /// standard `event` and `t_ms` keys.
    pub fn emit(&self, event: &str, fields: &[(&str, Json)]) {
        let mut sink = self.lock();
        if matches!(*sink, Sink::Off) {
            return;
        }
        let mut o = BTreeMap::new();
        o.insert("event".to_string(), Json::Str(event.to_string()));
        o.insert(
            "t_ms".to_string(),
            Json::Num(self.start.elapsed().as_secs_f64() * 1e3),
        );
        for (k, v) in fields {
            o.insert((*k).to_string(), v.clone());
        }
        let line = Json::Obj(o).emit();
        match &mut *sink {
            Sink::Off => {}
            Sink::Mem(lines) => lines.push(line),
            Sink::File(w) => {
                // a failed write must neither kill the run nor vanish:
                // count the dropped event and keep going
                if writeln!(w, "{line}").is_err() {
                    self.dropped.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }

    /// Events lost to file-sink write/flush errors so far.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Shared drop counter, for registering an exposition collector.
    pub(crate) fn dropped_handle(&self) -> Arc<AtomicU64> {
        Arc::clone(&self.dropped)
    }

    /// Buffered lines (in-memory sink only; empty for off/file sinks).
    pub fn lines(&self) -> Vec<String> {
        match &*self.lock() {
            Sink::Mem(lines) => lines.clone(),
            _ => Vec::new(),
        }
    }

    /// Flush a file sink (no-op otherwise). Call before process exit;
    /// dropping the last clone also flushes via `BufWriter`'s drop. A
    /// failed flush counts one drop (the buffered tail may be lost)
    /// rather than erroring out of a finishing run.
    pub fn flush(&self) {
        if let Sink::File(w) = &mut *self.lock() {
            if w.flush().is_err() {
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

impl Default for EventLog {
    fn default() -> EventLog {
        EventLog::disabled()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_log_drops_everything() {
        let log = EventLog::disabled();
        assert!(!log.enabled());
        log.emit("plan_build", &[("family", Json::Str("naive".into()))]);
        assert!(log.lines().is_empty());
    }

    #[test]
    fn every_line_is_json_with_event_and_t_ms() {
        let log = EventLog::in_memory();
        assert!(log.enabled());
        log.emit("plan_build", &[("family", Json::Str("blocked3d".into()))]);
        log.emit(
            "batch",
            &[("steps", Json::Num(4.0)), ("injections", Json::Num(1.0))],
        );
        let lines = log.lines();
        assert_eq!(lines.len(), 2);
        for line in &lines {
            let j = Json::parse(line).expect("JSONL line parses");
            assert!(j.get("event").unwrap().as_str().is_ok(), "{line}");
            assert!(j.get("t_ms").unwrap().as_f64().unwrap() >= 0.0, "{line}");
        }
        assert_eq!(
            Json::parse(&lines[0]).unwrap().get("family").unwrap().as_str().unwrap(),
            "blocked3d"
        );
        assert_eq!(
            Json::parse(&lines[1]).unwrap().get("steps").unwrap().as_usize().unwrap(),
            4
        );
    }

    #[test]
    fn clones_share_one_sink_and_rerouting_applies_to_all() {
        let log = EventLog::disabled();
        let clone = log.clone();
        log.to_memory();
        clone.emit("watchdog_nonfinite", &[]);
        assert_eq!(log.lines().len(), 1, "clone writes must land in the shared sink");
    }

    #[test]
    #[cfg(target_os = "linux")]
    fn full_disk_counts_drops_instead_of_killing_the_run() {
        let log = EventLog::disabled();
        log.to_file(Path::new("/dev/full")).expect("open the always-full device");
        // enough payload to overflow the BufWriter and force real
        // writes; every failed write/flush must count, never panic
        let big = "x".repeat(4096);
        for _ in 0..8 {
            log.emit("spam", &[("pad", Json::Str(big.clone()))]);
        }
        log.flush();
        assert!(log.dropped() >= 1, "ENOSPC must be counted, got {}", log.dropped());
    }

    #[test]
    fn file_sink_writes_parseable_lines() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("hostencil_events_test_{}.jsonl", std::process::id()));
        let log = EventLog::disabled();
        log.to_file(&path).expect("temp file");
        log.emit("run_start", &[("steps", Json::Num(8.0))]);
        log.flush();
        let text = std::fs::read_to_string(&path).expect("read back");
        let _ = std::fs::remove_file(&path);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 1);
        let j = Json::parse(lines[0]).unwrap();
        assert_eq!(j.get("event").unwrap().as_str().unwrap(), "run_start");
    }
}
