//! Flight-recorder telemetry: a lock-light metrics registry with a
//! **zero-allocation steady state**, plus two exporters (Prometheus
//! text exposition in [`prometheus`], JSONL event log in [`events`])
//! and RAII phase timers in [`span`].
//!
//! Design contract, enforced by `rust/tests/zero_alloc.rs`:
//!
//! * **Registration allocates, observation never does.** Handles
//!   ([`Counter`], [`Gauge`], [`Histogram`]) are registered once at
//!   plan-build / warm-up time — that path takes the registry mutex
//!   and grows the family table. The hot path (`inc`/`add`/`set`/
//!   `observe`) touches only pre-`Arc`'d atomics and preallocated
//!   fixed-size buckets: no locks, no heap.
//! * **Registration is idempotent.** Re-registering the same
//!   `(name, labels)` returns the *existing* handle, so plan rebuilds
//!   and repeated runs keep accumulating into one series instead of
//!   shadowing it. Callback collectors ([`Registry::counter_fn`] /
//!   [`Registry::gauge_fn`]) instead *replace* the closure, so a
//!   rebuilt worker pool re-points its collectors at the live pool.
//! * **Reading is exporter business.** `render`/`snapshot_json` take
//!   the mutex and walk every series; they run at exit or on demand,
//!   never inside the time loop.
//!
//! The registry handle is `Clone` (an `Arc` bump) and threads through
//! `PropagatorInputs`/`FusedInputs`/`Plan`, so serial, pooled, and
//! fused execution paths instrument identically — and the future
//! `hostencil serve` daemon can expose [`Registry::render`] verbatim
//! at `/metrics`.

pub mod events;
pub mod prometheus;
pub mod span;

use std::fmt;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::json::Json;

pub use events::EventLog;
pub use span::Span;

/// Default log-scale latency bucket upper bounds (seconds): x4 per
/// bucket from 1 µs to ~4.2 s, 12 finite bounds plus the implicit
/// `+Inf` overflow bucket. Wide enough to hold one tile batch on a
/// laptop and a full campaign cell on a loaded CI runner.
pub const LATENCY_BOUNDS: [f64; 12] = [
    1e-6,
    4e-6,
    1.6e-5,
    6.4e-5,
    2.56e-4,
    1.024e-3,
    4.096e-3,
    1.6384e-2,
    6.5536e-2,
    2.62144e-1,
    1.048576,
    4.194304,
];

/// Prometheus metric kinds supported by the registry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Counter,
    Gauge,
    Histogram,
}

impl Kind {
    pub fn name(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Histogram => "histogram",
        }
    }
}

/// Monotonically increasing counter. Cloning shares the underlying
/// atomic; all operations are `Relaxed` (exporters only need eventual
/// consistency, the hot path needs zero contention).
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    fn new() -> Counter {
        Counter(Arc::new(AtomicU64::new(0)))
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous signed value (occupancy, queue depth, ...).
#[derive(Clone)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    fn new() -> Gauge {
        Gauge(Arc::new(AtomicI64::new(0)))
    }

    #[inline]
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

struct HistogramInner {
    /// Finite bucket upper bounds, ascending; the `buckets` vec has one
    /// extra trailing slot for the `+Inf` overflow bucket.
    bounds: Vec<f64>,
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// Sum of observed values as `f64` bits, updated by CAS (no
    /// `AtomicF64` in std; contention here is one CAS per observation).
    sum_bits: AtomicU64,
}

/// Fixed-bucket histogram: bounds chosen at registration, bins
/// preallocated, every observation a handful of relaxed atomic ops.
#[derive(Clone)]
pub struct Histogram(Arc<HistogramInner>);

impl Histogram {
    fn new(bounds: &[f64]) -> Histogram {
        assert!(!bounds.is_empty(), "histogram needs at least one bucket bound");
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]) && bounds.iter().all(|b| b.is_finite()),
            "histogram bounds must be finite and strictly ascending"
        );
        Histogram(Arc::new(HistogramInner {
            bounds: bounds.to_vec(),
            buckets: (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
        }))
    }

    /// Record one observation. Allocation-free: a linear scan over the
    /// (dozen-ish) preallocated bounds plus three relaxed atomic ops.
    #[inline]
    pub fn observe(&self, v: f64) {
        let h = &*self.0;
        let mut i = 0;
        while i < h.bounds.len() && v > h.bounds[i] {
            i += 1;
        }
        h.buckets[i].fetch_add(1, Ordering::Relaxed);
        h.count.fetch_add(1, Ordering::Relaxed);
        let mut old = h.sum_bits.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(old) + v).to_bits();
            match h.sum_bits.compare_exchange_weak(old, new, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(cur) => old = cur,
            }
        }
    }

    #[inline]
    pub fn observe_duration(&self, d: Duration) {
        self.observe(d.as_secs_f64());
    }

    /// Start an RAII span that observes its elapsed seconds on drop.
    pub fn time(&self) -> Span {
        Span::new(self.clone())
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.0.sum_bits.load(Ordering::Relaxed))
    }

    /// Finite bucket upper bounds (the `+Inf` bucket is implicit).
    pub fn bounds(&self) -> &[f64] {
        &self.0.bounds
    }

    /// Per-bucket (non-cumulative) counts; the final entry is the
    /// `+Inf` overflow bucket.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.0.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Upper bound of the bucket holding the q-quantile observation
    /// (`+Inf` overflow reports `f64::INFINITY`; empty histograms 0).
    /// Bucket-resolution only — good enough for demo snapshots and
    /// threshold tests, not for precise percentiles.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, b) in self.0.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= target {
                return self.0.bounds.get(i).copied().unwrap_or(f64::INFINITY);
            }
        }
        f64::INFINITY
    }
}

/// One registered series: a concrete handle or a callback collector
/// read at export time (used for stats owned elsewhere, e.g. the
/// worker pool's own atomics).
pub(crate) enum Value {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
    CounterFn(Box<dyn Fn() -> u64 + Send + Sync>),
    GaugeFn(Box<dyn Fn() -> i64 + Send + Sync>),
}

pub(crate) struct Series {
    pub(crate) labels: Vec<(String, String)>,
    pub(crate) value: Value,
}

pub(crate) struct Family {
    pub(crate) name: String,
    pub(crate) help: String,
    pub(crate) kind: Kind,
    pub(crate) series: Vec<Series>,
}

struct Inner {
    families: Mutex<Vec<Family>>,
    events: EventLog,
}

/// The metrics registry: cheaply clonable (`Arc` bump), safe to share
/// across worker threads, holding every registered family in
/// registration order plus the flight-recorder [`EventLog`].
#[derive(Clone)]
pub struct Registry(Arc<Inner>);

impl Registry {
    pub fn new() -> Registry {
        let reg = Registry(Arc::new(Inner {
            families: Mutex::new(Vec::new()),
            events: EventLog::disabled(),
        }));
        // Every registry exposes pool occupancy out of the box: the
        // gauge reads the process-global live-worker count, so the
        // exposition carries it even for runs that never build a pool.
        reg.gauge_fn(
            "hostencil_pool_workers",
            "Live persistent worker-pool threads (parked or running).",
            &[],
            || crate::runtime::pool::live_worker_threads() as i64,
        );
        // ... and the flight recorder's loss count: a full disk under
        // `--events` degrades to counted drops, and the count rides
        // every exposition so the degradation is visible.
        let dropped = reg.0.events.dropped_handle();
        reg.counter_fn(
            "hostencil_events_dropped_total",
            "Flight-recorder events lost to file-sink write errors (run kept alive).",
            &[],
            move || dropped.load(std::sync::atomic::Ordering::Relaxed),
        );
        reg
    }

    /// The flight-recorder event log riding along with this registry
    /// (disabled until routed to a sink; see [`EventLog`]).
    pub fn events(&self) -> &EventLog {
        &self.0.events
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Vec<Family>> {
        self.0.families.lock().unwrap_or_else(|p| p.into_inner())
    }

    pub(crate) fn with_families<R>(&self, f: impl FnOnce(&[Family]) -> R) -> R {
        let fams = self.lock();
        f(&fams)
    }

    fn family_index(fams: &mut Vec<Family>, name: &str, help: &str, kind: Kind) -> usize {
        match fams.iter().position(|f| f.name == name) {
            Some(i) => {
                assert_eq!(
                    fams[i].kind, kind,
                    "metric {name} re-registered as {:?}, originally {:?}",
                    kind, fams[i].kind
                );
                i
            }
            None => {
                fams.push(Family {
                    name: name.to_string(),
                    help: help.to_string(),
                    kind,
                    series: Vec::new(),
                });
                fams.len() - 1
            }
        }
    }

    fn handle<T>(
        &self,
        name: &str,
        help: &str,
        kind: Kind,
        labels: &[(&str, &str)],
        mk: impl FnOnce() -> Value,
        get: impl Fn(&Value) -> Option<T>,
    ) -> T {
        let mut fams = self.lock();
        let idx = Self::family_index(&mut fams, name, help, kind);
        let fam = &mut fams[idx];
        let owned: Vec<(String, String)> =
            labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        if let Some(s) = fam.series.iter().find(|s| s.labels == owned) {
            return get(&s.value).unwrap_or_else(|| {
                panic!("metric {name}: series re-registered with a different value shape")
            });
        }
        let value = mk();
        let out = get(&value).expect("freshly built value matches its own kind");
        fam.series.push(Series { labels: owned, value });
        out
    }

    fn collector(&self, name: &str, help: &str, kind: Kind, labels: &[(&str, &str)], value: Value) {
        let mut fams = self.lock();
        let idx = Self::family_index(&mut fams, name, help, kind);
        let fam = &mut fams[idx];
        let owned: Vec<(String, String)> =
            labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        if let Some(s) = fam.series.iter_mut().find(|s| s.labels == owned) {
            // collectors track a live source that may be rebuilt (a new
            // worker pool after a thread-count change): newest wins
            s.value = value;
        } else {
            fam.series.push(Series { labels: owned, value });
        }
    }

    pub fn counter(&self, name: &str, help: &str) -> Counter {
        self.counter_with(name, help, &[])
    }

    pub fn counter_with(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Counter {
        self.handle(name, help, Kind::Counter, labels, || Value::Counter(Counter::new()), |v| {
            match v {
                Value::Counter(c) => Some(c.clone()),
                _ => None,
            }
        })
    }

    pub fn gauge(&self, name: &str, help: &str) -> Gauge {
        self.gauge_with(name, help, &[])
    }

    pub fn gauge_with(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Gauge {
        self.handle(name, help, Kind::Gauge, labels, || Value::Gauge(Gauge::new()), |v| match v {
            Value::Gauge(g) => Some(g.clone()),
            _ => None,
        })
    }

    pub fn histogram(&self, name: &str, help: &str, bounds: &[f64]) -> Histogram {
        self.histogram_with(name, help, bounds, &[])
    }

    /// Register (or fetch) a histogram; `bounds` only apply on first
    /// registration — an existing series keeps its original buckets.
    pub fn histogram_with(
        &self,
        name: &str,
        help: &str,
        bounds: &[f64],
        labels: &[(&str, &str)],
    ) -> Histogram {
        self.handle(
            name,
            help,
            Kind::Histogram,
            labels,
            || Value::Histogram(Histogram::new(bounds)),
            |v| match v {
                Value::Histogram(h) => Some(h.clone()),
                _ => None,
            },
        )
    }

    /// Register a counter read from `f` at export time (for counts
    /// owned by another subsystem's atomics). Re-registering the same
    /// series replaces the closure.
    pub fn counter_fn(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        f: impl Fn() -> u64 + Send + Sync + 'static,
    ) {
        self.collector(name, help, Kind::Counter, labels, Value::CounterFn(Box::new(f)));
    }

    /// Register a gauge read from `f` at export time.
    pub fn gauge_fn(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        f: impl Fn() -> i64 + Send + Sync + 'static,
    ) {
        self.collector(name, help, Kind::Gauge, labels, Value::GaugeFn(Box::new(f)));
    }

    /// Prometheus text exposition of every registered series.
    pub fn render(&self) -> String {
        prometheus::render(self)
    }

    /// Flat JSON snapshot: `"name{k=\"v\"}"` -> number for counters and
    /// gauges, `{count, sum}` for histograms. Embedded in bench and
    /// campaign JSON reports.
    pub fn snapshot_json(&self) -> Json {
        let mut root = std::collections::BTreeMap::new();
        self.with_families(|fams| {
            for fam in fams {
                for s in &fam.series {
                    let key = prometheus::series_name(&fam.name, &s.labels);
                    let val = match &s.value {
                        Value::Counter(c) => Json::Num(c.get() as f64),
                        Value::CounterFn(f) => Json::Num(f() as f64),
                        Value::Gauge(g) => Json::Num(g.get() as f64),
                        Value::GaugeFn(f) => Json::Num(f() as f64),
                        Value::Histogram(h) => {
                            let mut o = std::collections::BTreeMap::new();
                            o.insert("count".to_string(), Json::Num(h.count() as f64));
                            let sum = h.sum();
                            o.insert(
                                "sum".to_string(),
                                if sum.is_finite() { Json::Num(sum) } else { Json::Null },
                            );
                            Json::Obj(o)
                        }
                    };
                    root.insert(key, val);
                }
            }
        });
        Json::Obj(root)
    }
}

impl Default for Registry {
    fn default() -> Registry {
        Registry::new()
    }
}

impl fmt::Debug for Registry {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let n = self.with_families(|fams| fams.len());
        f.debug_struct("Registry").field("families", &n).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_accumulate_through_shared_handles() {
        let reg = Registry::new();
        let c = reg.counter("t_total", "help");
        c.inc();
        c.add(4);
        // re-registration returns the same series
        let c2 = reg.counter("t_total", "help");
        c2.inc();
        assert_eq!(c.get(), 6);
        let g = reg.gauge("t_gauge", "help");
        g.set(7);
        g.add(-2);
        assert_eq!(reg.gauge("t_gauge", "help").get(), 5);
    }

    #[test]
    fn labeled_series_are_distinct() {
        let reg = Registry::new();
        let a = reg.counter_with("t_tiles_total", "h", &[("slot", "0")]);
        let b = reg.counter_with("t_tiles_total", "h", &[("slot", "1")]);
        a.add(3);
        b.add(5);
        assert_eq!(reg.counter_with("t_tiles_total", "h", &[("slot", "0")]).get(), 3);
        assert_eq!(reg.counter_with("t_tiles_total", "h", &[("slot", "1")]).get(), 5);
    }

    #[test]
    fn histogram_buckets_are_le_boundaries() {
        let reg = Registry::new();
        let h = reg.histogram("t_lat_seconds", "h", &[0.001, 0.01, 0.1]);
        // a value exactly on a bound lands in that bound's bucket (le)
        h.observe(0.001);
        h.observe(0.0005);
        h.observe(0.05);
        h.observe(1.0); // overflow
        assert_eq!(h.bucket_counts(), vec![2, 0, 1, 1]);
        assert_eq!(h.count(), 4);
        assert!((h.sum() - 1.0515).abs() < 1e-12, "{}", h.sum());
    }

    #[test]
    fn histogram_quantiles_resolve_to_bucket_bounds() {
        let reg = Registry::new();
        let h = reg.histogram("t_q_seconds", "h", &[0.001, 0.01, 0.1]);
        assert_eq!(h.quantile(0.5), 0.0, "empty histogram");
        for _ in 0..90 {
            h.observe(0.0005);
        }
        for _ in 0..9 {
            h.observe(0.05);
        }
        h.observe(5.0);
        assert_eq!(h.quantile(0.5), 0.001);
        assert_eq!(h.quantile(0.95), 0.1);
        assert_eq!(h.quantile(1.0), f64::INFINITY, "max lives in the +Inf bucket");
    }

    #[test]
    #[should_panic(expected = "re-registered")]
    fn kind_mismatch_panics() {
        let reg = Registry::new();
        reg.counter("t_conflict", "h");
        reg.gauge("t_conflict", "h");
    }

    #[test]
    fn collectors_read_live_and_replace_on_reregistration() {
        let reg = Registry::new();
        let src = Arc::new(AtomicU64::new(11));
        let s2 = src.clone();
        reg.counter_fn("t_live_total", "h", &[], move || s2.load(Ordering::Relaxed));
        let text = reg.render();
        assert!(text.contains("t_live_total 11"), "{text}");
        src.store(13, Ordering::Relaxed);
        assert!(reg.render().contains("t_live_total 13"));
        // a rebuilt source replaces the closure instead of stacking a dup
        reg.counter_fn("t_live_total", "h", &[], || 99);
        let text = reg.render();
        assert!(text.contains("t_live_total 99"), "{text}");
        assert_eq!(text.matches("t_live_total ").count(), 1, "{text}");
    }

    #[test]
    fn every_registry_carries_the_pool_occupancy_gauge() {
        let text = Registry::new().render();
        assert!(text.contains("# TYPE hostencil_pool_workers gauge"), "{text}");
    }

    #[test]
    fn every_registry_exposes_the_event_drop_counter() {
        let reg = Registry::new();
        let text = reg.render();
        assert!(text.contains("hostencil_events_dropped_total 0"), "{text}");
        // the collector reads the registry's own event log live
        #[cfg(target_os = "linux")]
        {
            reg.events().to_file(std::path::Path::new("/dev/full")).expect("always-full device");
            let big = crate::json::Json::Str("x".repeat(4096));
            for _ in 0..8 {
                reg.events().emit("spam", &[("pad", big.clone())]);
            }
            reg.events().flush();
            assert!(!reg.render().contains("hostencil_events_dropped_total 0"));
        }
    }

    #[test]
    fn snapshot_json_is_flat_and_emittable() {
        let reg = Registry::new();
        reg.counter_with("t_c_total", "h", &[("family", "naive")]).add(2);
        reg.histogram("t_h_seconds", "h", &[0.1]).observe(0.05);
        let j = reg.snapshot_json();
        assert_eq!(
            j.get("t_c_total{family=\"naive\"}").unwrap().as_usize().unwrap(),
            2
        );
        let h = j.get("t_h_seconds").unwrap();
        assert_eq!(h.get("count").unwrap().as_usize().unwrap(), 1);
        assert!(crate::json::Json::parse(&j.emit()).is_ok());
    }
}
