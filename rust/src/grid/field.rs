//! Dense f32 3D field with `(z, y, x)` row-major layout.

use super::Dim3;

/// A dense 3D scalar field. The workhorse container of the coordinator:
/// wavefields, velocity models, damping profiles, and region tiles are
/// all `Field3`s.
#[derive(Clone, Debug, PartialEq)]
pub struct Field3 {
    dims: Dim3,
    data: Vec<f32>,
}

impl Field3 {
    /// Zero-filled field.
    pub fn zeros(dims: Dim3) -> Self {
        Field3 { dims, data: vec![0.0; dims.volume()] }
    }

    /// Constant-filled field.
    pub fn full(dims: Dim3, value: f32) -> Self {
        Field3 { dims, data: vec![value; dims.volume()] }
    }

    /// Wrap an existing buffer (must match `dims.volume()`).
    pub fn from_vec(dims: Dim3, data: Vec<f32>) -> anyhow::Result<Self> {
        anyhow::ensure!(
            data.len() == dims.volume(),
            "buffer length {} != {} volume {}",
            data.len(),
            dims,
            dims.volume()
        );
        Ok(Field3 { dims, data })
    }

    /// Build from a closure over (z, y, x).
    pub fn from_fn(dims: Dim3, mut f: impl FnMut(usize, usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(dims.volume());
        for z in 0..dims.z {
            for y in 0..dims.y {
                for x in 0..dims.x {
                    data.push(f(z, y, x));
                }
            }
        }
        Field3 { dims, data }
    }

    pub fn dims(&self) -> Dim3 {
        self.dims
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    #[inline(always)]
    pub fn idx(&self, z: usize, y: usize, x: usize) -> usize {
        debug_assert!(z < self.dims.z && y < self.dims.y && x < self.dims.x);
        (z * self.dims.y + y) * self.dims.x + x
    }

    #[inline(always)]
    pub fn get(&self, z: usize, y: usize, x: usize) -> f32 {
        self.data[self.idx(z, y, x)]
    }

    #[inline(always)]
    pub fn set(&mut self, z: usize, y: usize, x: usize, v: f32) {
        let i = self.idx(z, y, x);
        self.data[i] = v;
    }

    #[inline(always)]
    pub fn add(&mut self, z: usize, y: usize, x: usize, v: f32) {
        let i = self.idx(z, y, x);
        self.data[i] += v;
    }

    /// Extract a sub-box `[offset, offset+shape)` (coordinates in this
    /// field's own index space).
    pub fn extract(&self, offset: Dim3, shape: Dim3) -> Field3 {
        assert!(
            offset.z + shape.z <= self.dims.z
                && offset.y + shape.y <= self.dims.y
                && offset.x + shape.x <= self.dims.x,
            "extract [{offset}+{shape}] out of bounds for {}",
            self.dims
        );
        let mut out = Vec::with_capacity(shape.volume());
        for z in 0..shape.z {
            for y in 0..shape.y {
                let base = self.idx(offset.z + z, offset.y + y, offset.x);
                out.extend_from_slice(&self.data[base..base + shape.x]);
            }
        }
        Field3 { dims: shape, data: out }
    }

    /// Extract `[offset-halo, offset+shape+halo)` where `offset` is in
    /// *interior* coordinates of an `R`-ghost-padded field. Mirrors
    /// `compile.model.slice_pad`.
    pub fn extract_padded_region(&self, ghost: usize, offset: Dim3, shape: Dim3, halo: usize) -> Field3 {
        let o = Dim3::new(
            ghost + offset.z - halo,
            ghost + offset.y - halo,
            ghost + offset.x - halo,
        );
        self.extract(o, shape.padded(halo))
    }

    /// Write `tile` into this field at `offset` (own index space).
    pub fn scatter(&mut self, offset: Dim3, tile: &Field3) {
        let s = tile.dims;
        assert!(
            offset.z + s.z <= self.dims.z
                && offset.y + s.y <= self.dims.y
                && offset.x + s.x <= self.dims.x,
            "scatter [{offset}+{s}] out of bounds for {}",
            self.dims
        );
        for z in 0..s.z {
            for y in 0..s.y {
                let src = tile.idx(z, y, 0);
                let dst = self.idx(offset.z + z, offset.y + y, offset.x);
                self.data[dst..dst + s.x].copy_from_slice(&tile.data[src..src + s.x]);
            }
        }
    }

    /// Embed an interior-sized field into a `halo`-ghost-padded field of
    /// zeros (the Dirichlet closure used by every wavefield array).
    pub fn pad(&self, halo: usize) -> Field3 {
        let mut out = Field3::zeros(self.dims.padded(halo));
        out.scatter(Dim3::new(halo, halo, halo), self);
        out
    }

    /// Strip a `halo`-wide border.
    pub fn unpad(&self, halo: usize) -> Field3 {
        let inner = Dim3::new(
            self.dims.z - 2 * halo,
            self.dims.y - 2 * halo,
            self.dims.x - 2 * halo,
        );
        self.extract(Dim3::new(halo, halo, halo), inner)
    }

    /// Sum of squares — the energy monitor's core.
    pub fn energy(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    /// Largest absolute value.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |a, &b| a.max(b.abs()))
    }

    /// True if any element is NaN or infinite.
    pub fn has_non_finite(&self) -> bool {
        self.data.iter().any(|v| !v.is_finite())
    }

    /// Borrowed read-only view (zero-copy kernel input).
    #[inline(always)]
    pub fn view(&self) -> FieldView<'_> {
        FieldView { dims: self.dims, data: &self.data }
    }

    /// Borrowed mutable view (zero-copy in-place kernel output).
    #[inline(always)]
    pub fn view_mut(&mut self) -> FieldViewMut<'_> {
        FieldViewMut { dims: self.dims, data: &mut self.data }
    }

    /// Max |a - b| over two same-shaped fields.
    pub fn max_abs_diff(&self, other: &Field3) -> f32 {
        assert_eq!(self.dims, other.dims, "shape mismatch");
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |a, (&x, &y)| a.max((x - y).abs()))
    }
}

/// Borrowed, read-only view of a `(z, y, x)` row-major buffer. The
/// zero-copy input type of the in-place stencil kernels: neighbors are
/// read straight out of the persistent padded arrays, and contiguous
/// x-runs come back as plain slices (`seg`/`row`) so inner loops index
/// bounds-check-free and auto-vectorize.
///
/// `Copy`: pass it by value; it is two words plus an extent.
#[derive(Copy, Clone)]
pub struct FieldView<'a> {
    dims: Dim3,
    data: &'a [f32],
}

impl<'a> FieldView<'a> {
    /// Wrap a raw buffer (must match `dims.volume()`).
    pub fn new(dims: Dim3, data: &'a [f32]) -> FieldView<'a> {
        assert_eq!(data.len(), dims.volume(), "view buffer length != {dims} volume");
        FieldView { dims, data }
    }

    #[inline(always)]
    pub fn dims(&self) -> Dim3 {
        self.dims
    }

    #[inline(always)]
    pub fn idx(&self, z: usize, y: usize, x: usize) -> usize {
        debug_assert!(z < self.dims.z && y < self.dims.y && x < self.dims.x);
        (z * self.dims.y + y) * self.dims.x + x
    }

    #[inline(always)]
    pub fn get(&self, z: usize, y: usize, x: usize) -> f32 {
        self.data[self.idx(z, y, x)]
    }

    /// Contiguous x-run of `len` points starting at `(z, y, x)`.
    #[inline(always)]
    pub fn seg(&self, z: usize, y: usize, x: usize, len: usize) -> &'a [f32] {
        debug_assert!(x + len <= self.dims.x, "segment overruns the x row");
        let b = (z * self.dims.y + y) * self.dims.x + x;
        &self.data[b..b + len]
    }

    /// Full contiguous x-row at `(z, y)`.
    #[inline(always)]
    pub fn row(&self, z: usize, y: usize) -> &'a [f32] {
        self.seg(z, y, 0, self.dims.x)
    }
}

/// Borrowed mutable view: the zero-copy output type of the in-place
/// kernels. Rows of the persistent padded output buffer are handed out
/// as `&mut [f32]` segments and overwritten in place — no tile
/// allocation, no scatter.
pub struct FieldViewMut<'a> {
    dims: Dim3,
    data: &'a mut [f32],
}

impl<'a> FieldViewMut<'a> {
    /// Wrap a raw buffer (must match `dims.volume()`).
    pub fn new(dims: Dim3, data: &'a mut [f32]) -> FieldViewMut<'a> {
        assert_eq!(data.len(), dims.volume(), "view buffer length != {dims} volume");
        FieldViewMut { dims, data }
    }

    #[inline(always)]
    pub fn dims(&self) -> Dim3 {
        self.dims
    }

    /// Reborrow as a read-only view.
    #[inline(always)]
    pub fn as_view(&self) -> FieldView<'_> {
        FieldView { dims: self.dims, data: self.data }
    }

    /// Mutable contiguous x-run of `len` points starting at `(z, y, x)`.
    #[inline(always)]
    pub fn seg_mut(&mut self, z: usize, y: usize, x: usize, len: usize) -> &mut [f32] {
        debug_assert!(z < self.dims.z && y < self.dims.y);
        debug_assert!(x + len <= self.dims.x, "segment overruns the x row");
        let b = (z * self.dims.y + y) * self.dims.x + x;
        &mut self.data[b..b + len]
    }

    /// Full mutable x-row at `(z, y)`.
    #[inline(always)]
    pub fn row_mut(&mut self, z: usize, y: usize) -> &mut [f32] {
        self.seg_mut(z, y, 0, self.dims.x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_is_row_major_x_innermost() {
        let f = Field3::from_fn(Dim3::new(2, 3, 4), |z, y, x| (z * 100 + y * 10 + x) as f32);
        assert_eq!(f.get(0, 0, 0), 0.0);
        assert_eq!(f.get(0, 0, 3), 3.0);
        assert_eq!(f.get(1, 2, 3), 123.0);
        assert_eq!(f.as_slice()[1], 1.0); // x is contiguous
        assert_eq!(f.as_slice()[4], 10.0); // then y
    }

    #[test]
    fn extract_scatter_roundtrip() {
        let f = Field3::from_fn(Dim3::new(6, 6, 6), |z, y, x| (z * 36 + y * 6 + x) as f32);
        let tile = f.extract(Dim3::new(1, 2, 3), Dim3::new(2, 3, 2));
        assert_eq!(tile.get(0, 0, 0), f.get(1, 2, 3));
        assert_eq!(tile.get(1, 2, 1), f.get(2, 4, 4));
        let mut g = Field3::zeros(Dim3::new(6, 6, 6));
        g.scatter(Dim3::new(1, 2, 3), &tile);
        assert_eq!(g.get(2, 4, 4), f.get(2, 4, 4));
        assert_eq!(g.get(0, 0, 0), 0.0);
    }

    #[test]
    fn pad_unpad_roundtrip() {
        let f = Field3::from_fn(Dim3::new(3, 3, 3), |z, y, x| (z + y + x) as f32 + 1.0);
        let p = f.pad(4);
        assert_eq!(p.dims(), Dim3::new(11, 11, 11));
        assert_eq!(p.get(0, 0, 0), 0.0);
        assert_eq!(p.get(4, 4, 4), 1.0);
        assert_eq!(p.unpad(4), f);
    }

    #[test]
    fn extract_padded_region_matches_manual() {
        // padded field with ghost 4; region offset (1,1,1), shape (2,2,2), halo 1
        let p = Field3::from_fn(Dim3::new(12, 12, 12), |z, y, x| (z * 144 + y * 12 + x) as f32);
        let t = p.extract_padded_region(4, Dim3::new(1, 1, 1), Dim3::new(2, 2, 2), 1);
        assert_eq!(t.dims(), Dim3::new(4, 4, 4));
        assert_eq!(t.get(0, 0, 0), p.get(4, 4, 4));
        assert_eq!(t.get(3, 3, 3), p.get(7, 7, 7));
    }

    #[test]
    fn energy_and_diff() {
        let a = Field3::full(Dim3::new(2, 2, 2), 2.0);
        let b = Field3::full(Dim3::new(2, 2, 2), 1.5);
        assert_eq!(a.energy(), 32.0);
        assert!((a.max_abs_diff(&b) - 0.5).abs() < 1e-7);
        assert_eq!(a.max_abs(), 2.0);
        assert!(!a.has_non_finite());
    }

    #[test]
    fn from_vec_validates_length() {
        assert!(Field3::from_vec(Dim3::new(2, 2, 2), vec![0.0; 7]).is_err());
        assert!(Field3::from_vec(Dim3::new(2, 2, 2), vec![0.0; 8]).is_ok());
    }

    #[test]
    #[should_panic]
    fn extract_out_of_bounds_panics() {
        let f = Field3::zeros(Dim3::new(2, 2, 2));
        f.extract(Dim3::new(1, 1, 1), Dim3::new(2, 2, 2));
    }

    #[test]
    fn views_expose_contiguous_rows_without_copying() {
        let f = Field3::from_fn(Dim3::new(3, 4, 5), |z, y, x| (z * 100 + y * 10 + x) as f32);
        let v = f.view();
        assert_eq!(v.dims(), f.dims());
        assert_eq!(v.get(2, 3, 4), f.get(2, 3, 4));
        assert_eq!(v.row(1, 2), &f.as_slice()[f.idx(1, 2, 0)..f.idx(1, 2, 0) + 5]);
        assert_eq!(v.seg(2, 1, 1, 3), &[211.0, 212.0, 213.0]);
        // the same segment re-read through the view is the same memory
        assert_eq!(v.seg(0, 0, 0, 5).as_ptr(), f.as_slice().as_ptr());
    }

    #[test]
    fn mutable_view_writes_through_to_the_field() {
        let mut f = Field3::zeros(Dim3::new(2, 3, 4));
        {
            let mut m = f.view_mut();
            m.seg_mut(1, 2, 1, 2).copy_from_slice(&[7.0, 8.0]);
            m.row_mut(0, 0)[3] = -1.0;
            assert_eq!(m.as_view().get(1, 2, 2), 8.0);
        }
        assert_eq!(f.get(1, 2, 1), 7.0);
        assert_eq!(f.get(1, 2, 2), 8.0);
        assert_eq!(f.get(0, 0, 3), -1.0);
    }

    #[test]
    #[should_panic]
    fn view_length_mismatch_panics() {
        FieldView::new(Dim3::new(2, 2, 2), &[0.0; 7]);
    }
}
