//! Grid substrate: 3D fields, domain geometry, and the paper's 7-region
//! decomposition (Fig. 1).
//!
//! Layout matches the Python side: arrays are row-major `(z, y, x)` with
//! x innermost/contiguous. Wavefields carry an `R`-wide ghost layer of
//! zeros on every face (Dirichlet closure); `um`/`v` are interior-sized.

mod decompose;
mod field;

pub use decompose::{decompose, Region, RegionClass};
pub use field::{Field3, FieldView, FieldViewMut};

use crate::R;

/// Integer 3D extent/coordinate in `(z, y, x)` order.
#[derive(Copy, Clone, PartialEq, Eq, Debug, Hash)]
pub struct Dim3 {
    pub z: usize,
    pub y: usize,
    pub x: usize,
}

impl Dim3 {
    pub const fn new(z: usize, y: usize, x: usize) -> Self {
        Dim3 { z, y, x }
    }

    /// Total number of points.
    pub fn volume(&self) -> usize {
        self.z * self.y * self.x
    }

    /// Grow every face by `halo` cells.
    pub fn padded(&self, halo: usize) -> Dim3 {
        Dim3::new(self.z + 2 * halo, self.y + 2 * halo, self.x + 2 * halo)
    }

    pub fn as_array(&self) -> [usize; 3] {
        [self.z, self.y, self.x]
    }
}

impl std::fmt::Display for Dim3 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}x{}", self.z, self.y, self.x)
    }
}

/// The simulation domain: interior (physical + PML sponge) geometry and
/// discretization constants. Mirrors `compile.common.ProblemSpec`.
#[derive(Copy, Clone, Debug, PartialEq)]
pub struct Domain {
    /// Interior extent (physical domain + PML), excluding ghost cells.
    pub interior: Dim3,
    /// PML sponge thickness on every face, in cells.
    pub pml_width: usize,
    /// Grid spacing in meters.
    pub h: f64,
    /// Time step in seconds.
    pub dt: f64,
}

impl Domain {
    pub fn new(interior: Dim3, pml_width: usize, h: f64, dt: f64) -> anyhow::Result<Self> {
        let d = Domain { interior, pml_width, h, dt };
        d.validate()?;
        Ok(d)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.pml_width >= 1, "pml_width must be >= 1");
        anyhow::ensure!(
            self.interior.z > 2 * self.pml_width
                && self.interior.y > 2 * self.pml_width
                && self.interior.x > 2 * self.pml_width,
            "interior {} too small for PML width {}",
            self.interior,
            self.pml_width
        );
        anyhow::ensure!(self.h > 0.0 && self.dt > 0.0, "h and dt must be positive");
        Ok(())
    }

    /// Extent of ghost-padded wavefield arrays.
    pub fn padded(&self) -> Dim3 {
        self.interior.padded(R)
    }

    /// Extent of the inner (non-PML) region.
    pub fn inner(&self) -> Dim3 {
        let w = self.pml_width;
        Dim3::new(
            self.interior.z - 2 * w,
            self.interior.y - 2 * w,
            self.interior.x - 2 * w,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dim3_volume_and_padding() {
        let d = Dim3::new(2, 3, 4);
        assert_eq!(d.volume(), 24);
        assert_eq!(d.padded(4), Dim3::new(10, 11, 12));
        assert_eq!(format!("{d}"), "2x3x4");
    }

    #[test]
    fn domain_shapes() {
        let d = Domain::new(Dim3::new(48, 40, 32), 8, 10.0, 1e-3).unwrap();
        assert_eq!(d.padded(), Dim3::new(56, 48, 40));
        assert_eq!(d.inner(), Dim3::new(32, 24, 16));
    }

    #[test]
    fn domain_rejects_thin_interior() {
        assert!(Domain::new(Dim3::new(16, 16, 16), 8, 10.0, 1e-3).is_err());
        assert!(Domain::new(Dim3::new(16, 16, 16), 0, 10.0, 1e-3).is_err());
        assert!(Domain::new(Dim3::new(32, 32, 32), 8, -1.0, 1e-3).is_err());
    }
}
