//! The paper's 7-region domain decomposition (Fig. 1).
//!
//! The domain is cut along the top and bottom of the inner region first
//! (z), then front/back (y), then left/right (x), yielding one inner
//! region and six PML face subregions in three symmetric shape classes.
//! Mirrors `compile.model.decompose` — keep in sync.

use super::{Dim3, Domain};

/// Which kernel family a region needs (inner 25-point vs PML 7-point),
/// and — for PML — which of the paper's three symmetric shape classes it
/// belongs to (Table III groups characteristics by these classes).
#[derive(Copy, Clone, PartialEq, Eq, Debug, Hash)]
pub enum RegionClass {
    Inner,
    TopBottom,
    FrontBack,
    LeftRight,
}

impl RegionClass {
    /// Manifest `region_class` string used in artifact names.
    pub fn key(&self) -> &'static str {
        match self {
            RegionClass::Inner => "inner",
            RegionClass::TopBottom => "top_bottom",
            RegionClass::FrontBack => "front_back",
            RegionClass::LeftRight => "left_right",
        }
    }

    pub fn is_pml(&self) -> bool {
        !matches!(self, RegionClass::Inner)
    }
}

/// One launch region, in interior coordinates.
#[derive(Clone, Debug, PartialEq)]
pub struct Region {
    pub name: &'static str,
    pub class: RegionClass,
    pub offset: Dim3,
    pub shape: Dim3,
}

impl Region {
    /// Stencil halo this region's kernel reads (R for inner, 1 for PML).
    pub fn halo(&self) -> usize {
        if self.class.is_pml() {
            crate::R_ETA
        } else {
            crate::R
        }
    }

    /// Split this region into at-most-`tile`-sized sub-boxes (the last
    /// tile on each axis is clipped, so non-tile-aligned extents are
    /// covered exactly). Name and class are inherited; offsets stay in
    /// interior coordinates. The CPU propagators fan these sub-regions
    /// over worker threads — the host-side analog of a kernel's block
    /// grid.
    pub fn split(&self, tile: Dim3) -> Vec<Region> {
        let (tz, ty, tx) = (tile.z.max(1), tile.y.max(1), tile.x.max(1));
        let mut out = Vec::new();
        for z0 in (0..self.shape.z).step_by(tz) {
            let sz = tz.min(self.shape.z - z0);
            for y0 in (0..self.shape.y).step_by(ty) {
                let sy = ty.min(self.shape.y - y0);
                for x0 in (0..self.shape.x).step_by(tx) {
                    let sx = tx.min(self.shape.x - x0);
                    out.push(Region {
                        name: self.name,
                        class: self.class,
                        offset: Dim3::new(
                            self.offset.z + z0,
                            self.offset.y + y0,
                            self.offset.x + x0,
                        ),
                        shape: Dim3::new(sz, sy, sx),
                    });
                }
            }
        }
        out
    }
}

/// Decompose the domain into the paper's 7 launch regions. The regions
/// partition the interior exactly (validated by property tests).
pub fn decompose(d: &Domain) -> Vec<Region> {
    let Dim3 { z: nz, y: ny, x: nx } = d.interior;
    let w = d.pml_width;
    vec![
        Region {
            name: "inner",
            class: RegionClass::Inner,
            offset: Dim3::new(w, w, w),
            shape: d.inner(),
        },
        Region {
            name: "top",
            class: RegionClass::TopBottom,
            offset: Dim3::new(0, 0, 0),
            shape: Dim3::new(w, ny, nx),
        },
        Region {
            name: "bottom",
            class: RegionClass::TopBottom,
            offset: Dim3::new(nz - w, 0, 0),
            shape: Dim3::new(w, ny, nx),
        },
        Region {
            name: "front",
            class: RegionClass::FrontBack,
            offset: Dim3::new(w, 0, 0),
            shape: Dim3::new(nz - 2 * w, w, nx),
        },
        Region {
            name: "back",
            class: RegionClass::FrontBack,
            offset: Dim3::new(w, ny - w, 0),
            shape: Dim3::new(nz - 2 * w, w, nx),
        },
        Region {
            name: "left",
            class: RegionClass::LeftRight,
            offset: Dim3::new(w, w, 0),
            shape: Dim3::new(nz - 2 * w, ny - 2 * w, w),
        },
        Region {
            name: "right",
            class: RegionClass::LeftRight,
            offset: Dim3::new(w, w, nx - w),
            shape: Dim3::new(nz - 2 * w, ny - 2 * w, w),
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn domain() -> Domain {
        Domain::new(Dim3::new(48, 40, 32), 8, 10.0, 1e-3).unwrap()
    }

    #[test]
    fn seven_regions_partition_interior() {
        let d = domain();
        let regs = decompose(&d);
        assert_eq!(regs.len(), 7);
        let mut cover = vec![0u8; d.interior.volume()];
        for r in &regs {
            for z in 0..r.shape.z {
                for y in 0..r.shape.y {
                    for x in 0..r.shape.x {
                        let i = ((r.offset.z + z) * d.interior.y + r.offset.y + y) * d.interior.x
                            + r.offset.x
                            + x;
                        cover[i] += 1;
                    }
                }
            }
        }
        assert!(cover.iter().all(|&c| c == 1), "regions must tile exactly once");
    }

    #[test]
    fn symmetric_pairs_share_shape() {
        let regs = decompose(&domain());
        let by_name: std::collections::HashMap<_, _> =
            regs.iter().map(|r| (r.name, r)).collect();
        assert_eq!(by_name["top"].shape, by_name["bottom"].shape);
        assert_eq!(by_name["front"].shape, by_name["back"].shape);
        assert_eq!(by_name["left"].shape, by_name["right"].shape);
    }

    #[test]
    fn halo_per_class() {
        let regs = decompose(&domain());
        for r in &regs {
            match r.class {
                RegionClass::Inner => assert_eq!(r.halo(), crate::R),
                _ => assert_eq!(r.halo(), crate::R_ETA),
            }
        }
    }

    #[test]
    fn split_covers_region_exactly_with_clipped_tiles() {
        let d = domain();
        for reg in decompose(&d) {
            // deliberately non-divisor tile extents
            let tiles = reg.split(Dim3::new(5, 7, 3));
            let mut cover = vec![0u8; reg.shape.volume()];
            for t in &tiles {
                assert_eq!(t.class, reg.class);
                assert!(t.shape.z <= 5 && t.shape.y <= 7 && t.shape.x <= 3);
                for z in 0..t.shape.z {
                    for y in 0..t.shape.y {
                        for x in 0..t.shape.x {
                            let (lz, ly, lx) = (
                                t.offset.z - reg.offset.z + z,
                                t.offset.y - reg.offset.y + y,
                                t.offset.x - reg.offset.x + x,
                            );
                            cover[(lz * reg.shape.y + ly) * reg.shape.x + lx] += 1;
                        }
                    }
                }
            }
            assert!(cover.iter().all(|&c| c == 1), "{}: tiles must partition", reg.name);
        }
    }

    #[test]
    fn split_with_oversized_tile_is_identity() {
        let d = domain();
        let inner = &decompose(&d)[0];
        let tiles = inner.split(Dim3::new(999, 999, 999));
        assert_eq!(tiles.len(), 1);
        assert_eq!(tiles[0].offset, inner.offset);
        assert_eq!(tiles[0].shape, inner.shape);
        // zero tile extents are clamped to 1 instead of looping forever
        let degenerate = inner.split(Dim3::new(0, 999, 999));
        assert_eq!(degenerate.len(), inner.shape.z);
    }

    #[test]
    fn class_keys_match_manifest_names() {
        assert_eq!(RegionClass::TopBottom.key(), "top_bottom");
        assert_eq!(RegionClass::Inner.key(), "inner");
        assert!(RegionClass::LeftRight.is_pml());
        assert!(!RegionClass::Inner.is_pml());
    }
}
