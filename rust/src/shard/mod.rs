//! Sharded domain decomposition with fused halo exchange.
//!
//! One domain is split into `shards` z-slabs. Each [`Shard`] owns a
//! **private** R-ghost-padded buffer pair covering its slab plus an
//! `s*R`-deep halo band on each interior seam (`s` = fusion degree),
//! its own velocity/eta extracts, and its own tile [`Plan`] +
//! `WorkerPool` — so shards place their working set NUMA-locally and
//! never touch a neighbour's memory on the hot path.
//!
//! **Deep halos buy communication avoidance.** A leapfrog sub-step
//! contaminates at most `R` planes inward from a cut edge (the 25-point
//! stencil reaches `R = 4` planes; the PML eta halo of 1 and the
//! velocity's own plane are inside that bound). With an `H = s*R` halo
//! a shard can advance `s` sub-steps *without any synchronization*:
//! after `j <= s` steps only planes closer than `j*R` to the cut edge
//! are stale, and the owned slab is still bit-exact — for both leapfrog
//! levels, since level n-1 is level n of the previous sub-step. Shards
//! therefore exchange halos only at `TimeFused` batch boundaries (every
//! `s` steps): fusion amortizes exchanges exactly like it amortizes
//! DRAM sweeps.
//!
//! **Bit-identity** with the unsharded golden oracle falls out of three
//! facts: (a) every point applies its *global* region class (PML vs
//! inner) via [`row_segments`] on global coordinates, so classification
//! is identical; (b) the per-row kernels are the same
//! [`inner_row`]/[`pml_row`] the whole engine uses; and (c) at the
//! global z-edges the local zero ghost frame *is* the true Dirichlet
//! ghost, while at cut seams every plane a frame-zero read could
//! influence is overwritten by the next exchange before anyone reads
//! it. `rust/tests/shard_equivalence.rs` asserts `max_abs_diff == 0.0`
//! against the unsharded coordinator across fuse degrees, odd grids,
//! and seam-straddling sources/PML.
//!
//! **Transport is abstract**: shards publish/collect opaque band
//! buffers through [`HaloTransport`], so the in-process
//! [`InProcessTransport`] (per-seam mailboxes: publish copies *out* of
//! the live field, collect copies *into* the halo — a double-buffer
//! that never blocks a publisher on a collector) can be swapped for a
//! multi-process or multi-node backend without touching the engine.
//!
//! Concurrency is two-level and budgeted: `split_shard_budget` divides
//! the global worker budget into `outer` shard-parallel slots × `inner`
//! tile threads per shard (product never exceeds the budget, so
//! `--shards N` cannot oversubscribe). The steady state allocates
//! nothing — see `rust/tests/zero_alloc_shard.rs`.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::fault::{
    FaultKind, FaultPlan, FaultSite, HALO_BACKOFF_BASE, HALO_DEADLINE, HALO_MAX_ATTEMPTS,
};
use crate::grid::{Dim3, Domain, Field3, Region, RegionClass};
use crate::recovery::fnv1a64_f32;
use crate::runtime::pool::WorkerPool;
use crate::stencil::propagator::Plan;
use crate::stencil::{inner_row, pml_row, row_segments, simd, Consts, SourceBatch};
use crate::telemetry::{Counter, Histogram, Registry, LATENCY_BOUNDS};
use crate::R;

/// z-depth of one shard-local tile task (full y/x rows per tile).
const SHARD_TILE_Z: usize = 4;

/// One shard's owned z-slab `[z0, z1)` in global interior coordinates.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct Slab {
    pub z0: usize,
    pub z1: usize,
}

/// Split `nz` interior z-planes into `shards` contiguous slabs (the
/// first `nz % shards` slabs take the remainder plane each).
///
/// Rejects decompositions the deep-halo protocol cannot honour: with
/// more than one shard, every slab must be at least `halo = s*R`
/// planes thick, so a seam neighbour's *owned* planes fully cover the
/// band its peers collect (and so `ze0 = z0 - halo` never crosses a
/// second seam).
pub fn plan_slabs(nz: usize, shards: usize, halo: usize) -> anyhow::Result<Vec<Slab>> {
    anyhow::ensure!(shards >= 1, "shard count must be >= 1, got {shards}");
    anyhow::ensure!(
        shards <= nz,
        "{shards} shards cannot split {nz} z-planes: at most one shard per plane"
    );
    let base = nz / shards;
    let extra = nz % shards;
    let mut out = Vec::with_capacity(shards);
    let mut z0 = 0;
    for i in 0..shards {
        let thick = base + usize::from(i < extra);
        if shards > 1 && thick < halo {
            anyhow::bail!(
                "shard {i} would own {thick} z-planes but the fused halo needs {halo} (s*R); \
                 use fewer shards, a lower fusion degree, or a deeper grid"
            );
        }
        out.push(Slab { z0, z1: z0 + thick });
        z0 += thick;
    }
    Ok(out)
}

/// Divide a global worker budget between the shard fan-out and each
/// shard's tile fan-out: `outer` shards advance concurrently, each on
/// `inner` tile threads, with `outer * inner <= budget.max(1)` — the
/// same contract as the campaign's job/tile split, so `--shards N`
/// never oversubscribes the machine.
pub fn split_shard_budget(budget: usize, shards: usize) -> (usize, usize) {
    let budget = budget.max(1);
    let outer = budget.min(shards.max(1));
    (outer, (budget / outer).max(1))
}

/// Which seam band of a shard a transport message refers to.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Side {
    /// The low-z end (toward shard `i - 1`).
    Low,
    /// The high-z end (toward shard `i + 1`).
    High,
}

/// Why one transport operation failed. Transport errors are
/// *retryable by contract*: the engine's bounded-retry loop re-invokes
/// the operation with exponential backoff, and only when the attempt
/// budget or the per-exchange deadline is exhausted does the failure
/// escalate into an [`ExchangeError`] (which the coordinator turns
/// into a checkpoint + `SoftAbort`, never a panic).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportError {
    /// The band is not available right now (peer not yet published,
    /// connection lost, injected drop) — a retry may heal it.
    Unavailable(&'static str),
    /// The band arrived but is known-bad at the transport layer.
    Corrupt(&'static str),
}

impl TransportError {
    pub fn detail(self) -> &'static str {
        match self {
            TransportError::Unavailable(s) | TransportError::Corrupt(s) => s,
        }
    }
}

/// Publisher-computed checksums of one posted band: FNV-1a 64 over
/// each leapfrog level's f32 bit stream. The engine verifies collected
/// bytes against these *before* unpacking into the halo, so a band
/// corrupted in flight is detected and re-collected — never silently
/// applied to the wavefield.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BandCheck {
    pub u: u64,
    pub um: u64,
}

/// A halo exchange that could not be completed within its retry
/// budget: which seam operation failed, after how many attempts, and
/// why. Surfaced from [`ShardedEngine::advance_batch`]; the global
/// padded buffers still hold the pre-batch state (the failed batch is
/// never gathered), so the caller can checkpoint and soft-abort with
/// restorable state.
#[derive(Clone, Debug)]
pub struct ExchangeError {
    pub shard: usize,
    pub side: Side,
    pub attempts: u32,
    pub detail: String,
}

impl fmt::Display for ExchangeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "halo exchange failed at shard {} side {:?} after {} attempt(s): {}",
            self.shard, self.side, self.attempts, self.detail
        )
    }
}

impl std::error::Error for ExchangeError {}

/// The halo-exchange backend. Shards talk only in terms of opaque band
/// buffers (`halo * ny * nx` floats per leapfrog level), so an
/// implementation may live in-process, cross-process, or cross-node.
///
/// Contract: `publish(i, side, ...)` posts shard `i`'s *owned* band on
/// that side; `collect(i, side, ...)` fills shard `i`'s *halo* on that
/// side from the neighbour's published owned band and returns the
/// *publisher's* [`BandCheck`] checksums for end-to-end verification.
/// Both operations are fallible and retry-safe: a retried `collect`
/// must re-read the current mailbox, a retried `publish` must
/// overwrite the previous attempt. The engine barrier-separates the
/// publish and collect phases of a batch boundary, so a transport
/// never sees a collect race a publish of the same exchange round.
pub trait HaloTransport: Send + Sync {
    fn publish(&self, from: usize, side: Side, u: &[f32], um: &[f32])
        -> Result<(), TransportError>;
    fn collect(
        &self,
        to: usize,
        side: Side,
        u: &mut [f32],
        um: &mut [f32],
    ) -> Result<BandCheck, TransportError>;
}

/// One posted band: both leapfrog levels of one shard's owned seam
/// planes, plus the publisher-side checksums. Preallocated at
/// construction — steady-state exchanges only `copy_from_slice` and
/// hash under a short mutex hold.
struct Band {
    u: Vec<f32>,
    um: Vec<f32>,
    check: BandCheck,
}

/// The in-process transport: a mailbox per (shard, side). Publishing
/// copies the live field *out* into the mailbox and collecting copies
/// the mailbox *into* the halo — double-buffering that keeps
/// publishers and collectors off each other's live buffers. Mutexes
/// (not channels) keep the steady state allocation-free.
pub struct InProcessTransport {
    /// `bands[i][0]` = shard i's published Low band, `[1]` = High.
    bands: Vec<[Mutex<Band>; 2]>,
}

impl InProcessTransport {
    pub fn new(shards: usize, band_len: usize) -> InProcessTransport {
        let zero_sum = fnv1a64_f32(&vec![0.0; band_len]);
        let mk = || {
            Mutex::new(Band {
                u: vec![0.0; band_len],
                um: vec![0.0; band_len],
                check: BandCheck { u: zero_sum, um: zero_sum },
            })
        };
        InProcessTransport { bands: (0..shards).map(|_| [mk(), mk()]).collect() }
    }
}

fn side_idx(side: Side) -> usize {
    match side {
        Side::Low => 0,
        Side::High => 1,
    }
}

impl HaloTransport for InProcessTransport {
    fn publish(&self, from: usize, side: Side, u: &[f32], um: &[f32])
        -> Result<(), TransportError> {
        let mut b = self.bands[from][side_idx(side)]
            .lock()
            .unwrap_or_else(|p| p.into_inner());
        b.u.copy_from_slice(u);
        b.um.copy_from_slice(um);
        b.check = BandCheck { u: fnv1a64_f32(u), um: fnv1a64_f32(um) };
        Ok(())
    }

    fn collect(
        &self,
        to: usize,
        side: Side,
        u: &mut [f32],
        um: &mut [f32],
    ) -> Result<BandCheck, TransportError> {
        // shard `to`'s Low halo is its low neighbour's owned High band
        // (and vice versa): the seam is shared, the roles are mirrored
        let (nbr, nbr_side) = match side {
            Side::Low => (to - 1, Side::High),
            Side::High => (to + 1, Side::Low),
        };
        let b = self.bands[nbr][side_idx(nbr_side)]
            .lock()
            .unwrap_or_else(|p| p.into_inner());
        u.copy_from_slice(&b.u);
        um.copy_from_slice(&b.um);
        Ok(b.check)
    }
}

/// A chaos decorator around any [`HaloTransport`]: consults the fault
/// plan at the halo site on every collect and injects a dropped band
/// (one `Unavailable` the retry heals), a stall (sleeps past
/// [`HALO_DEADLINE`] then fails, deterministically exercising the
/// timeout escalation), or transient wire corruption (flips one bit of
/// the *collected* copy — the publisher's mailbox stays clean, so the
/// checksum catches it and the retry re-reads a good band). Publish
/// passes straight through. Installed by
/// [`ShardedEngine::set_faults`]; absent a fault plan the engine uses
/// the inner transport directly at zero cost.
pub struct FaultyTransport {
    inner: Box<dyn HaloTransport>,
    faults: Arc<FaultPlan>,
    /// How long an injected `halo:delay` stalls — always past the
    /// engine's per-exchange deadline, so the timeout path is
    /// exercised deterministically (`fault::HALO_STALL` at defaults).
    stall: Duration,
}

impl FaultyTransport {
    pub fn new(
        inner: Box<dyn HaloTransport>,
        faults: Arc<FaultPlan>,
        stall: Duration,
    ) -> FaultyTransport {
        FaultyTransport { inner, faults, stall }
    }
}

impl HaloTransport for FaultyTransport {
    fn publish(&self, from: usize, side: Side, u: &[f32], um: &[f32])
        -> Result<(), TransportError> {
        self.inner.publish(from, side, u, um)
    }

    fn collect(
        &self,
        to: usize,
        side: Side,
        u: &mut [f32],
        um: &mut [f32],
    ) -> Result<BandCheck, TransportError> {
        if self.faults.fire(FaultSite::Halo, FaultKind::Drop) {
            return Err(TransportError::Unavailable("injected fault: band dropped"));
        }
        if self.faults.fire(FaultSite::Halo, FaultKind::Delay) {
            std::thread::sleep(self.stall);
            return Err(TransportError::Unavailable("injected fault: transport stalled"));
        }
        let check = self.inner.collect(to, side, u, um)?;
        if self.faults.fire(FaultSite::Halo, FaultKind::Corrupt) {
            let mid = u.len() / 2;
            if let Some(x) = u.get_mut(mid) {
                *x = f32::from_bits(x.to_bits() ^ 0x1);
            }
        }
        Ok(check)
    }
}

/// Placeholder transport while the real one is being wrapped by
/// `set_faults`; never reachable on an exchange path.
struct DisconnectedTransport;

impl HaloTransport for DisconnectedTransport {
    fn publish(&self, _: usize, _: Side, _: &[f32], _: &[f32]) -> Result<(), TransportError> {
        Err(TransportError::Unavailable("transport disconnected"))
    }

    fn collect(
        &self,
        _: usize,
        _: Side,
        _: &mut [f32],
        _: &mut [f32],
    ) -> Result<BandCheck, TransportError> {
        Err(TransportError::Unavailable("transport disconnected"))
    }
}

/// One z-slab of the domain: private padded buffer pair over the
/// extended range `[ze0, ze1) = [z0 - H, z1 + H)` (clamped to the
/// grid), local velocity/eta extracts, a private tile plan, and the
/// preallocated pack/unpack staging for one seam band.
struct Shard {
    /// Owned slab `[z0, z1)` in global interior z.
    z0: usize,
    z1: usize,
    /// Extended (owned + halo) range `[ze0, ze1)` in global interior z.
    ze0: usize,
    ze1: usize,
    /// Extended interior shape: `(ze1 - ze0, ny, nx)`.
    ext: Dim3,
    /// R-ghost-padded leapfrog pair over the extended range. The ghost
    /// frame stays zero: at global edges it *is* the Dirichlet ghost,
    /// at cut seams every value it could influence is overwritten by
    /// the next halo exchange before the owned slab can read it.
    u: Field3,
    um: Field3,
    /// Velocity extract over the extended range (interior-shaped).
    v: Field3,
    /// Eta extract over the extended range, R-padded like the global
    /// `eta_pad` (the PML kernel reads a 1-deep eta halo).
    eta_pad: Field3,
    /// Private tile plan (own `WorkerPool` for `inner >= 2`).
    plan: Option<Plan<()>>,
    /// Seam-band staging, `halo * ny * nx` floats per level.
    band_u: Vec<f32>,
    band_um: Vec<f32>,
    /// Error slot for the exchange phases: the phase closures cannot
    /// return values through the pool fan-out, so a failed seam
    /// operation parks its [`ExchangeError`] here and `advance_batch`
    /// scans the slots after each phase barrier. `None` in steady
    /// state (the happy path never writes it).
    fail: Option<ExchangeError>,
}

impl Shard {
    /// Advance one leapfrog sub-step over the **whole extended range**
    /// in place, swap the pair, then apply sub-step `j`'s source
    /// injections that land in this shard's extended range.
    ///
    /// Every row applies its global region class: `gz = ze0 + lz` and
    /// `gy = ly` (y/x are not sharded) feed [`row_segments`] on the
    /// *global* domain, so per-point classification — and therefore
    /// arithmetic — is bit-identical to the unsharded sweep.
    fn advance_sub(&mut self, gd: &Domain, k: Consts, batch: &SourceBatch, j: usize) {
        let Shard { u, um, v, eta_pad, plan, ze0, ze1, .. } = self;
        let (ze0, ze1) = (*ze0, *ze1);
        let uv = u.view();
        let vv = v.view();
        let ev = eta_pad.view();
        let plan = plan.as_mut().expect("plan is built in ShardedEngine::new");
        plan.run_into(um, |t, _s, out| {
            for dz in 0..t.shape.z {
                let lz = t.offset.z + dz;
                let gz = ze0 + lz;
                for dy in 0..t.shape.y {
                    let ly = t.offset.y + dy;
                    for (x0, len, inner) in row_segments(gd, gz, ly) {
                        if len == 0 {
                            continue;
                        }
                        // SAFETY: tile tasks cover disjoint z-ranges,
                        // so each padded output row is written by
                        // exactly one worker
                        let row = unsafe { out.seg_mut(lz + R, ly + R, x0 + R, len) };
                        if inner {
                            inner_row(uv, vv, lz, ly, x0, len, k, row);
                        } else {
                            pml_row(uv, vv, ev, lz, ly, x0, len, k, row);
                        }
                    }
                }
            }
        });
        std::mem::swap(u, um);
        // inject *after* the swap (u now holds step n+1), mirroring the
        // coordinator/fused schedule: sub-step j applies amps row j.
        // Halo-plane injections keep those planes in lockstep with the
        // owner's computation; they are overwritten at the exchange
        // anyway, but owned planes within R of a seam read them first.
        for (i, p) in batch.positions.iter().enumerate() {
            if p.z >= ze0 && p.z < ze1 {
                u.add(R + (p.z - ze0), R + p.y, R + p.x, batch.amp(j, i));
            }
        }
    }

    /// Copy this shard's **owned** seam band (`halo` planes at `side`)
    /// into the preallocated staging buffers.
    fn pack(&mut self, side: Side, halo: usize) {
        let g0 = match side {
            Side::Low => self.z0,
            Side::High => self.z1 - halo,
        };
        let (ny, nx) = (self.ext.y, self.ext.x);
        for d in 0..halo {
            let lz = g0 + d - self.ze0;
            for y in 0..ny {
                let o = (d * ny + y) * nx;
                self.band_u[o..o + nx].copy_from_slice(self.u.view().seg(lz + R, y + R, R, nx));
                self.band_um[o..o + nx]
                    .copy_from_slice(self.um.view().seg(lz + R, y + R, R, nx));
            }
        }
    }

    /// Overwrite this shard's **halo** planes at `side` from the
    /// staging buffers (collected from the seam neighbour).
    fn unpack(&mut self, side: Side, halo: usize) {
        let g0 = match side {
            Side::Low => self.ze0,
            Side::High => self.z1,
        };
        let (ny, nx) = (self.ext.y, self.ext.x);
        for d in 0..halo {
            let lz = g0 + d - self.ze0;
            for y in 0..ny {
                let o = (d * ny + y) * nx;
                self.u
                    .view_mut()
                    .seg_mut(lz + R, y + R, R, nx)
                    .copy_from_slice(&self.band_u[o..o + nx]);
                self.um
                    .view_mut()
                    .seg_mut(lz + R, y + R, R, nx)
                    .copy_from_slice(&self.band_um[o..o + nx]);
            }
        }
    }

    /// Load both levels of the extended range from global padded
    /// buffers (ghost frames stay zero on both sides).
    fn load(&mut self, u_pad: &Field3, um_pad: &Field3) {
        let (ny, nx) = (self.ext.y, self.ext.x);
        for lz in 0..self.ext.z {
            let gz = self.ze0 + lz;
            for y in 0..ny {
                self.u
                    .view_mut()
                    .seg_mut(lz + R, y + R, R, nx)
                    .copy_from_slice(u_pad.view().seg(gz + R, y + R, R, nx));
                self.um
                    .view_mut()
                    .seg_mut(lz + R, y + R, R, nx)
                    .copy_from_slice(um_pad.view().seg(gz + R, y + R, R, nx));
            }
        }
    }

    /// Scatter the **owned** slab (both levels) back into global
    /// padded buffers.
    fn store_owned(&self, u_pad: &mut Field3, um_pad: &mut Field3) {
        let (ny, nx) = (self.ext.y, self.ext.x);
        for gz in self.z0..self.z1 {
            let lz = gz - self.ze0;
            for y in 0..ny {
                u_pad
                    .view_mut()
                    .seg_mut(gz + R, y + R, R, nx)
                    .copy_from_slice(self.u.view().seg(lz + R, y + R, R, nx));
                um_pad
                    .view_mut()
                    .seg_mut(gz + R, y + R, R, nx)
                    .copy_from_slice(self.um.view().seg(lz + R, y + R, R, nx));
            }
        }
    }
}

#[allow(dead_code)]
fn assert_shard_is_send() {
    fn needs_send<T: Send>() {}
    needs_send::<Shard>();
}

/// Hand-rolled disjoint-slot access for the shard fan-out (the plan
/// executor's equivalent wrapper is private to `propagator`).
struct ShardSlots {
    ptr: *mut Shard,
    len: usize,
}

// SAFETY: indices are handed out by an atomic cursor that gives each
// shard to exactly one worker per phase, and `Shard: Send` (asserted
// above), so moving the &mut access across threads is sound.
unsafe impl Sync for ShardSlots {}

impl ShardSlots {
    fn new(shards: &mut [Shard]) -> ShardSlots {
        ShardSlots { ptr: shards.as_mut_ptr(), len: shards.len() }
    }

    /// SAFETY: caller must hand each index to exactly one worker per
    /// phase (the atomic-cursor claim loop below).
    #[allow(clippy::mut_from_ref)]
    unsafe fn get(&self, i: usize) -> &mut Shard {
        debug_assert!(i < self.len);
        &mut *self.ptr.add(i)
    }
}

/// Fan `f(i, shard)` over the shards: serially without an outer pool,
/// else via an atomic-cursor claim loop on the persistent pool (the
/// same zero-alloc release/claim protocol the tile executor uses).
fn run_phase(
    pool: &mut Option<WorkerPool>,
    shards: &mut [Shard],
    f: impl Fn(usize, &mut Shard) + Sync,
) {
    match pool {
        Some(p) if shards.len() > 1 => {
            let slots = ShardSlots::new(shards);
            let n = slots.len;
            let cursor = AtomicUsize::new(0);
            p.run(&|_slot| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                // SAFETY: the cursor hands index i to exactly one
                // worker, so this &mut Shard aliases nothing
                f(i, unsafe { slots.get(i) });
            });
        }
        _ => {
            for (i, sh) in shards.iter_mut().enumerate() {
                f(i, sh);
            }
        }
    }
}

/// Halo-exchange instrumentation (registered once at engine build;
/// steady-state updates are atomic bumps and histogram observes).
struct ShardInstr {
    exchanges: Counter,
    bytes: Counter,
    latency: Histogram,
    retries: Counter,
}

/// Run one transport operation under the bounded-retry protocol:
/// exponential backoff between attempts, giving up when the attempt
/// budget ([`HALO_MAX_ATTEMPTS`]) or the per-exchange `deadline` is
/// exhausted — whichever comes first. The happy path is one call and
/// no allocation; the error string only materializes on escalation.
fn with_retry(
    shard: usize,
    side: Side,
    deadline: Duration,
    retries: Option<&Counter>,
    mut op: impl FnMut() -> Result<(), &'static str>,
) -> Result<(), ExchangeError> {
    let start = Instant::now();
    let mut attempt = 0u32;
    loop {
        attempt += 1;
        let detail = match op() {
            Ok(()) => return Ok(()),
            Err(d) => d,
        };
        if attempt >= HALO_MAX_ATTEMPTS || start.elapsed() >= deadline {
            return Err(ExchangeError { shard, side, attempts: attempt, detail: detail.to_string() });
        }
        if let Some(r) = retries {
            r.inc();
        }
        std::thread::sleep(HALO_BACKOFF_BASE * (1 << (attempt - 1)));
    }
}

/// The sharded propagation engine: per-shard buffers/plans/pools plus
/// a transport, advancing whole fused batches between exchanges.
pub struct ShardedEngine {
    domain: Domain,
    fuse: usize,
    halo: usize,
    outer: usize,
    inner: usize,
    shards: Vec<Shard>,
    transport: Box<dyn HaloTransport>,
    pool: Option<WorkerPool>,
    instr: Option<ShardInstr>,
    /// Per-exchange deadline for the retry loop (tests shrink it).
    deadline: Duration,
}

impl ShardedEngine {
    /// Build the engine: plan slabs, extract per-shard model fields,
    /// build per-shard plans (family `"shard"`), split the worker
    /// budget, and wire the in-process transport.
    ///
    /// `v` and `eta` are the interior-shaped velocity model and damping
    /// profile; `threads` is the *global* worker budget (0 = all
    /// cores); `fuse` fixes the halo depth `s*R` and the exchange
    /// cadence (batches of up to `fuse` steps).
    pub fn new(
        domain: &Domain,
        v: &Field3,
        eta: &Field3,
        fuse: usize,
        shards: usize,
        threads: usize,
        telemetry: Option<&Registry>,
    ) -> anyhow::Result<ShardedEngine> {
        anyhow::ensure!(fuse >= 1, "fusion degree must be >= 1, got {fuse}");
        let interior = domain.interior;
        assert_eq!(v.dims(), interior, "velocity model must be interior-shaped");
        assert_eq!(eta.dims(), interior, "eta profile must be interior-shaped");
        let halo = fuse * R;
        let slabs = plan_slabs(interior.z, shards, halo)?;
        let budget = if threads > 0 {
            threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        };
        let (outer, inner) = split_shard_budget(budget, slabs.len());
        let band_len = if slabs.len() > 1 { halo * interior.y * interior.x } else { 0 };
        let mut shard_v = Vec::with_capacity(slabs.len());
        for sl in &slabs {
            let ze0 = sl.z0.saturating_sub(halo);
            let ze1 = (sl.z1 + halo).min(interior.z);
            let ext = Dim3::new(ze1 - ze0, interior.y, interior.x);
            let local =
                Domain { interior: ext, pml_width: domain.pml_width, h: domain.h, dt: domain.dt };
            let mut sh = Shard {
                z0: sl.z0,
                z1: sl.z1,
                ze0,
                ze1,
                ext,
                u: Field3::zeros(local.padded()),
                um: Field3::zeros(local.padded()),
                v: v.extract(Dim3::new(ze0, 0, 0), ext),
                eta_pad: eta.extract(Dim3::new(ze0, 0, 0), ext).pad(R),
                plan: None,
                band_u: vec![0.0; band_len],
                band_um: vec![0.0; band_len],
                fail: None,
            };
            Plan::ensure(&mut sh.plan, &local, inner, "shard", telemetry, shard_tiles, |_| ());
            shard_v.push(sh);
        }
        let pool = if outer > 1 { Some(WorkerPool::new(outer)) } else { None };
        if let (Some(p), Some(reg)) = (&pool, telemetry) {
            p.register_telemetry(reg);
        }
        let instr = telemetry.map(|reg| ShardInstr {
            exchanges: reg.counter(
                "hostencil_halo_exchanges_total",
                "Halo-exchange rounds completed (one per shard seam per batch boundary).",
            ),
            bytes: reg.counter(
                "hostencil_halo_bytes_total",
                "Bytes of seam-band data moved through the halo transport (both leapfrog levels, both directions).",
            ),
            latency: reg.histogram(
                "hostencil_halo_exchange_latency_seconds",
                "Wall-clock latency of one batch-boundary halo exchange (publish + collect, all seams).",
                &LATENCY_BOUNDS,
            ),
            retries: reg.counter(
                "hostencil_halo_retries_total",
                "Halo transport operations retried after a transient failure (drop, corruption, unavailability).",
            ),
        });
        Ok(ShardedEngine {
            domain: *domain,
            fuse,
            halo,
            outer,
            inner,
            shards: shard_v,
            transport: Box::new(InProcessTransport::new(slabs.len(), band_len)),
            pool,
            instr,
            deadline: HALO_DEADLINE,
        })
    }

    /// Arm a fault plan on this engine: halo specs wrap the transport
    /// in a [`FaultyTransport`] decorator, pool specs arm the outer
    /// shard pool's injection check. Without the respective spec class
    /// the seam is left untouched — the disarmed hot path is
    /// bit-identical to a plan-free engine.
    pub fn set_faults(&mut self, faults: &Arc<FaultPlan>) {
        if faults.targets(FaultSite::Halo) {
            // stall 25% past the *current* deadline: callers shrinking
            // the deadline for fast tests should do so before arming
            // (at the default deadline this is exactly HALO_STALL)
            let stall = self.deadline + self.deadline / 4;
            let inner = std::mem::replace(&mut self.transport, Box::new(DisconnectedTransport));
            self.transport = Box::new(FaultyTransport::new(inner, Arc::clone(faults), stall));
        }
        if faults.targets(FaultSite::Pool) {
            if let Some(p) = &mut self.pool {
                p.set_faults(Arc::clone(faults));
            }
        }
    }

    /// Override the per-exchange deadline (default [`HALO_DEADLINE`]);
    /// tests shrink it to keep injected-stall cases fast.
    pub fn set_halo_deadline(&mut self, deadline: Duration) {
        self.deadline = deadline;
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Halo depth in z-planes (`fuse * R`).
    pub fn halo_depth(&self) -> usize {
        self.halo
    }

    pub fn fuse(&self) -> usize {
        self.fuse
    }

    /// `(outer shard slots, inner tile threads per shard)`.
    pub fn concurrency(&self) -> (usize, usize) {
        (self.outer, self.inner)
    }

    /// (Re)load every shard's extended range from global padded
    /// buffers. Call once after building (and after any out-of-band
    /// edit of the global wavefield).
    pub fn load(&mut self, u_pad: &Field3, um_pad: &Field3) {
        for sh in &mut self.shards {
            sh.load(u_pad, um_pad);
        }
    }

    /// Scatter every shard's **owned** slab back into global padded
    /// buffers — the owned union tiles the interior exactly, so the
    /// result is the full wavefield pair.
    pub fn gather_into(&self, u_pad: &mut Field3, um_pad: &mut Field3) {
        for sh in &self.shards {
            sh.store_owned(u_pad, um_pad);
        }
    }

    /// Advance one fused batch of `batch.n_steps <= fuse` sub-steps on
    /// every shard (no inter-shard sync inside the batch), then run
    /// the batch-boundary halo exchange: a publish phase posting owned
    /// seam bands and a collect phase verifying checksums and
    /// overwriting halos — each phase a barrier, so single-mailbox
    /// transports are race-free.
    ///
    /// Every transport operation rides the bounded-retry protocol
    /// (backoff + per-exchange deadline). An exhausted retry budget
    /// surfaces as `Err(ExchangeError)`; in that case the batch is
    /// *not* observable — the caller must skip the gather and its step
    /// accounting, so the global padded buffers keep the pre-batch
    /// state for a restorable checkpoint.
    pub fn advance_batch(&mut self, batch: &SourceBatch) -> Result<(), ExchangeError> {
        let b = batch.n_steps;
        assert!(
            b >= 1 && b <= self.fuse,
            "batch of {b} steps outside the engine's exchange cadence 1..={}",
            self.fuse
        );
        let gd = self.domain;
        let k = Consts::of(&gd).with_kernel(simd::active());
        let halo = self.halo;
        let n = self.shards.len();
        let deadline = self.deadline;
        let ShardedEngine { shards, pool, transport, instr, .. } = self;
        let transport: &dyn HaloTransport = &**transport;
        let retries = instr.as_ref().map(|i| i.retries.clone());
        let retries = retries.as_ref();

        run_phase(pool, shards, |_i, sh| {
            for j in 0..b {
                sh.advance_sub(&gd, k, batch, j);
            }
        });

        if n > 1 {
            let span = instr.as_ref().map(|i| i.latency.time());
            run_phase(pool, shards, |i, sh| {
                let mut side = |sh: &mut Shard, s: Side| {
                    if sh.fail.is_some() {
                        return;
                    }
                    sh.pack(s, halo);
                    let r = with_retry(i, s, deadline, retries, || {
                        transport.publish(i, s, &sh.band_u, &sh.band_um).map_err(|e| e.detail())
                    });
                    if let Err(e) = r {
                        sh.fail = Some(e);
                    }
                };
                if i > 0 {
                    side(sh, Side::Low);
                }
                if i + 1 < n {
                    side(sh, Side::High);
                }
            });
            // a failed publish leaves a stale mailbox with *valid*
            // checksums of the previous round — collecting past it
            // would apply stale planes silently, so the whole collect
            // phase is skipped once any publish has failed
            if shards.iter().all(|sh| sh.fail.is_none()) {
                run_phase(pool, shards, |i, sh| {
                    let mut side = |sh: &mut Shard, s: Side| {
                        if sh.fail.is_some() {
                            return;
                        }
                        let r = with_retry(i, s, deadline, retries, || {
                            let check = transport
                                .collect(i, s, &mut sh.band_u, &mut sh.band_um)
                                .map_err(|e| e.detail())?;
                            // end-to-end verification before the band
                            // touches the wavefield: a corrupt band is
                            // re-collected, never applied
                            if fnv1a64_f32(&sh.band_u) != check.u
                                || fnv1a64_f32(&sh.band_um) != check.um
                            {
                                return Err("collected band failed its checksum");
                            }
                            Ok(())
                        });
                        match r {
                            Ok(()) => sh.unpack(s, halo),
                            Err(e) => sh.fail = Some(e),
                        }
                    };
                    if i > 0 {
                        side(sh, Side::Low);
                    }
                    if i + 1 < n {
                        side(sh, Side::High);
                    }
                });
            }
            drop(span);
            for sh in shards.iter_mut() {
                if let Some(e) = sh.fail.take() {
                    return Err(e);
                }
            }
            if let Some(i) = instr.as_ref() {
                i.exchanges.add((n - 1) as u64);
                let seam_bytes =
                    2 * 2 * halo * gd.interior.y * gd.interior.x * std::mem::size_of::<f32>();
                i.bytes.add(((n - 1) * seam_bytes) as u64);
            }
        }
        Ok(())
    }
}

/// Tile a shard's extended interior into `SHARD_TILE_Z`-deep z-slices
/// (full y/x rows — classification happens per row inside the sweep).
fn shard_tiles(d: &Domain) -> Vec<Region> {
    Region { name: "shard", class: RegionClass::Inner, offset: Dim3::new(0, 0, 0), shape: d.interior }
        .split(Dim3::new(SHARD_TILE_Z, d.interior.y, d.interior.x))
}

/// Steady-state sharded throughput in steps/sec: silent batches at the
/// engine's exchange cadence, best of `samples` timed runs of `steps`
/// steps after `warmup` untimed runs (mirrors
/// `propagator::measure_steps_per_sec`; no gather inside the timed
/// region — this measures the engine, not the observer path).
pub fn measure_sharded_steps_per_sec(
    domain: &Domain,
    fuse: usize,
    shards: usize,
    steps: usize,
    warmup: usize,
    samples: usize,
) -> anyhow::Result<f64> {
    let interior = domain.interior;
    let v = Field3::full(interior, 2500.0);
    let eta = crate::wave::eta_profile(domain, 2500.0);
    let mut engine = ShardedEngine::new(domain, &v, &eta, fuse, shards, 0, None)?;
    let mut u_pad = Field3::zeros(domain.padded());
    u_pad.set(R + interior.z / 2, R + interior.y / 2, R + interior.x / 2, 1.0);
    let um_pad = Field3::zeros(domain.padded());
    engine.load(&u_pad, &um_pad);
    let run = |engine: &mut ShardedEngine| {
        let t0 = Instant::now();
        let mut done = 0;
        while done < steps {
            let b = fuse.min(steps - done);
            engine
                .advance_batch(&SourceBatch::silent(b))
                .expect("measurement run has no transport faults");
            done += b;
        }
        t0.elapsed()
    };
    for _ in 0..warmup {
        run(&mut engine);
    }
    let mut best = Duration::MAX;
    for _ in 0..samples.max(1) {
        best = best.min(run(&mut engine));
    }
    let mut out_u = Field3::zeros(domain.padded());
    let mut out_um = Field3::zeros(domain.padded());
    engine.gather_into(&mut out_u, &mut out_um);
    std::hint::black_box(out_u.as_slice().first().copied());
    Ok(steps as f64 / best.as_secs_f64().max(1e-12))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::{cfl_dt, propagator, FusedInputs, Propagator};
    use crate::testkit::Rng;
    use crate::wave;

    #[test]
    fn plan_slabs_distributes_the_remainder_and_tiles_the_axis() {
        let slabs = plan_slabs(13, 3, 4).expect("feasible");
        assert_eq!(
            slabs,
            vec![Slab { z0: 0, z1: 5 }, Slab { z0: 5, z1: 9 }, Slab { z0: 9, z1: 13 }]
        );
        // single shard: whole axis, halo irrelevant
        assert_eq!(plan_slabs(7, 1, 16).expect("single"), vec![Slab { z0: 0, z1: 7 }]);
    }

    #[test]
    fn plan_slabs_rejects_degenerate_counts() {
        assert!(plan_slabs(13, 0, 4).is_err());
        let err = plan_slabs(5, 6, 1).unwrap_err().to_string();
        assert!(err.contains("at most one shard per plane"), "got: {err}");
    }

    #[test]
    fn plan_slabs_rejects_slabs_thinner_than_the_halo() {
        // 13 planes over 3 shards -> 5,4,4; a fuse-2 halo needs 8
        let err = plan_slabs(13, 3, 8).unwrap_err().to_string();
        assert!(err.contains("fused halo needs 8"), "got: {err}");
        assert!(err.contains("fewer shards"), "got: {err}");
    }

    #[test]
    fn split_shard_budget_never_oversubscribes() {
        for budget in 1..=24usize {
            for shards in 1..=24usize {
                let (outer, inner) = split_shard_budget(budget, shards);
                assert!(outer >= 1 && inner >= 1);
                assert!(outer <= shards.max(1));
                assert!(
                    outer * inner <= budget.max(1),
                    "budget {budget} x shards {shards} -> {outer}x{inner}"
                );
            }
        }
        assert_eq!(split_shard_budget(8, 2), (2, 4));
        assert_eq!(split_shard_budget(3, 8), (3, 1));
        assert_eq!(split_shard_budget(0, 4), (1, 1));
    }

    #[test]
    fn transport_routes_bands_between_seam_neighbours() {
        let t = InProcessTransport::new(3, 4);
        t.publish(0, Side::High, &[1.0; 4], &[2.0; 4]).unwrap();
        t.publish(1, Side::Low, &[3.0; 4], &[4.0; 4]).unwrap();
        let (mut u, mut um) = ([0.0f32; 4], [0.0f32; 4]);
        // shard 1's Low halo <- shard 0's owned High band, and the
        // returned check matches the publisher-side hash end to end
        let check = t.collect(1, Side::Low, &mut u, &mut um).unwrap();
        assert_eq!((u, um), ([1.0; 4], [2.0; 4]));
        assert_eq!((check.u, check.um), (fnv1a64_f32(&u), fnv1a64_f32(&um)));
        // shard 0's High halo <- shard 1's owned Low band
        let check = t.collect(0, Side::High, &mut u, &mut um).unwrap();
        assert_eq!((u, um), ([3.0; 4], [4.0; 4]));
        assert_eq!((check.u, check.um), (fnv1a64_f32(&u), fnv1a64_f32(&um)));
    }

    /// Quick in-module bit-identity check (fuse 1, random state, seam
    /// sources); the full fuse x shards x grid matrix lives in
    /// `rust/tests/shard_equivalence.rs`.
    #[test]
    fn sharded_engine_matches_the_unsharded_reference_bitwise() {
        let h = 10.0;
        let interior = Dim3::new(19, 9, 11);
        let domain = Domain::new(interior, 2, h, cfl_dt(h, 3500.0)).expect("domain");
        let mut rng = Rng::new(0x5eed_5a5d);
        let u0 = rng.field(interior).pad(R);
        let um0 = rng.field(interior).pad(R);
        let v = rng.field_in(interior, 1500.0, 3500.0);
        let eta = wave::eta_profile(&domain, 3500.0);
        // sources straddling the 2-shard seam (z = 10) and the 3-shard
        // seams (z = 7, 13)
        let sources =
            [Dim3::new(9, 4, 5), Dim3::new(10, 2, 3), Dim3::new(7, 6, 8), Dim3::new(13, 4, 2)];
        let steps = 6;

        // unsharded reference: the naive propagator, one step at a time
        let eta_pad = eta.pad(R);
        let mut prop = propagator::build("naive").expect("naive");
        let (mut ru, mut rum) = (u0.clone(), um0.clone());
        for n in 0..steps {
            let amps: Vec<f32> =
                (0..sources.len()).map(|i| 1e-2 * ((n * sources.len() + i + 1) as f32)).collect();
            let inp = FusedInputs { domain: &domain, v: &v, eta_pad: &eta_pad, threads: 1, telemetry: None };
            prop.advance_fused(
                &inp,
                &mut ru,
                &mut rum,
                &SourceBatch { positions: &sources, amps: &amps, n_steps: 1 },
            );
        }

        for shards in [1, 2, 3] {
            let mut engine =
                ShardedEngine::new(&domain, &v, &eta, 1, shards, 2, None).expect("engine");
            engine.load(&u0, &um0);
            for n in 0..steps {
                let amps: Vec<f32> = (0..sources.len())
                    .map(|i| 1e-2 * ((n * sources.len() + i + 1) as f32))
                    .collect();
                engine
                    .advance_batch(&SourceBatch { positions: &sources, amps: &amps, n_steps: 1 })
                    .expect("fault-free batch");
            }
            let mut gu = Field3::zeros(domain.padded());
            let mut gum = Field3::zeros(domain.padded());
            engine.gather_into(&mut gu, &mut gum);
            assert_eq!(gu.max_abs_diff(&ru), 0.0, "{shards} shards: u diverged");
            assert_eq!(gum.max_abs_diff(&rum), 0.0, "{shards} shards: um diverged");
            // ghost ring stays zero
            assert_eq!(gu.unpad(R).pad(R).max_abs_diff(&gu), 0.0, "{shards} shards: ghost dirty");
        }
    }

    /// Drive a tiny 2-shard serial engine for 6 fuse-1 batches from an
    /// impulse initial condition, advancing the fault plan's step clock
    /// the way the coordinator does, and gather the result.
    fn run_chaos_engine(
        faults: Option<&Arc<FaultPlan>>,
        deadline: Option<Duration>,
        telemetry: Option<&Registry>,
    ) -> Result<(Field3, Field3), ExchangeError> {
        let h = 10.0;
        let interior = Dim3::new(16, 6, 7);
        let domain = Domain::new(interior, 2, h, cfl_dt(h, 3000.0)).expect("domain");
        let v = Field3::full(interior, 3000.0);
        let eta = wave::eta_profile(&domain, 3000.0);
        let mut engine = ShardedEngine::new(&domain, &v, &eta, 1, 2, 1, telemetry).expect("engine");
        if let Some(d) = deadline {
            engine.set_halo_deadline(d);
        }
        if let Some(f) = faults {
            engine.set_faults(f);
        }
        let mut u0 = Field3::zeros(domain.padded());
        u0.set(R + 8, R + 3, R + 3, 1.0);
        let um0 = Field3::zeros(domain.padded());
        engine.load(&u0, &um0);
        for n in 0..6u64 {
            if let Some(f) = faults {
                f.set_step(n);
            }
            engine.advance_batch(&SourceBatch::silent(1))?;
        }
        let mut gu = Field3::zeros(domain.padded());
        let mut gum = Field3::zeros(domain.padded());
        engine.gather_into(&mut gu, &mut gum);
        Ok((gu, gum))
    }

    #[test]
    fn dropped_band_retries_to_a_bit_identical_completion() {
        let clean = run_chaos_engine(None, None, None).expect("clean run");
        let reg = Registry::new();
        let plan = FaultPlan::single(FaultSite::Halo, FaultKind::Drop, 3, 7);
        let faulty = run_chaos_engine(Some(&plan), None, Some(&reg)).expect("drop must heal");
        assert_eq!(plan.injected(FaultSite::Halo), 1, "exactly one injected drop");
        assert!(
            reg.counter("hostencil_halo_retries_total", "").get() >= 1,
            "the healed drop must be visible as a retry"
        );
        assert_eq!(faulty.0.max_abs_diff(&clean.0), 0.0, "u diverged after a healed drop");
        assert_eq!(faulty.1.max_abs_diff(&clean.1), 0.0, "um diverged after a healed drop");
    }

    #[test]
    fn corrupted_band_is_caught_by_checksum_and_recollected() {
        let clean = run_chaos_engine(None, None, None).expect("clean run");
        let reg = Registry::new();
        let plan = FaultPlan::single(FaultSite::Halo, FaultKind::Corrupt, 2, 11);
        let faulty =
            run_chaos_engine(Some(&plan), None, Some(&reg)).expect("corruption must heal");
        assert_eq!(plan.injected(FaultSite::Halo), 1, "exactly one injected corruption");
        assert!(
            reg.counter("hostencil_halo_retries_total", "").get() >= 1,
            "the checksum catch must be visible as a retry"
        );
        // the corrupt band was never applied: the re-collected clean
        // band keeps the run bit-identical to the fault-free one
        assert_eq!(faulty.0.max_abs_diff(&clean.0), 0.0, "u diverged: corrupt band applied");
        assert_eq!(faulty.1.max_abs_diff(&clean.1), 0.0, "um diverged: corrupt band applied");
    }

    #[test]
    fn stalled_transport_exhausts_the_deadline_and_escalates() {
        let plan = FaultPlan::single(FaultSite::Halo, FaultKind::Delay, 2, 13);
        // 5ms deadline set *before* arming, so the injected stall
        // (deadline + 25%) overshoots it and the test stays fast
        let err = run_chaos_engine(Some(&plan), Some(Duration::from_millis(5)), None)
            .expect_err("a stall past the deadline must escalate");
        assert_eq!(plan.injected(FaultSite::Halo), 1);
        let msg = err.to_string();
        assert!(msg.contains("transport stalled"), "got: {msg}");
        assert!(msg.contains("halo exchange failed"), "got: {msg}");
    }
}
