//! Layer-3 coordinator: the simulation driver.
//!
//! Owns the wavefield state and, in decomposed mode, performs the
//! paper's launch topology every time step: seven region launches (one
//! inner, six PML faces), each fed a freshly sliced tile + halo and
//! scattered back into the next wavefield — exactly the role the CUDA
//! host code plays in the paper, with PJRT executables standing in for
//! kernel launches.
//!
//! Modes:
//! * `Decomposed`  — 7 launches/step (paper strategy 3, the contribution)
//! * `Monolithic`  — 1 branchy full-domain launch/step (strategy 1 /
//!   OpenACC-baseline analog)
//! * `Fused`       — 1 launch/step of the XLA-fused decomposed graph
//! * `Golden`      — pure-Rust CPU propagators, no PJRT. The kernel
//!   variant name selects the *code shape* here too: it resolves to one
//!   of the executable CPU analogs in `stencil::propagator` (naive,
//!   3D-blocked, 2.5D streaming, semi-stencil), so CPU runs measure
//!   real shape-dependent cost instead of always walking the golden
//!   per-point loop. The Golden time loop is zero-allocation and
//!   zero-spawn: two persistent padded buffers ping-pong via
//!   `Propagator::step_into`, and multithreaded tile fan-out goes
//!   through the persistent per-plan worker pool (`runtime::pool`)
//!   instead of per-step scoped threads (see
//!   `rust/tests/zero_alloc.rs`).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::fault::{FaultKind, FaultPlan, FaultSite};
use crate::grid::{decompose, Dim3, Domain, Field3, Region};
use crate::json::Json;
use crate::recovery::{self, BreakerConfig, BreakerKind, Checkpoint, DivergenceBreaker, SoftAbort};
use crate::runtime::{Engine, ExecArg};
use crate::shard::ShardedEngine;
use crate::stencil::propagator::{self, FusedInputs, Propagator, PropagatorInputs, SourceBatch};
use crate::telemetry::{Counter, Gauge, Histogram, Registry, LATENCY_BOUNDS};
use crate::wave::Source;
use crate::R;

/// Launch topology selector.
#[derive(Copy, Clone, PartialEq, Eq, Debug)]
pub enum Mode {
    Decomposed,
    Monolithic,
    Fused,
    Golden,
}

impl Mode {
    pub fn parse(s: &str) -> anyhow::Result<Mode> {
        Ok(match s {
            "decomposed" => Mode::Decomposed,
            "monolithic" => Mode::Monolithic,
            "fused" => Mode::Fused,
            "golden" => Mode::Golden,
            other => anyhow::bail!(
                "unknown mode {other:?} (expected decomposed|monolithic|fused|golden)"
            ),
        })
    }

    pub fn needs_engine(&self) -> bool {
        !matches!(self, Mode::Golden)
    }
}

/// Hook called after every completed time step of an observed run
/// (`Coordinator::run_observed`). Observers see the `R`-ghost-padded
/// wavefield at step n+1 — the ghost ring is zero by construction, so
/// padded aggregates (energy, max|u|) equal interior aggregates —
/// plus the step's already-computed interior energy (the coordinator
/// logs it anyway; passing it avoids a redundant full-field pass per
/// step). The scenario metrics collector is the canonical implementor.
pub trait StepObserver {
    fn on_step(&mut self, step: usize, u_pad: &Field3, energy: f64);
}

/// Options for [`Coordinator::run_observed`].
#[derive(Copy, Clone, Debug)]
pub struct RunOptions {
    /// When true (the `run` default), a NaN/Inf wavefield aborts the run
    /// with an error. Scenario stress runs set false: the run stops
    /// stepping (NaN only spreads) but returns a summary so the metrics
    /// collector can report *where* the field blew up.
    pub halt_on_non_finite: bool,
    /// Upper bound on the recording batch size, in steps. 0 (the
    /// default) keeps the backend's natural cadence — per step for
    /// unfused families, per fused batch for `tf_*`. Setting N >= 1
    /// caps batches at N steps so observed runs retain finer-grained
    /// energy/receiver traces from fused backends, trading away some
    /// of the fusion win (`--sample-every` on the CLI).
    pub sample_every: usize,
}

impl Default for RunOptions {
    fn default() -> Self {
        RunOptions { halt_on_non_finite: true, sample_every: 0 }
    }
}

/// Pre-registered coordinator metric handles: registration (which
/// allocates) happens once in [`Coordinator::set_telemetry`], so the
/// observed-run loop only bumps atomics. Metric names are catalogued
/// in docs/METRICS.md.
struct CoordTelemetry {
    registry: Registry,
    steps: Counter,
    batches: Counter,
    injections: Counter,
    nonfinite: Counter,
    batch_latency: Histogram,
    ckpt_writes: Counter,
    ckpt_bytes: Counter,
    ckpt_last_step: Gauge,
    ckpt_latency: Histogram,
    ckpt_failures: Counter,
    breaker_energy_trips: Counter,
    breaker_nan_trips: Counter,
    breaker_halo_trips: Counter,
}

/// Summary of a completed run.
#[derive(Clone, Debug)]
pub struct RunSummary {
    /// Steps actually completed (short of the request only when a
    /// non-halting observed run hit a non-finite wavefield).
    pub steps: usize,
    pub wall: Duration,
    pub launches: u64,
    pub final_max_abs: f32,
    pub final_energy: f64,
    /// interior-points x steps / wall seconds
    pub points_per_sec: f64,
    /// Interior energy per recorded state: one entry per step for
    /// unfused backends, one per fused batch (batch-boundary states
    /// are the only global states a fused sweep materializes).
    pub energy_log: Vec<f64>,
    /// Per-receiver time series, sampled at the same cadence as
    /// `energy_log`.
    pub traces: Vec<Vec<f32>>,
}

/// Per-region device-resident constants for the decomposed PJRT path,
/// uploaded once at construction (perf: re-uploading v/eta per launch
/// was pure overhead on the decomposed hot path; see EXPERIMENTS.md
/// §Perf). The CPU path reads `v`/`eta_pad` directly through the
/// propagator engine and needs no per-region host tiles.
struct RegionTiles {
    v_dev: xla::PjRtBuffer,
    eta_dev: Option<xla::PjRtBuffer>, // PML regions only
}

/// The wave-propagation coordinator.
pub struct Coordinator<'e> {
    pub domain: Domain,
    pub mode: Mode,
    engine: Option<&'e Engine>,
    regions: Vec<Region>,
    region_tiles: Vec<RegionTiles>,
    inner_artifact: String,
    pml_artifacts: HashMap<String, String>, // face-class key -> artifact name
    v: Field3,
    eta: Field3,
    eta_pad: Field3,
    /// wavefield at step n, R-ghost-padded
    u_pad: Field3,
    /// wavefield at step n-1, R-ghost-padded (ghost stays zero). On the
    /// PJRT paths regions extract their interior tiles from it and the
    /// buffers rotate by move; in Golden mode the propagator overwrites
    /// it in place (its center values are the leapfrog um term) and the
    /// two persistent buffers swap — the zero-allocation time loop.
    um_pad: Field3,
    /// CPU code-shape engine, selected from the kernel-variant id
    /// (Golden mode only).
    propagator: Option<Box<dyn Propagator>>,
    /// Worker threads for the propagator tile fan-out (0 = one per
    /// core). The campaign sets 1: its cell fan-out owns the cores.
    cpu_threads: usize,
    /// z-slab shard count for the sharded Golden run path (1 =
    /// unsharded; see [`Coordinator::set_shards`]).
    shard_count: usize,
    /// Lazily built sharded engine (first sharded batch). Dropped on
    /// any reconfiguration and rebuilt from the global pair — shard
    /// state equals the global wavefield at every batch boundary, so
    /// a rebuild loses nothing.
    shard: Option<ShardedEngine>,
    /// The propagator's natural fusion degree (1 for every family but
    /// `TimeFused`): observed runs advance in batches of this many
    /// steps, recording energy/traces and firing the observer once per
    /// batch — the whole point of temporal fusion is that intermediate
    /// global states never materialize.
    fuse: usize,
    /// Reusable per-batch injection schedule (positions + row-major
    /// `[sub-step x source]` amplitudes); capacity reserved once so
    /// steady-state batches never allocate.
    fused_pos: Vec<Dim3>,
    fused_amps: Vec<f32>,
    /// Injection sources with the velocity sampled at each position
    /// (primary source from the constructor + any `add_source` extras).
    sources: Vec<(Source, f32)>,
    receivers: Vec<Dim3>,
    traces: Vec<Vec<f32>>,
    energy_log: Vec<f64>,
    steps_done: usize,
    launches: u64,
    /// Attached flight-recorder registry + pre-registered handles
    /// (None until [`Coordinator::set_telemetry`]).
    telemetry: Option<CoordTelemetry>,
    /// Cadence checkpointing: write a snapshot whenever the step
    /// counter crosses a multiple of `checkpoint_every` (0 = no
    /// cadence). `checkpoint_path` is also the destination for
    /// breaker-trip snapshots, independent of the cadence.
    checkpoint_every: usize,
    checkpoint_path: Option<PathBuf>,
    /// Retention-ring depth at `checkpoint_path` (1 = the classic
    /// single overwritten snapshot; K keeps the K newest, rotated
    /// atomically before every write).
    checkpoint_keep: usize,
    /// Armed deterministic fault plan (None = every seam untouched).
    /// Threaded into the sharded engine on its next lazy build and
    /// consulted directly for checkpoint/restore I/O faults.
    faults: Option<Arc<FaultPlan>>,
    /// Per-exchange halo deadline override for the sharded engine
    /// (None = the engine default; tests and the chaos harness shrink
    /// it so injected stalls escalate quickly).
    halo_deadline: Option<Duration>,
    /// Divergence circuit breakers for observed runs (None = the
    /// legacy non-finite watchdog alone owns divergence handling).
    breaker_cfg: Option<BreakerConfig>,
    /// Structured reason the last observed run halted via a breaker
    /// trip (cleared when a run starts).
    soft_abort: Option<SoftAbort>,
}

impl<'e> Coordinator<'e> {
    /// Create a coordinator. `engine` may be `None` only for `Mode::Golden`.
    #[allow(clippy::too_many_arguments)] // mirrors the launch ABI: state + topology + physics
    pub fn new(
        engine: Option<&'e Engine>,
        domain: Domain,
        mode: Mode,
        inner_variant: &str,
        pml_variant: &str,
        v: Field3,
        eta: Field3,
        source: Source,
        receivers: Vec<Dim3>,
    ) -> anyhow::Result<Self> {
        domain.validate()?;
        anyhow::ensure!(v.dims() == domain.interior, "velocity must be interior-sized");
        anyhow::ensure!(eta.dims() == domain.interior, "eta must be interior-sized");
        let in_bounds = |p: Dim3| p.z < domain.interior.z && p.y < domain.interior.y && p.x < domain.interior.x;
        anyhow::ensure!(in_bounds(source.pos), "source {} outside interior", source.pos);
        for r in &receivers {
            anyhow::ensure!(in_bounds(*r), "receiver {} outside interior", r);
        }

        let regions = decompose(&domain);
        let mut pml_artifacts = HashMap::new();
        if mode.needs_engine() {
            let eng = engine.ok_or_else(|| anyhow::anyhow!("mode {mode:?} needs a PJRT engine"))?;
            let m = eng.manifest();
            anyhow::ensure!(
                m.domain == domain,
                "artifact domain {:?} != run domain {:?}; re-run `make artifacts` with matching dims",
                m.domain,
                domain
            );
            match mode {
                Mode::Decomposed => {
                    m.get(&format!("inner_{inner_variant}"))?;
                    for cls in ["top_bottom", "front_back", "left_right"] {
                        let name = format!("pml_{cls}_{pml_variant}");
                        m.get(&name)?;
                        pml_artifacts.insert(cls.to_string(), name);
                    }
                }
                Mode::Monolithic => {
                    m.get("monolithic")?;
                }
                Mode::Fused => {
                    m.get("fused")?;
                }
                Mode::Golden => unreachable!(),
            }
        }

        // Golden mode: resolve the variant name to its executable CPU
        // code shape up front, so unknown names fail at construction
        // exactly like unknown artifact names on the PJRT path.
        let cpu_propagator = if mode == Mode::Golden {
            Some(propagator::build(inner_variant)?)
        } else {
            None
        };

        let v_at_src = v.get(source.pos.z, source.pos.y, source.pos.x);
        let sources = vec![(source, v_at_src)];
        let n_recv = receivers.len();
        let eta_pad = eta.pad(R);
        let region_tiles = match (mode, engine) {
            (Mode::Decomposed, Some(eng)) => regions
                .iter()
                .map(|reg| -> anyhow::Result<RegionTiles> {
                    let v_t = v.extract(reg.offset, reg.shape);
                    let eta_t = reg
                        .class
                        .is_pml()
                        .then(|| eta_pad.extract_padded_region(R, reg.offset, reg.shape, 1));
                    Ok(RegionTiles {
                        v_dev: eng.upload(&v_t)?,
                        eta_dev: eta_t.as_ref().map(|e| eng.upload(e)).transpose()?,
                    })
                })
                .collect::<anyhow::Result<Vec<_>>>()?,
            _ => Vec::new(),
        };
        let fuse = cpu_propagator.as_ref().map(|p| p.max_fuse()).unwrap_or(1).max(1);
        Ok(Coordinator {
            domain,
            mode,
            engine,
            regions,
            region_tiles,
            inner_artifact: format!("inner_{inner_variant}"),
            pml_artifacts,
            eta_pad,
            eta,
            v,
            u_pad: Field3::zeros(domain.padded()),
            um_pad: Field3::zeros(domain.padded()),
            propagator: cpu_propagator,
            cpu_threads: 0,
            shard_count: 1,
            shard: None,
            fuse,
            fused_pos: Vec::new(),
            fused_amps: Vec::new(),
            sources,
            receivers,
            traces: vec![Vec::new(); n_recv],
            energy_log: Vec::new(),
            steps_done: 0,
            launches: 0,
            telemetry: None,
            checkpoint_every: 0,
            checkpoint_path: None,
            checkpoint_keep: 1,
            faults: None,
            halo_deadline: None,
            breaker_cfg: None,
            soft_abort: None,
        })
    }

    /// Attach a telemetry registry. Pre-registers the coordinator's
    /// counters and the batch-latency histogram so the stepping hot
    /// path only bumps pre-allocated atomics; the same registry rides
    /// down into the propagator layer via `PropagatorInputs`, where
    /// plans register their per-family instrumentation on next build.
    /// Flight-recorder events go to the registry's event log when one
    /// is enabled.
    pub fn set_telemetry(&mut self, reg: &Registry) {
        self.shard = None; // rebuild so the engine registers its series
        self.telemetry = Some(CoordTelemetry {
            registry: reg.clone(),
            steps: reg.counter("hostencil_steps_total", "Leapfrog time steps completed."),
            batches: reg.counter(
                "hostencil_batches_total",
                "Observed-run step batches completed (a fused sweep counts once).",
            ),
            injections: reg.counter(
                "hostencil_source_injections_total",
                "Individual source-term injections applied to the wavefield.",
            ),
            nonfinite: reg.counter(
                "hostencil_watchdog_nonfinite_total",
                "Times the energy watchdog observed a non-finite wavefield.",
            ),
            batch_latency: reg.histogram(
                "hostencil_batch_latency_seconds",
                "Wall-clock latency of one observed-run step batch.",
                &LATENCY_BOUNDS,
            ),
            ckpt_writes: reg.counter(
                "hostencil_checkpoint_writes_total",
                "Checkpoint snapshots written (cadence + breaker trips).",
            ),
            ckpt_bytes: reg.counter(
                "hostencil_checkpoint_bytes_total",
                "Serialized checkpoint bytes written.",
            ),
            ckpt_last_step: reg.gauge(
                "hostencil_checkpoint_last_step",
                "Step index of the most recent checkpoint write.",
            ),
            ckpt_latency: reg.histogram(
                "hostencil_checkpoint_write_latency_seconds",
                "Wall-clock latency of one checkpoint serialize + atomic write.",
                &LATENCY_BOUNDS,
            ),
            ckpt_failures: reg.counter(
                "hostencil_checkpoint_failures_total",
                "Cadence checkpoint writes that failed (run kept alive; the ring still holds the last good snapshot).",
            ),
            breaker_energy_trips: reg.counter_with(
                "hostencil_breaker_trips_total",
                "Divergence circuit-breaker trips, by breaker kind.",
                &[("kind", "energy_growth")],
            ),
            breaker_nan_trips: reg.counter_with(
                "hostencil_breaker_trips_total",
                "Divergence circuit-breaker trips, by breaker kind.",
                &[("kind", "nan_rate")],
            ),
            breaker_halo_trips: reg.counter_with(
                "hostencil_breaker_trips_total",
                "Divergence circuit-breaker trips, by breaker kind.",
                &[("kind", "halo_stall")],
            ),
        });
        if let (Some(f), Some(tel)) = (&self.faults, &self.telemetry) {
            f.register_telemetry(&tel.registry);
        }
    }

    /// The attached telemetry registry, if any.
    pub fn telemetry(&self) -> Option<&Registry> {
        self.telemetry.as_ref().map(|t| &t.registry)
    }

    /// One decomposed step: slice -> launch -> scatter, per region.
    /// Writes region tiles straight into the padded next-step buffer.
    fn step_decomposed(&mut self) -> anyhow::Result<Field3> {
        let eng = self.engine.expect("checked in new()");
        let mut out = Field3::zeros(self.domain.padded());
        for (reg, tiles) in self.regions.iter().zip(&self.region_tiles) {
            // NOTE perf: recycling the previous step's output buffers as
            // um inputs (a two-deep device-buffer queue) was measured at
            // <5% on this testbed and reverted — see EXPERIMENTS.md §Perf.
            let um_t = self.um_pad.extract_padded_region(R, reg.offset, reg.shape, 0);
            let v_dev = &tiles.v_dev;
            let tile = if reg.class.is_pml() {
                let u_t = self.u_pad.extract_padded_region(R, reg.offset, reg.shape, 1);
                let e_dev = tiles.eta_dev.as_ref().expect("pml region has eta buffer");
                let name = &self.pml_artifacts[reg.class.key()];
                eng.execute_args(
                    name,
                    &[
                        ExecArg::Host(&u_t),
                        ExecArg::Host(&um_t),
                        ExecArg::Device(v_dev),
                        ExecArg::Device(e_dev),
                    ],
                )?
            } else {
                let u_t = self.u_pad.extract_padded_region(R, reg.offset, reg.shape, R);
                eng.execute_args(
                    &self.inner_artifact,
                    &[ExecArg::Host(&u_t), ExecArg::Host(&um_t), ExecArg::Device(v_dev)],
                )?
            };
            self.launches += 1;
            out.scatter(
                Dim3::new(R + reg.offset.z, R + reg.offset.y, R + reg.offset.x),
                &tile,
            );
        }
        Ok(out)
    }

    /// One full-domain launch (monolithic or fused artifact).
    fn step_full(&mut self, artifact: &str) -> anyhow::Result<Field3> {
        let eng = self.engine.expect("checked in new()");
        let um = self.um_pad.unpad(R); // artifact signature takes interior um
        let out = eng.execute(artifact, &[&self.u_pad, &um, &self.v, &self.eta_pad])?;
        self.launches += 1;
        Ok(out.pad(R))
    }

    /// Advance one time step (stencil update + source injection +
    /// receiver/energy recording + state rotation).
    pub fn step(&mut self) -> anyhow::Result<()> {
        match self.mode {
            Mode::Golden => {
                // Zero-allocation in-place path: the propagator
                // overwrites um_pad (whose center values are the
                // leapfrog um term) with the next wavefield, then the
                // two persistent padded buffers swap. Launch
                // bookkeeping stays one logical launch per
                // decomposition region, matching the PJRT path.
                let prop = self.propagator.as_mut().expect("built in new() for Golden mode");
                prop.step_into(
                    &PropagatorInputs {
                        domain: &self.domain,
                        u_pad: &self.u_pad,
                        v: &self.v,
                        eta_pad: &self.eta_pad,
                        threads: self.cpu_threads,
                        telemetry: self.telemetry.as_ref().map(|t| &t.registry),
                    },
                    &mut self.um_pad,
                );
                self.launches += self.regions.len() as u64;
                std::mem::swap(&mut self.u_pad, &mut self.um_pad);
            }
            Mode::Decomposed | Mode::Monolithic | Mode::Fused => {
                // PJRT paths produce a fresh device-computed field;
                // rotate by move (no pad/unpad copies).
                let un = match self.mode {
                    Mode::Decomposed => self.step_decomposed()?,
                    Mode::Monolithic => self.step_full("monolithic")?,
                    Mode::Fused => self.step_full("fused")?,
                    Mode::Golden => unreachable!(),
                };
                self.um_pad = std::mem::replace(&mut self.u_pad, un);
            }
        }
        // u_pad now holds the new wavefield (ghost zeros preserved by
        // construction); inject sources and record directly from it.
        for (src, v_at) in &self.sources {
            let amp = src.amp_at(self.steps_done, self.domain.dt, *v_at);
            self.u_pad.add(R + src.pos.z, R + src.pos.y, R + src.pos.x, amp);
        }
        for (i, r) in self.receivers.iter().enumerate() {
            let sample = self.u_pad.get(R + r.z, R + r.y, R + r.x);
            self.traces[i].push(sample);
        }
        // ghost ring is zero, so padded energy == interior energy
        self.energy_log.push(self.u_pad.energy());
        self.steps_done += 1;
        if let Some(tel) = &self.telemetry {
            tel.steps.inc();
            tel.injections.add(self.sources.len() as u64);
        }
        Ok(())
    }

    /// Advance `b` steps through the propagator's fused batch path
    /// (Golden mode only). The per-sub-step source amplitudes ride
    /// down in a [`SourceBatch`] so injection lands between virtual
    /// sub-steps, bit-identical to `b` calls of [`Coordinator::step`];
    /// receivers and the energy log record once, at the batch
    /// boundary. Steady-state batches allocate nothing (the schedule
    /// buffers are reserved on first use and reused).
    fn step_fused(&mut self, b: usize) -> anyhow::Result<()> {
        debug_assert!(b >= 1);
        self.fused_pos.clear();
        self.fused_amps.clear();
        self.fused_pos.reserve(self.sources.len());
        self.fused_amps.reserve(self.sources.len() * b);
        for (src, _) in &self.sources {
            self.fused_pos.push(src.pos);
        }
        for j in 0..b {
            for (src, v_at) in &self.sources {
                self.fused_amps.push(src.amp_at(self.steps_done + j, self.domain.dt, *v_at));
            }
        }
        let prop = self.propagator.as_mut().expect("fused stepping is Golden-mode only");
        prop.advance_fused(
            &FusedInputs {
                domain: &self.domain,
                v: &self.v,
                eta_pad: &self.eta_pad,
                threads: self.cpu_threads,
                telemetry: self.telemetry.as_ref().map(|t| &t.registry),
            },
            &mut self.u_pad,
            &mut self.um_pad,
            &SourceBatch { positions: &self.fused_pos, amps: &self.fused_amps, n_steps: b },
        );
        // launch bookkeeping stays one logical launch per region per
        // (virtual) step, matching the unfused paths
        self.launches += (self.regions.len() * b) as u64;
        self.steps_done += b;
        for (i, r) in self.receivers.iter().enumerate() {
            self.traces[i].push(self.u_pad.get(R + r.z, R + r.y, R + r.x));
        }
        self.energy_log.push(self.u_pad.energy());
        if let Some(tel) = &self.telemetry {
            tel.steps.add(b as u64);
            tel.injections.add((self.sources.len() * b) as u64);
        }
        Ok(())
    }

    /// Advance `b` steps on the sharded engine ([`crate::shard`],
    /// Golden mode only). The injection schedule is the exact one
    /// [`Coordinator::step_fused`] builds; every shard advances `b`
    /// sub-steps without inter-shard sync, the batch-boundary halo
    /// exchange runs, and the owned slabs are gathered back into the
    /// global padded pair — so receiver/energy recording, observers,
    /// and the non-finite watchdog read the same state an unsharded
    /// run produces, bit-identically.
    ///
    /// Returns `Ok(Some(err))` when the halo exchange exhausted its
    /// retry budget: the batch never became observable (the global
    /// padded pair still holds the pre-batch state, nothing was
    /// gathered or counted), so the caller can checkpoint restorable
    /// state and soft-abort.
    fn step_sharded(&mut self, b: usize) -> anyhow::Result<Option<crate::shard::ExchangeError>> {
        debug_assert!(b >= 1 && b <= self.fuse.max(1));
        if self.shard.is_none() {
            let mut engine = ShardedEngine::new(
                &self.domain,
                &self.v,
                &self.eta,
                self.fuse.max(1),
                self.shard_count,
                self.cpu_threads,
                self.telemetry.as_ref().map(|t| &t.registry),
            )?;
            engine.load(&self.u_pad, &self.um_pad);
            // deadline before faults: the injected stall length is
            // derived from the deadline at arming time
            if let Some(d) = self.halo_deadline {
                engine.set_halo_deadline(d);
            }
            if let Some(f) = &self.faults {
                engine.set_faults(f);
            }
            self.shard = Some(engine);
        }
        self.fused_pos.clear();
        self.fused_amps.clear();
        self.fused_pos.reserve(self.sources.len());
        self.fused_amps.reserve(self.sources.len() * b);
        for (src, _) in &self.sources {
            self.fused_pos.push(src.pos);
        }
        for j in 0..b {
            for (src, v_at) in &self.sources {
                self.fused_amps.push(src.amp_at(self.steps_done + j, self.domain.dt, *v_at));
            }
        }
        let engine = self.shard.as_mut().expect("built above");
        if let Err(e) = engine.advance_batch(&SourceBatch {
            positions: &self.fused_pos,
            amps: &self.fused_amps,
            n_steps: b,
        }) {
            // the shard buffers may hold a half-exchanged batch; drop
            // the engine so any later resume rebuilds from the intact
            // global pair
            self.shard = None;
            return Ok(Some(e));
        }
        engine.gather_into(&mut self.u_pad, &mut self.um_pad);
        // launch bookkeeping: one logical launch per shard per
        // (virtual) step — the sharded analog of one per region
        self.launches += (self.shard_count * b) as u64;
        self.steps_done += b;
        for (i, r) in self.receivers.iter().enumerate() {
            self.traces[i].push(self.u_pad.get(R + r.z, R + r.y, R + r.x));
        }
        self.energy_log.push(self.u_pad.energy());
        if let Some(tel) = &self.telemetry {
            tel.steps.add(b as u64);
            tel.injections.add((self.sources.len() * b) as u64);
        }
        Ok(())
    }

    /// Natural step-batch size of this coordinator's backend: the
    /// propagator's fusion degree in Golden mode, 1 otherwise.
    /// Observed runs record energy/traces and fire the observer once
    /// per batch.
    pub fn fuse(&self) -> usize {
        self.fuse
    }

    /// Shard the Golden run path into `n` z-slabs ([`crate::shard`]):
    /// each slab advances on its own buffers/plan/pool and seam halos
    /// are exchanged at fused batch boundaries. `n <= 1` restores the
    /// unsharded path. Feasibility (every slab at least `fuse * R`
    /// planes thick) is validated here so infeasible configurations
    /// fail fast with a clear error instead of mid-run.
    pub fn set_shards(&mut self, n: usize) -> anyhow::Result<()> {
        self.shard = None;
        if n <= 1 {
            self.shard_count = 1;
            return Ok(());
        }
        anyhow::ensure!(
            self.mode == Mode::Golden,
            "--shards applies to the Golden (CPU engine) mode only, not {:?}",
            self.mode
        );
        crate::shard::plan_slabs(self.domain.interior.z, n, self.fuse.max(1) * R)?;
        self.shard_count = n;
        Ok(())
    }

    /// Active shard count (1 = unsharded).
    pub fn shards(&self) -> usize {
        self.shard_count
    }

    /// Register an additional injection source (multi-source scenarios:
    /// interference patterns, simultaneous-shot stress). The primary
    /// source from the constructor is always present.
    pub fn add_source(&mut self, source: Source) -> anyhow::Result<()> {
        let n = self.domain.interior;
        anyhow::ensure!(
            source.pos.z < n.z && source.pos.y < n.y && source.pos.x < n.x,
            "source {} outside interior {}",
            source.pos,
            n
        );
        let v_at = self.v.get(source.pos.z, source.pos.y, source.pos.x);
        self.sources.push((source, v_at));
        Ok(())
    }

    /// Injection sources with the velocity sampled at each position
    /// (primary + extras, in registration order).
    pub fn sources(&self) -> &[(Source, f32)] {
        &self.sources
    }

    /// Receiver positions, in trace order.
    pub fn receivers(&self) -> &[Dim3] {
        &self.receivers
    }

    /// Enable cadence checkpointing: a snapshot is written atomically
    /// to `path` every time the step counter crosses a multiple of
    /// `every` (a fused batch checkpoints at the first boundary past
    /// the multiple). `every = 0` disables the cadence but keeps
    /// `path` as the destination for breaker-trip snapshots.
    pub fn set_checkpointing(&mut self, every: usize, path: Option<PathBuf>) {
        self.checkpoint_every = every;
        self.checkpoint_path = path;
    }

    /// Retention-ring depth at the checkpoint path: keep the `keep`
    /// newest snapshots (`path`, `path.1`, ... — rotated atomically
    /// before every write). Clamped to >= 1; the CLI rejects 0 by name.
    pub fn set_checkpoint_keep(&mut self, keep: usize) {
        self.checkpoint_keep = keep.max(1);
    }

    /// Arm a deterministic fault plan for subsequent runs: halo/pool
    /// specs ride into the sharded engine on its next lazy build, and
    /// checkpoint/restore I/O consults the plan directly. Registers
    /// the `hostencil_fault_injected_total` series if telemetry is
    /// already attached (and [`Coordinator::set_telemetry`] registers
    /// it for plans armed first).
    pub fn set_faults(&mut self, faults: Arc<FaultPlan>) {
        self.shard = None; // rebuild so the engine arms its seams
        if let Some(tel) = &self.telemetry {
            faults.register_telemetry(&tel.registry);
        }
        self.faults = Some(faults);
    }

    /// Override the sharded engine's per-exchange halo deadline (tests
    /// and the chaos harness shrink it so injected stalls escalate in
    /// milliseconds instead of the production default).
    pub fn set_halo_deadline(&mut self, deadline: Duration) {
        self.shard = None;
        self.halo_deadline = Some(deadline);
    }

    /// Arm the divergence circuit breakers for subsequent observed
    /// runs (`None` disarms; see [`crate::recovery::BreakerConfig`]).
    /// With breakers armed, divergence ends the run in a [`SoftAbort`]
    /// (checkpoint-and-halt) instead of the legacy hard error.
    pub fn set_breakers(&mut self, cfg: Option<BreakerConfig>) {
        self.breaker_cfg = cfg;
    }

    /// Structured reason the last observed run halted early via a
    /// breaker trip (cleared when a run starts).
    pub fn soft_abort(&self) -> Option<&SoftAbort> {
        self.soft_abort.as_ref()
    }

    /// Default arming step for the energy-growth breaker: the Ricker
    /// wavelets are effectively silent past ~2.4/f0 seconds (delay
    /// 1.2/f0 plus the symmetric tail); 3/f0 adds margin. Before this
    /// step the injection ramp grows energy super-exponentially on
    /// perfectly healthy runs, so the window only starts recording
    /// once every source has gone quiet.
    fn auto_arm_step(&self) -> usize {
        let dt = self.domain.dt.max(f64::MIN_POSITIVE);
        self.sources
            .iter()
            .map(|(s, _)| (3.0 / (s.f0.max(f64::MIN_POSITIVE) * dt)).ceil() as usize)
            .max()
            .unwrap_or(0)
    }

    /// Snapshot the full propagator state at the current step
    /// boundary. The sharded path needs no extra gather: every sharded
    /// batch already collects the owned slabs back into the global
    /// padded pair, so batch boundaries always hold the global state.
    pub fn checkpoint(&self) -> Checkpoint {
        Checkpoint {
            interior: self.domain.interior,
            pml_width: self.domain.pml_width,
            h: self.domain.h,
            dt: self.domain.dt,
            steps_done: self.steps_done as u64,
            launches: self.launches,
            traces: self.traces.clone(),
            energy_log: self.energy_log.clone(),
            u_pad: self.u_pad.as_slice().to_vec(),
            um_pad: self.um_pad.as_slice().to_vec(),
        }
    }

    /// Load a snapshot into this coordinator and continue from it.
    /// The checkpoint's domain must match exactly (grid, PML width,
    /// and bitwise h/dt — restart consistency is only meaningful for
    /// the same discretization); the sharded engine, if any, is
    /// rebuilt lazily from the restored global pair.
    pub fn restore(&mut self, ck: &Checkpoint) -> anyhow::Result<()> {
        anyhow::ensure!(
            ck.interior == self.domain.interior && ck.pml_width == self.domain.pml_width,
            "checkpoint grid {} + pml {} does not match run grid {} + pml {}",
            ck.interior,
            ck.pml_width,
            self.domain.interior,
            self.domain.pml_width
        );
        anyhow::ensure!(
            ck.h.to_bits() == self.domain.h.to_bits()
                && ck.dt.to_bits() == self.domain.dt.to_bits(),
            "checkpoint discretization (h={}, dt={}) does not match the run (h={}, dt={})",
            ck.h,
            ck.dt,
            self.domain.h,
            self.domain.dt
        );
        anyhow::ensure!(
            ck.traces.len() == self.receivers.len(),
            "checkpoint carries {} receiver traces, run has {} receivers",
            ck.traces.len(),
            self.receivers.len()
        );
        let want = self.u_pad.as_slice().len();
        anyhow::ensure!(
            ck.u_pad.len() == want && ck.um_pad.len() == want,
            "checkpoint buffers ({} / {} floats) do not match the padded grid ({} floats)",
            ck.u_pad.len(),
            ck.um_pad.len(),
            want
        );
        let steps = usize::try_from(ck.steps_done)
            .map_err(|_| anyhow::anyhow!("checkpoint step cursor {} overflows", ck.steps_done))?;
        self.u_pad.as_mut_slice().copy_from_slice(&ck.u_pad);
        self.um_pad.as_mut_slice().copy_from_slice(&ck.um_pad);
        self.traces = ck.traces.clone();
        self.energy_log = ck.energy_log.clone();
        self.steps_done = steps;
        self.launches = ck.launches;
        self.soft_abort = None;
        self.shard = None;
        Ok(())
    }

    /// FNV-1a digest of (step cursor, u bits, um bits): bitwise state
    /// identity in one printable number, used by the CI restart smoke
    /// to compare an interrupted-and-restored run against an
    /// uninterrupted one.
    pub fn state_digest(&self) -> u64 {
        recovery::state_digest(
            self.steps_done as u64,
            self.u_pad.as_slice(),
            self.um_pad.as_slice(),
        )
    }

    /// Serialize + atomically write a snapshot to the configured path
    /// (no-op without one). Shared by the cadence and breaker-trip
    /// paths; bumps the `hostencil_checkpoint_*` series and emits a
    /// `checkpoint` flight-recorder event.
    fn write_checkpoint(&mut self) -> anyhow::Result<()> {
        let Some(path) = self.checkpoint_path.clone() else {
            return Ok(());
        };
        let t0 = Instant::now();
        let bytes = self.checkpoint().to_bytes();
        recovery::rotate_ring(&path, self.checkpoint_keep)?;
        recovery::write_atomic_with(&path, &bytes, self.faults.as_deref())?;
        if let Some(tel) = &self.telemetry {
            tel.ckpt_writes.inc();
            tel.ckpt_bytes.add(bytes.len() as u64);
            tel.ckpt_last_step.set(self.steps_done as i64);
            tel.ckpt_latency.observe(t0.elapsed().as_secs_f64());
            if tel.registry.events().enabled() {
                tel.registry.events().emit("checkpoint", &[
                    ("step", Json::Num(self.steps_done as f64)),
                    ("bytes", Json::Num(bytes.len() as f64)),
                ]);
            }
        }
        Ok(())
    }

    /// `write_checkpoint`, but a failure is counted (and logged to the
    /// flight recorder) instead of propagated: a full disk or injected
    /// write fault must not kill an otherwise healthy run — the
    /// retention ring still holds the last good snapshot.
    fn write_checkpoint_counted(&mut self) {
        if let Err(e) = self.write_checkpoint() {
            if let Some(tel) = &self.telemetry {
                tel.ckpt_failures.inc();
                if tel.registry.events().enabled() {
                    tel.registry.events().emit("checkpoint_failed", &[
                        ("step", Json::Num(self.steps_done as f64)),
                        ("error", Json::Str(e.to_string())),
                    ]);
                }
            }
        }
    }

    /// Restore from the newest *valid* snapshot in the retention ring
    /// rooted at `path` (checksum-failed slots are skipped). Returns
    /// the slot actually used plus one note per skipped slot. An armed
    /// `restore:corrupt` fault flips a byte of the newest slot first,
    /// so the fallback path is exercised deterministically.
    pub fn restore_from_ring(
        &mut self,
        path: &Path,
        keep: usize,
    ) -> anyhow::Result<(PathBuf, Vec<String>)> {
        if let Some(f) = &self.faults {
            if f.fire(FaultSite::Restore, FaultKind::Corrupt) {
                recovery::flip_byte_mid_file(path)?;
            }
        }
        let (ck, used, skipped) = recovery::load_newest_valid(path, keep)?;
        self.restore(&ck)?;
        Ok((used, skipped))
    }

    /// Run `steps` more steps, returning a summary.
    pub fn run(&mut self, steps: usize) -> anyhow::Result<RunSummary> {
        self.run_observed(steps, RunOptions::default(), None)
    }

    /// Run `steps` more steps with an optional observer. With
    /// `halt_on_non_finite` cleared, a blown-up wavefield ends the loop
    /// early (the summary's `steps` reports how far it got) instead of
    /// erroring — scenario stress runs rely on this to collect metrics
    /// from deliberately unstable configurations.
    ///
    /// Stepping happens in batches of the backend's fusion degree
    /// ([`Coordinator::fuse`], 1 for every family but `TimeFused`):
    /// a fused batch advances multiple leapfrog steps in one memory
    /// sweep, so energy/receiver recording and the observer callback
    /// happen once per batch — intermediate global states do not exist
    /// by design. For unfused backends nothing changes: batch size 1
    /// is exactly the old per-step loop.
    pub fn run_observed(
        &mut self,
        steps: usize,
        opts: RunOptions,
        mut observer: Option<&mut dyn StepObserver>,
    ) -> anyhow::Result<RunSummary> {
        // pre-reserve the per-step logs so steady-state pushes never
        // reallocate inside the timed loop
        self.energy_log.reserve(steps);
        for t in &mut self.traces {
            t.reserve(steps);
        }
        self.soft_abort = None;
        // the breaker ring is preallocated here, so armed steady-state
        // observation stays allocation-free
        let mut breaker =
            self.breaker_cfg.map(|cfg| DivergenceBreaker::new(cfg, self.auto_arm_step()));
        let t0 = Instant::now();
        let fuse = self.fuse.max(1);
        // sample_every caps the recording cadence below the backend's
        // natural fusion degree (0 keeps it)
        let cadence = match opts.sample_every {
            0 => fuse,
            n => fuse.min(n),
        };
        if let Some(tel) = &self.telemetry {
            tel.registry.events().emit("run_start", &[
                ("mode", Json::Str(format!("{:?}", self.mode))),
                ("steps", Json::Num(steps as f64)),
                ("fuse", Json::Num(fuse as f64)),
                ("cadence", Json::Num(cadence as f64)),
            ]);
        }
        let mut done = 0;
        while done < steps {
            let b = cadence.min(steps - done);
            // the fault clock tracks the step cursor: pre-batch here so
            // seam faults armed "at step s" fire inside the batch that
            // starts at s, advanced again after the batch so checkpoint
            // I/O at the boundary sees the post-batch step
            if let Some(f) = &self.faults {
                f.set_step(self.steps_done as u64);
            }
            let t_batch = Instant::now();
            if self.shard_count > 1 {
                if let Some(e) = self.step_sharded(b)? {
                    // the exchange exhausted its retry budget: the
                    // batch never became observable, so checkpoint the
                    // intact pre-batch state and soft-abort (the same
                    // checkpoint-and-halt contract the divergence
                    // breakers honor — never a panic, never a torn
                    // wavefield)
                    if let Some(tel) = &self.telemetry {
                        tel.breaker_halo_trips.inc();
                        tel.registry.events().emit("watchdog_trip", &[
                            ("kind", Json::Str(BreakerKind::HaloStall.name().to_string())),
                            ("step", Json::Num(self.steps_done as f64)),
                            ("detail", Json::Str(e.to_string())),
                        ]);
                    }
                    self.write_checkpoint_counted();
                    self.soft_abort = Some(SoftAbort {
                        kind: BreakerKind::HaloStall,
                        step: self.steps_done,
                        detail: e.to_string(),
                    });
                    break;
                }
            } else if b <= 1 {
                self.step()?;
            } else {
                self.step_fused(b)?;
            }
            done += b;
            if let Some(f) = &self.faults {
                f.set_step(self.steps_done as u64);
            }
            // the step/batch just logged its energy; a finite f32 field
            // always sums to a finite f64, so a non-finite energy is an
            // exact (and O(1)-here) proxy for a non-finite wavefield.
            let energy = self.energy_log.last().copied().unwrap_or(0.0);
            if let Some(tel) = &self.telemetry {
                tel.batches.inc();
                tel.batch_latency.observe(t_batch.elapsed().as_secs_f64());
                if tel.registry.events().enabled() {
                    tel.registry.events().emit("batch", &[
                        ("step", Json::Num(self.steps_done as f64)),
                        ("b", Json::Num(b as f64)),
                        ("secs", Json::Num(t_batch.elapsed().as_secs_f64())),
                        ("energy", Json::Num(energy)),
                    ]);
                }
            }
            if let Some(obs) = observer.as_deref_mut() {
                obs.on_step(self.steps_done, &self.u_pad, energy);
            }
            let tripped = breaker.as_mut().and_then(|br| br.observe(self.steps_done, energy));
            if !energy.is_finite() {
                if let Some(tel) = &self.telemetry {
                    tel.nonfinite.inc();
                    tel.registry.events().emit("watchdog_nonfinite", &[
                        ("step", Json::Num(self.steps_done as f64)),
                        ("halting", Json::Bool(opts.halt_on_non_finite)),
                    ]);
                }
                // with breakers armed, the NaN-rate budget owns the
                // halting decision (a trip soft-aborts below)
                if breaker.is_none() {
                    anyhow::ensure!(
                        !opts.halt_on_non_finite,
                        "wavefield blew up at step {} (CFL violation? dt={}, h={})",
                        self.steps_done,
                        self.domain.dt,
                        self.domain.h
                    );
                    // NaN/Inf only spreads from here; stop stepping.
                    break;
                }
            }
            if let Some(kind) = tripped {
                let cfg = self.breaker_cfg.unwrap_or_default();
                let detail = match kind {
                    BreakerKind::EnergyGrowth => format!(
                        "energy {energy:.3e} at step {} exceeded {}x the oldest sample in a \
                         {}-batch window",
                        self.steps_done, cfg.energy_ratio, cfg.energy_window
                    ),
                    BreakerKind::NanRate => format!(
                        "non-finite energy at step {} exceeded the NaN budget of {}",
                        self.steps_done, cfg.nan_budget
                    ),
                };
                if let Some(tel) = &self.telemetry {
                    match kind {
                        BreakerKind::EnergyGrowth => tel.breaker_energy_trips.inc(),
                        BreakerKind::NanRate => tel.breaker_nan_trips.inc(),
                    }
                    tel.registry.events().emit("watchdog_trip", &[
                        ("kind", Json::Str(kind.name().to_string())),
                        ("step", Json::Num(self.steps_done as f64)),
                        ("energy", Json::Num(energy)),
                    ]);
                }
                // checkpoint-and-halt: preserve the last pre-abort
                // state for post-mortem restore (no-op without a path;
                // a failed write is counted, the ring keeps the last
                // good snapshot)
                self.write_checkpoint_counted();
                self.soft_abort = Some(SoftAbort { kind, step: self.steps_done, detail });
                break;
            }
            if self.checkpoint_every > 0
                && (self.steps_done / self.checkpoint_every)
                    > ((self.steps_done - b) / self.checkpoint_every)
            {
                self.write_checkpoint_counted();
            }
        }
        let wall = t0.elapsed();
        if let Some(tel) = &self.telemetry {
            tel.registry.events().emit("run_end", &[
                ("steps_done", Json::Num(done as f64)),
                ("wall_ms", Json::Num(wall.as_secs_f64() * 1e3)),
            ]);
        }
        let u = self.wavefield();
        Ok(RunSummary {
            steps: done,
            wall,
            launches: self.launches,
            final_max_abs: u.max_abs(),
            final_energy: u.energy(),
            points_per_sec: (self.domain.interior.volume() * done) as f64
                / wall.as_secs_f64().max(1e-12),
            energy_log: self.energy_log.clone(),
            traces: self.traces.clone(),
        })
    }

    /// Current interior wavefield.
    pub fn wavefield(&self) -> Field3 {
        self.u_pad.unpad(R)
    }

    /// Worker threads for the CPU propagator tile fan-out (0 = one per
    /// core). The campaign sets 1 because its own cell fan-out already
    /// saturates the machine.
    pub fn set_cpu_threads(&mut self, threads: usize) {
        self.cpu_threads = threads;
        self.shard = None; // the budget split is baked into the engine
    }

    /// Name of the active CPU code shape (Golden mode only).
    pub fn propagator_name(&self) -> Option<&'static str> {
        self.propagator.as_ref().map(|p| p.name())
    }

    /// Physics signature of the active CPU code shape (Golden mode
    /// only): kind + tile dims, as used by campaign physics sharing.
    pub fn propagator_signature(&self) -> Option<String> {
        self.propagator.as_ref().map(|p| p.signature())
    }

    pub fn steps_done(&self) -> usize {
        self.steps_done
    }

    pub fn launches(&self) -> u64 {
        self.launches
    }

    pub fn eta(&self) -> &Field3 {
        &self.eta
    }

    pub fn velocity(&self) -> &Field3 {
        &self.v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil;
    use crate::wave::{self, VelocityModel};

    fn mk(mode: Mode) -> Coordinator<'static> {
        let interior = Dim3::new(24, 24, 24);
        let h = 10.0;
        let dt = stencil::cfl_dt(h, 2000.0);
        let domain = Domain::new(interior, 4, h, dt).unwrap();
        let v = VelocityModel::Constant(2000.0).build(interior);
        let eta = wave::eta_profile(&domain, 2000.0);
        let src = Source { pos: Dim3::new(12, 12, 12), f0: 15.0, amplitude: 1.0 };
        Coordinator::new(None, domain, mode, "gmem", "gmem", v, eta, src, vec![
            Dim3::new(4, 12, 12),
        ])
        .unwrap()
    }

    #[test]
    fn golden_mode_runs_without_engine() {
        let mut c = mk(Mode::Golden);
        let s = c.run(20).unwrap();
        assert_eq!(s.steps, 20);
        assert_eq!(s.launches, 7 * 20);
        assert!(s.final_max_abs > 0.0);
        assert_eq!(s.traces.len(), 1);
        assert_eq!(s.traces[0].len(), 20);
        assert_eq!(s.energy_log.len(), 20);
    }

    #[test]
    fn pjrt_mode_without_engine_fails() {
        let interior = Dim3::new(24, 24, 24);
        let domain = Domain::new(interior, 4, 10.0, 1e-3).unwrap();
        let v = Field3::full(interior, 2000.0);
        let eta = Field3::zeros(interior);
        let src = Source { pos: Dim3::new(12, 12, 12), f0: 15.0, amplitude: 1.0 };
        let err = Coordinator::new(
            None, domain, Mode::Decomposed, "gmem", "gmem", v, eta, src, vec![],
        );
        assert!(err.is_err());
    }

    #[test]
    fn source_outside_interior_rejected() {
        let interior = Dim3::new(24, 24, 24);
        let domain = Domain::new(interior, 4, 10.0, 1e-3).unwrap();
        let v = Field3::full(interior, 2000.0);
        let eta = Field3::zeros(interior);
        let src = Source { pos: Dim3::new(50, 12, 12), f0: 15.0, amplitude: 1.0 };
        assert!(Coordinator::new(None, domain, Mode::Golden, "gmem", "gmem", v, eta, src, vec![]).is_err());
    }

    #[test]
    fn golden_matches_golden_propagator() {
        // The coordinator's Golden mode must agree with GoldenPropagator.
        let mut c = mk(Mode::Golden);
        let interior = c.domain.interior;
        let mut p = stencil::GoldenPropagator::new(
            c.domain,
            VelocityModel::Constant(2000.0).build(interior),
            wave::eta_profile(&c.domain, 2000.0),
        );
        let src = Dim3::new(12, 12, 12);
        for n in 0..30 {
            c.step().unwrap();
            let amp = Source { pos: src, f0: 15.0, amplitude: 1.0 }.amp_at(n, c.domain.dt, 2000.0);
            p.advance(src, amp);
        }
        let d = c.wavefield().max_abs_diff(&p.wavefield());
        assert!(d == 0.0, "coordinator and golden propagator diverged: {d}");
    }

    #[test]
    fn golden_mode_selects_code_shape_from_variant_id() {
        let mk_variant = |variant: &str| {
            let interior = Dim3::new(24, 24, 24);
            let h = 10.0;
            let dt = stencil::cfl_dt(h, 2000.0);
            let domain = Domain::new(interior, 4, h, dt).unwrap();
            let v = VelocityModel::Constant(2000.0).build(interior);
            let eta = wave::eta_profile(&domain, 2000.0);
            let src = Source { pos: Dim3::new(12, 12, 12), f0: 15.0, amplitude: 1.0 };
            Coordinator::new(None, domain, Mode::Golden, variant, "gmem", v, eta, src, vec![])
                .unwrap()
        };
        let mut base = mk_variant("naive");
        assert_eq!(base.propagator_name(), Some("naive"));
        base.run(15).unwrap();
        assert_eq!(base.launches(), 7 * 15, "one logical launch per region per step");

        for (variant, name) in [
            ("gmem", "blocked3d"),
            ("st_smem_16x16", "streaming2.5d"),
            ("st_reg_shft", "streaming2.5d"),
        ] {
            let mut c = mk_variant(variant);
            assert_eq!(c.propagator_name(), Some(name), "{variant}");
            c.set_cpu_threads(2);
            c.run(15).unwrap();
            assert_eq!(c.launches(), 7 * 15);
            let d = c.wavefield().max_abs_diff(&base.wavefield());
            assert_eq!(d, 0.0, "{variant} deviated from naive");
        }

        // semi-stencil re-associates the x chain: ULP-level agreement
        let mut semi = mk_variant("semi");
        assert_eq!(semi.propagator_name(), Some("semi_stencil"));
        semi.run(15).unwrap();
        let rel = semi.wavefield().max_abs_diff(&base.wavefield())
            / base.wavefield().max_abs().max(1e-30);
        assert!(rel < 1e-4, "semi drifted: rel {rel}");

        // unknown code shapes are rejected at construction
        let interior = Dim3::new(24, 24, 24);
        let domain = Domain::new(interior, 4, 10.0, 1e-3).unwrap();
        let v = Field3::full(interior, 2000.0);
        let eta = Field3::zeros(interior);
        let src = Source { pos: Dim3::new(12, 12, 12), f0: 15.0, amplitude: 1.0 };
        assert!(Coordinator::new(
            None, domain, Mode::Golden, "warp_specialized", "gmem", v, eta, src, vec![]
        )
        .is_err());
    }

    #[test]
    fn cpu_thread_count_does_not_change_physics() {
        let run_with = |threads: usize| {
            let mut c = mk(Mode::Golden);
            c.set_cpu_threads(threads);
            c.run(12).unwrap();
            c.wavefield()
        };
        let serial = run_with(1);
        let parallel = run_with(4);
        assert_eq!(serial.max_abs_diff(&parallel), 0.0, "tile scheduling leaked into physics");
    }

    #[test]
    fn multi_source_superposes() {
        // the update is linear: u(srcA + srcB) ~= u(srcA) + u(srcB)
        let mk_src = |pos| Source { pos, f0: 15.0, amplitude: 1.0 };
        let a_pos = Dim3::new(9, 12, 12);
        let b_pos = Dim3::new(15, 12, 12);
        let interior = Dim3::new(24, 24, 24);
        let h = 10.0;
        let dt = stencil::cfl_dt(h, 2000.0);
        let domain = Domain::new(interior, 4, h, dt).unwrap();
        let build = |srcs: &[Dim3]| -> Field3 {
            let v = VelocityModel::Constant(2000.0).build(interior);
            let eta = wave::eta_profile(&domain, 2000.0);
            let mut c = Coordinator::new(
                None, domain, Mode::Golden, "gmem", "gmem", v, eta, mk_src(srcs[0]), vec![],
            )
            .unwrap();
            for &p in &srcs[1..] {
                c.add_source(mk_src(p)).unwrap();
            }
            c.run(25).unwrap();
            c.wavefield()
        };
        let ua = build(&[a_pos]);
        let ub = build(&[b_pos]);
        let uab = build(&[a_pos, b_pos]);
        let sum = Field3::from_vec(
            interior,
            ua.as_slice().iter().zip(ub.as_slice()).map(|(&x, &y)| x + y).collect(),
        )
        .unwrap();
        let rel = uab.max_abs_diff(&sum) / sum.max_abs().max(1e-30);
        assert!(rel < 1e-3, "superposition broken: rel {rel}");
    }

    #[test]
    fn add_source_out_of_bounds_rejected() {
        let mut c = mk(Mode::Golden);
        let bad = Source { pos: Dim3::new(99, 0, 0), f0: 15.0, amplitude: 1.0 };
        assert!(c.add_source(bad).is_err());
    }

    struct Counter {
        calls: usize,
        saw_non_finite: bool,
    }

    impl StepObserver for Counter {
        fn on_step(&mut self, _step: usize, u_pad: &Field3, energy: f64) {
            self.calls += 1;
            self.saw_non_finite |= !energy.is_finite() || u_pad.has_non_finite();
        }
    }

    #[test]
    fn observer_sees_every_step() {
        let mut c = mk(Mode::Golden);
        let mut obs = Counter { calls: 0, saw_non_finite: false };
        let s = c.run_observed(12, RunOptions::default(), Some(&mut obs)).unwrap();
        assert_eq!(s.steps, 12);
        assert_eq!(obs.calls, 12);
        assert!(!obs.saw_non_finite);
    }

    fn mk_unstable() -> Coordinator<'static> {
        let interior = Dim3::new(20, 20, 20);
        let h = 10.0;
        let dt = 3.0 * stencil::cfl_dt(h, 2000.0); // well past the CFL bound
        let domain = Domain::new(interior, 4, h, dt).unwrap();
        let v = VelocityModel::Constant(2000.0).build(interior);
        let eta = wave::eta_profile(&domain, 2000.0);
        let src = Source { pos: Dim3::new(10, 10, 10), f0: 15.0, amplitude: 1.0 };
        Coordinator::new(None, domain, Mode::Golden, "gmem", "gmem", v, eta, src, vec![]).unwrap()
    }

    #[test]
    fn unstable_run_errors_by_default_but_observed_run_reports() {
        let mut c = mk_unstable();
        assert!(c.run(400).is_err(), "CFL violation must abort a plain run");

        let mut c = mk_unstable();
        let mut obs = Counter { calls: 0, saw_non_finite: false };
        let opts = RunOptions { halt_on_non_finite: false, ..RunOptions::default() };
        let s = c.run_observed(400, opts, Some(&mut obs)).unwrap();
        assert!(s.steps < 400, "blow-up should end the run early, got {}", s.steps);
        assert!(obs.saw_non_finite, "observer must witness the blow-up");
    }

    fn mk_variant_coord(variant: &str, threads: usize) -> Coordinator<'static> {
        let interior = Dim3::new(24, 24, 24);
        let h = 10.0;
        let dt = stencil::cfl_dt(h, 2000.0);
        let domain = Domain::new(interior, 4, h, dt).unwrap();
        let v = VelocityModel::Constant(2000.0).build(interior);
        let eta = wave::eta_profile(&domain, 2000.0);
        let src = Source { pos: Dim3::new(12, 12, 12), f0: 15.0, amplitude: 1.0 };
        let mut c = Coordinator::new(
            None,
            domain,
            Mode::Golden,
            variant,
            "gmem",
            v,
            eta,
            src,
            vec![Dim3::new(4, 12, 12)],
        )
        .unwrap();
        c.set_cpu_threads(threads);
        c.add_source(Source { pos: Dim3::new(6, 18, 9), f0: 20.0, amplitude: -0.5 }).unwrap();
        c
    }

    #[test]
    fn fused_runs_are_bit_identical_at_batch_boundaries() {
        // 25 steps at fuse 2 = 12 full batches + a tail step; the
        // final state (and everything derived from it) must equal the
        // per-step golden run exactly
        let mut base = mk_variant_coord("naive", 1);
        let base_summary = base.run(25).unwrap();
        for (variant, fuse) in [("tf_s2", 2usize), ("tf_s4", 4)] {
            for threads in [1usize, 3] {
                let mut c = mk_variant_coord(variant, threads);
                assert_eq!(c.fuse(), fuse, "{variant}");
                assert_eq!(c.propagator_name(), Some("time_fused"));
                let s = c.run(25).unwrap();
                assert_eq!(s.steps, 25);
                assert_eq!(s.launches, 7 * 25, "one logical launch per region per step");
                assert_eq!(
                    c.wavefield().max_abs_diff(&base.wavefield()),
                    0.0,
                    "{variant} with {threads} threads deviated from golden"
                );
                assert_eq!(s.final_energy, base_summary.final_energy, "{variant}");
                assert_eq!(s.final_max_abs, base_summary.final_max_abs, "{variant}");
                // observation happens per batch: ceil(25 / fuse) entries
                let batches = 25usize.div_ceil(fuse);
                assert_eq!(s.energy_log.len(), batches, "{variant}");
                assert_eq!(s.traces[0].len(), batches, "{variant}");
                // every recorded batch boundary matches the golden
                // per-step log at the same absolute step
                for (i, e) in s.energy_log.iter().enumerate() {
                    let step = ((i + 1) * fuse).min(25);
                    assert_eq!(
                        *e,
                        base_summary.energy_log[step - 1],
                        "{variant}: energy at batch {i} (step {step})"
                    );
                }
            }
        }
    }

    #[test]
    fn sharded_runs_are_bit_identical_to_unsharded() {
        // multi-source (one per mk_variant_coord), receivers, and PML
        // regions all straddle the 2- and 3-shard seams of a 24-deep
        // grid (seams at z = 12 and z = 8/16, pml_width = 4)
        let mut base = mk_variant_coord("naive", 1);
        let base_summary = base.run(25).unwrap();
        for (variant, fuse) in [("naive", 1usize), ("tf_s2", 2)] {
            for shards in [2usize, 3] {
                let mut c = mk_variant_coord(variant, 2);
                c.set_shards(shards).unwrap();
                assert_eq!(c.shards(), shards);
                let s = c.run(25).unwrap();
                assert_eq!(s.steps, 25);
                assert_eq!(
                    s.launches,
                    (shards * 25) as u64,
                    "one logical launch per shard per step"
                );
                assert_eq!(
                    c.wavefield().max_abs_diff(&base.wavefield()),
                    0.0,
                    "{variant} x {shards} shards deviated from the unsharded oracle"
                );
                assert_eq!(s.final_energy, base_summary.final_energy, "{variant} x {shards}");
                let batches = 25usize.div_ceil(fuse);
                assert_eq!(s.energy_log.len(), batches, "{variant} x {shards}");
                assert_eq!(s.traces[0].len(), batches, "{variant} x {shards}");
                for (i, e) in s.energy_log.iter().enumerate() {
                    let step = ((i + 1) * fuse).min(25);
                    assert_eq!(
                        *e,
                        base_summary.energy_log[step - 1],
                        "{variant} x {shards}: energy at batch {i} (step {step})"
                    );
                }
            }
        }
    }

    #[test]
    fn set_shards_rejects_infeasible_decompositions() {
        // 24 z-planes over 2 shards is 12 < the s=4 halo depth of 16
        let mut c = mk_variant_coord("tf_s4", 1);
        let err = c.set_shards(2).unwrap_err().to_string();
        assert!(err.contains("fused halo needs 16"), "got: {err}");
        // more shards than z-planes is degenerate outright
        let mut c = mk_variant_coord("naive", 1);
        assert!(c.set_shards(25).is_err());
        // shards = 1 resets to the unsharded path cleanly
        let mut c = mk_variant_coord("tf_s2", 1);
        c.set_shards(2).unwrap();
        c.set_shards(1).unwrap();
        let s = c.run(10).unwrap();
        assert_eq!(s.launches, 7 * 10, "unsharded launch bookkeeping restored");
    }

    #[test]
    fn sharded_telemetry_counts_halo_exchanges() {
        let mut c = mk_variant_coord("tf_s2", 1);
        c.set_shards(2).unwrap();
        let reg = crate::telemetry::Registry::new();
        c.set_telemetry(&reg);
        let mut obs = Counter { calls: 0, saw_non_finite: false };
        let s = c.run_observed(10, RunOptions::default(), Some(&mut obs)).unwrap();
        assert_eq!(s.steps, 10);
        assert_eq!(obs.calls, 5, "observer fires once per fused shard batch");
        let text = reg.render();
        assert!(text.contains("hostencil_steps_total 10"), "{text}");
        // 5 batches x 1 seam
        assert!(text.contains("hostencil_halo_exchanges_total 5"), "{text}");
        // 5 batches x 1 seam x 2 bands x 2 levels x 8*24*24 floats x 4 bytes
        assert!(text.contains("hostencil_halo_bytes_total 368640"), "{text}");
        assert!(text.contains("hostencil_halo_exchange_latency_seconds_count 5"), "{text}");
        // one plan build per shard, under the "shard" family label
        assert!(text.contains("hostencil_plan_builds_total{family=\"shard\"} 2"), "{text}");
    }

    #[test]
    fn fused_observer_fires_once_per_batch() {
        let mut c = mk_variant_coord("tf_s2", 1);
        let mut obs = Counter { calls: 0, saw_non_finite: false };
        let s = c.run_observed(10, RunOptions::default(), Some(&mut obs)).unwrap();
        assert_eq!(s.steps, 10);
        assert_eq!(obs.calls, 5, "fuse 2 observes at batch boundaries");
        assert!(!obs.saw_non_finite);
        // unfused backends keep the old per-step cadence
        let mut c = mk_variant_coord("gmem", 1);
        assert_eq!(c.fuse(), 1);
        let mut obs = Counter { calls: 0, saw_non_finite: false };
        c.run_observed(10, RunOptions::default(), Some(&mut obs)).unwrap();
        assert_eq!(obs.calls, 10);
    }

    #[test]
    fn sample_every_restores_per_step_traces_under_fusion() {
        // an s=4 fused backend normally records ceil(10/4) = 3 batch
        // boundaries; --sample-every 1 must restore the full per-step
        // trace, bit-identical to the unfused run
        let mut base = mk_variant_coord("naive", 1);
        let su = base.run(10).unwrap();
        assert_eq!(su.energy_log.len(), 10);

        let mut fused = mk_variant_coord("tf_s4", 1);
        let sf = fused.run(10).unwrap();
        assert_eq!(sf.energy_log.len(), 3, "natural cadence is per fused batch");

        let mut fused = mk_variant_coord("tf_s4", 1);
        let opts = RunOptions { sample_every: 1, ..RunOptions::default() };
        let sf = fused.run_observed(10, opts, None).unwrap();
        assert_eq!(sf.energy_log.len(), su.energy_log.len());
        assert_eq!(sf.traces[0].len(), su.traces[0].len());
        for (i, (a, b)) in sf.energy_log.iter().zip(&su.energy_log).enumerate() {
            assert_eq!(a, b, "energy diverged at step {i}");
        }

        // intermediate cadences cap, never stretch, the batch size
        let mut fused = mk_variant_coord("tf_s4", 1);
        let opts = RunOptions { sample_every: 2, ..RunOptions::default() };
        let sf = fused.run_observed(10, opts, None).unwrap();
        assert_eq!(sf.energy_log.len(), 5);
        // unfused backends are unaffected by a larger sample_every
        let mut c = mk_variant_coord("naive", 1);
        let opts = RunOptions { sample_every: 4, ..RunOptions::default() };
        let s = c.run_observed(10, opts, None).unwrap();
        assert_eq!(s.energy_log.len(), 10);
    }

    #[test]
    fn telemetry_counts_steps_injections_and_batches() {
        let mut c = mk_variant_coord("tf_s2", 1);
        let reg = crate::telemetry::Registry::new();
        reg.events().to_memory();
        c.set_telemetry(&reg);
        c.run(10).unwrap();
        let text = reg.render();
        assert!(text.contains("hostencil_steps_total 10"), "{text}");
        // two sources (constructor + add_source) x 10 steps
        assert!(text.contains("hostencil_source_injections_total 20"), "{text}");
        assert!(text.contains("hostencil_batches_total 5"), "{text}");
        assert!(text.contains("hostencil_batch_latency_seconds_count 5"), "{text}");
        assert!(
            text.contains("hostencil_plan_builds_total{family=\"time_fused\"} 1"),
            "{text}"
        );
        assert!(text.contains("hostencil_pool_workers"), "{text}");
        let lines = reg.events().lines();
        assert!(lines.iter().any(|l| l.contains("\"event\":\"run_start\"")), "{lines:?}");
        assert!(lines.iter().any(|l| l.contains("\"event\":\"plan_build\"")), "{lines:?}");
        assert!(lines.iter().any(|l| l.contains("\"event\":\"batch\"")), "{lines:?}");
        assert!(lines.iter().any(|l| l.contains("\"event\":\"run_end\"")), "{lines:?}");
    }

    #[test]
    fn telemetry_watchdog_counts_blowups() {
        let mut c = mk_unstable();
        let reg = crate::telemetry::Registry::new();
        c.set_telemetry(&reg);
        let opts = RunOptions { halt_on_non_finite: false, ..RunOptions::default() };
        c.run_observed(400, opts, None).unwrap();
        assert!(
            reg.render().contains("hostencil_watchdog_nonfinite_total 1"),
            "watchdog must record exactly one non-finite observation"
        );
    }

    #[test]
    fn mode_parse() {
        assert_eq!(Mode::parse("golden").unwrap(), Mode::Golden);
        assert_eq!(Mode::parse("decomposed").unwrap(), Mode::Decomposed);
        assert!(Mode::parse("warp").is_err());
        assert!(Mode::Fused.needs_engine());
        assert!(!Mode::Golden.needs_engine());
    }

    #[test]
    fn checkpoint_restore_resumes_bitwise() {
        // uninterrupted oracle
        let mut full = mk_variant_coord("naive", 1);
        let full_summary = full.run(25).unwrap();

        // interrupted run: 10 steps, snapshot through the serialized
        // byte format, restore into a *fresh* coordinator, finish
        let mut a = mk_variant_coord("naive", 1);
        a.run(10).unwrap();
        let ck = Checkpoint::from_bytes(&a.checkpoint().to_bytes()).unwrap();
        assert_eq!(ck.steps_done, 10);

        let mut b = mk_variant_coord("naive", 1);
        b.restore(&ck).unwrap();
        assert_eq!(b.steps_done(), 10);
        let resumed = b.run(15).unwrap();
        assert_eq!(b.steps_done(), 25);
        assert_eq!(b.state_digest(), full.state_digest(), "restored state digest diverged");
        assert_eq!(b.wavefield().max_abs_diff(&full.wavefield()), 0.0);
        assert_eq!(resumed.final_energy, full_summary.final_energy);
        // the restored traces splice seamlessly onto the recording
        assert_eq!(resumed.traces, full_summary.traces);
        assert_eq!(resumed.energy_log, full_summary.energy_log);
        assert_eq!(resumed.launches, full_summary.launches);
    }

    #[test]
    fn restore_rejects_mismatched_configurations() {
        let a = mk_variant_coord("naive", 1);
        let mut b = mk_variant_coord("naive", 1);

        let mut ck = a.checkpoint();
        ck.dt *= 2.0;
        let err = b.restore(&ck).unwrap_err().to_string();
        assert!(err.contains("discretization"), "{err}");

        let mut ck = a.checkpoint();
        ck.u_pad.pop();
        assert!(b.restore(&ck).is_err(), "short buffer must be rejected");

        let mut ck = a.checkpoint();
        ck.traces.push(Vec::new());
        let err = b.restore(&ck).unwrap_err().to_string();
        assert!(err.contains("receiver"), "{err}");

        let mut ck = a.checkpoint();
        ck.interior = Dim3::new(8, 8, 8);
        assert!(b.restore(&ck).is_err(), "grid mismatch must be rejected");
    }

    #[test]
    fn energy_breaker_soft_aborts_unstable_runs_sharded_and_not() {
        for shards in [1usize, 2] {
            let mut c = mk_unstable();
            if shards > 1 {
                c.set_shards(shards).unwrap();
            }
            c.set_breakers(Some(BreakerConfig {
                energy_window: 4,
                energy_ratio: 10.0,
                arm_step: Some(4),
                nan_budget: 0,
            }));
            let reg = crate::telemetry::Registry::new();
            c.set_telemetry(&reg);
            reg.events().to_memory();
            // halt_on_non_finite defaults true, yet the armed breaker
            // converts divergence into a soft abort, not a hard error
            let s = c.run(400).expect("breaker must soft-abort, not error");
            assert!(s.steps < 400, "shards={shards}: breaker should end the run early");
            let abort = c.soft_abort().expect("breaker must have tripped");
            assert_eq!(abort.kind, BreakerKind::EnergyGrowth, "shards={shards}");
            assert!(abort.detail.contains("window"), "{}", abort.detail);
            let text = reg.render();
            assert!(
                text.contains("hostencil_breaker_trips_total{kind=\"energy_growth\"} 1"),
                "{text}"
            );
            let lines = reg.events().lines();
            assert!(
                lines.iter().any(|l| l.contains("\"event\":\"watchdog_trip\"")),
                "{lines:?}"
            );
        }
    }

    #[test]
    fn energy_breaker_stays_quiet_on_stable_runs() {
        // default config (auto-arm waits out the Ricker ramp, whose
        // super-exponential energy growth would otherwise false-trip):
        // a stable run must step to the budget with the window armed
        // and full, sharded or not
        for shards in [1usize, 2] {
            let mut c = mk_variant_coord("tf_s2", 1);
            if shards > 1 {
                c.set_shards(shards).unwrap();
            }
            c.set_breakers(Some(BreakerConfig::default()));
            // auto-arm = ceil(3 / (f0_min * dt)); run well past it so
            // the 16-batch window fills and compares repeatedly on
            // PML-decaying energy
            let arm = (3.0 / (15.0 * c.domain.dt)).ceil() as usize;
            let steps = arm + 2 * 16 * 2 + 10;
            let s = c.run(steps).unwrap();
            assert_eq!(s.steps, steps, "shards={shards}: stable run must reach the budget");
            assert!(c.soft_abort().is_none(), "shards={shards}: false positive trip");
        }
    }

    #[test]
    fn nan_breaker_trips_and_writes_a_checkpoint() {
        let path = std::env::temp_dir()
            .join(format!("hostencil_trip_ckpt_{}.ckpt", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let mut c = mk_unstable();
        c.set_checkpointing(0, Some(path.clone()));
        // arm the energy window past the horizon so only the NaN-rate
        // breaker can fire
        c.set_breakers(Some(BreakerConfig {
            arm_step: Some(usize::MAX),
            ..BreakerConfig::default()
        }));
        let s = c.run(400).unwrap();
        assert!(s.steps < 400);
        let abort = c.soft_abort().expect("NaN-rate breaker must trip");
        assert_eq!(abort.kind, BreakerKind::NanRate);
        let ck = Checkpoint::load(&path).expect("trip must leave a checkpoint behind");
        let _ = std::fs::remove_file(&path);
        assert_eq!(ck.steps_done as usize, c.steps_done());
    }

    #[test]
    fn cadence_checkpoints_cross_step_multiples() {
        let path = std::env::temp_dir()
            .join(format!("hostencil_cadence_ckpt_{}.ckpt", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let mut c = mk_variant_coord("tf_s2", 1);
        let reg = crate::telemetry::Registry::new();
        c.set_telemetry(&reg);
        c.set_checkpointing(6, Some(path.clone()));
        c.run(10).unwrap();
        // batch boundaries land at steps 2,4,6,8,10; only the step-6
        // boundary crosses a multiple of 6
        let ck = Checkpoint::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(ck.steps_done, 6, "fused cadence writes at the first boundary past 6");
        let text = reg.render();
        assert!(text.contains("hostencil_checkpoint_writes_total 1"), "{text}");
        assert!(text.contains("hostencil_checkpoint_last_step 6"), "{text}");
    }

    #[test]
    fn halo_stall_soft_aborts_with_a_restorable_checkpoint() {
        let path = std::env::temp_dir()
            .join(format!("hostencil_halo_stall_ckpt_{}.ckpt", std::process::id()));
        let _ = std::fs::remove_file(&path);
        // clean sharded oracle for the resume comparison
        let mut oracle = mk_variant_coord("tf_s2", 1);
        oracle.set_shards(2).unwrap();
        oracle.run(24).unwrap();

        let mut c = mk_variant_coord("tf_s2", 1);
        c.set_shards(2).unwrap();
        c.set_checkpointing(0, Some(path.clone()));
        // a short deadline so the injected stall escalates in
        // milliseconds instead of the production 200ms
        c.set_halo_deadline(Duration::from_millis(5));
        let reg = crate::telemetry::Registry::new();
        c.set_telemetry(&reg);
        c.set_faults(FaultPlan::single(FaultSite::Halo, FaultKind::Delay, 8, 3));
        let s = c.run(24).expect("a halo stall must soft-abort, not error");
        assert_eq!(s.steps, 8, "the stalled batch must never become observable");
        let abort = c.soft_abort().expect("halo stall must trip the breaker");
        assert_eq!(abort.kind, BreakerKind::HaloStall);
        assert_eq!(abort.step, 8);
        assert!(abort.detail.contains("transport stalled"), "{}", abort.detail);
        assert!(abort.detail.contains("halo exchange failed"), "{}", abort.detail);
        let text = reg.render();
        assert!(text.contains("hostencil_breaker_trips_total{kind=\"halo_stall\"} 1"), "{text}");
        assert!(text.contains("hostencil_fault_injected_total{site=\"halo\"} 1"), "{text}");

        // the trip checkpoint holds the intact pre-batch state and
        // resumes to a bit-identical completion
        let ck = Checkpoint::load(&path).expect("trip must leave a checkpoint behind");
        let _ = std::fs::remove_file(&path);
        assert_eq!(ck.steps_done, 8);
        let mut resumed = mk_variant_coord("tf_s2", 1);
        resumed.set_shards(2).unwrap();
        resumed.restore(&ck).unwrap();
        resumed.run(24 - ck.steps_done as usize).unwrap();
        assert_eq!(
            resumed.state_digest(),
            oracle.state_digest(),
            "restore + resume must converge on the unfaulted run"
        );
    }

    #[test]
    fn injected_checkpoint_enospc_is_counted_and_the_ring_keeps_rolling() {
        let dir = std::env::temp_dir()
            .join(format!("hostencil_coord_ring_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.ckpt");
        let mut c = mk_variant_coord("naive", 1);
        let reg = crate::telemetry::Registry::new();
        c.set_telemetry(&reg);
        c.set_checkpointing(3, Some(path.clone()));
        c.set_checkpoint_keep(2);
        c.set_faults(FaultPlan::single(FaultSite::Checkpoint, FaultKind::Enospc, 6, 9));
        let s = c.run(12).expect("a failed cadence write must not kill the run");
        assert_eq!(s.steps, 12);
        // writes attempted at 3, 6, 9, 12; the step-6 write hits the
        // injected ENOSPC after rotation, so the ring ends holding the
        // two newest *successful* snapshots
        let ring = recovery::ring_paths(&path, 2);
        assert_eq!(Checkpoint::load(&ring[0]).unwrap().steps_done, 12);
        assert_eq!(Checkpoint::load(&ring[1]).unwrap().steps_done, 9);
        let text = reg.render();
        assert!(text.contains("hostencil_checkpoint_failures_total 1"), "{text}");
        assert!(text.contains("hostencil_checkpoint_writes_total 3"), "{text}");
        assert!(text.contains("hostencil_fault_injected_total{site=\"ckpt\"} 1"), "{text}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn restore_falls_back_past_an_injected_corrupt_newest_slot() {
        let dir = std::env::temp_dir()
            .join(format!("hostencil_coord_fallback_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.ckpt");
        // produce a two-slot ring: run.ckpt at step 6, run.ckpt.1 at 3
        let mut writer = mk_variant_coord("naive", 1);
        writer.set_checkpointing(3, Some(path.clone()));
        writer.set_checkpoint_keep(2);
        writer.run(6).unwrap();
        let at6 = writer.state_digest();

        let mut c = mk_variant_coord("naive", 1);
        c.set_faults(FaultPlan::single(FaultSite::Restore, FaultKind::Corrupt, 0, 17));
        let (used, skipped) =
            c.restore_from_ring(&path, 2).expect("fallback must find the older slot");
        assert_eq!(used, recovery::ring_paths(&path, 2)[1], "newest slot was corrupted");
        assert_eq!(skipped.len(), 1);
        assert!(skipped[0].contains("checksum"), "{}", skipped[0]);
        assert_eq!(c.steps_done(), 3);
        // the fallback snapshot resumes onto the writer's trajectory
        c.run(3).unwrap();
        assert_eq!(c.state_digest(), at6, "resume from the older slot must reconverge");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
