//! Micro-benchmark harness (substrate — no `criterion` in the offline
//! crate set). Deterministic warmup + sampling with robust statistics;
//! bench binaries print one line per case plus optional CSV.

use std::time::{Duration, Instant};

/// Robust statistics over one benchmarked case.
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub samples: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    pub p95: Duration,
    pub max: Duration,
}

impl Stats {
    fn from_samples(name: &str, mut s: Vec<Duration>) -> Stats {
        s.sort();
        let n = s.len();
        let mean = s.iter().sum::<Duration>() / n as u32;
        // even sample counts average the two middle samples; taking
        // s[n/2] alone biased the median high by up to half the
        // inter-sample spread
        let median = if n % 2 == 0 { (s[n / 2 - 1] + s[n / 2]) / 2 } else { s[n / 2] };
        Stats {
            name: name.to_string(),
            samples: n,
            min: s[0],
            median,
            mean,
            p95: s[(n * 95 / 100).min(n - 1)],
            max: s[n - 1],
        }
    }

    pub fn line(&self) -> String {
        format!(
            "{:40} n={:<3} min={:>10.3?} med={:>10.3?} mean={:>10.3?} p95={:>10.3?}",
            self.name, self.samples, self.min, self.median, self.mean, self.p95
        )
    }
}

/// Benchmark runner: time `f` for `samples` iterations after `warmup`
/// throwaway iterations. Warm-up runs execute the closure but are
/// never sampled, so first-touch page faults, lazy init, and cold
/// caches stay out of the statistics; report `min` (also on every
/// `line()` and in the bench JSON) for steady-state throughput and
/// `median`/`mean` for whole-run behavior.
pub struct Bencher {
    pub warmup: usize,
    pub samples: usize,
    results: Vec<Stats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 2, samples: 10, results: Vec::new() }
    }
}

impl Bencher {
    pub fn new(warmup: usize, samples: usize) -> Bencher {
        assert!(samples >= 1);
        Bencher { warmup, samples, results: Vec::new() }
    }

    /// Honors `HOSTENCIL_BENCH_SAMPLES` / `HOSTENCIL_BENCH_WARMUP` env
    /// overrides so CI can run quick smoke benches.
    pub fn from_env() -> Bencher {
        let read = |k: &str, d: usize| {
            std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
        };
        Bencher::new(read("HOSTENCIL_BENCH_WARMUP", 1), read("HOSTENCIL_BENCH_SAMPLES", 5))
    }

    /// Time a closure; its return value is black-boxed to keep the work.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Stats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        let stats = Stats::from_samples(name, samples);
        println!("{}", stats.line());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    /// Emit all results as CSV (name, median_ns, mean_ns, min_ns, p95_ns).
    pub fn csv(&self) -> String {
        let mut out = String::from("name,median_ns,mean_ns,min_ns,p95_ns\n");
        for s in &self.results {
            out.push_str(&format!(
                "{},{},{},{},{}\n",
                s.name,
                s.median.as_nanos(),
                s.mean.as_nanos(),
                s.min.as_nanos(),
                s.p95.as_nanos()
            ));
        }
        out
    }
}

/// Least-squares Amdahl fit over a thread sweep: given measured
/// `(threads, rate)` samples including a 1-thread baseline, estimate
/// the serial fraction `f` of `rate(T) = rate(1) / (f + (1 - f) / T)`.
///
/// With `x = 1/T` and `y = rate(1)/rate(T)` (the inverse speedup), the
/// model is linear in `f`: `y = f + (1 - f) x`, so the residual
/// `y - x = f (1 - x)` fits by one-parameter regression through the
/// origin: `f = sum((y - x)(1 - x)) / sum((1 - x)^2)`, clamped to
/// [0, 1]. Perfect scaling fits f = 0; a flat rate fits f = 1.
///
/// Returns `None` without a 1-thread sample, a second distinct thread
/// count, or positive rates — the fit needs a baseline and at least
/// one real scaling observation.
pub fn amdahl_serial_fraction(samples: &[(usize, f64)]) -> Option<f64> {
    let rate1 = samples
        .iter()
        .find(|&&(t, r)| t == 1 && r > 0.0)
        .map(|&(_, r)| r)?;
    let mut num = 0.0;
    let mut den = 0.0;
    for &(t, r) in samples {
        if t <= 1 || r <= 0.0 {
            continue; // T = 1 contributes nothing (1 - x = 0)
        }
        let x = 1.0 / t as f64;
        let y = rate1 / r;
        num += (y - x) * (1.0 - x);
        den += (1.0 - x) * (1.0 - x);
    }
    if den == 0.0 {
        return None;
    }
    Some((num / den).clamp(0.0, 1.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering_invariants() {
        let mut b = Bencher::new(0, 7);
        b.bench("busy", || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        let s = &b.results()[0];
        assert_eq!(s.samples, 7);
        assert!(s.min <= s.median && s.median <= s.p95 && s.p95 <= s.max);
        assert!(s.min > Duration::ZERO);
    }

    #[test]
    fn even_sample_median_averages_the_middle_pair() {
        // regression: s[n/2] on an even count took the upper-middle
        // sample instead of the midpoint
        let ns = |v: u64| Duration::from_nanos(v);
        let even = Stats::from_samples("even", vec![ns(40), ns(10), ns(100), ns(20)]);
        assert_eq!(even.median, ns(30), "median of 10,20,40,100 is (20+40)/2");
        let odd = Stats::from_samples("odd", vec![ns(30), ns(10), ns(20)]);
        assert_eq!(odd.median, ns(20));
        let pair = Stats::from_samples("pair", vec![ns(10), ns(20)]);
        assert_eq!(pair.median, ns(15));
        assert!(even.min <= even.median && even.median <= even.max);
    }

    #[test]
    fn warmup_iterations_run_but_are_never_sampled() {
        // the steady-state guarantee: K warm-up runs execute the
        // closure (touching pages, building plans) yet leave exactly
        // `samples` timed samples behind
        let mut calls = 0usize;
        let mut b = Bencher::new(3, 4);
        b.bench("warm", || {
            calls += 1;
            calls
        });
        assert_eq!(calls, 3 + 4, "warmup must execute the closure");
        let s = &b.results()[0];
        assert_eq!(s.samples, 4, "warmup runs must not be sampled");
        assert!(s.min <= s.median, "min is the steady-state floor");
    }

    #[test]
    fn amdahl_fit_recovers_known_serial_fractions() {
        let rate = |f: f64, t: usize| 1000.0 / (f + (1.0 - f) / t as f64);
        let sweep = |f: f64| -> Vec<(usize, f64)> {
            [1, 2, 4, 8].iter().map(|&t| (t, rate(f, t))).collect()
        };
        // perfect scaling -> fully parallel; flat -> fully serial
        assert!(amdahl_serial_fraction(&sweep(0.0)).unwrap() < 1e-9);
        assert!((amdahl_serial_fraction(&sweep(1.0)).unwrap() - 1.0).abs() < 1e-9);
        // exact synthetic fractions recover to rounding error
        for f in [0.1, 0.35, 0.5, 0.8] {
            let got = amdahl_serial_fraction(&sweep(f)).unwrap();
            assert!((got - f).abs() < 1e-9, "f={f} got {got}");
        }
        // noisy data still lands in the right neighborhood
        let noisy: Vec<(usize, f64)> =
            sweep(0.3).iter().map(|&(t, r)| (t, r * (1.0 + 0.01 * t as f64))).collect();
        let got = amdahl_serial_fraction(&noisy).unwrap();
        assert!((got - 0.3).abs() < 0.1, "{got}");
    }

    #[test]
    fn amdahl_fit_rejects_degenerate_sweeps() {
        assert!(amdahl_serial_fraction(&[]).is_none());
        assert!(amdahl_serial_fraction(&[(2, 5.0), (4, 9.0)]).is_none(), "needs T=1");
        assert!(amdahl_serial_fraction(&[(1, 5.0)]).is_none(), "needs T>1");
        assert!(amdahl_serial_fraction(&[(1, 0.0), (2, 0.0)]).is_none(), "needs real rates");
        // a super-linear sweep clamps to 0 rather than going negative
        assert_eq!(amdahl_serial_fraction(&[(1, 100.0), (2, 300.0)]), Some(0.0));
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut b = Bencher::new(0, 2);
        b.bench("a", || 1);
        b.bench("b", || 2);
        let csv = b.csv();
        assert!(csv.starts_with("name,median_ns"));
        assert_eq!(csv.lines().count(), 3);
    }
}
