//! Minimal JSON parser (substrate).
//!
//! The vendored offline crate set has no `serde`/`serde_json`, so the
//! artifact manifest is parsed with this small, strict, recursive-descent
//! parser. Supports the full JSON grammar needed by `manifest.json`:
//! objects, arrays, strings (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(input: &str) -> anyhow::Result<Json> {
        let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        anyhow::ensure!(p.pos == p.bytes.len(), "trailing data at byte {}", p.pos);
        Ok(v)
    }

    pub fn as_obj(&self) -> anyhow::Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => anyhow::bail!("expected object, got {other}"),
        }
    }

    pub fn as_arr(&self) -> anyhow::Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => anyhow::bail!("expected array, got {other}"),
        }
    }

    pub fn as_str(&self) -> anyhow::Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => anyhow::bail!("expected string, got {other}"),
        }
    }

    pub fn as_f64(&self) -> anyhow::Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => anyhow::bail!("expected number, got {other}"),
        }
    }

    pub fn as_usize(&self) -> anyhow::Result<usize> {
        let n = self.as_f64()?;
        anyhow::ensure!(n >= 0.0 && n.fract() == 0.0, "expected non-negative integer, got {n}");
        Ok(n as usize)
    }

    /// Fetch a required object member.
    pub fn get(&self, key: &str) -> anyhow::Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow::anyhow!("missing key {key:?}"))
    }

    /// Emit as canonical JSON text: `parse(emit(v)) == v` for any value
    /// with finite numbers (non-finite numbers — which JSON cannot
    /// represent — emit as `null`; producers like the campaign report
    /// sanitize them to `Json::Null` up front for exact round-trips).
    pub fn emit(&self) -> String {
        let mut out = String::new();
        self.emit_into(&mut out);
        out
    }

    fn emit_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    // f64 Display is shortest-roundtrip and never uses
                    // exponent notation — always valid JSON.
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => emit_string(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.emit_into(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    emit_string(k, out);
                    out.push(':');
                    v.emit_into(out);
                }
                out.push('}');
            }
        }
    }
}

fn emit_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            '\u{8}' => out.push_str("\\b"),
            '\u{c}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => write!(f, "{n}"),
            Json::Str(s) => write!(f, "{s:?}"),
            Json::Arr(a) => write!(f, "array[{}]", a.len()),
            Json::Obj(o) => write!(f, "object{{{} keys}}", o.len()),
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.peek() == Some(b),
            "expected {:?} at byte {}, found {:?}",
            b as char,
            self.pos,
            self.peek().map(|c| c as char)
        );
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> anyhow::Result<Json> {
        let end = self.pos + lit.len();
        anyhow::ensure!(
            self.bytes.get(self.pos..end) == Some(lit.as_bytes()),
            "invalid literal at byte {}",
            self.pos
        );
        self.pos = end;
        Ok(v)
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => anyhow::bail!("expected ',' or '}}', found {other:?}"),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                other => anyhow::bail!("expected ',' or ']', found {other:?}"),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow::anyhow!("truncated \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => anyhow::bail!("bad escape {other:?}"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let rest = std::str::from_utf8(&self.bytes[start..])?;
                    let ch = rest
                        .chars()
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("truncated string at byte {start}"))?;
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
                None => anyhow::bail!("unterminated string"),
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested_structure() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str().unwrap(), "c");
        assert!(j.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn parses_unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn truncated_strings_error_instead_of_panicking() {
        // every cut point of a string with escapes must produce a
        // parse error, never a panic (regression: the bare-character
        // arm used to unwrap the next scalar)
        let full = r#"{"k": "aA\n\\b"}"#;
        for cut in 1..full.len() {
            if let Some(prefix) = full.get(..cut) {
                assert!(Json::parse(prefix).is_err(), "cut at {cut}: {prefix:?}");
            }
        }
        // escape introducer at EOF
        assert!(Json::parse("\"\\").is_err());
        // truncated \u escapes, empty through three hex digits
        assert!(Json::parse("\"\\u").is_err());
        assert!(Json::parse("\"\\u1").is_err());
        assert!(Json::parse("\"\\u12").is_err());
        assert!(Json::parse("\"\\u123").is_err());
    }

    #[test]
    fn accessors_type_check() {
        let j = Json::parse(r#"{"n": 3, "s": "x"}"#).unwrap();
        assert_eq!(j.get("n").unwrap().as_usize().unwrap(), 3);
        assert!(j.get("s").unwrap().as_f64().is_err());
        assert!(j.get("missing").is_err());
        assert!(Json::parse("1.5").unwrap().as_usize().is_err());
    }

    #[test]
    fn emit_renders_canonical_text() {
        let j = Json::parse(r#"{"b": [1, 2.5, true, null], "a": "x\ny"}"#).unwrap();
        // BTreeMap keys sort, integers drop the fraction, escapes survive
        assert_eq!(j.emit(), r#"{"a":"x\ny","b":[1,2.5,true,null]}"#);
        assert_eq!(Json::Num(3.0).emit(), "3");
        assert_eq!(Json::Num(-0.125).emit(), "-0.125");
        assert_eq!(Json::Str("q\"\\".into()).emit(), r#""q\"\\""#);
        assert_eq!(Json::Str("\u{1}".into()).emit(), "\"\\u0001\"");
    }

    #[test]
    fn emit_parse_roundtrip_is_identity() {
        let text = r#"{
          "nested": {"arr": [1, -2.75, "s", {"k": null}], "t": true},
          "big": 123456789, "tiny": 0.001
        }"#;
        let j = Json::parse(text).unwrap();
        let j2 = Json::parse(&j.emit()).unwrap();
        assert_eq!(j, j2);
        // emitting twice is a fixed point
        assert_eq!(j.emit(), j2.emit());
    }

    #[test]
    fn emit_sanitizes_non_finite_to_null() {
        assert_eq!(Json::Num(f64::NAN).emit(), "null");
        assert_eq!(Json::Num(f64::INFINITY).emit(), "null");
        let arr = Json::Arr(vec![Json::Num(1.0), Json::Num(f64::NEG_INFINITY)]);
        assert_eq!(arr.emit(), "[1,null]");
        assert!(Json::parse(&arr.emit()).is_ok());
    }

    #[test]
    fn roundtrips_real_manifest_shape() {
        let text = r#"{
          "format_version": 1,
          "spec": {"interior": [48,48,48], "pml_width": 8, "h": 10.0, "dt": 0.001, "halo": 4},
          "artifacts": [
            {"name": "inner_gmem", "file": "inner_gmem.hlo.txt", "kind": "inner",
             "inputs": [{"name": "u_pad", "shape": [40,40,40]}], "output_shape": [32,32,32]}
          ]
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("format_version").unwrap().as_usize().unwrap(), 1);
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str().unwrap(), "inner_gmem");
    }
}
