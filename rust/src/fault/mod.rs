//! Deterministic fault injection: a seeded [`FaultPlan`] armed from
//! `--faults "site:kind@step[:p]"` specs, threaded as an
//! `Option<Arc<FaultPlan>>` through the seams it attacks — the halo
//! transport (`shard/`), checkpoint I/O (`recovery/`), the worker pool
//! (`runtime/pool.rs`), and snapshot restore. The plan is **zero cost
//! when absent**: every seam holds an `Option` and the disarmed path
//! is a `None` check, so the zero-allocation proofs and bit-identity
//! gates are untouched by this module's existence.
//!
//! Determinism contract: a plan is a pure function of (spec list,
//! seed, the step cursor the coordinator publishes via [`set_step`],
//! and the per-spec draw ordinal). Two runs with the same specs and
//! seed inject at the same opportunities, so every chaos verdict is
//! reproducible. Probabilistic specs (`p < 1`) draw from a splitmix64
//! hash of (seed, spec index, draw ordinal) — no global RNG, no
//! cross-test contamination.
//!
//! Each spec is **one-shot**: it arms once the run reaches its step,
//! fires at most once (the first [`fire`] call that wins the draw and
//! the atomic claim consumes it), and stays consumed for the rest of
//! the run. Injections are counted per site and exported as
//! `hostencil_fault_injected_total{site=…}`.
//!
//! [`set_step`]: FaultPlan::set_step
//! [`fire`]: FaultPlan::fire

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::telemetry::Registry;

/// Per-exchange deadline for the halo retry loop: an exchange that
/// cannot be completed within this budget is declared stalled and the
/// engine escalates to the coordinator's soft-abort path. Generous
/// against an in-process mailbox (microseconds); sized for the future
/// cross-process transport where a peer can genuinely hang.
pub const HALO_DEADLINE: Duration = Duration::from_millis(200);

/// How long an injected `halo:delay` fault stalls the transport —
/// deliberately past [`HALO_DEADLINE`], so a delay fault
/// deterministically exercises the timeout path rather than racing it.
pub const HALO_STALL: Duration = Duration::from_millis(250);

/// Bounded retry budget for one halo collect/publish.
pub const HALO_MAX_ATTEMPTS: u32 = 4;

/// Exponential-backoff base between halo retries (doubles per attempt).
pub const HALO_BACKOFF_BASE: Duration = Duration::from_micros(50);

/// Named seams a fault can be injected into.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    /// Halo exchange through the `HaloTransport` seam.
    Halo,
    /// Checkpoint write path (`recovery::write_atomic` and its ring).
    Checkpoint,
    /// Worker pool (panic inside a pool thread).
    Pool,
    /// Snapshot restore path (on-disk corruption discovered at load).
    Restore,
}

impl FaultSite {
    pub const ALL: [FaultSite; 4] =
        [FaultSite::Halo, FaultSite::Checkpoint, FaultSite::Pool, FaultSite::Restore];

    /// The spec-grammar name (`halo:drop@8` etc.) — also the telemetry
    /// `site` label value.
    pub fn name(self) -> &'static str {
        match self {
            FaultSite::Halo => "halo",
            FaultSite::Checkpoint => "ckpt",
            FaultSite::Pool => "pool",
            FaultSite::Restore => "restore",
        }
    }

    fn index(self) -> usize {
        match self {
            FaultSite::Halo => 0,
            FaultSite::Checkpoint => 1,
            FaultSite::Pool => 2,
            FaultSite::Restore => 3,
        }
    }
}

/// What goes wrong at an armed site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Halo: the transport stalls past the exchange deadline.
    Delay,
    /// Halo: one collect finds no band (transient loss; retry heals).
    Drop,
    /// Halo: a band arrives bit-corrupted (checksum must catch it).
    /// Restore: the newest snapshot on disk is bit-corrupted.
    Corrupt,
    /// Checkpoint: the write stops partway through the tmp file.
    ShortWrite,
    /// Checkpoint: the write fails like a full disk.
    Enospc,
    /// Pool: a worker thread panics before claiming a tile.
    Panic,
}

impl FaultKind {
    /// The spec-grammar name.
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::Delay => "delay",
            FaultKind::Drop => "drop",
            FaultKind::Corrupt => "corrupt",
            FaultKind::ShortWrite => "short",
            FaultKind::Enospc => "enospc",
            FaultKind::Panic => "panic",
        }
    }
}

/// The (site, kind) combinations that mean something. Anything else in
/// a spec is rejected by name at parse time.
fn valid_combo(site: FaultSite, kind: FaultKind) -> bool {
    use FaultKind::*;
    use FaultSite::*;
    matches!(
        (site, kind),
        (Halo, Delay)
            | (Halo, Drop)
            | (Halo, Corrupt)
            | (Checkpoint, ShortWrite)
            | (Checkpoint, Enospc)
            | (Checkpoint, Corrupt)
            | (Pool, Panic)
            | (Restore, Corrupt)
    )
}

fn site_names(site: FaultSite) -> &'static str {
    match site {
        FaultSite::Halo => "delay, drop, corrupt",
        FaultSite::Checkpoint => "short, enospc, corrupt",
        FaultSite::Pool => "panic",
        FaultSite::Restore => "corrupt",
    }
}

/// One armed `site:kind@step[:p]` spec.
struct Spec {
    site: FaultSite,
    kind: FaultKind,
    /// First step (inclusive) at which the spec is armed.
    step: u64,
    /// Per-opportunity injection probability in [0, 1] (default 1).
    p: f64,
    /// One-shot consumption flag: set by the winning `fire`.
    fired: AtomicBool,
    /// Draw ordinal for probabilistic specs, so the k-th opportunity
    /// draws the same value in every run with the same seed.
    draws: AtomicU64,
}

/// A parsed, seeded set of fault specs. Shared (`Arc`) across every
/// seam of one run; all state is atomic, so `fire` races resolve to
/// exactly one winner per spec.
pub struct FaultPlan {
    specs: Vec<Spec>,
    seed: u64,
    /// Step cursor, published by the coordinator before each batch so
    /// seams deep in the stack know when specs arm.
    step: AtomicU64,
    /// Injections per site, indexed by `FaultSite::index`.
    injected: [AtomicU64; 4],
}

/// splitmix64: a tiny, high-quality mixing function — deterministic
/// draws without any RNG state to carry or contaminate.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

impl FaultPlan {
    /// Parse a comma-separated `site:kind@step[:p]` list. Sites:
    /// `halo`, `ckpt`, `pool`, `restore`. Kinds per site: halo
    /// `delay|drop|corrupt`, ckpt `short|enospc|corrupt`, pool
    /// `panic`, restore `corrupt`. `p` defaults to 1 and must be in
    /// [0, 1]. Every malformed token is rejected with the offending
    /// piece named.
    pub fn parse(list: &str, seed: u64) -> anyhow::Result<FaultPlan> {
        let mut specs = Vec::new();
        for tok in list.split(',') {
            let tok = tok.trim();
            anyhow::ensure!(!tok.is_empty(), "--faults: empty spec in {list:?}");
            let (site_s, rest) = tok.split_once(':').ok_or_else(|| {
                anyhow::anyhow!("--faults: {tok:?} is not site:kind@step[:p]")
            })?;
            let site = match site_s {
                "halo" => FaultSite::Halo,
                "ckpt" => FaultSite::Checkpoint,
                "pool" => FaultSite::Pool,
                "restore" => FaultSite::Restore,
                other => anyhow::bail!(
                    "--faults: unknown site {other:?} (sites: halo, ckpt, pool, restore)"
                ),
            };
            let (kind_s, tail) = rest.split_once('@').ok_or_else(|| {
                anyhow::anyhow!("--faults: {tok:?} is missing the @step trigger")
            })?;
            let kind = match kind_s {
                "delay" => FaultKind::Delay,
                "drop" => FaultKind::Drop,
                "corrupt" => FaultKind::Corrupt,
                "short" => FaultKind::ShortWrite,
                "enospc" => FaultKind::Enospc,
                "panic" => FaultKind::Panic,
                other => anyhow::bail!(
                    "--faults: unknown kind {other:?} (kinds: delay, drop, corrupt, short, enospc, panic)"
                ),
            };
            anyhow::ensure!(
                valid_combo(site, kind),
                "--faults: {}:{} is not a valid combination ({} supports: {})",
                site.name(),
                kind.name(),
                site.name(),
                site_names(site)
            );
            let (step_s, p_s) = match tail.split_once(':') {
                Some((s, p)) => (s, Some(p)),
                None => (tail, None),
            };
            let step: u64 = step_s
                .parse()
                .map_err(|e| anyhow::anyhow!("--faults: bad step {step_s:?} in {tok:?}: {e}"))?;
            let p: f64 = match p_s {
                None => 1.0,
                Some(p) => p
                    .parse()
                    .map_err(|e| anyhow::anyhow!("--faults: bad probability {p:?} in {tok:?}: {e}"))?,
            };
            anyhow::ensure!(
                (0.0..=1.0).contains(&p),
                "--faults: probability {p} in {tok:?} is outside [0, 1]"
            );
            specs.push(Spec {
                site,
                kind,
                step,
                p,
                fired: AtomicBool::new(false),
                draws: AtomicU64::new(0),
            });
        }
        anyhow::ensure!(!specs.is_empty(), "--faults: no specs in {list:?}");
        Ok(FaultPlan {
            specs,
            seed,
            step: AtomicU64::new(0),
            injected: [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)],
        })
    }

    /// A plan holding one always-certain spec (tests, chaos matrix).
    pub fn single(site: FaultSite, kind: FaultKind, step: u64, seed: u64) -> Arc<FaultPlan> {
        let plan = FaultPlan::parse(&format!("{}:{}@{step}", site.name(), kind.name()), seed)
            .expect("single-spec grammar is valid by construction");
        Arc::new(plan)
    }

    /// Publish the run's step cursor (the coordinator calls this before
    /// each batch; seams read it inside `fire`).
    pub fn set_step(&self, step: u64) {
        self.step.store(step, Ordering::Relaxed);
    }

    /// The last published step cursor.
    pub fn step(&self) -> u64 {
        self.step.load(Ordering::Relaxed)
    }

    /// Whether any spec targets `site` — seams use this to skip
    /// fault-path setup entirely when their site is never armed.
    pub fn targets(&self, site: FaultSite) -> bool {
        self.specs.iter().any(|s| s.site == site)
    }

    /// One injection opportunity at (site, kind): returns `true` iff an
    /// armed, unconsumed spec matches, wins its probability draw, and
    /// this call wins the atomic claim. At most one `fire` per spec
    /// ever returns `true`.
    pub fn fire(&self, site: FaultSite, kind: FaultKind) -> bool {
        let now = self.step.load(Ordering::Relaxed);
        for (idx, spec) in self.specs.iter().enumerate() {
            if spec.site != site || spec.kind != kind {
                continue;
            }
            if spec.fired.load(Ordering::Relaxed) || now < spec.step {
                continue;
            }
            if spec.p < 1.0 {
                let ordinal = spec.draws.fetch_add(1, Ordering::Relaxed);
                let h = splitmix64(
                    self.seed ^ (idx as u64).wrapping_mul(0xA076_1D64_78BD_642F) ^ ordinal,
                );
                let draw = (h >> 11) as f64 / (1u64 << 53) as f64;
                if draw >= spec.p {
                    continue;
                }
            }
            if spec
                .fired
                .compare_exchange(false, true, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
            {
                self.injected[site.index()].fetch_add(1, Ordering::Relaxed);
                return true;
            }
        }
        false
    }

    /// Injections recorded against `site` so far.
    pub fn injected(&self, site: FaultSite) -> u64 {
        self.injected[site.index()].load(Ordering::Relaxed)
    }

    /// Register `hostencil_fault_injected_total{site=…}` collectors for
    /// every site (zero series surprise: all four appear, firing or
    /// not, so dashboards can alert on absence).
    pub fn register_telemetry(self: &Arc<Self>, reg: &Registry) {
        for site in FaultSite::ALL {
            let me = Arc::clone(self);
            reg.counter_fn(
                "hostencil_fault_injected_total",
                "Deterministically injected faults, by site.",
                &[("site", site.name())],
                move || me.injected(site),
            );
        }
    }
}

impl std::fmt::Debug for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let specs: Vec<String> = self
            .specs
            .iter()
            .map(|s| format!("{}:{}@{}:{}", s.site.name(), s.kind.name(), s.step, s.p))
            .collect();
        f.debug_struct("FaultPlan")
            .field("specs", &specs)
            .field("seed", &self.seed)
            .field("step", &self.step())
            .finish()
    }
}

/// Panic payload used by injected `pool:panic` faults. The pool's
/// quarantine logic downcasts for exactly this marker: an *injected*
/// panic is survivable (quarantine + respawn once), while every other
/// payload — a genuine kernel bug — still re-raises on the caller
/// exactly as before.
#[derive(Debug)]
pub struct InjectedPanic {
    /// Step cursor at injection time, for the escalation message.
    pub step: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_full_grammar_including_probability() {
        let plan = FaultPlan::parse("halo:drop@8, ckpt:short@6:0.5,pool:panic@3", 42).unwrap();
        assert_eq!(plan.specs.len(), 3);
        assert_eq!(plan.specs[0].site, FaultSite::Halo);
        assert_eq!(plan.specs[0].kind, FaultKind::Drop);
        assert_eq!(plan.specs[0].step, 8);
        assert_eq!(plan.specs[0].p, 1.0);
        assert_eq!(plan.specs[1].site, FaultSite::Checkpoint);
        assert_eq!(plan.specs[1].p, 0.5);
        assert!(plan.targets(FaultSite::Pool));
        assert!(!plan.targets(FaultSite::Restore));
    }

    #[test]
    fn rejects_malformed_specs_by_name() {
        for (spec, needle) in [
            ("disk:drop@8", "unknown site"),
            ("halo:melt@8", "unknown kind"),
            ("halo:panic@8", "not a valid combination"),
            ("pool:drop@8", "not a valid combination"),
            ("halo:drop", "missing the @step"),
            ("halo@8", "not site:kind"),
            ("halo:drop@eight", "bad step"),
            ("halo:drop@8:1.5", "outside [0, 1]"),
            ("halo:drop@8:-0.1", "outside [0, 1]"),
            ("halo:drop@8:maybe", "bad probability"),
            ("", "no specs"),
            ("halo:drop@8,,ckpt:short@2", "empty spec"),
        ] {
            let err = FaultPlan::parse(spec, 1).expect_err(spec).to_string();
            assert!(err.contains(needle), "{spec:?}: {err}");
        }
    }

    #[test]
    fn fires_once_at_or_after_the_armed_step() {
        let plan = FaultPlan::parse("halo:drop@8", 7).unwrap();
        plan.set_step(4);
        assert!(!plan.fire(FaultSite::Halo, FaultKind::Drop), "not armed yet");
        plan.set_step(8);
        assert!(!plan.fire(FaultSite::Halo, FaultKind::Corrupt), "kind must match");
        assert!(!plan.fire(FaultSite::Checkpoint, FaultKind::ShortWrite), "site must match");
        assert!(plan.fire(FaultSite::Halo, FaultKind::Drop));
        assert!(!plan.fire(FaultSite::Halo, FaultKind::Drop), "one-shot: consumed");
        plan.set_step(20);
        assert!(!plan.fire(FaultSite::Halo, FaultKind::Drop), "stays consumed");
        assert_eq!(plan.injected(FaultSite::Halo), 1);
        assert_eq!(plan.injected(FaultSite::Checkpoint), 0);
    }

    #[test]
    fn probabilistic_draws_are_deterministic_per_seed() {
        let outcomes = |seed: u64| {
            let plan = FaultPlan::parse("halo:drop@0:0.3", seed).unwrap();
            plan.set_step(1);
            (0..32).map(|_| plan.fire(FaultSite::Halo, FaultKind::Drop)).collect::<Vec<_>>()
        };
        assert_eq!(outcomes(11), outcomes(11), "same seed, same draws");
        // one-shot: at most one true in any sequence
        assert!(outcomes(11).iter().filter(|&&b| b).count() <= 1);
        // across many seeds, a p=0.3 spec must both fire and not fire
        // on the first opportunity somewhere — i.e. the draw is real
        let firsts: Vec<bool> = (0..64).map(|s| outcomes(s)[0]).collect();
        assert!(firsts.iter().any(|&b| b) && firsts.iter().any(|&b| !b));
    }

    #[test]
    fn p_zero_never_fires_and_p_one_skips_the_draw() {
        let never = FaultPlan::parse("halo:drop@0:0", 3).unwrap();
        never.set_step(100);
        for _ in 0..64 {
            assert!(!never.fire(FaultSite::Halo, FaultKind::Drop));
        }
        let always = FaultPlan::parse("halo:drop@0:1", 3).unwrap();
        always.set_step(100);
        assert!(always.fire(FaultSite::Halo, FaultKind::Drop));
    }

    #[test]
    fn telemetry_exports_all_four_sites() {
        let reg = Registry::new();
        let plan = FaultPlan::single(FaultSite::Halo, FaultKind::Drop, 0, 1);
        plan.register_telemetry(&reg);
        plan.set_step(0);
        assert!(plan.fire(FaultSite::Halo, FaultKind::Drop));
        let text = reg.render();
        assert!(text.contains("hostencil_fault_injected_total{site=\"halo\"} 1"), "{text}");
        assert!(text.contains("hostencil_fault_injected_total{site=\"ckpt\"} 0"), "{text}");
        assert!(text.contains("hostencil_fault_injected_total{site=\"pool\"} 0"), "{text}");
        assert!(text.contains("hostencil_fault_injected_total{site=\"restore\"} 0"), "{text}");
    }

    #[test]
    fn concurrent_fire_has_exactly_one_winner() {
        let plan = FaultPlan::single(FaultSite::Pool, FaultKind::Panic, 0, 9);
        plan.set_step(1);
        let wins: usize = std::thread::scope(|s| {
            (0..8)
                .map(|_| {
                    let p = Arc::clone(&plan);
                    s.spawn(move || usize::from(p.fire(FaultSite::Pool, FaultKind::Panic)))
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .sum()
        });
        assert_eq!(wins, 1);
        assert_eq!(plan.injected(FaultSite::Pool), 1);
    }
}
