//! The simulated GPU testbed (substrate).
//!
//! The paper's evaluation ran on physical V100/P100/NVS510 machines with
//! nvprof/Nsight/HPCToolkit/ERT. None of that hardware exists in this
//! environment, so — per the substitution rule — we rebuild the testbed
//! analytically:
//!
//! * [`arch`]      — microarchitectural descriptions of the three GPUs
//!                   (Table I + published SM limits + ERT-style ceilings).
//! * [`occupancy`] — a CUDA occupancy calculator. Reproduces the paper's
//!                   Table III *theoretical* warps/occupancy exactly.
//! * [`kernels`]   — descriptors of all 25 kernel variants (block shapes,
//!                   register/shared-memory footprints from Table III).
//! * [`memory`]    — an L2/DRAM transaction model per code shape.
//! * [`timing`]    — a roofline-style time model with launch-overhead,
//!                   synchronization and register-spill penalty terms
//!                   (Table II).
//! * [`roofline`]  — ERT-like machine characterization + kernel operating
//!                   points (Table IV, Figure 3).
//!
//! The model's goal is the paper's *shape* — who wins, by roughly what
//! factor, where the crossovers fall — not its absolute numbers; deltas
//! against the published tables are printed by `report` and asserted
//! (as orderings) in `rust/tests/gpusim_tables.rs`.

pub mod arch;
pub mod autotune;
pub mod kernels;
pub mod memory;
pub mod occupancy;
pub mod roofline;
pub mod timing;

pub use arch::GpuArch;
pub use kernels::{Family, KernelVariant};
pub use occupancy::{occupancy, KernelResources, Limiter, Occupancy};
