//! GPU microarchitecture descriptions for the paper's three machines
//! (Table I), with SM resource limits from NVIDIA's published occupancy
//! data and ERT-style bandwidth/compute ceilings.
//!
//! The V100 ceilings are back-derived from the paper's own Table IV
//! ("machine peak performance" at a given arithmetic intensity implies
//! the ERT-measured bandwidth: peak = AI * BW), so our roofline uses the
//! *same* ceilings the authors measured:
//!   L2:   2566 GF/s at AI 0.78  -> ~3290 GB/s
//!   DRAM: 1498 GF/s at AI 1.92  ->  ~780 GB/s

/// One GPU generation: everything the occupancy calculator, transaction
/// model and timing model need to know.
#[derive(Clone, Debug)]
pub struct GpuArch {
    pub name: &'static str,
    pub sm_version: &'static str,
    pub sm_count: u32,
    /// Max resident warps per SM.
    pub max_warps_per_sm: u32,
    /// Max resident blocks per SM.
    pub max_blocks_per_sm: u32,
    pub max_threads_per_block: u32,
    /// Register file per SM, in 32-bit registers.
    pub regs_per_sm: u32,
    /// SM partitions (processing blocks); Volta/Pascal register
    /// allocation quantizes per partition, which is what makes Table
    /// III's 48-warp theoretical numbers come out (not 50).
    pub sm_partitions: u32,
    /// Register allocation granularity per warp, in registers.
    pub reg_alloc_granularity: u32,
    /// Max shared memory usable per SM (bytes).
    pub smem_per_sm: u32,
    /// Max shared memory per block (bytes).
    pub smem_per_block: u32,
    /// Shared memory allocation granularity (bytes).
    pub smem_granularity: u32,
    pub warp_size: u32,
    /// L2 cache size (bytes).
    pub l2_bytes: u64,
    /// ERT-style measured bandwidths (GB/s) and compute peak (GF/s).
    pub dram_gbps: f64,
    pub l2_gbps: f64,
    pub fp32_gflops: f64,
    /// Kernel launch overhead (microseconds per launch).
    pub launch_overhead_us: f64,
    /// Whether L1 and shared memory are a unified block (Volta+): when a
    /// kernel uses no shared memory, the whole block acts as L1 cache,
    /// which is why gmem code shapes win on V100 (paper §V.C).
    pub unified_l1: bool,
    /// Warps per SM needed to saturate the memory system (latency hiding).
    pub warps_to_saturate: f64,
    /// Multipliers on gmem-family u-read traffic when no shared-memory
    /// staging is used: how badly this part's L1 path handles the
    /// 25-point spread (1.0 = Volta unified L1; Kepler globals bypass L1
    /// entirely).
    pub gmem_dram_penalty: f64,
    pub gmem_l2_penalty: f64,
    /// Relative cost of -maxrregcount register spills (the paper's P100
    /// and NVS510 columns show far milder spill impact than V100).
    pub spill_scale: f64,
    /// Paper Table II evaluation grid (cubic edge length).
    pub eval_grid: usize,
    /// Paper PML width used in the evaluation grid (derived from Table
    /// III grid sizes: inner 948^3 for the 1000^3 V100 grid -> W = 26).
    pub eval_pml_width: usize,
}

/// NVIDIA Tesla V100 (Volta, sm_70).
pub fn v100() -> GpuArch {
    GpuArch {
        name: "V100",
        sm_version: "sm_70",
        sm_count: 80,
        max_warps_per_sm: 64,
        max_blocks_per_sm: 32,
        max_threads_per_block: 1024,
        regs_per_sm: 65536,
        sm_partitions: 4,
        reg_alloc_granularity: 256,
        smem_per_sm: 98304,  // 96 KiB usable
        smem_per_block: 98304,
        smem_granularity: 256,
        warp_size: 32,
        l2_bytes: 6 * 1024 * 1024,
        dram_gbps: 780.0,  // ERT-implied (Table IV)
        l2_gbps: 3290.0,   // ERT-implied (Table IV)
        fp32_gflops: 14_800.0,
        launch_overhead_us: 4.0,
        unified_l1: true,
        warps_to_saturate: 48.0,
        gmem_dram_penalty: 1.0,
        gmem_l2_penalty: 1.0,
        spill_scale: 1.0,
        eval_grid: 1000,
        eval_pml_width: 26,
    }
}

/// NVIDIA Tesla P100 (Pascal, sm_60).
pub fn p100() -> GpuArch {
    GpuArch {
        name: "P100",
        sm_version: "sm_60",
        sm_count: 56,
        max_warps_per_sm: 64,
        max_blocks_per_sm: 32,
        max_threads_per_block: 1024,
        regs_per_sm: 65536,
        sm_partitions: 2,
        reg_alloc_granularity: 256,
        smem_per_sm: 65536, // 64 KiB
        smem_per_block: 49152,
        smem_granularity: 256,
        warp_size: 32,
        l2_bytes: 4 * 1024 * 1024,
        dram_gbps: 550.0,  // ERT-measured scale of the 732 GB/s theoretical
        l2_gbps: 1900.0,
        fp32_gflops: 9_300.0,
        launch_overhead_us: 5.0,
        unified_l1: false, // separate small L1/tex cache
        warps_to_saturate: 28.0,
        gmem_dram_penalty: 1.9,
        gmem_l2_penalty: 1.6,
        spill_scale: 0.25,
        eval_grid: 893,
        eval_pml_width: 26,
    }
}

/// NVIDIA NVS 510 (Kepler GK107, sm_30).
pub fn nvs510() -> GpuArch {
    GpuArch {
        name: "NVS510",
        sm_version: "sm_30",
        sm_count: 1, // single SMX (192 cores)
        max_warps_per_sm: 64,
        max_blocks_per_sm: 16,
        max_threads_per_block: 1024,
        regs_per_sm: 65536,
        sm_partitions: 1,
        reg_alloc_granularity: 256,
        smem_per_sm: 49152, // 48 KiB
        smem_per_block: 49152,
        smem_granularity: 256,
        warp_size: 32,
        l2_bytes: 256 * 1024,
        dram_gbps: 25.0,  // 28.5 GB/s theoretical, ERT-scaled
        l2_gbps: 120.0,
        fp32_gflops: 306.0, // 192 cores x 0.797 GHz x 2
        launch_overhead_us: 8.0,
        unified_l1: false,
        // 25 GB/s DRAM saturates with very few in-flight warps
        warps_to_saturate: 8.0,
        gmem_dram_penalty: 2.6, // sm_3x global loads bypass L1 entirely
        gmem_l2_penalty: 2.0,
        spill_scale: 0.15, // sm_30 caps at 63 regs: every variant spills
        eval_grid: 300,
        eval_pml_width: 26,
    }
}

/// NVIDIA A100 (Ampere, sm_80) — *not* in the paper's testbed; §VI lists
/// "whether our observations on the V100 also hold for the latest NVIDIA
/// A100" as future work, so we provide the forward prediction: bigger
/// unified L1 (192 KiB) and a 40 MiB L2, so the gmem-family absorption
/// that made gmem_8x8x8 win on V100 strengthens further.
pub fn a100() -> GpuArch {
    GpuArch {
        name: "A100",
        sm_version: "sm_80",
        sm_count: 108,
        max_warps_per_sm: 64,
        max_blocks_per_sm: 32,
        max_threads_per_block: 1024,
        regs_per_sm: 65536,
        sm_partitions: 4,
        reg_alloc_granularity: 256,
        smem_per_sm: 167936, // 164 KiB usable
        smem_per_block: 167936,
        smem_granularity: 256,
        warp_size: 32,
        l2_bytes: 40 * 1024 * 1024,
        dram_gbps: 1400.0, // ERT-scale of the 1555 GB/s theoretical
        l2_gbps: 5200.0,
        fp32_gflops: 19_500.0,
        launch_overhead_us: 4.0,
        unified_l1: true,
        warps_to_saturate: 40.0,
        gmem_dram_penalty: 1.0,
        gmem_l2_penalty: 1.0,
        spill_scale: 1.0,
        eval_grid: 1000,
        eval_pml_width: 26,
    }
}

/// All three evaluation machines, in the paper's column order.
pub fn all() -> Vec<GpuArch> {
    vec![v100(), p100(), nvs510()]
}

pub fn by_name(name: &str) -> anyhow::Result<GpuArch> {
    match name.to_ascii_lowercase().as_str() {
        "v100" => Ok(v100()),
        "p100" => Ok(p100()),
        "nvs510" => Ok(nvs510()),
        "a100" => Ok(a100()),
        other => anyhow::bail!("unknown GPU {other:?} (expected v100|p100|nvs510|a100)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_iv_ceiling_consistency() {
        // The ERT ceilings must reproduce the paper's "machine peak
        // performance" columns: peak(AI) = AI * BW.
        let a = v100();
        let l2_peak_at_078 = 0.78 * a.l2_gbps;
        assert!((l2_peak_at_078 - 2566.0).abs() / 2566.0 < 0.01, "{l2_peak_at_078}");
        let dram_peak_at_192 = 1.92 * a.dram_gbps;
        assert!((dram_peak_at_192 - 1498.0).abs() / 1498.0 < 0.01, "{dram_peak_at_192}");
    }

    #[test]
    fn machines_are_ordered_by_capability() {
        let (v, p, n) = (v100(), p100(), nvs510());
        assert!(v.dram_gbps > p.dram_gbps && p.dram_gbps > n.dram_gbps);
        assert!(v.fp32_gflops > p.fp32_gflops && p.fp32_gflops > n.fp32_gflops);
        assert!(v.unified_l1 && !p.unified_l1 && !n.unified_l1);
    }

    #[test]
    fn a100_prediction_extends_v100_findings() {
        // forward prediction (paper §VI future work): the unified-L1
        // advantage persists, so gmem_8x8x8 should stay top-tier and the
        // whole sweep should run ~1.6-1.9x faster than V100 (bandwidth
        // ratio 1400/780).
        use crate::gpusim::{kernels, timing};
        let (a, v) = (a100(), v100());
        let t_a = timing::simulate(&a, &kernels::by_id("gmem_8x8x8").unwrap(), 1000).time_s;
        let t_v = timing::simulate(&v, &kernels::by_id("gmem_8x8x8").unwrap(), 1000).time_s;
        assert!(t_a < t_v / 1.4, "{t_a} vs {t_v}");
        let best = timing::simulate_all(&a, 1000)
            .into_iter()
            .min_by(|x, y| x.time_s.total_cmp(&y.time_s))
            .unwrap();
        assert_eq!(best.variant_id, "gmem_8x8x8", "unified-L1 advantage should persist");
    }

    #[test]
    fn by_name_roundtrip() {
        for a in all() {
            assert_eq!(by_name(a.name).unwrap().name, a.name);
        }
        assert_eq!(by_name("a100").unwrap().name, "A100");
        assert!(by_name("h100").is_err());
    }

    #[test]
    fn eval_grid_matches_table_iii_inner_grid() {
        // V100: inner extent 1000 - 2*26 = 948; with 8^3 blocks the inner
        // grid is ceil(948/8)^3 = 119^3 = 1,685,159 — the Table III value.
        let a = v100();
        let inner = a.eval_grid - 2 * a.eval_pml_width;
        let blocks = |n: usize, d: usize| n.div_ceil(d);
        assert_eq!(blocks(inner, 8).pow(3), 1_685_159);
        assert_eq!(blocks(inner, 4).pow(3), 13_312_053);
        assert_eq!(blocks(inner, 16).pow(2) * blocks(inner, 4), 853_200);
    }
}
