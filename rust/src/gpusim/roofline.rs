//! ERT-like machine characterization + roofline operating points
//! (Table IV columns, Figure 3 series).

use super::arch::GpuArch;
use super::timing::KernelRun;

/// One roofline ceiling: performance(AI) = min(AI * bw, peak).
#[derive(Copy, Clone, Debug)]
pub struct Ceiling {
    pub name: &'static str,
    pub bw_gbps: f64,
    pub peak_gflops: f64,
}

impl Ceiling {
    pub fn at(&self, ai: f64) -> f64 {
        (ai * self.bw_gbps).min(self.peak_gflops)
    }

    /// AI where the slanted roof meets the flat peak.
    pub fn ridge(&self) -> f64 {
        self.peak_gflops / self.bw_gbps
    }
}

/// The empirical machine characterization the paper obtains from ERT.
pub fn ceilings(arch: &GpuArch) -> (Ceiling, Ceiling) {
    (
        Ceiling { name: "L2", bw_gbps: arch.l2_gbps, peak_gflops: arch.fp32_gflops },
        Ceiling { name: "DRAM", bw_gbps: arch.dram_gbps, peak_gflops: arch.fp32_gflops },
    )
}

/// One kernel's operating point on one roofline.
#[derive(Clone, Debug)]
pub struct RoofPoint {
    pub variant_id: &'static str,
    pub ai: f64,
    pub gflops: f64,
    pub peak_at_ai: f64,
    pub pct_of_peak: f64,
}

/// Figure-3 data: points for every kernel under both rooflines.
pub struct RooflineData {
    pub arch: &'static str,
    pub l2: Ceiling,
    pub dram: Ceiling,
    pub l2_points: Vec<RoofPoint>,
    pub dram_points: Vec<RoofPoint>,
}

pub fn roofline_data(arch: &GpuArch, runs: &[KernelRun]) -> RooflineData {
    let (l2, dram) = ceilings(arch);
    let mk = |ai: f64, gflops: f64, c: &Ceiling, id: &'static str| RoofPoint {
        variant_id: id,
        ai,
        gflops,
        peak_at_ai: c.at(ai),
        pct_of_peak: 100.0 * gflops / c.at(ai),
    };
    RooflineData {
        arch: arch.name,
        l2,
        dram,
        l2_points: runs.iter().map(|r| mk(r.ai_l2, r.gflops, &l2, r.variant_id)).collect(),
        dram_points: runs.iter().map(|r| mk(r.ai_dram, r.gflops, &dram, r.variant_id)).collect(),
    }
}

impl RooflineData {
    /// CSV with one row per (roof, kernel) pair — the Figure 3 series.
    pub fn csv(&self) -> String {
        let mut out = String::from("roof,kernel,ai,gflops,peak_at_ai,pct_of_peak\n");
        for (roof, pts) in [("L2", &self.l2_points), ("DRAM", &self.dram_points)] {
            for p in pts {
                out.push_str(&format!(
                    "{roof},{},{:.4},{:.1},{:.1},{:.2}\n",
                    p.variant_id, p.ai, p.gflops, p.peak_at_ai, p.pct_of_peak
                ));
            }
        }
        out
    }

    /// Crude ASCII log-log scatter of a point set under its ceiling —
    /// the terminal rendition of Fig. 3a/3b.
    pub fn ascii_plot(&self, dram: bool) -> String {
        let (c, pts) = if dram { (&self.dram, &self.dram_points) } else { (&self.l2, &self.l2_points) };
        let (w, h) = (72usize, 20usize);
        let (ai_min, ai_max) = (0.05f64, 20.0f64);
        let (gf_min, gf_max) = (10.0f64, c.peak_gflops * 1.5);
        let xi = |ai: f64| {
            (((ai.max(ai_min).ln() - ai_min.ln()) / (ai_max.ln() - ai_min.ln())) * (w - 1) as f64)
                as usize
        };
        let yi = |gf: f64| {
            h - 1
                - (((gf.clamp(gf_min, gf_max).ln() - gf_min.ln()) / (gf_max.ln() - gf_min.ln()))
                    * (h - 1) as f64) as usize
        };
        let mut canvas = vec![vec![b' '; w]; h];
        // ceiling
        for px in 0..w {
            let ai = (ai_min.ln() + (ai_max.ln() - ai_min.ln()) * px as f64 / (w - 1) as f64).exp();
            let gf = c.at(ai);
            let py = yi(gf);
            canvas[py][px] = b'-';
        }
        // points
        for p in pts {
            let (px, py) = (xi(p.ai).min(w - 1), yi(p.gflops).min(h - 1));
            canvas[py][px] = b'*';
        }
        let mut out = format!(
            "{} roofline ({}): bw {:.0} GB/s, peak {:.0} GF/s, ridge AI {:.2}\n",
            c.name,
            self.arch,
            c.bw_gbps,
            c.peak_gflops,
            c.ridge()
        );
        for row in canvas {
            out.push_str(std::str::from_utf8(&row).unwrap());
            out.push('\n');
        }
        out.push_str(&format!(
            "x: AI [{:.2}..{:.0}] FLOP/byte (log)   y: [{:.0}..{:.0}] GF/s (log)   *=kernel\n",
            ai_min, ai_max, gf_min, gf_max
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::arch::v100;
    use crate::gpusim::timing::simulate_all;

    #[test]
    fn ceiling_math() {
        let c = Ceiling { name: "DRAM", bw_gbps: 780.0, peak_gflops: 14800.0 };
        assert!((c.at(1.92) - 1497.6).abs() < 0.1);
        assert_eq!(c.at(1000.0), 14800.0);
        assert!((c.ridge() - 14800.0 / 780.0).abs() < 1e-9);
    }

    #[test]
    fn points_below_ceiling() {
        let a = v100();
        let runs = simulate_all(&a, 100);
        let data = roofline_data(&a, &runs);
        for p in data.dram_points.iter().chain(&data.l2_points) {
            assert!(p.gflops <= p.peak_at_ai * 1.0001, "{} above roof", p.variant_id);
            assert!(p.pct_of_peak > 0.0);
        }
    }

    #[test]
    fn csv_has_50_rows() {
        let a = v100();
        let runs = simulate_all(&a, 10);
        let csv = roofline_data(&a, &runs).csv();
        assert_eq!(csv.lines().count(), 1 + 2 * 25);
    }

    #[test]
    fn ascii_plot_renders() {
        let a = v100();
        let runs = simulate_all(&a, 10);
        let plot = roofline_data(&a, &runs).ascii_plot(true);
        assert!(plot.contains('*'));
        assert!(plot.contains("DRAM roofline"));
    }
}
