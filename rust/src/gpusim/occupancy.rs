//! CUDA occupancy calculator.
//!
//! Computes theoretical resident warps per SM from block resources, with
//! the per-partition register quantization that NVIDIA's tools apply on
//! Volta/Pascal. Reproduces the paper's Table III "Theoretical Active
//! Warps / Theoretical Occupancy" columns exactly (verified in unit
//! tests against all 26 published rows).

use super::arch::GpuArch;

/// Resources one kernel launch requests per block.
#[derive(Copy, Clone, Debug)]
pub struct KernelResources {
    pub threads_per_block: u32,
    pub regs_per_thread: u32,
    pub smem_per_block: u32,
}

/// What capped the occupancy.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Limiter {
    Warps,
    Blocks,
    Registers,
    SharedMem,
}

/// Theoretical occupancy result.
#[derive(Copy, Clone, Debug)]
pub struct Occupancy {
    pub blocks_per_sm: u32,
    pub active_warps: u32,
    /// active_warps / max_warps, in percent.
    pub occupancy_pct: f64,
    pub limiter: Limiter,
}

fn div_round_up(a: u32, b: u32) -> u32 {
    a.div_ceil(b)
}

fn round_up_to(a: u32, granularity: u32) -> u32 {
    div_round_up(a, granularity) * granularity
}

/// Theoretical occupancy for `res` on `arch`.
pub fn occupancy(arch: &GpuArch, res: &KernelResources) -> Occupancy {
    assert!(res.threads_per_block >= 1);
    assert!(
        res.threads_per_block <= arch.max_threads_per_block,
        "block of {} threads exceeds {} limit {}",
        res.threads_per_block,
        arch.name,
        arch.max_threads_per_block
    );
    let warps_per_block = div_round_up(res.threads_per_block, arch.warp_size);

    // 1. warp-count limit
    let blocks_by_warps = arch.max_warps_per_sm / warps_per_block;

    // 2. hardware block-slot limit
    let blocks_by_slots = arch.max_blocks_per_sm;

    // 3. register limit, quantized per SM partition: each partition owns
    //    regs_per_sm / partitions registers; a warp's allocation rounds
    //    up to the granularity; warps fit per partition independently.
    let blocks_by_regs = if res.regs_per_thread == 0 {
        u32::MAX
    } else {
        let per_warp = round_up_to(res.regs_per_thread * arch.warp_size, arch.reg_alloc_granularity);
        let per_partition = arch.regs_per_sm / arch.sm_partitions;
        let warps_by_regs = (per_partition / per_warp) * arch.sm_partitions;
        warps_by_regs / warps_per_block
    };

    // 4. shared-memory limit. A footprint past the per-block cap means
    //    the kernel cannot launch at all: report 0 blocks (limiter
    //    SharedMem) instead of panicking, so prediction layers can
    //    surface "cannot launch" as a verdict rather than a crash
    //    (e.g. the deep tf_s4 fused ring on pre-Ampere parts).
    let blocks_by_smem = if res.smem_per_block == 0 {
        u32::MAX
    } else if res.smem_per_block > arch.smem_per_block {
        0
    } else {
        arch.smem_per_sm / round_up_to(res.smem_per_block, arch.smem_granularity)
    };

    let (blocks, limiter) = [
        (blocks_by_warps, Limiter::Warps),
        (blocks_by_slots, Limiter::Blocks),
        (blocks_by_regs, Limiter::Registers),
        (blocks_by_smem, Limiter::SharedMem),
    ]
    .into_iter()
    .min_by_key(|&(b, _)| b)
    .unwrap();

    let active_warps = blocks * warps_per_block;
    Occupancy {
        blocks_per_sm: blocks,
        active_warps,
        occupancy_pct: 100.0 * active_warps as f64 / arch.max_warps_per_sm as f64,
        limiter,
    }
}

/// Achieved occupancy model: the theoretical value shaved by (a) grid
/// starvation — too few blocks to fill every SM to its per-SM block
/// count — and (b) a small scheduling-tail factor for very large grids.
pub fn achieved_warps(arch: &GpuArch, occ: &Occupancy, grid_blocks: u64, tail_factor: f64) -> f64 {
    let warps_per_block = occ.active_warps as f64 / occ.blocks_per_sm.max(1) as f64;
    let blocks_per_sm_avail = grid_blocks as f64 / arch.sm_count as f64;
    let resident = blocks_per_sm_avail.min(occ.blocks_per_sm as f64);
    (resident * warps_per_block * tail_factor).min(occ.active_warps as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::arch::v100;

    fn occ(threads: u32, regs: u32, smem: u32) -> Occupancy {
        occupancy(&v100(), &KernelResources {
            threads_per_block: threads,
            regs_per_thread: regs,
            smem_per_block: smem,
        })
    }

    /// Every inner-region row of Table III (top), V100.
    #[test]
    fn table_iii_inner_theoretical_warps() {
        // (threads, regs, smem_bytes, expected_warps, expected_pct)
        let rows: &[(u32, u32, u32, u32, f64)] = &[
            (64, 40, 0, 48, 75.0),        // gmem_4x4x4
            (256, 40, 0, 48, 75.0),       // gmem_8x8x4
            (512, 40, 0, 48, 75.0),       // gmem_8x8x8
            (1024, 40, 0, 32, 50.0),      // gmem_16x16x4
            (1024, 40, 0, 32, 50.0),      // gmem_32x32x1
            (512, 38, 16384, 48, 75.0),   // smem_u (16^3 tile)
            (512, 40, 0, 48, 75.0),       // smem_eta_1 (inner kernel = gmem)
            (512, 40, 0, 48, 75.0),       // smem_eta_3
            (768, 40, 3072, 48, 75.0),    // semi (+partial buffer)
            (64, 56, 9216, 20, 31.25),    // st_smem_8x8: 9 planes 16x16
            (128, 56, 9 * 16 * 24 * 4, 28, 43.75), // st_smem_8x16
            (128, 56, 9 * 24 * 16 * 4, 28, 43.75), // st_smem_16x8
            (256, 56, 9 * 24 * 24 * 4, 32, 50.0),  // st_smem_16x16
            (64, 96, 16 * 16 * 4, 20, 31.25),      // st_reg_shft_8x8
            (256, 96, 24 * 24 * 4, 16, 25.0),      // st_reg_shft_16x16
            (512, 96, 24 * 40 * 4, 16, 25.0),      // st_reg_shft_16x32
            (1024, 64, 24 * 72 * 4, 32, 50.0),     // st_reg_shft_16x64 (Nr=64)
            (512, 96, 40 * 24 * 4, 16, 25.0),      // st_reg_shft_32x16
            (1024, 64, 40 * 40 * 4, 32, 50.0),     // st_reg_shft_32x32 (Nr=64)
            (1024, 64, 72 * 24 * 4, 32, 50.0),     // st_reg_shft_64x16 (Nr=64)
            (64, 78, 16 * 16 * 4, 24, 37.5),       // st_reg_fixed_8x8
            (128, 78, 24 * 16 * 4, 24, 37.5),      // st_reg_fixed_16x8
            (256, 78, 24 * 24 * 4, 24, 37.5),      // st_reg_fixed_16x16
            (512, 78, 40 * 24 * 4, 16, 25.0),      // st_reg_fixed_32x16
            (1024, 64, 40 * 40 * 4, 32, 50.0),     // st_reg_fixed_32x32 (Nr=64)
        ];
        for &(t, r, s, want_warps, want_pct) in rows {
            let o = occ(t, r, s);
            assert_eq!(
                o.active_warps, want_warps,
                "threads={t} regs={r} smem={s}: got {} warps, want {want_warps}",
                o.active_warps
            );
            assert!((o.occupancy_pct - want_pct).abs() < 0.1);
        }
    }

    /// PML rows of Table III (bottom) with distinct register counts.
    #[test]
    fn table_iii_pml_theoretical_warps() {
        let rows: &[(u32, u32, u32, u32, f64)] = &[
            (64, 48, 0, 40, 62.5),       // gmem_4x4x4 pml
            (256, 48, 0, 40, 62.5),      // gmem_8x8x4 pml
            (512, 48, 0, 32, 50.0),      // gmem_8x8x8 pml
            (1024, 48, 0, 32, 50.0),     // gmem_16x16x4 pml
            (512, 48, 16384, 32, 50.0),  // smem_u pml
            (512, 32, 4000, 64, 100.0),  // smem_eta_1 pml: 10^3 eta tile
            (512, 32, 4000, 64, 100.0),  // smem_eta_3 pml
            (768, 64, 3072, 24, 37.5),   // semi pml
            (64, 72, 9216, 20, 31.25),   // st_smem_8x8 pml
            (64, 80, 1024, 24, 37.5),    // st_reg_shft_8x8 pml
            (64, 106, 1024, 16, 25.0),   // st_reg_fixed_8x8 pml
            (128, 104, 1536, 16, 25.0),  // st_reg_fixed_16x8 pml
            (512, 106, 3840, 16, 25.0),  // st_reg_fixed_32x16 pml
        ];
        for &(t, r, s, want_warps, want_pct) in rows {
            let o = occ(t, r, s);
            assert_eq!(
                o.active_warps, want_warps,
                "threads={t} regs={r} smem={s}: got {} want {want_warps}",
                o.active_warps
            );
            assert!((o.occupancy_pct - want_pct).abs() < 0.1);
        }
    }

    #[test]
    fn limiter_identification() {
        assert_eq!(occ(1024, 32, 0).limiter, Limiter::Warps); // 2 blocks x 32 warps
        assert_eq!(occ(64, 96, 1024).limiter, Limiter::Registers);
        assert_eq!(occ(64, 56, 9216).limiter, Limiter::SharedMem);
        assert_eq!(occ(32, 16, 0).limiter, Limiter::Blocks); // tiny blocks cap at 32
    }

    #[test]
    fn achieved_caps_at_grid_starvation() {
        // st_smem_8x8 PML top/bottom: grid 500 blocks over 80 SMs with
        // 10-block occupancy -> 500/80 = 6.25 resident -> 12.5 warps
        // (paper achieved: 12.4).
        let a = v100();
        let o = occ(64, 72, 9216);
        assert_eq!(o.blocks_per_sm, 10);
        let got = achieved_warps(&a, &o, 500, 1.0);
        assert!((got - 12.5).abs() < 0.1, "{got}");
        // huge grid: full theoretical
        let got = achieved_warps(&a, &o, 1_000_000, 1.0);
        assert!((got - 20.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn oversized_block_panics() {
        occ(2048, 32, 0);
    }

    #[test]
    fn infeasible_smem_reports_zero_blocks_instead_of_panicking() {
        // a footprint past the per-block cap cannot launch: 0 blocks,
        // limited by shared memory (the tf_s4 ring on V100 hits this)
        let o = occ(256, 56, 120_000);
        assert_eq!(o.blocks_per_sm, 0);
        assert_eq!(o.active_warps, 0);
        assert_eq!(o.limiter, Limiter::SharedMem);
        assert_eq!(o.occupancy_pct, 0.0);
    }
}
