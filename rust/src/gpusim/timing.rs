//! Roofline-style timing model (Table II).
//!
//! Per region: time = max(compute, L2, DRAM) / efficiency + penalties,
//! where efficiency combines a per-arch base factor (real stencils never
//! hit ERT streaming bandwidth; the paper's best kernels achieve ~50% of
//! the DRAM roofline, which is what `base_eff` encodes) with an
//! occupancy-derived latency-hiding factor. Semi-stencil pays a
//! synchronization multiplier (its dominant stall in the paper was
//! STL_SYNC); register-capped variants already pay spill traffic in the
//! memory model.

use super::arch::GpuArch;
use super::kernels::KernelVariant;
use super::memory::point_traffic;
use super::occupancy::{achieved_warps, occupancy, Occupancy};
use crate::grid::Dim3;

/// Per-arch calibration constants (documented, single source of truth).
#[derive(Copy, Clone, Debug)]
pub struct Calib {
    /// Fraction of the ERT bandwidth ceiling a tuned stencil sustains.
    pub base_eff: f64,
    /// Synchronization multiplier for semi-stencil's two-phase barriers.
    pub semi_sync: f64,
    /// Extra multiplier for staging eta through shared memory (slightly
    /// counterproductive on unified-L1 parts, mildly helpful on Kepler).
    pub pml_eta_smem: f64,
}

pub fn calib(arch: &GpuArch) -> Calib {
    match arch.name {
        "V100" => Calib { base_eff: 0.63, semi_sync: 2.2, pml_eta_smem: 1.25 },
        // forward prediction: Ampere behaves like Volta, slightly better
        // sustained fraction (larger L2, async copy)
        "A100" => Calib { base_eff: 0.66, semi_sync: 2.2, pml_eta_smem: 1.25 },
        "P100" => Calib { base_eff: 0.40, semi_sync: 1.6, pml_eta_smem: 1.02 },
        _ => Calib { base_eff: 0.15, semi_sync: 2.0, pml_eta_smem: 0.90 },
    }
}

/// Cost of one region's launch for one time step.
#[derive(Clone, Debug)]
pub struct RegionCost {
    pub region: &'static str,
    pub points: f64,
    pub grid_blocks: u64,
    pub occ: Occupancy,
    pub achieved_warps: f64,
    pub l2_bytes: f64,
    pub dram_bytes: f64,
    pub flops: f64,
    pub time_s: f64,
}

/// Whole-run prediction for one kernel variant on one machine.
#[derive(Clone, Debug)]
pub struct KernelRun {
    pub variant_id: &'static str,
    pub arch: &'static str,
    pub steps: usize,
    pub time_s: f64,
    pub flops_total: f64,
    pub gflops: f64,
    pub l2_transactions: f64,
    pub dram_transactions: f64,
    pub ai_l2: f64,
    pub ai_dram: f64,
    pub l2_peak_gflops: f64,
    pub dram_peak_gflops: f64,
    pub pct_of_l2_peak: f64,
    pub pct_of_dram_peak: f64,
    pub regions: Vec<RegionCost>,
}

/// Occupancy-derived latency-hiding factor: below the saturation warp
/// count, sustained bandwidth falls with the square root of the deficit
/// (MLP compounds sub-linearly; calibrated against the paper's
/// st_smem_8x8 vs 16x8 gap and the V100 gmem-vs-streaming crossover).
fn occ_factor(arch: &GpuArch, warps: f64) -> f64 {
    (warps / arch.warps_to_saturate).min(1.0).sqrt()
}

fn region_cost(
    arch: &GpuArch,
    v: &KernelVariant,
    name: &'static str,
    dims: Dim3,
    pml: bool,
) -> RegionCost {
    let c = calib(arch);
    let points = dims.volume() as f64;
    let res = if pml { v.resources_pml() } else { v.resources_inner() };
    let occ = occupancy(arch, &res);
    let grid_blocks = v.grid_blocks(dims);
    let aw = achieved_warps(arch, &occ, grid_blocks, 0.97);

    let t = point_traffic(arch, v, pml);
    let l2_bytes = t.l2_bytes * points;
    let dram_bytes = t.dram_bytes * points;
    let fpp = if pml { 30.0 } else { v.family.flops_per_point() };
    let flops = fpp * points;

    let eff = c.base_eff * occ_factor(arch, aw);
    let t_l2 = l2_bytes / (arch.l2_gbps * 1e9) / eff;
    let t_dram = dram_bytes / (arch.dram_gbps * 1e9) / eff;
    let t_comp = flops / (arch.fp32_gflops * 1e9 * 0.85);
    let mut time = t_l2.max(t_dram).max(t_comp) + arch.launch_overhead_us * 1e-6;

    if v.family == super::kernels::Family::Semi {
        time *= c.semi_sync;
    }
    // The 2R+1-deep ring buffer costs a block-wide barrier plus 9 smem
    // round-trips per plane (register variants avoid both).
    if v.family == super::kernels::Family::StSmem {
        time *= 1.12;
    }
    // On unified-L1 parts explicit shared-memory staging is redundant
    // work the cache would have done anyway (paper: smem_u loses to
    // gmem_8x8x8 on V100 and wins everywhere else).
    if arch.unified_l1
        && !pml
        && matches!(
            v.family,
            super::kernels::Family::SmemU | super::kernels::Family::StSmem
        )
    {
        time *= 1.10;
    }
    // Register-streaming loop bookkeeping is visible on Volta, where the
    // memory system would otherwise have kept up.
    if arch.unified_l1
        && matches!(
            v.family,
            super::kernels::Family::StRegShft | super::kernels::Family::StRegFixed
        )
    {
        time *= 1.06;
    }
    if pml
        && matches!(
            v.family,
            super::kernels::Family::SmemEta1 | super::kernels::Family::SmemEta3
        )
    {
        time *= c.pml_eta_smem;
    }

    RegionCost {
        region: name,
        points,
        grid_blocks,
        occ,
        achieved_warps: aw,
        l2_bytes,
        dram_bytes,
        flops,
        time_s: time,
    }
}

/// Predict a full Table II cell: `steps` iterations of the 7-region
/// decomposition on `arch`'s evaluation grid.
pub fn simulate(arch: &GpuArch, v: &KernelVariant, steps: usize) -> KernelRun {
    let mut regions = Vec::new();
    let mut step_time = 0.0;
    let mut l2 = 0.0;
    let mut dram = 0.0;
    let mut flops = 0.0;
    for (name, dims, count) in KernelVariant::eval_regions(arch) {
        let pml = name != "inner";
        let rc = region_cost(arch, v, name, dims, pml);
        step_time += rc.time_s * count as f64;
        l2 += rc.l2_bytes * count as f64;
        dram += rc.dram_bytes * count as f64;
        flops += rc.flops * count as f64;
        regions.push(rc);
    }
    let time_s = step_time * steps as f64;
    let flops_total = flops * steps as f64;
    let gflops = flops_total / time_s / 1e9;
    let l2_transactions = l2 * steps as f64 / 32.0;
    let dram_transactions = dram * steps as f64 / 32.0;
    let ai_l2 = flops_total / (l2 * steps as f64);
    let ai_dram = flops_total / (dram * steps as f64);
    let l2_peak = (ai_l2 * arch.l2_gbps).min(arch.fp32_gflops);
    let dram_peak = (ai_dram * arch.dram_gbps).min(arch.fp32_gflops);
    KernelRun {
        variant_id: v.id,
        arch: arch.name,
        steps,
        time_s,
        flops_total,
        gflops,
        l2_transactions,
        dram_transactions,
        ai_l2,
        ai_dram,
        l2_peak_gflops: l2_peak,
        dram_peak_gflops: dram_peak,
        pct_of_l2_peak: 100.0 * gflops / l2_peak,
        pct_of_dram_peak: 100.0 * gflops / dram_peak,
        regions,
    }
}

/// Simulate every paper variant on `arch` (Table II column).
pub fn simulate_all(arch: &GpuArch, steps: usize) -> Vec<KernelRun> {
    super::kernels::paper_variants()
        .iter()
        .map(|v| simulate(arch, v, steps))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::arch::{nvs510, p100, v100};
    use crate::gpusim::kernels::by_id;

    fn time(arch: &GpuArch, id: &str) -> f64 {
        simulate(arch, &by_id(id).unwrap(), 1000).time_s
    }

    #[test]
    fn v100_gmem_8x8x8_in_band() {
        // Paper: 53.88 s. Accept a generous band — the assertion that
        // matters (fastest on V100) lives in tests/gpusim_tables.rs.
        let t = time(&v100(), "gmem_8x8x8");
        assert!((25.0..110.0).contains(&t), "{t}");
    }

    #[test]
    fn orderings_v100() {
        let a = v100();
        let g888 = time(&a, "gmem_8x8x8");
        assert!(g888 < time(&a, "gmem_4x4x4"));
        assert!(g888 < time(&a, "gmem_32x32x1") / 3.0, "thin blocks catastrophic");
        assert!(g888 < time(&a, "semi") / 2.0, "semi pays sync");
        // spilling 1024-thread shft variants lose to their 256-thread kin
        assert!(time(&a, "st_reg_shft_16x16") < time(&a, "st_reg_shft_16x64"));
    }

    #[test]
    fn orderings_p100() {
        let a = p100();
        // paper: smem_u (76.2) beats gmem_8x8x8 (117.7) on P100 ...
        assert!(time(&a, "smem_u") < time(&a, "gmem_8x8x8"));
        // ... and the best kernel is a 2.5D register variant
        assert!(time(&a, "st_reg_fixed_32x32") < time(&a, "smem_u"));
    }

    #[test]
    fn orderings_nvs510() {
        let a = nvs510();
        assert!(time(&a, "smem_u") < time(&a, "gmem_8x8x8"));
        assert!(time(&a, "st_reg_fixed_16x8") < time(&a, "smem_u"));
        assert!(time(&a, "gmem_32x32x1") > 2.5 * time(&a, "gmem_8x8x8"));
    }

    #[test]
    fn run_metrics_consistent() {
        let r = simulate(&v100(), &by_id("gmem_8x8x8").unwrap(), 1000);
        assert!(r.gflops > 0.0);
        assert!((r.ai_l2 - r.flops_total / (r.l2_transactions * 32.0)).abs() < 1e-9);
        assert!(r.pct_of_dram_peak > 0.0 && r.pct_of_dram_peak < 100.0);
        assert_eq!(r.regions.len(), 4); // inner + 3 face classes
        // FLOP total matches the paper's scale (4.45e13 for 1e9 x 1000)
        assert!((r.flops_total - 4.453e13).abs() / 4.453e13 < 0.05, "{}", r.flops_total);
    }

    #[test]
    fn simulate_all_covers_25() {
        assert_eq!(simulate_all(&v100(), 10).len(), 25);
    }
}
