//! Descriptors for the paper's 25 kernel variants (Table II/III rows).
//!
//! Register counts are the nvcc-reported values from Table III (V100).
//! Shared-memory footprints follow each code shape's staging buffers.
//!
//! Grid-size mapping (reverse-engineered from Table III and verified
//! against every published row in unit tests):
//! * 3D names `gmem_{Dx}x{Dy}x{Dz}`: Dx tiles x, Dy tiles y, Dz tiles z;
//!   grid = ru(z/Dz) ru(y/Dy) ru(x/Dx).
//! * 2.5D names `st_*_{A}x{B}`: A tiles z, B tiles y, the kernel streams
//!   along x; grid = ru(z/A) ru(y/B).
//! * The paper's eval grid is 1000^3 (V100) with PML width 26: the inner
//!   extent 948 reproduces Table III exactly (119^3 = 1,685,159 blocks).

use super::arch::GpuArch;
use super::occupancy::KernelResources;
use crate::grid::Dim3;

/// Code-shape family (paper §IV).
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub enum Family {
    /// 3D blocking, global memory only (§IV.1)
    Gmem,
    /// 3D blocking, u staged in shared memory (§IV.2)
    SmemU,
    /// 3D blocking, eta staged with one conditional (§IV.3)
    SmemEta1,
    /// 3D blocking, eta staged with three conditionals (§IV.3)
    SmemEta3,
    /// semi-stencil on x inside 3D blocks (§IV.4)
    Semi,
    /// 2.5D streaming, ring buffer of 2R+1 planes in smem (§IV.5)
    StSmem,
    /// 2.5D streaming, register shifting (§IV.6)
    StRegShft,
    /// 2.5D streaming, fixed registers + unrolling (§IV.7)
    StRegFixed,
}

impl Family {
    pub fn is_streaming(&self) -> bool {
        matches!(self, Family::StSmem | Family::StRegShft | Family::StRegFixed)
    }

    /// FLOPs per point update. The paper measured 4.453e13 FLOP for 1e9
    /// points x 1000 steps = 44.53 FLOP/point for all variants except
    /// semi (6.4e13 -> 64: the partial-result phases re-do the center
    /// and double the x-axis FMA chain).
    pub fn flops_per_point(&self) -> f64 {
        match self {
            Family::Semi => 64.0,
            _ => 44.53,
        }
    }
}

/// One kernel variant = one Table II row (plus, beyond the paper, the
/// temporally fused `tf_*` descriptors — see [`fused_variants`]).
#[derive(Clone, Debug)]
pub struct KernelVariant {
    pub id: &'static str,
    pub family: Family,
    /// Tile dims as named (3D: (dx,dy,dz); 2.5D: (a,b) with dz == 0).
    pub d1: u32,
    pub d2: u32,
    pub d3: u32,
    /// Temporal fusion degree: leapfrog steps advanced per memory
    /// sweep. 1 for every Table II variant; the `tf_s{S}` descriptors
    /// carry 2 or 4. Fused streaming kernels deepen the plane ring to
    /// `(2R+1) + s` and widen the tile skirt to `s*R` (redundant-halo
    /// overlapped tiling), which [`KernelVariant::smem_inner`] and the
    /// traffic model (`gpusim::memory`) both account for.
    pub fuse: u32,
    /// Explicit -maxrregcount cap (Table II "Nr" column).
    pub maxrregcount: Option<u32>,
    /// nvcc register allocation, inner kernel (Table III top).
    pub regs_inner: u32,
    /// nvcc register allocation, PML kernels (Table III bottom).
    pub regs_pml: u32,
    /// Registers nvcc would allocate without the cap (spill modeling;
    /// for capped variants the paper reports 96/80 inner/pml for
    /// st_reg_shft and 78/106 for st_reg_fixed).
    pub regs_needed_inner: u32,
    pub regs_needed_pml: u32,
}

const R: u32 = 4; // halo of the high-order stencil

impl KernelVariant {
    pub fn is_streaming(&self) -> bool {
        self.family.is_streaming()
    }

    pub fn threads_per_block(&self) -> u32 {
        if self.is_streaming() {
            self.d1 * self.d2
        } else if self.family == Family::Semi {
            // semi uses a 768-thread block on an 8^3 tile (extra warps
            // drive the two-phase partial pipeline — Table III).
            768
        } else {
            self.d1 * self.d2 * self.d3
        }
    }

    /// Shared-memory bytes per block, inner kernel.
    pub fn smem_inner(&self) -> u32 {
        match self.family {
            Family::Gmem | Family::SmemEta1 | Family::SmemEta3 => 0,
            Family::SmemU => (self.d1 + 2 * R) * (self.d2 + 2 * R) * (self.d3 + 2 * R) * 4,
            Family::Semi => self.d1 * self.d2 * self.d3 * 4, // partial buffer
            Family::StSmem => {
                if self.fuse > 1 {
                    // temporally fused ring: (2R+1) + s planes, each
                    // widened by the s*R redundant-halo skirt
                    let s = self.fuse;
                    (2 * R + 1 + s) * (self.d1 + 2 * s * R) * (self.d2 + 2 * s * R) * 4
                } else {
                    (2 * R + 1) * (self.d1 + 2 * R) * (self.d2 + 2 * R) * 4
                }
            }
            Family::StRegShft | Family::StRegFixed => {
                (self.d1 + 2 * R) * (self.d2 + 2 * R) * 4 // current plane only
            }
        }
    }

    /// Shared-memory bytes per block, PML kernel (eta tile has halo 1).
    pub fn smem_pml(&self) -> u32 {
        match self.family {
            Family::Gmem => 0,
            Family::SmemEta1 | Family::SmemEta3 => {
                (self.d1 + 2) * (self.d2 + 2) * (self.d3 + 2) * 4
            }
            // the other families stage u exactly like their inner kernel
            _ => self.smem_inner(),
        }
    }

    pub fn resources_inner(&self) -> KernelResources {
        KernelResources {
            threads_per_block: self.threads_per_block(),
            regs_per_thread: self.regs_inner,
            smem_per_block: self.smem_inner(),
        }
    }

    pub fn resources_pml(&self) -> KernelResources {
        KernelResources {
            threads_per_block: self.threads_per_block(),
            regs_per_thread: self.regs_pml,
            smem_per_block: self.smem_pml(),
        }
    }

    /// Registers spilled per thread by an explicit -maxrregcount cap.
    pub fn spilled_regs(&self, pml: bool) -> u32 {
        match self.maxrregcount {
            None => 0,
            Some(cap) => {
                let needed = if pml { self.regs_needed_pml } else { self.regs_needed_inner };
                needed.saturating_sub(cap)
            }
        }
    }

    /// Number of blocks one launch spawns for a region of `dims`.
    pub fn grid_blocks(&self, dims: Dim3) -> u64 {
        let ru = |n: usize, d: u32| n.div_ceil(d as usize) as u64;
        if self.is_streaming() {
            // plane tiles (z, y); streams along x
            ru(dims.z, self.d1) * ru(dims.y, self.d2)
        } else {
            ru(dims.z, self.d3) * ru(dims.y, self.d2) * ru(dims.x, self.d1)
        }
    }

    /// The paper's seven evaluation regions for a cubic grid of edge
    /// `arch.eval_grid` with PML width `arch.eval_pml_width`:
    /// (inner, top/bottom x2, front/back x2, left/right x2).
    pub fn eval_regions(arch: &GpuArch) -> Vec<(&'static str, Dim3, usize)> {
        let n = arch.eval_grid;
        let w = arch.eval_pml_width;
        let i = n - 2 * w;
        vec![
            ("inner", Dim3::new(i, i, i), 1),
            ("top_bottom", Dim3::new(w, n, n), 2),
            ("front_back", Dim3::new(i, w, n), 2),
            ("left_right", Dim3::new(i, i, w), 2),
        ]
    }
}

/// All 25 Table II variants, in row order.
pub fn paper_variants() -> Vec<KernelVariant> {
    let v = |id, family, d1, d2, d3, nr: Option<u32>, ri, rp, rni, rnp| KernelVariant {
        id,
        family,
        d1,
        d2,
        d3,
        fuse: 1,
        maxrregcount: nr,
        regs_inner: ri,
        regs_pml: rp,
        regs_needed_inner: rni,
        regs_needed_pml: rnp,
    };
    vec![
        v("gmem_4x4x4", Family::Gmem, 4, 4, 4, None, 40, 48, 40, 48),
        v("gmem_8x8x4", Family::Gmem, 8, 8, 4, None, 40, 48, 40, 48),
        v("gmem_8x8x8", Family::Gmem, 8, 8, 8, None, 40, 48, 40, 48),
        v("gmem_16x16x4", Family::Gmem, 16, 16, 4, None, 40, 48, 40, 48),
        v("gmem_32x32x1", Family::Gmem, 32, 32, 1, None, 40, 48, 40, 48),
        v("smem_u", Family::SmemU, 8, 8, 8, None, 38, 48, 38, 48),
        v("smem_eta_1", Family::SmemEta1, 8, 8, 8, None, 40, 32, 40, 32),
        v("smem_eta_3", Family::SmemEta3, 8, 8, 8, None, 40, 32, 40, 32),
        v("semi", Family::Semi, 8, 8, 8, None, 40, 64, 40, 64),
        v("st_smem_8x8", Family::StSmem, 8, 8, 0, None, 56, 72, 56, 72),
        v("st_smem_8x16", Family::StSmem, 8, 16, 0, None, 56, 72, 56, 72),
        v("st_smem_16x8", Family::StSmem, 16, 8, 0, None, 56, 72, 56, 72),
        v("st_smem_16x16", Family::StSmem, 16, 16, 0, None, 56, 72, 56, 72),
        v("st_reg_shft_8x8", Family::StRegShft, 8, 8, 0, None, 96, 80, 96, 80),
        v("st_reg_shft_16x16", Family::StRegShft, 16, 16, 0, None, 96, 80, 96, 80),
        v("st_reg_shft_16x32", Family::StRegShft, 16, 32, 0, None, 96, 80, 96, 80),
        v("st_reg_shft_16x64", Family::StRegShft, 16, 64, 0, Some(64), 64, 64, 96, 80),
        v("st_reg_shft_32x16", Family::StRegShft, 32, 16, 0, None, 96, 80, 96, 80),
        v("st_reg_shft_32x32", Family::StRegShft, 32, 32, 0, Some(64), 64, 64, 96, 80),
        v("st_reg_shft_64x16", Family::StRegShft, 64, 16, 0, Some(64), 64, 64, 96, 80),
        v("st_reg_fixed_8x8", Family::StRegFixed, 8, 8, 0, None, 78, 106, 78, 106),
        v("st_reg_fixed_16x8", Family::StRegFixed, 16, 8, 0, None, 78, 104, 78, 104),
        v("st_reg_fixed_16x16", Family::StRegFixed, 16, 16, 0, None, 78, 104, 78, 104),
        v("st_reg_fixed_32x16", Family::StRegFixed, 32, 16, 0, None, 78, 106, 78, 106),
        v("st_reg_fixed_32x32", Family::StRegFixed, 32, 32, 0, Some(64), 64, 64, 78, 106),
    ]
}

/// The temporally fused descriptors (beyond the paper's Table II):
/// 2.5D plane streaming advancing `s` leapfrog steps per memory sweep
/// with overlapped `s*R` halo skirts. `tf_s1` is the degenerate
/// degree-1 control (identical resources to `st_smem_16x16`, and the
/// CPU factory maps it onto the plain `Streaming25D` shape), so fusion
/// sweeps have an in-family unfused baseline.
pub fn fused_variants() -> Vec<KernelVariant> {
    let tf = |id, d1, d2, fuse| KernelVariant {
        id,
        family: Family::StSmem,
        d1,
        d2,
        d3: 0,
        fuse,
        maxrregcount: None,
        regs_inner: 56,
        regs_pml: 72,
        regs_needed_inner: 56,
        regs_needed_pml: 72,
    };
    vec![
        tf("tf_s1", 16, 16, 1),
        tf("tf_s2", 16, 16, 2),
        tf("tf_s4", 16, 16, 4),
    ]
}

pub fn by_id(id: &str) -> anyhow::Result<KernelVariant> {
    paper_variants()
        .into_iter()
        .chain(fused_variants())
        .find(|v| v.id == id)
        .ok_or_else(|| anyhow::anyhow!("unknown kernel variant {id:?}"))
}

/// Representative Table II id for a family shorthand (the `run
/// --variant` names), or `None` for anything else.
pub fn family_representative(name: &str) -> Option<&'static str> {
    match name {
        "gmem" => Some("gmem_8x8x8"),
        "smem_u" => Some("smem_u"),
        "semi" => Some("semi"),
        "st_smem" => Some("st_smem_16x16"),
        "st_reg_shft" => Some("st_reg_shft_16x16"),
        "st_reg_fixed" => Some("st_reg_fixed_32x32"),
        "tf" => Some("tf_s2"),
        _ => None,
    }
}

/// Resolve a family shorthand or full Table II id to its variant.
/// Single source of truth for every layer that accepts either form
/// (CLI `--variant`, campaign specs, the CPU propagator factory).
pub fn resolve(name: &str) -> anyhow::Result<KernelVariant> {
    by_id(family_representative(name).unwrap_or(name))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::arch::v100;

    #[test]
    fn twenty_five_variants() {
        let vs = paper_variants();
        assert_eq!(vs.len(), 25);
        let mut ids: Vec<_> = vs.iter().map(|v| v.id).collect();
        ids.dedup();
        assert_eq!(ids.len(), 25, "ids must be unique");
    }

    #[test]
    fn table_iii_block_sizes() {
        let sizes: Vec<(&str, u32)> = paper_variants()
            .iter()
            .map(|v| (v.id, v.threads_per_block()))
            .collect();
        let expect = |id: &str, n: u32| {
            assert_eq!(sizes.iter().find(|(i, _)| *i == id).unwrap().1, n, "{id}")
        };
        expect("gmem_4x4x4", 64);
        expect("gmem_8x8x8", 512);
        expect("gmem_16x16x4", 1024);
        expect("semi", 768);
        expect("st_smem_8x16", 128);
        expect("st_reg_shft_16x64", 1024);
        expect("st_reg_fixed_32x16", 512);
    }

    #[test]
    fn table_iii_inner_grid_sizes() {
        // V100: inner region 948^3.
        let inner = Dim3::new(948, 948, 948);
        let g = |id: &str| by_id(id).unwrap().grid_blocks(inner);
        assert_eq!(g("gmem_4x4x4"), 13_312_053);
        assert_eq!(g("gmem_8x8x4"), 3_356_157);
        assert_eq!(g("gmem_8x8x8"), 1_685_159);
        assert_eq!(g("gmem_16x16x4"), 853_200);
        assert_eq!(g("semi"), 1_685_159);
        assert_eq!(g("st_smem_8x8"), 14_161);
        assert_eq!(g("st_smem_8x16"), 7_140);
        assert_eq!(g("st_smem_16x16"), 3_600);
        assert_eq!(g("st_reg_shft_16x32"), 1_800);
        assert_eq!(g("st_reg_shft_16x64"), 900);
        assert_eq!(g("st_reg_fixed_32x32"), 900);
    }

    #[test]
    fn table_iii_pml_grid_sizes() {
        // top/bottom (26,1000,1000); front/back (948,26,1000);
        // left/right (948,948,26).
        let tb = Dim3::new(26, 1000, 1000);
        let fb = Dim3::new(948, 26, 1000);
        let lr = Dim3::new(948, 948, 26);
        let g = |id: &str, d: Dim3| by_id(id).unwrap().grid_blocks(d);
        assert_eq!(g("gmem_4x4x4", tb), 437_500);
        assert_eq!(g("gmem_4x4x4", fb), 414_750);
        assert_eq!(g("gmem_4x4x4", lr), 393_183);
        assert_eq!(g("gmem_8x8x4", tb), 109_375);
        assert_eq!(g("gmem_8x8x4", fb), 118_500);
        assert_eq!(g("gmem_8x8x4", lr), 112_812);
        assert_eq!(g("gmem_8x8x8", tb), 62_500);
        assert_eq!(g("gmem_8x8x8", fb), 59_500);
        assert_eq!(g("gmem_8x8x8", lr), 56_644);
        assert_eq!(g("st_smem_8x8", tb), 500);
        assert_eq!(g("st_smem_8x8", fb), 476);
        assert_eq!(g("st_smem_8x8", lr), 14_161);
        assert_eq!(g("st_smem_16x16", tb), 126);
        assert_eq!(g("st_reg_shft_16x32", tb), 64);
        assert_eq!(g("st_reg_shft_16x64", tb), 32);
        assert_eq!(g("st_reg_shft_16x64", fb), 60);
        assert_eq!(g("st_reg_shft_16x64", lr), 900);
        assert_eq!(g("st_reg_fixed_32x32", fb), 30);
    }

    #[test]
    fn smem_footprints() {
        assert_eq!(by_id("smem_u").unwrap().smem_inner(), 16 * 16 * 16 * 4);
        assert_eq!(by_id("st_smem_8x8").unwrap().smem_inner(), 9 * 16 * 16 * 4);
        assert_eq!(by_id("st_reg_shft_16x16").unwrap().smem_inner(), 24 * 24 * 4);
        assert_eq!(by_id("gmem_8x8x8").unwrap().smem_inner(), 0);
        assert_eq!(by_id("smem_eta_1").unwrap().smem_pml(), 10 * 10 * 10 * 4);
        assert_eq!(by_id("smem_eta_1").unwrap().smem_inner(), 0);
    }

    #[test]
    fn spill_accounting() {
        assert_eq!(by_id("st_reg_shft_16x64").unwrap().spilled_regs(false), 32);
        assert_eq!(by_id("st_reg_shft_16x64").unwrap().spilled_regs(true), 16);
        assert_eq!(by_id("st_reg_fixed_32x32").unwrap().spilled_regs(false), 14);
        assert_eq!(by_id("st_reg_shft_16x16").unwrap().spilled_regs(false), 0);
        assert_eq!(by_id("gmem_8x8x8").unwrap().spilled_regs(false), 0);
    }

    #[test]
    fn eval_regions_cover_grid() {
        let a = v100();
        let regions = KernelVariant::eval_regions(&a);
        let total: usize = regions.iter().map(|(_, d, c)| d.volume() * c).sum();
        assert_eq!(total, a.eval_grid.pow(3));
    }

    #[test]
    fn unknown_id_rejected() {
        assert!(by_id("gmem_2x2x2").is_err());
    }

    #[test]
    fn resolve_accepts_shorthands_and_full_ids() {
        assert_eq!(resolve("gmem").unwrap().id, "gmem_8x8x8");
        assert_eq!(resolve("st_reg_fixed").unwrap().id, "st_reg_fixed_32x32");
        assert_eq!(resolve("gmem_4x4x4").unwrap().id, "gmem_4x4x4");
        assert!(resolve("warp_specialized").is_err());
    }

    #[test]
    fn fused_descriptors_resolve_with_degrees_and_deep_rings() {
        // paper_variants stays exactly Table II; tf_* live next to it
        assert!(paper_variants().iter().all(|v| v.fuse == 1));
        let degrees: Vec<u32> = fused_variants().iter().map(|v| v.fuse).collect();
        assert_eq!(degrees, vec![1, 2, 4]);
        assert_eq!(resolve("tf").unwrap().id, "tf_s2");
        assert_eq!(by_id("tf_s4").unwrap().fuse, 4);
        assert_eq!(by_id("tf_s2").unwrap().threads_per_block(), 256);

        // the s=1 control matches the plain streaming ring exactly
        assert_eq!(
            by_id("tf_s1").unwrap().smem_inner(),
            by_id("st_smem_16x16").unwrap().smem_inner()
        );
        // fused rings: (2R+1)+s planes of (d+2sR)^2
        assert_eq!(by_id("tf_s2").unwrap().smem_inner(), 11 * 32 * 32 * 4);
        assert_eq!(by_id("tf_s4").unwrap().smem_inner(), 13 * 48 * 48 * 4);
        // the deep s=4 skirt is a real cost: it outgrows even a V100
        // thread block's shared memory (the measured CPU analog is how
        // that degree stays explorable)
        assert!(by_id("tf_s4").unwrap().smem_inner() > v100().smem_per_block);
    }
}
