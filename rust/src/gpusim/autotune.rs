//! Tile-shape autotuner over the gpusim timing model — plus a
//! *measured* mode that re-ranks the model's top candidates by actual
//! CPU cost.
//!
//! The paper hand-picks tile shapes per machine (Table II's variants);
//! its conclusion calls for tooling that searches this space. This
//! module does exactly that: enumerate legal tile shapes for a code
//! shape family, score each with the occupancy + traffic + timing
//! models, and return the predicted-best configuration per machine.
//!
//! [`tune_measured`] (the `hostencil autotune --measured` backend)
//! closes the loop the ROADMAP asked for: it takes the model's top
//! candidates, builds each one's executable CPU analog
//! (`stencil::propagator`), times real in-place steps on a grid, and
//! reports where the model's ranking agrees with measured cost —
//! meaningful only now that the time loop is allocation-free, so the
//! measured rate reflects code shape rather than allocator traffic.
//!
//! The measured mode also searches the CPU-only axes the AMD/Nvidia
//! tuning study (arXiv 2406.08923) identifies as the per-architecture
//! payoff: row-kernel lane width and unroll depth. These have no
//! gpusim-model analog (the model scores GPU tile geometry), so they
//! enter as a measured-only sweep: each model-ranked tile shape is
//! timed once per requested `(lanes, unroll)` combination, forced
//! through [`crate::stencil::simd::force`].

use super::arch::GpuArch;
use super::kernels::{Family, KernelVariant};
use super::timing::{simulate, KernelRun};
use crate::grid::{Dim3, Domain};
use crate::stencil::{self, propagator, simd};

/// One autotuner candidate and its predicted run.
#[derive(Clone, Debug)]
pub struct Candidate {
    pub variant: KernelVariant,
    pub run: KernelRun,
}

/// Register counts per family (nvcc allocations from Table III; the
/// 1024-thread configurations are capped at 64 like the paper's).
fn regs_for(family: Family, threads: u32) -> (Option<u32>, u32, u32, u32, u32) {
    let capped = threads >= 1024;
    match family {
        Family::Gmem => (None, 40, 48, 40, 48),
        Family::SmemU => (None, 38, 48, 38, 48),
        Family::SmemEta1 | Family::SmemEta3 => (None, 40, 32, 40, 32),
        Family::Semi => (None, 40, 64, 40, 64),
        Family::StSmem => (None, 56, 72, 56, 72),
        Family::StRegShft => {
            if capped {
                (Some(64), 64, 64, 96, 80)
            } else {
                (None, 96, 80, 96, 80)
            }
        }
        Family::StRegFixed => {
            if capped {
                (Some(64), 64, 64, 78, 106)
            } else {
                (None, 78, 106, 78, 106)
            }
        }
    }
}

/// Enumerate legal tile shapes for `family` on `arch`.
pub fn candidates(arch: &GpuArch, family: Family) -> Vec<KernelVariant> {
    candidates_with(arch, family, &[1])
}

/// Enumerate legal (tile shape x fusion degree) candidates. Degrees
/// beyond 1 only make sense for the streaming families (temporal
/// fusion rides the plane ring); 3D families silently keep degree 1.
/// Infeasible combinations — a fused ring whose `(2R+1)+s` planes with
/// `s*R` skirts outgrow shared memory — are filtered like any other
/// over-budget shape, which is how the search space prunes deep fusion
/// on small-smem parts.
pub fn candidates_with(arch: &GpuArch, family: Family, fuse_degrees: &[u32]) -> Vec<KernelVariant> {
    let dims: &[u32] = &[4, 8, 16, 32, 64];
    let mut out = Vec::new();
    let streaming = family.is_streaming();
    let degrees: Vec<u32> = if streaming {
        let mut d: Vec<u32> = fuse_degrees.iter().copied().filter(|&s| s >= 1).collect();
        if d.is_empty() {
            d.push(1);
        }
        d
    } else {
        vec![1]
    };
    let shapes: Vec<(u32, u32, u32)> = if streaming {
        dims.iter()
            .flat_map(|&a| dims.iter().map(move |&b| (a, b, 0)))
            .collect()
    } else {
        dims.iter()
            .flat_map(|&a| {
                dims.iter().flat_map(move |&b| dims.iter().map(move |&c| (a, b, c)))
            })
            .collect()
    };
    for (d1, d2, d3) in shapes {
        let threads = if streaming { d1 * d2 } else { d1 * d2 * d3 };
        if threads < 32 || threads > arch.max_threads_per_block {
            continue;
        }
        let (nr, ri, rp, rni, rnp) = regs_for(family, threads);
        for &fuse in &degrees {
            let v = KernelVariant {
                id: "autotune",
                family,
                d1,
                d2,
                d3,
                fuse,
                maxrregcount: nr,
                regs_inner: ri,
                regs_pml: rp,
                regs_needed_inner: rni,
                regs_needed_pml: rnp,
            };
            // shared-memory feasibility (the paper: "otherwise, crash
            // the program execution")
            if v.smem_inner().max(v.smem_pml()) > arch.smem_per_block {
                continue;
            }
            out.push(v);
        }
    }
    out
}

/// Score every candidate of `family` on `arch`; best (lowest predicted
/// time) first.
pub fn tune(arch: &GpuArch, family: Family, steps: usize) -> Vec<Candidate> {
    tune_with(arch, family, steps, &[1])
}

/// [`tune`] over an explicit (shape x fusion degree) search space.
pub fn tune_with(
    arch: &GpuArch,
    family: Family,
    steps: usize,
    fuse_degrees: &[u32],
) -> Vec<Candidate> {
    let mut scored: Vec<Candidate> = candidates_with(arch, family, fuse_degrees)
        .into_iter()
        .map(|v| {
            let run = simulate(arch, &v, steps);
            Candidate { variant: v, run }
        })
        .collect();
    scored.sort_by(|a, b| a.run.time_s.total_cmp(&b.run.time_s));
    scored
}

/// Tune every family on `arch` and return the overall champion.
pub fn tune_all(arch: &GpuArch, steps: usize) -> Vec<Candidate> {
    tune_all_with(arch, steps, &[1])
}

/// [`tune_all`] over an explicit fusion-degree search space (degrees
/// only widen the streaming families; see [`candidates_with`]).
pub fn tune_all_with(arch: &GpuArch, steps: usize, fuse_degrees: &[u32]) -> Vec<Candidate> {
    let mut best: Vec<Candidate> = [
        Family::Gmem,
        Family::SmemU,
        Family::Semi,
        Family::StSmem,
        Family::StRegShft,
        Family::StRegFixed,
    ]
    .into_iter()
    .filter_map(|f| tune_with(arch, f, steps, fuse_degrees).into_iter().next())
    .collect();
    best.sort_by(|a, b| a.run.time_s.total_cmp(&b.run.time_s));
    best
}

/// One `--measured` row: a model-ranked candidate plus its measured
/// CPU full-step rate.
#[derive(Clone, Debug)]
pub struct MeasuredCandidate {
    pub candidate: Candidate,
    /// Rank in the model's ordering of the measured set (0 = model-best).
    pub model_rank: usize,
    /// Row-kernel lane width this row was measured with (1 = scalar).
    pub lanes: u8,
    /// Row-kernel unroll depth this row was measured with.
    pub unroll: u8,
    /// Measured CPU full-step rate of the candidate's executable analog.
    pub steps_per_sec: f64,
}

/// Outcome of a measured-mode search for one family.
#[derive(Clone, Debug)]
pub struct MeasuredReport {
    pub family: Family,
    /// CPU measurement grid (interior extent).
    pub grid: Dim3,
    /// Measured candidates in model order (best-predicted first).
    pub rows: Vec<MeasuredCandidate>,
    /// Fraction of candidate pairs the model orders like the
    /// measurement (1.0 = identical ranking).
    pub rank_agreement: f64,
    pub concordant_pairs: usize,
    pub total_pairs: usize,
}

impl MeasuredReport {
    /// The model's pick (first row by construction).
    pub fn model_best(&self) -> &MeasuredCandidate {
        &self.rows[0]
    }

    /// The measurement's pick (highest measured rate).
    pub fn measured_best(&self) -> &MeasuredCandidate {
        self.rows
            .iter()
            .max_by(|a, b| a.steps_per_sec.total_cmp(&b.steps_per_sec))
            .expect("measured report has rows")
    }
}

/// The CPU measurement domain for a cubic grid of extent `n` (PML 4,
/// CFL-stable dt for the synthetic constant-2500 m/s model).
pub fn measured_domain(n: usize) -> anyhow::Result<Domain> {
    let h = 10.0;
    Domain::new(Dim3::new(n, n, n), 4, h, stencil::cfl_dt(h, 2500.0))
}

/// Search tile shapes for `family` against *measured* CPU cost: take
/// the model's `top` best candidates, run each one's executable CPU
/// analog for `steps` in-place steps on `domain` (best of `samples`
/// after `warmup` throwaway runs), and report model-vs-measured rank
/// agreement over all candidate pairs. `fuse_degrees` widens the
/// search to (shape x fusion degree) for streaming families — the
/// fused candidates execute through the `TimeFused` CPU analog, so
/// `s` in {1, 2, 4} is ranked by the same measured signal as the tile
/// shapes (`&[1]` reproduces the unfused search exactly).
///
/// `lane_combos` widens the search once more, to (shape x fuse x lane
/// width x unroll): each model candidate is measured once per
/// `(lanes, unroll)` combination, forced through [`simd::force`] for
/// the duration of the timing (and released afterwards). `&[]` keeps
/// one row per candidate under whatever kernel dispatch is already
/// active. Results are bit-identical across combinations by the row-
/// kernel contract (docs/KERNELS.md), so the sweep ranks cost only.
#[allow(clippy::too_many_arguments)] // mirrors the bench knobs: search scope + measurement budget
pub fn tune_measured(
    arch: &GpuArch,
    family: Family,
    top: usize,
    domain: &Domain,
    steps: usize,
    warmup: usize,
    samples: usize,
    fuse_degrees: &[u32],
    lane_combos: &[(u8, u8)],
) -> anyhow::Result<MeasuredReport> {
    anyhow::ensure!(top >= 2, "--measured needs at least 2 candidates to rank");
    anyhow::ensure!(steps >= 1, "--measured needs at least 1 step per sample");
    let ranked = tune_with(arch, family, 1000, fuse_degrees);
    anyhow::ensure!(
        ranked.len() >= 2,
        "family {family:?} has fewer than 2 feasible candidates on {}",
        arch.name
    );
    let sweep = !lane_combos.is_empty();
    let active = simd::active();
    let combos: Vec<(u8, u8)> =
        if sweep { lane_combos.to_vec() } else { vec![(active.lanes, active.unroll)] };
    let mut rows: Vec<MeasuredCandidate> = Vec::new();
    for (i, c) in ranked.into_iter().take(top).enumerate() {
        for &(lanes, unroll) in &combos {
            if sweep && !simd::force(lanes, unroll) {
                simd::clear_force();
                anyhow::bail!(
                    "unsupported lane/unroll combination {lanes}x{unroll} \
                     (lanes 1|4|8|16, unroll 1|2|4; 1x1 is the scalar row)"
                );
            }
            let kern = simd::active();
            let mut prop = propagator::from_variant(&c.variant);
            let sps = propagator::measure_steps_per_sec(prop.as_mut(), domain, steps, warmup, samples);
            rows.push(MeasuredCandidate {
                candidate: c.clone(),
                model_rank: i,
                lanes: kern.lanes,
                unroll: kern.unroll,
                steps_per_sec: sps,
            });
        }
    }
    if sweep {
        simd::clear_force();
    }
    // pairwise agreement: rows are in model order, so a pair is
    // concordant when the earlier row also measures at least as fast.
    // Lane variants of the same shape share a model rank — the model
    // has no opinion on them, so those pairs are excluded.
    let mut concordant = 0usize;
    let mut total = 0usize;
    for i in 0..rows.len() {
        for j in i + 1..rows.len() {
            if rows[i].model_rank == rows[j].model_rank {
                continue;
            }
            total += 1;
            if rows[i].steps_per_sec >= rows[j].steps_per_sec {
                concordant += 1;
            }
        }
    }
    Ok(MeasuredReport {
        family,
        grid: domain.interior,
        rows,
        rank_agreement: concordant as f64 / total.max(1) as f64,
        concordant_pairs: concordant,
        total_pairs: total,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::arch::{p100, v100};
    use crate::gpusim::kernels::by_id;

    #[test]
    fn candidates_respect_hardware_limits() {
        let a = v100();
        for fam in [Family::Gmem, Family::StSmem, Family::StRegShft] {
            let cs = candidates(&a, fam);
            assert!(!cs.is_empty());
            for c in cs {
                assert!(c.threads_per_block() <= a.max_threads_per_block);
                assert!(c.smem_inner() <= a.smem_per_block);
            }
        }
    }

    #[test]
    fn tuner_recovers_the_papers_gmem_design_rules_on_v100() {
        // The paper's hand-tuned 3D gmem answer on V100 is 8x8x8. The
        // model-driven search must (a) rank it in the top tier, and
        // (b) agree with the paper's design rules: thick z (full z-halo
        // amortization) and no thin dz<=2 tiles anywhere near the top.
        // (The tuner's own pick, 16x4x8, trades y-extent for wider
        // x-coalescing at the same dz — a shape the paper never tried;
        // see EXPERIMENTS.md SExtensions.)
        let ranked = tune(&v100(), Family::Gmem, 1000);
        let pos_888 = ranked
            .iter()
            .position(|c| (c.variant.d1, c.variant.d2, c.variant.d3) == (8, 8, 8))
            .expect("8x8x8 in search space");
        assert!(pos_888 < 5, "8x8x8 ranked #{}", pos_888 + 1);
        let best = &ranked[0];
        assert!(best.variant.d3 >= 8, "top pick must keep thick z");
        assert!(best.run.time_s <= ranked[pos_888].run.time_s);
        for c in ranked.iter().take(5) {
            assert!(c.variant.d3 > 2, "thin blocks must not reach the top");
        }
    }

    #[test]
    fn tuner_never_loses_to_the_published_variant() {
        // The search space includes each published tile, so the tuned
        // result can only match or beat it.
        let a = p100();
        let published = simulate(&a, &by_id("st_reg_fixed_32x32").unwrap(), 1000).time_s;
        let tuned = tune(&a, Family::StRegFixed, 1000)[0].run.time_s;
        assert!(tuned <= published * 1.001, "{tuned} vs {published}");
    }

    #[test]
    fn tune_all_orders_families() {
        let best = tune_all(&v100(), 100);
        assert!(!best.is_empty());
        for w in best.windows(2) {
            assert!(w[0].run.time_s <= w[1].run.time_s);
        }
    }

    #[test]
    fn measured_mode_times_candidates_and_reports_rank_agreement() {
        let domain = measured_domain(14).unwrap();
        let r = tune_measured(&v100(), Family::Gmem, 3, &domain, 2, 0, 1, &[1], &[]).unwrap();
        assert_eq!(r.rows.len(), 3);
        assert_eq!(r.total_pairs, 3);
        assert!(r.concordant_pairs <= r.total_pairs);
        assert!((0.0..=1.0).contains(&r.rank_agreement));
        for (i, m) in r.rows.iter().enumerate() {
            assert_eq!(m.model_rank, i, "rows must stay in model order");
            assert!(m.steps_per_sec > 0.0 && m.steps_per_sec.is_finite());
        }
        // the measured best is, by definition, at least as fast as the
        // model's pick when re-measured
        assert!(r.measured_best().steps_per_sec >= r.model_best().steps_per_sec);
        // model order within the measured set must match the full ranking
        let full = tune(&v100(), Family::Gmem, 1000);
        assert_eq!(r.rows[0].candidate.variant.d1, full[0].variant.d1);
        assert_eq!(r.rows[0].candidate.variant.d3, full[0].variant.d3);
    }

    #[test]
    fn measured_mode_rejects_degenerate_searches() {
        let domain = measured_domain(14).unwrap();
        assert!(tune_measured(&v100(), Family::Gmem, 1, &domain, 2, 0, 1, &[1], &[]).is_err());
        assert!(tune_measured(&v100(), Family::Gmem, 3, &domain, 0, 0, 1, &[1], &[]).is_err());
        // lane/unroll combos outside the supported grid are rejected
        assert!(tune_measured(&v100(), Family::Gmem, 2, &domain, 1, 0, 1, &[1], &[(5, 2)]).is_err());
        assert!(tune_measured(&v100(), Family::Gmem, 2, &domain, 1, 0, 1, &[1], &[(8, 3)]).is_err());
    }

    #[test]
    fn fusion_degrees_enter_the_streaming_search_space() {
        let a = v100();
        // degree axis only exists for streaming families...
        let st = candidates_with(&a, Family::StSmem, &[1, 2, 4]);
        let degrees: std::collections::HashSet<u32> = st.iter().map(|v| v.fuse).collect();
        assert!(degrees.contains(&1) && degrees.contains(&2), "{degrees:?}");
        // ...every candidate still respects shared memory (deep fused
        // rings on big tiles must have been pruned)
        for c in &st {
            assert!(c.smem_inner() <= a.smem_per_block, "{}x{} s{}", c.d1, c.d2, c.fuse);
        }
        assert!(st.len() > candidates(&a, Family::StSmem).len());
        // ...and 3D families ignore it entirely
        let g = candidates_with(&a, Family::Gmem, &[1, 2, 4]);
        assert!(g.iter().all(|v| v.fuse == 1));
        assert_eq!(g.len(), candidates(&a, Family::Gmem).len());
    }

    #[test]
    fn measured_mode_sweeps_lane_width_and_unroll() {
        let domain = measured_domain(14).unwrap();
        let combos = [(1u8, 1u8), (4, 2), (8, 2)];
        let r = tune_measured(&v100(), Family::Gmem, 2, &domain, 1, 0, 1, &[1], &combos).unwrap();
        assert_eq!(r.rows.len(), 6, "2 shapes x 3 lane combos");
        // same-shape lane variants share a model rank and are excluded
        // from concordance: only the 3x3 cross-shape pairs count
        assert_eq!(r.total_pairs, 9);
        let seen: std::collections::HashSet<(u8, u8)> =
            r.rows.iter().map(|m| (m.lanes, m.unroll)).collect();
        assert!(seen.contains(&(1, 1)), "scalar control row present: {seen:?}");
        assert!(seen.contains(&(8, 2)), "widest requested combo present: {seen:?}");
        for m in &r.rows {
            assert!(m.steps_per_sec > 0.0 && m.steps_per_sec.is_finite());
        }
        // the sweep releases its force override when it finishes
        assert_eq!(simd::active(), simd::detected(), "lane force must not leak");
    }

    #[test]
    fn measured_mode_ranks_fusion_degrees_through_the_fused_analog() {
        // the fused candidates execute via TimeFused; the report must
        // carry their degrees and finite measured rates
        let domain = measured_domain(16).unwrap();
        let r = tune_measured(&v100(), Family::StSmem, 4, &domain, 2, 0, 1, &[1, 2, 4], &[]).unwrap();
        assert_eq!(r.rows.len(), 4);
        for m in &r.rows {
            assert!(m.steps_per_sec > 0.0 && m.steps_per_sec.is_finite());
        }
        assert!(
            r.rows.iter().any(|m| m.candidate.variant.fuse > 1),
            "the model's top streaming candidates should include a fused degree \
             (DRAM amortization dominates the model): {:?}",
            r.rows.iter().map(|m| m.candidate.variant.fuse).collect::<Vec<_>>()
        );
    }
}
