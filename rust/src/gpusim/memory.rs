//! L2/DRAM traffic model per code shape.
//!
//! Every point update streams um (read), v (read) and u+ (write): 12 B
//! at both levels. The interesting term is the u-array read traffic,
//! which depends on the code shape:
//!
//! * 3D blocking re-fetches the (D+2R)^3 halo-extended tile per block.
//!   At L2 this is the full halo ratio (the L1/staging level only
//!   absorbs intra-block reuse); at DRAM, x/y-halo re-reads from
//!   neighboring blocks partially hit in L2 (working-set model) while
//!   z-halo planes — an entire block-layer apart in schedule order —
//!   miss, giving the (Dz+2R)/Dz re-read factor.
//! * 2.5D streaming carries all z-reuse in registers / the ring buffer,
//!   so z re-reads vanish; only the 2D tile halo is re-fetched.
//! * Register-capped variants add local-memory spill traffic.
//!
//! Absolute transaction counts from nvprof include effects this model
//! does not capture (sector replay, TLB, eta/PML mixing); `report`
//! prints model-vs-paper deltas and the tests assert *orderings*.

use super::arch::GpuArch;
use super::kernels::{Family, KernelVariant};

const R: f64 = 4.0;

/// Bytes per point update at each memory level.
#[derive(Copy, Clone, Debug, Default)]
pub struct PointTraffic {
    pub l2_bytes: f64,
    pub dram_bytes: f64,
}

fn clamp01(x: f64) -> f64 {
    x.clamp(0.0, 1.0)
}

/// Sector-quantization factor for x-rows of `width` floats fetched with
/// halo misalignment (32 B sectors; the halo shifts rows off sector
/// boundaries by R floats, costing on average half an extra sector).
fn sector_factor(width: f64) -> f64 {
    let sectors = (width * 4.0 / 32.0).ceil() + 0.5;
    (sectors * 32.0) / (width * 4.0)
}

impl KernelVariant {
    /// Halo ratio of the 3D tile: (Dx+2R)(Dy+2R)(Dz+2R) / DxDyDz.
    fn ratio3(&self, halo: f64) -> f64 {
        let (dx, dy, dz) = (self.d1 as f64, self.d2 as f64, self.d3 as f64);
        ((dx + 2.0 * halo) * (dy + 2.0 * halo) * (dz + 2.0 * halo)) / (dx * dy * dz)
    }

    /// Halo ratio of the 2D streaming tile.
    fn ratio2(&self, halo: f64) -> f64 {
        let (a, b) = (self.d1 as f64, self.d2 as f64);
        ((a + 2.0 * halo) * (b + 2.0 * halo)) / (a * b)
    }
}

/// u-read traffic per point for the inner (high-order) kernel.
fn inner_u_read(arch: &GpuArch, v: &KernelVariant) -> PointTraffic {
    match v.family {
        Family::Gmem | Family::SmemU | Family::SmemEta1 | Family::SmemEta3 | Family::Semi => {
            let cx = sector_factor(v.d1 as f64 + 2.0 * R);
            // Thin blocks (small Dz) thrash the L1: the z-halo planes they
            // stage are (2R+Dz)/Dz of their volume and evict before reuse
            // (paper: gmem_32x32x1's 13.9e12 L2 transactions). Bounded by
            // the physical limit of 25 sector-quantized reads per point.
            let thrash = if v.d3 == 1 {
                // dz == 1: zero z-reuse in L1 — all 25 reads reach L2
                // sector-quantized (paper: gmem_32x32x1's 13.9e12).
                8.0
            } else {
                ((v.d3 as f64 + 2.0 * R) / v.d3 as f64 / 2.0).max(1.0)
            };
            let floats = if v.d3 == 1 {
                25.0 * cx * (2.0 * R / v.d3 as f64) / 1.6
            } else {
                (v.ratio3(R) * cx * thrash).min(25.0 * cx)
            };
            let _ = thrash;
            let mut l2 = 4.0 * floats;
            if v.family == Family::Semi {
                // backward phase re-reads + partial store/reload
                l2 *= 1.45;
            }
            // DRAM: compulsory + z-halo re-reads (a full block-layer apart
            // in schedule order; they survive in L2 only if a whole grid
            // plane fits) + x/y-halo re-reads (working set = one row of
            // blocks).
            let z_rereads = (v.d3 as f64 + 2.0 * R) / v.d3 as f64;
            // reuse distance of a z-halo plane = one full layer of blocks
            let layer_bytes = (arch.eval_grid as f64).powi(2) * (v.d3 as f64 + 2.0 * R) * 4.0;
            let miss_z = clamp01(layer_bytes / arch.l2_bytes as f64);
            let ratio_xy = v.ratio2(R); // x/y-halo ratio of the tile footprint
            let tile_bytes =
                (v.d1 as f64 + 2.0 * R) * (v.d2 as f64 + 2.0 * R) * (v.d3 as f64 + 2.0 * R) * 4.0;
            let row_blocks = (arch.eval_grid as f64 / v.d1 as f64).ceil();
            let miss_xy = clamp01(row_blocks * tile_bytes / arch.l2_bytes as f64);
            let mut dram = 4.0
                * (1.0 + (z_rereads - 1.0) * miss_z + (ratio_xy - 1.0) * miss_xy)
                * cx.min(1.25);
            if v.family == Family::Semi {
                dram *= 1.3; // partial spill traffic
            }
            // No large unified L1 on pre-Volta parts: the 25-point spread
            // thrashes the small L1/tex cache and halo absorption drops
            // (the paper's central P100 finding).
            if !arch.unified_l1 && v.smem_inner() == 0 {
                l2 *= arch.gmem_l2_penalty;
                dram *= arch.gmem_dram_penalty;
            }
            PointTraffic { l2_bytes: l2, dram_bytes: dram }
        }
        Family::StSmem | Family::StRegShft | Family::StRegFixed => {
            // z-reuse fully captured by ring buffer / register queue. The
            // first tile dimension maps to the contiguous axis: small d1
            // under-fills sectors (paper: st_smem_16x8 beats 8x16 by ~2x,
            // and "one should cut the plane such that the x-dimension ...
            // is assigned to the innermost dimension with a relatively
            // larger size").
            //
            // Temporal fusion (v.fuse = s > 1) changes both levels:
            // * the overlapped tile carries an s*R redundant-halo skirt,
            //   so the per-sweep halo ratio uses the widened halo and
            //   the redundant re-reads land at L2 (the skirt is
            //   recomputed from staged data every sub-step);
            // * the wavefield streams through DRAM once per s steps, so
            //   the per-step compulsory+halo DRAM traffic divides by s.
            // The tension between those two terms is exactly what the
            // autotuner ranks when it searches fusion degrees.
            let s = v.fuse.max(1) as f64;
            let halo = s * R; // s*R skirt; s = 1 is the plain 2.5D ring
            let streaming_coalesce = sector_factor(v.d1 as f64 + 2.0 * halo).max(1.1);
            let extra_core_read = if v.family == Family::StSmem { 0.0 } else { 1.0 };
            let l2 = 4.0 * (v.ratio2(halo) + extra_core_read) * streaming_coalesce;
            let tile_bytes = (v.d1 as f64 + 2.0 * halo) * (v.d2 as f64 + 2.0 * halo) * 4.0;
            let row_blocks = (arch.eval_grid as f64 / v.d1 as f64).ceil();
            // 0.4 floor: plane-by-plane streaming re-touches halo columns
            // every iteration, evicting neighbors' rows (calibrated to the
            // paper's near-identical DRAM traffic of st_* and gmem_8x8x8).
            let miss_xy = clamp01(row_blocks * tile_bytes / arch.l2_bytes as f64).max(0.4);
            let dram = 4.0 * (1.0 + (v.ratio2(halo) - 1.0) * miss_xy)
                * streaming_coalesce.min(1.25)
                / s;
            PointTraffic { l2_bytes: l2, dram_bytes: dram }
        }
    }
}

/// u+eta read traffic per point for the PML (7-point) kernel.
fn pml_u_eta_read(arch: &GpuArch, v: &KernelVariant) -> PointTraffic {
    // Low-order halo (1) -> small ratios regardless of family.
    let (u_ratio, eta_ratio) = if v.is_streaming() {
        (v.ratio2(1.0), v.ratio2(1.0))
    } else {
        (v.ratio3(1.0), v.ratio3(1.0))
    };
    let cx = sector_factor(v.d1 as f64 + 2.0);
    let mut l2 = 4.0 * (u_ratio + eta_ratio) * cx;
    let mut dram = 4.0 * 2.0 * 1.1; // essentially compulsory at halo 1
    if !arch.unified_l1 && v.smem_pml() == 0 {
        l2 *= 1.3;
        dram *= 1.4;
    }
    PointTraffic { l2_bytes: l2, dram_bytes: dram }
}

/// Local-memory spill traffic per point (bytes), added when an explicit
/// -maxrregcount forces register spilling. Shifting variants touch their
/// spilled slots every iteration; fixed-register variants mostly park
/// cold values (the paper: "the performance impact ... is hidden").
fn spill_bytes(arch: &GpuArch, v: &KernelVariant, pml: bool) -> f64 {
    let spilled = v.spilled_regs(pml) as f64;
    arch.spill_scale
        * match v.family {
            Family::StRegShft => 1.0 * spilled,
            Family::StRegFixed => 0.1 * spilled,
            _ => 0.5 * spilled,
        }
}

/// Total per-point traffic for one kernel flavor (inner or PML):
/// u reads + um/v/u+ stream + spills. For temporally fused inner
/// kernels the um/v/u+ stream amortizes at DRAM — one sweep serves
/// `fuse` steps — while the L2 stream term stays per sub-step (every
/// virtual step still touches the staged values). PML kernels run
/// unfused (the boundary skirt is stepped per virtual sub-step), so
/// their traffic never sees the fusion degree.
pub fn point_traffic(arch: &GpuArch, v: &KernelVariant, pml: bool) -> PointTraffic {
    let stream = 12.0; // um read + v read + u+ write
    let base = if pml { pml_u_eta_read(arch, v) } else { inner_u_read(arch, v) };
    let spill = spill_bytes(arch, v, pml);
    let stream_dram = if pml { stream } else { stream / v.fuse.max(1) as f64 };
    PointTraffic {
        l2_bytes: base.l2_bytes + stream + 2.0 * spill,
        dram_bytes: base.dram_bytes + stream_dram + spill,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::arch::{p100, v100};
    use crate::gpusim::kernels::by_id;

    fn trans_per_pt(t: PointTraffic) -> (f64, f64) {
        (t.l2_bytes / 32.0, t.dram_bytes / 32.0)
    }

    #[test]
    fn gmem_8x8x8_matches_paper_band() {
        // Paper (V100, Table IV): 1.79 L2 trans/pt, 0.726 DRAM trans/pt.
        let t = point_traffic(&v100(), &by_id("gmem_8x8x8").unwrap(), false);
        let (l2, dram) = trans_per_pt(t);
        assert!((0.9..=2.3).contains(&l2), "l2 {l2}");
        assert!((0.5..=1.0).contains(&dram), "dram {dram}");
    }

    #[test]
    fn smem_u_tracks_gmem_at_l2() {
        // Paper: smem_u 1.82e12 vs gmem 1.79e12 — nearly identical.
        let a = v100();
        let g = point_traffic(&a, &by_id("gmem_8x8x8").unwrap(), false);
        let s = point_traffic(&a, &by_id("smem_u").unwrap(), false);
        assert!((g.l2_bytes - s.l2_bytes).abs() / g.l2_bytes < 0.05);
    }

    #[test]
    fn streaming_reduces_dram_vs_3d() {
        // 2.5D carries z-reuse in registers; 3D re-reads z halos.
        let a = v100();
        let g = point_traffic(&a, &by_id("gmem_8x8x8").unwrap(), false);
        let st = point_traffic(&a, &by_id("st_smem_16x16").unwrap(), false);
        assert!(st.dram_bytes < g.dram_bytes, "{} vs {}", st.dram_bytes, g.dram_bytes);
    }

    #[test]
    fn thin_blocks_explode_l2() {
        // Paper: gmem_32x32x1 has 13.9e12 L2 transactions (7.8x gmem_8x8x8).
        let a = v100();
        let thin = point_traffic(&a, &by_id("gmem_32x32x1").unwrap(), false);
        let cube = point_traffic(&a, &by_id("gmem_8x8x8").unwrap(), false);
        assert!(
            thin.l2_bytes > 3.0 * cube.l2_bytes,
            "{} vs {}",
            thin.l2_bytes,
            cube.l2_bytes
        );
    }

    #[test]
    fn spilled_variants_pay_dram() {
        let a = v100();
        let capped = point_traffic(&a, &by_id("st_reg_shft_16x64").unwrap(), false);
        let free = point_traffic(&a, &by_id("st_reg_shft_16x16").unwrap(), false);
        assert!(capped.dram_bytes > free.dram_bytes + 16.0);
        // fixed-register spills cost much less
        let fixed = point_traffic(&a, &by_id("st_reg_fixed_32x32").unwrap(), false);
        let fixed_free = point_traffic(&a, &by_id("st_reg_fixed_16x16").unwrap(), false);
        assert!(fixed.dram_bytes - fixed_free.dram_bytes < capped.dram_bytes - free.dram_bytes);
    }

    #[test]
    fn p100_punishes_gmem_not_smem() {
        let (vp, pp) = (v100(), p100());
        let g_v = point_traffic(&vp, &by_id("gmem_8x8x8").unwrap(), false);
        let g_p = point_traffic(&pp, &by_id("gmem_8x8x8").unwrap(), false);
        let s_v = point_traffic(&vp, &by_id("smem_u").unwrap(), false);
        let s_p = point_traffic(&pp, &by_id("smem_u").unwrap(), false);
        assert!(g_p.dram_bytes > 1.5 * g_v.dram_bytes);
        assert!((s_p.dram_bytes - s_v.dram_bytes).abs() / s_v.dram_bytes < 0.2);
    }

    #[test]
    fn pml_traffic_is_low_order() {
        // halo-1 kernels move far less than the 25-point inner kernel
        let a = v100();
        let inner = point_traffic(&a, &by_id("gmem_8x8x8").unwrap(), false);
        let pml = point_traffic(&a, &by_id("gmem_8x8x8").unwrap(), true);
        assert!(pml.l2_bytes < inner.l2_bytes);
    }

    #[test]
    fn sector_factor_sane() {
        assert!(sector_factor(16.0) > 1.0);
        assert!(sector_factor(40.0) < sector_factor(12.0)); // wide rows coalesce better
    }

    #[test]
    fn temporal_fusion_trades_l2_for_dram() {
        // fusing s steps per sweep amortizes DRAM traffic but pays for
        // the redundant s*R halo skirt at L2 — the model must show both
        let a = v100();
        let base = point_traffic(&a, &by_id("tf_s1").unwrap(), false);
        let s2 = point_traffic(&a, &by_id("tf_s2").unwrap(), false);
        assert!(
            s2.dram_bytes < base.dram_bytes,
            "tf_s2 DRAM {} must undercut unfused {}",
            s2.dram_bytes,
            base.dram_bytes
        );
        assert!(
            s2.l2_bytes > base.l2_bytes,
            "the s*R skirt must cost L2: {} vs {}",
            s2.l2_bytes,
            base.l2_bytes
        );
        // s = 1 control is exactly the plain 16x16 streaming ring
        let st = point_traffic(&a, &by_id("st_smem_16x16").unwrap(), false);
        assert_eq!(base.l2_bytes, st.l2_bytes);
        assert_eq!(base.dram_bytes, st.dram_bytes);
        // PML kernels run unfused: no fusion term anywhere
        let p_base = point_traffic(&a, &by_id("tf_s1").unwrap(), true);
        let p_s2 = point_traffic(&a, &by_id("tf_s2").unwrap(), true);
        assert_eq!(p_base.dram_bytes, p_s2.dram_bytes);
        assert_eq!(p_base.l2_bytes, p_s2.l2_bytes);
    }
}
