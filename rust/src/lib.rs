//! `hostencil` — a Rust + JAX + Pallas reproduction of *"Accelerating
//! High-Order Stencils on GPUs"* (Sai et al., 2020).
//!
//! Three-layer architecture (see DESIGN.md):
//!
//! * **Layer 1** (build time, Python): Pallas kernels expressing the
//!   paper's CUDA code shapes (`python/compile/kernels/`).
//! * **Layer 2** (build time, Python): the JAX region step functions,
//!   AOT-lowered to HLO text artifacts (`python/compile/{model,aot}.py`).
//! * **Layer 3** (run time, this crate): the simulation coordinator —
//!   region scheduling over PJRT-loaded executables, wavefield state
//!   management, sources/receivers — plus the simulated GPU testbed
//!   (`gpusim`) that regenerates the paper's evaluation tables/figures.
//!
//! Python never runs on the simulation path: after `make artifacts` the
//! `hostencil` binary is self-contained.
//!
//! On top of the coordinator sits the **scenario subsystem**
//! ([`scenario`]): a catalogue of named physics stress scenarios
//! (homogeneous point source, layered reflector, gradient medium, PML
//! corner absorption, multi-source interference, long-run energy
//! stability, CFL-margin stress, degenerate tiny grids), each judged
//! against named pass/fail criteria into a `Pass`/`SoftFail`/`HardFail`
//! verdict, plus a campaign runner that fans the scenario x kernel
//! variant x machine matrix out over worker threads and exports a
//! report table + JSON. See `hostencil scenario` / `hostencil campaign`
//! and `examples/scenario_gauntlet.rs`.
//!
//! The CPU side executes through the **code-shape engine**
//! ([`stencil::propagator`]): a `Propagator` trait with tiled,
//! multithreaded CPU analogs of the paper's kernel families —
//!
//! | kernel variant id          | family (§IV)      | CPU code shape  |
//! |----------------------------|-------------------|-----------------|
//! | `naive` / `golden`         | — (reference)     | `Naive`         |
//! | `gmem_*`, `smem_u`, `smem_eta_*` | 3D blocking | `Blocked3D`     |
//! | `semi`                     | semi-stencil      | `SemiStencil`   |
//! | `st_smem_*`, `st_reg_*`    | 2.5D streaming    | `Streaming25D`  |
//! | `tf_s2`, `tf_s4`           | temporal blocking | `TimeFused`     |
//!
//! — so a kernel-variant id picks real executable code on the CPU path
//! (`Mode::Golden`), and campaign cells report *measured* steps/sec
//! (CPU engine, shared per propagator signature) next to *predicted*
//! steps/sec (gpusim model). All shapes except semi-stencil are
//! bit-identical to the golden reference; semi re-associates the
//! x-axis chain and agrees to a few ULP (`hostencil bench`,
//! `rust/tests/propagator_equivalence.rs`).
//!
//! The engine's time loop is **zero-allocation and zero-spawn**:
//! kernels read neighbors straight out of the persistent
//! R-ghost-padded wavefield through borrowed views
//! ([`grid::FieldView`]/[`grid::FieldViewMut`]) and update contiguous
//! x-rows of the output buffer in place — the output holds u(n-1) on
//! entry (the leapfrog `um` term), so two persistent padded buffers
//! simply ping-pong each step (`Propagator::step_into` + swap). Tile
//! task lists, per-worker scratch (streaming ring planes, semi partial
//! rows), and the persistent worker pool ([`runtime::pool`]) are all
//! planned once per (domain, threads): parallel steps release parked
//! condvar workers via a generation bump instead of spawning scoped
//! threads, so steady-state cost is the kernel, not the harness, on
//! every path. `rust/tests/zero_alloc.rs` proves the steady-state loop
//! allocates nothing for all four families, serial and pooled, and
//! `rust/tests/pool_lifecycle.rs` covers the pool's edge cases. On
//! this clean signal, `hostencil autotune --measured` re-ranks the
//! gpusim model's top tile shapes by *measured* CPU cost and reports
//! model-vs-measured rank agreement, `hostencil campaign --threads N`
//! treats N as a global worker budget split between the job fan-out
//! and each job's tile fan-out, and `hostencil bench --thread-sweep
//! 1,2,4,8` measures per-thread-count steady-state rates and parallel
//! efficiency of the pool executor (plus a least-squares Amdahl fit of
//! each shape's serial fraction, printed next to gpusim's occupancy
//! prediction).
//!
//! The **temporally fused family** (`stencil::fused::TimeFused`,
//! variants `tf_s2`/`tf_s4`) goes one step further: it advances `s`
//! leapfrog steps per memory sweep with overlapped (redundant-halo)
//! (z, y) tiles, staying bit-identical to golden — skirt points apply
//! their own region's update and sources inject between virtual
//! sub-steps via the `Propagator::advance_fused` batch path, which the
//! coordinator drives between observer callbacks. `hostencil run
//! --fuse 2`, `hostencil bench --fuse 1,2,4`, and `hostencil autotune
//! --measured --fuse` select, sweep, and rank fusion degrees; the
//! gpusim traffic model amortizes DRAM by `s` and charges the `s*R`
//! skirt at L2, so the model ranks fusion alongside tile shapes.
//!
//! Every layer is observable through the **flight-recorder telemetry**
//! ([`telemetry`]): a zero-steady-state-allocation metrics registry
//! (atomic counters/gauges, fixed-bucket log-scale histograms, RAII
//! phase spans) threaded through `PropagatorInputs`/`Plan` so serial,
//! pooled, and fused paths instrument identically — pool park/wake/
//! busy stats, per-family plan builds and tile claims, fused-skirt
//! recompute overhead, coordinator batch latency, source injections,
//! and watchdog trips. `--telemetry out.prom` writes Prometheus text
//! exposition (the `/metrics` payload a future `hostencil serve` will
//! expose), `--events out.jsonl` streams the JSONL event log, and
//! `hostencil telemetry --demo` prints a live snapshot; see
//! `docs/METRICS.md` for the full metric reference.
//!
//! Long-running production runs lean on the **recovery subsystem**
//! ([`recovery`]): versioned, checksummed binary checkpoints of the
//! full propagator state (`--checkpoint-every` / `--restore`, bitwise
//! -identical continuation proven by
//! `rust/tests/restart_consistency.rs`), divergence circuit breakers
//! (an energy-growth window and a NaN-rate budget) that trip to a
//! checkpoint-and-halt `SoftAbort` instead of stepping a dead run to
//! the budget, and JSONL trace recording (`--record`) replayable by
//! `hostencil replay`, which re-executes the run and diffs receiver
//! output against the recording. See `docs/OPERATIONS.md`.
//!
//! Those seams are kept honest by **deterministic fault injection**
//! ([`fault`]): seeded `--faults "site:kind@step[:p]"` plans arm
//! the halo exchange, checkpoint I/O, worker pool, and restore paths,
//! and `hostencil chaos` asserts that every injected fault class
//! either retries to a bit-identical completion or soft-aborts with
//! a restorable checkpoint — never a panic, never silent corruption.
//! With no plan armed the seams cost nothing and the zero-allocation
//! proofs hold unchanged.

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod fault;
pub mod gpusim;
pub mod grid;
pub mod json;
pub mod manifest;
pub mod recovery;
pub mod report;
pub mod runtime;
pub mod scenario;
pub mod shard;
pub mod stencil;
pub mod telemetry;
pub mod testkit;
pub mod wave;

/// Halo width of the high-order stencil (half the 8th spatial order).
pub const R: usize = 4;

/// Halo width of the eta array in the PML update.
pub const R_ETA: usize = 1;
