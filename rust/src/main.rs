//! `hostencil` CLI — the Layer-3 entrypoint.
//!
//! Subcommands:
//!   info        platform + artifact manifest + machine table (Table I)
//!   run         run a wave simulation (PJRT or golden backend)
//!   replay      re-execute a recorded run and diff receiver output
//!   validate    PJRT executables vs the pure-Rust golden propagator
//!   table2      regenerate Table II  (predicted wall time vs paper)
//!   table3      regenerate Table III (occupancy characteristics)
//!   table4      regenerate Table IV  (roofline characteristics)
//!   fig3        regenerate Figure 3  (roofline plots + CSV)
//!   occupancy   occupancy calculator for ad-hoc kernel resources
//!   sweep       tile-size sweep on the gpusim timing model
//!   scenario    named physics stress scenarios with pass/fail verdicts
//!   campaign    parallel scenario x variant x machine verdict matrix
//!   bench       measured CPU propagator matrix (code-shape engine)

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use hostencil::coordinator::{Coordinator, Mode, RunOptions};
use hostencil::fault::{FaultKind, FaultPlan, FaultSite};
use hostencil::gpusim::{arch, kernels, occupancy, timing, KernelResources};
use hostencil::recovery::{self, BreakerConfig, Checkpoint, Trace, TraceReceiver, TraceSource};
use hostencil::runtime::Engine;
use hostencil::telemetry::Registry;
use hostencil::wave;
use hostencil::{config::RunConfig, report};

/// Tiny `--key value` / `--key=value` / `--flag` argument parser (no
/// clap offline). Values that merely *look* like flags — negative
/// numbers such as `-1.5e-3` — are accepted as values; stray
/// positionals and malformed tokens are rejected instead of being
/// silently swallowed as flags.
struct Args {
    cmd: String,
    opts: HashMap<String, String>,
    /// Options that appeared with no value (`--quick`). Kept separate
    /// from `opts` so `--json` with a forgotten path errors instead of
    /// silently becoming the value `"true"`.
    flags: std::collections::HashSet<String>,
}

/// A token that may follow `--key` as its value: anything not starting
/// with `-`, or a negative number (`-5`, `-1.5e-3`, `-.25`).
fn is_value_token(tok: &str) -> bool {
    if !tok.starts_with('-') {
        return true;
    }
    let body = tok.trim_start_matches('-');
    if tok.starts_with("--") || body.is_empty() {
        return false;
    }
    body.starts_with(|c: char| c.is_ascii_digit() || c == '.')
}

impl Args {
    fn parse() -> anyhow::Result<Args> {
        Self::parse_from(std::env::args().skip(1).collect())
    }

    fn parse_from(tokens: Vec<String>) -> anyhow::Result<Args> {
        let mut it = tokens.into_iter();
        let cmd = it.next().unwrap_or_else(|| "help".to_string());
        let rest: Vec<String> = it.collect();
        let mut opts = HashMap::new();
        let mut flags = std::collections::HashSet::new();
        let mut i = 0;
        while i < rest.len() {
            let tok = &rest[i];
            let Some(body) = tok.strip_prefix("--") else {
                anyhow::bail!(
                    "unexpected argument {tok:?} (options are --key value, --key=value or --flag)"
                );
            };
            anyhow::ensure!(!body.is_empty(), "bare \"--\" is not a valid option");
            if let Some((k, v)) = body.split_once('=') {
                opts.insert(k.to_string(), v.to_string());
                i += 1;
            } else if i + 1 < rest.len() && is_value_token(&rest[i + 1]) {
                opts.insert(body.to_string(), rest[i + 1].clone());
                i += 2;
            } else {
                flags.insert(body.to_string());
                i += 1;
            }
        }
        Ok(Args { cmd, opts, flags })
    }

    /// Value of a value-taking option: `Ok(None)` when absent, an error
    /// when the option was given with no value.
    fn get(&self, k: &str) -> anyhow::Result<Option<&str>> {
        anyhow::ensure!(
            !self.flags.contains(k),
            "option --{k} needs a value (got a bare flag)"
        );
        Ok(self.opts.get(k).map(|s| s.as_str()))
    }

    fn has_flag(&self, k: &str) -> bool {
        self.flags.contains(k)
    }

    fn usize_or(&self, k: &str, d: usize) -> anyhow::Result<usize> {
        match self.get(k)? {
            None => Ok(d),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{k}: {e}")),
        }
    }
}

const HELP: &str = "hostencil — high-order stencil reproduction (Sai et al. 2020)

USAGE: hostencil <command> [options]

commands:
  info                         platform, artifacts, machines
  run        [--config f] [--steps N] [--mode decomposed|monolithic|fused|golden]
             [--variant gmem|smem_u|semi|st_smem|st_reg_shft|st_reg_fixed]
             [--pml-variant gmem|smem_eta_1|smem_eta_3] [--artifacts dir]
             [--propagator naive|<variant>] force the CPU code-shape engine:
                                            golden mode with that propagator
             [--cpu-threads N]              propagator tile worker threads
             [--fuse 1|2|4]                 golden mode with the temporally
                                            fused family at that degree
                                            (tf_s2/tf_s4; 1 = the unfused
                                            streaming control; overrides
                                            --propagator): s leapfrog steps
                                            per memory sweep, bit-identical
                                            physics, energy/receivers sampled
                                            per batch
             [--sample-every N]             cap observed batches at N steps
                                            so fused runs keep finer-grained
                                            energy/receiver traces
             [--shards N]                   split the interior into N z-slab
                                            shards (golden mode only): each
                                            shard owns private padded buffers
                                            plus an s*R-deep halo band and
                                            advances on its own plan/pool;
                                            seam halos are exchanged at fused
                                            batch boundaries, physics stays
                                            bit-identical to unsharded (see
                                            docs/SHARDING.md); errors up front
                                            when a slab would be thinner than
                                            the fused halo
             [--checkpoint-every N]         write a versioned, checksummed
                                            snapshot of the full propagator
                                            state every N steps (atomic
                                            tmp+rename; N >= 1; default
                                            destination hostencil.ckpt)
             [--checkpoint-path f]          snapshot destination; breaker
                                            trips dump here even without a
                                            cadence
             [--checkpoint-keep K]          retention-ring depth at the
                                            snapshot path: keep the K newest
                                            snapshots (f, f.1, ...) with an
                                            atomic rotation before each write;
                                            --restore falls back past slots
                                            that fail their checksum to the
                                            newest valid one (K >= 1,
                                            default 1)
             [--faults list]                arm deterministic fault injection:
                                            comma-separated site:kind@step[:p]
                                            specs — halo:delay|drop|corrupt,
                                            ckpt:short|enospc|corrupt,
                                            pool:panic, restore:corrupt; each
                                            spec fires at most once, at the
                                            first step boundary at or past
                                            `step`, with probability p in
                                            [0, 1] (default 1); the injection
                                            seams cost nothing when the flag
                                            is absent (see docs/OPERATIONS.md)
             [--fault-seed N]               seed for probabilistic fault
                                            draws (needs --faults; same seed
                                            = same schedule)
             [--restore f]                  resume from a snapshot: the grid
                                            and discretization are verified,
                                            then the remaining step budget
                                            runs bit-identical to the
                                            uninterrupted run
             [--record f]                   write a self-contained JSONL
                                            trace (model, sources, injected
                                            amplitudes, receiver traces)
                                            replayable by `hostencil replay`
                                            (golden mode only)
             [--breakers]                   arm the divergence circuit
                                            breakers: instead of stepping a
                                            diverged field to the budget,
                                            trip, checkpoint, and soft-abort
                                            with a structured reason
             [--breaker-window N] [--breaker-ratio r] [--breaker-arm N]
             [--nan-budget N]               breaker tuning; each implies
                                            --breakers (see docs/OPERATIONS.md)
  replay     --trace f [--tol t]            re-execute a `--record` trace on
                                            the CPU golden path and diff the
                                            replayed receiver output against
                                            the recording (default tolerance
                                            0.0 = bitwise)
  validate   [--artifacts dir] [--steps N]    PJRT vs golden, all variants
  table2     [--steps N]                      predicted wall time vs paper
  table3                                      occupancy characteristics
  table4     [--steps N]                      roofline characteristics
  fig3       [--machine v100|p100|nvs510] [--csv path]
  occupancy  --threads N --regs N [--smem bytes] [--machine v100]
  sweep      [--machine v100]                 tile-size sweep (timing model)
  autotune   [--machine v100] [--family st_reg_fixed|gmem|...]
                                            search tile shapes on the model
             [--fuse]                       widen the streaming search space
                                            with temporal-fusion degrees
                                            s in {1,2,4} (the traffic model
                                            amortizes DRAM by s and pays the
                                            s*R skirt at L2; infeasible deep
                                            rings are pruned by shared memory)
             [--measured] [--size N] [--steps N] [--top K]
                                            re-rank the model's top K tile
                                            shapes (and, with --fuse, fusion
                                            degrees — executed through the
                                            TimeFused analog) by *measured*
                                            CPU cost and report
                                            model-vs-measured rank agreement;
                                            --measured also sweeps the row-
                                            kernel lane width x unroll grid
                                            (CPU-only axes the model cannot
                                            score) — each shape is timed per
                                            (lanes, unroll) combination, the
                                            scalar 1x1 control included
             [--lanes 1x1,8x2,...]          restrict the measured lane sweep
                                            to explicit WxU combos (lanes
                                            1|4|8|16, unroll 1|2|4)
  scenario   [--id name|all] [--list] [--steps N] [--machine m --variant v]
             [--propagator p] [--cpu-threads N] [--json path] [--sample-every N]
             [--shards N] [--checkpoint-every N] [--checkpoint-path f]
             [--restore f] [--breakers]
                                            run named physics stress scenarios
                                            (CPU propagator backend) with
                                            pass/fail verdicts; stress ids
                                            expect HardFail; --shards runs the
                                            physics on the sharded engine
                                            (bit-identical, so expectations
                                            are unchanged)
  campaign   [--machine v100|p100|nvs510|a100|all] [--variant id|all]
             [--quick] [--threads N] [--json path] [--steps-scale f]
             [--sample-every N] [--shards N] [--serial-fraction f]
                                            scenario x variant x machine matrix
                                            in parallel; each cell shows
                                            measured (CPU code shape) and
                                            predicted (gpusim) steps/sec;
                                            physics is shared across cells with
                                            the same propagator signature;
                                            --threads is a *global* worker
                                            budget split between the job
                                            fan-out and each job's tile fan-out
                                            (default: available cores);
                                            --shards N runs every physics job
                                            on the sharded engine (the job's
                                            budget slice splits again across
                                            shards x tiles, still bounded by
                                            --threads); --serial-fraction f
                                            derates the gpusim-predicted
                                            steps/sec column by the Amdahl
                                            efficiency 1/(f*P + (1-f)) at the
                                            machine's modeled parallelism
                                            P = blocks/SM x SM count — feed it
                                            the fitted serial fraction that
                                            `bench --thread-sweep` prints;
                                            non-zero exit when any cell deviates
                                            from its expected verdict
  bench      [--size N] [--steps N] [--json path] [--cpu-threads N] [--check]
             [--margin 0.15] [--thread-sweep 1,2,4,8] [--fuse 1,2,4]
             [--simd-sweep] [--machine v100] [--shards N] [--shard-sweep 1,2,4]
             [--checkpoint-sweep 0,8,1]
                                            time the CPU propagator matrix
                                            (naive/blocked/streaming/semi +
                                            the fused tf_s2/tf_s4 rows; JSON
                                            v2 cases carry `fuse` plus the
                                            dispatched row-kernel `isa` and
                                            `lanes` fields) on a fixed grid;
                                            ranks by steady-state
                                            min (warm-up discarded, min next to
                                            median/mean in the JSON); --check
                                            exits non-zero if the tiled shapes
                                            lose to naive, tf_s2 loses to
                                            blocked_gmem, or (with a SIMD
                                            dispatch) the dispatched rows lose
                                            to forced-scalar rows at threads=1
                                            — every gate rides the --margin
                                            noise allowance (a fraction in
                                            [0, 1), default 0.15);
                                            --simd-sweep times each tiled
                                            shape scalar-forced vs dispatched
                                            at threads=1 and emits a
                                            `simd_sweep` JSON array with
                                            speedups (the row kernels are
                                            bit-identical either way, so the
                                            sweep ranks cost only);
                                            --fuse re-times the fused family
                                            at each listed degree (1 = unfused
                                            streaming control) and emits a
                                            `fuse_sweep` JSON array with
                                            speedups vs s=1;
                                            --thread-sweep re-times the matrix
                                            at each worker count on the
                                            persistent pool executor and
                                            reports steady-state rates plus
                                            parallel efficiency, defined as
                                            rate_T / (T x rate_1) — 100% is
                                            perfect scaling, and a flat rate
                                            (eff ~ 100%/T) means the grid is
                                            too small or the shape too serial
                                            to feed T workers; sweep rows land
                                            in the JSON as `thread_sweep`, and
                                            with --check the two smallest
                                            swept counts gate scaling: more
                                            workers must not lose to fewer
                                            (15% margin) — the zero-spawn pool
                                            must never make parallelism a net
                                            cost (needs >= 2 counts); when the
                                            sweep includes a 1-thread row, a
                                            least-squares Amdahl fit prints
                                            each shape's serial fraction next
                                            to gpusim's occupancy prediction
                                            (--machine, default v100; JSON
                                            `scaling_model` array) — measured
                                            vs predicted now covers parallel
                                            efficiency too (feed the fit to
                                            `campaign --serial-fraction`);
                                            --shards N times the main matrix
                                            on the sharded engine;
                                            --shard-sweep re-times the fuse-2
                                            sharded engine at each z-slab
                                            shard count and emits a
                                            `shard_sweep` JSON array with
                                            speedups vs the 1-shard control
                                            (infeasible counts are skipped
                                            with a note); with --check and
                                            measured 1- and 2-shard rows,
                                            2 shards must not lose to 1
                                            beyond --margin;
                                            --checkpoint-sweep re-times the
                                            fuse-2 engine at each snapshot
                                            cadence (0 = the checkpointing-
                                            off control) and emits a
                                            `checkpoint_sweep` JSON array
                                            with the steps/sec overhead of
                                            each cadence vs off; honors
                                            HOSTENCIL_BENCH_SAMPLES /
                                            HOSTENCIL_BENCH_WARMUP
  telemetry  [--demo] [--propagator p] [--steps N] [--size N] [--cpu-threads N]
                                            short instrumented run; print the
                                            Prometheus exposition and the
                                            captured flight-recorder events
  chaos      [--check] [--steps N] [--fault-seed N]
                                            run the deterministic fault x
                                            recovery matrix on a small sharded
                                            configuration: every injected
                                            fault class must either retry to a
                                            bit-identical completion or end in
                                            a soft abort with a restorable
                                            checkpoint — never a panic, never
                                            silent corruption; --check exits
                                            non-zero on any violated cell
                                            (the CI chaos gate)

telemetry flags (run / scenario / campaign / bench):
  --telemetry out.prom    write the Prometheus text exposition of every
                          registered metric (steps/injections counters,
                          batch-latency histograms, pool gauges, per-slot
                          tile/busy counters) at exit
  --events out.jsonl      stream flight-recorder events (plan builds,
                          batch boundaries, watchdog trips, run start/end)
                          to a JSONL file as the run progresses
  --sample-every N        cap observed batches at N steps (see run)
";

/// Map a fusion degree to its executable `tf_*` descriptor (1 = the
/// unfused streaming control). Anything else — 0 in particular, which
/// would mean "advance no steps per sweep" — is rejected up front.
fn fuse_variant(s: usize) -> anyhow::Result<&'static str> {
    match s {
        1 => Ok("tf_s1"),
        2 => Ok("tf_s2"),
        4 => Ok("tf_s4"),
        other => anyhow::bail!(
            "--fuse {other} unsupported: fusion degrees are 1, 2 or 4 (tf_s1/tf_s2/tf_s4)"
        ),
    }
}

/// Parse a `--fuse` degree list (`1,2,4`): sorted, deduplicated, every
/// entry a supported fusion degree.
fn parse_fuse_list(s: &str) -> anyhow::Result<Vec<usize>> {
    let mut out = Vec::new();
    for tok in s.split(',') {
        let d: usize = tok
            .trim()
            .parse()
            .map_err(|e| anyhow::anyhow!("--fuse: bad degree {tok:?}: {e}"))?;
        fuse_variant(d)?; // validates the degree (0 and friends rejected)
        out.push(d);
    }
    out.sort_unstable();
    out.dedup();
    Ok(out)
}

/// Shared `--telemetry` / `--events` wiring for run/scenario/campaign/
/// bench: one registry every layer instruments into, plus the
/// exposition path to write at exit. `None` when neither flag was
/// given — those runs stay exactly as un-instrumented as before.
struct CliTelemetry {
    registry: Registry,
    prom_path: Option<String>,
}

fn telemetry_from_args(args: &Args) -> anyhow::Result<Option<CliTelemetry>> {
    let prom_path = args.get("telemetry")?.map(str::to_string);
    let events_path = args.get("events")?.map(str::to_string);
    if prom_path.is_none() && events_path.is_none() {
        return Ok(None);
    }
    let registry = Registry::new();
    if let Some(p) = &events_path {
        // route the flight recorder to the file now, so events stream
        // out as the run progresses instead of buffering until exit
        registry.events().to_file(std::path::Path::new(p))?;
    }
    Ok(Some(CliTelemetry { registry, prom_path }))
}

impl CliTelemetry {
    /// Flush the event stream and write the exposition snapshot. Runs
    /// that error out skip this — a half-run has no snapshot worth
    /// publishing, and the streamed events still carry the story up to
    /// the failure (`BufWriter` flushes on drop).
    fn finish(&self) -> anyhow::Result<()> {
        self.registry.events().flush();
        if let Some(path) = &self.prom_path {
            std::fs::write(path, self.registry.render())?;
            println!("wrote {path}");
        }
        Ok(())
    }
}

/// Resolve the checkpoint cadence + destination from the CLI.
///
/// `--checkpoint-every 0` is rejected by name rather than silently
/// treated as "off": off is the absence of the flag. A cadence without
/// an explicit `--checkpoint-path` gets the default snapshot name, and
/// an explicit path *without* a cadence is kept so breaker trips still
/// have somewhere to dump state.
fn checkpointing_from_args(args: &Args) -> anyhow::Result<(usize, Option<PathBuf>)> {
    let every = match args.get("checkpoint-every")? {
        None => 0,
        Some(n) => {
            let n: usize = n.parse().map_err(|e| anyhow::anyhow!("--checkpoint-every: {e}"))?;
            anyhow::ensure!(n >= 1, "--checkpoint-every must be >= 1 (omit the flag to disable)");
            n
        }
    };
    let path = match args.get("checkpoint-path")? {
        Some(p) => Some(PathBuf::from(p)),
        None if every > 0 => Some(PathBuf::from("hostencil.ckpt")),
        None => None,
    };
    Ok((every, path))
}

/// Resolve the checkpoint retention-ring depth. The live snapshot is
/// itself a slot, so `--checkpoint-keep 0` would mean "write snapshots
/// nowhere" — rejected by name rather than clamped.
fn checkpoint_keep_from_args(args: &Args) -> anyhow::Result<usize> {
    match args.get("checkpoint-keep")? {
        None => Ok(1),
        Some(k) => {
            let k: usize = k.parse().map_err(|e| anyhow::anyhow!("--checkpoint-keep: {e}"))?;
            anyhow::ensure!(
                k >= 1,
                "--checkpoint-keep must be >= 1 (the live snapshot is the first ring slot)"
            );
            Ok(k)
        }
    }
}

/// Default seed for probabilistic fault draws: stable across runs so a
/// reported failure replays without hunting for the seed.
const DEFAULT_FAULT_SEED: u64 = 0x5EED;

fn fault_seed_from_args(args: &Args) -> anyhow::Result<u64> {
    match args.get("fault-seed")? {
        None => Ok(DEFAULT_FAULT_SEED),
        Some(s) => s.parse().map_err(|e| anyhow::anyhow!("--fault-seed: {e}")),
    }
}

/// Resolve the deterministic fault plan from `--faults` /
/// `--fault-seed`. `None` (the flag absent) keeps every injection seam
/// disarmed and cost-free; a seed without a plan is rejected by name so
/// a typo'd `--faults` spelling cannot silently run fault-free.
fn faults_from_args(args: &Args) -> anyhow::Result<Option<Arc<FaultPlan>>> {
    match args.get("faults")? {
        None => {
            anyhow::ensure!(
                args.get("fault-seed")?.is_none(),
                "--fault-seed without --faults has nothing to seed"
            );
            Ok(None)
        }
        Some(list) => {
            Ok(Some(Arc::new(FaultPlan::parse(list, fault_seed_from_args(args)?)?)))
        }
    }
}

/// Resolve the divergence-breaker configuration from the CLI. Breakers
/// arm when `--breakers` is given or any tuning option is; every field
/// defaults to [`BreakerConfig::default`]. Degenerate tunings (a window
/// too short to compare against, a ratio that would trip on flat
/// energy) are rejected by flag name.
fn breakers_from_args(args: &Args) -> anyhow::Result<Option<BreakerConfig>> {
    let tuned = ["breaker-window", "breaker-ratio", "breaker-arm", "nan-budget"]
        .iter()
        .any(|k| !matches!(args.get(k), Ok(None)));
    if !args.has_flag("breakers") && !tuned {
        return Ok(None);
    }
    let d = BreakerConfig::default();
    let energy_window = args.usize_or("breaker-window", d.energy_window)?;
    anyhow::ensure!(
        energy_window >= 2,
        "--breaker-window must be >= 2 (the ratio compares newest vs oldest sample)"
    );
    let energy_ratio = match args.get("breaker-ratio")? {
        None => d.energy_ratio,
        Some(r) => r.parse().map_err(|e| anyhow::anyhow!("--breaker-ratio: {e}"))?,
    };
    anyhow::ensure!(
        energy_ratio > 1.0,
        "--breaker-ratio must be > 1.0 (a ratio at or below 1 trips on steady energy)"
    );
    let arm_step = match args.get("breaker-arm")? {
        None => d.arm_step,
        Some(n) => Some(n.parse().map_err(|e| anyhow::anyhow!("--breaker-arm: {e}"))?),
    };
    let nan_budget = args.usize_or("nan-budget", d.nan_budget)?;
    Ok(Some(BreakerConfig { energy_window, energy_ratio, arm_step, nan_budget }))
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let args = Args::parse()?;
    match args.cmd.as_str() {
        "info" => cmd_info(&args),
        "run" => cmd_run(&args),
        "replay" => cmd_replay(&args),
        "validate" => cmd_validate(&args),
        "table2" => {
            print!("{}", report::table2(args.usize_or("steps", 1000)?));
            for m in ["v100", "p100", "nvs510"] {
                println!(
                    "rank agreement vs paper ({m}): {:.1}% of variant pairs ordered identically",
                    100.0 * report::rank_agreement(m, 100)?
                );
            }
            Ok(())
        }
        "table3" => {
            print!("{}", report::table3());
            Ok(())
        }
        "table4" => {
            print!("{}", report::table4(args.usize_or("steps", 1000)?));
            Ok(())
        }
        "fig3" => cmd_fig3(&args),
        "occupancy" => cmd_occupancy(&args),
        "sweep" => cmd_sweep(&args),
        "autotune" => cmd_autotune(&args),
        "scenario" => cmd_scenario(&args),
        "campaign" => cmd_campaign(&args),
        "bench" => cmd_bench(&args),
        "telemetry" => cmd_telemetry(&args),
        "chaos" => cmd_chaos(&args),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        other => anyhow::bail!("unknown command {other:?}\n{HELP}"),
    }
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    println!("{}", report::table1());
    let dir = args.get("artifacts")?.unwrap_or("artifacts");
    match Engine::load(dir) {
        Ok(engine) => {
            let m = engine.manifest();
            println!("PJRT platform : {}", engine.platform());
            println!(
                "artifacts     : {} in {dir:?} (domain {} pml {} dt {} h {})",
                m.artifacts.len(),
                m.domain.interior,
                m.domain.pml_width,
                m.domain.dt,
                m.domain.h
            );
            println!("inner variants: {}", m.inner_variants().join(", "));
            println!("pml variants  : {}", m.pml_variants().join(", "));
        }
        Err(e) => println!("artifacts     : unavailable ({e})"),
    }
    Ok(())
}

/// Build a coordinator from a run config (shared by run/validate).
fn build_coordinator<'e>(
    cfg: &RunConfig,
    engine: Option<&'e Engine>,
) -> anyhow::Result<Coordinator<'e>> {
    let v = cfg.model.build(cfg.domain.interior);
    let v_max = v.as_slice().iter().fold(0.0f32, |a, &b| a.max(b)) as f64;
    let eta = wave::eta_profile(&cfg.domain, v_max);
    Coordinator::new(
        engine,
        cfg.domain,
        cfg.mode,
        &cfg.inner_variant,
        &cfg.pml_variant,
        v,
        eta,
        cfg.source,
        cfg.receivers.clone(),
    )
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let mut cfg = match args.get("config")? {
        Some(path) => RunConfig::load(path)?,
        None => RunConfig::defaults(),
    };
    if let Some(s) = args.get("steps")? {
        cfg.steps = s.parse()?;
    }
    if let Some(m) = args.get("mode")? {
        cfg.mode = Mode::parse(m)?;
    }
    if let Some(v) = args.get("variant")? {
        cfg.inner_variant = v.to_string();
    }
    if let Some(v) = args.get("pml-variant")? {
        cfg.pml_variant = v.to_string();
    }
    if let Some(d) = args.get("artifacts")? {
        cfg.artifacts_dir = d.to_string();
    }
    if let Some(p) = args.get("propagator")? {
        // the code-shape engine is CPU-side: force golden mode and let
        // the variant id select the executable shape
        cfg.mode = Mode::Golden;
        cfg.inner_variant = p.to_string();
    }
    if let Some(f) = args.get("fuse")? {
        // temporal fusion is a CPU code-shape family too: golden mode
        // with the tf_* descriptor of that degree (wins over
        // --propagator when both are given)
        let s: usize = f.parse().map_err(|e| anyhow::anyhow!("--fuse: {e}"))?;
        cfg.mode = Mode::Golden;
        cfg.inner_variant = fuse_variant(s)?.to_string();
    }

    let engine = if cfg.mode.needs_engine() {
        Some(Engine::load(&cfg.artifacts_dir)?)
    } else {
        None
    };
    if let Some(eng) = &engine {
        // the artifact domain wins (it was fixed at AOT time)
        cfg.domain = eng.manifest().domain;
    }

    println!(
        "run: {} steps, mode {:?}, inner {}, pml {}, domain {} (pml {})",
        cfg.steps,
        cfg.mode,
        cfg.inner_variant,
        cfg.pml_variant,
        cfg.domain.interior,
        cfg.domain.pml_width
    );
    let mut coord = build_coordinator(&cfg, engine.as_ref())?;
    coord.set_cpu_threads(args.usize_or("cpu-threads", 0)?);
    coord.set_shards(args.usize_or("shards", 1)?)?;
    let telemetry = telemetry_from_args(args)?;
    if let Some(t) = &telemetry {
        coord.set_telemetry(&t.registry);
    }
    if let Some(sig) = coord.propagator_signature() {
        println!("cpu code shape: {sig}");
    }
    if coord.shards() > 1 {
        println!("sharding      : {} z-slab shards, halo exchange every batch", coord.shards());
    }
    let breakers = breakers_from_args(args)?;
    let (ckpt_every, ckpt_path) = checkpointing_from_args(args)?;
    if ckpt_every > 0 {
        if let Some(p) = &ckpt_path {
            println!("checkpointing : every {ckpt_every} steps -> {}", p.display());
        }
    }
    // a breaker trip skips the hard non-finite halt: the breaker owns
    // the abort (checkpoint + structured reason) instead of a bail
    coord.set_breakers(breakers);
    coord.set_checkpointing(ckpt_every, ckpt_path);
    let keep = checkpoint_keep_from_args(args)?;
    coord.set_checkpoint_keep(keep);
    if keep > 1 {
        println!("retention ring: {keep} snapshot slots");
    }
    if let Some(f) = faults_from_args(args)? {
        println!(
            "faults armed  : {} (seed {:#x})",
            args.get("faults")?.unwrap_or(""),
            fault_seed_from_args(args)?
        );
        coord.set_faults(f);
    }
    let mut steps = cfg.steps;
    if let Some(path) = args.get("restore")? {
        // the retention ring owns restore: the newest slot that passes
        // its checksum wins, and skipped (corrupt/torn) slots are named
        let (used, skipped) = coord.restore_from_ring(Path::new(path), keep)?;
        for note in &skipped {
            println!("restore skip  : {note}");
        }
        steps = cfg.steps.saturating_sub(coord.steps_done());
        println!(
            "restored      : {} at step {} ({steps} of {} steps remaining)",
            used.display(),
            coord.steps_done(),
            cfg.steps
        );
    }
    let summary = coord.run_observed(
        steps,
        RunOptions {
            sample_every: args.usize_or("sample-every", 0)?,
            ..RunOptions::default()
        },
        None,
    )?;
    println!(
        "done: {} launches, wall {:.3?}, {:.2} Mpts/s ({:.1} steps/s measured), \
         final |u|max {:.3e}, energy {:.3e}",
        summary.launches,
        summary.wall,
        summary.points_per_sec / 1e6,
        summary.steps as f64 / summary.wall.as_secs_f64().max(1e-12),
        summary.final_max_abs,
        summary.final_energy
    );
    if let Some(abort) = coord.soft_abort() {
        println!(
            "soft abort    : {} breaker tripped at step {} — {}",
            abort.kind.name(),
            abort.step,
            abort.detail
        );
    }
    // a stable digest over (step cursor, both leapfrog buffers): lets
    // CI compare a restored run against its uninterrupted twin by grep
    println!("state digest  : {:#018x}", coord.state_digest());
    if let Some(eng) = &engine {
        println!("\nper-artifact engine stats:");
        for (name, s) in eng.stats() {
            println!(
                "  {:32} calls {:>6}  mean exec {:>10.3?}  compile {:>8.3?}",
                name,
                s.calls,
                s.mean_exec(),
                s.compile_time
            );
        }
    }
    if !summary.traces.is_empty() {
        let rms: Vec<f64> = summary
            .traces
            .iter()
            .map(|t| (t.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / t.len().max(1) as f64).sqrt())
            .collect();
        let rms_str: Vec<String> = rms.iter().map(|r| format!("{r:.3e}")).collect();
        println!("receiver RMS: [{}]", rms_str.join(", "));
    }
    if let Some(path) = args.get("record")? {
        anyhow::ensure!(
            matches!(cfg.mode, Mode::Golden),
            "--record needs the CPU golden path (use --propagator or --fuse)"
        );
        anyhow::ensure!(
            args.get("restore")?.is_none(),
            "--record with --restore is unsupported (a trace must start at step 0)"
        );
        anyhow::ensure!(
            args.usize_or("sample-every", 0)? == 0,
            "--record with --sample-every is unsupported (the trace cadence is the \
             propagator's natural batch)"
        );
        // the injected amplitudes are recomputed per step and stored in
        // the trace, so replay can verify the source schedule before
        // diffing receivers
        let trace = Trace {
            interior: cfg.domain.interior,
            pml_width: cfg.domain.pml_width,
            h: cfg.domain.h,
            dt: cfg.domain.dt,
            steps: summary.steps,
            fuse: coord.fuse(),
            propagator: cfg.inner_variant.clone(),
            model: cfg.model.clone(),
            sources: coord
                .sources()
                .iter()
                .map(|&(source, v_at)| TraceSource {
                    source,
                    amps: (0..summary.steps)
                        .map(|n| source.amp_at(n, cfg.domain.dt, v_at))
                        .collect(),
                })
                .collect(),
            receivers: coord
                .receivers()
                .iter()
                .zip(&summary.traces)
                .map(|(&pos, t)| TraceReceiver { pos, trace: t.clone() })
                .collect(),
        };
        trace.save(Path::new(path))?;
        println!(
            "recorded      : {} steps of {} -> {path}",
            trace.steps, trace.propagator
        );
    }
    if let Some(t) = &telemetry {
        t.finish()?;
    }
    Ok(())
}

/// `hostencil replay --trace f`: rebuild the recorded run (domain,
/// velocity model, propagator, sources) from a JSONL trace, re-execute
/// it on the CPU golden path, and diff the replayed receiver traces
/// against the recording. The recorded injection schedule is verified
/// first, so a drifted source term reports as such rather than as a
/// mysterious receiver mismatch.
fn cmd_replay(args: &Args) -> anyhow::Result<()> {
    use hostencil::grid::{Dim3, Domain};

    let path = args
        .get("trace")?
        .ok_or_else(|| anyhow::anyhow!("replay needs --trace <file> (a `run --record` trace)"))?;
    let tol: f64 = match args.get("tol")? {
        None => 0.0,
        Some(t) => t.parse().map_err(|e| anyhow::anyhow!("--tol: {e}"))?,
    };
    let trace = Trace::load(Path::new(path))?;
    let domain = Domain::new(trace.interior, trace.pml_width, trace.h, trace.dt)?;
    let v = trace.model.build(trace.interior);
    let v_max = v.as_slice().iter().fold(0.0f32, |a, &b| a.max(b)) as f64;
    let eta = wave::eta_profile(&domain, v_max);
    let receivers: Vec<Dim3> = trace.receivers.iter().map(|r| r.pos).collect();
    let mut coord = Coordinator::new(
        None,
        domain,
        Mode::Golden,
        &trace.propagator,
        "gmem",
        v,
        eta,
        trace.sources[0].source,
        receivers,
    )?;
    for s in &trace.sources[1..] {
        coord.add_source(s.source)?;
    }
    anyhow::ensure!(
        coord.fuse() == trace.fuse,
        "propagator {} advances {} steps per sweep but the trace was recorded at fuse {} \
         (the receiver sampling cadence would differ)",
        trace.propagator,
        coord.fuse(),
        trace.fuse
    );
    for (i, (rec, &(source, v_at))) in trace.sources.iter().zip(coord.sources()).enumerate() {
        for (n, &amp) in rec.amps.iter().enumerate() {
            let here = source.amp_at(n, trace.dt, v_at);
            anyhow::ensure!(
                amp == here,
                "source {i} amplitude diverged at step {n}: recorded {amp:e}, \
                 replay computes {here:e}"
            );
        }
    }
    println!(
        "replay: {} steps of {} on {} (pml {}), {} source(s), {} receiver(s)",
        trace.steps,
        trace.propagator,
        trace.interior,
        trace.pml_width,
        trace.sources.len(),
        trace.receivers.len()
    );
    let summary = coord.run_observed(trace.steps, RunOptions::default(), None)?;
    let worst = recovery::max_trace_diff(&trace.receivers, &summary.traces)?;
    println!(
        "replayed {} steps: max |replayed - recorded| = {worst:.3e} (tolerance {tol:.1e})",
        summary.steps
    );
    anyhow::ensure!(
        worst <= tol,
        "replay diverged from the recording: max receiver deviation {worst:.3e} > \
         tolerance {tol:.3e}"
    );
    println!("replay OK: receiver traces match the recording");
    Ok(())
}

fn cmd_validate(args: &Args) -> anyhow::Result<()> {
    let dir = args.get("artifacts")?.unwrap_or("artifacts");
    let steps = args.usize_or("steps", 10)?;
    let engine = Engine::load(dir)?;
    let domain = engine.manifest().domain;
    let inner_variants: Vec<String> = engine
        .manifest()
        .inner_variants()
        .iter()
        .map(|s| s.to_string())
        .collect();
    println!(
        "validating {} steps on domain {} against golden CPU stencils",
        steps, domain.interior
    );
    let mut worst_overall = 0.0f32;
    for variant in &inner_variants {
        for pml_variant in engine.manifest().pml_variants() {
            let mut cfg = RunConfig::defaults();
            cfg.domain = domain;
            cfg.mode = Mode::Decomposed;
            cfg.inner_variant = variant.clone();
            cfg.pml_variant = pml_variant.clone();
            let mut pjrt = build_coordinator(&cfg, Some(&engine))?;
            cfg.mode = Mode::Golden;
            let mut gold = build_coordinator(&cfg, None)?;
            for _ in 0..steps {
                pjrt.step()?;
                gold.step()?;
            }
            let d = pjrt.wavefield().max_abs_diff(&gold.wavefield());
            let scale = gold.wavefield().max_abs().max(1e-30);
            let rel = d / scale;
            worst_overall = worst_overall.max(rel);
            println!(
                "  inner {variant:14} pml {pml_variant:12} max|diff| {d:.3e} (rel {rel:.3e})"
            );
            anyhow::ensure!(rel < 1e-4, "{variant}/{pml_variant} diverged from golden");
        }
    }
    println!("validate OK (worst relative deviation {worst_overall:.3e})");
    Ok(())
}

fn cmd_fig3(args: &Args) -> anyhow::Result<()> {
    let machine = args.get("machine")?.unwrap_or("v100");
    let (text, csv) = report::fig3(machine, args.usize_or("steps", 1000)?)?;
    println!("{text}");
    if let Some(path) = args.get("csv")? {
        std::fs::write(path, &csv)?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_occupancy(args: &Args) -> anyhow::Result<()> {
    let machine = arch::by_name(args.get("machine")?.unwrap_or("v100"))?;
    let res = KernelResources {
        threads_per_block: args.usize_or("threads", 256)? as u32,
        regs_per_thread: args.usize_or("regs", 32)? as u32,
        smem_per_block: args.usize_or("smem", 0)? as u32,
    };
    let occ = occupancy(&machine, &res);
    println!(
        "{}: {} blocks/SM, {} active warps, {:.1}% occupancy (limited by {:?})",
        machine.name, occ.blocks_per_sm, occ.active_warps, occ.occupancy_pct, occ.limiter
    );
    Ok(())
}

fn shape_of(v: &kernels::KernelVariant) -> String {
    let base = if v.is_streaming() {
        format!("{}x{}", v.d1, v.d2)
    } else {
        format!("{}x{}x{}", v.d1, v.d2, v.d3)
    };
    if v.fuse > 1 {
        format!("{base}+s{}", v.fuse)
    } else {
        base
    }
}

fn cmd_autotune(args: &Args) -> anyhow::Result<()> {
    use hostencil::gpusim::{autotune, Family};
    let machine = arch::by_name(args.get("machine")?.unwrap_or("v100"))?;
    let family = match args.get("family")? {
        None => None,
        Some("gmem") => Some(Family::Gmem),
        Some("smem_u") => Some(Family::SmemU),
        Some("semi") => Some(Family::Semi),
        Some("st_smem") => Some(Family::StSmem),
        Some("st_reg_shft") => Some(Family::StRegShft),
        Some("st_reg_fixed") => Some(Family::StRegFixed),
        Some(other) => anyhow::bail!("unknown family {other:?}"),
    };
    // --fuse widens the streaming search with temporal-fusion degrees;
    // 3D families ignore the axis (fusion rides the plane ring)
    let degrees: &[u32] = if args.has_flag("fuse") { &[1, 2, 4] } else { &[1] };
    if args.has_flag("measured") {
        return cmd_autotune_measured(args, &machine, family, degrees);
    }
    let show = |c: &autotune::Candidate| {
        let v = &c.variant;
        println!(
            "  {:?} {:<10} {:>6} thr {:>8.2}s  {:>6.0} GF/s",
            v.family,
            shape_of(v),
            v.threads_per_block(),
            c.run.time_s,
            c.run.gflops
        );
    };
    match family {
        Some(f) => {
            println!("autotune {:?} on {} (top 8 of the search space):", f, machine.name);
            for c in autotune::tune_with(&machine, f, 1000, degrees).iter().take(8) {
                show(c);
            }
        }
        None => {
            println!("autotune all families on {} (best per family):", machine.name);
            for c in autotune::tune_all_with(&machine, 1000, degrees) {
                show(&c);
            }
        }
    }
    Ok(())
}

/// Parse `--lanes 1x1,8x2,16x4` into (lane width, unroll) pairs. The
/// supported grid itself is validated downstream by `tune_measured`
/// (which owns the error message naming the legal values).
fn parse_lane_combos(spec: &str) -> anyhow::Result<Vec<(u8, u8)>> {
    let mut out = Vec::new();
    for part in spec.split(',').filter(|s| !s.trim().is_empty()) {
        let part = part.trim();
        let (w, u) = part
            .split_once('x')
            .ok_or_else(|| anyhow::anyhow!("--lanes: {part:?} is not WxU (e.g. 8x2)"))?;
        let w: u8 = w.trim().parse().map_err(|e| anyhow::anyhow!("--lanes: {part:?}: {e}"))?;
        let u: u8 = u.trim().parse().map_err(|e| anyhow::anyhow!("--lanes: {part:?}: {e}"))?;
        out.push((w, u));
    }
    anyhow::ensure!(!out.is_empty(), "--lanes needs at least one WxU combo (e.g. 1x1,8x2)");
    Ok(out)
}

/// Display tag for a measured (lane width, unroll) combination.
fn lane_label(lanes: u8, unroll: u8) -> String {
    if lanes <= 1 {
        "scalar".to_string()
    } else {
        format!("w{lanes}u{unroll}")
    }
}

/// `autotune --measured`: re-rank the model's top tile shapes by
/// *measured* CPU cost (the executable code-shape engine, in-place
/// zero-allocation time loop) and report model-vs-measured rank
/// agreement.
fn cmd_autotune_measured(
    args: &Args,
    machine: &hostencil::gpusim::GpuArch,
    family: Option<hostencil::gpusim::Family>,
    fuse_degrees: &[u32],
) -> anyhow::Result<()> {
    use hostencil::gpusim::{autotune, Family};
    let n = args.usize_or("size", 28)?;
    anyhow::ensure!(n >= 12, "--size must be >= 12 (needs room for PML width 4)");
    let steps = args.usize_or("steps", 4)?;
    let top = args.usize_or("top", 5)?;
    // same HOSTENCIL_BENCH_* contract (and defaults) as `bench`
    let budget = hostencil::bench::Bencher::from_env();
    let (warmup, samples) = (budget.warmup, budget.samples.max(1));
    // the lane-width x unroll axis of the search (CPU-only: the gpusim
    // model has no opinion on it, so it is measured-only). Default is
    // the full supported grid plus the scalar control; `--lanes` picks
    // an explicit subset, e.g. `--lanes 1x1,8x2`.
    let lane_combos: Vec<(u8, u8)> = match args.get("lanes")? {
        Some(spec) => parse_lane_combos(spec)?,
        None => {
            let mut grid = vec![(1u8, 1u8)];
            for &w in &hostencil::stencil::simd::LANE_WIDTHS {
                for &u in &hostencil::stencil::simd::UNROLLS {
                    grid.push((w, u));
                }
            }
            grid
        }
    };
    let domain = autotune::measured_domain(n)?;
    let families = match family {
        Some(f) => vec![f],
        None => vec![
            Family::Gmem,
            Family::SmemU,
            Family::Semi,
            Family::StSmem,
            Family::StRegShft,
            Family::StRegFixed,
        ],
    };
    println!(
        "autotune --measured on {}: top {top} model candidates per family x {} lane combos, \
         CPU grid {} (pml {}), {steps} steps x {samples} samples (+{warmup} warmup)",
        machine.name,
        lane_combos.len(),
        domain.interior,
        domain.pml_width
    );
    for f in families {
        let r = autotune::tune_measured(
            machine,
            f,
            top,
            &domain,
            steps,
            warmup,
            samples,
            fuse_degrees,
            &lane_combos,
        )?;
        println!("\n{:?} (model order):", r.family);
        for m in &r.rows {
            println!(
                "  model#{:<2} {:<10} {:<7} pred {:>8.2}s  measured {:>10.1} steps/s",
                m.model_rank + 1,
                shape_of(&m.candidate.variant),
                lane_label(m.lanes, m.unroll),
                m.candidate.run.time_s,
                m.steps_per_sec
            );
        }
        let best = r.measured_best();
        println!(
            "  model best {} | measured best {} {} | rank agreement {:.0}% \
             ({}/{} cross-shape pairs concordant)",
            shape_of(&r.model_best().candidate.variant),
            shape_of(&best.candidate.variant),
            lane_label(best.lanes, best.unroll),
            100.0 * r.rank_agreement,
            r.concordant_pairs,
            r.total_pairs
        );
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    let machine = arch::by_name(args.get("machine")?.unwrap_or("v100"))?;
    println!("tile-size sweep on {} (timing model, 1000 steps):", machine.name);
    let mut rows: Vec<(String, f64)> = kernels::paper_variants()
        .iter()
        .map(|v| (v.id.to_string(), timing::simulate(&machine, v, 1000).time_s))
        .collect();
    rows.sort_by(|a, b| a.1.total_cmp(&b.1));
    for (i, (id, t)) in rows.iter().enumerate() {
        println!("  {:>2}. {:<22}{:>9.2}s", i + 1, id, t);
    }
    println!("\nbest predicted kernel: {}", rows[0].0);
    Ok(())
}

fn cmd_scenario(args: &Args) -> anyhow::Result<()> {
    use hostencil::scenario::{run_scenario, RunnerOptions, ScenarioId};

    if args.has_flag("list") {
        println!("{:<28}{:<10}{}", "scenario", "expects", "description");
        for id in ScenarioId::all() {
            println!("{:<28}{:<10}{}", id.name(), id.expected_verdict().name(), id.describe());
        }
        return Ok(());
    }

    let ids = match args.get("id")? {
        None | Some("all") => ScenarioId::all(),
        Some(name) => vec![ScenarioId::parse(name)?],
    };
    let telemetry = telemetry_from_args(args)?;
    let (ckpt_every, ckpt_path) = checkpointing_from_args(args)?;
    let opts = RunnerOptions {
        steps_override: match args.get("steps")? {
            None => None,
            Some(s) => Some(s.parse().map_err(|e| anyhow::anyhow!("--steps: {e}"))?),
        },
        steps_scale: None,
        machine: args.get("machine")?.map(|s| s.to_string()),
        variant: match args.get("variant")? {
            None => None,
            Some(v) => Some(hostencil::scenario::campaign::resolve_variant(v)?),
        },
        propagator: args.get("propagator")?.map(|s| s.to_string()),
        cpu_threads: args.usize_or("cpu-threads", 0)?,
        sample_every: args.usize_or("sample-every", 0)?,
        shards: args.usize_or("shards", 0)?,
        telemetry: telemetry.as_ref().map(|t| t.registry.clone()),
        checkpoint_every: ckpt_every,
        checkpoint_path: ckpt_path,
        restore: args.get("restore")?.map(PathBuf::from),
        breakers: breakers_from_args(args)?,
    };

    let mut unexpected = Vec::new();
    let mut json_runs = Vec::new();
    for id in ids {
        let run = run_scenario(id, &opts)?;
        let tag = if run.as_expected() { "" } else { "  <-- UNEXPECTED" };
        println!(
            "{:<28}{:<10}(expected {}){tag}",
            id.name(),
            run.result.overall.name(),
            id.expected_verdict().name()
        );
        for c in &run.result.criteria {
            println!(
                "    {} {:<22} {}",
                if c.passed { "ok  " } else { "FAIL" },
                c.name,
                c.detail
            );
        }
        println!(
            "    [{} steps, peak |u| {:.3e}, final energy {:.3e}, {:.1} ms]",
            run.metrics.steps_completed,
            run.metrics.peak_abs,
            run.metrics.final_energy,
            run.metrics.wall_ms
        );
        if !run.as_expected() {
            unexpected.push(id.name());
        }
        if args.get("json")?.is_some() {
            let mut o = std::collections::BTreeMap::new();
            o.insert("scenario".to_string(), hostencil::json::Json::Str(id.name().into()));
            o.insert(
                "verdict".to_string(),
                hostencil::json::Json::Str(run.result.overall.name().into()),
            );
            o.insert(
                "failed_criteria".to_string(),
                hostencil::json::Json::Arr(
                    run.result
                        .failed()
                        .iter()
                        .map(|c| hostencil::json::Json::Str(c.name.into()))
                        .collect(),
                ),
            );
            json_runs.push(hostencil::json::Json::Obj(o));
        }
    }
    if let Some(path) = args.get("json")? {
        std::fs::write(path, hostencil::json::Json::Arr(json_runs).emit())?;
        println!("wrote {path}");
    }
    if let Some(t) = &telemetry {
        // publish before the verdict gate: an unexpected verdict is
        // exactly when the exposition is most worth reading
        t.finish()?;
    }
    anyhow::ensure!(
        unexpected.is_empty(),
        "scenarios with unexpected verdicts: {}",
        unexpected.join(", ")
    );
    Ok(())
}

fn cmd_campaign(args: &Args) -> anyhow::Result<()> {
    use hostencil::scenario::campaign::{self, CampaignSpec};

    let machines: Vec<String> = match args.get("machine")? {
        None | Some("all") => ["v100", "p100", "nvs510"].iter().map(|s| s.to_string()).collect(),
        Some(m) => {
            arch::by_name(m)?; // validate early
            vec![m.to_string()]
        }
    };
    let mut spec = if args.has_flag("quick") {
        CampaignSpec::quick(machines)
    } else {
        CampaignSpec::full(machines)
    };
    match args.get("variant")? {
        None | Some("all") => {}
        Some(v) => spec.variants = vec![campaign::resolve_variant(v)?],
    }
    if let Some(s) = args.get("steps-scale")? {
        let scale: f64 = s.parse().map_err(|e| anyhow::anyhow!("--steps-scale: {e}"))?;
        anyhow::ensure!(scale > 0.0, "--steps-scale must be positive");
        spec.steps_scale = Some(scale);
    }
    spec.threads = args.usize_or("threads", 0)?;
    spec.sample_every = args.usize_or("sample-every", 0)?;
    spec.shards = args.usize_or("shards", 1)?;
    if let Some(f) = args.get("serial-fraction")? {
        let f: f64 = f.parse().map_err(|e| anyhow::anyhow!("--serial-fraction: {e}"))?;
        anyhow::ensure!(
            (0.0..1.0).contains(&f),
            "--serial-fraction must be a fraction in [0.0, 1.0), got {f}"
        );
        spec.serial_fraction = Some(f);
    }
    let telemetry = telemetry_from_args(args)?;
    spec.telemetry = telemetry.as_ref().map(|t| t.registry.clone());

    println!(
        "campaign: {} scenarios x {} variants x {} machines = {} cells",
        spec.scenarios.len(),
        spec.variants.len(),
        spec.machines.len(),
        spec.scenarios.len() * spec.variants.len() * spec.machines.len()
    );
    let report = campaign::run_campaign(&spec);
    print!("{}", report::campaign_table(&report));

    if let Some(path) = args.get("json")? {
        std::fs::write(path, report.to_json().emit())?;
        println!("wrote {path}");
    }
    if let Some(t) = &telemetry {
        // publish before the off-expectation gate (see cmd_scenario)
        t.finish()?;
    }
    anyhow::ensure!(
        report.off_expectation_count() == 0,
        "{} cell(s) deviated from their expected verdict",
        report.off_expectation_count()
    );
    Ok(())
}

/// Parse a `--thread-sweep` list (`1,2,4,8`): comma-separated worker
/// counts, sorted and deduplicated so the 1-thread rate (when the
/// list contains it) is measured before the larger counts that report
/// efficiency against it, and so `--check` can gate the two smallest
/// counts.
fn parse_thread_list(s: &str) -> anyhow::Result<Vec<usize>> {
    let mut out = Vec::new();
    for tok in s.split(',') {
        let t: usize = tok
            .trim()
            .parse()
            .map_err(|e| anyhow::anyhow!("--thread-sweep: bad count {tok:?}: {e}"))?;
        anyhow::ensure!(t >= 1, "--thread-sweep: worker counts must be >= 1");
        out.push(t);
    }
    out.sort_unstable();
    out.dedup();
    Ok(out)
}

/// Parse a `--shard-sweep` list (`1,2,4`): comma-separated z-slab
/// shard counts, sorted and deduplicated so the 1-shard control (when
/// the list contains it) is measured before the counts that report
/// speedup against it, and so `--check` can gate 2-vs-1 shards.
fn parse_shard_list(s: &str) -> anyhow::Result<Vec<usize>> {
    let mut out = Vec::new();
    for tok in s.split(',') {
        let t: usize = tok
            .trim()
            .parse()
            .map_err(|e| anyhow::anyhow!("--shard-sweep: bad count {tok:?}: {e}"))?;
        anyhow::ensure!(t >= 1, "--shard-sweep: shard counts must be >= 1");
        out.push(t);
    }
    out.sort_unstable();
    out.dedup();
    Ok(out)
}

/// Parse a `--checkpoint-sweep` cadence list (`0,8,1`): sorted and
/// deduplicated. Cadence 0 is the checkpointing-off control the
/// overhead column compares against (so 0 is *allowed* here, unlike
/// `--checkpoint-every`).
fn parse_ckpt_list(s: &str) -> anyhow::Result<Vec<usize>> {
    let mut out = Vec::new();
    for tok in s.split(',') {
        let t: usize = tok
            .trim()
            .parse()
            .map_err(|e| anyhow::anyhow!("--checkpoint-sweep: bad cadence {tok:?}: {e}"))?;
        out.push(t);
    }
    out.sort_unstable();
    out.dedup();
    Ok(out)
}

/// Time the executable CPU propagator matrix on a fixed small grid and
/// optionally emit a `BENCH_*.json`-compatible file, so the repo's perf
/// trajectory tracks *measured* numbers (`hostencil bench --json
/// BENCH_0.json`). `--thread-sweep` re-times the matrix per worker
/// count on the persistent pool executor so parallel efficiency is
/// directly measurable. Sample counts honor `HOSTENCIL_BENCH_SAMPLES`
/// / `HOSTENCIL_BENCH_WARMUP` for CI smoke runs.
fn cmd_bench(args: &Args) -> anyhow::Result<()> {
    use hostencil::bench::Bencher;
    use hostencil::grid::{Dim3, Domain};
    use hostencil::json::Json;
    use hostencil::stencil::{self, propagator};
    use hostencil::wave::{Source, VelocityModel};
    use std::collections::BTreeMap;

    let n = args.usize_or("size", 24)?;
    anyhow::ensure!(n >= 12, "--size must be >= 12 (needs room for PML width 4)");
    let steps = args.usize_or("steps", 8)?;
    anyhow::ensure!(steps >= 1, "--steps must be >= 1");
    // --check noise allowance: a relative rate slack so shared-runner
    // jitter on small smoke grids cannot flake the gates (0.15 = the
    // historical hard-coded 15%)
    let margin: f64 = match args.get("margin")? {
        None => 0.15,
        Some(v) => {
            let m: f64 = v.parse().map_err(|e| anyhow::anyhow!("--margin: {e}"))?;
            anyhow::ensure!(
                (0.0..1.0).contains(&m),
                "--margin must be a fraction in [0.0, 1.0), got {m}"
            );
            m
        }
    };
    // (parse_thread_list never returns an empty list: even "" fails
    // the per-token parse, and a bare --thread-sweep errors in get())
    let sweep: Option<Vec<usize>> = match args.get("thread-sweep")? {
        None => None,
        Some(list) => Some(parse_thread_list(list)?),
    };
    let fuse_list: Option<Vec<usize>> = match args.get("fuse")? {
        None => None,
        Some(list) => Some(parse_fuse_list(list)?),
    };
    let shard_list: Option<Vec<usize>> = match args.get("shard-sweep")? {
        None => None,
        Some(list) => Some(parse_shard_list(list)?),
    };
    let ckpt_list: Option<Vec<usize>> = match args.get("checkpoint-sweep")? {
        None => None,
        Some(list) => Some(parse_ckpt_list(list)?),
    };
    // one registry across the whole matrix (series are deduplicated by
    // name + labels, collectors re-point to the live pool), so the
    // exit snapshot aggregates every timed shape
    let telemetry = telemetry_from_args(args)?;
    let sample_every = args.usize_or("sample-every", 0)?;
    let h = 10.0;
    let v0 = 2500.0f32;
    let dt = stencil::cfl_dt(h, v0 as f64);
    let domain = Domain::new(Dim3::new(n, n, n), 4, h, dt)?;
    let interior = domain.interior;
    let rate = |ns: u128| (interior.volume() * steps) as f64 / (ns as f64 / 1e9).max(1e-12);

    struct Row {
        name: String,
        /// temporal fusion degree of the shape (1 for unfused rows)
        fuse: u32,
        /// row-kernel ISA the case dispatched ("scalar" for naive,
        /// which keeps the bit-identity oracle by contract)
        isa: String,
        /// row-kernel lane width (1 = scalar)
        lanes: u8,
        median_ns: u128,
        mean_ns: u128,
        min_ns: u128,
        /// median-based rate (whole-run throughput)
        pps: f64,
        /// min-based rate (steady state: first-touch faults excluded)
        pps_best: f64,
    }

    // the kernel every tiled family dispatches this process (recorded
    // per case so BENCH artifacts are comparable across machines)
    let kern = stencil::simd::active();

    let mut b = Bencher::from_env();
    println!(
        "bench: propagator matrix on {} interior (pml {}), {} steps/sample, {} samples (+{} warmup)",
        interior, domain.pml_width, steps, b.samples, b.warmup
    );
    let mut rows: Vec<Row> = Vec::new();
    for (label, variant) in propagator::bench_matrix() {
        let v = VelocityModel::Constant(v0).build(interior);
        let eta = wave::eta_profile(&domain, v0 as f64);
        let src = Source { pos: Dim3::new(n / 2, n / 2, n / 2), f0: 15.0, amplitude: 1.0 };
        let mut coord =
            Coordinator::new(None, domain, Mode::Golden, variant, "gmem", v, eta, src, vec![])?;
        coord.set_cpu_threads(args.usize_or("cpu-threads", 0)?);
        coord.set_shards(args.usize_or("shards", 1)?)?;
        if let Some(t) = &telemetry {
            coord.set_telemetry(&t.registry);
        }
        let (median_ns, mean_ns, min_ns) = {
            let s = b.bench(label, || {
                coord
                    .run_observed(
                        steps,
                        RunOptions { sample_every, ..RunOptions::default() },
                        None,
                    )
                    .expect("bench step")
                    .final_max_abs
            });
            (s.median.as_nanos(), s.mean.as_nanos(), s.min.as_nanos())
        };
        let (isa, lanes) = if label == "naive" {
            ("scalar".to_string(), 1)
        } else {
            (kern.isa.name().to_string(), kern.lanes)
        };
        rows.push(Row {
            name: label.to_string(),
            // the naive reference has no gpusim descriptor; every
            // other matrix row resolves (tf rows carry their degree)
            fuse: kernels::resolve(variant).map(|v| v.fuse).unwrap_or(1),
            isa,
            lanes,
            median_ns,
            mean_ns,
            min_ns,
            pps: rate(median_ns),
            pps_best: rate(min_ns),
        });
    }
    // rank by the steady-state (min) time: medians of short smoke runs
    // are polluted by first-touch page faults and scheduler noise
    rows.sort_by(|x, y| x.min_ns.cmp(&y.min_ns));
    println!("\nranking (steady-state min, median in parens):");
    for (i, r) in rows.iter().enumerate() {
        println!(
            "  {:>2}. {:<22}{:>10.2} Mpts/s  ({:>8.2})",
            i + 1,
            r.name,
            r.pps_best / 1e6,
            r.pps / 1e6
        );
    }

    // --thread-sweep: re-time the matrix per worker count on the
    // persistent pool executor. Parallel efficiency is rate_T / (T x
    // rate_1) — with a zero-spawn fan-out the only losses left are
    // genuine ones (serial fraction, memory bandwidth, too-small
    // grids), which is exactly what the sweep makes visible.
    struct SweepRow {
        name: &'static str,
        threads: usize,
        min_ns: u128,
        pps_best: f64,
        sps_best: f64,
        efficiency: Option<f64>,
    }
    let mut sweep_rows: Vec<SweepRow> = Vec::new();
    if let Some(counts) = &sweep {
        println!("\nthread sweep (steady-state min; efficiency = rate_T / (T x rate_1)):");
        for (label, variant) in propagator::bench_matrix() {
            let mut rate1: Option<f64> = None;
            for &t in counts {
                let v = VelocityModel::Constant(v0).build(interior);
                let eta = wave::eta_profile(&domain, v0 as f64);
                let src =
                    Source { pos: Dim3::new(n / 2, n / 2, n / 2), f0: 15.0, amplitude: 1.0 };
                let mut coord = Coordinator::new(
                    None,
                    domain,
                    Mode::Golden,
                    variant,
                    "gmem",
                    v,
                    eta,
                    src,
                    vec![],
                )?;
                coord.set_cpu_threads(t);
                if let Some(tel) = &telemetry {
                    coord.set_telemetry(&tel.registry);
                }
                let min_ns = b
                    .bench(&format!("{label} @{t}thr"), || {
                        coord
                            .run_observed(
                                steps,
                                RunOptions { sample_every, ..RunOptions::default() },
                                None,
                            )
                            .expect("bench step")
                            .final_max_abs
                    })
                    .min
                    .as_nanos();
                let pps_best = rate(min_ns);
                if t == 1 {
                    rate1 = Some(pps_best);
                }
                sweep_rows.push(SweepRow {
                    name: label,
                    threads: t,
                    min_ns,
                    pps_best,
                    sps_best: steps as f64 / (min_ns as f64 / 1e9).max(1e-12),
                    efficiency: rate1.map(|r1| pps_best / (t as f64 * r1)),
                });
            }
        }
        for r in &sweep_rows {
            let eff = match r.efficiency {
                Some(e) => format!("{:>5.0}%", 100.0 * e),
                None => "    -".to_string(),
            };
            println!(
                "  {:<22}{:>3} thr {:>10.2} Mpts/s  {:>8.1} steps/s  eff {eff}",
                r.name,
                r.threads,
                r.pps_best / 1e6,
                r.sps_best
            );
        }
    }

    // Scaling model (ROADMAP: measured-vs-predicted must cover
    // parallel efficiency, not just single-thread rate): least-squares
    // Amdahl fit of each shape's serial fraction over the sweep, next
    // to gpusim's occupancy prediction for the matching inner kernel.
    // The fit needs the 1-thread baseline; shapes/sweeps without one
    // print "-".
    struct ScalingRow {
        name: &'static str,
        serial_fraction: Option<f64>,
        occupancy_pct: Option<f64>,
    }
    let mut scaling_rows: Vec<ScalingRow> = Vec::new();
    if !sweep_rows.is_empty() {
        let machine = arch::by_name(args.get("machine")?.unwrap_or("v100"))?;
        println!(
            "\nscaling model (Amdahl least-squares fit over the sweep; occupancy: {} inner kernel):",
            machine.name
        );
        for (label, variant) in propagator::bench_matrix() {
            let samples: Vec<(usize, f64)> = sweep_rows
                .iter()
                .filter(|r| r.name == label)
                .map(|r| (r.threads, r.pps_best))
                .collect();
            let f = hostencil::bench::amdahl_serial_fraction(&samples);
            let occ_pct = kernels::resolve(variant)
                .ok()
                .map(|v| occupancy(&machine, &v.resources_inner()).occupancy_pct);
            let f_str = match f {
                Some(f) => format!("{:>5.1}%", 100.0 * f),
                None => "    -".to_string(),
            };
            let occ_str = match occ_pct {
                Some(p) => format!("{p:>5.1}%"),
                None => "    -".to_string(),
            };
            println!("  {label:<22}serial fraction {f_str}   predicted occupancy {occ_str}");
            scaling_rows.push(ScalingRow {
                name: label,
                serial_fraction: f,
                occupancy_pct: occ_pct,
            });
        }
    }

    // --fuse: re-time the temporally fused family at each degree on
    // identical physics (s = 1 is the unfused streaming control), so
    // the fusion payoff — one memory sweep per s steps vs the
    // redundant-skirt overhead — is directly measurable.
    struct FuseRow {
        s: usize,
        min_ns: u128,
        pps_best: f64,
        sps_best: f64,
        speedup: Option<f64>,
    }
    let mut fuse_rows: Vec<FuseRow> = Vec::new();
    if let Some(degrees) = &fuse_list {
        println!("\nfusion sweep (tf_s{{S}}; steady-state min; speedup vs the s=1 control):");
        let mut rate1: Option<f64> = None;
        for &s in degrees {
            let variant = fuse_variant(s)?;
            let v = VelocityModel::Constant(v0).build(interior);
            let eta = wave::eta_profile(&domain, v0 as f64);
            let src = Source { pos: Dim3::new(n / 2, n / 2, n / 2), f0: 15.0, amplitude: 1.0 };
            let mut coord = Coordinator::new(
                None,
                domain,
                Mode::Golden,
                variant,
                "gmem",
                v,
                eta,
                src,
                vec![],
            )?;
            coord.set_cpu_threads(args.usize_or("cpu-threads", 0)?);
            if let Some(t) = &telemetry {
                coord.set_telemetry(&t.registry);
            }
            let min_ns = b
                .bench(&format!("tf @s{s}"), || {
                    coord
                        .run_observed(
                            steps,
                            RunOptions { sample_every, ..RunOptions::default() },
                            None,
                        )
                        .expect("bench step")
                        .final_max_abs
                })
                .min
                .as_nanos();
            let pps_best = rate(min_ns);
            if s == 1 {
                rate1 = Some(pps_best);
            }
            fuse_rows.push(FuseRow {
                s,
                min_ns,
                pps_best,
                sps_best: steps as f64 / (min_ns as f64 / 1e9).max(1e-12),
                speedup: rate1.map(|r1| pps_best / r1),
            });
        }
        for r in &fuse_rows {
            let sp = match r.speedup {
                Some(x) => format!("{x:>5.2}x"),
                None => "     -".to_string(),
            };
            println!(
                "  s={:<2} {:>10.2} Mpts/s  {:>8.1} steps/s  vs s=1 {sp}",
                r.s,
                r.pps_best / 1e6,
                r.sps_best
            );
        }
    }

    // --shard-sweep: re-time the deep-halo sharded engine (fuse 2, so
    // the halo exchange cadence is exercised, not just the split) at
    // each z-slab shard count. Speedup is measured against the 1-shard
    // control; counts the grid cannot host (a slab thinner than the
    // s*R halo) are skipped with a note rather than failing the sweep.
    struct ShardRow {
        shards: usize,
        sps_best: f64,
        speedup: Option<f64>,
    }
    let mut shard_rows: Vec<ShardRow> = Vec::new();
    if let Some(counts) = &shard_list {
        println!("\nshard sweep (fuse 2 deep-halo z-slabs; steady-state best; speedup vs 1 shard):");
        let mut rate1: Option<f64> = None;
        for &sc in counts {
            match hostencil::shard::measure_sharded_steps_per_sec(
                &domain,
                2,
                sc,
                steps,
                b.warmup,
                b.samples.max(1),
            ) {
                Ok(sps) => {
                    if sc == 1 {
                        rate1 = Some(sps);
                    }
                    shard_rows.push(ShardRow {
                        shards: sc,
                        sps_best: sps,
                        speedup: rate1.map(|r1| sps / r1),
                    });
                }
                Err(e) => println!("  {sc:>2} shards: skipped ({e})"),
            }
        }
        for r in &shard_rows {
            let sp = match r.speedup {
                Some(x) => format!("{x:>5.2}x"),
                None => "     -".to_string(),
            };
            println!("  {:>2} shards {:>8.1} steps/s  vs 1 shard {sp}", r.shards, r.sps_best);
        }
    }

    // --checkpoint-sweep: re-time the fuse-2 engine with cadence
    // checkpointing on vs off, so the snapshot cost (serialize both
    // padded buffers + atomic tmp/rename) is directly measurable as a
    // steps/sec overhead. Cadence 0 is the off control; a cadence below
    // the fuse degree still writes once per crossed multiple.
    struct CkptRow {
        every: usize,
        min_ns: u128,
        sps_best: f64,
        overhead_vs_off: Option<f64>,
    }
    let mut ckpt_rows: Vec<CkptRow> = Vec::new();
    if let Some(cadences) = &ckpt_list {
        let snap = std::env::temp_dir()
            .join(format!("hostencil_bench_ckpt_{}.ckpt", std::process::id()));
        println!("\ncheckpoint sweep (tf_s2; steady-state min; overhead vs cadence off):");
        let mut rate0: Option<f64> = None;
        for &every in cadences {
            let v = VelocityModel::Constant(v0).build(interior);
            let eta = wave::eta_profile(&domain, v0 as f64);
            let src = Source { pos: Dim3::new(n / 2, n / 2, n / 2), f0: 15.0, amplitude: 1.0 };
            let mut coord = Coordinator::new(
                None,
                domain,
                Mode::Golden,
                "tf_s2",
                "gmem",
                v,
                eta,
                src,
                vec![],
            )?;
            coord.set_cpu_threads(args.usize_or("cpu-threads", 0)?);
            coord.set_checkpointing(every, Some(snap.clone()));
            let min_ns = b
                .bench(&format!("ckpt @{every}"), || {
                    coord
                        .run_observed(
                            steps,
                            RunOptions { sample_every, ..RunOptions::default() },
                            None,
                        )
                        .expect("bench step")
                        .final_max_abs
                })
                .min
                .as_nanos();
            let sps_best = steps as f64 / (min_ns as f64 / 1e9).max(1e-12);
            if every == 0 {
                rate0 = Some(sps_best);
            }
            ckpt_rows.push(CkptRow {
                every,
                min_ns,
                sps_best,
                overhead_vs_off: rate0.map(|r0| 1.0 - sps_best / r0),
            });
        }
        let _ = std::fs::remove_file(&snap);
        let _ = std::fs::remove_file(snap.with_extension("ckpt.tmp"));
        for r in &ckpt_rows {
            let ov = match r.overhead_vs_off {
                Some(x) => format!("{:>6.2}%", 100.0 * x),
                None => "      -".to_string(),
            };
            let label = if r.every == 0 { "off".to_string() } else { r.every.to_string() };
            println!("  every {label:<4} {:>8.1} steps/s  overhead {ov}", r.sps_best);
        }
    }

    // --simd-sweep: re-time the tiled matrix at threads=1, once with
    // the row kernel forced scalar and once with the process dispatch,
    // so the explicit-SIMD payoff is directly measurable per shape
    // (results are bit-identical either way — the sweep ranks cost
    // only). `--check` alone times just its gate shape.
    struct SimdRow {
        name: &'static str,
        scalar_pps: f64,
        simd_pps: f64,
        speedup: f64,
    }
    let mut simd_rows: Vec<SimdRow> = Vec::new();
    let full_simd_sweep = args.has_flag("simd-sweep");
    // with a scalar dispatch (simd feature off, or no usable ISA) the
    // two legs are the same code path, so --check alone measures
    // nothing and its gate reports "skipped" below
    if full_simd_sweep || (args.has_flag("check") && kern.lanes > 1) {
        if full_simd_sweep {
            println!(
                "\nsimd sweep (threads=1, steady-state min; dispatch {}):",
                kern.tag()
            );
        }
        for (label, variant) in propagator::bench_matrix() {
            // naive keeps the scalar oracle by contract and never
            // dispatches; the check-only path times the gate shape only
            if label == "naive" || (!full_simd_sweep && label != "blocked3d_8x8x8") {
                continue;
            }
            let mut leg = |forced_scalar: bool| -> anyhow::Result<f64> {
                if forced_scalar {
                    anyhow::ensure!(stencil::simd::force(1, 1), "scalar force must be valid");
                } else {
                    stencil::simd::clear_force();
                }
                let v = VelocityModel::Constant(v0).build(interior);
                let eta = wave::eta_profile(&domain, v0 as f64);
                let src =
                    Source { pos: Dim3::new(n / 2, n / 2, n / 2), f0: 15.0, amplitude: 1.0 };
                let mut coord = Coordinator::new(
                    None,
                    domain,
                    Mode::Golden,
                    variant,
                    "gmem",
                    v,
                    eta,
                    src,
                    vec![],
                )?;
                coord.set_cpu_threads(1);
                if let Some(t) = &telemetry {
                    coord.set_telemetry(&t.registry);
                }
                let tag = if forced_scalar { "scalar" } else { "simd" };
                let min_ns = b
                    .bench(&format!("{label} [{tag}]"), || {
                        coord
                            .run_observed(
                                steps,
                                RunOptions { sample_every, ..RunOptions::default() },
                                None,
                            )
                            .expect("bench step")
                            .final_max_abs
                    })
                    .min
                    .as_nanos();
                Ok(rate(min_ns))
            };
            let scalar_pps = leg(true)?;
            let simd_pps = leg(false)?;
            simd_rows.push(SimdRow {
                name: label,
                scalar_pps,
                simd_pps,
                speedup: simd_pps / scalar_pps.max(1e-12),
            });
        }
        stencil::simd::clear_force();
        if full_simd_sweep {
            for r in &simd_rows {
                println!(
                    "  {:<22}scalar {:>10.2} Mpts/s  simd {:>10.2} Mpts/s  speedup {:>5.2}x",
                    r.name,
                    r.scalar_pps / 1e6,
                    r.simd_pps / 1e6,
                    r.speedup
                );
            }
        }
    }

    if let Some(path) = args.get("json")? {
        let cases: Vec<Json> = rows
            .iter()
            .map(|r| {
                let mut o = BTreeMap::new();
                o.insert("name".to_string(), Json::Str(r.name.clone()));
                o.insert("fuse".to_string(), Json::Num(r.fuse as f64));
                o.insert("isa".to_string(), Json::Str(r.isa.clone()));
                o.insert("lanes".to_string(), Json::Num(r.lanes as f64));
                o.insert("median_ns".to_string(), Json::Num(r.median_ns as f64));
                o.insert("mean_ns".to_string(), Json::Num(r.mean_ns as f64));
                o.insert("min_ns".to_string(), Json::Num(r.min_ns as f64));
                o.insert("points_per_sec".to_string(), Json::Num(r.pps));
                o.insert("points_per_sec_best".to_string(), Json::Num(r.pps_best));
                o.insert(
                    "steps_per_sec_best".to_string(),
                    Json::Num(steps as f64 / (r.min_ns as f64 / 1e9).max(1e-12)),
                );
                Json::Obj(o)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("format_version".to_string(), Json::Num(2.0));
        root.insert("kind".to_string(), Json::Str("hostencil-bench".to_string()));
        root.insert("grid".to_string(), Json::Str(format!("{interior}")));
        root.insert("steps_per_sample".to_string(), Json::Num(steps as f64));
        root.insert("samples".to_string(), Json::Num(b.samples as f64));
        root.insert("warmup".to_string(), Json::Num(b.warmup as f64));
        root.insert("cases".to_string(), Json::Arr(cases));
        if !sweep_rows.is_empty() {
            // JSON v2 extension: per-thread-count steady-state rates of
            // the pool executor (absent unless --thread-sweep was given)
            let sweep_json: Vec<Json> = sweep_rows
                .iter()
                .map(|r| {
                    let mut o = BTreeMap::new();
                    o.insert("name".to_string(), Json::Str(r.name.to_string()));
                    o.insert("threads".to_string(), Json::Num(r.threads as f64));
                    o.insert("min_ns".to_string(), Json::Num(r.min_ns as f64));
                    o.insert("points_per_sec_best".to_string(), Json::Num(r.pps_best));
                    o.insert("steps_per_sec_best".to_string(), Json::Num(r.sps_best));
                    if let Some(e) = r.efficiency {
                        o.insert("efficiency".to_string(), Json::Num(e));
                    }
                    Json::Obj(o)
                })
                .collect();
            root.insert("thread_sweep".to_string(), Json::Arr(sweep_json));
        }
        if !scaling_rows.is_empty() {
            // JSON v2 extension: per-shape Amdahl fit + occupancy
            // prediction (absent unless --thread-sweep was given)
            let scaling_json: Vec<Json> = scaling_rows
                .iter()
                .map(|r| {
                    let mut o = BTreeMap::new();
                    o.insert("name".to_string(), Json::Str(r.name.to_string()));
                    if let Some(f) = r.serial_fraction {
                        o.insert("serial_fraction".to_string(), Json::Num(f));
                    }
                    if let Some(p) = r.occupancy_pct {
                        o.insert("occupancy_pct".to_string(), Json::Num(p));
                    }
                    Json::Obj(o)
                })
                .collect();
            root.insert("scaling_model".to_string(), Json::Arr(scaling_json));
        }
        if !fuse_rows.is_empty() {
            // JSON v2 extension: the temporal-fusion degree sweep
            // (absent unless --fuse was given)
            let fuse_json: Vec<Json> = fuse_rows
                .iter()
                .map(|r| {
                    let mut o = BTreeMap::new();
                    o.insert("fuse".to_string(), Json::Num(r.s as f64));
                    o.insert("min_ns".to_string(), Json::Num(r.min_ns as f64));
                    o.insert("points_per_sec_best".to_string(), Json::Num(r.pps_best));
                    o.insert("steps_per_sec_best".to_string(), Json::Num(r.sps_best));
                    if let Some(x) = r.speedup {
                        o.insert("speedup_vs_unfused".to_string(), Json::Num(x));
                    }
                    Json::Obj(o)
                })
                .collect();
            root.insert("fuse_sweep".to_string(), Json::Arr(fuse_json));
        }
        if !shard_rows.is_empty() {
            // JSON v2 extension: the z-slab shard-count sweep (absent
            // unless --shard-sweep was given; infeasible counts are
            // skipped, so rows cover the measured counts only)
            let shard_json: Vec<Json> = shard_rows
                .iter()
                .map(|r| {
                    let mut o = BTreeMap::new();
                    o.insert("shards".to_string(), Json::Num(r.shards as f64));
                    o.insert("steps_per_sec_best".to_string(), Json::Num(r.sps_best));
                    if let Some(x) = r.speedup {
                        o.insert("speedup_vs_single".to_string(), Json::Num(x));
                    }
                    Json::Obj(o)
                })
                .collect();
            root.insert("shard_sweep".to_string(), Json::Arr(shard_json));
        }
        if !ckpt_rows.is_empty() {
            // JSON v2 extension: the checkpoint-cadence overhead sweep
            // (absent unless --checkpoint-sweep was given; cadence 0 is
            // the checkpointing-off control)
            let ckpt_json: Vec<Json> = ckpt_rows
                .iter()
                .map(|r| {
                    let mut o = BTreeMap::new();
                    o.insert("every".to_string(), Json::Num(r.every as f64));
                    o.insert("min_ns".to_string(), Json::Num(r.min_ns as f64));
                    o.insert("steps_per_sec_best".to_string(), Json::Num(r.sps_best));
                    if let Some(x) = r.overhead_vs_off {
                        o.insert("overhead_vs_off".to_string(), Json::Num(x));
                    }
                    Json::Obj(o)
                })
                .collect();
            root.insert("checkpoint_sweep".to_string(), Json::Arr(ckpt_json));
        }
        if full_simd_sweep && !simd_rows.is_empty() {
            // JSON v2 extension: the scalar-vs-SIMD row-kernel sweep
            // (absent unless --simd-sweep was given)
            let simd_json: Vec<Json> = simd_rows
                .iter()
                .map(|r| {
                    let mut o = BTreeMap::new();
                    o.insert("name".to_string(), Json::Str(r.name.to_string()));
                    o.insert("isa".to_string(), Json::Str(kern.isa.name().to_string()));
                    o.insert("lanes".to_string(), Json::Num(kern.lanes as f64));
                    o.insert("scalar_points_per_sec_best".to_string(), Json::Num(r.scalar_pps));
                    o.insert("simd_points_per_sec_best".to_string(), Json::Num(r.simd_pps));
                    o.insert("speedup_vs_scalar".to_string(), Json::Num(r.speedup));
                    Json::Obj(o)
                })
                .collect();
            root.insert("simd_sweep".to_string(), Json::Arr(simd_json));
        }
        if let Some(t) = &telemetry {
            // flat registry snapshot next to the timing cases, so one
            // artifact carries both the ranks and the counters that
            // produced them
            root.insert("telemetry".to_string(), t.registry.snapshot_json());
        }
        std::fs::write(path, Json::Obj(root).emit())?;
        println!("wrote {path}");
    }

    if args.has_flag("check") {
        // Regression canary: the tiled shapes must not *lose* to the
        // per-region reference — the paper's whole point is that code
        // shape pays, and a per-step allocation or fan-out regression
        // shows up here first. Compared on steady-state (min) rates
        // with the --margin noise allowance (default 15%) so shared-
        // runner noise on small smoke grids cannot flake the gate.
        let pct = 100.0 * margin;
        let best = |name: &str| -> anyhow::Result<f64> {
            rows.iter()
                .find(|r| r.name == name)
                .map(|r| r.pps_best)
                .ok_or_else(|| anyhow::anyhow!("bench --check: no case named {name:?}"))
        };
        let naive = best("naive")?;
        for name in ["blocked3d_16x16x4", "streaming25d_16x16"] {
            let got = best(name)?;
            anyhow::ensure!(
                got >= (1.0 - margin) * naive,
                "bench --check: {name} ({:.2} Mpts/s steady-state) fell below naive \
                 ({:.2} Mpts/s) beyond the {pct:.0}% noise margin; the tiled shapes must \
                 not lose to the reference",
                got / 1e6,
                naive / 1e6
            );
        }
        println!("bench --check OK: blocked3d and streaming25d hold >= naive (steady-state)");

        // Fusion canary: advancing s=2 steps per sweep must not lose
        // to the plain 3D gmem analog — if it does, the fused family's
        // staging/skirt overhead has outgrown what batching buys and
        // the whole tentpole regressed. Same 15% noise margin. The
        // comparison row is deliberately blocked3d_8x8x8 (the paper's
        // gmem baseline): on cache-resident smoke grids fusion's DRAM
        // amortization buys little and the ~1.5x redundant-skirt
        // compute is real, but the gmem analog's 8-point x-rows pay
        // ~26 slice setups per 8 points while tf_s2 streams full-width
        // rows — the margin the gate rides on.
        let tf = best("tf_s2")?;
        let blocked_gmem = best("blocked3d_8x8x8")?;
        anyhow::ensure!(
            tf >= (1.0 - margin) * blocked_gmem,
            "bench --check: tf_s2 ({:.2} Mpts/s steady-state) fell below blocked_gmem \
             ({:.2} Mpts/s) beyond the {pct:.0}% noise margin; temporal fusion must not \
             lose to single-step blocking",
            tf / 1e6,
            blocked_gmem / 1e6
        );
        println!("bench --check OK: tf_s2 holds >= blocked_gmem (steady-state)");

        // SIMD canary: the dispatched row kernel must be equal-or-
        // better than the forced-scalar row at threads=1 — dispatch is
        // only allowed to pay, never to regress. The target factor is
        // 1.0x; the --margin allowance absorbs timing noise only.
        if kern.lanes <= 1 {
            println!("bench --check: simd gate skipped (scalar dispatch active)");
        } else {
            let gate = simd_rows
                .iter()
                .find(|r| r.name == "blocked3d_8x8x8")
                .ok_or_else(|| anyhow::anyhow!("bench --check: no simd measurement for the gate shape"))?;
            anyhow::ensure!(
                gate.simd_pps * (1.0 + margin) >= gate.scalar_pps,
                "bench --check: {} rows ({:.2} Mpts/s steady-state) lost to forced-scalar \
                 rows ({:.2} Mpts/s) at threads=1 beyond the {pct:.0}% noise margin; the \
                 dispatched kernel must be >= 1.0x scalar",
                kern.tag(),
                gate.simd_pps / 1e6,
                gate.scalar_pps / 1e6
            );
            println!(
                "bench --check OK: {} rows hold >= scalar rows at threads=1 ({:.2}x)",
                kern.tag(),
                gate.speedup
            );
        }

        // Thread-scaling canary: with the persistent pool (zero spawn,
        // zero alloc per step) extra workers must never make a step
        // materially slower — if they do, per-step executor overhead
        // has crept back in. Gates the two smallest swept counts (the
        // list is sorted; for the CI sweep `1,2` that is 2-vs-1
        // thread) with the same --margin noise allowance as the shape
        // gate.
        if let Some(counts) = &sweep {
            anyhow::ensure!(
                counts.len() >= 2,
                "bench --check: --thread-sweep needs at least two worker counts to gate \
                 scaling (got {counts:?})"
            );
            let (lo, hi) = (counts[0], counts[1]);
            let sweep_min = |name: &str, t: usize| -> anyhow::Result<u128> {
                sweep_rows
                    .iter()
                    .find(|r| r.name == name && r.threads == t)
                    .map(|r| r.min_ns)
                    .ok_or_else(|| anyhow::anyhow!("bench --check: no sweep entry {name} @{t}thr"))
            };
            for (label, _) in propagator::bench_matrix() {
                let (t_lo, t_hi) = (sweep_min(label, lo)?, sweep_min(label, hi)?);
                anyhow::ensure!(
                    t_hi as f64 <= (1.0 + margin) * t_lo as f64,
                    "bench --check: {label} {hi}-thread steady-state ({:.2} ms) lost to \
                     {lo}-thread ({:.2} ms) beyond the {pct:.0}% noise margin; the pool \
                     fan-out must not cost more than it buys",
                    t_hi as f64 / 1e6,
                    t_lo as f64 / 1e6
                );
            }
            println!(
                "bench --check OK: {hi}-thread steady-state holds >= {lo}-thread across \
                 the matrix"
            );
        }

        // Shard-scaling canary: splitting the grid into two z-slabs —
        // seam halo exchange, per-shard pools and all — must not make
        // a step materially slower than the 1-shard control. If it
        // does, the exchange (or the budget split) costs more than the
        // fan-out buys and the sharded path regressed. Same --margin
        // noise allowance; needs both counts 1 and 2 in the sweep (an
        // infeasible/skipped count skips the gate with a note).
        if shard_list.is_some() {
            let rate_at =
                |n: usize| shard_rows.iter().find(|r| r.shards == n).map(|r| r.sps_best);
            match (rate_at(1), rate_at(2)) {
                (Some(r1), Some(r2)) => {
                    anyhow::ensure!(
                        r2 >= (1.0 - margin) * r1,
                        "bench --check: 2-shard steady-state ({r2:.1} steps/s) fell below \
                         the 1-shard control ({r1:.1} steps/s) beyond the {pct:.0}% noise \
                         margin; the halo exchange must not cost more than the shard \
                         fan-out buys",
                    );
                    println!(
                        "bench --check OK: 2-shard steady-state holds >= 1-shard ({:.2}x)",
                        r2 / r1
                    );
                }
                _ => println!(
                    "bench --check: shard gate skipped (needs measured 1- and 2-shard rows)"
                ),
            }
        }
    }
    if let Some(t) = &telemetry {
        t.finish()?;
    }
    Ok(())
}

/// `hostencil telemetry --demo`: run a short instrumented simulation
/// on a small grid and print a live snapshot — the full Prometheus
/// exposition plus the flight-recorder event stream. The quickest way
/// to see what `--telemetry` / `--events` will emit without wiring up
/// files, and a smoke check that every layer's instrumentation fires.
fn cmd_telemetry(args: &Args) -> anyhow::Result<()> {
    use hostencil::grid::{Dim3, Domain};
    use hostencil::stencil;
    use hostencil::wave::{Source, VelocityModel};

    // --demo is the only mode today; accept its absence so plain
    // `hostencil telemetry` works too
    let _ = args.has_flag("demo");
    let n = args.usize_or("size", 20)?;
    anyhow::ensure!(n >= 12, "--size must be >= 12 (needs room for PML width 4)");
    let steps = args.usize_or("steps", 12)?;
    // the fused family exercises the most instrumentation (skirt
    // counters, batch cadence); any propagator/tf descriptor works
    let variant = args.get("propagator")?.unwrap_or("tf_s2");

    let h = 10.0;
    let v0 = 2500.0f32;
    let dt = stencil::cfl_dt(h, v0 as f64);
    let domain = Domain::new(Dim3::new(n, n, n), 4, h, dt)?;
    let interior = domain.interior;
    let v = VelocityModel::Constant(v0).build(interior);
    let eta = wave::eta_profile(&domain, v0 as f64);
    let src = Source { pos: Dim3::new(n / 2, n / 2, n / 2), f0: 15.0, amplitude: 1.0 };
    let mut coord =
        Coordinator::new(None, domain, Mode::Golden, variant, "gmem", v, eta, src, vec![])?;
    coord.set_cpu_threads(args.usize_or("cpu-threads", 0)?);

    let reg = Registry::new();
    reg.events().to_memory();
    coord.set_telemetry(&reg);
    let summary = coord.run_observed(
        steps,
        RunOptions {
            sample_every: args.usize_or("sample-every", 0)?,
            ..RunOptions::default()
        },
        None,
    )?;
    println!(
        "telemetry demo: {} steps of {variant} on {interior} in {:.3?}\n",
        summary.steps, summary.wall
    );
    print!("{}", reg.render());
    let lines = reg.events().lines();
    println!("\nflight recorder ({} events):", lines.len());
    for l in &lines {
        println!("  {l}");
    }
    Ok(())
}

/// The small sharded configuration every chaos cell runs: Golden mode,
/// fused degree 2, two z-slab shards on a two-worker outer pool — the
/// smallest shape that exercises the halo transport, the shard pool,
/// and fused batch boundaries at once.
fn chaos_coordinator() -> anyhow::Result<Coordinator<'static>> {
    use hostencil::grid::{Dim3, Domain};
    use hostencil::stencil;
    use hostencil::wave::{Source, VelocityModel};

    let interior = Dim3::new(24, 16, 16);
    let h = 10.0;
    let v0 = 2500.0f32;
    let domain = Domain::new(interior, 4, h, stencil::cfl_dt(h, v0 as f64))?;
    let v = VelocityModel::Constant(v0).build(interior);
    let eta = wave::eta_profile(&domain, v0 as f64);
    let src = Source { pos: Dim3::new(12, 8, 8), f0: 15.0, amplitude: 1.0 };
    let mut c = Coordinator::new(
        None,
        domain,
        Mode::Golden,
        "tf_s2",
        "gmem",
        v,
        eta,
        src,
        vec![Dim3::new(6, 8, 8)],
    )?;
    c.set_cpu_threads(2);
    c.set_shards(2)?;
    Ok(c)
}

/// `hostencil chaos`: drive the deterministic fault x recovery matrix
/// and assert the chaos invariant — **every injected fault class
/// either retries/heals to a bit-identical completion or ends in a
/// soft abort with a restorable checkpoint; never a panic, never
/// silent corruption**. Each cell runs the same small sharded
/// configuration with one armed fault spec and is compared against the
/// fault-free baseline digest. `--check` exits non-zero on any
/// violated cell (the CI chaos gate).
fn cmd_chaos(args: &Args) -> anyhow::Result<()> {
    use std::panic::{catch_unwind, AssertUnwindSafe};

    let check = args.has_flag("check");
    let steps = args.usize_or("steps", 24)?;
    anyhow::ensure!(
        steps >= 12 && steps % 6 == 0,
        "--steps must be a multiple of 6 and >= 12 (the matrix checkpoints on a 6-step cadence)"
    );
    let seed = fault_seed_from_args(args)?;
    let dir = std::env::temp_dir().join(format!("hostencil_chaos_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;

    // the fault-free oracle every cell must reconverge on, bitwise
    let mut oracle = chaos_coordinator()?;
    let s = oracle.run(steps)?;
    anyhow::ensure!(
        s.steps == steps && oracle.soft_abort().is_none(),
        "the fault-free baseline must complete"
    );
    let want = oracle.state_digest();
    println!("chaos: {steps} steps, 2 shards, baseline digest {want:#018x}, seed {seed:#x}");

    // the mid-run step every transient fault arms at (a fused batch
    // boundary, so halo/pool/ckpt seams all cross it)
    let mid = (steps / 3) as u64;

    // a transient fault the seams must absorb: the run completes with
    // the fault injected exactly once and a bit-identical digest
    let heal = |site: FaultSite, kind: FaultKind| -> anyhow::Result<String> {
        let plan = FaultPlan::single(site, kind, mid, seed);
        let mut c = chaos_coordinator()?;
        c.set_faults(Arc::clone(&plan));
        let s = c.run(steps)?;
        if let Some(a) = c.soft_abort() {
            anyhow::bail!("unexpected soft abort at step {}: {}", a.step, a.detail);
        }
        anyhow::ensure!(s.steps == steps, "run stopped at step {} of {steps}", s.steps);
        anyhow::ensure!(plan.injected(site) == 1, "the armed fault never fired");
        anyhow::ensure!(
            c.state_digest() == want,
            "digest {:#018x} diverged from the baseline",
            c.state_digest()
        );
        Ok("healed in place; completion bit-identical".to_string())
    };

    // an unrecoverable stall: the run must soft-abort with a
    // checkpoint that restores and resumes onto the oracle
    let stall = || -> anyhow::Result<String> {
        let path = dir.join("stall.ckpt");
        let plan = FaultPlan::single(FaultSite::Halo, FaultKind::Delay, mid, seed);
        let mut c = chaos_coordinator()?;
        c.set_checkpointing(0, Some(path.clone()));
        // a short deadline so the injected stall escalates immediately
        c.set_halo_deadline(Duration::from_millis(10));
        c.set_faults(Arc::clone(&plan));
        let s = c.run(steps)?;
        let (kind, step) = match c.soft_abort() {
            Some(a) => (a.kind.name().to_string(), a.step),
            None => anyhow::bail!("the stalled exchange must soft-abort, ran {} steps", s.steps),
        };
        anyhow::ensure!(kind == "halo_stall", "unexpected breaker kind {kind}");
        anyhow::ensure!(s.steps < steps, "soft abort cannot complete the budget");
        let mut r = chaos_coordinator()?;
        let (_, skipped) = r.restore_from_ring(&path, 1)?;
        anyhow::ensure!(skipped.is_empty(), "the trip checkpoint must be valid: {skipped:?}");
        anyhow::ensure!(r.steps_done() == step, "checkpoint cursor != abort step");
        r.run(steps - step)?;
        anyhow::ensure!(
            r.state_digest() == want,
            "resume digest {:#018x} diverged from the baseline",
            r.state_digest()
        );
        Ok(format!("soft-aborted at step {step}; restore + resume reconverged bitwise"))
    };

    // a failed cadence write (torn tmp or ENOSPC): counted, the run
    // survives, and the ring's newest slot is still a valid snapshot
    let ckpt_write = |kind: FaultKind| -> anyhow::Result<String> {
        let path = dir.join(format!("write_{}.ckpt", kind.name()));
        let plan = FaultPlan::single(FaultSite::Checkpoint, kind, mid, seed);
        let mut c = chaos_coordinator()?;
        c.set_checkpointing(6, Some(path.clone()));
        c.set_checkpoint_keep(2);
        c.set_faults(Arc::clone(&plan));
        let s = c.run(steps)?;
        anyhow::ensure!(s.steps == steps, "a failed snapshot write must not kill the run");
        anyhow::ensure!(plan.injected(FaultSite::Checkpoint) == 1, "the write fault never fired");
        anyhow::ensure!(c.state_digest() == want, "digest diverged from the baseline");
        let newest = Checkpoint::load(&path)
            .map_err(|e| anyhow::anyhow!("the ring's newest slot must stay valid: {e}"))?;
        anyhow::ensure!(
            newest.steps_done as usize == steps,
            "newest slot holds step {}, want {steps}",
            newest.steps_done
        );
        Ok("write failed and was counted; run completed, ring slot valid".to_string())
    };

    // silent post-publish corruption: invisible on the write path by
    // design, caught by the checksum at restore, where the ring falls
    // back to the previous cadence snapshot and reconverges
    let ckpt_corrupt = || -> anyhow::Result<String> {
        let path = dir.join("corrupt.ckpt");
        let plan = FaultPlan::single(FaultSite::Checkpoint, FaultKind::Corrupt, steps as u64, seed);
        let mut c = chaos_coordinator()?;
        c.set_checkpointing(6, Some(path.clone()));
        c.set_checkpoint_keep(2);
        c.set_faults(Arc::clone(&plan));
        let s = c.run(steps)?;
        anyhow::ensure!(s.steps == steps && c.state_digest() == want, "corrupting run diverged");
        anyhow::ensure!(plan.injected(FaultSite::Checkpoint) == 1, "the corruption never fired");
        let mut r = chaos_coordinator()?;
        let (_, skipped) = r.restore_from_ring(&path, 2)?;
        anyhow::ensure!(
            skipped.len() == 1 && skipped[0].contains("checksum"),
            "the corrupt newest slot must be skipped by checksum, got {skipped:?}"
        );
        anyhow::ensure!(r.steps_done() == steps - 6, "fallback must land on the prior cadence");
        r.run(6)?;
        anyhow::ensure!(r.state_digest() == want, "fallback resume diverged from the baseline");
        Ok("corruption caught by checksum at restore; ring fell back and reconverged".to_string())
    };

    // corruption injected at restore time on a clean ring: same
    // detect-and-fall-back contract, armed on the reader instead
    let restore_corrupt = || -> anyhow::Result<String> {
        let path = dir.join("restore.ckpt");
        let mut w = chaos_coordinator()?;
        w.set_checkpointing(6, Some(path.clone()));
        w.set_checkpoint_keep(2);
        let s = w.run(steps)?;
        anyhow::ensure!(s.steps == steps, "the ring-writer leg must complete");
        let mut r = chaos_coordinator()?;
        r.set_faults(FaultPlan::single(FaultSite::Restore, FaultKind::Corrupt, 0, seed));
        let (_, skipped) = r.restore_from_ring(&path, 2)?;
        anyhow::ensure!(
            skipped.len() == 1 && skipped[0].contains("checksum"),
            "the corrupted slot must be skipped by checksum, got {skipped:?}"
        );
        anyhow::ensure!(r.steps_done() == steps - 6, "fallback must land on the prior cadence");
        r.run(6)?;
        anyhow::ensure!(r.state_digest() == want, "fallback resume diverged from the baseline");
        Ok("restore-time corruption detected; ring fell back and reconverged".to_string())
    };

    let mut failures = 0usize;
    let mut verdict = |name: &str, r: std::thread::Result<anyhow::Result<String>>| match r {
        Ok(Ok(note)) => println!("  ok   {name:<16} {note}"),
        Ok(Err(e)) => {
            failures += 1;
            println!("  FAIL {name:<16} {e:#}");
        }
        Err(p) => {
            failures += 1;
            let msg = p
                .downcast_ref::<&str>()
                .copied()
                .or_else(|| p.downcast_ref::<String>().map(String::as_str))
                .unwrap_or("non-string panic payload");
            println!("  FAIL {name:<16} panicked: {msg} (the invariant forbids panics)");
        }
    };
    verdict("halo:drop", catch_unwind(AssertUnwindSafe(|| heal(FaultSite::Halo, FaultKind::Drop))));
    verdict(
        "halo:corrupt",
        catch_unwind(AssertUnwindSafe(|| heal(FaultSite::Halo, FaultKind::Corrupt))),
    );
    verdict("halo:delay", catch_unwind(AssertUnwindSafe(stall)));
    verdict(
        "pool:panic",
        catch_unwind(AssertUnwindSafe(|| heal(FaultSite::Pool, FaultKind::Panic))),
    );
    verdict(
        "ckpt:short",
        catch_unwind(AssertUnwindSafe(|| ckpt_write(FaultKind::ShortWrite))),
    );
    verdict("ckpt:enospc", catch_unwind(AssertUnwindSafe(|| ckpt_write(FaultKind::Enospc))));
    verdict("ckpt:corrupt", catch_unwind(AssertUnwindSafe(ckpt_corrupt)));
    verdict("restore:corrupt", catch_unwind(AssertUnwindSafe(restore_corrupt)));
    drop(verdict);
    let _ = std::fs::remove_dir_all(&dir);

    if failures > 0 {
        anyhow::ensure!(!check, "{failures} chaos cell(s) violated the recovery invariant");
        println!("chaos: {failures} cell(s) FAILED (run with --check to gate on this)");
    } else {
        println!(
            "chaos: all cells hold — every fault healed bit-identically or soft-aborted \
             with a restorable checkpoint"
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse_from(toks.iter().map(|s| s.to_string()).collect()).unwrap()
    }

    #[test]
    fn parses_key_value_and_flags() {
        let a = parse(&["run", "--steps", "50", "--quick", "--mode", "golden"]);
        assert_eq!(a.cmd, "run");
        assert_eq!(a.get("steps").unwrap(), Some("50"));
        assert_eq!(a.get("mode").unwrap(), Some("golden"));
        assert!(a.has_flag("quick"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 50);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn negative_numeric_values_are_values_not_flags() {
        // regression: `--key -1.5` used to be at the mercy of the flag
        // heuristic; negative numbers must parse as values
        let a = parse(&["sweep", "--offset", "-3", "--dt", "-1.5e-3", "--frac", "-.25"]);
        assert_eq!(a.get("offset").unwrap(), Some("-3"));
        assert_eq!(a.get("dt").unwrap(), Some("-1.5e-3"));
        assert_eq!(a.get("frac").unwrap(), Some("-.25"));
        assert!(!a.has_flag("offset"));
    }

    #[test]
    fn key_equals_value_form() {
        let a = parse(&["run", "--steps=80", "--variant=gmem"]);
        assert_eq!(a.get("steps").unwrap(), Some("80"));
        assert_eq!(a.get("variant").unwrap(), Some("gmem"));
    }

    #[test]
    fn flag_followed_by_option_stays_a_flag() {
        let a = parse(&["campaign", "--quick", "--machine", "v100"]);
        assert!(a.has_flag("quick"));
        assert_eq!(a.get("machine").unwrap(), Some("v100"));
    }

    #[test]
    fn stray_positionals_and_bad_tokens_are_rejected() {
        let bad = |toks: &[&str]| {
            Args::parse_from(toks.iter().map(|s| s.to_string()).collect()).is_err()
        };
        assert!(bad(&["run", "oops"]));
        assert!(bad(&["run", "--steps", "50", "stray"]));
        assert!(bad(&["run", "-x"])); // single-dash non-numeric
        assert!(bad(&["run", "--"]));
    }

    #[test]
    fn value_token_classifier() {
        assert!(is_value_token("50"));
        assert!(is_value_token("golden"));
        assert!(is_value_token("-5"));
        assert!(is_value_token("-1.5e-3"));
        assert!(is_value_token("-.25"));
        assert!(!is_value_token("--steps"));
        assert!(!is_value_token("-x"));
        assert!(!is_value_token("-"));
    }

    #[test]
    fn value_taking_option_without_value_errors() {
        // regression: `--json` with a forgotten path used to become the
        // literal value "true" (and write a file named "true")
        let a = parse(&["campaign", "--json"]);
        assert!(a.has_flag("json"));
        assert!(a.get("json").is_err());
        let b = parse(&["run", "--steps"]);
        assert!(b.usize_or("steps", 5).is_err());
    }

    #[test]
    fn usize_or_reports_bad_values() {
        let a = parse(&["run", "--steps", "-5"]);
        let err = a.usize_or("steps", 0).unwrap_err().to_string();
        assert!(err.contains("--steps"), "{err}");
    }

    #[test]
    fn fuse_flag_parses_in_both_forms_and_rejects_zero() {
        // mirrors the PR 1 negative-number hardening: --fuse must take
        // both `--fuse 4` and `--fuse=4`, and reject nonsense degrees
        let a = parse(&["run", "--fuse", "4"]);
        assert_eq!(a.get("fuse").unwrap(), Some("4"));
        assert_eq!(fuse_variant(a.usize_or("fuse", 1).unwrap()).unwrap(), "tf_s4");
        let b = parse(&["run", "--fuse=4", "--steps", "10"]);
        assert_eq!(b.get("fuse").unwrap(), Some("4"));
        assert_eq!(b.usize_or("steps", 0).unwrap(), 10);
        let c = parse(&["run", "--fuse=2"]);
        assert_eq!(fuse_variant(c.usize_or("fuse", 1).unwrap()).unwrap(), "tf_s2");
        // degree 0 parses as a usize but must be rejected as a degree
        let z = parse(&["run", "--fuse", "0"]);
        assert_eq!(z.usize_or("fuse", 1).unwrap(), 0);
        let err = fuse_variant(0).unwrap_err().to_string();
        assert!(err.contains("--fuse 0"), "{err}");
        // a bare --fuse on run (value-taking) errors instead of
        // silently becoming "true"
        let bare = parse(&["run", "--fuse"]);
        assert!(bare.get("fuse").is_err());
        // negative degrees fail the usize parse with the flag named
        let neg = parse(&["run", "--fuse", "-2"]);
        assert!(neg.usize_or("fuse", 1).is_err());
    }

    #[test]
    fn fuse_variant_maps_supported_degrees_only() {
        assert_eq!(fuse_variant(1).unwrap(), "tf_s1");
        assert_eq!(fuse_variant(2).unwrap(), "tf_s2");
        assert_eq!(fuse_variant(4).unwrap(), "tf_s4");
        for bad in [0usize, 3, 5, 8] {
            assert!(fuse_variant(bad).is_err(), "degree {bad} must be rejected");
        }
    }

    #[test]
    fn fuse_list_parses_sorts_dedups_and_validates() {
        assert_eq!(parse_fuse_list("1,2,4").unwrap(), vec![1, 2, 4]);
        assert_eq!(parse_fuse_list("4, 2,1,2").unwrap(), vec![1, 2, 4]);
        assert_eq!(parse_fuse_list("2").unwrap(), vec![2]);
        assert!(parse_fuse_list("").is_err());
        assert!(parse_fuse_list("0,2").is_err(), "zero steps per sweep is meaningless");
        assert!(parse_fuse_list("1,3").is_err(), "only supported degrees");
        assert!(parse_fuse_list("two").is_err());
    }

    #[test]
    fn telemetry_flags_parse_on_every_command() {
        for cmd in ["run", "scenario", "campaign", "bench"] {
            let a = parse(&[
                cmd,
                "--telemetry",
                "out.prom",
                "--events",
                "ev.jsonl",
                "--sample-every",
                "2",
            ]);
            assert_eq!(a.get("telemetry").unwrap(), Some("out.prom"));
            assert_eq!(a.get("events").unwrap(), Some("ev.jsonl"));
            assert_eq!(a.usize_or("sample-every", 0).unwrap(), 2);
        }
        // a bare --telemetry (forgotten path) errors instead of
        // silently writing a file named "true"
        let bare = parse(&["run", "--telemetry"]);
        assert!(bare.get("telemetry").is_err());
        let bare = parse(&["run", "--events"]);
        assert!(bare.get("events").is_err());
    }

    #[test]
    fn telemetry_from_args_wires_registry_and_paths() {
        // neither flag: no registry, runs stay un-instrumented
        assert!(telemetry_from_args(&parse(&["run", "--steps", "5"])).unwrap().is_none());

        // --telemetry alone: exposition path set, recorder stays off
        let t = telemetry_from_args(&parse(&["run", "--telemetry", "out.prom"]))
            .unwrap()
            .expect("registry");
        assert_eq!(t.prom_path.as_deref(), Some("out.prom"));
        assert!(!t.registry.events().enabled());

        // --events alone: recorder routed to the file immediately
        let path = std::env::temp_dir()
            .join(format!("hostencil_cli_events_{}.jsonl", std::process::id()));
        let toks = vec!["run".to_string(), format!("--events={}", path.display())];
        let t2 = telemetry_from_args(&Args::parse_from(toks).unwrap())
            .unwrap()
            .expect("registry");
        assert!(t2.registry.events().enabled());
        assert!(t2.prom_path.is_none());
        t2.registry.events().emit("run_start", &[]);
        t2.finish().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert!(text.contains("\"event\":\"run_start\""), "{text}");
    }

    #[test]
    fn thread_sweep_list_parses_sorts_and_dedups() {
        assert_eq!(parse_thread_list("1,2,4").unwrap(), vec![1, 2, 4]);
        assert_eq!(parse_thread_list("4, 2,1,2").unwrap(), vec![1, 2, 4]);
        assert_eq!(parse_thread_list("8").unwrap(), vec![8]);
        assert!(parse_thread_list("").is_err());
        assert!(parse_thread_list("0,2").is_err(), "zero workers is meaningless");
        assert!(parse_thread_list("two").is_err());
    }

    #[test]
    fn margin_flag_parses_values_and_keeps_flag_semantics() {
        // --margin takes both forms like every other value option
        let a = parse(&["bench", "--margin", "0.25", "--check"]);
        assert_eq!(a.get("margin").unwrap(), Some("0.25"));
        assert!(a.has_flag("check"));
        let b = parse(&["bench", "--margin=0.05"]);
        assert_eq!(b.get("margin").unwrap(), Some("0.05"));
        // a bare --margin (forgotten value) errors instead of silently
        // becoming "true"
        let bare = parse(&["bench", "--margin"]);
        assert!(bare.get("margin").is_err());
        // --simd-sweep is a plain flag
        let s = parse(&["bench", "--simd-sweep", "--check"]);
        assert!(s.has_flag("simd-sweep"));
    }

    #[test]
    fn lane_combo_list_parses_wxu_pairs() {
        assert_eq!(parse_lane_combos("1x1,8x2").unwrap(), vec![(1, 1), (8, 2)]);
        assert_eq!(parse_lane_combos(" 16x4 ").unwrap(), vec![(16, 4)]);
        assert!(parse_lane_combos("").is_err());
        assert!(parse_lane_combos("8").is_err(), "missing unroll");
        assert!(parse_lane_combos("axb").is_err());
        // out-of-grid combos parse here; tune_measured rejects them
        assert_eq!(parse_lane_combos("5x2").unwrap(), vec![(5, 2)]);
    }

    #[test]
    fn lane_labels_render_scalar_and_wide_combos() {
        assert_eq!(lane_label(1, 1), "scalar");
        assert_eq!(lane_label(8, 2), "w8u2");
        assert_eq!(lane_label(16, 4), "w16u4");
    }

    #[test]
    fn shard_flags_parse_on_run_scenario_campaign_and_bench() {
        for cmd in ["run", "scenario", "campaign", "bench"] {
            let a = parse(&[cmd, "--shards", "2", "--steps", "10"]);
            assert_eq!(a.usize_or("shards", 1).unwrap(), 2);
            let b = parse(&[cmd, "--shards=3"]);
            assert_eq!(b.usize_or("shards", 1).unwrap(), 3);
        }
        // a bare --shards (forgotten count) errors instead of silently
        // defaulting
        let bare = parse(&["run", "--shards"]);
        assert!(bare.usize_or("shards", 1).is_err());
    }

    #[test]
    fn shard_sweep_list_parses_sorts_and_dedups() {
        assert_eq!(parse_shard_list("1,2,4").unwrap(), vec![1, 2, 4]);
        assert_eq!(parse_shard_list("4, 2,1,2").unwrap(), vec![1, 2, 4]);
        assert_eq!(parse_shard_list("3").unwrap(), vec![3]);
        assert!(parse_shard_list("").is_err());
        assert!(parse_shard_list("0,2").is_err(), "zero shards is meaningless");
        assert!(parse_shard_list("two").is_err());
    }

    #[test]
    fn checkpoint_sweep_list_allows_the_off_control() {
        assert_eq!(parse_ckpt_list("0,8,1").unwrap(), vec![0, 1, 8]);
        assert_eq!(parse_ckpt_list("4").unwrap(), vec![4]);
        assert!(parse_ckpt_list("").is_err());
        assert!(parse_ckpt_list("x").is_err());
    }

    #[test]
    fn checkpoint_flags_resolve_and_reject_zero_cadence() {
        let a = parse(&["run", "--checkpoint-every", "25", "--checkpoint-path", "snap.ckpt"]);
        assert_eq!(
            checkpointing_from_args(&a).unwrap(),
            (25, Some(PathBuf::from("snap.ckpt")))
        );
        // a cadence without a path gets the default snapshot name
        let b = parse(&["run", "--checkpoint-every=10"]);
        assert_eq!(
            checkpointing_from_args(&b).unwrap(),
            (10, Some(PathBuf::from("hostencil.ckpt")))
        );
        // --checkpoint-every 0 is rejected by name, not treated as off
        let z = parse(&["run", "--checkpoint-every", "0"]);
        let err = checkpointing_from_args(&z).unwrap_err().to_string();
        assert!(err.contains("--checkpoint-every"), "{err}");
        assert!(err.contains(">= 1"), "{err}");
        // no flags at all: checkpointing stays fully off
        let none = parse(&["run", "--steps", "5"]);
        assert_eq!(checkpointing_from_args(&none).unwrap(), (0, None));
        // an explicit path without a cadence is kept for breaker trips
        let trip = parse(&["run", "--checkpoint-path", "dump.ckpt"]);
        assert_eq!(
            checkpointing_from_args(&trip).unwrap(),
            (0, Some(PathBuf::from("dump.ckpt")))
        );
    }

    #[test]
    fn restore_with_a_missing_file_names_the_path() {
        let err = Checkpoint::load(Path::new("/nonexistent/run.ckpt"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("cannot read checkpoint"), "{err}");
        assert!(err.contains("/nonexistent/run.ckpt"), "{err}");
    }

    #[test]
    fn breaker_flags_imply_arming_and_validate() {
        // no breaker flags: breakers stay disarmed
        assert!(breakers_from_args(&parse(&["run", "--steps", "5"])).unwrap().is_none());
        // the bare flag arms with defaults
        let armed = breakers_from_args(&parse(&["run", "--breakers"])).unwrap().expect("armed");
        assert_eq!(armed.energy_window, BreakerConfig::default().energy_window);
        assert_eq!(armed.nan_budget, BreakerConfig::default().nan_budget);
        // any tuning option arms the breakers on its own
        let tuned = breakers_from_args(&parse(&["run", "--breaker-ratio", "100"]))
            .unwrap()
            .expect("armed");
        assert_eq!(tuned.energy_ratio, 100.0);
        let win = breakers_from_args(&parse(&["run", "--breaker-window=4", "--nan-budget", "2"]))
            .unwrap()
            .expect("armed");
        assert_eq!(win.energy_window, 4);
        assert_eq!(win.nan_budget, 2);
        let arm = breakers_from_args(&parse(&["run", "--breaker-arm", "30"]))
            .unwrap()
            .expect("armed");
        assert_eq!(arm.arm_step, Some(30));
        // degenerate tunings are rejected with the flag named
        let e = breakers_from_args(&parse(&["run", "--breaker-window", "1"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("--breaker-window"), "{e}");
        let e = breakers_from_args(&parse(&["run", "--breaker-ratio", "0.5"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("--breaker-ratio"), "{e}");
    }

    #[test]
    fn replay_requires_a_trace_and_reports_missing_files() {
        let a = parse(&["replay"]);
        let err = cmd_replay(&a).unwrap_err().to_string();
        assert!(err.contains("--trace"), "{err}");
        // a missing trace file is a named error, not a panic
        let b = parse(&["replay", "--trace", "/nonexistent/rec.jsonl"]);
        let err = cmd_replay(&b).unwrap_err().to_string();
        assert!(err.contains("cannot read trace"), "{err}");
        assert!(err.contains("/nonexistent/rec.jsonl"), "{err}");
    }

    #[test]
    fn serial_fraction_flag_takes_fractional_values() {
        let a = parse(&["campaign", "--serial-fraction", "0.03", "--quick"]);
        assert_eq!(a.get("serial-fraction").unwrap(), Some("0.03"));
        assert!(a.has_flag("quick"));
        let b = parse(&["campaign", "--serial-fraction=0.1"]);
        assert_eq!(b.get("serial-fraction").unwrap(), Some("0.1"));
        // a bare --serial-fraction errors instead of becoming "true"
        let bare = parse(&["campaign", "--serial-fraction"]);
        assert!(bare.get("serial-fraction").is_err());
    }

    #[test]
    fn fault_flags_resolve_and_reject_malformed_specs_by_name() {
        // no --faults: every injection seam stays disarmed
        assert!(faults_from_args(&parse(&["run", "--steps", "5"])).unwrap().is_none());
        // a single spec arms exactly its site
        let plan = faults_from_args(&parse(&["run", "--faults", "halo:drop@8"]))
            .unwrap()
            .expect("armed");
        assert!(plan.targets(FaultSite::Halo));
        assert!(!plan.targets(FaultSite::Pool));
        // comma lists with probabilities and an explicit seed
        let plan = faults_from_args(&parse(&[
            "run",
            "--faults",
            "ckpt:enospc@6:0.5,pool:panic@8",
            "--fault-seed",
            "42",
        ]))
        .unwrap()
        .expect("armed");
        assert!(plan.targets(FaultSite::Checkpoint));
        assert!(plan.targets(FaultSite::Pool));
        // malformed specs are rejected with the offending piece named
        let bad = |list: &str| {
            faults_from_args(&parse(&["run", "--faults", list])).unwrap_err().to_string()
        };
        assert!(bad("gpu:panic@3").contains("unknown site \"gpu\""), "{}", bad("gpu:panic@3"));
        assert!(bad("halo:melt@3").contains("unknown kind \"melt\""), "{}", bad("halo:melt@3"));
        assert!(bad("halo:drop").contains("missing the @step"), "{}", bad("halo:drop"));
        assert!(bad("pool:corrupt@2").contains("not a valid combination"), "{}", bad("pool:corrupt@2"));
        assert!(bad("halo:drop@x").contains("bad step"), "{}", bad("halo:drop@x"));
        assert!(
            bad("ckpt:enospc@2:1.5").contains("outside [0, 1]"),
            "{}",
            bad("ckpt:enospc@2:1.5")
        );
        assert!(
            bad("halo:drop@2:-0.1").contains("outside [0, 1]"),
            "{}",
            bad("halo:drop@2:-0.1")
        );
        assert!(bad("").contains("empty spec"), "{}", bad(""));
        // a seed without a plan is rejected by name (typo guard)
        let e = faults_from_args(&parse(&["run", "--fault-seed", "7"])).unwrap_err().to_string();
        assert!(e.contains("--fault-seed without --faults"), "{e}");
        // a malformed seed names its flag
        let e = faults_from_args(&parse(&["run", "--faults", "halo:drop@1", "--fault-seed", "x"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("--fault-seed"), "{e}");
        // a bare --faults (forgotten list) errors instead of "true"
        let bare = parse(&["run", "--faults"]);
        assert!(faults_from_args(&bare).is_err());
    }

    #[test]
    fn checkpoint_keep_resolves_and_rejects_zero_by_name() {
        // absent: the ring is just the live snapshot
        assert_eq!(checkpoint_keep_from_args(&parse(&["run", "--steps", "5"])).unwrap(), 1);
        let a = parse(&["run", "--checkpoint-keep", "3"]);
        assert_eq!(checkpoint_keep_from_args(&a).unwrap(), 3);
        let b = parse(&["run", "--checkpoint-keep=2"]);
        assert_eq!(checkpoint_keep_from_args(&b).unwrap(), 2);
        // 0 would mean "keep no snapshots at all" — rejected by name,
        // not clamped
        let z = parse(&["run", "--checkpoint-keep", "0"]);
        let err = checkpoint_keep_from_args(&z).unwrap_err().to_string();
        assert!(err.contains("--checkpoint-keep"), "{err}");
        assert!(err.contains(">= 1"), "{err}");
        // a malformed count names the flag
        let neg = parse(&["run", "--checkpoint-keep", "-2"]);
        let err = checkpoint_keep_from_args(&neg).unwrap_err().to_string();
        assert!(err.contains("--checkpoint-keep"), "{err}");
        // a bare --checkpoint-keep errors instead of defaulting
        let bare = parse(&["run", "--checkpoint-keep"]);
        assert!(checkpoint_keep_from_args(&bare).is_err());
    }
}
