//! Minimal Prometheus text-format (0.0.4) parser for exporter tests
//! and CI smoke checks: just enough to round-trip
//! `telemetry::prometheus::render` output and assert on series values.
//! Not a general scrape client — unsupported syntax is a hard error,
//! so renderer drift surfaces as a test failure instead of being
//! silently accepted.

use anyhow::{bail, Context};

/// One sample line: full sample name (histogram samples keep their
/// `_bucket` / `_sum` / `_count` suffix), label set in source order,
/// parsed value.
#[derive(Clone, Debug, PartialEq)]
pub struct PromSample {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

/// Family metadata accumulated from `# HELP` / `# TYPE` lines.
#[derive(Clone, Debug, PartialEq)]
pub struct PromFamily {
    pub name: String,
    pub help: String,
    /// `counter` | `gauge` | `histogram` (or whatever TYPE said);
    /// `untyped` when no TYPE line was seen.
    pub kind: String,
}

/// A parsed exposition document.
#[derive(Clone, Debug, Default)]
pub struct PromMetrics {
    pub families: Vec<PromFamily>,
    pub samples: Vec<PromSample>,
}

impl PromMetrics {
    pub fn family(&self, name: &str) -> Option<&PromFamily> {
        self.families.iter().find(|f| f.name == name)
    }

    /// Value of the sample with exactly this label set
    /// (order-insensitive; histogram users name the suffix, e.g.
    /// `value("lat_seconds_bucket", &[("le", "+Inf")])`).
    pub fn value(&self, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
        self.samples
            .iter()
            .find(|s| {
                s.name == name
                    && s.labels.len() == labels.len()
                    && labels
                        .iter()
                        .all(|(k, v)| s.labels.iter().any(|(sk, sv)| sk == k && sv == v))
            })
            .map(|s| s.value)
    }

    /// All samples with this exact name.
    pub fn samples_of<'a>(&'a self, name: &'a str) -> impl Iterator<Item = &'a PromSample> {
        self.samples.iter().filter(move |s| s.name == name)
    }
}

/// Parse a full exposition document.
pub fn parse(text: &str) -> anyhow::Result<PromMetrics> {
    let mut out = PromMetrics::default();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let (name, help) = rest.split_once(' ').unwrap_or((rest, ""));
            family_entry(&mut out.families, name).help = unescape_help(help);
        } else if let Some(rest) = line.strip_prefix("# TYPE ") {
            let (name, kind) = rest
                .split_once(' ')
                .with_context(|| format!("line {}: TYPE without a kind: {line:?}", ln + 1))?;
            family_entry(&mut out.families, name).kind = kind.trim().to_string();
        } else if line.starts_with('#') {
            // other comments are legal and ignored
        } else {
            out.samples
                .push(parse_sample(line).with_context(|| format!("line {}", ln + 1))?);
        }
    }
    Ok(out)
}

fn family_entry<'a>(families: &'a mut Vec<PromFamily>, name: &str) -> &'a mut PromFamily {
    if let Some(i) = families.iter().position(|f| f.name == name) {
        return &mut families[i];
    }
    families.push(PromFamily {
        name: name.to_string(),
        help: String::new(),
        kind: "untyped".to_string(),
    });
    families.last_mut().expect("just pushed")
}

fn parse_sample(line: &str) -> anyhow::Result<PromSample> {
    let brace = line.find('{');
    let space = line.find(' ');
    let labeled = match (brace, space) {
        (Some(b), Some(s)) => b < s,
        (Some(_), None) => true,
        _ => false,
    };
    let (name, labels, rest) = if labeled {
        let b = brace.expect("labeled implies a brace");
        let (labels, rest) = parse_labels(&line[b..])?;
        (&line[..b], labels, rest)
    } else {
        let s = space.with_context(|| format!("sample has no value: {line:?}"))?;
        (&line[..s], Vec::new(), &line[s..])
    };
    anyhow::ensure!(!name.is_empty(), "sample has no name: {line:?}");
    let mut toks = rest.split_whitespace();
    let value = parse_value(
        toks.next()
            .with_context(|| format!("sample has no value: {line:?}"))?,
    )?;
    // one optional trailing token (a timestamp) is legal; more is not
    anyhow::ensure!(toks.count() <= 1, "trailing garbage in sample: {line:?}");
    Ok(PromSample { name: name.to_string(), labels, value })
}

/// Parse a `{k="v",...}` label set; returns the labels and the
/// remainder of the line after the closing brace.
fn parse_labels(s: &str) -> anyhow::Result<(Vec<(String, String)>, &str)> {
    let bytes = s.as_bytes();
    anyhow::ensure!(bytes.first() == Some(&b'{'), "label set must start with '{{': {s:?}");
    let mut i = 1;
    let mut labels = Vec::new();
    loop {
        anyhow::ensure!(i < bytes.len(), "unterminated label set: {s:?}");
        if bytes[i] == b'}' {
            i += 1;
            break;
        }
        let start = i;
        while i < bytes.len() && bytes[i] != b'=' {
            i += 1;
        }
        anyhow::ensure!(i < bytes.len(), "label without '=': {s:?}");
        let key = s[start..i].to_string();
        i += 1; // '='
        anyhow::ensure!(bytes.get(i) == Some(&b'"'), "label value must be quoted: {s:?}");
        i += 1;
        let mut val = String::new();
        loop {
            anyhow::ensure!(i < bytes.len(), "unterminated label value: {s:?}");
            match bytes[i] {
                b'\\' => {
                    match bytes.get(i + 1) {
                        Some(b'\\') => val.push('\\'),
                        Some(b'"') => val.push('"'),
                        Some(b'n') => val.push('\n'),
                        _ => bail!("bad escape in label value: {s:?}"),
                    }
                    i += 2;
                }
                b'"' => {
                    i += 1;
                    break;
                }
                _ => {
                    let ch = s[i..].chars().next().expect("in-bounds index");
                    val.push(ch);
                    i += ch.len_utf8();
                }
            }
        }
        labels.push((key, val));
        match bytes.get(i) {
            Some(b',') => i += 1,
            Some(b'}') => {}
            _ => bail!("expected ',' or '}}' after a label: {s:?}"),
        }
    }
    Ok((labels, &s[i..]))
}

fn parse_value(tok: &str) -> anyhow::Result<f64> {
    Ok(match tok {
        "+Inf" | "Inf" => f64::INFINITY,
        "-Inf" => f64::NEG_INFINITY,
        "NaN" => f64::NAN,
        t => t.parse::<f64>().with_context(|| format!("bad sample value {t:?}"))?,
    })
}

fn unescape_help(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c == '\\' {
            match chars.next() {
                Some('\\') => out.push('\\'),
                Some('n') => out.push('\n'),
                Some(other) => {
                    out.push('\\');
                    out.push(other);
                }
                None => out.push('\\'),
            }
        } else {
            out.push(c);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::Registry;

    #[test]
    fn round_trips_the_registry_renderer() {
        let reg = Registry::new();
        reg.counter("rt_steps_total", "Steps.").add(42);
        reg.gauge("rt_depth", "Depth.").set(-7);
        reg.counter_with("rt_tiles_total", "Tiles.", &[("family", "naive"), ("slot", "0")])
            .add(9);
        let h = reg.histogram("rt_lat_seconds", "Latency.", &[0.001, 0.01, 0.1]);
        for v in [0.0005, 0.002, 0.02, 5.0] {
            h.observe(v);
        }
        let m = parse(&reg.render()).unwrap();

        assert_eq!(m.family("rt_steps_total").unwrap().kind, "counter");
        assert_eq!(m.family("rt_steps_total").unwrap().help, "Steps.");
        assert_eq!(m.family("rt_depth").unwrap().kind, "gauge");
        assert_eq!(m.family("rt_lat_seconds").unwrap().kind, "histogram");
        assert_eq!(m.value("rt_steps_total", &[]), Some(42.0));
        assert_eq!(m.value("rt_depth", &[]), Some(-7.0));
        // label order must not matter to the lookup
        assert_eq!(m.value("rt_tiles_total", &[("slot", "0"), ("family", "naive")]), Some(9.0));
        // cumulative buckets; +Inf equals _count
        assert_eq!(m.value("rt_lat_seconds_bucket", &[("le", "0.001")]), Some(1.0));
        assert_eq!(m.value("rt_lat_seconds_bucket", &[("le", "0.01")]), Some(2.0));
        assert_eq!(m.value("rt_lat_seconds_bucket", &[("le", "0.1")]), Some(3.0));
        assert_eq!(m.value("rt_lat_seconds_bucket", &[("le", "+Inf")]), Some(4.0));
        assert_eq!(m.value("rt_lat_seconds_count", &[]), Some(4.0));
        let sum = m.value("rt_lat_seconds_sum", &[]).unwrap();
        assert!((sum - 5.0225).abs() < 1e-12, "{sum}");
        // the auto-registered pool gauge is part of every registry
        assert_eq!(m.family("hostencil_pool_workers").unwrap().kind, "gauge");
        assert!(m.value("hostencil_pool_workers", &[]).is_some());
    }

    #[test]
    fn escaped_label_values_round_trip() {
        let reg = Registry::new();
        let tricky = "a\\b\"c\nd";
        reg.counter_with("rt_esc_total", "Escapes.", &[("path", tricky)]).inc();
        let m = parse(&reg.render()).unwrap();
        assert_eq!(m.value("rt_esc_total", &[("path", tricky)]), Some(1.0));
    }

    #[test]
    fn special_values_and_timestamps_parse() {
        let m = parse("a 1 1234567890\nb +Inf\nc -Inf\nd NaN\ne 2.5e-3\n").unwrap();
        assert_eq!(m.value("a", &[]), Some(1.0));
        assert_eq!(m.value("b", &[]), Some(f64::INFINITY));
        assert_eq!(m.value("c", &[]), Some(f64::NEG_INFINITY));
        assert!(m.value("d", &[]).unwrap().is_nan());
        assert_eq!(m.value("e", &[]), Some(0.0025));
    }

    #[test]
    fn malformed_lines_are_hard_errors() {
        assert!(parse("name_only\n").is_err());
        assert!(parse("x{unclosed=\"v\" 1\n").is_err());
        assert!(parse("x{k=unquoted} 1\n").is_err());
        assert!(parse("x 1 2 3\n").is_err());
        assert!(parse("x notanumber\n").is_err());
    }
}
