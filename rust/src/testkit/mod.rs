//! Property-testing helpers (substrate — no `proptest` in the offline
//! crate set): a fast deterministic RNG plus shrink-free random-case
//! runners used by the `rust/tests/proptests.rs` suite, and a minimal
//! Prometheus text-format parser ([`prom`]) that round-trips
//! `telemetry::prometheus::render` output in exporter tests.

pub mod prom;

use crate::grid::{Dim3, Field3};

/// xorshift64* — deterministic, seedable, good enough for test-case
/// generation (NOT cryptographic).
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng { state: seed.max(1) }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + (self.next_u64() as usize) % (hi - lo + 1)
    }

    /// Standard-normal-ish value (sum of uniforms; adequate for tests).
    pub fn normal(&mut self) -> f32 {
        let mut s = 0.0f32;
        for _ in 0..12 {
            s += self.f32();
        }
        s - 6.0
    }

    /// Pick one element.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.range(0, items.len() - 1)]
    }

    /// Random field with normal-ish entries.
    pub fn field(&mut self, dims: Dim3) -> Field3 {
        Field3::from_fn(dims, |_, _, _| self.normal())
    }

    /// Random positive field in [lo, hi).
    pub fn field_in(&mut self, dims: Dim3, lo: f32, hi: f32) -> Field3 {
        Field3::from_fn(dims, |_, _, _| self.range_f32(lo, hi))
    }
}

/// Run `f` for `cases` seeded cases; panics with the failing seed so the
/// case can be replayed exactly.
pub fn check(name: &str, cases: usize, mut f: impl FnMut(&mut Rng)) {
    let base = 0x5EED_0000u64;
    for i in 0..cases {
        let seed = base + i as u64;
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property {name:?} failed on case {i} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn range_respects_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let v = r.range(3, 9);
            assert!((3..=9).contains(&v));
        }
    }

    #[test]
    fn normal_is_roughly_centered() {
        let mut r = Rng::new(11);
        let mean: f32 = (0..4000).map(|_| r.normal()).sum::<f32>() / 4000.0;
        assert!(mean.abs() < 0.2, "{mean}");
    }

    #[test]
    fn field_has_right_dims() {
        let mut r = Rng::new(3);
        let f = r.field(Dim3::new(2, 3, 4));
        assert_eq!(f.dims(), Dim3::new(2, 3, 4));
        let g = r.field_in(Dim3::new(2, 2, 2), 1.0, 2.0);
        assert!(g.as_slice().iter().all(|&v| (1.0..2.0).contains(&v)));
    }

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check("counter", 17, |_| n += 1);
        assert_eq!(n, 17);
    }
}
