//! 3D-blocked propagator: the CPU analog of the paper's `gmem` /
//! `smem_u` / `smem_eta_*` families (§IV.1-3).
//!
//! On the GPU those families differ in *staging* (global memory vs
//! shared-memory tiles); on the CPU the cache hierarchy does the
//! staging, so they collapse onto one shape: split every decomposition
//! region into the variant's d1 x d2 x d3 tiles and fan the tiles over
//! worker threads — each tile's working set is what a thread block
//! would have staged. The tile task list is planned once per domain
//! and tiles update the shared padded output in place (see
//! `propagator` module docs on the zero-allocation steady state).

use super::propagator::{
    inner_tile_into, pml_tile_into, Plan, Propagator, PropagatorInputs,
};
use super::{simd, Consts};
use crate::gpusim::kernels::KernelVariant;
use crate::grid::{decompose, Dim3, Field3};

/// Cache-tiled 3D blocking over the 7-region decomposition.
pub struct Blocked3D {
    /// Tile extents in (z, y, x) order — the variant's (d3, d2, d1);
    /// Table II names tiles `{Dx}x{Dy}x{Dz}`, x innermost.
    pub tile: Dim3,
    plan: Option<Plan<()>>,
}

impl Blocked3D {
    pub fn new(tile: Dim3) -> Blocked3D {
        Blocked3D { tile, plan: None }
    }

    pub fn from_variant(v: &KernelVariant) -> Blocked3D {
        Blocked3D::new(Dim3::new(
            (v.d3.max(1)) as usize,
            (v.d2.max(1)) as usize,
            (v.d1.max(1)) as usize,
        ))
    }
}

impl Propagator for Blocked3D {
    fn name(&self) -> &'static str {
        "blocked3d"
    }

    fn signature(&self) -> String {
        format!("blocked3d:{}:{}", self.tile, simd::detected().tag())
    }

    fn step_into(&mut self, inp: &PropagatorInputs<'_>, out: &mut Field3) {
        debug_assert_eq!(out.dims(), inp.domain.padded());
        let k = Consts::of(inp.domain).with_kernel(simd::active());
        let tile = self.tile;
        let plan = Plan::ensure(
            &mut self.plan,
            inp.domain,
            inp.threads,
            "blocked3d",
            inp.telemetry,
            |d| decompose(d).iter().flat_map(|r| r.split(tile)).collect(),
            |_| (),
        );
        plan.run_into(out, |t, _s, o| {
            if t.class.is_pml() {
                pml_tile_into(inp, t, k, o);
            } else {
                inner_tile_into(inp, t, k, o);
            }
        });
    }
}
