//! The executable code-shape engine: a [`Propagator`] trait with
//! tiled, multithreaded CPU implementations of the paper's kernel
//! families (§IV).
//!
//! The gpusim layer *predicts* how each of the 25 `KernelVariant`s
//! would perform on real GPUs; this module makes the underlying code
//! shapes *executable* on the CPU so shape choice has measurable cost:
//!
//! | paper family (§IV)                  | CPU analog          |
//! |-------------------------------------|---------------------|
//! | — (reference)                       | [`Naive`]           |
//! | gmem / smem_u / smem_eta_* 3D blocks| `Blocked3D`         |
//! | semi-stencil                        | `SemiStencil`       |
//! | st_smem / st_reg_* 2.5D streaming   | `Streaming25D`      |
//!
//! Every propagator drives the same 7-region decomposition
//! (`grid::decompose`), splits regions into tiles (its block grid),
//! and fans the tiles over `std::thread` workers. All families except
//! `SemiStencil` keep the golden arithmetic ordering per point, so
//! they are bit-identical to [`super::GoldenPropagator`]; semi-stencil
//! re-associates the x-axis chain by design and agrees to a few ULP
//! (asserted by `rust/tests/propagator_equivalence.rs`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use super::{C2, C8};
use crate::grid::{decompose, Dim3, Domain, Field3, Region};
use crate::gpusim::kernels::{self, Family};
use crate::R;

pub use super::blocked::Blocked3D;
pub use super::semi::SemiStencil;
pub use super::streaming::Streaming25D;

/// Borrowed per-step state handed to a propagator. All wavefields are
/// `R`-ghost-padded with a zero ghost ring (the Dirichlet closure);
/// `v` is interior-sized.
pub struct PropagatorInputs<'a> {
    pub domain: &'a Domain,
    /// Wavefield at step n.
    pub u_pad: &'a Field3,
    /// Wavefield at step n-1.
    pub um_pad: &'a Field3,
    /// Velocity model, interior-sized.
    pub v: &'a Field3,
    /// Damping profile, R-ghost-padded (zero ghost).
    pub eta_pad: &'a Field3,
    /// Worker threads for the tile fan-out (0 = one per core).
    pub threads: usize,
}

/// One executable CPU code shape. Implementations compute a full
/// decomposed time step (inner 25-point + six PML faces) and return
/// the next `R`-ghost-padded wavefield; source injection, receivers,
/// and state rotation stay in the coordinator.
pub trait Propagator: Send + Sync {
    /// Stable display name (also used as the bench label prefix).
    fn name(&self) -> &'static str;

    /// Identifies physics-equivalent configurations (kind + tile
    /// dims). Two kernel variants with the same signature produce the
    /// same measured physics, so the campaign runs them once.
    fn signature(&self) -> String;

    /// Compute the next R-ghost-padded wavefield (no source injection;
    /// the ghost ring stays zero).
    fn step(&self, inp: &PropagatorInputs<'_>) -> Field3;
}

/// Build the CPU propagator for a name: `naive`/`golden`, a family
/// shorthand (`gmem`, `st_smem`, ...), or a full Table II variant id
/// (`gmem_8x8x8`, `st_reg_shft_16x32`, ...). Families map to their
/// CPU analogs per the module-level table.
pub fn build(name: &str) -> anyhow::Result<Box<dyn Propagator>> {
    if matches!(name, "naive" | "golden") {
        return Ok(Box::new(Naive));
    }
    let v = kernels::resolve(name)?;
    Ok(match v.family {
        Family::Gmem | Family::SmemU | Family::SmemEta1 | Family::SmemEta3 => {
            Box::new(Blocked3D::from_variant(&v))
        }
        Family::Semi => Box::new(SemiStencil::from_variant(&v)),
        Family::StSmem | Family::StRegShft | Family::StRegFixed => {
            Box::new(Streaming25D::from_variant(&v))
        }
    })
}

/// Physics signature of a variant name without keeping the propagator
/// (campaign physics sharing keys on this).
pub fn signature(name: &str) -> anyhow::Result<String> {
    Ok(build(name)?.signature())
}

/// The `hostencil bench` matrix: representative propagator
/// configurations with stable labels.
pub fn bench_matrix() -> Vec<(&'static str, &'static str)> {
    vec![
        ("naive", "naive"),
        ("blocked3d_8x8x8", "gmem_8x8x8"),
        ("blocked3d_16x16x4", "gmem_16x16x4"),
        ("semi_8x8x8", "semi"),
        ("streaming25d_8x8", "st_smem_8x8"),
        ("streaming25d_16x16", "st_smem_16x16"),
    ]
}

/// Precomputed per-step scalar constants. Derivations mirror
/// `stencil::lap8` / `step_inner` / `step_pml` exactly (f64 -> f32
/// casts in the same places) so fused per-point updates stay
/// bit-identical to the golden two-pass ones.
#[derive(Copy, Clone)]
pub(crate) struct Consts {
    pub dt2: f32,
    pub dt_f: f32,
    pub inv_h2: f32,
}

impl Consts {
    pub(crate) fn of(domain: &Domain) -> Consts {
        Consts {
            dt2: (domain.dt * domain.dt) as f32,
            dt_f: domain.dt as f32,
            inv_h2: (1.0 / (domain.h * domain.h)) as f32,
        }
    }
}

/// Fused inner (25-point, 8th-order) leapfrog update of the interior
/// point `(iz, iy, ix)`. Arithmetic ordering mirrors `lap8` +
/// `step_inner`: per-point results are bit-identical.
#[inline(always)]
pub(crate) fn inner_point(
    inp: &PropagatorInputs<'_>,
    iz: usize,
    iy: usize,
    ix: usize,
    k: Consts,
) -> f32 {
    let u = inp.u_pad;
    let (cz, cy, cx) = (iz + R, iy + R, ix + R);
    let mut acc = 3.0 * C8[0] * u.get(cz, cy, cx);
    for m in 1..=R {
        acc += C8[m]
            * (u.get(cz + m, cy, cx)
                + u.get(cz - m, cy, cx)
                + u.get(cz, cy + m, cx)
                + u.get(cz, cy - m, cx)
                + u.get(cz, cy, cx + m)
                + u.get(cz, cy, cx - m));
    }
    let lap = acc * k.inv_h2;
    let core = u.get(cz, cy, cx);
    let vv = inp.v.get(iz, iy, ix);
    2.0 * core - inp.um_pad.get(cz, cy, cx) + k.dt2 * vv * vv * lap
}

/// Fused PML (7-point, damped) update of the interior point
/// `(iz, iy, ix)`. Mirrors `lap2` + `eta_bar` + `step_pml`.
#[inline(always)]
pub(crate) fn pml_point(
    inp: &PropagatorInputs<'_>,
    iz: usize,
    iy: usize,
    ix: usize,
    k: Consts,
) -> f32 {
    let u = inp.u_pad;
    let e = inp.eta_pad;
    let (cz, cy, cx) = (iz + R, iy + R, ix + R);
    let acc = 3.0 * C2[0] * u.get(cz, cy, cx)
        + (u.get(cz + 1, cy, cx)
            + u.get(cz - 1, cy, cx)
            + u.get(cz, cy + 1, cx)
            + u.get(cz, cy - 1, cx)
            + u.get(cz, cy, cx + 1)
            + u.get(cz, cy, cx - 1));
    let lap = acc * k.inv_h2;
    let eb = (e.get(cz, cy, cx)
        + e.get(cz + 1, cy, cx)
        + e.get(cz - 1, cy, cx)
        + e.get(cz, cy + 1, cx)
        + e.get(cz, cy - 1, cx)
        + e.get(cz, cy, cx + 1)
        + e.get(cz, cy, cx - 1))
        / 7.0;
    let ed = eb * k.dt_f;
    let core = u.get(cz, cy, cx);
    let vv = inp.v.get(iz, iy, ix);
    let num = 2.0 * core - (1.0 - ed) * inp.um_pad.get(cz, cy, cx) + k.dt2 * vv * vv * lap;
    num / (1.0 + ed)
}

/// Walk an inner tile point by point (the per-point gmem shape).
pub(crate) fn inner_tile(inp: &PropagatorInputs<'_>, offset: Dim3, shape: Dim3, k: Consts) -> Field3 {
    let mut out = Field3::zeros(shape);
    for z in 0..shape.z {
        for y in 0..shape.y {
            for x in 0..shape.x {
                out.set(z, y, x, inner_point(inp, offset.z + z, offset.y + y, offset.x + x, k));
            }
        }
    }
    out
}

/// Walk a PML tile point by point (shared by every family: the
/// paper's PML kernels differ only in eta staging, which has no CPU
/// cache analog beyond tiling).
pub(crate) fn pml_tile(inp: &PropagatorInputs<'_>, offset: Dim3, shape: Dim3, k: Consts) -> Field3 {
    let mut out = Field3::zeros(shape);
    for z in 0..shape.z {
        for y in 0..shape.y {
            for x in 0..shape.x {
                out.set(z, y, x, pml_point(inp, offset.z + z, offset.y + y, offset.x + x, k));
            }
        }
    }
    out
}

fn resolve_threads(requested: usize, tasks: usize) -> usize {
    let n = if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    };
    n.min(tasks).max(1)
}

/// Fan tile tasks over worker threads (shared atomic cursor, the same
/// idiom as the campaign runner) and scatter each computed tile into a
/// fresh R-ghost-padded output field. Tiles partition the interior, so
/// the result is scheduling-independent.
///
/// Callers rebuild the task list each step; that is O(tiles) work and
/// allocation against O(points x 45 FLOP) of stencil compute, so it
/// stays out of the measured-rate noise floor. Cache the plan in the
/// propagator if profiling ever says otherwise.
pub(crate) fn run_tiled<F>(domain: &Domain, tasks: &[Region], threads: usize, f: F) -> Field3
where
    F: Fn(&Region) -> Field3 + Sync,
{
    let mut out = Field3::zeros(domain.padded());
    let dst = |t: &Region| Dim3::new(R + t.offset.z, R + t.offset.y, R + t.offset.x);
    let n = resolve_threads(threads, tasks.len());
    if n == 1 {
        for t in tasks {
            out.scatter(dst(t), &f(t));
        }
        return out;
    }
    let cursor = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<Field3>>> = Mutex::new(vec![None; tasks.len()]);
    std::thread::scope(|s| {
        for _ in 0..n {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= tasks.len() {
                    break;
                }
                let tile = f(&tasks[i]);
                results.lock().unwrap()[i] = Some(tile);
            });
        }
    });
    for (t, tile) in tasks.iter().zip(results.into_inner().unwrap()) {
        out.scatter(dst(t), &tile.expect("every tile task ran"));
    }
    out
}

/// The reference shape: one task per decomposition region, per-point
/// global-memory walk — exactly the golden propagator's code shape,
/// parallelized over the seven regions.
pub struct Naive;

impl Propagator for Naive {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn signature(&self) -> String {
        "naive".to_string()
    }

    fn step(&self, inp: &PropagatorInputs<'_>) -> Field3 {
        let k = Consts::of(inp.domain);
        let tasks = decompose(inp.domain);
        run_tiled(inp.domain, &tasks, inp.threads, |t| {
            if t.class.is_pml() {
                pml_tile(inp, t.offset, t.shape, k)
            } else {
                inner_tile(inp, t.offset, t.shape, k)
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Rng;
    use crate::wave;

    struct State {
        domain: Domain,
        u_pad: Field3,
        um_pad: Field3,
        v: Field3,
        eta_pad: Field3,
    }

    fn random_state(interior: Dim3, pml: usize, seed: u64) -> State {
        let domain = Domain::new(interior, pml, 10.0, 1e-3).unwrap();
        let mut rng = Rng::new(seed);
        State {
            domain,
            u_pad: rng.field(interior).pad(R),
            um_pad: rng.field(interior).pad(R),
            v: rng.field_in(interior, 1500.0, 3500.0),
            eta_pad: wave::eta_profile(&domain, 3500.0).pad(R),
        }
    }

    fn step_with(st: &State, name: &str, threads: usize) -> Field3 {
        build(name).unwrap().step(&PropagatorInputs {
            domain: &st.domain,
            u_pad: &st.u_pad,
            um_pad: &st.um_pad,
            v: &st.v,
            eta_pad: &st.eta_pad,
            threads,
        })
    }

    #[test]
    fn factory_resolves_names_families_and_ids() {
        assert_eq!(build("naive").unwrap().name(), "naive");
        assert_eq!(build("golden").unwrap().name(), "naive");
        assert_eq!(build("gmem").unwrap().name(), "blocked3d");
        assert_eq!(build("smem_u").unwrap().name(), "blocked3d");
        assert_eq!(build("semi").unwrap().name(), "semi_stencil");
        assert_eq!(build("st_smem_8x8").unwrap().name(), "streaming2.5d");
        assert_eq!(build("st_reg_fixed").unwrap().name(), "streaming2.5d");
        assert!(build("warp_specialized").is_err());
    }

    #[test]
    fn signatures_group_physics_equivalent_variants() {
        // same kind + tile dims -> same physics -> shared campaign run
        assert_eq!(signature("gmem_8x8x8").unwrap(), signature("smem_u").unwrap());
        assert_eq!(
            signature("st_smem_16x16").unwrap(),
            signature("st_reg_shft_16x16").unwrap()
        );
        assert_ne!(signature("gmem_8x8x8").unwrap(), signature("gmem_16x16x4").unwrap());
        assert_ne!(signature("naive").unwrap(), signature("gmem_8x8x8").unwrap());
        assert_ne!(signature("semi").unwrap(), signature("gmem_8x8x8").unwrap());
    }

    #[test]
    fn bench_matrix_entries_all_build_with_unique_labels() {
        let m = bench_matrix();
        for (label, variant) in &m {
            build(variant).unwrap_or_else(|e| panic!("{label}: {e}"));
        }
        let mut labels: Vec<_> = m.iter().map(|(l, _)| *l).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), m.len(), "bench labels must be unique");
    }

    #[test]
    fn tiled_and_streaming_shapes_are_bit_identical_to_naive() {
        // non-tile-aligned extents on purpose: 13x11x17 with 8^3 /
        // 16x16x4 / 32x32x1 tiles exercises every clipping path
        let st = random_state(Dim3::new(13, 11, 17), 3, 0xC0FFEE);
        let base = step_with(&st, "naive", 1);
        assert!(base.max_abs() > 0.0);
        for name in [
            "gmem_8x8x8",
            "gmem_32x32x1",
            "gmem_16x16x4",
            "smem_u",
            "st_smem_8x8",
            "st_reg_fixed_32x32",
        ] {
            for threads in [1, 3] {
                let got = step_with(&st, name, threads);
                assert_eq!(
                    got.max_abs_diff(&base),
                    0.0,
                    "{name} with {threads} threads deviated from naive"
                );
            }
        }
    }

    #[test]
    fn semi_stencil_matches_naive_to_ulp_level() {
        let st = random_state(Dim3::new(12, 14, 13), 3, 0xBEEF);
        let base = step_with(&st, "naive", 1);
        for threads in [1, 2] {
            let got = step_with(&st, "semi", threads);
            let rel = got.max_abs_diff(&base) / base.max_abs().max(1e-30);
            assert!(rel < 1e-5, "semi re-association drifted: rel {rel}");
        }
    }

    #[test]
    fn ghost_ring_stays_zero() {
        let st = random_state(Dim3::new(11, 9, 13), 2, 7);
        for name in ["naive", "gmem_8x8x8", "st_smem_8x8", "semi"] {
            let out = step_with(&st, name, 2);
            let d = out.dims();
            assert_eq!(out.get(0, 0, 0), 0.0, "{name}");
            assert_eq!(out.get(d.z - 1, d.y - 1, d.x - 1), 0.0, "{name}");
            assert_eq!(out.unpad(R).pad(R), out, "{name}: ghost must be zero");
        }
    }
}
