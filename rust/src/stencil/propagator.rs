//! The executable code-shape engine: a [`Propagator`] trait with
//! tiled, multithreaded CPU implementations of the paper's kernel
//! families (§IV).
//!
//! The gpusim layer *predicts* how each of the 25 `KernelVariant`s
//! would perform on real GPUs; this module makes the underlying code
//! shapes *executable* on the CPU so shape choice has measurable cost:
//!
//! | paper family (§IV)                  | CPU analog          |
//! |-------------------------------------|---------------------|
//! | — (reference)                       | [`Naive`]           |
//! | gmem / smem_u / smem_eta_* 3D blocks| `Blocked3D`         |
//! | semi-stencil                        | `SemiStencil`       |
//! | st_smem / st_reg_* 2.5D streaming   | `Streaming25D`      |
//!
//! Every propagator drives the same 7-region decomposition
//! (`grid::decompose`), splits regions into tiles (its block grid),
//! and fans the tiles over the persistent worker-pool executor
//! (`crate::runtime::pool`).
//!
//! ## Zero-allocation, zero-spawn steady state
//!
//! [`Propagator::step_into`] advances the wavefield **in place**: the
//! output buffer holds u(n-1) on entry — read only at the center point,
//! as the leapfrog `um` term — and u(n+1) on exit, so two persistent
//! padded buffers ping-pong with a `swap` and the time loop never
//! allocates. All per-domain state (tile task lists, per-worker
//! scratch like streaming ring buffers and semi-stencil partial rows,
//! and the worker pool itself) lives in a [`Plan`] built on first use
//! and reused while the (domain, threads) key is unchanged;
//! `rust/tests/zero_alloc.rs` proves the steady-state loop performs
//! zero heap allocations for every family on the serial *and* the
//! pooled parallel path. With one worker the tasks run inline on the
//! caller's thread (no pool is ever built); with more, the plan's
//! [`crate::runtime::pool::WorkerPool`] releases its parked workers
//! via a per-step generation bump — no `thread::scope`, no per-step
//! spawn, O(threads) condvar bookkeeping, never O(points) — each slot
//! owning its scratch entry across steps, and tiles write disjoint
//! rows of the shared output directly (no per-tile buffers, no
//! scatter).
//!
//! All families except `SemiStencil` keep the golden arithmetic
//! ordering per point, so they are bit-identical to
//! [`super::GoldenPropagator`]; semi-stencil re-associates the x-axis
//! chain by design and agrees to a few ULP (asserted by
//! `rust/tests/propagator_equivalence.rs`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use super::{inner_row, pml_row, Consts};
use crate::grid::{decompose, Dim3, Domain, Field3, Region};
use crate::gpusim::kernels::{self, Family, KernelVariant};
use crate::json::Json;
use crate::runtime::pool::WorkerPool;
use crate::telemetry::{Counter, Histogram, Registry, LATENCY_BOUNDS};
use crate::R;

pub use super::blocked::Blocked3D;
pub use super::fused::TimeFused;
pub use super::semi::SemiStencil;
pub use super::streaming::Streaming25D;

/// Borrowed per-step state handed to a propagator. All wavefields are
/// `R`-ghost-padded with a zero ghost ring (the Dirichlet closure);
/// `v` is interior-sized. The previous wavefield is **not** here: it
/// lives in the output buffer passed to [`Propagator::step_into`].
pub struct PropagatorInputs<'a> {
    pub domain: &'a Domain,
    /// Wavefield at step n.
    pub u_pad: &'a Field3,
    /// Velocity model, interior-sized.
    pub v: &'a Field3,
    /// Damping profile, R-ghost-padded (zero ghost).
    pub eta_pad: &'a Field3,
    /// Worker threads for the tile fan-out (0 = one per core).
    pub threads: usize,
    /// Metrics registry; `None` runs uninstrumented. Instrumentation
    /// handles are registered once at plan-build time, so the
    /// steady-state step stays allocation-free either way.
    pub telemetry: Option<&'a Registry>,
}

/// Borrowed per-batch state for [`Propagator::advance_fused`]: the
/// static fields of [`PropagatorInputs`] without the wavefield — both
/// wavefield buffers are passed `&mut` because a multi-step batch
/// rotates them internally.
pub struct FusedInputs<'a> {
    pub domain: &'a Domain,
    /// Velocity model, interior-sized.
    pub v: &'a Field3,
    /// Damping profile, R-ghost-padded (zero ghost).
    pub eta_pad: &'a Field3,
    /// Worker threads for the tile fan-out (0 = one per core).
    pub threads: usize,
    /// Metrics registry; `None` runs uninstrumented.
    pub telemetry: Option<&'a Registry>,
}

/// Per-batch source-injection schedule: after every virtual sub-step
/// `j`, `amp(j, i)` is added to the wavefield at `positions[i]`
/// (interior coordinates) — the same order the coordinator injects
/// after an unfused step, so fused batches stay bit-identical.
pub struct SourceBatch<'a> {
    /// Interior positions, one per source.
    pub positions: &'a [Dim3],
    /// Row-major `[n_steps x positions.len()]` amplitudes.
    pub amps: &'a [f32],
    /// Leapfrog steps this batch advances.
    pub n_steps: usize,
}

impl SourceBatch<'_> {
    /// Amplitude of source `i` after virtual sub-step `j` (0-based).
    #[inline]
    pub fn amp(&self, j: usize, i: usize) -> f32 {
        self.amps[j * self.positions.len() + i]
    }

    /// A batch of `n_steps` with no sources.
    pub fn silent(n_steps: usize) -> SourceBatch<'static> {
        SourceBatch { positions: &[], amps: &[], n_steps }
    }
}

/// One executable CPU code shape. Implementations compute a full
/// decomposed time step (inner 25-point + six PML faces) **in place**;
/// source injection, receivers, and buffer rotation stay in the
/// coordinator — except inside a fused batch, where injection must
/// land between virtual sub-steps and therefore rides along in the
/// [`SourceBatch`].
pub trait Propagator: Send {
    /// Stable display name (also used as the bench label prefix).
    fn name(&self) -> &'static str;

    /// Identifies physics-equivalent configurations (kind + tile
    /// dims). Two kernel variants with the same signature produce the
    /// same measured physics, so the campaign runs them once.
    fn signature(&self) -> String;

    /// Advance one step in place. On entry `out` holds the
    /// R-ghost-padded wavefield at step n-1 (the leapfrog `um` term,
    /// read only at the center point); on exit it holds step n+1. The
    /// ghost ring is never written and stays zero. Steady-state calls
    /// perform no heap allocations; per-domain scratch is (re)built
    /// only when the (domain, threads) key changes.
    fn step_into(&mut self, inp: &PropagatorInputs<'_>, out: &mut Field3);

    /// Natural fusion degree: how many leapfrog steps one memory sweep
    /// advances. 1 for every family except [`TimeFused`]; the
    /// coordinator hands `advance_fused` batches of (at most) this
    /// size between observer callbacks.
    fn max_fuse(&self) -> usize {
        1
    }

    /// Advance `batch.n_steps` steps, rotating the two persistent
    /// padded buffers and injecting `batch` sources after every
    /// virtual sub-step. On return `u_pad` holds the newest wavefield
    /// and `um_pad` the one before it, exactly as if the coordinator
    /// had stepped + swapped + injected `n_steps` times — the default
    /// implementation does literally that, so unfused families get the
    /// batch API for free. [`TimeFused`] overrides it with the
    /// overlapped-tile sweep that touches memory once per batch.
    /// Steady-state calls perform no heap allocations on any
    /// implementation.
    fn advance_fused(
        &mut self,
        inp: &FusedInputs<'_>,
        u_pad: &mut Field3,
        um_pad: &mut Field3,
        batch: &SourceBatch<'_>,
    ) {
        for j in 0..batch.n_steps {
            self.step_into(
                &PropagatorInputs {
                    domain: inp.domain,
                    u_pad,
                    v: inp.v,
                    eta_pad: inp.eta_pad,
                    threads: inp.threads,
                    telemetry: inp.telemetry,
                },
                um_pad,
            );
            std::mem::swap(u_pad, um_pad);
            for (i, p) in batch.positions.iter().enumerate() {
                u_pad.add(R + p.z, R + p.y, R + p.x, batch.amp(j, i));
            }
        }
    }
}

/// The executable CPU analog of a gpusim kernel variant (families map
/// per the module-level table). A streaming variant with a fusion
/// degree above 1 (the `tf_s*` descriptors, or fused autotune
/// candidates) maps onto [`TimeFused`]; `tf_s1` deliberately collapses
/// onto the plain [`Streaming25D`] shape so degree-1 rows of a fusion
/// sweep measure the real unfused baseline.
pub fn from_variant(v: &KernelVariant) -> Box<dyn Propagator> {
    match v.family {
        Family::Gmem | Family::SmemU | Family::SmemEta1 | Family::SmemEta3 => {
            Box::new(Blocked3D::from_variant(v))
        }
        Family::Semi => Box::new(SemiStencil::from_variant(v)),
        Family::StSmem | Family::StRegShft | Family::StRegFixed => {
            if v.fuse > 1 {
                Box::new(TimeFused::from_variant(v))
            } else {
                Box::new(Streaming25D::from_variant(v))
            }
        }
    }
}

/// Build the CPU propagator for a name: `naive`/`golden`, a family
/// shorthand (`gmem`, `st_smem`, ...), or a full Table II variant id
/// (`gmem_8x8x8`, `st_reg_shft_16x32`, ...).
pub fn build(name: &str) -> anyhow::Result<Box<dyn Propagator>> {
    if matches!(name, "naive" | "golden") {
        return Ok(Box::new(Naive::default()));
    }
    Ok(from_variant(&kernels::resolve(name)?))
}

/// Physics signature of a variant name without keeping the propagator
/// (campaign physics sharing keys on this).
pub fn signature(name: &str) -> anyhow::Result<String> {
    Ok(build(name)?.signature())
}

/// The `hostencil bench` matrix: representative propagator
/// configurations with stable labels.
pub fn bench_matrix() -> Vec<(&'static str, &'static str)> {
    vec![
        ("naive", "naive"),
        ("blocked3d_8x8x8", "gmem_8x8x8"),
        ("blocked3d_16x16x4", "gmem_16x16x4"),
        ("semi_8x8x8", "semi"),
        ("streaming25d_8x8", "st_smem_8x8"),
        ("streaming25d_16x16", "st_smem_16x16"),
        ("tf_s2", "tf_s2"),
        ("tf_s4", "tf_s4"),
    ]
}

/// Cached per-domain execution state: the tile task list, one scratch
/// slot per worker, and the persistent worker pool, keyed on (domain,
/// requested threads). Built once on first step and reused for every
/// subsequent step — this is what makes the steady-state loop
/// allocation-free *and* spawn-free.
pub(crate) struct Plan<S> {
    domain: Domain,
    threads: usize,
    pub(crate) tasks: Vec<Region>,
    /// One entry per worker slot (always >= 1); slot i of the pool
    /// owns entry i, so per-worker scratch stays pinned across steps.
    pub(crate) scratch: Vec<S>,
    /// Persistent executor for multi-worker plans. `None` on the
    /// serial fast path: one worker slot never touches a pool or
    /// spawns a thread.
    pool: Option<WorkerPool>,
    /// Telemetry handles, registered once when a registry is attached
    /// (at build, or lazily on the first instrumented step). `None`
    /// runs uninstrumented at zero cost.
    instr: Option<PlanInstr>,
}

/// Pre-registered per-plan metric handles: the steady-state step only
/// touches these atomics, never the registry.
pub(crate) struct PlanInstr {
    /// Tiles claimed off the shared cursor, one counter per worker slot.
    tiles: Vec<Counter>,
    /// One whole `run_tasks` sweep (a step for unfused families, a
    /// fused batch for `tf_*`).
    sweep: Histogram,
}

impl PlanInstr {
    fn register(reg: &Registry, family: &'static str, slots: usize) -> PlanInstr {
        // Record the row-kernel dispatch decision alongside the plan:
        // one gauge for the lane width, one counter per ISA. Both are
        // registered here — at plan build, never on the steady-state
        // step — so the instrumented hot loop stays allocation-free.
        let kern = super::simd::active();
        reg.gauge(
            "hostencil_simd_width",
            "Lane width of the dispatched SIMD row kernel (1 = scalar).",
        )
        .set(kern.lanes as i64);
        reg.counter_with(
            "hostencil_simd_dispatch_total",
            "Row-kernel dispatch decisions recorded at plan build, by ISA.",
            &[("isa", kern.isa.name())],
        )
        .inc();
        let tiles = (0..slots)
            .map(|i| {
                let slot = i.to_string();
                reg.counter_with(
                    "hostencil_tiles_claimed_total",
                    "Tile tasks claimed by each worker slot.",
                    &[("family", family), ("slot", &slot)],
                )
            })
            .collect();
        let sweep = reg.histogram_with(
            "hostencil_step_latency_seconds",
            "Latency of one tile sweep: a single step for unfused families, \
             a whole fused batch for tf_*.",
            &LATENCY_BOUNDS,
            &[("family", family)],
        );
        PlanInstr { tiles, sweep }
    }
}

impl<S> Plan<S> {
    /// Return the cached plan, rebuilding it if the key changed. A
    /// rebuild re-tiles and re-sizes scratch, but the old pool's
    /// parked threads are recycled whenever the resolved worker count
    /// is unchanged — a domain switch must not pay a respawn.
    ///
    /// `family` labels this plan's metric series; when `telemetry` is
    /// present, builds/rebuilds are counted and logged to the flight
    /// recorder, and the plan's instrumentation handles (tile-claim
    /// counters, sweep-latency histogram) are registered here — never
    /// on the steady-state path.
    pub(crate) fn ensure<'a>(
        slot: &'a mut Option<Plan<S>>,
        domain: &Domain,
        threads: usize,
        family: &'static str,
        telemetry: Option<&Registry>,
        tile: impl FnOnce(&Domain) -> Vec<Region>,
        mk_scratch: impl Fn(&[Region]) -> S + Sync,
    ) -> &'a mut Plan<S>
    where
        S: Send,
    {
        let stale = match slot {
            Some(p) => p.domain != *domain || p.threads != threads,
            None => true,
        };
        if stale {
            let rebuild = slot.is_some();
            // retire the old plan *first*: its task list and per-worker
            // scratch (which the fused family sizes in whole wavefield
            // bricks) must not coexist with the replacement, and a
            // wrong-sized pool should join its threads before the new
            // one spawns
            let old_pool = slot.take().and_then(|old| old.pool);
            let tasks = tile(domain);
            let workers = resolve_threads(threads, tasks.len());
            let mut pool = match old_pool {
                Some(old) if workers > 1 && old.workers() == workers => Some(old),
                other => {
                    drop(other);
                    if workers > 1 {
                        Some(WorkerPool::new(workers))
                    } else {
                        None
                    }
                }
            };
            // NUMA-aware first-touch placement: each worker slot's
            // scratch (streaming rings, semi partial rows, fused
            // wavefield bricks) is constructed — and its pages first
            // written — *on the thread that owns the slot*, so on
            // first-touch kernels the backing pages land on the
            // worker's node. Slot 0 is the caller, matching the slot-0
            // role in every subsequent sweep; the serial path
            // constructs inline exactly as before.
            let scratch: Vec<S> = match pool.as_mut() {
                Some(pool) => {
                    let mut slots: Vec<Option<S>> = (0..workers).map(|_| None).collect();
                    {
                        let shared = SharedScratch::new(&mut slots);
                        pool.run(&|slot| {
                            // SAFETY: each pool slot index runs on
                            // exactly one thread per `run`, so slots
                            // never alias.
                            *unsafe { shared.slot(slot) } = Some(mk_scratch(&tasks));
                        });
                    }
                    slots
                        .into_iter()
                        .map(|s| s.expect("every pool slot initializes its scratch"))
                        .collect()
                }
                None => (0..workers).map(|_| mk_scratch(&tasks)).collect(),
            };
            if let Some(reg) = telemetry {
                let name = if rebuild {
                    "hostencil_plan_rebuilds_total"
                } else {
                    "hostencil_plan_builds_total"
                };
                let help = if rebuild {
                    "Plan rebuilds after a (domain, threads) key change."
                } else {
                    "First-use plan builds per propagator family."
                };
                reg.counter_with(name, help, &[("family", family)]).inc();
                reg.events().emit(
                    "plan_build",
                    &[
                        ("family", Json::Str(family.to_string())),
                        ("rebuild", Json::Bool(rebuild)),
                        ("tasks", Json::Num(tasks.len() as f64)),
                        ("workers", Json::Num(workers as f64)),
                    ],
                );
            }
            *slot = Some(Plan { domain: *domain, threads, tasks, scratch, pool, instr: None });
        }
        let plan = slot.as_mut().expect("plan just ensured");
        if plan.instr.is_none() {
            if let Some(reg) = telemetry {
                plan.instr = Some(PlanInstr::register(reg, family, plan.scratch.len()));
                if let Some(pool) = &plan.pool {
                    pool.register_telemetry(reg);
                }
            }
        }
        plan
    }

    /// Fan the plan's tile tasks over its worker slots, each task
    /// writing its rows of `out` in place through `f`. With a single
    /// worker slot the tasks run serially on the caller's thread — no
    /// pool, no synchronization. With more, the persistent pool
    /// executes the step: the caller's thread is slot 0, the parked
    /// workers take slots 1.., every slot claims tiles off a shared
    /// atomic cursor (the same idiom as the campaign runner) and owns
    /// its scratch entry. Tiles partition the interior, so the result
    /// is scheduling-independent, and steady-state calls allocate
    /// nothing and spawn nothing on either path.
    pub(crate) fn run_into(
        &mut self,
        out: &mut Field3,
        f: impl Fn(&Region, &mut S, &SharedOut) + Sync,
    ) where
        S: Send,
    {
        let shared = SharedOut::new(out);
        self.run_tasks(|t, s| f(t, s, &shared));
    }

    /// [`Plan::run_into`] without the single-output plumbing: fan the
    /// tile tasks over the worker slots with each task borrowing its
    /// scratch entry. The fused family uses this directly because its
    /// tasks write *two* output buffers (next u and next um) through
    /// their own [`SharedOut`] handles.
    pub(crate) fn run_tasks(&mut self, f: impl Fn(&Region, &mut S) + Sync)
    where
        S: Send,
    {
        // RAII sweep timer: observes into the per-family latency
        // histogram when this call returns (pre-registered handle —
        // cloning it is an Arc bump, no allocation)
        let _sweep = self.instr.as_ref().map(|i| i.sweep.time());
        if self.scratch.len() <= 1 {
            let s = self.scratch.first_mut().expect("plan always has >= 1 worker slot");
            for t in &self.tasks {
                f(t, &mut *s);
            }
            if let Some(instr) = &self.instr {
                instr.tiles[0].add(self.tasks.len() as u64);
            }
            return;
        }
        let tasks = &self.tasks;
        let instr = self.instr.as_ref();
        let cursor = AtomicUsize::new(0);
        let scratch = SharedScratch::new(&mut self.scratch);
        let pool = self.pool.as_mut().expect("multi-worker plans always carry a pool");
        // Release-mode check of the invariant the unsafe slot access
        // below rides on: every pool slot index (0..workers) must map
        // to exactly one scratch entry. `Plan::ensure` maintains this
        // through every rebuild/recycle path; verify it locally so a
        // future drift becomes a panic, not out-of-bounds UB.
        assert_eq!(
            pool.workers(),
            scratch.len,
            "pool worker slots and scratch slots diverged"
        );
        pool.run(&|slot| {
            // SAFETY: every slot index is claimed by exactly one
            // thread per step (the caller is 0, parked workers 1..),
            // so slots never alias.
            let s = unsafe { scratch.slot(slot) };
            let mut claimed = 0u64;
            loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= tasks.len() {
                    break;
                }
                f(&tasks[i], &mut *s);
                claimed += 1;
            }
            // one atomic add per slot per sweep, not per tile
            if let Some(instr) = instr {
                instr.tiles[slot].add(claimed);
            }
        });
    }
}

/// Raw shared handle to the plan's per-worker scratch slots, for the
/// pooled fan-out: each pool slot index owns exactly one entry, so
/// workers take disjoint `&mut S` without locking.
struct SharedScratch<S> {
    ptr: *mut S,
    len: usize,
}

unsafe impl<S: Send> Sync for SharedScratch<S> {}

impl<S> SharedScratch<S> {
    fn new(slots: &mut [S]) -> SharedScratch<S> {
        SharedScratch { ptr: slots.as_mut_ptr(), len: slots.len() }
    }

    /// SAFETY: the caller must guarantee no two threads use the same
    /// slot index concurrently.
    #[allow(clippy::mut_from_ref)] // the whole point: disjoint &mut slots across workers
    unsafe fn slot(&self, i: usize) -> &mut S {
        debug_assert!(i < self.len);
        &mut *self.ptr.add(i)
    }
}

/// A zeroed f32 buffer whose pages are actually *written*, not just
/// reserved: `vec![0.0; n]` lowers to `alloc_zeroed`, which on Linux
/// returns copy-on-write zero pages that fault in on first use — on
/// whichever thread that happens to be. Writing every element here
/// makes the constructing thread the first toucher, which is what pins
/// scratch pages to a worker's NUMA node when [`Plan::ensure`] builds
/// scratch on the owning worker's thread. Scratch constructors
/// (streaming rings, semi partial rows, fused bricks) must use this
/// instead of `vec![0.0; n]`.
#[allow(clippy::slow_vector_initialization)] // deliberate: resize *writes* pages, vec![] callocs
pub(crate) fn first_touch_zeros(n: usize) -> Vec<f32> {
    let mut v = Vec::with_capacity(n);
    v.resize(n, 0.0);
    v
}

fn resolve_threads(requested: usize, tasks: usize) -> usize {
    let n = if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    };
    n.min(tasks).max(1)
}

/// Raw shared handle to the padded output buffer, for disjoint in-place
/// tile writes from the worker fan-out.
///
/// Safety contract: the tile task lists handed to [`Plan::run_into`]
/// partition the interior (asserted by `grid::decompose`/`Region::split`
/// tests), and every kernel touches only the rows of its own tile, so
/// concurrently outstanding segments never alias.
pub(crate) struct SharedOut {
    ptr: *mut f32,
    dims: Dim3,
    len: usize,
}

unsafe impl Send for SharedOut {}
unsafe impl Sync for SharedOut {}

impl SharedOut {
    pub(crate) fn new(f: &mut Field3) -> SharedOut {
        let dims = f.dims();
        let s = f.as_mut_slice();
        SharedOut { ptr: s.as_mut_ptr(), dims, len: s.len() }
    }

    #[inline(always)]
    fn base(&self, z: usize, y: usize, x: usize) -> usize {
        debug_assert!(z < self.dims.z && y < self.dims.y && x < self.dims.x);
        (z * self.dims.y + y) * self.dims.x + x
    }

    /// Mutable contiguous x-run of `len` points at padded `(z, y, x)`.
    ///
    /// SAFETY: the caller must guarantee no concurrently outstanding
    /// segment overlaps this one (tiles partition the interior).
    #[allow(clippy::mut_from_ref)] // the whole point: disjoint &mut rows across workers
    #[inline(always)]
    pub(crate) unsafe fn seg_mut(&self, z: usize, y: usize, x: usize, len: usize) -> &mut [f32] {
        let b = self.base(z, y, x);
        debug_assert!(x + len <= self.dims.x && b + len <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(b), len)
    }

    /// Read one value (the leapfrog um term of a point this task owns).
    ///
    /// SAFETY: only the owning task may touch this point.
    #[inline(always)]
    pub(crate) unsafe fn read(&self, z: usize, y: usize, x: usize) -> f32 {
        *self.ptr.add(self.base(z, y, x))
    }

    /// Write one value of a point this task owns.
    ///
    /// SAFETY: only the owning task may touch this point.
    #[inline(always)]
    pub(crate) unsafe fn write(&self, z: usize, y: usize, x: usize, v: f32) {
        *self.ptr.add(self.base(z, y, x)) = v;
    }
}

/// Walk an inner tile row by row through the vectorizable fused row
/// kernel, updating the tile's rows of the padded output in place.
pub(crate) fn inner_tile_into(inp: &PropagatorInputs<'_>, t: &Region, k: Consts, out: &SharedOut) {
    let u = inp.u_pad.view();
    let v = inp.v.view();
    for dz in 0..t.shape.z {
        for dy in 0..t.shape.y {
            let (iz, iy) = (t.offset.z + dz, t.offset.y + dy);
            // SAFETY: tiles partition the interior; this row segment
            // belongs exclusively to the current task.
            let row = unsafe { out.seg_mut(iz + R, iy + R, t.offset.x + R, t.shape.x) };
            inner_row(u, v, iz, iy, t.offset.x, t.shape.x, k, row);
        }
    }
}

/// Walk a PML tile row by row (shared by every family: the paper's PML
/// kernels differ only in eta staging, which has no CPU cache analog
/// beyond tiling).
pub(crate) fn pml_tile_into(inp: &PropagatorInputs<'_>, t: &Region, k: Consts, out: &SharedOut) {
    let u = inp.u_pad.view();
    let v = inp.v.view();
    let e = inp.eta_pad.view();
    for dz in 0..t.shape.z {
        for dy in 0..t.shape.y {
            let (iz, iy) = (t.offset.z + dz, t.offset.y + dy);
            // SAFETY: tiles partition the interior; this row segment
            // belongs exclusively to the current task.
            let row = unsafe { out.seg_mut(iz + R, iy + R, t.offset.x + R, t.shape.x) };
            pml_row(u, v, e, iz, iy, t.offset.x, t.shape.x, k, row);
        }
    }
}

/// The reference shape: one task per decomposition region, per-point
/// global-memory walk — exactly the golden propagator's code shape,
/// parallelized over the seven regions.
#[derive(Default)]
pub struct Naive {
    plan: Option<Plan<()>>,
}

impl Naive {
    pub fn new() -> Naive {
        Naive::default()
    }
}

impl Propagator for Naive {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn signature(&self) -> String {
        "naive".to_string()
    }

    fn step_into(&mut self, inp: &PropagatorInputs<'_>, out: &mut Field3) {
        debug_assert_eq!(out.dims(), inp.domain.padded());
        let k = Consts::of(inp.domain);
        let plan = Plan::ensure(
            &mut self.plan,
            inp.domain,
            inp.threads,
            "naive",
            inp.telemetry,
            decompose,
            |_| (),
        );
        plan.run_into(out, |t, _s, o| {
            if t.class.is_pml() {
                pml_tile_into(inp, t, k, o);
            } else {
                inner_tile_into(inp, t, k, o);
            }
        });
    }
}

/// Time `steps` in-place steps of `prop` on a synthetic point-source
/// state over `domain`, returning the best-of-`samples` full-step rate
/// after `warmup` throwaway runs (all-core tile fan-out). This is the
/// measured cost the `autotune --measured` search ranks tile shapes
/// (and fusion degrees) by: steps advance through `advance_fused` in
/// batches of the propagator's natural degree, so a fused family is
/// measured on its whole-batch sweep while unfused families take the
/// identical step-and-swap path as before (the default batch impl).
pub fn measure_steps_per_sec(
    prop: &mut dyn Propagator,
    domain: &Domain,
    steps: usize,
    warmup: usize,
    samples: usize,
) -> f64 {
    let interior = domain.interior;
    let v = Field3::full(interior, 2500.0);
    let eta_pad = crate::wave::eta_profile(domain, 2500.0).pad(R);
    let mut u_pad = Field3::zeros(domain.padded());
    u_pad.set(R + interior.z / 2, R + interior.y / 2, R + interior.x / 2, 1.0);
    let mut um_pad = Field3::zeros(domain.padded());

    let run = |u_pad: &mut Field3, um_pad: &mut Field3, prop: &mut dyn Propagator| {
        let fuse = prop.max_fuse().max(1);
        let inp = FusedInputs { domain, v: &v, eta_pad: &eta_pad, threads: 0, telemetry: None };
        let t0 = Instant::now();
        let mut done = 0;
        while done < steps {
            let b = fuse.min(steps - done);
            prop.advance_fused(&inp, u_pad, um_pad, &SourceBatch::silent(b));
            done += b;
        }
        t0.elapsed()
    };
    for _ in 0..warmup {
        run(&mut u_pad, &mut um_pad, &mut *prop);
    }
    let mut best = Duration::MAX;
    for _ in 0..samples.max(1) {
        best = best.min(run(&mut u_pad, &mut um_pad, &mut *prop));
    }
    std::hint::black_box(u_pad.as_slice().first().copied());
    steps as f64 / best.as_secs_f64().max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Rng;
    use crate::wave;

    struct State {
        domain: Domain,
        u_pad: Field3,
        um_pad: Field3,
        v: Field3,
        eta_pad: Field3,
    }

    fn random_state(interior: Dim3, pml: usize, seed: u64) -> State {
        let domain = Domain::new(interior, pml, 10.0, 1e-3).unwrap();
        let mut rng = Rng::new(seed);
        State {
            domain,
            u_pad: rng.field(interior).pad(R),
            um_pad: rng.field(interior).pad(R),
            v: rng.field_in(interior, 1500.0, 3500.0),
            eta_pad: wave::eta_profile(&domain, 3500.0).pad(R),
        }
    }

    fn step_with(st: &State, name: &str, threads: usize) -> Field3 {
        let mut prop = build(name).unwrap();
        let mut out = st.um_pad.clone();
        prop.step_into(
            &PropagatorInputs {
                domain: &st.domain,
                u_pad: &st.u_pad,
                v: &st.v,
                eta_pad: &st.eta_pad,
                threads,
                telemetry: None,
            },
            &mut out,
        );
        out
    }

    #[test]
    fn factory_resolves_names_families_and_ids() {
        assert_eq!(build("naive").unwrap().name(), "naive");
        assert_eq!(build("golden").unwrap().name(), "naive");
        assert_eq!(build("gmem").unwrap().name(), "blocked3d");
        assert_eq!(build("smem_u").unwrap().name(), "blocked3d");
        assert_eq!(build("semi").unwrap().name(), "semi_stencil");
        assert_eq!(build("st_smem_8x8").unwrap().name(), "streaming2.5d");
        assert_eq!(build("st_reg_fixed").unwrap().name(), "streaming2.5d");
        assert!(build("warp_specialized").is_err());
    }

    #[test]
    fn signatures_group_physics_equivalent_variants() {
        // same kind + tile dims -> same physics -> shared campaign run
        assert_eq!(signature("gmem_8x8x8").unwrap(), signature("smem_u").unwrap());
        assert_eq!(
            signature("st_smem_16x16").unwrap(),
            signature("st_reg_shft_16x16").unwrap()
        );
        assert_ne!(signature("gmem_8x8x8").unwrap(), signature("gmem_16x16x4").unwrap());
        assert_ne!(signature("naive").unwrap(), signature("gmem_8x8x8").unwrap());
        assert_ne!(signature("semi").unwrap(), signature("gmem_8x8x8").unwrap());
    }

    #[test]
    fn bench_matrix_entries_all_build_with_unique_labels() {
        let m = bench_matrix();
        for (label, variant) in &m {
            build(variant).unwrap_or_else(|e| panic!("{label}: {e}"));
        }
        let mut labels: Vec<_> = m.iter().map(|(l, _)| *l).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), m.len(), "bench labels must be unique");
    }

    #[test]
    fn tiled_and_streaming_shapes_are_bit_identical_to_naive() {
        // non-tile-aligned extents on purpose: 13x11x17 with 8^3 /
        // 16x16x4 / 32x32x1 tiles exercises every clipping path
        let st = random_state(Dim3::new(13, 11, 17), 3, 0xC0FFEE);
        let base = step_with(&st, "naive", 1);
        assert!(base.max_abs() > 0.0);
        for name in [
            "gmem_8x8x8",
            "gmem_32x32x1",
            "gmem_16x16x4",
            "smem_u",
            "st_smem_8x8",
            "st_reg_fixed_32x32",
        ] {
            for threads in [1, 3] {
                let got = step_with(&st, name, threads);
                assert_eq!(
                    got.max_abs_diff(&base),
                    0.0,
                    "{name} with {threads} threads deviated from naive"
                );
            }
        }
    }

    #[test]
    fn semi_stencil_matches_naive_to_ulp_level() {
        let st = random_state(Dim3::new(12, 14, 13), 3, 0xBEEF);
        let base = step_with(&st, "naive", 1);
        for threads in [1, 2] {
            let got = step_with(&st, "semi", threads);
            let rel = got.max_abs_diff(&base) / base.max_abs().max(1e-30);
            assert!(rel < 1e-5, "semi re-association drifted: rel {rel}");
        }
    }

    #[test]
    fn ghost_ring_stays_zero() {
        let st = random_state(Dim3::new(11, 9, 13), 2, 7);
        for name in ["naive", "gmem_8x8x8", "st_smem_8x8", "semi"] {
            let out = step_with(&st, name, 2);
            let d = out.dims();
            assert_eq!(out.get(0, 0, 0), 0.0, "{name}");
            assert_eq!(out.get(d.z - 1, d.y - 1, d.x - 1), 0.0, "{name}");
            assert_eq!(out.unpad(R).pad(R), out, "{name}: ghost must be zero");
        }
    }

    #[test]
    fn cached_plans_survive_repeated_steps_and_domain_changes() {
        // a reused propagator must match fresh ones step for step, and
        // re-prepare cleanly when the domain (or thread count) changes
        for name in ["naive", "gmem_8x8x8", "st_smem_8x8", "semi"] {
            let mut reused = build(name).unwrap();
            let step_reused = |p: &mut Box<dyn Propagator>, st: &State, threads: usize| {
                let mut out = st.um_pad.clone();
                p.step_into(
                    &PropagatorInputs {
                        domain: &st.domain,
                        u_pad: &st.u_pad,
                        v: &st.v,
                        eta_pad: &st.eta_pad,
                        threads,
                        telemetry: None,
                    },
                    &mut out,
                );
                out
            };
            let a = random_state(Dim3::new(13, 11, 17), 3, 1);
            let b = random_state(Dim3::new(9, 15, 12), 2, 2);
            for st in [&a, &b, &a] {
                for threads in [1, 2] {
                    let got = step_reused(&mut reused, st, threads);
                    let fresh = step_with(st, name, threads);
                    assert_eq!(
                        got.max_abs_diff(&fresh),
                        0.0,
                        "{name}: stale plan after domain/thread change"
                    );
                }
            }
        }
    }

    #[test]
    fn in_place_step_reads_um_from_the_output_buffer() {
        // two different um buffers must give two different results —
        // i.e. the kernel really consumes what `out` held on entry
        let st = random_state(Dim3::new(10, 9, 11), 2, 42);
        let a = step_with(&st, "naive", 1);
        let padded = st.domain.padded();
        let st2 = State { um_pad: Field3::zeros(padded), ..st };
        let b = step_with(&st2, "naive", 1);
        assert!(a.max_abs_diff(&b) > 0.0, "um term ignored");
    }

    #[test]
    fn measured_rate_is_positive_and_finite() {
        let domain = Domain::new(Dim3::new(12, 12, 12), 3, 10.0, 1e-3).unwrap();
        let mut prop = build("gmem_8x8x8").unwrap();
        let sps = measure_steps_per_sec(prop.as_mut(), &domain, 2, 0, 1);
        assert!(sps > 0.0 && sps.is_finite());
    }
}
