//! Temporally fused propagator: the fifth code-shape family.
//!
//! PRs 3–4 made every step zero-alloc and spawn-free, which left the
//! 25-point kernel memory-bandwidth-bound: each leapfrog step still
//! streams the full wavefield through the memory hierarchy once.
//! [`TimeFused`] breaks that ceiling with *temporal blocking* — the
//! deep-pipeline idea of Zohouri et al. (FPGA OpenCL stencils) and the
//! skewed wavefronts of Jacquelin et al., expressed as overlapped
//! (redundant-halo) tiles: one sweep advances the interior `s`
//! leapfrog steps, so u/um/v/eta stream through memory once per `s`
//! steps instead of once per step.
//!
//! ## The overlapped-tile trapezoid
//!
//! The interior's (z, y) plane is tiled `tile_z x tile_y` with full x
//! rows (x is the contiguous axis and is never tiled — same convention
//! as [`super::streaming::Streaming25D`]). To advance a tile `T` by
//! `n` steps in one visit, sub-step `j` (1-based) computes `T` plus an
//! `(n-j)*R` skirt: the skirt values are *redundantly recomputed* —
//! every tile derives its own copy of the halo its later sub-steps
//! need, so tiles stay fully independent within a batch and the
//! parallel fan-out needs no cross-tile synchronization. Each worker
//! stages its tile's working set — `u(n0)` and `u(n0-1)` plus the
//! static `v`/`eta` — in per-worker scratch planned once per (domain,
//! threads): the CPU materialization of the `(2R+1) + s`-deep plane
//! ring a fused GPU kernel would stream through shared memory (on the
//! CPU the x-stream is already register/L1-resident, so the ring is
//! kept resident as one brick and the two time levels ping-pong in
//! place through the same fused row kernels as every other family).
//!
//! ## Bit-identical physics
//!
//! Golden equivalence survives fusion because nothing about the
//! per-point arithmetic changes:
//! * every computed point applies its *own* region's update —
//!   [`row_segments`] splits each x-row into PML / inner / PML exactly
//!   along the 7-region decomposition's boundaries, so skirt points in
//!   the PML sponge step through [`super::pml_row`] and inner points
//!   through [`super::inner_row`], in the golden arithmetic order;
//! * per-step source injection lands *between* virtual sub-steps: the
//!   coordinator hands the whole batch's amplitude schedule down via
//!   [`SourceBatch`], and each tile injects into any computed point
//!   that matches a source position, in coordinator order;
//! * out-of-interior neighbors read the local zero frame — the same
//!   Dirichlet ghost the padded global arrays carry.
//! The equivalence suite asserts `tf_s2`/`tf_s4` are bit-identical to
//! the golden oracle on odd grids with multi-source injection.
//!
//! ## Buffer protocol
//!
//! A fused batch cannot write into the buffers it reads: a tile's
//! skirt overlaps its neighbors' cores, so in-place output would
//! clobber inputs of concurrently (or later) executed tiles. The
//! family therefore owns a second persistent padded buffer pair:
//! tiles write `u(n0+n)` / `u(n0+n-1)` of their core into it, and the
//! pairs O(1)-swap with the caller's buffers after the sweep — the
//! steady state allocates nothing (`rust/tests/zero_alloc.rs` covers
//! `tf_*` at threads 1 and 3). This is why temporal fusion changes the
//! `Propagator` contract itself: `advance_fused` takes both wavefield
//! buffers `&mut` and a per-batch injection schedule, and the
//! coordinator hands the family whole step batches between observer
//! callbacks.

use super::propagator::{
    first_touch_zeros, FusedInputs, Plan, Propagator, PropagatorInputs, SharedOut, SourceBatch,
};
use super::{inner_row, pml_row, simd, Consts};
use crate::gpusim::kernels::KernelVariant;
use crate::grid::{Dim3, Domain, Field3, FieldView, Region, RegionClass};
use crate::telemetry::{Counter, Registry};
use crate::R;

/// Per-worker staging for one tile's fused batch: two time-level
/// bricks (`ua`/`ub`, R-framed like the global padded arrays), the
/// damping profile (`ee`, R-framed) and the velocity model (`vv`,
/// frameless) over the tile-plus-skirt extent. Allocated once in the
/// plan at the worst-case (tile + 2sR, clipped) extent; every batch
/// re-slices it.
pub(crate) struct FusedScratch {
    ua: Vec<f32>,
    ub: Vec<f32>,
    ee: Vec<f32>,
    vv: Vec<f32>,
}

impl FusedScratch {
    fn for_domain(d: &Domain, s: usize, tile_z: usize, tile_y: usize) -> FusedScratch {
        let ni = d.interior;
        let skirt = s.max(1) * R;
        let ez = (tile_z + 2 * skirt).min(ni.z);
        let ey = (tile_y + 2 * skirt).min(ni.y);
        let dp = Dim3::new(ez, ey, ni.x).padded(R).volume();
        let de = ez * ey * ni.x;
        // first-touch: this ctor runs on the owning worker's thread
        // (Plan::ensure routes scratch construction through the pool),
        // so writing every element places the brick's pages on that
        // worker's NUMA node
        FusedScratch {
            ua: first_touch_zeros(dp),
            ub: first_touch_zeros(dp),
            ee: first_touch_zeros(dp),
            vv: first_touch_zeros(de),
        }
    }
}

/// Temporal blocking: advance the interior `s` leapfrog steps per
/// memory sweep with overlapped (z, y) tiles.
pub struct TimeFused {
    /// Fusion degree: leapfrog steps per sweep (>= 1; the factory only
    /// builds degrees >= 2 — degree 1 belongs to `Streaming25D`).
    pub s: usize,
    /// Plane-tile extents: `tile_z` tiles z, `tile_y` tiles y; x rows
    /// stay whole.
    pub tile_z: usize,
    pub tile_y: usize,
    plan: Option<Plan<FusedScratch>>,
    /// Persistent output pair for the fused sweep (swapped with the
    /// caller's buffers after each batch); rebuilt only on a domain
    /// change.
    next: Option<(Field3, Field3)>,
    /// Skirt-recompute overhead counter (points computed beyond the
    /// tile cores per sweep), registered once when telemetry attaches.
    skirt: Option<Counter>,
}

impl TimeFused {
    pub fn new(s: usize, tile_z: usize, tile_y: usize) -> TimeFused {
        TimeFused {
            s: s.max(1),
            tile_z: tile_z.max(1),
            tile_y: tile_y.max(1),
            plan: None,
            next: None,
            skirt: None,
        }
    }

    pub fn from_variant(v: &KernelVariant) -> TimeFused {
        TimeFused::new(v.fuse as usize, v.d1 as usize, v.d2 as usize)
    }
}

/// Build (or fetch) the cached fused plan for `slot`: (z, y) tiles
/// over the whole interior with full x rows — the fused family
/// classifies per point instead of tiling the 7 regions separately,
/// because its skirts cross region boundaries anyway. A free function
/// over the plan slot (not `&mut self`) so `advance_fused` can hold
/// the plan and the output-pair field at the same time.
fn ensure_plan<'a>(
    slot: &'a mut Option<Plan<FusedScratch>>,
    domain: &Domain,
    threads: usize,
    telemetry: Option<&Registry>,
    s: usize,
    tz: usize,
    ty: usize,
) -> &'a mut Plan<FusedScratch> {
    let d = *domain;
    Plan::ensure(
        slot,
        domain,
        threads,
        "time_fused",
        telemetry,
        |d| {
            let whole = Region {
                name: "interior",
                class: RegionClass::Inner,
                offset: Dim3::new(0, 0, 0),
                shape: d.interior,
            };
            whole.split(Dim3::new(tz, ty, d.interior.x))
        },
        move |_| FusedScratch::for_domain(&d, s, tz, ty),
    )
}

impl Propagator for TimeFused {
    fn name(&self) -> &'static str {
        "time_fused"
    }

    fn signature(&self) -> String {
        format!("time_fused:s{}:{}x{}:{}", self.s, self.tile_z, self.tile_y, simd::detected().tag())
    }

    /// Single-step path: the classification-split row walk over the
    /// global buffers, in place — no skirt, no staging. Used by plain
    /// `Coordinator::step()` and as the tail of odd-length runs; bit-
    /// identical to the golden walk.
    fn step_into(&mut self, inp: &PropagatorInputs<'_>, out: &mut Field3) {
        debug_assert_eq!(out.dims(), inp.domain.padded());
        let k = Consts::of(inp.domain).with_kernel(simd::active());
        let plan = ensure_plan(
            &mut self.plan,
            inp.domain,
            inp.threads,
            inp.telemetry,
            self.s,
            self.tile_z,
            self.tile_y,
        );
        plan.run_into(out, |t, _scr, o| direct_tile_into(inp, t, k, o));
    }

    fn max_fuse(&self) -> usize {
        self.s
    }

    /// The fused sweep: every tile advances `batch.n_steps` virtual
    /// sub-steps locally (trapezoid skirts, per-sub-step injection)
    /// and writes its core's two newest time levels into the
    /// persistent output pair, which then O(1)-swaps with the caller's
    /// buffers.
    fn advance_fused(
        &mut self,
        inp: &FusedInputs<'_>,
        u_pad: &mut Field3,
        um_pad: &mut Field3,
        batch: &SourceBatch<'_>,
    ) {
        let n = batch.n_steps;
        if n == 0 {
            return;
        }
        if n == 1 {
            // tail batch: direct in-place step + rotate + inject,
            // exactly the trait's default path
            self.step_into(
                &PropagatorInputs {
                    domain: inp.domain,
                    u_pad,
                    v: inp.v,
                    eta_pad: inp.eta_pad,
                    threads: inp.threads,
                    telemetry: inp.telemetry,
                },
                um_pad,
            );
            std::mem::swap(u_pad, um_pad);
            for (i, p) in batch.positions.iter().enumerate() {
                u_pad.add(R + p.z, R + p.y, R + p.x, batch.amp(0, i));
            }
            return;
        }
        assert!(n <= self.s, "batch of {n} steps exceeds this family's fusion degree {}", self.s);
        debug_assert_eq!(u_pad.dims(), inp.domain.padded());
        debug_assert_eq!(um_pad.dims(), inp.domain.padded());
        let k = Consts::of(inp.domain).with_kernel(simd::active());
        let domain = *inp.domain;
        let padded = inp.domain.padded();
        if self.next.as_ref().map(|(a, _)| a.dims()) != Some(padded) {
            // Field3::zeros is calloc-backed (pages untouched until
            // written); each worker's core copy-out below is the first
            // write, so the output pair's pages land on the node of
            // the worker that owns each tile — first-touch for free.
            self.next = Some((Field3::zeros(padded), Field3::zeros(padded)));
        }
        if self.skirt.is_none() {
            if let Some(reg) = inp.telemetry {
                let sv = self.s.to_string();
                self.skirt = Some(reg.counter_with(
                    "hostencil_fused_skirt_points_total",
                    "Redundantly recomputed trapezoid-skirt points in fused sweeps \
                     (computed points beyond the tile cores).",
                    &[("s", &sv)],
                ));
            }
        }
        let skirt_counter = self.skirt.clone();
        let plan = ensure_plan(
            &mut self.plan,
            inp.domain,
            inp.threads,
            inp.telemetry,
            self.s,
            self.tile_z,
            self.tile_y,
        );
        let (next_u, next_um) = self.next.as_mut().expect("just ensured");
        {
            let out_u = SharedOut::new(next_u);
            let out_um = SharedOut::new(next_um);
            let u = u_pad.view();
            let um = um_pad.view();
            let v = inp.v.view();
            let eta = inp.eta_pad.view();
            plan.run_tasks(|t, scr| {
                let extra =
                    fused_tile_batch(&domain, u, um, v, eta, t, n, k, batch, scr, &out_u, &out_um);
                if let Some(c) = &skirt_counter {
                    // one atomic add per tile per sweep (Counter is Sync)
                    c.add(extra);
                }
            });
        }
        std::mem::swap(u_pad, next_u);
        std::mem::swap(um_pad, next_um);
    }
}

/// The up-to-three x-segments of interior row `(gz, gy)` with their
/// region class: `(x0, len, inner)`. Rows inside the inner z/y band
/// split into PML cap, inner core, PML cap along the exact 7-region
/// decomposition boundaries; every other row is one whole-row PML
/// segment (the two tail entries come back zero-length). Keeping this
/// split exact is what makes per-point classification bit-identical to
/// the golden region walk.
pub(crate) fn row_segments(d: &Domain, gz: usize, gy: usize) -> [(usize, usize, bool); 3] {
    let n = d.interior;
    let w = d.pml_width;
    let inner_zy = gz >= w && gz < n.z - w && gy >= w && gy < n.y - w;
    if inner_zy {
        [(0, w, false), (w, n.x - 2 * w, true), (n.x - w, w, false)]
    } else {
        [(0, n.x, false), (0, 0, false), (0, 0, false)]
    }
}

/// One tile of the single-step path: walk the tile's rows through the
/// class-split fused row kernels, updating the padded output in place.
fn direct_tile_into(inp: &PropagatorInputs<'_>, t: &Region, k: Consts, out: &SharedOut) {
    debug_assert_eq!(t.shape.x, inp.domain.interior.x, "fused tiles keep whole x rows");
    let u = inp.u_pad.view();
    let v = inp.v.view();
    let e = inp.eta_pad.view();
    for dz in 0..t.shape.z {
        for dy in 0..t.shape.y {
            let (gz, gy) = (t.offset.z + dz, t.offset.y + dy);
            for (x0, len, inner) in row_segments(inp.domain, gz, gy) {
                if len == 0 {
                    continue;
                }
                // SAFETY: tiles partition the interior; this row
                // segment belongs exclusively to the current task.
                let row = unsafe { out.seg_mut(gz + R, gy + R, x0 + R, len) };
                if inner {
                    inner_row(u, v, gz, gy, x0, len, k, row);
                } else {
                    pml_row(u, v, e, gz, gy, x0, len, k, row);
                }
            }
        }
    }
}

/// Zero the R-wide frame of a `dp`-shaped local brick (the local image
/// of the global arrays' Dirichlet ghost ring). Interior cells are the
/// loader's/kernels' responsibility — every cell a sub-step reads is
/// either framed here, loaded, or written by an earlier sub-step.
fn zero_frame(buf: &mut [f32], dp: Dim3) {
    let plane = dp.y * dp.x;
    buf[..R * plane].fill(0.0);
    buf[(dp.z - R) * plane..dp.z * plane].fill(0.0);
    for pz in R..dp.z - R {
        let base = pz * plane;
        buf[base..base + R * dp.x].fill(0.0);
        buf[base + (dp.y - R) * dp.x..base + dp.y * dp.x].fill(0.0);
        for py in R..dp.y - R {
            let rb = base + py * dp.x;
            buf[rb..rb + R].fill(0.0);
            buf[rb + dp.x - R..rb + dp.x].fill(0.0);
        }
    }
}

/// Advance one tile `batch.n_steps` virtual sub-steps in per-worker
/// scratch and write its core's two newest time levels into the
/// output pair. Returns the number of redundantly recomputed skirt
/// points (computed points beyond `n` visits of the tile core) — the
/// fused family's recompute-overhead telemetry. See the module docs
/// for the trapezoid geometry; the invariants the loops below maintain
/// are:
///
/// * `E_j` (the sub-step-`j` computed box) is the tile plus an
///   `(n-j)*R` skirt, clipped to the interior;
/// * dilating `E_{j+1}` by the stencil halo `R` stays inside
///   `E_j ∪ frame`, so every neighbor a sub-step reads was computed
///   one sub-step earlier (or is ghost zero);
/// * the leapfrog `um` term of sub-step `j+2` is the center value
///   written at sub-step `j`, which `E_{j+2} ⊆ E_j` guarantees.
#[allow(clippy::too_many_arguments)] // mirrors the kernel ABI: fields + tile + batch + outputs
fn fused_tile_batch(
    d: &Domain,
    u: FieldView<'_>,
    um: FieldView<'_>,
    v: FieldView<'_>,
    eta: FieldView<'_>,
    t: &Region,
    n: usize,
    k: Consts,
    batch: &SourceBatch<'_>,
    scr: &mut FusedScratch,
    out_u: &SharedOut,
    out_um: &SharedOut,
) -> u64 {
    let ni = d.interior;
    let nx = ni.x;
    debug_assert_eq!(t.shape.x, nx, "fused tiles keep whole x rows");
    let skirt = n * R;
    // E_c: the loaded extent — tile plus the full n*R skirt, clipped.
    let z0e = t.offset.z.saturating_sub(skirt);
    let z1e = (t.offset.z + t.shape.z + skirt).min(ni.z);
    let y0e = t.offset.y.saturating_sub(skirt);
    let y1e = (t.offset.y + t.shape.y + skirt).min(ni.y);
    let de = Dim3::new(z1e - z0e, y1e - y0e, nx);
    let dp = de.padded(R);

    // take the two time-level bricks out of the scratch so they can
    // ping-pong by O(1) Vec swap (no allocation: take leaves an empty
    // Vec, and both are restored below)
    let mut ua = std::mem::take(&mut scr.ua);
    let mut ub = std::mem::take(&mut scr.ub);
    let ee = &mut scr.ee[..dp.volume()];
    let vv = &mut scr.vv[..de.volume()];
    zero_frame(&mut ua[..dp.volume()], dp);
    zero_frame(&mut ub[..dp.volume()], dp);
    zero_frame(ee, dp);

    let lrow = |lz: usize, ly: usize, x: usize| (lz * dp.y + ly) * dp.x + x;
    // load u + eta over all of E_c (sub-step 1 reads the full skirt)
    for lz in 0..de.z {
        let gz = z0e + lz;
        for ly in 0..de.y {
            let gy = y0e + ly;
            let dst = lrow(R + lz, R + ly, R);
            ua[dst..dst + nx].copy_from_slice(u.seg(gz + R, gy + R, R, nx));
            ee[dst..dst + nx].copy_from_slice(eta.seg(gz + R, gy + R, R, nx));
        }
    }
    // um + v only feed computed points, so their load stops at E_1
    // (the (n-1)*R skirt)
    let s1 = skirt - R;
    let z0a = t.offset.z.saturating_sub(s1);
    let z1a = (t.offset.z + t.shape.z + s1).min(ni.z);
    let y0a = t.offset.y.saturating_sub(s1);
    let y1a = (t.offset.y + t.shape.y + s1).min(ni.y);
    for gz in z0a..z1a {
        let lz = gz - z0e;
        for gy in y0a..y1a {
            let ly = gy - y0e;
            let dst = lrow(R + lz, R + ly, R);
            ub[dst..dst + nx].copy_from_slice(um.seg(gz + R, gy + R, R, nx));
            let vdst = (lz * de.y + ly) * de.x;
            vv[vdst..vdst + nx].copy_from_slice(v.seg(gz, gy, 0, nx));
        }
    }

    // the trapezoid: ua holds the newest computed level, ub the one
    // before it (and, on entry to each sub-step, the row kernels'
    // in-place um term)
    let mut computed: u64 = 0;
    for j in 1..=n {
        let sk = (n - j) * R;
        let z0j = t.offset.z.saturating_sub(sk);
        let z1j = (t.offset.z + t.shape.z + sk).min(ni.z);
        let y0j = t.offset.y.saturating_sub(sk);
        let y1j = (t.offset.y + t.shape.y + sk).min(ni.y);
        computed += ((z1j - z0j) * (y1j - y0j) * nx) as u64;
        {
            let uav = FieldView::new(dp, &ua[..dp.volume()]);
            let vvv = FieldView::new(de, vv);
            let eev = FieldView::new(dp, ee);
            for gz in z0j..z1j {
                let lz = gz - z0e;
                for gy in y0j..y1j {
                    let ly = gy - y0e;
                    for (x0, len, inner) in row_segments(d, gz, gy) {
                        if len == 0 {
                            continue;
                        }
                        let b = lrow(R + lz, R + ly, R + x0);
                        let row = &mut ub[b..b + len];
                        if inner {
                            inner_row(uav, vvv, lz, ly, x0, len, k, row);
                        } else {
                            pml_row(uav, vvv, eev, lz, ly, x0, len, k, row);
                        }
                    }
                }
            }
        }
        // per-sub-step source injection, in coordinator order; x is
        // always inside the (whole-row) computed extent
        for (i, p) in batch.positions.iter().enumerate() {
            if p.z >= z0j && p.z < z1j && p.y >= y0j && p.y < y1j {
                ub[lrow(R + p.z - z0e, R + p.y - y0e, R + p.x)] += batch.amp(j - 1, i);
            }
        }
        std::mem::swap(&mut ua, &mut ub);
    }

    // ua = u(n0+n) on E_n = T, ub = u(n0+n-1) on E_{n-1} ⊇ T: write
    // the core out. SAFETY: tiles partition the interior and each
    // (gz, gy) row belongs to exactly one tile, for both buffers.
    for dz in 0..t.shape.z {
        let gz = t.offset.z + dz;
        let lz = gz - z0e;
        for dy in 0..t.shape.y {
            let gy = t.offset.y + dy;
            let ly = gy - y0e;
            let src = lrow(R + lz, R + ly, R);
            unsafe {
                out_u.seg_mut(gz + R, gy + R, R, nx).copy_from_slice(&ua[src..src + nx]);
                out_um.seg_mut(gz + R, gy + R, R, nx).copy_from_slice(&ub[src..src + nx]);
            }
        }
    }
    scr.ua = ua;
    scr.ub = ub;
    computed - (n * t.shape.z * t.shape.y * nx) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::propagator::build;
    use crate::testkit::Rng;
    use crate::wave;

    struct State {
        domain: Domain,
        u_pad: Field3,
        um_pad: Field3,
        v: Field3,
        eta_pad: Field3,
    }

    fn random_state(interior: Dim3, pml: usize, seed: u64) -> State {
        let domain = Domain::new(interior, pml, 10.0, 1e-3).unwrap();
        let mut rng = Rng::new(seed);
        State {
            domain,
            u_pad: rng.field(interior).pad(R),
            um_pad: rng.field(interior).pad(R),
            v: rng.field_in(interior, 1500.0, 3500.0),
            eta_pad: wave::eta_profile(&domain, 3500.0).pad(R),
        }
    }

    fn inputs(st: &State, threads: usize) -> FusedInputs<'_> {
        FusedInputs { domain: &st.domain, v: &st.v, eta_pad: &st.eta_pad, threads, telemetry: None }
    }

    /// Sources that straddle region classes: inner center, PML corner
    /// strip, near-edge inner point.
    fn sources(interior: Dim3) -> Vec<Dim3> {
        vec![
            Dim3::new(interior.z / 2, interior.y / 2, interior.x / 2),
            Dim3::new(1, 1, 2),
            Dim3::new(interior.z - 2, interior.y - 2, interior.x - 3),
        ]
    }

    fn amps_for(n: usize, n_src: usize) -> Vec<f32> {
        (0..n * n_src)
            .map(|i| 0.01 * (i as f32 + 1.0) * if i % 2 == 0 { 1.0 } else { -1.0 })
            .collect()
    }

    /// Run `batches` through a propagator, returning (u, um).
    fn run_batches(
        prop: &mut dyn Propagator,
        st: &State,
        threads: usize,
        batches: &[usize],
        positions: &[Dim3],
    ) -> (Field3, Field3) {
        let mut u = st.u_pad.clone();
        let mut um = st.um_pad.clone();
        for &n in batches {
            let amps = amps_for(n, positions.len());
            let batch = SourceBatch { positions, amps: &amps, n_steps: n };
            prop.advance_fused(&inputs(st, threads), &mut u, &mut um, &batch);
        }
        (u, um)
    }

    #[test]
    fn fused_batches_are_bit_identical_to_stepped_golden() {
        // odd grid + degenerate tiny grid; multi-source with PML-strip
        // injection; full batches, tail batches, threads 1 and 3
        for (interior, pml, seed) in
            [(Dim3::new(13, 11, 17), 3, 0xF00D), (Dim3::new(9, 7, 11), 2, 0xBEEF)]
        {
            let st = random_state(interior, pml, seed);
            let positions = sources(interior);
            for s in [2usize, 4] {
                for threads in [1usize, 3] {
                    // 3 batches: full, full, tail — 2s+1 steps total
                    let batches = [s, s, 1];
                    let mut tf = TimeFused::new(s, 16, 16);
                    let (u_f, um_f) = run_batches(&mut tf, &st, threads, &batches, &positions);

                    // golden: the default (step + swap + inject) path
                    let mut gold = build("naive").unwrap();
                    let (u_g, um_g) = run_batches(gold.as_mut(), &st, 1, &batches, &positions);

                    assert_eq!(
                        u_f.max_abs_diff(&u_g),
                        0.0,
                        "{interior} s={s} threads={threads}: u diverged from golden"
                    );
                    assert_eq!(
                        um_f.max_abs_diff(&um_g),
                        0.0,
                        "{interior} s={s} threads={threads}: um diverged from golden"
                    );
                    assert!(u_f.max_abs() > 0.0, "wave must have propagated");
                    assert_eq!(u_f.unpad(R).pad(R), u_f, "ghost ring must stay zero");
                    assert_eq!(um_f.unpad(R).pad(R), um_f, "um ghost ring must stay zero");
                }
            }
        }
    }

    #[test]
    fn small_tiles_recompute_skirts_identically() {
        // deliberately tiny 4x4 plane tiles: deep overlapped skirts
        // cross region boundaries everywhere and must still agree
        let st = random_state(Dim3::new(15, 12, 14), 3, 0xACE5);
        let positions = sources(st.domain.interior);
        let mut tf = TimeFused::new(2, 4, 4);
        let (u_f, _) = run_batches(&mut tf, &st, 2, &[2, 2], &positions);
        let mut gold = build("naive").unwrap();
        let (u_g, _) = run_batches(gold.as_mut(), &st, 1, &[2, 2], &positions);
        assert_eq!(u_f.max_abs_diff(&u_g), 0.0, "4x4 tiles diverged");
    }

    #[test]
    fn direct_single_step_matches_naive() {
        let st = random_state(Dim3::new(13, 11, 17), 3, 0xC0DE);
        let step = |prop: &mut dyn Propagator, threads: usize| -> Field3 {
            let mut out = st.um_pad.clone();
            prop.step_into(
                &PropagatorInputs {
                    domain: &st.domain,
                    u_pad: &st.u_pad,
                    v: &st.v,
                    eta_pad: &st.eta_pad,
                    threads,
                    telemetry: None,
                },
                &mut out,
            );
            out
        };
        let mut naive = build("naive").unwrap();
        let base = step(naive.as_mut(), 1);
        for threads in [1, 2] {
            let mut tf = TimeFused::new(2, 16, 16);
            let got = step(&mut tf, threads);
            assert_eq!(got.max_abs_diff(&base), 0.0, "direct path deviated ({threads} thr)");
        }
    }

    #[test]
    fn factory_maps_degrees_onto_the_right_shapes() {
        assert_eq!(build("tf_s2").unwrap().name(), "time_fused");
        assert_eq!(build("tf_s2").unwrap().max_fuse(), 2);
        assert_eq!(build("tf_s4").unwrap().max_fuse(), 4);
        assert_eq!(build("tf").unwrap().max_fuse(), 2, "tf shorthand is tf_s2");
        // the degree-1 control is the plain streaming shape
        assert_eq!(build("tf_s1").unwrap().name(), "streaming2.5d");
        assert_eq!(build("tf_s1").unwrap().max_fuse(), 1);
        // signatures separate degrees (different physics *schedule*,
        // same physics — but fused runs observe per batch, so campaign
        // cells must not share a physics run across degrees)
        assert_ne!(build("tf_s2").unwrap().signature(), build("tf_s4").unwrap().signature());
    }

    #[test]
    fn reused_plans_survive_domain_changes_and_batch_sizes() {
        let a = random_state(Dim3::new(13, 11, 17), 3, 1);
        let b = random_state(Dim3::new(9, 15, 12), 2, 2);
        let positions_a = sources(a.domain.interior);
        let positions_b = sources(b.domain.interior);
        let mut reused = TimeFused::new(4, 16, 16);
        for (st, positions) in [(&a, &positions_a), (&b, &positions_b), (&a, &positions_a)] {
            for threads in [1usize, 2] {
                let (u_got, um_got) = run_batches(&mut reused, st, threads, &[4, 3], positions);
                let mut fresh = TimeFused::new(4, 16, 16);
                let (u_want, um_want) = run_batches(&mut fresh, st, 1, &[4, 3], positions);
                assert_eq!(u_got.max_abs_diff(&u_want), 0.0, "stale fused plan (u)");
                assert_eq!(um_got.max_abs_diff(&um_want), 0.0, "stale fused plan (um)");
            }
        }
    }

    #[test]
    fn oversized_batch_is_rejected() {
        let st = random_state(Dim3::new(11, 9, 11), 2, 3);
        let mut tf = TimeFused::new(2, 16, 16);
        let mut u = st.u_pad.clone();
        let mut um = st.um_pad.clone();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            tf.advance_fused(&inputs(&st, 1), &mut u, &mut um, &SourceBatch::silent(3));
        }));
        assert!(r.is_err(), "a batch deeper than the fusion degree must panic loudly");
    }

    #[test]
    fn row_segments_follow_the_decomposition() {
        let d = Domain::new(Dim3::new(16, 14, 12), 3, 10.0, 1e-3).unwrap();
        // PML row (outside the inner z band): one whole-row segment
        assert_eq!(row_segments(&d, 0, 7), [(0, 12, false), (0, 0, false), (0, 0, false)]);
        assert_eq!(row_segments(&d, 7, 13), [(0, 12, false), (0, 0, false), (0, 0, false)]);
        // inner row: PML cap, inner core, PML cap
        assert_eq!(row_segments(&d, 7, 7), [(0, 3, false), (3, 6, true), (9, 3, false)]);
    }
}
