//! Pure-Rust stencils: the CPU reference numerics plus the executable
//! code-shape engine.
//!
//! * The two-pass free functions (`lap8`, `step_inner`, `step_pml`, ...)
//!   are the *spec*: the same numerics as `python/compile/common.py` /
//!   `kernels/ref.py`, with arithmetic ordering mirroring the jnp
//!   reference so f32 results agree to a few ULP. They allocate per
//!   call and stay off the hot path.
//! * The fused row kernels (`inner_row`, `pml_row`) are the *hot path*:
//!   they read neighbors directly from the persistent R-ghost-padded
//!   wavefield through [`crate::grid::FieldView`] and update one
//!   contiguous x-row **in place** (the output row holds `um` on entry
//!   — the classic two-buffer leapfrog). Every neighbor run is pre-cut
//!   to the row length, so the inner loop indexes bounds-check-free and
//!   LLVM auto-vectorizes it. Per-point arithmetic ordering matches the
//!   two-pass spec exactly: results are bit-identical (asserted below).
//!   Each row dispatches on the kernel recorded in `Consts`: the scalar
//!   oracle, or the explicit-SIMD lane kernels in [`simd`] (runtime ISA
//!   dispatch behind the `simd` cargo feature) — bit-identical by
//!   construction, so the dispatch choice is purely a speed knob.
//! * [`GoldenPropagator`] drives the row kernels over the 7-region
//!   decomposition with two persistent padded buffers — the oracle the
//!   integration tests compare PJRT output against.
//! * [`propagator`] is the code-shape engine: a [`propagator::Propagator`]
//!   trait with tiled, multithreaded CPU analogs of the paper's kernel
//!   families (naive, 3D-blocked, 2.5D streaming, semi-stencil, and
//!   the temporally fused `tf_*` family that advances `s` leapfrog
//!   steps per memory sweep), so "which shape is fastest at which tile
//!   size — and at which fusion degree" is measurable on the CPU path,
//!   not just predicted by gpusim.

mod blocked;
mod fused;
mod golden;
pub mod propagator;
mod semi;
pub mod simd;
mod streaming;

pub use golden::GoldenPropagator;
pub use propagator::{FusedInputs, Propagator, PropagatorInputs, SourceBatch};

pub(crate) use fused::row_segments;

use crate::grid::{Dim3, Domain, Field3, FieldView};
use crate::{R, R_ETA};

/// 8th-order per-axis second-derivative coefficients (center, +-1..+-4).
pub const C8: [f32; 5] = [
    -205.0 / 72.0,
    8.0 / 5.0,
    -1.0 / 5.0,
    8.0 / 315.0,
    -1.0 / 560.0,
];

/// 2nd-order coefficients (center, +-1).
pub const C2: [f32; 2] = [-2.0, 1.0];

/// Largest stable leapfrog dt (mirrors `compile.common.cfl_dt`).
pub fn cfl_dt(h: f64, v_max: f64) -> f64 {
    let s: f64 = C8[0].abs() as f64 + 2.0 * C8[1..].iter().map(|c| c.abs() as f64).sum::<f64>();
    0.9 * 2.0 * h / (v_max * (3.0 * s).sqrt())
}

/// 25-point 8th-order Laplacian of an R-padded tile -> interior shape.
pub fn lap8(t: &Field3, h: f64) -> Field3 {
    let p = t.dims();
    let s = Dim3::new(p.z - 2 * R, p.y - 2 * R, p.x - 2 * R);
    let inv_h2 = (1.0 / (h * h)) as f32;
    let mut out = Field3::zeros(s);
    for z in 0..s.z {
        for y in 0..s.y {
            for x in 0..s.x {
                let (cz, cy, cx) = (z + R, y + R, x + R);
                // Mirror jnp ordering: 3*c0*core, then per-m (z+,z-,y+,y-,x+,x-).
                let mut acc = 3.0 * C8[0] * t.get(cz, cy, cx);
                for m in 1..=R {
                    acc += C8[m]
                        * (t.get(cz + m, cy, cx)
                            + t.get(cz - m, cy, cx)
                            + t.get(cz, cy + m, cx)
                            + t.get(cz, cy - m, cx)
                            + t.get(cz, cy, cx + m)
                            + t.get(cz, cy, cx - m));
                }
                out.set(z, y, x, acc * inv_h2);
            }
        }
    }
    out
}

/// 7-point 2nd-order Laplacian of a 1-padded tile -> interior shape.
pub fn lap2(t: &Field3, h: f64) -> Field3 {
    let p = t.dims();
    let s = Dim3::new(p.z - 2, p.y - 2, p.x - 2);
    let inv_h2 = (1.0 / (h * h)) as f32;
    let mut out = Field3::zeros(s);
    for z in 0..s.z {
        for y in 0..s.y {
            for x in 0..s.x {
                let (cz, cy, cx) = (z + 1, y + 1, x + 1);
                let acc = 3.0 * C2[0] * t.get(cz, cy, cx)
                    + (t.get(cz + 1, cy, cx)
                        + t.get(cz - 1, cy, cx)
                        + t.get(cz, cy + 1, cx)
                        + t.get(cz, cy - 1, cx)
                        + t.get(cz, cy, cx + 1)
                        + t.get(cz, cy, cx - 1));
                out.set(z, y, x, acc * inv_h2);
            }
        }
    }
    out
}

/// 7-point star average of eta over a 1-padded tile -> interior shape.
pub fn eta_bar(t: &Field3) -> Field3 {
    let p = t.dims();
    let s = Dim3::new(p.z - 2, p.y - 2, p.x - 2);
    let mut out = Field3::zeros(s);
    for z in 0..s.z {
        for y in 0..s.y {
            for x in 0..s.x {
                let (cz, cy, cx) = (z + 1, y + 1, x + 1);
                let acc = t.get(cz, cy, cx)
                    + t.get(cz + 1, cy, cx)
                    + t.get(cz - 1, cy, cx)
                    + t.get(cz, cy + 1, cx)
                    + t.get(cz, cy - 1, cx)
                    + t.get(cz, cy, cx + 1)
                    + t.get(cz, cy, cx - 1);
                out.set(z, y, x, acc / 7.0);
            }
        }
    }
    out
}

/// Leapfrog update for an inner-region tile: u+ = 2u - um + dt^2 v^2 lap8(u).
pub fn step_inner(u_pad: &Field3, um: &Field3, v: &Field3, dt: f64, h: f64) -> Field3 {
    let lap = lap8(u_pad, h);
    let s = lap.dims();
    assert_eq!(um.dims(), s);
    assert_eq!(v.dims(), s);
    let dt2 = (dt * dt) as f32;
    let mut out = Field3::zeros(s);
    for z in 0..s.z {
        for y in 0..s.y {
            for x in 0..s.x {
                let core = u_pad.get(z + R, y + R, x + R);
                let vv = v.get(z, y, x);
                let val = 2.0 * core - um.get(z, y, x) + dt2 * vv * vv * lap.get(z, y, x);
                out.set(z, y, x, val);
            }
        }
    }
    out
}

/// Damped PML update:
/// u+ = [2u - (1 - eta_bar dt) um + dt^2 v^2 lap2(u)] / (1 + eta_bar dt).
pub fn step_pml(
    u_pad1: &Field3,
    um: &Field3,
    v: &Field3,
    eta_pad1: &Field3,
    dt: f64,
    h: f64,
) -> Field3 {
    let lap = lap2(u_pad1, h);
    let eb = eta_bar(eta_pad1);
    let s = lap.dims();
    assert_eq!(um.dims(), s);
    assert_eq!(v.dims(), s);
    let dt2 = (dt * dt) as f32;
    let dt_f = dt as f32;
    let mut out = Field3::zeros(s);
    for z in 0..s.z {
        for y in 0..s.y {
            for x in 0..s.x {
                let core = u_pad1.get(z + R_ETA, y + R_ETA, x + R_ETA);
                let ed = eb.get(z, y, x) * dt_f;
                let vv = v.get(z, y, x);
                let num =
                    2.0 * core - (1.0 - ed) * um.get(z, y, x) + dt2 * vv * vv * lap.get(z, y, x);
                out.set(z, y, x, num / (1.0 + ed));
            }
        }
    }
    out
}

/// Precomputed per-step scalar constants. Derivations mirror `lap8` /
/// `step_inner` / `step_pml` exactly (f64 -> f32 casts in the same
/// places) so the fused row kernels stay bit-identical to the two-pass
/// spec. Also carries the dispatched row-kernel choice: [`Consts::of`]
/// defaults to the scalar oracle; families that take the SIMD path
/// attach `simd::active()` via [`Consts::with_kernel`].
#[derive(Copy, Clone)]
pub(crate) struct Consts {
    pub dt2: f32,
    pub dt_f: f32,
    pub inv_h2: f32,
    /// Row-kernel dispatch for this step (scalar unless overridden).
    pub kern: simd::RowKernel,
}

impl Consts {
    pub(crate) fn of(domain: &Domain) -> Consts {
        Consts {
            dt2: (domain.dt * domain.dt) as f32,
            dt_f: domain.dt as f32,
            inv_h2: (1.0 / (domain.h * domain.h)) as f32,
            kern: simd::RowKernel::SCALAR,
        }
    }

    /// The same constants with a dispatched row kernel attached.
    pub(crate) fn with_kernel(self, kern: simd::RowKernel) -> Consts {
        Consts { kern, ..self }
    }
}

/// Fused inner (25-point, 8th-order) leapfrog update of one contiguous
/// x-row of interior points `(iz, iy, x0..x0+len)`, **in place**:
/// `out` is the matching row segment of the R-ghost-padded output
/// buffer, holding the `um` (step n-1) values on entry and the step
/// n+1 values on exit. `u` is the padded step-n wavefield, `v` the
/// interior-sized velocity model.
///
/// Every neighbor run is pre-cut to exactly `len`, so the loop body
/// indexes bounds-check-free and auto-vectorizes. Arithmetic ordering
/// mirrors `lap8` + `step_inner`: per-point results are bit-identical
/// to the two-pass spec.
///
/// Dispatches on `k.kern`: the scalar oracle below, or the explicit-
/// SIMD path ([`simd`]) — which replicates the per-point op order
/// exactly and tails into the scalar kernel, so the choice never
/// changes a single bit of output.
#[allow(clippy::too_many_arguments)] // mirrors the kernel ABI: fields + row coords + constants
#[inline]
pub(crate) fn inner_row(
    u: FieldView<'_>,
    v: FieldView<'_>,
    iz: usize,
    iy: usize,
    x0: usize,
    len: usize,
    k: Consts,
    out: &mut [f32],
) {
    if k.kern.lanes > 1 {
        simd::inner_row_simd(u, v, iz, iy, x0, len, k, out)
    } else {
        inner_row_scalar(u, v, iz, iy, x0, len, k, out)
    }
}

/// The scalar inner-row oracle (see [`inner_row`] for the contract).
#[allow(clippy::too_many_arguments)] // mirrors the kernel ABI: fields + row coords + constants
#[inline]
pub(crate) fn inner_row_scalar(
    u: FieldView<'_>,
    v: FieldView<'_>,
    iz: usize,
    iy: usize,
    x0: usize,
    len: usize,
    k: Consts,
    out: &mut [f32],
) {
    assert_eq!(out.len(), len, "output row length mismatch");
    let (cz, cy) = (iz + R, iy + R);
    let b = x0 + R; // padded x of the first point
    let zp: [&[f32]; R] = std::array::from_fn(|m| u.seg(cz + m + 1, cy, b, len));
    let zm: [&[f32]; R] = std::array::from_fn(|m| u.seg(cz - m - 1, cy, b, len));
    let yp: [&[f32]; R] = std::array::from_fn(|m| u.seg(cz, cy + m + 1, b, len));
    let ym: [&[f32]; R] = std::array::from_fn(|m| u.seg(cz, cy - m - 1, b, len));
    let xp: [&[f32]; R] = std::array::from_fn(|m| u.seg(cz, cy, b + m + 1, len));
    let xm: [&[f32]; R] = std::array::from_fn(|m| u.seg(cz, cy, b - m - 1, len));
    let ctr = u.seg(cz, cy, b, len);
    let vs = v.seg(iz, iy, x0, len);
    for i in 0..len {
        // Mirror jnp ordering: 3*c0*core, then per-m (z+,z-,y+,y-,x+,x-).
        let mut acc = 3.0 * C8[0] * ctr[i];
        for m in 1..=R {
            acc += C8[m]
                * (zp[m - 1][i]
                    + zm[m - 1][i]
                    + yp[m - 1][i]
                    + ym[m - 1][i]
                    + xp[m - 1][i]
                    + xm[m - 1][i]);
        }
        let lap = acc * k.inv_h2;
        let vv = vs[i];
        out[i] = 2.0 * ctr[i] - out[i] + k.dt2 * vv * vv * lap;
    }
}

/// Fused PML (7-point, damped) update of one contiguous x-row, in
/// place like [`inner_row`]. `eta` is the R-ghost-padded damping
/// profile. Mirrors `lap2` + `eta_bar` + `step_pml` bit-for-bit, and
/// dispatches on `k.kern` exactly like [`inner_row`].
#[allow(clippy::too_many_arguments)] // mirrors the kernel ABI: fields + row coords + constants
#[inline]
pub(crate) fn pml_row(
    u: FieldView<'_>,
    v: FieldView<'_>,
    eta: FieldView<'_>,
    iz: usize,
    iy: usize,
    x0: usize,
    len: usize,
    k: Consts,
    out: &mut [f32],
) {
    if k.kern.lanes > 1 {
        simd::pml_row_simd(u, v, eta, iz, iy, x0, len, k, out)
    } else {
        pml_row_scalar(u, v, eta, iz, iy, x0, len, k, out)
    }
}

/// The scalar PML-row oracle (see [`pml_row`] for the contract).
#[allow(clippy::too_many_arguments)] // mirrors the kernel ABI: fields + row coords + constants
#[inline]
pub(crate) fn pml_row_scalar(
    u: FieldView<'_>,
    v: FieldView<'_>,
    eta: FieldView<'_>,
    iz: usize,
    iy: usize,
    x0: usize,
    len: usize,
    k: Consts,
    out: &mut [f32],
) {
    assert_eq!(out.len(), len, "output row length mismatch");
    let (cz, cy) = (iz + R, iy + R);
    let b = x0 + R;
    let uc = u.seg(cz, cy, b, len);
    let u_zp = u.seg(cz + 1, cy, b, len);
    let u_zm = u.seg(cz - 1, cy, b, len);
    let u_yp = u.seg(cz, cy + 1, b, len);
    let u_ym = u.seg(cz, cy - 1, b, len);
    let u_xp = u.seg(cz, cy, b + 1, len);
    let u_xm = u.seg(cz, cy, b - 1, len);
    let ec = eta.seg(cz, cy, b, len);
    let e_zp = eta.seg(cz + 1, cy, b, len);
    let e_zm = eta.seg(cz - 1, cy, b, len);
    let e_yp = eta.seg(cz, cy + 1, b, len);
    let e_ym = eta.seg(cz, cy - 1, b, len);
    let e_xp = eta.seg(cz, cy, b + 1, len);
    let e_xm = eta.seg(cz, cy, b - 1, len);
    let vs = v.seg(iz, iy, x0, len);
    for i in 0..len {
        let acc = 3.0 * C2[0] * uc[i]
            + (u_zp[i] + u_zm[i] + u_yp[i] + u_ym[i] + u_xp[i] + u_xm[i]);
        let lap = acc * k.inv_h2;
        let eb = (ec[i] + e_zp[i] + e_zm[i] + e_yp[i] + e_ym[i] + e_xp[i] + e_xm[i]) / 7.0;
        let ed = eb * k.dt_f;
        let vv = vs[i];
        let num = 2.0 * uc[i] - (1.0 - ed) * out[i] + k.dt2 * vv * vv * lap;
        out[i] = num / (1.0 + ed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::{Dim3, Field3};

    #[test]
    fn coefficients_annihilate_constants() {
        let s: f32 = C8[0] + 2.0 * C8[1..].iter().sum::<f32>();
        assert!(s.abs() < 1e-6);
    }

    #[test]
    fn lap8_constant_is_zero() {
        let t = Field3::full(Dim3::new(12, 12, 12), 7.5);
        let l = lap8(&t, 10.0);
        assert!(l.max_abs() < 1e-4);
    }

    #[test]
    fn lap8_quadratic_exact() {
        // u = 3z^2 + 2y^2 + x^2 -> lap = 12.
        let h = 2.0f64;
        let t = Field3::from_fn(Dim3::new(14, 13, 12), |z, y, x| {
            let (zf, yf, xf) = (z as f64 * h, y as f64 * h, x as f64 * h);
            (3.0 * zf * zf + 2.0 * yf * yf + xf * xf) as f32
        });
        let l = lap8(&t, h);
        let d = l.dims();
        for z in 0..d.z {
            for y in 0..d.y {
                for x in 0..d.x {
                    assert!((l.get(z, y, x) - 12.0).abs() < 2e-3, "{}", l.get(z, y, x));
                }
            }
        }
    }

    #[test]
    fn lap2_quadratic_exact() {
        let t = Field3::from_fn(Dim3::new(8, 7, 6), |z, y, x| {
            ((z * z + y * y + x * x) as f32) * 1.0
        });
        let l = lap2(&t, 1.0);
        assert!((l.get(0, 0, 0) - 6.0).abs() < 1e-3);
        assert!((l.get(5, 4, 3) - 6.0).abs() < 1e-3);
    }

    #[test]
    fn eta_bar_point_source() {
        let mut t = Field3::zeros(Dim3::new(3, 3, 3));
        t.set(1, 1, 1, 7.0);
        let eb = eta_bar(&t);
        assert_eq!(eb.dims(), Dim3::new(1, 1, 1));
        assert!((eb.get(0, 0, 0) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn inner_step_leapfrog_identity_when_flat() {
        // constant field => lap == 0 => u+ = 2u - um
        let u = Field3::full(Dim3::new(10, 10, 10), 3.0);
        let um = Field3::full(Dim3::new(2, 2, 2), 1.0);
        let v = Field3::full(Dim3::new(2, 2, 2), 2000.0);
        let out = step_inner(&u, &um, &v, 1e-3, 10.0);
        for &val in out.as_slice() {
            assert!((val - 5.0).abs() < 1e-3);
        }
    }

    #[test]
    fn pml_step_damps() {
        let u = Field3::full(Dim3::new(4, 4, 4), 1.0);
        let um = Field3::full(Dim3::new(2, 2, 2), 1.0);
        let v = Field3::full(Dim3::new(2, 2, 2), 2000.0);
        let eta0 = Field3::zeros(Dim3::new(4, 4, 4));
        let eta1 = Field3::full(Dim3::new(4, 4, 4), 100.0);
        let a = step_pml(&u, &um, &v, &eta0, 1e-3, 10.0);
        let b = step_pml(&u, &um, &v, &eta1, 1e-3, 10.0);
        for (x, y) in a.as_slice().iter().zip(b.as_slice()) {
            assert!(y.abs() <= x.abs() + 1e-6);
        }
    }

    #[test]
    fn fused_row_kernels_match_the_two_pass_spec_bitwise() {
        // the in-place hot path must reproduce the allocating spec
        // bit-for-bit, including the leapfrog um-in-out trick
        use crate::testkit::Rng;
        let s = Dim3::new(9, 7, 11);
        let (h, dt) = (10.0, 1e-3);
        let domain = Domain::new(s, 2, h, dt).unwrap();
        let mut rng = Rng::new(0xFEED);
        let u = rng.field(s);
        let um = rng.field(s);
        let v = rng.field_in(s, 1500.0, 3500.0);
        let eta = rng.field_in(s, 0.0, 50.0);
        let (u_pad, um_pad, eta_pad) = (u.pad(R), um.pad(R), eta.pad(R));
        let k = Consts::of(&domain);

        // inner family, whole interior in one sweep
        let spec = step_inner(&u_pad, &um, &v, dt, h);
        let mut got = um_pad.clone();
        {
            let uv = u_pad.view();
            let vv = v.view();
            let mut out = got.view_mut();
            for iz in 0..s.z {
                for iy in 0..s.y {
                    let row = out.seg_mut(iz + R, iy + R, R, s.x);
                    inner_row(uv, vv, iz, iy, 0, s.x, k, row);
                }
            }
        }
        assert_eq!(got.unpad(R).max_abs_diff(&spec), 0.0, "inner_row vs lap8+step_inner");
        assert_eq!(got.unpad(R).pad(R), got, "ghost ring must stay zero");

        // PML family, whole interior in one sweep
        let u_t = u_pad.extract_padded_region(R, Dim3::new(0, 0, 0), s, 1);
        let e_t = eta_pad.extract_padded_region(R, Dim3::new(0, 0, 0), s, 1);
        let spec = step_pml(&u_t, &um, &v, &e_t, dt, h);
        let mut got = um_pad.clone();
        {
            let uv = u_pad.view();
            let vv = v.view();
            let ev = eta_pad.view();
            let mut out = got.view_mut();
            for iz in 0..s.z {
                for iy in 0..s.y {
                    let row = out.seg_mut(iz + R, iy + R, R, s.x);
                    pml_row(uv, vv, ev, iz, iy, 0, s.x, k, row);
                }
            }
        }
        assert_eq!(
            got.unpad(R).max_abs_diff(&spec),
            0.0,
            "pml_row vs lap2+eta_bar+step_pml"
        );
    }

    #[test]
    fn row_kernels_handle_partial_rows() {
        // a mid-row segment must equal the same points of a full sweep
        use crate::testkit::Rng;
        let s = Dim3::new(6, 6, 12);
        let domain = Domain::new(s, 2, 10.0, 1e-3).unwrap();
        let mut rng = Rng::new(0xACE);
        let u_pad = rng.field(s).pad(R);
        let um_pad = rng.field(s).pad(R);
        let v = rng.field_in(s, 1500.0, 3500.0);
        let k = Consts::of(&domain);
        let uv = u_pad.view();
        let vv = v.view();

        let mut full = um_pad.clone();
        let mut part = um_pad.clone();
        let (iz, iy) = (3, 2);
        inner_row(uv, vv, iz, iy, 0, s.x, k, full.view_mut().seg_mut(iz + R, iy + R, R, s.x));
        // same row in two pieces: [0, 5) and [5, 12)
        inner_row(uv, vv, iz, iy, 0, 5, k, part.view_mut().seg_mut(iz + R, iy + R, R, 5));
        inner_row(
            uv,
            vv,
            iz,
            iy,
            5,
            s.x - 5,
            k,
            part.view_mut().seg_mut(iz + R, iy + R, R + 5, s.x - 5),
        );
        assert_eq!(full.max_abs_diff(&part), 0.0);
    }

    #[test]
    fn cfl_is_tighter_than_second_order() {
        let dt = cfl_dt(10.0, 3000.0);
        assert!(dt > 0.0);
        assert!(dt < 10.0 / (3000.0 * 3.0f64.sqrt()));
    }
}
