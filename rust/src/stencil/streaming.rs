//! 2.5D streaming propagator: the CPU analog of the paper's `st_smem`
//! family (§IV.5), which also stands in for `st_reg_shft` /
//! `st_reg_fixed` (§IV.6-7 — register files have no CPU analog beyond
//! the same plane-streaming traversal).
//!
//! The inner region's (z, y) plane is tiled a x b; each tile streams
//! along x keeping a ring buffer of 2R+1 (z, y) planes — the
//! shared-memory ring of the CUDA kernel, here a per-worker buffer
//! (planned once, reused every step) that keeps the 25-point working
//! set hot in L1/L2. PML faces use the same (z, y) tiling but walk the
//! 7-point halo-1 update through the vectorized row kernel (streaming
//! a 1-deep halo buys nothing).
//!
//! The ring holds exact copies of `u`, and per-point arithmetic keeps
//! the `lap8` term ordering, so results are bit-identical to the
//! golden propagator.

use super::propagator::{
    first_touch_zeros, pml_tile_into, Plan, Propagator, PropagatorInputs, SharedOut,
};
use super::{simd, Consts};
use crate::gpusim::kernels::KernelVariant;
use crate::grid::{decompose, Dim3, Field3, Region};
use crate::{stencil::C8, R};

/// Per-worker ring storage: 2R+1 plane slots, each sized for the
/// largest inner tile's padded (z, y) plane. Allocated once in the
/// plan; every step reuses it.
pub(crate) struct Ring {
    buf: Vec<f32>,
    plane_cap: usize,
}

impl Ring {
    fn for_tasks(tasks: &[Region]) -> Ring {
        let plane_cap = tasks
            .iter()
            .filter(|t| !t.class.is_pml())
            .map(|t| (t.shape.z + 2 * R) * (t.shape.y + 2 * R))
            .max()
            .unwrap_or(0);
        // first-touch: the ring is built on the owning worker's thread
        // (Plan::ensure runs the scratch ctor through the pool), so
        // writing every element here places its pages on that worker's
        // NUMA node rather than wherever the main thread first faulted
        Ring { buf: first_touch_zeros((2 * R + 1) * plane_cap), plane_cap }
    }
}

/// 2.5D plane streaming with a 2R+1 ring buffer of planes.
pub struct Streaming25D {
    /// Plane-tile extents: `tile_z` tiles z, `tile_y` tiles y (the
    /// variant's (A, B) in `st_*_{A}x{B}`); the kernel streams along x.
    pub tile_z: usize,
    pub tile_y: usize,
    plan: Option<Plan<Ring>>,
}

impl Streaming25D {
    pub fn new(tile_z: usize, tile_y: usize) -> Streaming25D {
        Streaming25D { tile_z: tile_z.max(1), tile_y: tile_y.max(1), plan: None }
    }

    pub fn from_variant(v: &KernelVariant) -> Streaming25D {
        Streaming25D::new(v.d1 as usize, v.d2 as usize)
    }
}

impl Propagator for Streaming25D {
    fn name(&self) -> &'static str {
        "streaming2.5d"
    }

    fn signature(&self) -> String {
        format!("streaming2.5d:{}x{}:{}", self.tile_z, self.tile_y, simd::detected().tag())
    }

    fn step_into(&mut self, inp: &PropagatorInputs<'_>, out: &mut Field3) {
        debug_assert_eq!(out.dims(), inp.domain.padded());
        let k = Consts::of(inp.domain).with_kernel(simd::active());
        let (tz, ty) = (self.tile_z, self.tile_y);
        let plan = Plan::ensure(
            &mut self.plan,
            inp.domain,
            inp.threads,
            "streaming2.5d",
            inp.telemetry,
            // every region keeps its full x extent: the stream axis is
            // never tiled (that is the point of the 2.5D shape)
            |d| {
                decompose(d)
                    .iter()
                    .flat_map(|r| r.split(Dim3::new(tz, ty, r.shape.x)))
                    .collect()
            },
            Ring::for_tasks,
        );
        plan.run_into(out, |t, ring, o| {
            if t.class.is_pml() {
                pml_tile_into(inp, t, k, o);
            } else {
                streaming_inner_tile_into(inp, t, k, ring, o);
            }
        });
    }
}

/// Stream one inner (z, y) tile along x with a ring of 2R+1 planes,
/// updating the tile's points of the padded output in place.
///
/// This loop nest stays scalar-inline rather than dispatching to the
/// `simd` row kernels: the ring transposes the data so the unit-stride
/// axis is y within a plane slot, not x of the padded field, and the
/// x-taps come from five different ring slots — the row-kernel contract
/// (contiguous x segments of one array) does not apply. The PML faces
/// of this family do go through the dispatched `pml_row`.
fn streaming_inner_tile_into(
    inp: &PropagatorInputs<'_>,
    t: &Region,
    k: Consts,
    ring: &mut Ring,
    out: &SharedOut,
) {
    let u = inp.u_pad.view();
    let (offset, shape) = (t.offset, t.shape);
    let np = 2 * R + 1; // ring depth
    let pz = shape.z + 2 * R; // plane rows: z extent + halo
    let py = shape.y + 2 * R; // plane cols: y extent + halo
    let cap = ring.plane_cap;
    debug_assert!(pz * py <= cap, "ring scratch undersized for this tile");
    let buf = &mut ring.buf;

    // The plane at stream position q (local x, in -R..shape.x+R) lives
    // in slot (q + R) % np. Plane row dz / col dy cover padded coords
    // (offset.z + dz, offset.y + dy): the tile's z/y halo and the
    // array's R-ghost padding cancel exactly.
    let load = |buf: &mut [f32], q: isize| {
        let slot = ((q + R as isize) as usize) % np;
        // padded x of stream position q; add R before the usize cast —
        // offset.x + q alone can go negative when pml_width < R
        let px = (offset.x as isize + q + R as isize) as usize;
        let plane = &mut buf[slot * cap..slot * cap + pz * py];
        for dz in 0..pz {
            // the (z, y) plane at fixed x is strided in u but
            // contiguous in the ring slot
            for dy in 0..py {
                plane[dz * py + dy] = u.get(offset.z + dz, offset.y + dy, px);
            }
        }
    };

    // prime the ring with the R left-halo planes plus R-1 ahead
    for q in -(R as isize)..(R as isize) {
        load(buf, q);
    }

    for x in 0..shape.x {
        // pull in the leading plane, then update column x from the ring
        load(buf, x as isize + R as isize);
        let ctr = &buf[((x + R) % np) * cap..][..pz * py];
        for dz in 0..shape.z {
            for dy in 0..shape.y {
                let (rz, ry) = (dz + R, dy + R);
                let mut acc = 3.0 * C8[0] * ctr[rz * py + ry];
                for m in 1..=R {
                    let xp = &buf[((x + R + m) % np) * cap..][..pz * py];
                    let xm = &buf[((x + R - m) % np) * cap..][..pz * py];
                    acc += C8[m]
                        * (ctr[(rz + m) * py + ry]
                            + ctr[(rz - m) * py + ry]
                            + ctr[rz * py + (ry + m)]
                            + ctr[rz * py + (ry - m)]
                            + xp[rz * py + ry]
                            + xm[rz * py + ry]);
                }
                let lap = acc * k.inv_h2;
                let core = ctr[rz * py + ry];
                let (iz, iy, ix) = (offset.z + dz, offset.y + dy, offset.x + x);
                let vv = inp.v.get(iz, iy, ix);
                // SAFETY: each interior point belongs to exactly one
                // tile; this task owns (iz, iy, ix).
                unsafe {
                    let um = out.read(iz + R, iy + R, ix + R);
                    out.write(iz + R, iy + R, ix + R, 2.0 * core - um + k.dt2 * vv * vv * lap);
                }
            }
        }
    }
}
