//! 2.5D streaming propagator: the CPU analog of the paper's `st_smem`
//! family (§IV.5), which also stands in for `st_reg_shft` /
//! `st_reg_fixed` (§IV.6-7 — register files have no CPU analog beyond
//! the same plane-streaming traversal).
//!
//! The inner region's (z, y) plane is tiled a x b; each tile streams
//! along x keeping a ring buffer of 2R+1 (z, y) planes — the
//! shared-memory ring of the CUDA kernel, here a thread-local buffer
//! that keeps the 25-point working set hot in L1/L2. PML faces use the
//! same (z, y) tiling but walk the 7-point halo-1 update directly
//! (streaming a 1-deep halo buys nothing).
//!
//! The ring holds exact copies of `u`, and per-point arithmetic keeps
//! the `lap8` term ordering, so results are bit-identical to the
//! golden propagator.

use super::propagator::{pml_tile, run_tiled, Consts, Propagator, PropagatorInputs};
use crate::gpusim::kernels::KernelVariant;
use crate::grid::{decompose, Dim3, Field3};
use crate::{stencil::C8, R};

/// 2.5D plane streaming with a 2R+1 ring buffer of planes.
pub struct Streaming25D {
    /// Plane-tile extents: `tile_z` tiles z, `tile_y` tiles y (the
    /// variant's (A, B) in `st_*_{A}x{B}`); the kernel streams along x.
    pub tile_z: usize,
    pub tile_y: usize,
}

impl Streaming25D {
    pub fn new(tile_z: usize, tile_y: usize) -> Streaming25D {
        Streaming25D { tile_z: tile_z.max(1), tile_y: tile_y.max(1) }
    }

    pub fn from_variant(v: &KernelVariant) -> Streaming25D {
        Streaming25D::new(v.d1 as usize, v.d2 as usize)
    }
}

impl Propagator for Streaming25D {
    fn name(&self) -> &'static str {
        "streaming2.5d"
    }

    fn signature(&self) -> String {
        format!("streaming2.5d:{}x{}", self.tile_z, self.tile_y)
    }

    fn step(&self, inp: &PropagatorInputs<'_>) -> Field3 {
        let k = Consts::of(inp.domain);
        // every region keeps its full x extent: the stream axis is
        // never tiled (that is the point of the 2.5D shape)
        let tasks: Vec<_> = decompose(inp.domain)
            .iter()
            .flat_map(|r| r.split(Dim3::new(self.tile_z, self.tile_y, r.shape.x)))
            .collect();
        run_tiled(inp.domain, &tasks, inp.threads, |t| {
            if t.class.is_pml() {
                pml_tile(inp, t.offset, t.shape, k)
            } else {
                streaming_inner_tile(inp, t.offset, t.shape, k)
            }
        })
    }
}

/// Stream one inner (z, y) tile along x with a ring of 2R+1 planes.
fn streaming_inner_tile(
    inp: &PropagatorInputs<'_>,
    offset: Dim3,
    shape: Dim3,
    k: Consts,
) -> Field3 {
    let u = inp.u_pad;
    let np = 2 * R + 1; // ring depth
    let pz = shape.z + 2 * R; // plane rows: z extent + halo
    let py = shape.y + 2 * R; // plane cols: y extent + halo
    let mut ring: Vec<Vec<f32>> = vec![vec![0.0f32; pz * py]; np];

    // The plane at stream position q (local x, in -R..shape.x+R) lives
    // in slot (q + R) % np. Plane row dz / col dy cover padded coords
    // (offset.z + dz, offset.y + dy): the tile's z/y halo and the
    // array's R-ghost padding cancel exactly.
    let load = |ring: &mut Vec<Vec<f32>>, q: isize| {
        let slot = ((q + R as isize) as usize) % np;
        // padded x of stream position q; add R before the usize cast —
        // offset.x + q alone can go negative when pml_width < R
        let px = (offset.x as isize + q + R as isize) as usize;
        let plane = &mut ring[slot];
        for dz in 0..pz {
            for dy in 0..py {
                plane[dz * py + dy] = u.get(offset.z + dz, offset.y + dy, px);
            }
        }
    };

    // prime the ring with the R left-halo planes plus R-1 ahead
    for q in -(R as isize)..(R as isize) {
        load(&mut ring, q);
    }

    let mut out = Field3::zeros(shape);
    for x in 0..shape.x {
        // pull in the leading plane, then update column x from the ring
        load(&mut ring, x as isize + R as isize);
        let ctr = &ring[(x + R) % np];
        for dz in 0..shape.z {
            for dy in 0..shape.y {
                let (rz, ry) = (dz + R, dy + R);
                let mut acc = 3.0 * C8[0] * ctr[rz * py + ry];
                for m in 1..=R {
                    let xp = &ring[(x + R + m) % np];
                    let xm = &ring[(x + R - m) % np];
                    acc += C8[m]
                        * (ctr[(rz + m) * py + ry]
                            + ctr[(rz - m) * py + ry]
                            + ctr[rz * py + (ry + m)]
                            + ctr[rz * py + (ry - m)]
                            + xp[rz * py + ry]
                            + xm[rz * py + ry]);
                }
                let lap = acc * k.inv_h2;
                let core = ctr[rz * py + ry];
                let (iz, iy, ix) = (offset.z + dz, offset.y + dy, offset.x + x);
                let vv = inp.v.get(iz, iy, ix);
                let val =
                    2.0 * core - inp.um_pad.get(iz + R, iy + R, ix + R) + k.dt2 * vv * vv * lap;
                out.set(dz, dy, x, val);
            }
        }
    }
    out
}
