//! Semi-stencil propagator: the CPU analog of the paper's `semi`
//! family (§IV.4, after Ortega et al.).
//!
//! The classic stencil gathers all 2R x-neighbors per output point;
//! the semi-stencil inverts that on one axis: each *input* value is
//! read once and scatters its C8[m] contributions into a partial-sum
//! buffer — a FORWARD phase for the outputs to its right, a BACKWARD
//! phase for the outputs to its left. A COMBINE pass then adds the
//! center and z/y-axis terms. Halving reads per point is the GPU win;
//! here the shape itself is the point.
//!
//! Because the x-axis chain is re-associated, results agree with the
//! golden propagator to a few ULP rather than bitwise (the equivalence
//! suite asserts the tolerance).

use super::propagator::{pml_tile, run_tiled, Consts, Propagator, PropagatorInputs};
use crate::gpusim::kernels::KernelVariant;
use crate::grid::{decompose, Dim3, Field3};
use crate::{stencil::C8, R};

/// Two-phase semi-stencil on x inside 3D blocks.
pub struct SemiStencil {
    /// Block extents in (z, y, x) order — the variant's (d3, d2, d1).
    pub tile: Dim3,
}

impl SemiStencil {
    pub fn new(tile: Dim3) -> SemiStencil {
        SemiStencil { tile }
    }

    pub fn from_variant(v: &KernelVariant) -> SemiStencil {
        SemiStencil::new(Dim3::new(
            (v.d3.max(1)) as usize,
            (v.d2.max(1)) as usize,
            (v.d1.max(1)) as usize,
        ))
    }
}

impl Propagator for SemiStencil {
    fn name(&self) -> &'static str {
        "semi_stencil"
    }

    fn signature(&self) -> String {
        format!("semi_stencil:{}", self.tile)
    }

    fn step(&self, inp: &PropagatorInputs<'_>) -> Field3 {
        let k = Consts::of(inp.domain);
        let tasks: Vec<_> = decompose(inp.domain)
            .iter()
            .flat_map(|r| r.split(self.tile))
            .collect();
        run_tiled(inp.domain, &tasks, inp.threads, |t| {
            if t.class.is_pml() {
                pml_tile(inp, t.offset, t.shape, k)
            } else {
                semi_inner_tile(inp, t.offset, t.shape, k)
            }
        })
    }
}

/// Forward/backward partial-sum update of one inner tile.
fn semi_inner_tile(inp: &PropagatorInputs<'_>, offset: Dim3, shape: Dim3, k: Consts) -> Field3 {
    let u = inp.u_pad;
    let mut out = Field3::zeros(shape);
    let ri = R as isize;
    let sx = shape.x as isize;
    let mut partial = vec![0.0f32; shape.x];
    for dz in 0..shape.z {
        for dy in 0..shape.y {
            let (cz, cy) = (offset.z + dz + R, offset.y + dy + R);
            partial.iter_mut().for_each(|p| *p = 0.0);
            // FORWARD phase: walk inputs left -> right; each input
            // scatters C8[m] * u into the m outputs on its right.
            for q in -ri..sx {
                let px = (offset.x as isize + q + R as isize) as usize;
                let uq = u.get(cz, cy, px);
                for m in 1..=R {
                    let tgt = q + m as isize;
                    if (0..sx).contains(&tgt) {
                        partial[tgt as usize] += C8[m] * uq;
                    }
                }
            }
            // BACKWARD phase: right -> left; contributions to the m
            // outputs on the input's left complete the partial sums.
            for q in (1..sx + ri).rev() {
                let px = (offset.x as isize + q + R as isize) as usize;
                let uq = u.get(cz, cy, px);
                for m in 1..=R {
                    let tgt = q - m as isize;
                    if (0..sx).contains(&tgt) {
                        partial[tgt as usize] += C8[m] * uq;
                    }
                }
            }
            // COMBINE: center + z/y-axis gather + completed x partials.
            for dx in 0..shape.x {
                let cx = offset.x + dx + R;
                let mut acc = 3.0 * C8[0] * u.get(cz, cy, cx);
                for m in 1..=R {
                    acc += C8[m]
                        * (u.get(cz + m, cy, cx)
                            + u.get(cz - m, cy, cx)
                            + u.get(cz, cy + m, cx)
                            + u.get(cz, cy - m, cx));
                }
                let lap = (acc + partial[dx]) * k.inv_h2;
                let core = u.get(cz, cy, cx);
                let (iz, iy, ix) = (offset.z + dz, offset.y + dy, offset.x + dx);
                let vv = inp.v.get(iz, iy, ix);
                let val =
                    2.0 * core - inp.um_pad.get(iz + R, iy + R, ix + R) + k.dt2 * vv * vv * lap;
                out.set(dz, dy, dx, val);
            }
        }
    }
    out
}
