//! Semi-stencil propagator: the CPU analog of the paper's `semi`
//! family (§IV.4, after Ortega et al.).
//!
//! The classic stencil gathers all 2R x-neighbors per output point;
//! the semi-stencil inverts that on one axis: each *input* value is
//! read once and scatters its C8[m] contributions into a partial-sum
//! buffer — a FORWARD phase for the outputs to its right, a BACKWARD
//! phase for the outputs to its left. A COMBINE pass then adds the
//! center and z/y-axis terms and applies the leapfrog update in place.
//! Halving reads per point is the GPU win; here the shape itself is
//! the point. The partial-sum row is per-worker scratch planned once
//! and reused every step.
//!
//! Because the x-axis chain is re-associated, results agree with the
//! golden propagator to a few ULP rather than bitwise (the equivalence
//! suite asserts the tolerance).

use super::propagator::{
    first_touch_zeros, pml_tile_into, Plan, Propagator, PropagatorInputs, SharedOut,
};
use super::{simd, Consts};
use crate::gpusim::kernels::KernelVariant;
use crate::grid::{decompose, Dim3, Field3, Region};
use crate::{stencil::C8, R};

/// Per-worker partial-sum row, sized for the widest inner tile.
pub(crate) struct PartialRow {
    buf: Vec<f32>,
}

impl PartialRow {
    fn for_tasks(tasks: &[Region]) -> PartialRow {
        let widest = tasks
            .iter()
            .filter(|t| !t.class.is_pml())
            .map(|t| t.shape.x)
            .max()
            .unwrap_or(0);
        // first-touch on the owning worker's thread (the ctor runs
        // through the pool) so the partial-sum pages are NUMA-local
        PartialRow { buf: first_touch_zeros(widest) }
    }
}

/// Two-phase semi-stencil on x inside 3D blocks.
pub struct SemiStencil {
    /// Block extents in (z, y, x) order — the variant's (d3, d2, d1).
    pub tile: Dim3,
    plan: Option<Plan<PartialRow>>,
}

impl SemiStencil {
    pub fn new(tile: Dim3) -> SemiStencil {
        SemiStencil { tile, plan: None }
    }

    pub fn from_variant(v: &KernelVariant) -> SemiStencil {
        SemiStencil::new(Dim3::new(
            (v.d3.max(1)) as usize,
            (v.d2.max(1)) as usize,
            (v.d1.max(1)) as usize,
        ))
    }
}

impl Propagator for SemiStencil {
    fn name(&self) -> &'static str {
        "semi_stencil"
    }

    fn signature(&self) -> String {
        format!("semi_stencil:{}:{}", self.tile, simd::detected().tag())
    }

    fn step_into(&mut self, inp: &PropagatorInputs<'_>, out: &mut Field3) {
        debug_assert_eq!(out.dims(), inp.domain.padded());
        let k = Consts::of(inp.domain).with_kernel(simd::active());
        let tile = self.tile;
        let plan = Plan::ensure(
            &mut self.plan,
            inp.domain,
            inp.threads,
            "semi_stencil",
            inp.telemetry,
            |d| decompose(d).iter().flat_map(|r| r.split(tile)).collect(),
            PartialRow::for_tasks,
        );
        plan.run_into(out, |t, partial, o| {
            if t.class.is_pml() {
                pml_tile_into(inp, t, k, o);
            } else {
                semi_inner_tile_into(inp, t, k, partial, o);
            }
        });
    }
}

/// Forward/backward partial-sum update of one inner tile, in place.
fn semi_inner_tile_into(
    inp: &PropagatorInputs<'_>,
    t: &Region,
    k: Consts,
    partial: &mut PartialRow,
    out: &SharedOut,
) {
    let u = inp.u_pad.view();
    let (offset, shape) = (t.offset, t.shape);
    let ri = R as isize;
    let sx = shape.x as isize;
    debug_assert!(shape.x <= partial.buf.len(), "partial scratch undersized");
    let p = &mut partial.buf[..shape.x];
    for dz in 0..shape.z {
        for dy in 0..shape.y {
            let (cz, cy) = (offset.z + dz + R, offset.y + dy + R);
            let urow = u.row(cz, cy); // contiguous along the x axis
            p.iter_mut().for_each(|v| *v = 0.0);
            // FORWARD phase: walk inputs left -> right; each input
            // scatters C8[m] * u into the m outputs on its right.
            for q in -ri..sx {
                let px = (offset.x as isize + q + ri) as usize;
                let uq = urow[px];
                for m in 1..=R {
                    let tgt = q + m as isize;
                    if (0..sx).contains(&tgt) {
                        p[tgt as usize] += C8[m] * uq;
                    }
                }
            }
            // BACKWARD phase: right -> left; contributions to the m
            // outputs on the input's left complete the partial sums.
            for q in (1..sx + ri).rev() {
                let px = (offset.x as isize + q + ri) as usize;
                let uq = urow[px];
                for m in 1..=R {
                    let tgt = q - m as isize;
                    if (0..sx).contains(&tgt) {
                        p[tgt as usize] += C8[m] * uq;
                    }
                }
            }
            // COMBINE: center + z/y-axis gather + completed x partials,
            // fused with the leapfrog update into the output row (which
            // holds um on entry). Neighbor runs are pre-cut to the row
            // length so this loop vectorizes like `inner_row`. It stays
            // scalar-inline rather than dispatching to the `simd` row
            // kernels: the x-axis term arrives pre-summed in `p`, which
            // the 25-point row-kernel contract has no slot for (this
            // family is ULP-equivalent, not bitwise, by design).
            let b = offset.x + R;
            let len = shape.x;
            let zp: [&[f32]; R] = std::array::from_fn(|m| u.seg(cz + m + 1, cy, b, len));
            let zm: [&[f32]; R] = std::array::from_fn(|m| u.seg(cz - m - 1, cy, b, len));
            let yp: [&[f32]; R] = std::array::from_fn(|m| u.seg(cz, cy + m + 1, b, len));
            let ym: [&[f32]; R] = std::array::from_fn(|m| u.seg(cz, cy - m - 1, b, len));
            let ctr = u.seg(cz, cy, b, len);
            let vs = inp.v.view().seg(offset.z + dz, offset.y + dy, offset.x, len);
            // SAFETY: tiles partition the interior; this row segment
            // belongs exclusively to the current task.
            let orow = unsafe { out.seg_mut(cz, cy, b, len) };
            for i in 0..len {
                let mut acc = 3.0 * C8[0] * ctr[i];
                for m in 1..=R {
                    acc += C8[m] * (zp[m - 1][i] + zm[m - 1][i] + yp[m - 1][i] + ym[m - 1][i]);
                }
                let lap = (acc + p[i]) * k.inv_h2;
                let vv = vs[i];
                orow[i] = 2.0 * ctr[i] - orow[i] + k.dt2 * vv * vv * lap;
            }
        }
    }
}
