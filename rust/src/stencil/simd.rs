//! Explicit-SIMD row kernels with runtime ISA dispatch.
//!
//! The scalar row kernels in the parent module auto-vectorize well on
//! a good day — but "on a good day" is exactly the compiler-dependence
//! the paper's hand-tuned kernels exist to eliminate. This module
//! vectorizes `inner_row` / `pml_row` across x explicitly:
//!
//! * [`Lanes<W>`] is the lane abstraction: a `[f32; W]` wrapper whose
//!   element-wise `+ - * /` are plain IEEE-754 f32 operations in lane
//!   order. Rust/LLVM never contracts separate `*`/`+` into an FMA, so
//!   a W-wide chunk performs **exactly** the scalar per-point op
//!   sequence — the SIMD path is bit-identical to the scalar oracle by
//!   construction, not by tolerance.
//! * `inner_row_w<W, U>` / `pml_row_w<W, U>` walk a row in `U`
//!   explicitly unrolled `W`-wide chunks (W ∈ {4, 8, 16}, U ∈
//!   {1, 2, 4}), with the tap chain over radius m = 1..=4 unrolled in
//!   the scalar reduction order (z+, z-, y+, y-, x+, x-). Partial rows
//!   end in an **explicit scalar tail**: the remainder is handed to
//!   the scalar kernel itself, so tails are the oracle by definition.
//! * Runtime ISA dispatch is decided **once** (a `OnceLock`; the
//!   steady-state read is one relaxed atomic load, no allocation):
//!   with the `simd` cargo feature on, x86/x86_64 hosts that pass
//!   `is_x86_feature_detected!("avx2")` get `#[target_feature]`-
//!   compiled AVX2 monomorphizations; aarch64 uses the portable lanes
//!   (NEON is baseline, no feature gate needed); everything else gets
//!   the portable lanes or the scalar fallback. With the feature off,
//!   dispatch is always scalar and the engine behaves exactly as
//!   before.
//! * [`force`] / [`clear_force`] override the (lane width, unroll)
//!   pair without touching the detected ISA — this is how `bench
//!   --simd-sweep` times the scalar control and how `autotune
//!   --measured` searches the lane-width × unroll axes on the host.
//!
//! The dispatch decision is recorded in every non-oracle propagator's
//! `signature()` (via [`RowKernel::tag`] of the *detected* kernel, so
//! signatures stay stable while a force override is probing) and in
//! telemetry at plan build (`hostencil_simd_width` gauge,
//! `hostencil_simd_dispatch_total{isa=...}` counter). `Naive` keeps
//! the scalar path unconditionally: it is the bit-identity oracle the
//! equivalence tests compare everything else against.
//!
//! See `docs/KERNELS.md` for the full row-kernel contract.
#![allow(clippy::too_many_arguments)] // kernels mirror the row ABI: fields + row coords + constants

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::OnceLock;

use super::{inner_row_scalar, pml_row_scalar, Consts, C2, C8};
use crate::grid::FieldView;
use crate::R;

// The tap macros below unroll exactly radius-4 chains.
const _: () = assert!(R == 4, "explicit tap unrolling assumes an 8th-order (R = 4) stencil");

/// Instruction set the dispatched row kernel is compiled for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// Plain scalar loop — the bit-identity oracle, always available.
    Scalar,
    /// Portable lane code without a `#[target_feature]` gate: the
    /// compiler targets the build's baseline vector ISA (SSE2 on
    /// x86_64, the forced-width path on hosts without a detected
    /// backend).
    Portable,
    /// AVX2 monomorphizations, selected after a positive
    /// `is_x86_feature_detected!("avx2")`.
    Avx2,
    /// aarch64 portable lanes — NEON is baseline on aarch64, so the
    /// portable code *is* NEON code; no runtime gate is needed.
    Neon,
}

impl Isa {
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Portable => "portable",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }
}

/// One dispatched row-kernel choice: which ISA path, how many f32
/// lanes per chunk, and how many chunks each unrolled group advances.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RowKernel {
    pub isa: Isa,
    pub lanes: u8,
    pub unroll: u8,
}

impl RowKernel {
    /// The always-available fallback (and the `Naive` oracle's kernel).
    pub const SCALAR: RowKernel = RowKernel { isa: Isa::Scalar, lanes: 1, unroll: 1 };

    /// Compact display tag: `scalar`, `avx2x8`, `neonx4`, `portablex4`.
    pub fn tag(self) -> String {
        if self.lanes <= 1 {
            "scalar".to_string()
        } else {
            format!("{}x{}", self.isa.name(), self.lanes)
        }
    }
}

/// Lane widths the dispatcher has monomorphizations for.
pub const LANE_WIDTHS: [u8; 3] = [4, 8, 16];
/// Unroll depths the dispatcher has monomorphizations for.
pub const UNROLLS: [u8; 3] = [1, 2, 4];

/// Default chunk-unroll depth for detected backends: two chunks in
/// flight hide the tap-chain latency without blowing the register
/// budget at W = 16.
const DEFAULT_UNROLL: u8 = 2;

static DETECTED: OnceLock<RowKernel> = OnceLock::new();
/// Force override, encoded as `0x8000_0000 | lanes << 8 | unroll`
/// (0 = no override). Relaxed ordering is enough: the override is a
/// single-word probe toggled between timed runs, never mid-row.
static FORCE: AtomicU32 = AtomicU32::new(0);

fn detect() -> RowKernel {
    if !cfg!(feature = "simd") {
        return RowKernel::SCALAR;
    }
    detect_arch()
}

#[allow(unreachable_code)] // arch-gated early returns leave dead tails on some targets
fn detect_arch() -> RowKernel {
    #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return RowKernel { isa: Isa::Avx2, lanes: 8, unroll: DEFAULT_UNROLL };
        }
        return RowKernel { isa: Isa::Portable, lanes: 4, unroll: DEFAULT_UNROLL };
    }
    #[cfg(target_arch = "aarch64")]
    return RowKernel { isa: Isa::Neon, lanes: 4, unroll: DEFAULT_UNROLL };
    RowKernel { isa: Isa::Portable, lanes: 4, unroll: DEFAULT_UNROLL }
}

/// The kernel runtime detection chose for this host (feature- and
/// ISA-dependent, never affected by [`force`]). Decided once, cached.
pub fn detected() -> RowKernel {
    *DETECTED.get_or_init(detect)
}

/// The kernel the propagator families will dispatch to *right now*:
/// the detected kernel unless a [`force`] override is live.
pub fn active() -> RowKernel {
    decode_force(FORCE.load(Ordering::Relaxed), detected())
}

fn decode_force(f: u32, base: RowKernel) -> RowKernel {
    if f == 0 {
        return base;
    }
    let lanes = ((f >> 8) & 0xff) as u8;
    let unroll = (f & 0xff) as u8;
    if lanes <= 1 {
        return RowKernel::SCALAR;
    }
    // A forced width on a host whose detection came back scalar (e.g.
    // the `simd` feature is off) runs the portable lanes — safe
    // everywhere, and exactly what the autotune lane sweep wants.
    let isa = match base.isa {
        Isa::Scalar => Isa::Portable,
        other => other,
    };
    RowKernel { isa, lanes, unroll }
}

fn encode_force(lanes: u8, unroll: u8) -> u32 {
    0x8000_0000 | ((lanes as u32) << 8) | unroll as u32
}

/// Override the dispatched (lane width, unroll) pair — `(1, 1)` forces
/// the scalar oracle. Returns `false` (and changes nothing) for combos
/// without a monomorphization. Probe-only API for `bench --simd-sweep`
/// and the `autotune --measured` lane search; [`clear_force`] restores
/// detection.
pub fn force(lanes: u8, unroll: u8) -> bool {
    let ok = (lanes == 1 && unroll == 1)
        || (LANE_WIDTHS.contains(&lanes) && UNROLLS.contains(&unroll));
    if ok {
        FORCE.store(encode_force(lanes, unroll), Ordering::Relaxed);
    }
    ok
}

/// Drop any [`force`] override and return to the detected kernel.
pub fn clear_force() {
    FORCE.store(0, Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// Lane abstraction

/// `W` f32 lanes updated element-wise in lane order. Every operator is
/// a plain f32 op — no `mul_add`, no re-association — so arithmetic on
/// `Lanes<W>` is the scalar arithmetic, W points at a time.
#[derive(Copy, Clone)]
struct Lanes<const W: usize>([f32; W]);

impl<const W: usize> Lanes<W> {
    #[inline(always)]
    fn load(s: &[f32], i: usize) -> Lanes<W> {
        let s = &s[i..i + W];
        Lanes(std::array::from_fn(|j| s[j]))
    }

    #[inline(always)]
    fn splat(v: f32) -> Lanes<W> {
        Lanes([v; W])
    }

    #[inline(always)]
    fn store(self, out: &mut [f32], i: usize) {
        out[i..i + W].copy_from_slice(&self.0);
    }
}

impl<const W: usize> std::ops::Add for Lanes<W> {
    type Output = Lanes<W>;
    #[inline(always)]
    fn add(self, o: Lanes<W>) -> Lanes<W> {
        Lanes(std::array::from_fn(|j| self.0[j] + o.0[j]))
    }
}

impl<const W: usize> std::ops::Sub for Lanes<W> {
    type Output = Lanes<W>;
    #[inline(always)]
    fn sub(self, o: Lanes<W>) -> Lanes<W> {
        Lanes(std::array::from_fn(|j| self.0[j] - o.0[j]))
    }
}

impl<const W: usize> std::ops::Mul for Lanes<W> {
    type Output = Lanes<W>;
    #[inline(always)]
    fn mul(self, o: Lanes<W>) -> Lanes<W> {
        Lanes(std::array::from_fn(|j| self.0[j] * o.0[j]))
    }
}

impl<const W: usize> std::ops::Div for Lanes<W> {
    type Output = Lanes<W>;
    #[inline(always)]
    fn div(self, o: Lanes<W>) -> Lanes<W> {
        Lanes(std::array::from_fn(|j| self.0[j] / o.0[j]))
    }
}

// ---------------------------------------------------------------------------
// W-wide chunk updates (bit-identical to one scalar loop iteration x W)

/// One `W`-wide chunk of the inner 25-point update at row offset `i`.
/// Mirrors the scalar body of `inner_row_scalar` op for op.
#[inline(always)]
fn inner_lanes<const W: usize>(
    zp: &[&[f32]; R],
    zm: &[&[f32]; R],
    yp: &[&[f32]; R],
    ym: &[&[f32]; R],
    xp: &[&[f32]; R],
    xm: &[&[f32]; R],
    ctr: &[f32],
    vs: &[f32],
    out: &mut [f32],
    i: usize,
    k: Consts,
) {
    let c = Lanes::<W>::load(ctr, i);
    let mut acc = Lanes::splat(3.0 * C8[0]) * c;
    // Explicitly unrolled tap chain, one expansion per radius m, in
    // the scalar reduction order (z+, z-, y+, y-, x+, x-).
    macro_rules! tap {
        ($m:literal) => {{
            let t = Lanes::<W>::load(zp[$m - 1], i)
                + Lanes::load(zm[$m - 1], i)
                + Lanes::load(yp[$m - 1], i)
                + Lanes::load(ym[$m - 1], i)
                + Lanes::load(xp[$m - 1], i)
                + Lanes::load(xm[$m - 1], i);
            acc = acc + Lanes::splat(C8[$m]) * t;
        }};
    }
    tap!(1);
    tap!(2);
    tap!(3);
    tap!(4);
    let lap = acc * Lanes::splat(k.inv_h2);
    let vv = Lanes::<W>::load(vs, i);
    let o = Lanes::<W>::load(out, i);
    (Lanes::splat(2.0) * c - o + Lanes::splat(k.dt2) * vv * vv * lap).store(out, i);
}

/// One `W`-wide chunk of the damped 7-point PML update at row offset
/// `i`. Mirrors the scalar body of `pml_row_scalar` op for op.
#[inline(always)]
fn pml_lanes<const W: usize>(
    uc: &[f32],
    u_zp: &[f32],
    u_zm: &[f32],
    u_yp: &[f32],
    u_ym: &[f32],
    u_xp: &[f32],
    u_xm: &[f32],
    ec: &[f32],
    e_zp: &[f32],
    e_zm: &[f32],
    e_yp: &[f32],
    e_ym: &[f32],
    e_xp: &[f32],
    e_xm: &[f32],
    vs: &[f32],
    out: &mut [f32],
    i: usize,
    k: Consts,
) {
    let c = Lanes::<W>::load(uc, i);
    let s = Lanes::<W>::load(u_zp, i)
        + Lanes::load(u_zm, i)
        + Lanes::load(u_yp, i)
        + Lanes::load(u_ym, i)
        + Lanes::load(u_xp, i)
        + Lanes::load(u_xm, i);
    let acc = Lanes::splat(3.0 * C2[0]) * c + s;
    let lap = acc * Lanes::splat(k.inv_h2);
    let eb = (Lanes::<W>::load(ec, i)
        + Lanes::load(e_zp, i)
        + Lanes::load(e_zm, i)
        + Lanes::load(e_yp, i)
        + Lanes::load(e_ym, i)
        + Lanes::load(e_xp, i)
        + Lanes::load(e_xm, i))
        / Lanes::splat(7.0);
    let ed = eb * Lanes::splat(k.dt_f);
    let vv = Lanes::<W>::load(vs, i);
    let o = Lanes::<W>::load(out, i);
    let num =
        Lanes::splat(2.0) * c - (Lanes::splat(1.0) - ed) * o + Lanes::splat(k.dt2) * vv * vv * lap;
    (num / (Lanes::splat(1.0) + ed)).store(out, i);
}

// ---------------------------------------------------------------------------
// Whole-row kernels: U unrolled W-wide chunks + explicit scalar tail

/// `W`-lane, `U`-chunk-unrolled inner update of one x-row. Same ABI,
/// same per-point arithmetic, and — via the scalar tail on the
/// remainder — the same results as `inner_row_scalar`, bit for bit.
#[inline(always)]
fn inner_row_w<const W: usize, const U: usize>(
    u: FieldView<'_>,
    v: FieldView<'_>,
    iz: usize,
    iy: usize,
    x0: usize,
    len: usize,
    k: Consts,
    out: &mut [f32],
) {
    assert_eq!(out.len(), len, "output row length mismatch");
    let (cz, cy) = (iz + R, iy + R);
    let b = x0 + R;
    let zp: [&[f32]; R] = std::array::from_fn(|m| u.seg(cz + m + 1, cy, b, len));
    let zm: [&[f32]; R] = std::array::from_fn(|m| u.seg(cz - m - 1, cy, b, len));
    let yp: [&[f32]; R] = std::array::from_fn(|m| u.seg(cz, cy + m + 1, b, len));
    let ym: [&[f32]; R] = std::array::from_fn(|m| u.seg(cz, cy - m - 1, b, len));
    let xp: [&[f32]; R] = std::array::from_fn(|m| u.seg(cz, cy, b + m + 1, len));
    let xm: [&[f32]; R] = std::array::from_fn(|m| u.seg(cz, cy, b - m - 1, len));
    let ctr = u.seg(cz, cy, b, len);
    let vs = v.seg(iz, iy, x0, len);
    let main = len - len % W;
    let mut i = 0;
    // U chunks per iteration; the inner bound is const, so the loop
    // body is U explicitly unrolled chunk updates.
    while i + W * U <= main {
        let mut j = 0;
        while j < U {
            inner_lanes::<W>(&zp, &zm, &yp, &ym, &xp, &xm, ctr, vs, out, i + j * W, k);
            j += 1;
        }
        i += W * U;
    }
    while i + W <= main {
        inner_lanes::<W>(&zp, &zm, &yp, &ym, &xp, &xm, ctr, vs, out, i, k);
        i += W;
    }
    // Explicit scalar tail: the remainder *is* the scalar oracle.
    if main < len {
        inner_row_scalar(u, v, iz, iy, x0 + main, len - main, k, &mut out[main..]);
    }
}

/// `W`-lane, `U`-chunk-unrolled PML update of one x-row; bit-identical
/// to `pml_row_scalar` (same op order, scalar tail).
#[inline(always)]
fn pml_row_w<const W: usize, const U: usize>(
    u: FieldView<'_>,
    v: FieldView<'_>,
    eta: FieldView<'_>,
    iz: usize,
    iy: usize,
    x0: usize,
    len: usize,
    k: Consts,
    out: &mut [f32],
) {
    assert_eq!(out.len(), len, "output row length mismatch");
    let (cz, cy) = (iz + R, iy + R);
    let b = x0 + R;
    let uc = u.seg(cz, cy, b, len);
    let u_zp = u.seg(cz + 1, cy, b, len);
    let u_zm = u.seg(cz - 1, cy, b, len);
    let u_yp = u.seg(cz, cy + 1, b, len);
    let u_ym = u.seg(cz, cy - 1, b, len);
    let u_xp = u.seg(cz, cy, b + 1, len);
    let u_xm = u.seg(cz, cy, b - 1, len);
    let ec = eta.seg(cz, cy, b, len);
    let e_zp = eta.seg(cz + 1, cy, b, len);
    let e_zm = eta.seg(cz - 1, cy, b, len);
    let e_yp = eta.seg(cz, cy + 1, b, len);
    let e_ym = eta.seg(cz, cy - 1, b, len);
    let e_xp = eta.seg(cz, cy, b + 1, len);
    let e_xm = eta.seg(cz, cy, b - 1, len);
    let vs = v.seg(iz, iy, x0, len);
    let main = len - len % W;
    let mut i = 0;
    while i + W * U <= main {
        let mut j = 0;
        while j < U {
            pml_lanes::<W>(
                uc,
                u_zp,
                u_zm,
                u_yp,
                u_ym,
                u_xp,
                u_xm,
                ec,
                e_zp,
                e_zm,
                e_yp,
                e_ym,
                e_xp,
                e_xm,
                vs,
                out,
                i + j * W,
                k,
            );
            j += 1;
        }
        i += W * U;
    }
    while i + W <= main {
        pml_lanes::<W>(
            uc, u_zp, u_zm, u_yp, u_ym, u_xp, u_xm, ec, e_zp, e_zm, e_yp, e_ym, e_xp, e_xm, vs,
            out, i, k,
        );
        i += W;
    }
    if main < len {
        pml_row_scalar(u, v, eta, iz, iy, x0 + main, len - main, k, &mut out[main..]);
    }
}

// ---------------------------------------------------------------------------
// Dispatch

/// Expand a `(lanes, unroll)` pair into the matching monomorphization,
/// falling back to the scalar kernel for combos without one.
macro_rules! width_match {
    ($lanes:expr, $unroll:expr, $call:ident ( $($a:expr),* ), $fallback:expr) => {
        match ($lanes, $unroll) {
            (4, 1) => $call::<4, 1>($($a),*),
            (4, 2) => $call::<4, 2>($($a),*),
            (4, 4) => $call::<4, 4>($($a),*),
            (8, 1) => $call::<8, 1>($($a),*),
            (8, 2) => $call::<8, 2>($($a),*),
            (8, 4) => $call::<8, 4>($($a),*),
            (16, 1) => $call::<16, 1>($($a),*),
            (16, 2) => $call::<16, 2>($($a),*),
            (16, 4) => $call::<16, 4>($($a),*),
            _ => $fallback,
        }
    };
}

/// Route one inner row through the kernel recorded in `k.kern`. Called
/// by the `inner_row` dispatcher in the parent module whenever the
/// kernel is non-scalar.
#[inline]
pub(crate) fn inner_row_simd(
    u: FieldView<'_>,
    v: FieldView<'_>,
    iz: usize,
    iy: usize,
    x0: usize,
    len: usize,
    k: Consts,
    out: &mut [f32],
) {
    let kern = k.kern;
    #[cfg(all(feature = "simd", any(target_arch = "x86", target_arch = "x86_64")))]
    if kern.isa == Isa::Avx2 {
        // SAFETY: `Isa::Avx2` is only ever produced by `detect()`
        // after `is_x86_feature_detected!("avx2")` returned true on
        // this host, so the AVX2-compiled monomorphizations are safe
        // to enter.
        unsafe { x86::inner(kern, u, v, iz, iy, x0, len, k, out) };
        return;
    }
    width_match!(
        kern.lanes,
        kern.unroll,
        inner_row_w(u, v, iz, iy, x0, len, k, out),
        inner_row_scalar(u, v, iz, iy, x0, len, k, out)
    )
}

/// Route one PML row through the kernel recorded in `k.kern`.
#[inline]
pub(crate) fn pml_row_simd(
    u: FieldView<'_>,
    v: FieldView<'_>,
    eta: FieldView<'_>,
    iz: usize,
    iy: usize,
    x0: usize,
    len: usize,
    k: Consts,
    out: &mut [f32],
) {
    let kern = k.kern;
    #[cfg(all(feature = "simd", any(target_arch = "x86", target_arch = "x86_64")))]
    if kern.isa == Isa::Avx2 {
        // SAFETY: as in `inner_row_simd` — AVX2 presence was verified
        // by detection before this ISA could be selected.
        unsafe { x86::pml(kern, u, v, eta, iz, iy, x0, len, k, out) };
        return;
    }
    width_match!(
        kern.lanes,
        kern.unroll,
        pml_row_w(u, v, eta, iz, iy, x0, len, k, out),
        pml_row_scalar(u, v, eta, iz, iy, x0, len, k, out)
    )
}

/// AVX2 monomorphizations. `#[target_feature]` recompiles the (fully
/// `#[inline(always)]`) generic lane kernels with the AVX2 register
/// file and 256-bit ops; the arithmetic is the same element-wise f32
/// sequence, so results remain bit-identical to the scalar oracle —
/// wider registers change *how fast*, never *what*.
#[cfg(all(feature = "simd", any(target_arch = "x86", target_arch = "x86_64")))]
mod x86 {
    use super::*;

    macro_rules! avx2_pair {
        ($inner:ident, $pml:ident, $w:literal, $u:literal) => {
            /// SAFETY: requires AVX2 on the running host.
            #[target_feature(enable = "avx2")]
            unsafe fn $inner(
                u: FieldView<'_>,
                v: FieldView<'_>,
                iz: usize,
                iy: usize,
                x0: usize,
                len: usize,
                k: Consts,
                out: &mut [f32],
            ) {
                inner_row_w::<$w, $u>(u, v, iz, iy, x0, len, k, out)
            }

            /// SAFETY: requires AVX2 on the running host.
            #[target_feature(enable = "avx2")]
            unsafe fn $pml(
                u: FieldView<'_>,
                v: FieldView<'_>,
                eta: FieldView<'_>,
                iz: usize,
                iy: usize,
                x0: usize,
                len: usize,
                k: Consts,
                out: &mut [f32],
            ) {
                pml_row_w::<$w, $u>(u, v, eta, iz, iy, x0, len, k, out)
            }
        };
    }

    avx2_pair!(inner_w4_u1, pml_w4_u1, 4, 1);
    avx2_pair!(inner_w4_u2, pml_w4_u2, 4, 2);
    avx2_pair!(inner_w4_u4, pml_w4_u4, 4, 4);
    avx2_pair!(inner_w8_u1, pml_w8_u1, 8, 1);
    avx2_pair!(inner_w8_u2, pml_w8_u2, 8, 2);
    avx2_pair!(inner_w8_u4, pml_w8_u4, 8, 4);
    avx2_pair!(inner_w16_u1, pml_w16_u1, 16, 1);
    avx2_pair!(inner_w16_u2, pml_w16_u2, 16, 2);
    avx2_pair!(inner_w16_u4, pml_w16_u4, 16, 4);

    /// SAFETY: the caller must have verified AVX2 support on this host.
    pub(super) unsafe fn inner(
        kern: RowKernel,
        u: FieldView<'_>,
        v: FieldView<'_>,
        iz: usize,
        iy: usize,
        x0: usize,
        len: usize,
        k: Consts,
        out: &mut [f32],
    ) {
        match (kern.lanes, kern.unroll) {
            (4, 1) => inner_w4_u1(u, v, iz, iy, x0, len, k, out),
            (4, 2) => inner_w4_u2(u, v, iz, iy, x0, len, k, out),
            (4, 4) => inner_w4_u4(u, v, iz, iy, x0, len, k, out),
            (8, 1) => inner_w8_u1(u, v, iz, iy, x0, len, k, out),
            (8, 2) => inner_w8_u2(u, v, iz, iy, x0, len, k, out),
            (8, 4) => inner_w8_u4(u, v, iz, iy, x0, len, k, out),
            (16, 1) => inner_w16_u1(u, v, iz, iy, x0, len, k, out),
            (16, 2) => inner_w16_u2(u, v, iz, iy, x0, len, k, out),
            (16, 4) => inner_w16_u4(u, v, iz, iy, x0, len, k, out),
            _ => inner_row_scalar(u, v, iz, iy, x0, len, k, out),
        }
    }

    /// SAFETY: the caller must have verified AVX2 support on this host.
    pub(super) unsafe fn pml(
        kern: RowKernel,
        u: FieldView<'_>,
        v: FieldView<'_>,
        eta: FieldView<'_>,
        iz: usize,
        iy: usize,
        x0: usize,
        len: usize,
        k: Consts,
        out: &mut [f32],
    ) {
        match (kern.lanes, kern.unroll) {
            (4, 1) => pml_w4_u1(u, v, eta, iz, iy, x0, len, k, out),
            (4, 2) => pml_w4_u2(u, v, eta, iz, iy, x0, len, k, out),
            (4, 4) => pml_w4_u4(u, v, eta, iz, iy, x0, len, k, out),
            (8, 1) => pml_w8_u1(u, v, eta, iz, iy, x0, len, k, out),
            (8, 2) => pml_w8_u2(u, v, eta, iz, iy, x0, len, k, out),
            (8, 4) => pml_w8_u4(u, v, eta, iz, iy, x0, len, k, out),
            (16, 1) => pml_w16_u1(u, v, eta, iz, iy, x0, len, k, out),
            (16, 2) => pml_w16_u2(u, v, eta, iz, iy, x0, len, k, out),
            (16, 4) => pml_w16_u4(u, v, eta, iz, iy, x0, len, k, out),
            _ => pml_row_scalar(u, v, eta, iz, iy, x0, len, k, out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::{Dim3, Domain};
    use crate::testkit::Rng;

    /// Compare `inner_row_w` / `pml_row_w` against the scalar oracle
    /// on the tail-stress row lengths (W-1, W, W+1, 2W+3, full) at
    /// several offsets and row positions. Bit-identity, not tolerance.
    fn check_pair<const W: usize, const U: usize>() {
        let s = Dim3::new(6, 5, 40);
        let domain = Domain::new(s, 2, 10.0, 1e-3).unwrap();
        let mut rng = Rng::new(0x51AD + W as u64 * 131 + U as u64);
        let u_pad = rng.field(s).pad(R);
        let um_pad = rng.field(s).pad(R);
        let eta_pad = rng.field_in(s, 0.0, 50.0).pad(R);
        let v = rng.field_in(s, 1500.0, 3500.0);
        let k = Consts::of(&domain);
        let uv = u_pad.view();
        let vv = v.view();
        let ev = eta_pad.view();
        let w = W;
        for len in [w - 1, w, w + 1, 2 * w + 3, s.x] {
            for x0 in [0usize, 3] {
                if x0 + len > s.x {
                    continue;
                }
                for (iz, iy) in [(0usize, 0usize), (3, 2), (s.z - 1, s.y - 1)] {
                    let mut a = um_pad.clone();
                    let mut b = um_pad.clone();
                    inner_row_scalar(
                        uv,
                        vv,
                        iz,
                        iy,
                        x0,
                        len,
                        k,
                        a.view_mut().seg_mut(iz + R, iy + R, x0 + R, len),
                    );
                    inner_row_w::<W, U>(
                        uv,
                        vv,
                        iz,
                        iy,
                        x0,
                        len,
                        k,
                        b.view_mut().seg_mut(iz + R, iy + R, x0 + R, len),
                    );
                    assert_eq!(a.max_abs_diff(&b), 0.0, "inner W={W} U={U} len={len} x0={x0}");

                    let mut a = um_pad.clone();
                    let mut b = um_pad.clone();
                    pml_row_scalar(
                        uv,
                        vv,
                        ev,
                        iz,
                        iy,
                        x0,
                        len,
                        k,
                        a.view_mut().seg_mut(iz + R, iy + R, x0 + R, len),
                    );
                    pml_row_w::<W, U>(
                        uv,
                        vv,
                        ev,
                        iz,
                        iy,
                        x0,
                        len,
                        k,
                        b.view_mut().seg_mut(iz + R, iy + R, x0 + R, len),
                    );
                    assert_eq!(a.max_abs_diff(&b), 0.0, "pml W={W} U={U} len={len} x0={x0}");
                }
            }
        }
    }

    #[test]
    fn wide_kernels_match_scalar_bitwise_w4() {
        check_pair::<4, 1>();
        check_pair::<4, 2>();
        check_pair::<4, 4>();
    }

    #[test]
    fn wide_kernels_match_scalar_bitwise_w8() {
        check_pair::<8, 1>();
        check_pair::<8, 2>();
        check_pair::<8, 4>();
    }

    #[test]
    fn wide_kernels_match_scalar_bitwise_w16() {
        check_pair::<16, 1>();
        check_pair::<16, 2>();
        check_pair::<16, 4>();
    }

    #[test]
    fn odd_grid_full_sweep_is_bit_identical() {
        // An odd interior (x = 37 leaves a 5-point tail at W = 8, a
        // 1-point tail at W = 4) swept row by row through both paths.
        let s = Dim3::new(5, 7, 37);
        let domain = Domain::new(s, 2, 10.0, 1e-3).unwrap();
        let mut rng = Rng::new(0x0DDD);
        let u_pad = rng.field(s).pad(R);
        let um_pad = rng.field(s).pad(R);
        let eta_pad = rng.field_in(s, 0.0, 50.0).pad(R);
        let v = rng.field_in(s, 1500.0, 3500.0);
        let k = Consts::of(&domain);
        let (uv, vv, ev) = (u_pad.view(), v.view(), eta_pad.view());
        let mut scalar = um_pad.clone();
        let mut wide = um_pad.clone();
        for iz in 0..s.z {
            for iy in 0..s.y {
                inner_row_scalar(
                    uv,
                    vv,
                    iz,
                    iy,
                    0,
                    s.x,
                    k,
                    scalar.view_mut().seg_mut(iz + R, iy + R, R, s.x),
                );
                inner_row_w::<8, 2>(
                    uv,
                    vv,
                    iz,
                    iy,
                    0,
                    s.x,
                    k,
                    wide.view_mut().seg_mut(iz + R, iy + R, R, s.x),
                );
            }
        }
        assert_eq!(scalar.max_abs_diff(&wide), 0.0, "inner full sweep");
        let mut scalar = um_pad.clone();
        let mut wide = um_pad.clone();
        for iz in 0..s.z {
            for iy in 0..s.y {
                pml_row_scalar(
                    uv,
                    vv,
                    ev,
                    iz,
                    iy,
                    0,
                    s.x,
                    k,
                    scalar.view_mut().seg_mut(iz + R, iy + R, R, s.x),
                );
                pml_row_w::<4, 4>(
                    uv,
                    vv,
                    ev,
                    iz,
                    iy,
                    0,
                    s.x,
                    k,
                    wide.view_mut().seg_mut(iz + R, iy + R, R, s.x),
                );
            }
        }
        assert_eq!(scalar.max_abs_diff(&wide), 0.0, "pml full sweep");
    }

    #[test]
    fn pml_rows_split_at_region_seams_match_full_rows() {
        // A row updated in two wide pieces (the x-seam between two PML
        // regions) must equal one full scalar row: each piece runs its
        // own chunk/tail split, so seams stress every tail path.
        let s = Dim3::new(6, 6, 23);
        let domain = Domain::new(s, 2, 10.0, 1e-3).unwrap();
        let mut rng = Rng::new(0x5EA3);
        let u_pad = rng.field(s).pad(R);
        let um_pad = rng.field(s).pad(R);
        let eta_pad = rng.field_in(s, 0.0, 50.0).pad(R);
        let v = rng.field_in(s, 1500.0, 3500.0);
        let k = Consts::of(&domain);
        let (uv, vv, ev) = (u_pad.view(), v.view(), eta_pad.view());
        let (iz, iy) = (2, 4);
        let mut full = um_pad.clone();
        pml_row_scalar(
            uv,
            vv,
            ev,
            iz,
            iy,
            0,
            s.x,
            k,
            full.view_mut().seg_mut(iz + R, iy + R, R, s.x),
        );
        for split in [1usize, 4, 7, 8, 9, 19] {
            let mut parts = um_pad.clone();
            pml_row_w::<8, 1>(
                uv,
                vv,
                ev,
                iz,
                iy,
                0,
                split,
                k,
                parts.view_mut().seg_mut(iz + R, iy + R, R, split),
            );
            pml_row_w::<8, 1>(
                uv,
                vv,
                ev,
                iz,
                iy,
                split,
                s.x - split,
                k,
                parts.view_mut().seg_mut(iz + R, iy + R, R + split, s.x - split),
            );
            assert_eq!(full.max_abs_diff(&parts), 0.0, "seam at x = {split}");
        }
    }

    #[test]
    fn dispatch_is_cached_and_encodes_forces() {
        let a = detected();
        assert_eq!(a, detected(), "detection must be stable");
        assert!(a.lanes >= 1);
        if !cfg!(feature = "simd") {
            assert_eq!(a, RowKernel::SCALAR, "feature off must dispatch scalar");
        }
        // Pure decode checks (no global state): scalar force, width
        // force, width force on a scalar-detected host.
        assert_eq!(decode_force(0, a), a);
        assert_eq!(decode_force(encode_force(1, 1), a), RowKernel::SCALAR);
        let f = decode_force(encode_force(8, 2), a);
        assert_eq!((f.lanes, f.unroll), (8, 2));
        assert_ne!(f.isa, Isa::Scalar);
        let g = decode_force(encode_force(16, 4), RowKernel::SCALAR);
        assert_eq!((g.isa, g.lanes, g.unroll), (Isa::Portable, 16, 4));
        // Unsupported combos are rejected without touching the override.
        assert!(!force(5, 2));
        assert!(!force(8, 3));
        assert_eq!(RowKernel::SCALAR.tag(), "scalar");
        assert_eq!(RowKernel { isa: Isa::Avx2, lanes: 8, unroll: 2 }.tag(), "avx2x8");
        assert_eq!(RowKernel { isa: Isa::Neon, lanes: 4, unroll: 1 }.tag(), "neonx4");
    }
}
