//! Golden CPU propagator: full decomposed time stepping in pure Rust.
//!
//! This is the oracle the integration tests compare PJRT output against,
//! and the fallback backend when no artifacts are present.

use crate::grid::{decompose, Dim3, Domain, Field3};
use crate::R;

/// A self-contained CPU wave propagator over the 7-region decomposition.
pub struct GoldenPropagator {
    pub domain: Domain,
    /// Velocity model, interior-sized.
    pub v: Field3,
    /// Damping profile, R-ghost-padded (zero ghost).
    pub eta_pad: Field3,
    /// Wavefield at step n, R-ghost-padded.
    pub u_pad: Field3,
    /// Wavefield at step n-1, interior-sized.
    pub um: Field3,
    steps_done: usize,
}

impl GoldenPropagator {
    pub fn new(domain: Domain, v: Field3, eta: Field3) -> Self {
        assert_eq!(v.dims(), domain.interior, "velocity must be interior-sized");
        assert_eq!(eta.dims(), domain.interior, "eta must be interior-sized");
        GoldenPropagator {
            domain,
            v,
            eta_pad: eta.pad(R),
            u_pad: Field3::zeros(domain.padded()),
            um: Field3::zeros(domain.interior),
            steps_done: 0,
        }
    }

    /// One decomposed step: per-region stencil + scatter, no source.
    /// Returns the new interior wavefield.
    pub fn step_decomposed(&self) -> Field3 {
        let mut out = Field3::zeros(self.domain.interior);
        for reg in decompose(&self.domain) {
            let um_t = self.um.extract(reg.offset, reg.shape);
            let v_t = self.v.extract(reg.offset, reg.shape);
            let tile = if reg.class.is_pml() {
                let u_t = self.u_pad.extract_padded_region(R, reg.offset, reg.shape, 1);
                let e_t = self.eta_pad.extract_padded_region(R, reg.offset, reg.shape, 1);
                super::step_pml(&u_t, &um_t, &v_t, &e_t, self.domain.dt, self.domain.h)
            } else {
                let u_t = self.u_pad.extract_padded_region(R, reg.offset, reg.shape, R);
                super::step_inner(&u_t, &um_t, &v_t, self.domain.dt, self.domain.h)
            };
            out.scatter(reg.offset, &tile);
        }
        out
    }

    /// Advance one step, injecting `src_amp` at interior point `src`.
    pub fn advance(&mut self, src: Dim3, src_amp: f32) {
        let mut un = self.step_decomposed();
        un.add(src.z, src.y, src.x, src_amp);
        self.um = self.u_pad.unpad(R);
        self.u_pad = un.pad(R);
        self.steps_done += 1;
    }

    pub fn steps_done(&self) -> usize {
        self.steps_done
    }

    /// Current interior wavefield.
    pub fn wavefield(&self) -> Field3 {
        self.u_pad.unpad(R)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wave;

    fn tiny() -> GoldenPropagator {
        let interior = Dim3::new(24, 24, 24);
        let h = 10.0;
        let dt = crate::stencil::cfl_dt(h, 2000.0);
        let domain = Domain::new(interior, 4, h, dt).unwrap();
        let v = Field3::full(interior, 2000.0);
        let eta = wave::eta_profile(&domain, 2000.0);
        GoldenPropagator::new(domain, v, eta)
    }

    #[test]
    fn zero_field_stays_zero_without_source() {
        let mut p = tiny();
        for _ in 0..5 {
            p.advance(Dim3::new(12, 12, 12), 0.0);
        }
        assert_eq!(p.wavefield().max_abs(), 0.0);
    }

    #[test]
    fn source_produces_bounded_finite_field() {
        let mut p = tiny();
        let src = Dim3::new(12, 12, 12);
        for n in 0..80 {
            let w = wave::ricker(n as f64 * p.domain.dt, 15.0);
            p.advance(src, (p.domain.dt * p.domain.dt * 2000.0 * 2000.0 * w) as f32);
        }
        let u = p.wavefield();
        assert!(!u.has_non_finite());
        assert!(u.max_abs() > 0.0);
        assert!(u.max_abs() < 1e3);
        assert_eq!(p.steps_done(), 80);
    }

    #[test]
    fn energy_decays_with_pml_after_boundary_contact() {
        // identical runs, with and without damping
        let mut with_pml = tiny();
        let interior = with_pml.domain.interior;
        let mut without = GoldenPropagator::new(
            with_pml.domain,
            Field3::full(interior, 2000.0),
            Field3::zeros(interior),
        );
        let src = Dim3::new(12, 12, 12);
        for n in 0..200 {
            let w = wave::ricker(n as f64 * with_pml.domain.dt, 15.0);
            let amp = (with_pml.domain.dt * with_pml.domain.dt * 2000.0 * 2000.0 * w) as f32;
            with_pml.advance(src, amp);
            without.advance(src, amp);
        }
        let e_pml = with_pml.wavefield().energy();
        let e_ref = without.wavefield().energy();
        assert!(
            e_pml < 0.5 * e_ref,
            "PML must absorb boundary energy: {e_pml} vs {e_ref}"
        );
    }
}
