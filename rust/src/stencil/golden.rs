//! Golden CPU propagator: full decomposed time stepping in pure Rust.
//!
//! This is the oracle the integration tests compare PJRT output against,
//! and the fallback backend when no artifacts are present.
//!
//! The hot path is zero-allocation: two persistent R-ghost-padded
//! buffers ping-pong each step — the fused row kernels overwrite the
//! step n-1 buffer in place (its center values are the leapfrog `um`
//! term) and the buffers swap. [`GoldenPropagator::step_decomposed`]
//! keeps the original allocating two-pass extract/scatter pipeline as
//! the readable spec; `advance` is asserted bit-identical to it.

use crate::grid::{decompose, Dim3, Domain, Field3, Region};
use crate::R;

use super::Consts;

/// A self-contained CPU wave propagator over the 7-region decomposition.
pub struct GoldenPropagator {
    pub domain: Domain,
    /// Velocity model, interior-sized.
    pub v: Field3,
    /// Damping profile, R-ghost-padded (zero ghost).
    pub eta_pad: Field3,
    /// Wavefield at step n, R-ghost-padded.
    pub u_pad: Field3,
    /// Wavefield at step n-1, R-ghost-padded; overwritten in place by
    /// each `advance` and swapped with `u_pad`.
    pub um_pad: Field3,
    /// The 7 launch regions, computed once.
    regions: Vec<Region>,
    steps_done: usize,
}

impl GoldenPropagator {
    pub fn new(domain: Domain, v: Field3, eta: Field3) -> Self {
        assert_eq!(v.dims(), domain.interior, "velocity must be interior-sized");
        assert_eq!(eta.dims(), domain.interior, "eta must be interior-sized");
        GoldenPropagator {
            v,
            eta_pad: eta.pad(R),
            u_pad: Field3::zeros(domain.padded()),
            um_pad: Field3::zeros(domain.padded()),
            regions: decompose(&domain),
            domain,
            steps_done: 0,
        }
    }

    /// One decomposed step through the allocating two-pass spec:
    /// per-region extract -> `step_inner`/`step_pml` -> scatter. Kept
    /// off the hot path as the readable reference the in-place
    /// `advance` is asserted against. Returns the new interior
    /// wavefield.
    pub fn step_decomposed(&self) -> Field3 {
        let mut out = Field3::zeros(self.domain.interior);
        for reg in &self.regions {
            let um_t = self.um_pad.extract_padded_region(R, reg.offset, reg.shape, 0);
            let v_t = self.v.extract(reg.offset, reg.shape);
            let tile = if reg.class.is_pml() {
                let u_t = self.u_pad.extract_padded_region(R, reg.offset, reg.shape, 1);
                let e_t = self.eta_pad.extract_padded_region(R, reg.offset, reg.shape, 1);
                super::step_pml(&u_t, &um_t, &v_t, &e_t, self.domain.dt, self.domain.h)
            } else {
                let u_t = self.u_pad.extract_padded_region(R, reg.offset, reg.shape, R);
                super::step_inner(&u_t, &um_t, &v_t, self.domain.dt, self.domain.h)
            };
            out.scatter(reg.offset, &tile);
        }
        out
    }

    /// Advance one step, injecting `src_amp` at interior point `src`.
    /// Zero-allocation: the fused row kernels overwrite `um_pad` in
    /// place (reading its center values as the leapfrog `um` term),
    /// then the padded buffers swap.
    pub fn advance(&mut self, src: Dim3, src_amp: f32) {
        let k = Consts::of(&self.domain);
        {
            let u = self.u_pad.view();
            let v = self.v.view();
            let e = self.eta_pad.view();
            let mut out = self.um_pad.view_mut();
            for reg in &self.regions {
                for dz in 0..reg.shape.z {
                    for dy in 0..reg.shape.y {
                        let (iz, iy) = (reg.offset.z + dz, reg.offset.y + dy);
                        let row = out.seg_mut(iz + R, iy + R, reg.offset.x + R, reg.shape.x);
                        if reg.class.is_pml() {
                            super::pml_row(u, v, e, iz, iy, reg.offset.x, reg.shape.x, k, row);
                        } else {
                            super::inner_row(u, v, iz, iy, reg.offset.x, reg.shape.x, k, row);
                        }
                    }
                }
            }
        }
        std::mem::swap(&mut self.u_pad, &mut self.um_pad);
        self.u_pad.add(R + src.z, R + src.y, R + src.x, src_amp);
        self.steps_done += 1;
    }

    pub fn steps_done(&self) -> usize {
        self.steps_done
    }

    /// Current interior wavefield.
    pub fn wavefield(&self) -> Field3 {
        self.u_pad.unpad(R)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wave;

    fn tiny() -> GoldenPropagator {
        let interior = Dim3::new(24, 24, 24);
        let h = 10.0;
        let dt = crate::stencil::cfl_dt(h, 2000.0);
        let domain = Domain::new(interior, 4, h, dt).unwrap();
        let v = Field3::full(interior, 2000.0);
        let eta = wave::eta_profile(&domain, 2000.0);
        GoldenPropagator::new(domain, v, eta)
    }

    #[test]
    fn zero_field_stays_zero_without_source() {
        let mut p = tiny();
        for _ in 0..5 {
            p.advance(Dim3::new(12, 12, 12), 0.0);
        }
        assert_eq!(p.wavefield().max_abs(), 0.0);
    }

    #[test]
    fn source_produces_bounded_finite_field() {
        let mut p = tiny();
        let src = Dim3::new(12, 12, 12);
        for n in 0..80 {
            let w = wave::ricker(n as f64 * p.domain.dt, 15.0);
            p.advance(src, (p.domain.dt * p.domain.dt * 2000.0 * 2000.0 * w) as f32);
        }
        let u = p.wavefield();
        assert!(!u.has_non_finite());
        assert!(u.max_abs() > 0.0);
        assert!(u.max_abs() < 1e3);
        assert_eq!(p.steps_done(), 80);
    }

    #[test]
    fn in_place_advance_matches_the_two_pass_spec_bitwise() {
        // `advance` (fused row kernels, ping-pong buffers) must track
        // the allocating extract/step/scatter reference bit for bit,
        // including the source-injection and rotation order
        let mut fast = tiny();
        let mut spec = tiny();
        let src = Dim3::new(12, 12, 12);
        for n in 0..40 {
            let w = wave::ricker(n as f64 * fast.domain.dt, 15.0);
            let amp = (fast.domain.dt * fast.domain.dt * 2000.0 * 2000.0 * w) as f32;
            fast.advance(src, amp);
            // the pre-refactor advance: fresh output + pad/unpad rotation
            let mut un = spec.step_decomposed();
            un.add(src.z, src.y, src.x, amp);
            let prev_u = std::mem::replace(&mut spec.u_pad, un.pad(R));
            spec.um_pad = prev_u;
        }
        assert_eq!(fast.u_pad.max_abs_diff(&spec.u_pad), 0.0, "u diverged from spec");
        assert_eq!(fast.um_pad.max_abs_diff(&spec.um_pad), 0.0, "um diverged from spec");
        assert!(fast.wavefield().max_abs() > 0.0, "wave must have propagated");
    }

    #[test]
    fn ghost_ring_stays_zero_across_steps() {
        let mut p = tiny();
        for n in 0..12 {
            let w = wave::ricker(n as f64 * p.domain.dt, 15.0);
            p.advance(Dim3::new(12, 12, 12), (p.domain.dt * p.domain.dt * 4e6 * w) as f32);
        }
        assert_eq!(p.u_pad.unpad(R).pad(R), p.u_pad, "ghost ring must stay zero");
    }

    #[test]
    fn energy_decays_with_pml_after_boundary_contact() {
        // identical runs, with and without damping
        let mut with_pml = tiny();
        let interior = with_pml.domain.interior;
        let mut without = GoldenPropagator::new(
            with_pml.domain,
            Field3::full(interior, 2000.0),
            Field3::zeros(interior),
        );
        let src = Dim3::new(12, 12, 12);
        for n in 0..200 {
            let w = wave::ricker(n as f64 * with_pml.domain.dt, 15.0);
            let amp = (with_pml.domain.dt * with_pml.domain.dt * 2000.0 * 2000.0 * w) as f32;
            with_pml.advance(src, amp);
            without.advance(src, amp);
        }
        let e_pml = with_pml.wavefield().energy();
        let e_ref = without.wavefield().energy();
        assert!(
            e_pml < 0.5 * e_ref,
            "PML must absorb boundary energy: {e_pml} vs {e_ref}"
        );
    }
}
