//! Table/figure renderers: regenerate the paper's evaluation artifacts
//! (Tables I-IV, Figure 3) from the `gpusim` models, side by side with
//! the published numbers.

pub mod paperdata;

use crate::gpusim::{arch, occupancy, timing};
use crate::grid::Dim3;

fn hr(width: usize) -> String {
    "-".repeat(width)
}

/// Table I: machine specifications.
pub fn table1() -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<14}{:>12}{:>12}{:>12}\n",
        "Table I", "V100", "P100", "NVS510"
    ));
    out.push_str(&hr(50));
    out.push('\n');
    let rows: Vec<(&str, Box<dyn Fn(&arch::GpuArch) -> String>)> = vec![
        ("SMs", Box::new(|a: &arch::GpuArch| a.sm_count.to_string())),
        ("sm version", Box::new(|a| a.sm_version.to_string())),
        ("DRAM GB/s", Box::new(|a| format!("{:.0}", a.dram_gbps))),
        ("L2 GB/s", Box::new(|a| format!("{:.0}", a.l2_gbps))),
        ("L2 bytes", Box::new(|a| format!("{}K", a.l2_bytes / 1024))),
        ("fp32 GF/s", Box::new(|a| format!("{:.0}", a.fp32_gflops))),
        ("eval grid", Box::new(|a| format!("{0}^3", a.eval_grid))),
    ];
    let machines = arch::all();
    for (name, f) in rows {
        out.push_str(&format!(
            "{:<14}{:>12}{:>12}{:>12}\n",
            name,
            f(&machines[0]),
            f(&machines[1]),
            f(&machines[2])
        ));
    }
    out
}

/// Table II: modeled wall-time (s, 1000 steps) vs the paper's
/// measurements on all three machines.
pub fn table2(steps: usize) -> String {
    let machines = arch::all();
    let mut out = format!(
        "{:<20}{:>9}{:>9}{:>7}{:>9}{:>9}{:>7}{:>9}{:>9}{:>7}\n",
        "Table II (s)", "V100", "paper", "d%", "P100", "paper", "d%", "NVS510", "paper", "d%"
    );
    out.push_str(&hr(95));
    out.push('\n');
    let runs: Vec<Vec<timing::KernelRun>> =
        machines.iter().map(|a| timing::simulate_all(a, steps)).collect();
    for (i, v) in crate::gpusim::kernels::paper_variants().iter().enumerate() {
        let p = paperdata::table2_row(v.id).expect("paper row");
        let paper = [p.v100, p.p100, p.nvs510];
        out.push_str(&format!("{:<20}", v.id));
        for m in 0..3 {
            let model = runs[m][i].time_s;
            let delta = 100.0 * (model - paper[m]) / paper[m];
            out.push_str(&format!("{model:>9.2}{:>9.2}{delta:>+7.0}", paper[m]));
        }
        out.push('\n');
    }
    out
}

/// Table III (inner region, V100): occupancy model vs paper.
pub fn table3() -> String {
    let a = arch::v100();
    let inner = Dim3::new(
        a.eval_grid - 2 * a.eval_pml_width,
        a.eval_grid - 2 * a.eval_pml_width,
        a.eval_grid - 2 * a.eval_pml_width,
    );
    let mut out = format!(
        "{:<20}{:>7}{:>11}{:>6}{:>8}{:>8}{:>9}{:>9}{:>8}{:>8}\n",
        "Table III (V100)",
        "block",
        "grid",
        "regs",
        "thWarps",
        "paper",
        "achWarps",
        "paper",
        "thOcc%",
        "paper"
    );
    out.push_str(&hr(94));
    out.push('\n');
    for v in crate::gpusim::kernels::paper_variants() {
        let p = paperdata::table3_row(v.id).expect("paper row");
        let occ = occupancy::occupancy(&a, &v.resources_inner());
        let grid = v.grid_blocks(inner);
        let ach = occupancy::achieved_warps(&a, &occ, grid, 0.97);
        out.push_str(&format!(
            "{:<20}{:>7}{:>11}{:>6}{:>8}{:>8.1}{:>9.1}{:>9.1}{:>8.1}{:>8.1}\n",
            v.id,
            v.threads_per_block(),
            grid,
            v.regs_inner,
            occ.active_warps,
            p.theoretical_warps,
            ach,
            p.achieved_warps,
            occ.occupancy_pct,
            p.theoretical_occupancy,
        ));
    }
    out
}

/// Table IV (V100): performance characteristics, model vs paper.
pub fn table4(steps: usize) -> String {
    let a = arch::v100();
    let runs = timing::simulate_all(&a, steps);
    let mut out = format!(
        "{:<20}{:>8}{:>7}{:>7}{:>8}{:>7}{:>7}{:>8}{:>7}{:>8}{:>8}\n",
        "Table IV (V100)",
        "GF/s",
        "paper",
        "aiL2",
        "paper",
        "aiDRAM",
        "paper",
        "L2e12",
        "paper",
        "DRe11",
        "paper"
    );
    out.push_str(&hr(95));
    out.push('\n');
    for r in &runs {
        let p = paperdata::table4_row(r.variant_id).expect("paper row");
        out.push_str(&format!(
            "{:<20}{:>8.0}{:>7.0}{:>7.2}{:>8.2}{:>7.2}{:>7.2}{:>8.2}{:>7.2}{:>8.2}{:>8.2}\n",
            r.variant_id,
            r.gflops,
            p.gflops,
            r.ai_l2,
            p.ai_l2,
            r.ai_dram,
            p.ai_dram,
            r.l2_transactions / 1e12,
            p.l2_trans_e12,
            r.dram_transactions / 1e11,
            p.dram_trans_e11,
        ));
    }
    out
}

/// Figure 3: roofline plot data (ASCII) + CSV for external plotting.
pub fn fig3(machine: &str, steps: usize) -> anyhow::Result<(String, String)> {
    let a = arch::by_name(machine)?;
    let runs = timing::simulate_all(&a, steps);
    let data = crate::gpusim::roofline::roofline_data(&a, &runs);
    let mut text = String::new();
    text.push_str(&data.ascii_plot(false));
    text.push('\n');
    text.push_str(&data.ascii_plot(true));
    Ok((text, data.csv()))
}

/// Campaign verdict table: one row per scenario x variant x machine
/// cell, plus an aggregate footer. (The campaign itself lives in
/// `crate::scenario::campaign`; this is just its renderer, kept with
/// the other table renderers.)
pub fn campaign_table(report: &crate::scenario::campaign::CampaignReport) -> String {
    let mut out = format!(
        "{:<26}{:<20}{:<9}{:>9}{:>7}{:>11}{:>11}{:>10}{:>9}  {}\n",
        "scenario", "variant", "machine", "verdict", "steps", "meas st/s", "pred st/s",
        "kern ms", "leak", "notes"
    );
    out.push_str(&hr(126));
    out.push('\n');
    for c in &report.cells {
        let notes = if let Some(e) = &c.error {
            format!("error: {e}")
        } else if c.verdict == crate::scenario::Verdict::Pass {
            String::new()
        } else if c.verdict == c.expected {
            format!("expected ({})", c.failed_criteria.join(", "))
        } else {
            c.failed_criteria.join(", ")
        };
        out.push_str(&format!(
            "{:<26}{:<20}{:<9}{:>9}{:>7}{:>11.1}{:>11.1}{:>10.1}{:>9.3}  {}\n",
            c.scenario.name(),
            c.variant,
            c.machine,
            c.verdict.name(),
            c.steps_completed,
            c.measured_steps_per_sec,
            c.predicted_steps_per_sec,
            c.batch_wall_ms,
            c.boundary_leakage,
            notes
        ));
    }
    out.push_str(&hr(126));
    out.push('\n');
    out.push_str(&format!(
        "{} cells: {} Pass, {} SoftFail, {} HardFail ({} off-expectation) — \
         {:.2?} on {} threads, {} shared physics run(s), row kernel {}\n",
        report.cells.len(),
        report.count(crate::scenario::Verdict::Pass),
        report.count(crate::scenario::Verdict::SoftFail),
        report.count(crate::scenario::Verdict::HardFail),
        report.off_expectation_count(),
        report.wall,
        report.threads,
        report.physics_runs,
        // the dispatched CPU row kernel (scalar / avx2x8 / ...): the
        // measured columns are only comparable across machines when
        // the dispatch is known
        crate::stencil::simd::active().tag()
    ));
    out
}

/// Kendall-tau-style rank agreement between model times and paper times
/// on one machine: fraction of concordant variant pairs. Used by tests
/// and EXPERIMENTS.md to quantify "the shape holds".
pub fn rank_agreement(machine: &str, steps: usize) -> anyhow::Result<f64> {
    let a = arch::by_name(machine)?;
    let runs = timing::simulate_all(&a, steps);
    let sel = |r: &paperdata::Table2Row| -> f64 {
        match machine.to_ascii_lowercase().as_str() {
            "v100" => r.v100,
            "p100" => r.p100,
            _ => r.nvs510,
        }
    };
    let pairs: Vec<(f64, f64)> = runs
        .iter()
        .map(|r| {
            let p = paperdata::table2_row(r.variant_id).expect("paper row");
            (r.time_s, sel(p))
        })
        .collect();
    let mut concordant = 0usize;
    let mut total = 0usize;
    for i in 0..pairs.len() {
        for j in i + 1..pairs.len() {
            total += 1;
            let model = pairs[i].0 - pairs[j].0;
            let paper = pairs[i].1 - pairs[j].1;
            if model * paper > 0.0 {
                concordant += 1;
            }
        }
    }
    Ok(concordant as f64 / total as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_renders_three_machines() {
        let t = table1();
        assert!(t.contains("V100") && t.contains("P100") && t.contains("NVS510"));
        assert!(t.contains("sm_70"));
    }

    #[test]
    fn table2_has_25_rows_plus_header() {
        let t = table2(1000);
        assert_eq!(t.lines().count(), 2 + 25);
        assert!(t.contains("gmem_8x8x8"));
    }

    #[test]
    fn table3_theoretical_matches_paper_exactly() {
        // the occupancy calculator must reproduce every published value
        let a = arch::v100();
        for v in crate::gpusim::kernels::paper_variants() {
            let p = paperdata::table3_row(v.id).unwrap();
            let occ = occupancy::occupancy(&a, &v.resources_inner());
            assert_eq!(
                occ.active_warps as f64, p.theoretical_warps,
                "{}: theoretical warps",
                v.id
            );
            assert!((occ.occupancy_pct - p.theoretical_occupancy).abs() < 0.3, "{}", v.id);
        }
    }

    #[test]
    fn table4_renders() {
        let t = table4(1000);
        assert_eq!(t.lines().count(), 2 + 25);
    }

    #[test]
    fn fig3_produces_plot_and_csv() {
        let (text, csv) = fig3("v100", 100).unwrap();
        assert!(text.contains("DRAM roofline"));
        assert_eq!(csv.lines().count(), 51);
    }

    #[test]
    fn campaign_table_renders_cells_and_footer() {
        use crate::scenario::campaign::{run_campaign, CampaignSpec};
        use crate::scenario::ScenarioId;
        let spec = CampaignSpec {
            scenarios: vec![ScenarioId::TinyGrid],
            variants: vec!["gmem_8x8x8".to_string()],
            machines: vec!["v100".to_string()],
            steps_scale: Some(0.5),
            threads: 1,
            sample_every: 0,
            telemetry: None,
        };
        let t = campaign_table(&run_campaign(&spec));
        assert!(t.contains("tiny-grid"), "{t}");
        assert!(t.contains("gmem_8x8x8"));
        assert!(t.contains("meas st/s") && t.contains("pred st/s"), "{t}");
        assert!(t.contains("kern ms"), "the telemetry wall column must render: {t}");
        assert!(t.contains("1 cells:"), "{t}");
        assert!(t.contains("1 shared physics run(s)"), "{t}");
        // footer records the dispatched row kernel so BENCH/campaign
        // artifacts are comparable across machines (the tag itself is
        // not asserted: a parallel test may hold a lane-force override)
        assert!(t.contains("row kernel "), "{t}");
    }

    #[test]
    fn rank_agreement_is_meaningful() {
        // the model must order variant pairs like the paper far more
        // often than chance on every machine
        for m in ["v100", "p100", "nvs510"] {
            let tau = rank_agreement(m, 100).unwrap();
            assert!(tau > 0.70, "{m}: rank agreement only {tau}");
        }
    }
}
