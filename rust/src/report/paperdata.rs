//! The paper's published evaluation numbers, embedded verbatim so the
//! report module can print model-vs-paper deltas and the test suite can
//! assert that the simulator reproduces the paper's *shape* (orderings,
//! ratios, crossovers).
//!
//! Sources: Table II (wall-time seconds, 1000 steps), Table III (V100
//! kernel characteristics, inner region), Table IV (V100 performance
//! characteristics, whole execution).

/// Table II row: measured seconds on each machine.
#[derive(Copy, Clone, Debug)]
pub struct Table2Row {
    pub id: &'static str,
    pub v100: f64,
    pub p100: f64,
    pub nvs510: f64,
}

pub const TABLE2: &[Table2Row] = &[
    Table2Row { id: "gmem_4x4x4", v100: 77.77, p100: 181.99, nvs510: 682.89 },
    Table2Row { id: "gmem_8x8x4", v100: 71.91, p100: 167.75, nvs510: 674.09 },
    Table2Row { id: "gmem_8x8x8", v100: 53.88, p100: 117.74, nvs510: 415.85 },
    Table2Row { id: "gmem_16x16x4", v100: 85.52, p100: 195.82, nvs510: 760.72 },
    Table2Row { id: "gmem_32x32x1", v100: 292.36, p100: 639.62, nvs510: 2507.22 },
    Table2Row { id: "smem_u", v100: 57.30, p100: 76.18, nvs510: 210.42 },
    Table2Row { id: "smem_eta_1", v100: 54.87, p100: 119.15, nvs510: 397.56 },
    Table2Row { id: "smem_eta_3", v100: 54.34, p100: 117.39, nvs510: 396.49 },
    Table2Row { id: "semi", v100: 172.84, p100: 217.29, nvs510: 1726.17 },
    Table2Row { id: "st_smem_8x8", v100: 116.38, p100: 112.71, nvs510: 509.18 },
    Table2Row { id: "st_smem_8x16", v100: 113.46, p100: 105.41, nvs510: 439.47 },
    Table2Row { id: "st_smem_16x8", v100: 59.92, p100: 77.91, nvs510: 425.73 },
    Table2Row { id: "st_smem_16x16", v100: 55.87, p100: 72.73, nvs510: 349.45 },
    Table2Row { id: "st_reg_shft_8x8", v100: 104.36, p100: 144.89, nvs510: 209.87 },
    Table2Row { id: "st_reg_shft_16x16", v100: 65.79, p100: 80.23, nvs510: 182.52 },
    Table2Row { id: "st_reg_shft_16x32", v100: 65.61, p100: 82.25, nvs510: 199.61 },
    Table2Row { id: "st_reg_shft_16x64", v100: 115.54, p100: 98.19, nvs510: 240.41 },
    Table2Row { id: "st_reg_shft_32x16", v100: 60.83, p100: 70.63, nvs510: 171.30 },
    Table2Row { id: "st_reg_shft_32x32", v100: 93.92, p100: 76.27, nvs510: 167.29 },
    Table2Row { id: "st_reg_shft_64x16", v100: 90.98, p100: 80.67, nvs510: 202.74 },
    Table2Row { id: "st_reg_fixed_8x8", v100: 113.88, p100: 152.75, nvs510: 195.05 },
    Table2Row { id: "st_reg_fixed_16x8", v100: 70.24, p100: 84.05, nvs510: 159.73 },
    Table2Row { id: "st_reg_fixed_16x16", v100: 61.66, p100: 76.10, nvs510: 170.03 },
    Table2Row { id: "st_reg_fixed_32x16", v100: 62.45, p100: 66.60, nvs510: 162.05 },
    Table2Row { id: "st_reg_fixed_32x32", v100: 58.96, p100: 61.74, nvs510: 160.91 },
];

/// Table III row (V100, inner region).
#[derive(Copy, Clone, Debug)]
pub struct Table3Row {
    pub id: &'static str,
    pub block_size: u32,
    pub grid_size: u64,
    pub regs_per_thread: u32,
    pub achieved_warps: f64,
    pub achieved_occupancy: f64,
    pub theoretical_warps: f64,
    pub theoretical_occupancy: f64,
}

pub const TABLE3_INNER: &[Table3Row] = &[
    Table3Row { id: "gmem_4x4x4", block_size: 64, grid_size: 13_312_053, regs_per_thread: 40, achieved_warps: 37.2, achieved_occupancy: 58.2, theoretical_warps: 48.0, theoretical_occupancy: 75.0 },
    Table3Row { id: "gmem_8x8x4", block_size: 256, grid_size: 3_356_157, regs_per_thread: 40, achieved_warps: 44.0, achieved_occupancy: 68.7, theoretical_warps: 48.0, theoretical_occupancy: 75.0 },
    Table3Row { id: "gmem_8x8x8", block_size: 512, grid_size: 1_685_159, regs_per_thread: 40, achieved_warps: 42.5, achieved_occupancy: 66.4, theoretical_warps: 48.0, theoretical_occupancy: 75.0 },
    Table3Row { id: "gmem_16x16x4", block_size: 1024, grid_size: 853_200, regs_per_thread: 40, achieved_warps: 28.9, achieved_occupancy: 45.2, theoretical_warps: 32.0, theoretical_occupancy: 50.0 },
    Table3Row { id: "gmem_32x32x1", block_size: 1024, grid_size: 851_400, regs_per_thread: 40, achieved_warps: 29.3, achieved_occupancy: 45.8, theoretical_warps: 32.0, theoretical_occupancy: 50.0 },
    Table3Row { id: "smem_u", block_size: 512, grid_size: 1_685_159, regs_per_thread: 38, achieved_warps: 44.6, achieved_occupancy: 69.7, theoretical_warps: 48.0, theoretical_occupancy: 75.0 },
    Table3Row { id: "smem_eta_1", block_size: 512, grid_size: 1_685_159, regs_per_thread: 40, achieved_warps: 42.4, achieved_occupancy: 66.3, theoretical_warps: 48.0, theoretical_occupancy: 75.0 },
    Table3Row { id: "smem_eta_3", block_size: 512, grid_size: 1_685_159, regs_per_thread: 40, achieved_warps: 42.4, achieved_occupancy: 66.2, theoretical_warps: 48.0, theoretical_occupancy: 75.0 },
    Table3Row { id: "semi", block_size: 768, grid_size: 1_685_159, regs_per_thread: 40, achieved_warps: 41.2, achieved_occupancy: 64.4, theoretical_warps: 48.0, theoretical_occupancy: 75.0 },
    Table3Row { id: "st_smem_8x8", block_size: 64, grid_size: 14_161, regs_per_thread: 56, achieved_warps: 19.9, achieved_occupancy: 31.1, theoretical_warps: 20.0, theoretical_occupancy: 31.2 },
    Table3Row { id: "st_smem_8x16", block_size: 128, grid_size: 7_140, regs_per_thread: 56, achieved_warps: 27.9, achieved_occupancy: 43.6, theoretical_warps: 28.0, theoretical_occupancy: 43.7 },
    Table3Row { id: "st_smem_16x8", block_size: 128, grid_size: 7_140, regs_per_thread: 56, achieved_warps: 27.9, achieved_occupancy: 43.5, theoretical_warps: 28.0, theoretical_occupancy: 43.7 },
    Table3Row { id: "st_smem_16x16", block_size: 256, grid_size: 3_600, regs_per_thread: 56, achieved_warps: 31.6, achieved_occupancy: 49.4, theoretical_warps: 32.0, theoretical_occupancy: 50.0 },
    Table3Row { id: "st_reg_shft_8x8", block_size: 64, grid_size: 14_161, regs_per_thread: 96, achieved_warps: 19.0, achieved_occupancy: 29.7, theoretical_warps: 20.0, theoretical_occupancy: 31.2 },
    Table3Row { id: "st_reg_shft_16x16", block_size: 256, grid_size: 3_600, regs_per_thread: 96, achieved_warps: 15.9, achieved_occupancy: 24.9, theoretical_warps: 16.0, theoretical_occupancy: 25.0 },
    Table3Row { id: "st_reg_shft_16x32", block_size: 512, grid_size: 1_800, regs_per_thread: 96, achieved_warps: 16.0, achieved_occupancy: 25.0, theoretical_warps: 16.0, theoretical_occupancy: 25.0 },
    Table3Row { id: "st_reg_shft_16x64", block_size: 1024, grid_size: 900, regs_per_thread: 64, achieved_warps: 32.0, achieved_occupancy: 50.0, theoretical_warps: 32.0, theoretical_occupancy: 50.0 },
    Table3Row { id: "st_reg_shft_32x16", block_size: 512, grid_size: 1_800, regs_per_thread: 96, achieved_warps: 16.0, achieved_occupancy: 25.0, theoretical_warps: 16.0, theoretical_occupancy: 25.0 },
    Table3Row { id: "st_reg_shft_32x32", block_size: 1024, grid_size: 900, regs_per_thread: 64, achieved_warps: 32.0, achieved_occupancy: 50.0, theoretical_warps: 32.0, theoretical_occupancy: 50.0 },
    Table3Row { id: "st_reg_shft_64x16", block_size: 1024, grid_size: 900, regs_per_thread: 64, achieved_warps: 32.0, achieved_occupancy: 50.0, theoretical_warps: 32.0, theoretical_occupancy: 50.0 },
    Table3Row { id: "st_reg_fixed_8x8", block_size: 64, grid_size: 14_161, regs_per_thread: 78, achieved_warps: 23.9, achieved_occupancy: 37.3, theoretical_warps: 24.0, theoretical_occupancy: 37.5 },
    Table3Row { id: "st_reg_fixed_16x8", block_size: 128, grid_size: 7_140, regs_per_thread: 78, achieved_warps: 23.9, achieved_occupancy: 37.3, theoretical_warps: 24.0, theoretical_occupancy: 37.5 },
    Table3Row { id: "st_reg_fixed_16x16", block_size: 256, grid_size: 3_600, regs_per_thread: 78, achieved_warps: 23.9, achieved_occupancy: 37.4, theoretical_warps: 24.0, theoretical_occupancy: 37.5 },
    Table3Row { id: "st_reg_fixed_32x16", block_size: 512, grid_size: 1_800, regs_per_thread: 78, achieved_warps: 16.0, achieved_occupancy: 25.0, theoretical_warps: 16.0, theoretical_occupancy: 25.0 },
    Table3Row { id: "st_reg_fixed_32x32", block_size: 1024, grid_size: 900, regs_per_thread: 64, achieved_warps: 32.0, achieved_occupancy: 50.0, theoretical_warps: 32.0, theoretical_occupancy: 50.0 },
];

/// Table IV row (V100, whole execution).
#[derive(Copy, Clone, Debug)]
pub struct Table4Row {
    pub id: &'static str,
    /// total FLOP, x1e13
    pub flop_e13: f64,
    pub gflops: f64,
    /// L2 transactions, x1e12
    pub l2_trans_e12: f64,
    pub ai_l2: f64,
    pub l2_peak_gflops: f64,
    pub pct_l2_peak: f64,
    /// DRAM transactions, x1e11
    pub dram_trans_e11: f64,
    pub ai_dram: f64,
    pub dram_peak_gflops: f64,
    pub pct_dram_peak: f64,
}

pub const TABLE4: &[Table4Row] = &[
    Table4Row { id: "gmem_4x4x4", flop_e13: 4.453, gflops: 533.0, l2_trans_e12: 3.38, ai_l2: 0.41, l2_peak_gflops: 1361.0, pct_l2_peak: 39.19, dram_trans_e11: 8.42, ai_dram: 1.65, dram_peak_gflops: 1291.0, pct_dram_peak: 41.29 },
    Table4Row { id: "gmem_8x8x4", flop_e13: 4.453, gflops: 577.0, l2_trans_e12: 2.81, ai_l2: 0.49, l2_peak_gflops: 1635.0, pct_l2_peak: 35.27, dram_trans_e11: 7.26, ai_dram: 1.92, dram_peak_gflops: 1498.0, pct_dram_peak: 38.50 },
    Table4Row { id: "gmem_8x8x8", flop_e13: 4.453, gflops: 770.0, l2_trans_e12: 1.79, ai_l2: 0.78, l2_peak_gflops: 2566.0, pct_l2_peak: 30.00, dram_trans_e11: 7.26, ai_dram: 1.92, dram_peak_gflops: 1498.0, pct_dram_peak: 51.39 },
    Table4Row { id: "gmem_16x16x4", flop_e13: 4.453, gflops: 485.0, l2_trans_e12: 2.45, ai_l2: 0.57, l2_peak_gflops: 1877.0, pct_l2_peak: 25.83, dram_trans_e11: 6.67, ai_dram: 2.08, dram_peak_gflops: 1628.0, pct_dram_peak: 29.78 },
    Table4Row { id: "gmem_32x32x1", flop_e13: 4.453, gflops: 142.0, l2_trans_e12: 13.90, ai_l2: 0.10, l2_peak_gflops: 330.0, pct_l2_peak: 42.95, dram_trans_e11: 6.56, ai_dram: 2.12, dram_peak_gflops: 1656.0, pct_dram_peak: 8.57 },
    Table4Row { id: "smem_u", flop_e13: 4.453, gflops: 724.0, l2_trans_e12: 1.82, ai_l2: 0.77, l2_peak_gflops: 2531.0, pct_l2_peak: 28.60, dram_trans_e11: 7.37, ai_dram: 1.89, dram_peak_gflops: 1474.0, pct_dram_peak: 49.11 },
    Table4Row { id: "smem_eta_1", flop_e13: 4.453, gflops: 756.0, l2_trans_e12: 1.82, ai_l2: 0.76, l2_peak_gflops: 2522.0, pct_l2_peak: 29.97, dram_trans_e11: 7.31, ai_dram: 1.90, dram_peak_gflops: 1487.0, pct_dram_peak: 50.81 },
    Table4Row { id: "smem_eta_3", flop_e13: 4.453, gflops: 763.0, l2_trans_e12: 1.81, ai_l2: 0.77, l2_peak_gflops: 2535.0, pct_l2_peak: 30.10, dram_trans_e11: 7.31, ai_dram: 1.90, dram_peak_gflops: 1488.0, pct_dram_peak: 51.30 },
    Table4Row { id: "semi", flop_e13: 6.400, gflops: 345.0, l2_trans_e12: 2.67, ai_l2: 0.75, l2_peak_gflops: 2480.0, pct_l2_peak: 13.90, dram_trans_e11: 18.40, ai_dram: 1.08, dram_peak_gflops: 847.0, pct_dram_peak: 40.71 },
    Table4Row { id: "st_smem_8x8", flop_e13: 4.453, gflops: 356.0, l2_trans_e12: 1.59, ai_l2: 0.87, l2_peak_gflops: 2891.0, pct_l2_peak: 12.33, dram_trans_e11: 12.30, ai_dram: 1.13, dram_peak_gflops: 885.0, pct_dram_peak: 40.27 },
    Table4Row { id: "st_smem_8x16", flop_e13: 4.453, gflops: 366.0, l2_trans_e12: 1.47, ai_l2: 0.95, l2_peak_gflops: 3130.0, pct_l2_peak: 11.68, dram_trans_e11: 13.30, ai_dram: 1.05, dram_peak_gflops: 820.0, pct_dram_peak: 44.58 },
    Table4Row { id: "st_smem_16x8", flop_e13: 4.453, gflops: 692.0, l2_trans_e12: 1.17, ai_l2: 1.19, l2_peak_gflops: 3933.0, pct_l2_peak: 17.59, dram_trans_e11: 7.74, ai_dram: 1.80, dram_peak_gflops: 1404.0, pct_dram_peak: 49.27 },
    Table4Row { id: "st_smem_16x16", flop_e13: 4.453, gflops: 742.0, l2_trans_e12: 1.04, ai_l2: 1.34, l2_peak_gflops: 4414.0, pct_l2_peak: 16.81, dram_trans_e11: 6.97, ai_dram: 2.00, dram_peak_gflops: 1560.0, pct_dram_peak: 47.58 },
    Table4Row { id: "st_reg_shft_8x8", flop_e13: 4.453, gflops: 397.0, l2_trans_e12: 1.57, ai_l2: 0.89, l2_peak_gflops: 2935.0, pct_l2_peak: 13.54, dram_trans_e11: 10.40, ai_dram: 1.34, dram_peak_gflops: 1047.0, pct_dram_peak: 37.96 },
    Table4Row { id: "st_reg_shft_16x16", flop_e13: 4.453, gflops: 630.0, l2_trans_e12: 1.20, ai_l2: 1.16, l2_peak_gflops: 3841.0, pct_l2_peak: 16.41, dram_trans_e11: 7.22, ai_dram: 1.93, dram_peak_gflops: 1506.0, pct_dram_peak: 41.86 },
    Table4Row { id: "st_reg_shft_16x32", flop_e13: 4.453, gflops: 632.0, l2_trans_e12: 1.15, ai_l2: 1.21, l2_peak_gflops: 3991.0, pct_l2_peak: 15.84, dram_trans_e11: 6.76, ai_dram: 2.06, dram_peak_gflops: 1607.0, pct_dram_peak: 39.32 },
    Table4Row { id: "st_reg_shft_16x64", flop_e13: 4.453, gflops: 359.0, l2_trans_e12: 1.99, ai_l2: 0.70, l2_peak_gflops: 2317.0, pct_l2_peak: 15.49, dram_trans_e11: 17.00, ai_dram: 0.82, dram_peak_gflops: 638.0, pct_dram_peak: 56.25 },
    Table4Row { id: "st_reg_shft_32x16", flop_e13: 4.453, gflops: 682.0, l2_trans_e12: 0.94, ai_l2: 1.47, l2_peak_gflops: 4861.0, pct_l2_peak: 14.02, dram_trans_e11: 6.94, ai_dram: 2.00, dram_peak_gflops: 1566.0, pct_dram_peak: 43.54 },
    Table4Row { id: "st_reg_shft_32x32", flop_e13: 4.453, gflops: 442.0, l2_trans_e12: 1.67, ai_l2: 0.83, l2_peak_gflops: 2750.0, pct_l2_peak: 16.05, dram_trans_e11: 15.50, ai_dram: 0.90, dram_peak_gflops: 701.0, pct_dram_peak: 62.95 },
    Table4Row { id: "st_reg_shft_64x16", flop_e13: 4.453, gflops: 456.0, l2_trans_e12: 1.57, ai_l2: 0.89, l2_peak_gflops: 2938.0, pct_l2_peak: 15.52, dram_trans_e11: 14.50, ai_dram: 0.96, dram_peak_gflops: 752.0, pct_dram_peak: 60.64 },
    Table4Row { id: "st_reg_fixed_8x8", flop_e13: 4.453, gflops: 364.0, l2_trans_e12: 1.65, ai_l2: 0.84, l2_peak_gflops: 2791.0, pct_l2_peak: 13.05, dram_trans_e11: 15.00, ai_dram: 0.93, dram_peak_gflops: 723.0, pct_dram_peak: 50.36 },
    Table4Row { id: "st_reg_fixed_16x8", flop_e13: 4.453, gflops: 590.0, l2_trans_e12: 1.27, ai_l2: 1.10, l2_peak_gflops: 3632.0, pct_l2_peak: 16.26, dram_trans_e11: 9.59, ai_dram: 1.45, dram_peak_gflops: 1133.0, pct_dram_peak: 52.11 },
    Table4Row { id: "st_reg_fixed_16x16", flop_e13: 4.453, gflops: 673.0, l2_trans_e12: 1.18, ai_l2: 1.18, l2_peak_gflops: 3899.0, pct_l2_peak: 17.25, dram_trans_e11: 7.71, ai_dram: 1.80, dram_peak_gflops: 1409.0, pct_dram_peak: 47.72 },
    // NOTE: the published table prints "9.12" L2 transactions for
    // st_reg_fixed_32x16 — inconsistent with its own AI column
    // (4.453e13 / 1.53 = 2.9e13 B = 0.91e12 transactions); we record the
    // self-consistent 0.912.
    Table4Row { id: "st_reg_fixed_32x16", flop_e13: 4.453, gflops: 664.0, l2_trans_e12: 0.912, ai_l2: 1.53, l2_peak_gflops: 5043.0, pct_l2_peak: 13.17, dram_trans_e11: 7.14, ai_dram: 1.95, dram_peak_gflops: 1522.0, pct_dram_peak: 43.62 },
    Table4Row { id: "st_reg_fixed_32x32", flop_e13: 4.453, gflops: 703.0, l2_trans_e12: 1.09, ai_l2: 1.27, l2_peak_gflops: 4209.0, pct_l2_peak: 16.71, dram_trans_e11: 9.08, ai_dram: 1.53, dram_peak_gflops: 1197.0, pct_dram_peak: 58.78 },
];

pub fn table2_row(id: &str) -> Option<&'static Table2Row> {
    TABLE2.iter().find(|r| r.id == id)
}

pub fn table3_row(id: &str) -> Option<&'static Table3Row> {
    TABLE3_INNER.iter().find(|r| r.id == id)
}

pub fn table4_row(id: &str) -> Option<&'static Table4Row> {
    TABLE4.iter().find(|r| r.id == id)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_cover_all_25_variants() {
        assert_eq!(TABLE2.len(), 25);
        assert_eq!(TABLE3_INNER.len(), 25);
        assert_eq!(TABLE4.len(), 25);
        for v in crate::gpusim::kernels::paper_variants() {
            assert!(table2_row(v.id).is_some(), "{} missing in TABLE2", v.id);
            assert!(table3_row(v.id).is_some(), "{} missing in TABLE3", v.id);
            assert!(table4_row(v.id).is_some(), "{} missing in TABLE4", v.id);
        }
    }

    #[test]
    fn table4_internally_consistent() {
        // AI * peak-bandwidth must equal the quoted machine peak; GFLOPs /
        // peak must equal the quoted percentage (to table rounding).
        for r in TABLE4 {
            let pct = 100.0 * r.gflops / r.dram_peak_gflops;
            assert!((pct - r.pct_dram_peak).abs() < 1.0, "{}: {pct} vs {}", r.id, r.pct_dram_peak);
            let ai = r.flop_e13 * 1e13 / (r.l2_trans_e12 * 1e12 * 32.0);
            assert!((ai - r.ai_l2).abs() / r.ai_l2 < 0.15, "{}: {ai} vs {}", r.id, r.ai_l2);
        }
    }

    #[test]
    fn paper_headlines_hold_in_data() {
        // gmem_8x8x8 is the fastest V100 kernel
        let best_v100 = TABLE2.iter().min_by(|a, b| a.v100.total_cmp(&b.v100)).unwrap();
        assert_eq!(best_v100.id, "gmem_8x8x8");
        // the fastest P100 and NVS510 kernels are 2.5D fixed-register
        let best_p100 = TABLE2.iter().min_by(|a, b| a.p100.total_cmp(&b.p100)).unwrap();
        assert_eq!(best_p100.id, "st_reg_fixed_32x32");
        let best_nvs = TABLE2.iter().min_by(|a, b| a.nvs510.total_cmp(&b.nvs510)).unwrap();
        assert_eq!(best_nvs.id, "st_reg_fixed_16x8");
        // thin blocks are the slowest everywhere
        for sel in [|r: &Table2Row| r.v100, |r: &Table2Row| r.p100, |r: &Table2Row| r.nvs510] {
            let worst = TABLE2.iter().max_by(|a, b| sel(a).total_cmp(&sel(b))).unwrap();
            assert!(worst.id == "gmem_32x32x1" || worst.id == "semi");
        }
    }
}
