//! Vendored shim of the `anyhow` API surface used by `hostencil`.
//!
//! The offline crate set has no crates.io access, so this tiny crate
//! supplies exactly what the codebase calls: [`Error`], [`Result`], and
//! the `anyhow!` / `bail!` / `ensure!` macros. Errors are a single
//! rendered message (the codebase never chains contexts), and any
//! `std::error::Error` converts via `?` just like the real crate.

use std::fmt;

/// A rendered error message. Like the real `anyhow::Error`, this type
/// deliberately does NOT implement `std::error::Error`, which is what
/// makes the blanket `From<E: std::error::Error>` impl possible.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (the `anyhow!` macro's
    /// backing constructor).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with a defaulted error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string (or any displayable).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)+) => {
        return ::core::result::Result::Err($crate::anyhow!($($t)+))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!($($t)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(!flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn macros_build_messages() {
        let x = 3;
        let e: Error = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let e: Error = anyhow!("x = {}", x);
        assert_eq!(e.to_string(), "x = 3");
        let e: Error = anyhow!("x = {x}");
        assert_eq!(e.to_string(), "x = 3");
    }

    #[test]
    fn ensure_and_bail_return_early() {
        assert_eq!(fails(false).unwrap(), 7);
        let err = fails(true).unwrap_err();
        assert_eq!(err.to_string(), "flag was true");
    }

    #[test]
    fn std_errors_convert() {
        fn parse(s: &str) -> Result<i64> {
            Ok(s.parse::<i64>()?)
        }
        assert_eq!(parse("12").unwrap(), 12);
        assert!(parse("nope").is_err());
    }
}
