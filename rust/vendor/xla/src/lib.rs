//! Vendored stub of the `xla` crate surface used by `hostencil::runtime`.
//!
//! The offline build has no PJRT/XLA shared libraries, so this crate
//! supplies the same types and signatures with host-side behavior:
//!
//! * [`Literal`] and [`PjRtBuffer`] are real f32 containers — shape
//!   bookkeeping, reshape validation, and host round-trips all work
//!   (the runtime unit tests exercise them).
//! * [`PjRtClient::compile`] reports "unavailable": executing AOT HLO
//!   artifacts needs the real PJRT runtime. Every artifact-gated test
//!   in the repo already skips when `artifacts/manifest.json` is
//!   missing, so the stub only surfaces when someone actually tries to
//!   launch an executable.
//!
//! Swap this path dependency for the real `xla` crate (and delete the
//! stub) once the environment ships PJRT.

use std::fmt;

/// Error type matching the real crate's role; converts into
/// `anyhow::Error` through the blanket `std::error::Error` impl.
#[derive(Debug, Clone)]
pub struct XlaError {
    msg: String,
}

impl XlaError {
    fn new(msg: impl Into<String>) -> XlaError {
        XlaError { msg: msg.into() }
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla(stub): {}", self.msg)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

/// Element types the stub can move between host slices and buffers.
pub trait NativeType: Copy {
    fn to_f32(self) -> f32;
    fn from_f32(v: f32) -> Self;
}

impl NativeType for f32 {
    fn to_f32(self) -> f32 {
        self
    }
    fn from_f32(v: f32) -> Self {
        v
    }
}

impl NativeType for f64 {
    fn to_f32(self) -> f32 {
        self as f32
    }
    fn from_f32(v: f32) -> Self {
        v as f64
    }
}

/// A host literal: dense f32 data plus a shape.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    /// Reinterpret with a new shape of the same element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.data.len() {
            return Err(XlaError::new(format!(
                "reshape to {:?} ({} elements) from {} elements",
                dims,
                want,
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Copy out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// A "device" buffer — host-resident in the stub.
#[derive(Clone, Debug)]
pub struct PjRtBuffer {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl PjRtBuffer {
    /// Synchronous device->host copy.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(Literal { data: self.data.clone(), dims: self.dims.clone() })
    }
}

/// Parsed (well, carried) HLO module text.
#[derive(Clone, Debug)]
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    /// Read an HLO text artifact from disk. File I/O is real; parsing is
    /// deferred to `compile`, which the stub cannot perform.
    pub fn from_text_file(path: impl AsRef<std::path::Path>) -> Result<HloModuleProto> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| XlaError::new(format!("cannot read HLO text {path:?}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// A computation wrapping an HLO module.
#[derive(Clone, Debug)]
pub struct XlaComputation {
    _text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _text: proto.text.clone() }
    }
}

/// A compiled executable. Unreachable through the stub client (compile
/// always errors), but the type and signatures exist so the runtime
/// layer typechecks identically against the real crate.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b<T: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::new(
            "PJRT execution is unavailable in the stub runtime (vendored rust/vendor/xla)",
        ))
    }
}

/// The (stub) CPU PJRT client.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError::new(
            "HLO compilation is unavailable in the stub runtime; build against the real \
             xla crate (see rust/vendor/xla/src/lib.rs) to execute AOT artifacts",
        ))
    }

    /// Host->"device" transfer.
    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let want: usize = dims.iter().product();
        if want != data.len() {
            return Err(XlaError::new(format!(
                "host buffer has {} elements but dims {:?} imply {}",
                data.len(),
                dims,
                want
            )));
        }
        Ok(PjRtBuffer {
            data: data.iter().map(|&v| v.to_f32()).collect(),
            dims: dims.iter().map(|&d| d as i64).collect(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_reshape_and_roundtrip() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn buffer_transfer_roundtrip() {
        let c = PjRtClient::cpu().unwrap();
        let b = c.buffer_from_host_buffer::<f32>(&[1.0, 2.0, 3.0, 4.0], &[2, 2], None).unwrap();
        let l = b.to_literal_sync().unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(c.buffer_from_host_buffer::<f32>(&[1.0], &[2, 2], None).is_err());
    }

    #[test]
    fn compile_reports_stub() {
        let c = PjRtClient::cpu().unwrap();
        let proto = HloModuleProto { text: "HloModule m".into() };
        let comp = XlaComputation::from_proto(&proto);
        let err = c.compile(&comp).unwrap_err().to_string();
        assert!(err.contains("stub"), "{err}");
    }
}
