//! Bench: regenerate Figure 3 — the L2 and DRAM rooflines with all 25
//! kernel operating points, for each machine. ASCII to stdout, CSV to
//! target/fig3_<machine>.csv.

use hostencil::bench::Bencher;
use hostencil::report;

fn main() {
    std::fs::create_dir_all("target").ok();
    for machine in ["v100", "p100", "nvs510"] {
        let (text, csv) = report::fig3(machine, 1000).expect("fig3");
        println!("=== Figure 3 ({machine}) ===");
        println!("{text}");
        let path = format!("target/fig3_{machine}.csv");
        std::fs::write(&path, &csv).expect("write csv");
        println!("wrote {path} ({} rows)\n", csv.lines().count() - 1);
    }

    let mut b = Bencher::from_env();
    b.bench("fig3/v100_full_pipeline", || report::fig3("v100", 1000).unwrap().1.len());
    println!("\n{}", b.csv());
}
