//! Bench: regenerate Table IV (roofline performance characteristics)
//! and time the full whole-machine simulation sweep.

use hostencil::bench::Bencher;
use hostencil::gpusim::arch;
use hostencil::gpusim::timing;
use hostencil::report;

fn main() {
    println!("=== Table IV (model vs paper, V100) ===");
    print!("{}", report::table4(1000));

    // summary deltas: mean |model - paper| across the 25 rows
    let runs = timing::simulate_all(&arch::v100(), 1000);
    let mut d_ai_l2 = 0.0;
    let mut d_ai_dram = 0.0;
    for r in &runs {
        let p = hostencil::report::paperdata::table4_row(r.variant_id).unwrap();
        d_ai_l2 += ((r.ai_l2 - p.ai_l2) / p.ai_l2).abs();
        d_ai_dram += ((r.ai_dram - p.ai_dram) / p.ai_dram).abs();
    }
    println!(
        "\nmean |rel delta| vs paper: AI_L2 {:.1}%  AI_DRAM {:.1}%",
        100.0 * d_ai_l2 / runs.len() as f64,
        100.0 * d_ai_dram / runs.len() as f64
    );

    let mut b = Bencher::from_env();
    for m in arch::all() {
        b.bench(&format!("simulate_all/{}", m.name), || {
            timing::simulate_all(&m, 1000).len()
        });
    }
    println!("\n{}", b.csv());
}
