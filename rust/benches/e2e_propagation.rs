//! Bench: end-to-end propagation on the real PJRT testbed — the local
//! analog of the paper's headline "2x over the monolithic baseline"
//! claim: decomposed (7 launches/step, strategy 3) vs monolithic
//! (1 branchy launch/step, strategy 1 / OpenACC analog) vs fused
//! (1 XLA-fused launch/step) vs the pure-Rust golden CPU propagator.

use hostencil::bench::Bencher;
use hostencil::coordinator::{Coordinator, Mode};
use hostencil::grid::Dim3;
use hostencil::runtime::Engine;
use hostencil::wave::{self, Source, VelocityModel};

fn mk<'e>(engine: Option<&'e Engine>, domain: hostencil::grid::Domain, mode: Mode) -> Coordinator<'e> {
    let model = VelocityModel::Constant(2500.0);
    let c = domain.interior.z / 2;
    Coordinator::new(
        engine,
        domain,
        mode,
        "gmem",
        "smem_eta_1",
        model.build(domain.interior),
        wave::eta_profile(&domain, 2500.0),
        Source { pos: Dim3::new(c, c, c), f0: 15.0, amplitude: 1.0 },
        vec![],
    )
    .expect("coordinator")
}

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("artifacts/ missing — run `make artifacts` first");
        return;
    }
    let engine = Engine::load("artifacts").expect("engine");
    engine.preload_all().expect("preload");
    let domain = engine.manifest().domain;
    let steps: usize = std::env::var("HOSTENCIL_BENCH_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(5);
    let pts = (domain.interior.volume() * steps) as f64;

    println!(
        "e2e: domain {} (pml {}), {steps} steps per sample",
        domain.interior, domain.pml_width
    );
    let mut b = Bencher::from_env();
    let mut results: Vec<(&str, f64)> = Vec::new();
    for (name, mode) in [
        ("decomposed(7-launch)", Mode::Decomposed),
        ("monolithic(baseline)", Mode::Monolithic),
        ("fused(1-launch)", Mode::Fused),
        ("golden(rust-cpu)", Mode::Golden),
    ] {
        let eng = if mode.needs_engine() { Some(&engine) } else { None };
        let mut coord = mk(eng, domain, mode);
        let stats = b.bench(name, || {
            for _ in 0..steps {
                coord.step().unwrap();
            }
            coord.wavefield().energy()
        });
        results.push((name, stats.median.as_secs_f64()));
    }

    println!("\nthroughput (median):");
    for (name, t) in &results {
        println!("  {:24} {:>8.2} Mpts/s", name, pts / t / 1e6);
    }
    let deco = results[0].1;
    let mono = results[1].1;
    println!(
        "\nmonolithic/decomposed time ratio: {:.2}x (paper's headline: ~2x over the OpenACC-style baseline)",
        mono / deco
    );
    println!("\n{}", b.csv());
}
