//! Bench: regenerate Table III (kernel characteristics / occupancy) and
//! time the occupancy calculator itself (it sits inside every timing-
//! model query, so the sweep tooling wants it fast).

use hostencil::bench::Bencher;
use hostencil::gpusim::arch::{self, v100};
use hostencil::gpusim::{kernels, occupancy};
use hostencil::report;

fn main() {
    println!("{}", report::table1());
    println!("=== Table III (model vs paper, V100 inner region) ===");
    print!("{}", report::table3());

    // PML region classes (Table III bottom): model occupancy per class
    println!("\n=== Table III (PML kernels, V100) ===");
    let a = v100();
    println!(
        "{:<20}{:>7}{:>6}{:>9}{:>9}",
        "variant", "block", "regs", "thWarps", "thOcc%"
    );
    for v in kernels::paper_variants() {
        let occ = occupancy::occupancy(&a, &v.resources_pml());
        println!(
            "{:<20}{:>7}{:>6}{:>9}{:>9.1}",
            v.id,
            v.threads_per_block(),
            v.regs_pml,
            occ.active_warps,
            occ.occupancy_pct
        );
    }

    let mut b = Bencher::from_env();
    let variants = kernels::paper_variants();
    let machines = arch::all();
    b.bench("occupancy/25_variants_x_3_machines", || {
        let mut acc = 0u32;
        for m in &machines {
            for v in &variants {
                acc += occupancy::occupancy(m, &v.resources_inner()).active_warps;
                acc += occupancy::occupancy(m, &v.resources_pml()).active_warps;
            }
        }
        acc
    });
    println!("\n{}", b.csv());
}
