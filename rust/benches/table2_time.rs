//! Bench: regenerate Table II.
//!
//! Two parts:
//!  1. the gpusim prediction for all 25 variants x 3 machines (printed
//!     against the paper's measurements, with rank agreement), and
//!  2. measured wall time of the real PJRT artifacts on this CPU
//!     testbed, per inner-kernel variant (the local analog of a Table II
//!     column: same workload, same launch topology, real executables).

use hostencil::bench::Bencher;
use hostencil::coordinator::{Coordinator, Mode};
use hostencil::grid::Dim3;
use hostencil::report;
use hostencil::runtime::Engine;
use hostencil::wave::{self, Source, VelocityModel};

fn main() {
    println!("=== Table II (model vs paper) ===");
    print!("{}", report::table2(1000));
    for m in ["v100", "p100", "nvs510"] {
        println!(
            "rank agreement ({m}): {:.1}%",
            100.0 * report::rank_agreement(m, 100).unwrap()
        );
    }

    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("\nartifacts/ missing — skipping measured column (run `make artifacts`)");
        return;
    }
    println!("\n=== Table II (measured, CPU PJRT testbed, {} steps/sample) ===", steps());
    let engine = Engine::load("artifacts").expect("engine");
    let domain = engine.manifest().domain;
    let mut b = Bencher::from_env();
    let variants: Vec<String> = engine
        .manifest()
        .inner_variants()
        .iter()
        .map(|s| s.to_string())
        .collect();
    for variant in &variants {
        let mut coord = mk(&engine, variant, "smem_eta_1");
        b.bench(&format!("decomposed/{variant}"), || {
            for _ in 0..steps() {
                coord.step().unwrap();
            }
            coord.wavefield().energy()
        });
        let _ = domain;
    }
    println!("\n{}", b.csv());
}

fn steps() -> usize {
    std::env::var("HOSTENCIL_BENCH_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3)
}

fn mk<'e>(engine: &'e Engine, inner: &str, pml: &str) -> Coordinator<'e> {
    let domain = engine.manifest().domain;
    let model = VelocityModel::Constant(2500.0);
    let c = domain.interior.z / 2;
    Coordinator::new(
        Some(engine),
        domain,
        Mode::Decomposed,
        inner,
        pml,
        model.build(domain.interior),
        wave::eta_profile(&domain, 2500.0),
        Source { pos: Dim3::new(c, c, c), f0: 15.0, amplitude: 1.0 },
        vec![],
    )
    .expect("coordinator")
}
