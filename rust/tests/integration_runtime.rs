//! Integration: PJRT runtime over the real AOT artifacts.
//!
//! Requires `make artifacts` (skips with a message otherwise — the
//! Makefile `test` target guarantees them).

use hostencil::grid::{Dim3, Field3};
use hostencil::runtime::Engine;
use hostencil::stencil;
use hostencil::testkit::Rng;
use hostencil::R;

fn engine() -> Option<Engine> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return None;
    }
    Some(Engine::load("artifacts").expect("engine loads"))
}

#[test]
fn manifest_covers_expected_artifact_set() {
    let Some(eng) = engine() else { return };
    let m = eng.manifest();
    for v in ["gmem", "smem_u", "semi", "st_smem", "st_reg_shft", "st_reg_fixed"] {
        assert!(m.get(&format!("inner_{v}")).is_ok(), "inner_{v}");
    }
    for cls in ["top_bottom", "front_back", "left_right"] {
        for v in ["gmem", "smem_eta_1", "smem_eta_3"] {
            assert!(m.get(&format!("pml_{cls}_{v}")).is_ok(), "pml_{cls}_{v}");
        }
    }
    assert!(m.get("monolithic").is_ok());
    assert!(m.get("fused").is_ok());
}

#[test]
fn every_inner_artifact_matches_rust_golden_stencil() {
    let Some(eng) = engine() else { return };
    let m = eng.manifest().clone();
    let domain = m.domain;
    let inner = domain.inner();
    let mut rng = Rng::new(0xFEED);
    let u_pad = rng.field(inner.padded(R));
    let um = rng.field(inner);
    let v = rng.field_in(inner, 1500.0, 3000.0);
    let want = stencil::step_inner(&u_pad, &um, &v, domain.dt, domain.h);

    for variant in m.inner_variants() {
        let got = eng
            .execute(&format!("inner_{variant}"), &[&u_pad, &um, &v])
            .unwrap_or_else(|e| panic!("{variant}: {e}"));
        let d = got.max_abs_diff(&want);
        let rel = d / want.max_abs().max(1e-30);
        assert!(rel < 5e-5, "inner_{variant} diverges: rel {rel}");
    }
}

#[test]
fn every_pml_artifact_matches_rust_golden_stencil() {
    let Some(eng) = engine() else { return };
    let m = eng.manifest().clone();
    let domain = m.domain;
    let mut rng = Rng::new(0xBEEF);
    for art in m.artifacts.iter().filter(|a| a.kind == "pml") {
        let shape = art.output_shape;
        let pad1 = shape.padded(1);
        let u = rng.field(pad1);
        let um = rng.field(shape);
        let v = rng.field_in(shape, 1500.0, 3000.0);
        let eta = rng.field_in(pad1, 0.0, 300.0);
        let want = stencil::step_pml(&u, &um, &v, &eta, domain.dt, domain.h);
        let got = eng.execute(&art.name, &[&u, &um, &v, &eta]).expect(&art.name);
        let d = got.max_abs_diff(&want);
        let rel = d / want.max_abs().max(1e-30);
        assert!(rel < 5e-5, "{} diverges: rel {rel}", art.name);
    }
}

#[test]
fn monolithic_and_fused_match_composed_golden() {
    let Some(eng) = engine() else { return };
    let domain = eng.manifest().domain;
    let n = domain.interior;
    let mut rng = Rng::new(0xCAFE);
    // interior data embedded in zero ghost (the coordinator invariant)
    let u_pad = rng.field(n).pad(R);
    let um = rng.field(n);
    let v = rng.field_in(n, 1500.0, 3000.0);
    let eta_pad = rng.field_in(n, 0.0, 200.0).pad(R);

    let got = eng.execute("monolithic", &[&u_pad, &um, &v, &eta_pad]).unwrap();
    let fused = eng.execute("fused", &[&u_pad, &um, &v, &eta_pad]).unwrap();

    // golden decomposed
    let mut want = Field3::zeros(n);
    for reg in hostencil::grid::decompose(&domain) {
        let um_t = um.extract(reg.offset, reg.shape);
        let v_t = v.extract(reg.offset, reg.shape);
        let tile = if reg.class.is_pml() {
            let u_t = u_pad.extract_padded_region(R, reg.offset, reg.shape, 1);
            let e_t = eta_pad.extract_padded_region(R, reg.offset, reg.shape, 1);
            stencil::step_pml(&u_t, &um_t, &v_t, &e_t, domain.dt, domain.h)
        } else {
            let u_t = u_pad.extract_padded_region(R, reg.offset, reg.shape, R);
            stencil::step_inner(&u_t, &um_t, &v_t, domain.dt, domain.h)
        };
        want.scatter(reg.offset, &tile);
    }
    let scale = want.max_abs().max(1e-30);
    assert!(got.max_abs_diff(&want) / scale < 5e-5, "monolithic vs golden");
    assert!(fused.max_abs_diff(&want) / scale < 5e-5, "fused vs golden");
}

#[test]
fn execute_rejects_wrong_shapes_and_arity() {
    let Some(eng) = engine() else { return };
    let domain = eng.manifest().domain;
    let inner = domain.inner();
    let bad = Field3::zeros(Dim3::new(2, 2, 2));
    assert!(eng.execute("inner_gmem", &[&bad, &bad, &bad]).is_err());
    let ok_pad = Field3::zeros(inner.padded(R));
    assert!(eng.execute("inner_gmem", &[&ok_pad]).is_err());
    assert!(eng.execute("no_such_artifact", &[]).is_err());
}

#[test]
fn engine_stats_accumulate() {
    let Some(eng) = engine() else { return };
    let domain = eng.manifest().domain;
    let inner = domain.inner();
    let u_pad = Field3::zeros(inner.padded(R));
    let um = Field3::zeros(inner);
    let v = Field3::full(inner, 2000.0);
    let before = eng.total_calls();
    for _ in 0..3 {
        eng.execute("inner_gmem", &[&u_pad, &um, &v]).unwrap();
    }
    assert_eq!(eng.total_calls(), before + 3);
    let stats = eng.stats();
    let s = stats.iter().find(|(n, _)| n == "inner_gmem").unwrap();
    assert!(s.1.calls >= 3);
    assert!(s.1.exec_time > std::time::Duration::ZERO);
}

#[test]
fn zero_wavefield_stays_zero_through_pjrt() {
    let Some(eng) = engine() else { return };
    let domain = eng.manifest().domain;
    let inner = domain.inner();
    let u_pad = Field3::zeros(inner.padded(R));
    let um = Field3::zeros(inner);
    let v = Field3::full(inner, 2500.0);
    let out = eng.execute("inner_gmem", &[&u_pad, &um, &v]).unwrap();
    assert_eq!(out.max_abs(), 0.0);
}
