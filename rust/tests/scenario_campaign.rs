//! Integration: the scenario catalogue and campaign runner.
//!
//! Everything here runs on the pure-Rust golden backend — no AOT
//! artifacts required — so these tests always execute (no skip gate).

use hostencil::json::Json;
use hostencil::scenario::campaign::{run_campaign, CampaignSpec};
use hostencil::scenario::{run_scenario, RunnerOptions, ScenarioId, Verdict};

/// Trimmed runner options so debug-profile test runs stay fast; the
/// criteria are step-count independent except absorption, which the
/// scale keeps meaningful.
fn quick() -> RunnerOptions {
    RunnerOptions { steps_scale: Some(0.5), ..RunnerOptions::default() }
}

#[test]
fn every_non_stress_scenario_passes() {
    for id in ScenarioId::all().into_iter().filter(|id| !id.is_stress()) {
        let run = run_scenario(id, &RunnerOptions::default()).expect(id.name());
        assert_eq!(
            run.result.overall,
            Verdict::Pass,
            "{} should Pass; failed criteria: {:?}",
            id.name(),
            run.result
                .failed()
                .iter()
                .map(|c| format!("{}: {}", c.name, c.detail))
                .collect::<Vec<_>>()
        );
    }
}

#[test]
fn cfl_violation_scenario_hard_fails() {
    // the deliberately mis-configured scenario: dt 2.5x past the CFL
    // bound must produce a HardFail verdict (and say why)
    let run = run_scenario(ScenarioId::CflMarginStress, &quick()).unwrap();
    assert_eq!(run.result.overall, Verdict::HardFail);
    let failed: Vec<&str> = run.result.failed().iter().map(|c| c.name).collect();
    assert!(failed.contains(&"cfl_respected"), "{failed:?}");
    // and the catalogue knows it: the run is *expected* to fail
    assert!(run.as_expected());
}

#[test]
fn cfl_stress_actually_blows_up_the_field() {
    let run = run_scenario(ScenarioId::CflMarginStress, &RunnerOptions::default()).unwrap();
    assert!(
        run.metrics.first_non_finite.is_some(),
        "2.5x CFL should reach non-finite within the step budget"
    );
    assert!(run.metrics.steps_completed < run.metrics.steps_requested);
}

#[test]
fn scenario_runs_are_deterministic() {
    let a = run_scenario(ScenarioId::HomogeneousPoint, &quick()).unwrap();
    let b = run_scenario(ScenarioId::HomogeneousPoint, &quick()).unwrap();
    assert_eq!(a.metrics.energy_trace, b.metrics.energy_trace);
    assert_eq!(a.metrics.peak_abs, b.metrics.peak_abs);
    assert_eq!(a.result.overall, b.result.overall);
}

#[test]
fn campaign_matrix_runs_in_parallel_and_aggregates() {
    let spec = CampaignSpec {
        scenarios: vec![ScenarioId::TinyGrid, ScenarioId::CflMarginStress],
        variants: vec!["gmem_8x8x8".to_string(), "st_reg_fixed_32x32".to_string()],
        machines: vec!["v100".to_string()],
        steps_scale: Some(0.5),
        threads: 4,
        sample_every: 0,
        shards: 1,
        serial_fraction: None,
        telemetry: None,
    };
    let report = run_campaign(&spec);
    assert_eq!(report.cells.len(), 4);
    assert!(report.threads >= 1 && report.threads <= 4);

    // cells come back in deterministic matrix order
    assert_eq!(report.cells[0].scenario, ScenarioId::TinyGrid);
    assert_eq!(report.cells[0].variant, "gmem_8x8x8");
    assert_eq!(report.cells[3].scenario, ScenarioId::CflMarginStress);

    // stress cells hard-fail, but *expectedly* — the campaign stays green
    for c in &report.cells {
        if c.scenario.is_stress() {
            assert_eq!(c.verdict, Verdict::HardFail, "{c:?}");
            assert!(!c.off_expectation());
        } else {
            assert_ne!(c.verdict, Verdict::HardFail, "{c:?}");
        }
        assert!(c.predicted_steps_per_sec > 0.0, "{c:?}");
        // the measured column comes from the CPU code shape that ran
        // this cell's physics
        assert!(c.measured_steps_per_sec > 0.0, "{c:?}");
        assert!(!c.propagator.is_empty(), "{c:?}");
    }
    // gmem_8x8x8 -> blocked3d, st_reg_fixed_32x32 -> streaming2.5d:
    // two shapes x two scenarios = 4 physics runs for 4 cells here,
    // but the machine axis never re-runs physics
    assert_eq!(report.physics_runs, 4);
    assert_eq!(report.off_expectation_count(), 0);
}

#[test]
fn campaign_json_is_parseable_and_round_trips() {
    let spec = CampaignSpec {
        scenarios: vec![ScenarioId::TinyGrid, ScenarioId::CflMarginStress],
        variants: vec!["gmem_8x8x8".to_string()],
        machines: vec!["v100".to_string()],
        steps_scale: Some(0.5),
        threads: 2,
        sample_every: 0,
        shards: 1,
        serial_fraction: None,
        telemetry: None,
    };
    let report = run_campaign(&spec);
    let j = report.to_json();
    let text = j.emit();

    // the emitted text is valid JSON for our own strict parser...
    let parsed = Json::parse(&text).expect("campaign JSON must parse");
    // ...and round-trips exactly (non-finite metrics were sanitized)
    assert_eq!(parsed, j);

    // schema spot-checks a consumer would rely on
    assert_eq!(parsed.get("kind").unwrap().as_str().unwrap(), "hostencil-campaign");
    let cells = parsed.get("cells").unwrap().as_arr().unwrap();
    assert_eq!(cells.len(), 2);
    let stress = &cells[1];
    assert_eq!(stress.get("scenario").unwrap().as_str().unwrap(), "cfl-margin-stress");
    assert_eq!(stress.get("verdict").unwrap().as_str().unwrap(), "HardFail");
    let summary = parsed.get("summary").unwrap();
    assert_eq!(summary.get("total").unwrap().as_usize().unwrap(), 2);
    assert_eq!(summary.get("off_expectation").unwrap().as_usize().unwrap(), 0);
}

#[test]
fn campaign_single_thread_matches_parallel() {
    let mk = |threads| CampaignSpec {
        scenarios: vec![ScenarioId::TinyGrid],
        variants: vec!["gmem_8x8x8".to_string()],
        machines: vec!["v100".to_string(), "nvs510".to_string()],
        steps_scale: Some(0.5),
        threads,
        sample_every: 0,
        shards: 1,
        serial_fraction: None,
        telemetry: None,
    };
    let serial = run_campaign(&mk(1));
    let parallel = run_campaign(&mk(2));
    assert_eq!(serial.cells.len(), parallel.cells.len());
    for (a, b) in serial.cells.iter().zip(&parallel.cells) {
        assert_eq!(a.scenario, b.scenario);
        assert_eq!(a.machine, b.machine);
        assert_eq!(a.verdict, b.verdict);
        assert_eq!(a.peak_abs, b.peak_abs, "physics must be scheduling-independent");
    }
    // machine axis feeds the perf model: V100 predicts faster steps
    let v100 = &serial.cells[0];
    let nvs = &serial.cells[1];
    assert!(v100.predicted_steps_per_sec > nvs.predicted_steps_per_sec);
}
