//! Integration: checkpoint/restart must be *bitwise invisible* to the
//! physics. A run interrupted at step k, snapshotted through the
//! serialized byte format (or the on-disk file), and resumed in a
//! fresh process-equivalent coordinator must finish with exactly the
//! wavefield, energy log, and receiver traces of the uninterrupted
//! run — for the unfused propagator, both fused degrees, and the
//! sharded engine. This is the enforcement of the recovery contract
//! (docs/OPERATIONS.md) at the public-API level.

use hostencil::coordinator::{Coordinator, Mode, RunOptions};
use hostencil::grid::{Dim3, Domain};
use hostencil::recovery::Checkpoint;
use hostencil::stencil;
use hostencil::wave::{self, Source, VelocityModel};

/// A layered-model coordinator with an off-center source and two
/// receivers, so restart has non-trivial traces and a z-varying medium
/// to disagree about if the snapshot were lossy.
fn coordinator(variant: &str, interior: Dim3, threads: usize) -> Coordinator<'static> {
    let h = 10.0;
    let v_max = 3000.0f64;
    let domain = Domain::new(interior, 4, h, stencil::cfl_dt(h, v_max)).unwrap();
    let model = VelocityModel::Layered(vec![(0.0, 2000.0), (0.4, 2600.0), (0.7, 3000.0)]);
    let v = model.build(interior);
    let eta = wave::eta_profile(&domain, v_max);
    let (nz, ny, nx) = (interior.z, interior.y, interior.x);
    let src = Source { pos: Dim3::new(nz / 3, ny / 2, nx / 2), f0: 18.0, amplitude: 1.0 };
    let recv = vec![
        Dim3::new(2 * nz / 3, ny / 2, nx / 2),
        Dim3::new(nz / 2, ny / 3, 2 * nx / 3),
    ];
    let mut c =
        Coordinator::new(None, domain, Mode::Golden, variant, "gmem", v, eta, src, recv).unwrap();
    c.set_cpu_threads(threads);
    c
}

/// Run `steps` uninterrupted; run the same configuration to step `k`,
/// snapshot through the serialized byte format, restore into a fresh
/// coordinator, finish, and demand bitwise agreement on everything
/// observable.
fn assert_restart_bitwise(variant: &str, interior: Dim3, shards: usize, k: usize, steps: usize) {
    let label = format!("{variant} {interior:?} x{shards} split at {k}");
    let opts = RunOptions::default();

    let mut full = coordinator(variant, interior, 2);
    full.set_shards(shards).unwrap();
    let oracle = full.run_observed(steps, opts, None).unwrap();

    let mut first = coordinator(variant, interior, 2);
    first.set_shards(shards).unwrap();
    first.run_observed(k, opts, None).unwrap();
    // round-trip the snapshot through the wire format, as a real
    // restart would — not just a clone of in-memory state
    let ck = Checkpoint::from_bytes(&first.checkpoint().to_bytes()).expect("snapshot roundtrip");
    assert_eq!(ck.steps_done as usize, k, "{label}: snapshot step cursor");

    let mut resumed = coordinator(variant, interior, 2);
    resumed.set_shards(shards).unwrap();
    resumed.restore(&ck).unwrap();
    let got = resumed.run_observed(steps - k, opts, None).unwrap();

    assert!(oracle.final_max_abs > 0.0, "{label}: wave must have propagated");
    assert_eq!(
        resumed.wavefield().max_abs_diff(&full.wavefield()),
        0.0,
        "{label}: resumed wavefield must be bit-identical"
    );
    assert_eq!(
        resumed.state_digest(),
        full.state_digest(),
        "{label}: state digest (um + step cursor) diverged"
    );
    assert_eq!(got.traces, oracle.traces, "{label}: receiver traces must splice seamlessly");
    assert_eq!(got.energy_log, oracle.energy_log, "{label}: per-batch energy log");
    assert_eq!(
        got.final_energy.to_bits(),
        oracle.final_energy.to_bits(),
        "{label}: final energy"
    );
}

#[test]
fn unfused_restart_is_bitwise() {
    // split at a step that is *not* a batch-friendly round number
    assert_restart_bitwise("naive", Dim3::new(20, 14, 14), 1, 7, 20);
}

#[test]
fn fused_restarts_are_bitwise_at_batch_boundaries() {
    // the checkpoint cursor always sits on a batch boundary (snapshots
    // are taken between observed batches), so k must be a multiple of
    // the fusion degree for the interrupted leg
    assert_restart_bitwise("tf_s2", Dim3::new(20, 14, 14), 1, 8, 20);
    assert_restart_bitwise("tf_s4", Dim3::new(20, 14, 14), 1, 8, 20);
}

#[test]
fn sharded_restart_is_bitwise() {
    // the sharded engine gathers into the global buffers at batch
    // boundaries, so a snapshot taken mid-run restores into either a
    // sharded or unsharded continuation; keep shards on both legs here
    assert_restart_bitwise("tf_s2", Dim3::new(25, 14, 14), 2, 8, 20);
}

#[test]
fn restart_crosses_the_shard_boundary() {
    // snapshot a *sharded* run, resume it *unsharded*: the snapshot is
    // the global gathered state, so the decomposition must not matter
    let interior = Dim3::new(25, 14, 14);
    let opts = RunOptions::default();

    let mut full = coordinator("naive", interior, 2);
    let oracle = full.run_observed(18, opts, None).unwrap();

    let mut sharded = coordinator("naive", interior, 2);
    sharded.set_shards(2).unwrap();
    sharded.run_observed(9, opts, None).unwrap();
    let ck = Checkpoint::from_bytes(&sharded.checkpoint().to_bytes()).unwrap();

    let mut resumed = coordinator("naive", interior, 2);
    resumed.restore(&ck).unwrap();
    let got = resumed.run_observed(9, opts, None).unwrap();

    assert_eq!(resumed.wavefield().max_abs_diff(&full.wavefield()), 0.0);
    assert_eq!(resumed.state_digest(), full.state_digest());
    assert_eq!(got.traces, oracle.traces);
}

#[test]
fn on_disk_snapshot_round_trips_and_rejects_corruption() {
    let dir = std::env::temp_dir();
    let path = dir.join(format!("hostencil_restart_test_{}.ckpt", std::process::id()));

    let mut first = coordinator("naive", Dim3::new(20, 14, 14), 1);
    first.run_observed(10, RunOptions::default(), None).unwrap();
    let ck = first.checkpoint();
    ck.save(&path).unwrap();

    // the atomic-write staging file must not linger
    assert!(!path.with_extension("ckpt.tmp").exists(), "staging file left behind");

    let loaded = Checkpoint::load(&path).unwrap();
    assert_eq!(loaded.steps_done, 10);
    assert_eq!(loaded.state_digest(), ck.state_digest());

    // flip one payload byte: the checksum must reject the file
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&path, &bytes).unwrap();
    let err = Checkpoint::load(&path).unwrap_err().to_string();
    assert!(err.contains("checksum"), "{err}");

    std::fs::remove_file(&path).ok();
}
