//! Shape checks: the gpusim testbed must reproduce the paper's findings
//! (who wins, by roughly what factor, where crossovers fall) — §V.C of
//! the paper, asserted against the model's own Table II/III/IV outputs.

use hostencil::gpusim::arch::{self, nvs510, p100, v100};
use hostencil::gpusim::{kernels, occupancy, timing};
use hostencil::report::{self, paperdata};

fn time(a: &arch::GpuArch, id: &str) -> f64 {
    timing::simulate(a, &kernels::by_id(id).unwrap(), 1000).time_s
}

fn best(a: &arch::GpuArch) -> String {
    timing::simulate_all(a, 1000)
        .into_iter()
        .min_by(|x, y| x.time_s.total_cmp(&y.time_s))
        .unwrap()
        .variant_id
        .to_string()
}

fn worst(a: &arch::GpuArch) -> String {
    timing::simulate_all(a, 1000)
        .into_iter()
        .max_by(|x, y| x.time_s.total_cmp(&y.time_s))
        .unwrap()
        .variant_id
        .to_string()
}

#[test]
fn v100_winner_is_gmem_8x8x8() {
    // paper §V.C: "Despite its simplicity, on V100 it has the best
    // performance."
    assert_eq!(best(&v100()), "gmem_8x8x8");
}

#[test]
fn p100_and_nvs510_winners_are_25d_register_kernels() {
    // paper: "the best performed implementations on P100 and NVS 510
    // come from 2.5D approaches"
    assert!(best(&p100()).starts_with("st_reg_"), "{}", best(&p100()));
    assert!(best(&nvs510()).starts_with("st_reg_"), "{}", best(&nvs510()));
}

#[test]
fn thin_blocks_are_the_worst_everywhere() {
    for a in [v100(), p100(), nvs510()] {
        assert_eq!(worst(&a), "gmem_32x32x1", "{}", a.name);
    }
}

#[test]
fn gmem_8x8x8_lacks_performance_portability() {
    // paper: best on V100 but "one of the slowest implementations on
    // P100" — concretely, smem_u beats it by >1.3x on P100 and NVS510.
    for a in [p100(), nvs510()] {
        let ratio = time(&a, "gmem_8x8x8") / time(&a, "smem_u");
        assert!(ratio > 1.3, "{}: {}", a.name, ratio);
    }
    // ... while on V100 it wins against smem_u.
    assert!(time(&v100(), "gmem_8x8x8") < time(&v100(), "smem_u"));
}

#[test]
fn semi_stencil_pays_for_synchronization() {
    // paper: semi is ~3.2x slower than gmem_8x8x8 on V100
    let ratio = time(&v100(), "semi") / time(&v100(), "gmem_8x8x8");
    assert!(ratio > 2.0, "{ratio}");
}

#[test]
fn register_spilling_hurts_shifting_variants_on_v100() {
    // paper: the 1024-thread st_reg_shft variants (Nr=64) lose badly to
    // their uncapped 256-thread kin on V100 ...
    let a = v100();
    assert!(time(&a, "st_reg_shft_16x64") > 1.5 * time(&a, "st_reg_shft_16x16"));
    // ... while fixed registers + unrolling hide the spill cost.
    assert!(time(&a, "st_reg_fixed_32x32") < 1.2 * time(&a, "st_reg_fixed_16x16"));
}

#[test]
fn wider_x_tile_beats_taller_y_tile() {
    // paper: st_reg_shft_32x16 runs faster than st_reg_shft_16x32
    // (coalescing on the contiguous dimension)
    let a = v100();
    assert!(time(&a, "st_reg_shft_32x16") < time(&a, "st_reg_shft_16x32"));
    assert!(time(&a, "st_smem_16x8") < time(&a, "st_smem_8x16"));
}

#[test]
fn larger_planes_run_faster_within_a_25d_family() {
    // paper: "the larger the 2D plane, the better the performance"
    // (absent spilling)
    let a = v100();
    assert!(time(&a, "st_smem_16x16") < time(&a, "st_smem_8x8"));
    assert!(time(&a, "st_reg_shft_16x16") < time(&a, "st_reg_shft_8x8"));
    assert!(time(&a, "st_reg_fixed_16x16") < time(&a, "st_reg_fixed_8x8"));
}

#[test]
fn best_kernel_beats_monolithic_analog_by_about_2x() {
    // paper abstract: "twice the performance of a proprietary code ...
    // mapped to GPUs using OpenACC". Our monolithic analog is a branchy
    // single kernel; the model's stand-in is the worst non-pathological
    // 3D variant. Check the best kernel gains a factor ~>=1.4 over the
    // naive gmem_4x4x4-style baseline.
    let a = v100();
    let best_t = time(&a, "gmem_8x8x8");
    let naive = time(&a, "gmem_4x4x4");
    assert!(naive / best_t > 1.3, "{}", naive / best_t);
}

#[test]
fn rank_agreement_beats_chance_by_a_wide_margin() {
    for m in ["v100", "p100", "nvs510"] {
        let tau = report::rank_agreement(m, 100).unwrap();
        assert!(tau > 0.75, "{m}: only {tau}");
    }
}

#[test]
fn occupancy_matches_every_table_iii_row_exactly() {
    let a = v100();
    for v in kernels::paper_variants() {
        let p = paperdata::table3_row(v.id).unwrap();
        let occ = occupancy::occupancy(&a, &v.resources_inner());
        assert_eq!(occ.active_warps as f64, p.theoretical_warps, "{}", v.id);
    }
}

#[test]
fn inner_grid_sizes_match_every_table_iii_row() {
    // one intentional deviation: the paper prints 851,400 for
    // gmem_32x32x1 where ceil-division of its own inner extent gives
    // 853,200 (inconsistent with its 16x16x4 row); we follow the math.
    let inner = hostencil::grid::Dim3::new(948, 948, 948);
    for v in kernels::paper_variants() {
        let p = paperdata::table3_row(v.id).unwrap();
        let got = v.grid_blocks(inner);
        if v.id == "gmem_32x32x1" {
            assert_eq!(got, 853_200);
            continue;
        }
        assert_eq!(got, p.grid_size, "{}", v.id);
    }
}

#[test]
fn table4_model_tracks_paper_arithmetic_intensity() {
    // AI correlates strongly: model and paper must order the gmem and
    // streaming families identically on L2 arithmetic intensity.
    let a = v100();
    let runs = timing::simulate_all(&a, 100);
    let ai = |id: &str| runs.iter().find(|r| r.variant_id == id).unwrap().ai_l2;
    let pai = |id: &str| paperdata::table4_row(id).unwrap().ai_l2;
    for (x, y) in [
        ("gmem_8x8x8", "gmem_32x32x1"),
        ("st_smem_16x16", "st_smem_8x8"),
        ("st_reg_shft_32x16", "st_reg_shft_8x8"),
        ("st_smem_16x16", "gmem_8x8x8"),
    ] {
        assert_eq!(
            ai(x) > ai(y),
            pai(x) > pai(y),
            "AI ordering of {x} vs {y} disagrees with the paper"
        );
    }
}

#[test]
fn dram_percent_of_peak_in_paper_band_for_best_kernels() {
    // paper: tuned kernels achieve ~40-60% of the DRAM roofline; the
    // model must land its best kernels in that band too.
    let a = v100();
    let runs = timing::simulate_all(&a, 100);
    let r = runs.iter().find(|r| r.variant_id == "gmem_8x8x8").unwrap();
    assert!(
        (30.0..75.0).contains(&r.pct_of_dram_peak),
        "{}",
        r.pct_of_dram_peak
    );
}

#[test]
fn eta_smem_pays_on_v100_helps_on_nvs510() {
    // paper Table II: smem_eta_1 is slightly slower than gmem_8x8x8 on
    // V100 (54.87 vs 53.88) but faster on NVS510 (397 vs 415).
    assert!(time(&v100(), "smem_eta_1") > time(&v100(), "gmem_8x8x8"));
    assert!(time(&nvs510(), "smem_eta_1") < time(&nvs510(), "gmem_8x8x8"));
}
