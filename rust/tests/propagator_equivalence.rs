//! Integration: golden-equivalence of the executable CPU code shapes.
//!
//! Every propagator family must reproduce the golden physics across
//! random velocity models, odd-shaped (non-tile-aligned) grids, and
//! multi-source runs. The tiled and streaming shapes keep the golden
//! per-point arithmetic ordering, so they are held to *bitwise*
//! equality; semi-stencil re-associates the x-axis chain by design and
//! is held to a few-ULP relative tolerance.

use hostencil::coordinator::{Coordinator, Mode};
use hostencil::grid::{Dim3, Field3};
use hostencil::stencil::{self, GoldenPropagator};
use hostencil::testkit::check;
use hostencil::wave::{self, Source, VelocityModel};

/// Relative tolerance for the re-associated semi-stencil over a
/// multi-step run (per-step deviation is ULP-level; the leapfrog
/// recursion amplifies it mildly).
const SEMI_RTOL: f32 = 5e-4;

fn grid_domain(interior: Dim3, pml: usize, model: &VelocityModel) -> hostencil::grid::Domain {
    let h = 10.0;
    let v_max = model.v_max_on(interior) as f64;
    hostencil::grid::Domain::new(interior, pml, h, stencil::cfl_dt(h, v_max)).unwrap()
}

/// Run `steps` of golden-mode physics with the given code shape.
fn run_shape(
    variant: &str,
    interior: Dim3,
    pml: usize,
    model: &VelocityModel,
    sources: &[Source],
    steps: usize,
    threads: usize,
) -> Field3 {
    let domain = grid_domain(interior, pml, model);
    let v = model.build(interior);
    let v_max = model.v_max_on(interior) as f64;
    let eta = wave::eta_profile(&domain, v_max);
    let mut c = Coordinator::new(
        None,
        domain,
        Mode::Golden,
        variant,
        "gmem",
        v,
        eta,
        sources[0],
        vec![],
    )
    .unwrap();
    c.set_cpu_threads(threads);
    for s in &sources[1..] {
        c.add_source(*s).unwrap();
    }
    c.run(steps).unwrap();
    c.wavefield()
}

fn center_source(interior: Dim3) -> Source {
    Source {
        pos: Dim3::new(interior.z / 2, interior.y / 2, interior.x / 2),
        f0: 18.0,
        amplitude: 1.0,
    }
}

const EXACT_SHAPES: [&str; 6] = [
    "gmem_8x8x8",
    "gmem_16x16x4",
    "gmem_32x32x1",
    "smem_u",
    "st_smem_8x8",
    "st_reg_fixed_32x32",
];

#[test]
fn every_shape_matches_golden_on_non_tile_aligned_grids() {
    // three odd grid shapes, none a multiple of any tile dimension
    let cases = [
        (Dim3::new(17, 13, 19), 4),
        (Dim3::new(21, 15, 11), 3),
        (Dim3::new(9, 7, 11), 2), // the degenerate tiny-grid shape
    ];
    for (interior, pml) in cases {
        let model = VelocityModel::Constant(2400.0);
        let src = [center_source(interior)];
        let golden = run_shape("naive", interior, pml, &model, &src, 25, 1);
        assert!(golden.max_abs() > 0.0, "{interior}: wave must have propagated");
        for variant in EXACT_SHAPES {
            let got = run_shape(variant, interior, pml, &model, &src, 25, 2);
            assert_eq!(
                got.max_abs_diff(&golden),
                0.0,
                "{variant} on {interior} deviated from golden"
            );
        }
        let semi = run_shape("semi", interior, pml, &model, &src, 25, 2);
        let rel = semi.max_abs_diff(&golden) / golden.max_abs().max(1e-30);
        assert!(rel < SEMI_RTOL, "semi on {interior}: rel {rel}");
    }
}

#[test]
fn fused_family_matches_golden_bitwise_at_every_degree() {
    // temporal fusion sweeps memory once per s steps, but every point
    // still takes its own region's update in golden arithmetic order
    // and sources inject between virtual sub-steps — so the final
    // wavefield must be *bit-identical* to the per-step golden run,
    // on odd (non-tile-aligned) grids, with multi-source injection,
    // at 25 steps (which no supported degree divides: the tail-batch
    // path is always exercised).
    let cases = [
        (Dim3::new(17, 13, 19), 4),
        (Dim3::new(21, 15, 11), 3),
        (Dim3::new(9, 7, 11), 2), // the degenerate tiny-grid shape
    ];
    for (interior, pml) in cases {
        let model = VelocityModel::Constant(2400.0);
        // multi-source: center plus an antiphase source in the PML band
        let sources = [
            center_source(interior),
            Source { pos: Dim3::new(1, 1, 2), f0: 22.0, amplitude: -0.7 },
        ];
        let golden = run_shape("naive", interior, pml, &model, &sources, 25, 1);
        assert!(golden.max_abs() > 0.0, "{interior}: wave must have propagated");
        for variant in ["tf_s2", "tf_s4"] {
            for threads in [1, 3] {
                let got = run_shape(variant, interior, pml, &model, &sources, 25, threads);
                assert_eq!(
                    got.max_abs_diff(&golden),
                    0.0,
                    "{variant} on {interior} ({threads} threads) deviated from golden"
                );
            }
        }
        // the degree-1 control rides the plain streaming shape and
        // must agree too
        let ctl = run_shape("tf_s1", interior, pml, &model, &sources, 25, 2);
        assert_eq!(ctl.max_abs_diff(&golden), 0.0, "tf_s1 control on {interior}");
    }
}

#[test]
fn naive_coordinator_agrees_with_golden_propagator_exactly() {
    // ties the engine to the pre-refactor oracle: same physics, same
    // bits, including the source-injection path
    let interior = Dim3::new(19, 17, 15);
    let model = VelocityModel::Constant(2000.0);
    let domain = grid_domain(interior, 4, &model);
    let src = center_source(interior);
    let mut oracle = GoldenPropagator::new(
        domain,
        model.build(interior),
        wave::eta_profile(&domain, 2000.0),
    );
    for n in 0..30 {
        oracle.advance(src.pos, src.amp_at(n, domain.dt, 2000.0));
    }
    for variant in ["naive", "gmem_8x8x8", "st_smem_16x16"] {
        let got = run_shape(variant, interior, 4, &model, &[src], 30, 3);
        assert_eq!(
            got.max_abs_diff(&oracle.wavefield()),
            0.0,
            "{variant} vs GoldenPropagator"
        );
    }
}

#[test]
fn prop_random_models_grids_and_sources_stay_equivalent() {
    check("propagator equivalence", 4, |rng| {
        let pml = rng.range(2, 4);
        let interior = Dim3::new(
            rng.range(2 * pml + 3, 21),
            rng.range(2 * pml + 3, 21),
            rng.range(2 * pml + 3, 21),
        );
        let model = match rng.range(0, 2) {
            0 => VelocityModel::Constant(rng.range_f32(1800.0, 3200.0)),
            1 => VelocityModel::GradientZ {
                v0: rng.range_f32(1500.0, 2000.0),
                k_per_m: rng.range_f32(0.2, 1.5),
                h: 10.0,
            },
            _ => VelocityModel::Layered(vec![
                (0.0, rng.range_f32(1500.0, 2000.0)),
                (0.5, rng.range_f32(2500.0, 4000.0)),
            ]),
        };
        // multi-source: 1-3 sources, one possibly antiphase
        let mut sources = vec![center_source(interior)];
        for _ in 0..rng.range(0, 2) {
            sources.push(Source {
                pos: Dim3::new(
                    rng.range(pml, interior.z - pml - 1),
                    rng.range(pml, interior.y - pml - 1),
                    rng.range(pml, interior.x - pml - 1),
                ),
                f0: 22.0,
                amplitude: if rng.range(0, 1) == 0 { 1.0 } else { -0.7 },
            });
        }
        let steps = 12;
        let golden = run_shape("naive", interior, pml, &model, &sources, steps, 1);
        for variant in ["gmem_8x8x8", "st_smem_8x8"] {
            let got = run_shape(variant, interior, pml, &model, &sources, steps, 2);
            assert_eq!(
                got.max_abs_diff(&golden),
                0.0,
                "{variant} on {interior} pml {pml}"
            );
        }
        let semi = run_shape("semi", interior, pml, &model, &sources, steps, 2);
        let rel = semi.max_abs_diff(&golden) / golden.max_abs().max(1e-30);
        assert!(rel < SEMI_RTOL, "semi on {interior} pml {pml}: rel {rel}");
    });
}
