//! Integration: the full coordinator over PJRT artifacts — every launch
//! topology must agree with the golden CPU propagator while actually
//! propagating a wave.

use hostencil::coordinator::{Coordinator, Mode};
use hostencil::grid::Dim3;
use hostencil::runtime::Engine;
use hostencil::wave::{self, Source, VelocityModel};

fn engine() -> Option<Engine> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return None;
    }
    Some(Engine::load("artifacts").expect("engine loads"))
}

fn coordinator<'e>(
    eng: Option<&'e Engine>,
    mode: Mode,
    inner_variant: &str,
    pml_variant: &str,
) -> Coordinator<'e> {
    let domain = match eng {
        Some(e) => e.manifest().domain,
        None => panic!("tests here always pass an engine for domain"),
    };
    let model = VelocityModel::Constant(2500.0);
    let v = model.build(domain.interior);
    let eta = wave::eta_profile(&domain, 2500.0);
    let c = domain.interior.z / 2;
    let src = Source { pos: Dim3::new(c, c, c), f0: 15.0, amplitude: 1.0 };
    let recv = vec![Dim3::new(domain.pml_width + 1, c, c)];
    Coordinator::new(eng, domain, mode, inner_variant, pml_variant, v, eta, src, recv).unwrap()
}

fn golden<'e>(eng: &'e Engine) -> Coordinator<'e> {
    // golden mode, but constructed with the same domain as the artifacts
    let domain = eng.manifest().domain;
    let model = VelocityModel::Constant(2500.0);
    let v = model.build(domain.interior);
    let eta = wave::eta_profile(&domain, 2500.0);
    let c = domain.interior.z / 2;
    let src = Source { pos: Dim3::new(c, c, c), f0: 15.0, amplitude: 1.0 };
    let recv = vec![Dim3::new(domain.pml_width + 1, c, c)];
    Coordinator::new(
        None,
        domain,
        Mode::Golden,
        "gmem",
        "gmem",
        v,
        eta,
        src,
        recv,
    )
    .unwrap()
}

const STEPS: usize = 8;

fn assert_close(a: &mut Coordinator, b: &mut Coordinator, label: &str) {
    for _ in 0..STEPS {
        a.step().unwrap();
        b.step().unwrap();
    }
    let ua = a.wavefield();
    let ub = b.wavefield();
    assert!(ua.max_abs() > 0.0, "{label}: wave must have propagated");
    let rel = ua.max_abs_diff(&ub) / ub.max_abs().max(1e-30);
    assert!(rel < 1e-4, "{label}: rel diff {rel}");
}

#[test]
fn decomposed_pjrt_matches_golden_for_every_variant_pair() {
    let Some(eng) = engine() else { return };
    for inner in eng.manifest().inner_variants() {
        for pml in eng.manifest().pml_variants() {
            let mut pjrt = coordinator(Some(&eng), Mode::Decomposed, inner, &pml);
            let mut gold = golden(&eng);
            assert_close(&mut pjrt, &mut gold, &format!("{inner}/{pml}"));
        }
    }
}

#[test]
fn monolithic_and_fused_match_decomposed() {
    let Some(eng) = engine() else { return };
    let mut mono = coordinator(Some(&eng), Mode::Monolithic, "gmem", "gmem");
    let mut fused = coordinator(Some(&eng), Mode::Fused, "gmem", "gmem");
    let mut deco = coordinator(Some(&eng), Mode::Decomposed, "gmem", "gmem");
    for _ in 0..STEPS {
        mono.step().unwrap();
        fused.step().unwrap();
        deco.step().unwrap();
    }
    let ud = deco.wavefield();
    let scale = ud.max_abs().max(1e-30);
    assert!(mono.wavefield().max_abs_diff(&ud) / scale < 1e-4);
    assert!(fused.wavefield().max_abs_diff(&ud) / scale < 1e-4);
    // launch accounting: decomposed does 7x the launches
    assert_eq!(deco.launches(), 7 * STEPS as u64);
    assert_eq!(mono.launches(), STEPS as u64);
}

#[test]
fn receivers_record_the_arriving_wave() {
    let Some(eng) = engine() else { return };
    let mut c = coordinator(Some(&eng), Mode::Decomposed, "gmem", "smem_eta_1");
    let summary = c.run(60).unwrap();
    assert_eq!(summary.traces.len(), 1);
    assert_eq!(summary.traces[0].len(), 60);
    // the wave eventually reaches the shallow receiver
    let max_amp = summary.traces[0].iter().fold(0.0f32, |a, &b| a.max(b.abs()));
    assert!(max_amp > 0.0, "receiver never saw the wave");
    assert!(summary.energy_log.iter().all(|e| e.is_finite()));
    assert!(summary.points_per_sec > 0.0);
}

#[test]
fn pml_absorbs_energy_through_pjrt() {
    let Some(eng) = engine() else { return };
    let domain = eng.manifest().domain;
    let model = VelocityModel::Constant(2500.0);
    let c = domain.interior.z / 2;
    let src = Source { pos: Dim3::new(c, c, c), f0: 15.0, amplitude: 1.0 };
    // with PML
    let mut damped = Coordinator::new(
        Some(&eng),
        domain,
        Mode::Decomposed,
        "gmem",
        "gmem",
        model.build(domain.interior),
        wave::eta_profile(&domain, 2500.0),
        src,
        vec![],
    )
    .unwrap();
    // without damping (eta = 0): boundary reflects back into the domain
    let mut reflecting = Coordinator::new(
        Some(&eng),
        domain,
        Mode::Decomposed,
        "gmem",
        "gmem",
        model.build(domain.interior),
        hostencil::grid::Field3::zeros(domain.interior),
        src,
        vec![],
    )
    .unwrap();
    // enough steps for the front to hit the boundary and come back
    let s1 = damped.run(160).unwrap();
    let s2 = reflecting.run(160).unwrap();
    assert!(
        s1.final_energy < 0.6 * s2.final_energy,
        "PML must absorb: {} vs {}",
        s1.final_energy,
        s2.final_energy
    );
}

#[test]
fn mismatched_domain_is_rejected() {
    let Some(eng) = engine() else { return };
    let mut domain = eng.manifest().domain;
    domain.interior = Dim3::new(
        domain.interior.z + 8,
        domain.interior.y,
        domain.interior.x,
    );
    let model = VelocityModel::Constant(2500.0);
    let err = Coordinator::new(
        Some(&eng),
        domain,
        Mode::Decomposed,
        "gmem",
        "gmem",
        model.build(domain.interior),
        hostencil::grid::Field3::zeros(domain.interior),
        Source { pos: Dim3::new(4, 4, 4), f0: 15.0, amplitude: 1.0 },
        vec![],
    );
    assert!(err.is_err(), "domain mismatch must be rejected before launch");
}

#[test]
fn unknown_variant_is_rejected_at_construction() {
    let Some(eng) = engine() else { return };
    let domain = eng.manifest().domain;
    let model = VelocityModel::Constant(2500.0);
    let err = Coordinator::new(
        Some(&eng),
        domain,
        Mode::Decomposed,
        "warp_specialized",
        "gmem",
        model.build(domain.interior),
        hostencil::grid::Field3::zeros(domain.interior),
        Source { pos: Dim3::new(4, 4, 4), f0: 15.0, amplitude: 1.0 },
        vec![],
    );
    assert!(err.is_err());
}

#[test]
fn shipped_example_config_loads_and_runs() {
    let Some(eng) = engine() else { return };
    let cfg = hostencil::config::RunConfig::load("examples/configs/survey.toml")
        .expect("shipped config parses");
    assert_eq!(cfg.inner_variant, "st_reg_fixed");
    assert_eq!(cfg.receivers.len(), 12);
    assert!(matches!(cfg.model, VelocityModel::Layered(_)));
    // the artifact domain wins (dt was baked at AOT time) — same policy
    // as the CLI run command
    let domain = eng.manifest().domain;
    assert_eq!(domain.interior, cfg.domain.interior);
    // run a few steps through the real engine
    let v = cfg.model.build(domain.interior);
    let v_max = v.as_slice().iter().fold(0.0f32, |a, &b| a.max(b)) as f64;
    let eta = wave::eta_profile(&domain, v_max);
    let mut c = Coordinator::new(
        Some(&eng),
        domain,
        cfg.mode,
        &cfg.inner_variant,
        &cfg.pml_variant,
        v,
        eta,
        cfg.source,
        cfg.receivers,
    )
    .unwrap();
    let s = c.run(5).unwrap();
    assert_eq!(s.launches, 35);
    assert!(s.final_max_abs.is_finite());
}
