//! Integration: the chaos-harness invariants at the crate's public
//! surface. Two families of checks:
//!
//! 1. **Exhaustive snapshot corruption** — every single-byte flip and
//!    every truncation length of a serialized checkpoint must produce
//!    a named error, never a panic and never a silent accept. The
//!    trailing checksum is verified before any length field is
//!    trusted, so no corrupted header can drive a giant allocation.
//! 2. **Fault injection end-to-end** — the CLI-level chaos contract
//!    driven through the public coordinator API: an injected transport
//!    fault either heals to a bit-identical completion or soft-aborts
//!    with a checkpoint that restores and reconverges; a corrupted
//!    retention-ring slot is skipped by checksum and the fallback slot
//!    resumes onto the unfaulted trajectory.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::time::Duration;

use hostencil::coordinator::{Coordinator, Mode};
use hostencil::fault::{FaultKind, FaultPlan, FaultSite};
use hostencil::grid::{Dim3, Domain};
use hostencil::recovery::{self, Checkpoint};
use hostencil::stencil;
use hostencil::wave::{self, Source, VelocityModel};

/// A compact snapshot with every section non-empty, so the exhaustive
/// sweeps cover header, ragged traces, energy log, and both buffers.
fn small_checkpoint() -> Checkpoint {
    Checkpoint {
        interior: Dim3::new(2, 3, 4),
        pml_width: 1,
        h: 10.0,
        dt: 1.25e-3,
        steps_done: 7,
        launches: 49,
        traces: vec![vec![0.5, -0.25, 0.125], vec![-1.0]],
        energy_log: vec![1.0, 2.5, 0.75],
        u_pad: (0..24).map(|i| i as f32 * 0.5).collect(),
        um_pad: (0..24).map(|i| -(i as f32) * 0.25).collect(),
    }
}

#[test]
fn every_byte_flip_of_a_snapshot_is_a_named_error_never_a_panic() {
    let bytes = small_checkpoint().to_bytes();
    Checkpoint::from_bytes(&bytes).expect("the pristine snapshot must parse");
    for i in 0..bytes.len() {
        let mut mutated = bytes.clone();
        mutated[i] ^= 0xFF;
        let outcome =
            catch_unwind(AssertUnwindSafe(|| Checkpoint::from_bytes(&mutated).map(|_| ())));
        match outcome {
            Ok(Ok(())) => panic!("flipping byte {i} was accepted silently"),
            Ok(Err(e)) => {
                let msg = e.to_string();
                assert!(!msg.is_empty(), "flip at byte {i} produced an unnamed error");
            }
            Err(_) => panic!("flipping byte {i} panicked the parser"),
        }
    }
}

#[test]
fn every_truncation_of_a_snapshot_is_a_named_error_never_a_panic() {
    let bytes = small_checkpoint().to_bytes();
    for len in 0..bytes.len() {
        let cut = &bytes[..len];
        let outcome = catch_unwind(AssertUnwindSafe(|| Checkpoint::from_bytes(cut).map(|_| ())));
        match outcome {
            Ok(Ok(())) => panic!("truncating to {len} bytes was accepted silently"),
            Ok(Err(e)) => {
                let msg = e.to_string();
                assert!(
                    msg.contains("too short")
                        || msg.contains("checksum")
                        || msg.contains("truncated"),
                    "truncation to {len} bytes: unexpected error {msg:?}"
                );
            }
            Err(_) => panic!("truncating to {len} bytes panicked the parser"),
        }
    }
}

#[test]
fn extended_snapshots_are_rejected_too() {
    // appended garbage breaks the trailing checksum; appended zeros
    // after a recomputed checksum would still fail the exact-length
    // check — either way, never a panic
    let mut bytes = small_checkpoint().to_bytes();
    bytes.extend_from_slice(&[0u8; 16]);
    let err = Checkpoint::from_bytes(&bytes).unwrap_err().to_string();
    assert!(err.contains("checksum"), "{err}");
}

/// The shared sharded configuration for the end-to-end fault legs:
/// fused degree 2, two z-slab shards, two worker threads.
fn chaos_coordinator() -> Coordinator<'static> {
    let interior = Dim3::new(20, 12, 12);
    let h = 10.0;
    let v0 = 2500.0f32;
    let domain = Domain::new(interior, 4, h, stencil::cfl_dt(h, v0 as f64)).unwrap();
    let v = VelocityModel::Constant(v0).build(interior);
    let eta = wave::eta_profile(&domain, v0 as f64);
    let src = Source { pos: Dim3::new(10, 6, 6), f0: 15.0, amplitude: 1.0 };
    let mut c = Coordinator::new(
        None,
        domain,
        Mode::Golden,
        "tf_s2",
        "gmem",
        v,
        eta,
        src,
        vec![Dim3::new(5, 6, 6)],
    )
    .unwrap();
    c.set_cpu_threads(2);
    c.set_shards(2).unwrap();
    c
}

fn scratch_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hostencil_chaosit_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn dropped_halo_band_heals_to_a_bit_identical_completion() {
    let mut oracle = chaos_coordinator();
    oracle.run(12).unwrap();

    let plan = FaultPlan::single(FaultSite::Halo, FaultKind::Drop, 4, 1);
    let mut c = chaos_coordinator();
    c.set_faults(std::sync::Arc::clone(&plan));
    let s = c.run(12).unwrap();
    assert_eq!(s.steps, 12, "the retry seam must absorb a dropped band");
    assert!(c.soft_abort().is_none());
    assert_eq!(plan.injected(FaultSite::Halo), 1, "the drop must actually fire");
    assert_eq!(c.state_digest(), oracle.state_digest(), "healed run must be bit-identical");
}

#[test]
fn stalled_halo_soft_aborts_and_the_checkpoint_resumes_bitwise() {
    let dir = scratch_dir("stall");
    let path = dir.join("trip.ckpt");
    let mut oracle = chaos_coordinator();
    oracle.run(12).unwrap();

    let mut c = chaos_coordinator();
    c.set_checkpointing(0, Some(path.clone()));
    c.set_halo_deadline(Duration::from_millis(5));
    c.set_faults(FaultPlan::single(FaultSite::Halo, FaultKind::Delay, 4, 1));
    let s = c.run(12).unwrap();
    let abort = c.soft_abort().expect("an exhausted exchange deadline must soft-abort");
    assert_eq!(abort.kind.name(), "halo_stall");
    assert!(s.steps < 12);

    let mut resumed = chaos_coordinator();
    let (used, skipped) = resumed.restore_from_ring(&path, 1).unwrap();
    assert_eq!(used, path);
    assert!(skipped.is_empty(), "{skipped:?}");
    assert_eq!(resumed.steps_done(), abort.step, "the trip snapshot holds pre-batch state");
    resumed.run(12 - abort.step).unwrap();
    assert_eq!(
        resumed.state_digest(),
        oracle.state_digest(),
        "restore + resume must reconverge on the unfaulted run"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn ring_fallback_skips_a_corrupt_slot_and_reconverges() {
    let dir = scratch_dir("ring");
    let path = dir.join("run.ckpt");

    // write a two-slot ring at steps 4 and 8, remembering the final
    // digest at step 12
    let mut writer = chaos_coordinator();
    writer.set_checkpointing(4, Some(path.clone()));
    writer.set_checkpoint_keep(2);
    writer.run(12).unwrap();
    let want = writer.state_digest();
    let ring = recovery::ring_paths(&path, 2);
    assert_eq!(Checkpoint::load(&ring[0]).unwrap().steps_done, 12);
    assert_eq!(Checkpoint::load(&ring[1]).unwrap().steps_done, 8);

    // a reader armed with restore-time corruption: the newest slot is
    // flipped, detected by checksum, and skipped with a note
    let mut r = chaos_coordinator();
    r.set_faults(FaultPlan::single(FaultSite::Restore, FaultKind::Corrupt, 0, 1));
    let (used, skipped) = r.restore_from_ring(&path, 2).unwrap();
    assert_eq!(used, ring[1], "the fallback must land on the older slot");
    assert_eq!(skipped.len(), 1, "{skipped:?}");
    assert!(skipped[0].contains("checksum"), "{}", skipped[0]);
    assert_eq!(r.steps_done(), 8);
    r.run(4).unwrap();
    assert_eq!(r.state_digest(), want, "the fallback slot must resume onto the trajectory");
    let _ = std::fs::remove_dir_all(&dir);
}
