//! Zero-allocation steady state for the **sharded** engine: once the
//! per-shard plans are warm, a `ShardedEngine::advance_batch` — tile
//! sweeps on every shard, the three barrier phases, both halo-band
//! publishes/collects through the in-process transport, telemetry
//! bumps, and the periodic `gather_into` a coordinator performs at
//! batch boundaries — must not touch the heap at all, at 1, 2 and 3
//! shards alike.
//!
//! Same discipline as `zero_alloc.rs`: a counting `#[global_allocator]`
//! wraps the system allocator, exactly one test lives in this binary
//! (the counter is process-global), and the counter sees every thread,
//! so the outer shard workers and the inner tile pools are under the
//! same microscope as the caller. The in-process transport's mailbox
//! bands are allocated once at engine build; armed exchanges are
//! `copy_from_slice` into those standing buffers plus atomic counter
//! bumps and a histogram observation into preallocated buckets.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use hostencil::grid::{Dim3, Domain, Field3};
use hostencil::shard::ShardedEngine;
use hostencil::stencil::{self, SourceBatch};
use hostencil::telemetry::Registry;
use hostencil::wave;
use hostencil::R;

struct CountingAllocator;

static ARMED: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicU64 = AtomicU64::new(0);

impl CountingAllocator {
    #[inline]
    fn count() {
        if ARMED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
    }
}

unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        Self::count();
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        Self::count();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        Self::count();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAllocator = CountingAllocator;

/// Run `steps` warm sharded steps (in batches of the fusion degree,
/// with a gather after every batch, the way the coordinator drives the
/// engine) and return how many heap allocations they performed.
fn allocs_in_sharded_steady_state(domain: &Domain, shards: usize, steps: usize) -> u64 {
    let fuse = 2;
    let interior = domain.interior;
    let v = Field3::full(interior, 2000.0);
    let eta = wave::eta_profile(domain, 2000.0);
    let telemetry = Registry::new();
    let mut engine =
        ShardedEngine::new(domain, &v, &eta, fuse, shards, 3, Some(&telemetry)).expect("engine");

    let mut u_pad = Field3::zeros(domain.padded());
    u_pad.set(R + interior.z / 2, R + interior.y / 2, R + interior.x / 2, 1.0);
    let mut um_pad = Field3::zeros(domain.padded());
    engine.load(&u_pad, &um_pad);

    // multi-source schedule, one point near the 3-shard seam plane;
    // buffers sized for the largest batch and built before arming (the
    // coordinator reuses its schedule buffers the same way)
    let positions = [
        Dim3::new(interior.z / 2, interior.y / 2, interior.x / 2),
        Dim3::new(2 * interior.z / 3, 2 * interior.y / 3, 2 * interior.x / 3),
    ];
    let amps = vec![1e-3f32; fuse * positions.len()];
    let advance = |engine: &mut ShardedEngine, n: usize| {
        let mut done = 0;
        while done < n {
            let b = fuse.min(n - done);
            let batch =
                SourceBatch { positions: &positions, amps: &amps[..b * positions.len()], n_steps: b };
            engine.advance_batch(&batch);
            done += b;
        }
    };

    // engine build already did the heavy lifting (plans, scratch,
    // pools, mailbox bands, telemetry registration — all before the
    // counter arms); a couple of warm batches settle anything lazy
    advance(&mut engine, 2 * fuse);
    engine.gather_into(&mut u_pad, &mut um_pad);

    ALLOCS.store(0, Ordering::SeqCst);
    ARMED.store(true, Ordering::SeqCst);
    advance(&mut engine, steps);
    engine.gather_into(&mut u_pad, &mut um_pad);
    ARMED.store(false, Ordering::SeqCst);

    assert!(
        u_pad.max_abs() > 0.0 && !u_pad.has_non_finite(),
        "{shards} shard(s): steady-state wave must stay finite and non-zero"
    );
    let rendered = telemetry.render();
    assert!(
        rendered.contains("hostencil_plan_builds_total{family=\"shard\"}"),
        "{shards} shard(s): warm-up must have built instrumented per-shard plans"
    );
    if shards > 1 {
        assert!(
            rendered.contains("hostencil_halo_exchanges_total"),
            "{shards} shard(s): halo exchange instrumentation must be live"
        );
        assert!(
            !rendered.contains("hostencil_halo_exchanges_total 0"),
            "{shards} shard(s): warm batches must have exchanged halos"
        );
    }
    ALLOCS.load(Ordering::SeqCst)
}

#[test]
fn sharded_steady_state_performs_zero_heap_allocations() {
    // 24 z-planes, fuse 2 (8-deep halos): 1 shard owns 24, 2 shards
    // own 12/12, 3 shards own 8/8/8 — the thinnest legal slabs, so the
    // halo bands cover entire neighbor slabs and the exchange volume
    // is maximal relative to the grid
    let h = 10.0;
    let domain =
        Domain::new(Dim3::new(24, 17, 21), 3, h, stencil::cfl_dt(h, 2000.0)).expect("domain");

    for shards in [1, 2, 3] {
        let n = allocs_in_sharded_steady_state(&domain, shards, 8);
        assert_eq!(
            n, 0,
            "{shards} shard(s): {n} heap allocations in 8 steady-state sharded steps"
        );
    }
}
